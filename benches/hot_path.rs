//! Bench: L3 hot-path microbenchmarks — per-document work in the placer
//! (top-K offer, ledger charge, feature extraction, native scoring) and
//! PJRT scoring by batch size. These are the targets of the §Perf pass.

use shptier::benchkit::Bencher;
use shptier::cost::PerDocCosts;
use shptier::interestingness::{extract, RbfScorer};
use shptier::runtime::{Manifest, PjrtScorer};
use shptier::storage::{StorageSim, TierId};
use shptier::topk::{BoundedTopK, FullRankTracker, Scored};
use shptier::util::Rng;

fn main() {
    println!("== hot_path benches ==");
    let mut b = Bencher::from_env();

    // ---- top-K trackers ---------------------------------------------------
    let mut rng = Rng::new(1);
    let stream: Vec<f64> = (0..100_000).map(|_| rng.next_f64()).collect();
    b.bench("bounded_topk_offer/K=100,N=100k", stream.len() as u64, || {
        let mut t = BoundedTopK::new(100);
        for (i, &s) in stream.iter().enumerate() {
            t.offer(Scored::new(i as u64, s));
        }
        t.len()
    });
    b.bench("bounded_topk_offer/K=10000,N=100k", stream.len() as u64, || {
        let mut t = BoundedTopK::new(10_000);
        for (i, &s) in stream.iter().enumerate() {
            t.offer(Scored::new(i as u64, s));
        }
        t.len()
    });
    let small: Vec<f64> = stream[..10_000].to_vec();
    b.bench("full_rank_insert/N=10k", small.len() as u64, || {
        let mut t = FullRankTracker::with_capacity(small.len());
        for (i, &s) in small.iter().enumerate() {
            t.insert(Scored::new(i as u64, s));
        }
        t.len()
    });

    // ---- storage sim ops ----------------------------------------------------
    let costs = PerDocCosts { write: 1e-6, read: 1e-6, rent_window: 1e-5 };
    b.bench("storage_put_delete/10k ops", 10_000, || {
        let mut sim = StorageSim::two_tier(costs, costs, true);
        for d in 0..5_000u64 {
            sim.put(d, TierId::A, 0.1).unwrap();
        }
        for d in 0..5_000u64 {
            sim.delete(d, 0.9).unwrap();
        }
        sim.ledger().total()
    });

    // ---- native scoring -----------------------------------------------------
    let series: Vec<f32> = (0..256)
        .map(|i| 100.0 + 50.0 * (i as f32 * 0.2).sin())
        .collect();
    b.bench("feature_extract/T=256", 1, || extract(&series));
    let scorer = RbfScorer::synthetic_demo();
    b.bench("native_score/T=256,S=2", 1, || scorer.score_series(&series));

    // manifest-weighted scorer (64 SVs) if artifacts are built
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        let manifest = Manifest::load(dir).expect("manifest");
        let full = manifest.scorer.clone();
        b.bench("native_score/T=256,S=64", 1, || full.score_series(&series));

        // ---- PJRT scoring by batch size -----------------------------------
        let pjrt = PjrtScorer::from_manifest(&manifest).expect("pjrt");
        for batch in [1usize, 16, 64, 256] {
            let rows: Vec<Vec<f32>> = (0..batch).map(|_| series.clone()).collect();
            b.bench(&format!("pjrt_score/batch={batch}"), batch as u64, || {
                pjrt.score(&rows).unwrap()
            });
        }
    } else {
        println!("(artifacts missing — skipping PJRT benches; run `make artifacts`)");
    }
}
