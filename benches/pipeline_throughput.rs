//! Bench: end-to-end pipeline throughput (docs/s) by stage configuration —
//! the paper-system headline performance number, and the SSA producer in
//! isolation (the expected bottleneck).

use shptier::benchkit::Bencher;
use shptier::config::LaunchConfig;
use shptier::interestingness::RbfScorer;
use shptier::pipeline::{run_pipeline, PipelineConfig, ScorerFactory};
use shptier::runtime::{NativeScorer, Scorer};
use shptier::ssa::{oscillator_at, oscillator_sweep, simulate};
use shptier::util::Rng;

fn native_factory() -> ScorerFactory {
    Box::new(|| Ok(Box::new(NativeScorer::new(RbfScorer::synthetic_demo())) as Box<dyn Scorer>))
}

fn main() {
    println!("== pipeline_throughput benches ==");
    let mut b = Bencher::from_env();

    // ---- producer in isolation: one SSA document --------------------------
    let grid = oscillator_sweep(4, 1);
    let mut rng = Rng::new(3);
    let mut point = 0u64;
    b.bench("ssa_document/T=256,t_end=60", 1, || {
        point = (point + 1) % grid.points();
        let net = oscillator_at(&grid.point(point));
        simulate(&net, 60.0, 256, 50_000_000, &mut rng).firings
    });

    // ---- full pipeline, native scorer, by producer count -------------------
    let base = LaunchConfig::from_toml("[workload]\nn_docs = 1000\n").unwrap();
    for producers in [1usize, 2, 4, 8] {
        let config = PipelineConfig {
            n_docs: 1000,
            producers,
            record_series: false,
            record_scores: false,
            ..PipelineConfig::default()
        };
        let grid = oscillator_sweep(4, 1);
        b.bench(&format!("pipeline_1000docs/producers={producers}"), 1000, || {
            let mut policy = base.policy.instantiate(&base.model);
            run_pipeline(&config, &grid, &base.model, policy.as_mut(), native_factory())
                .unwrap()
                .docs_processed
        });
    }

    // ---- batching ablation --------------------------------------------------
    for batch_max in [1usize, 16, 256] {
        let config = PipelineConfig {
            n_docs: 500,
            producers: 4,
            batch_max,
            record_series: false,
            record_scores: false,
            ..PipelineConfig::default()
        };
        let grid = oscillator_sweep(4, 1);
        b.bench(&format!("pipeline_500docs/batch_max={batch_max}"), 500, || {
            let mut policy = base.policy.instantiate(&base.model);
            run_pipeline(&config, &grid, &base.model, policy.as_mut(), native_factory())
                .unwrap()
                .docs_processed
        });
    }
}
