//! Bench: fleet scheduler throughput — aggregate docs/sec vs stream count
//! (M ∈ {1, 4, 16, 64}), vs worker-pool size on a 16-stream fleet (the
//! scaling acceptance criterion: ≥ 4× from 1 → 8 workers), vs worker-pool
//! size on a deliberately *skewed* fleet (the ADR-008 work-stealing
//! criterion: ≥ 3× from 1 → 8 workers despite lumpy stream lengths, with
//! a bitwise-identical report digest at every worker count — a digest
//! mismatch fails the bench outright), vs storage backend, with the
//! ADR-007 adaptive arbiter off/on (its overhead dimension), journaled
//! ops/sec on a sync fs backend with per-op appends vs group commit (the
//! ADR-009 acceptance criterion: ≥ 10×), and the admission-selector
//! dimension (ADR-010: bounded heap vs log-memory sketch at K ∈ {1e3,
//! 1e5} — logmem must fit ≥ 10× more streams per GB of selector state).
//!
//! Set `SHPTIER_BENCH_RECORD=1` to write the results as a baseline JSON to
//! `benches/baselines/fleet_throughput.json` (see that file for the
//! schema); `SHPTIER_BENCH_QUICK=1` shrinks the time budget for CI.
//!
//! Without `SHPTIER_BENCH_RECORD`, the run compares its throughput against
//! the recorded baseline: any benchmark slower than
//! `SHPTIER_BENCH_TOLERANCE` (default 0.25, i.e. a 4× regression) times the
//! baseline docs/sec is reported, and with `SHPTIER_BENCH_CHECK=1` (the CI
//! gate) the process exits non-zero. A placeholder baseline (empty
//! `results`) skips the comparison with a notice in dev runs, but is itself
//! **fatal** under `SHPTIER_BENCH_CHECK=1`: a checked run that compares
//! nothing protects nothing, so CI records a baseline on the runner before
//! checking. The tolerance is deliberately loose because CI hardware
//! differs from the recording host; the gate exists to catch
//! order-of-magnitude regressions, not noise.

use shptier::benchkit::{BenchResult, Bencher};
use shptier::cost::hot_demand;
use shptier::engine::BackendSpec;
use shptier::fleet::{demo_fleet, run_fleet, skewed_fleet, FleetConfig, FleetMode};
use shptier::cost::PerDocCosts;
use shptier::serdes::Json;
use shptier::storage::{FsBackend, StorageBackend, TierId};
use std::collections::BTreeMap;

const DOCS_PER_STREAM: u64 = 500;

fn fleet_config(workers: usize, hot_capacity: u64) -> FleetConfig {
    FleetConfig {
        hot_capacity,
        workers,
        channel_capacity: 256,
        batch: 16,
        t_len: 256,
        seed: 1,
        mode: FleetMode::Arbitrated,
        ..FleetConfig::default()
    }
}

fn contended_capacity(specs: &[shptier::fleet::StreamSpec]) -> u64 {
    let demand: u64 = specs.iter().map(|s| hot_demand(&s.model, false)).sum();
    (demand / 2).max(1)
}

fn main() {
    println!("== fleet_throughput benches ==");
    let mut b = Bencher::from_env();

    // ---- aggregate throughput by stream count (fixed 4 workers) ----------
    for m in [1usize, 4, 16, 64] {
        let specs = demo_fleet(m, DOCS_PER_STREAM, 16, true, 1);
        let total: u64 = specs.iter().map(|s| s.model.n).sum();
        let cfg = fleet_config(4, contended_capacity(&specs));
        b.bench(&format!("fleet_docs/streams={m},workers=4"), total, || {
            run_fleet(&specs, &cfg).unwrap().docs_processed
        });
    }

    // ---- worker scaling on a 16-stream fleet (acceptance: ≥4x @ 8w) ------
    let specs16 = demo_fleet(16, DOCS_PER_STREAM, 16, true, 1);
    let total16: u64 = specs16.iter().map(|s| s.model.n).sum();
    let cap16 = contended_capacity(&specs16);
    for w in [1usize, 2, 4, 8] {
        let cfg = fleet_config(w, cap16);
        b.bench(&format!("fleet_scaling/streams=16,workers={w}"), total16, || {
            run_fleet(&specs16, &cfg).unwrap().docs_processed
        });
    }

    // ---- work stealing on a skewed fleet (ADR-008) -----------------------
    // Every 4th stream is 8× longer, so a fixed partition would leave most
    // workers idle while one grinds through the long tail; stealing keeps
    // them busy. The outcome must not depend on who did the work: every
    // worker count has to land the identical report digest, checked across
    // all timed iterations.
    let skew = skewed_fleet(8, DOCS_PER_STREAM, 8, 3);
    let skew_total: u64 = skew.iter().map(|s| s.model.n).sum();
    let skew_cap = contended_capacity(&skew);
    let mut skew_digests: std::collections::BTreeSet<u64> = std::collections::BTreeSet::new();
    for w in [1usize, 2, 4, 8] {
        let cfg = fleet_config(w, skew_cap);
        let specs = skew.clone();
        let digests = &mut skew_digests;
        b.bench(&format!("fleet_skew/streams=8,workers={w}"), skew_total, move || {
            let report = run_fleet(&specs, &cfg).unwrap();
            digests.insert(report.digest());
            report.docs_processed
        });
    }
    if skew_digests.len() != 1 {
        eprintln!(
            "FAIL: work stealing changed the fleet outcome across worker counts \
             (distinct digests: {skew_digests:?})"
        );
        std::process::exit(1);
    }

    // ---- substrate overhead: one small fleet per StorageBackend ----------
    // (sim = accounting only; fs = files + WAL; obj = request-counted
    // keyspace + manifest log). Durable roots are fresh per iteration —
    // the fleet surface refuses stale journals — but their removal is
    // deferred until after the bench so the timed body measures backend
    // work, not directory cleanup.
    let specs4 = demo_fleet(4, 200, 8, true, 1);
    let total4: u64 = specs4.iter().map(|s| s.model.n).sum();
    let cap4 = contended_capacity(&specs4);
    let mut used_roots: Vec<std::path::PathBuf> = Vec::new();
    for backend in ["sim", "fs", "obj"] {
        let specs = specs4.clone();
        let roots = &mut used_roots;
        b.bench(&format!("fleet_backend/streams=4,backend={backend}"), total4, || {
            let mut cfg = fleet_config(1, cap4);
            cfg.backend = match backend {
                "fs" => {
                    let root = shptier::util::scratch_dir("bench-fs");
                    roots.push(root.clone());
                    BackendSpec::Fs { root }
                }
                "obj" => {
                    let root = shptier::util::scratch_dir("bench-obj");
                    roots.push(root.clone());
                    BackendSpec::Obj { root }
                }
                _ => BackendSpec::Sim,
            };
            run_fleet(&specs, &cfg).unwrap().docs_processed
        });
    }
    for root in used_roots {
        let _ = std::fs::remove_dir_all(root);
    }

    // ---- adaptive overhead (ADR-007): drift-aware arbiter vs plain -------
    // The admission estimator/detector run on every session either way;
    // `adaptive=on` additionally arms the bandit arbiter and the
    // drift-triggered re-derivation path. The pair rides the same
    // regression gate as every other dimension, so a slowdown in the
    // always-on observe-path bookkeeping shows up here first.
    for adaptive in [false, true] {
        let specs = specs4.clone();
        let mut cfg = fleet_config(1, cap4);
        cfg.adaptive = adaptive;
        let label = if adaptive { "on" } else { "off" };
        b.bench(&format!("fleet_adaptive/streams=4,adaptive={label}"), total4, || {
            run_fleet(&specs, &cfg).unwrap().docs_processed
        });
    }

    // ---- journaled op throughput (ADR-009): per-op vs group commit -------
    // The honest durability case: the fs backend with sync_writes on, so
    // every per-op append pays its own write+fsync while group commit
    // amortizes the same records into one write+fsync per batch. The op
    // body is reads of a tiny resident set (warm page cache) so journal
    // appends — not payload IO — dominate the timed work. Acceptance:
    // >=10x journaled ops/sec, reported below next to the scaling bars.
    const JOURNAL_OPS: u64 = 192;
    let journal_costs = vec![
        PerDocCosts { write: 1.0, read: 4.0, rent_window: 0.5 },
        PerDocCosts { write: 3.0, read: 0.5, rent_window: 0.1 },
    ];
    let mut journal_roots: Vec<std::path::PathBuf> = Vec::new();
    for mode in ["per-op", "group"] {
        let costs = journal_costs.clone();
        let roots = &mut journal_roots;
        b.bench(&format!("fleet_journal/mode={mode}"), JOURNAL_OPS, move || {
            let root = shptier::util::scratch_dir("bench-journal");
            roots.push(root.clone());
            let mut be = FsBackend::open(&root, costs.clone(), false).unwrap();
            be.set_sync_writes(true);
            if mode == "group" {
                be.set_group_commit(true);
            }
            be.set_attribution(Some(0));
            for d in 0..4 {
                be.put(d, TierId::A, 0.0).unwrap();
            }
            for i in 0..JOURNAL_OPS {
                be.read(i % 4).unwrap();
            }
            be.journal_flush().unwrap();
            JOURNAL_OPS
        });
    }
    for root in journal_roots {
        let _ = std::fs::remove_dir_all(root);
    }

    // ---- selector memory & throughput (ADR-010): bounded vs logmem -------
    // Drive 2K uniform scores through a bare selector at K ∈ {1e3, 1e5}.
    // Offer throughput rides the record+check gate like every other
    // dimension; the resident-bytes comparison below is the ADR-010
    // acceptance bar — at K = 1e5 the log-memory sketch must fit ≥ 10×
    // more concurrent streams per GB of selector state than the exact
    // heap (a miss fails the bench outright, like the skew digest check).
    use shptier::topk::{Scored, SelectorKind};
    let mut selector_bytes: BTreeMap<(u64, &'static str), usize> = BTreeMap::new();
    for k in [1_000u64, 100_000] {
        let n = 2 * k;
        for kind in [SelectorKind::Bounded, SelectorKind::LogMem] {
            let label = kind.label();
            let bytes = &mut selector_bytes;
            b.bench(&format!("fleet_selector/k={k},selector={label}"), n, move || {
                let mut sel = kind.build(k as usize);
                let mut rng = shptier::util::Rng::new(42);
                for i in 0..n {
                    sel.offer(Scored::new(i, rng.next_f64()));
                }
                bytes.insert((k, label), sel.resident_bytes());
                n
            });
        }
    }
    const GB: f64 = 1024.0 * 1024.0 * 1024.0;
    for k in [1_000u64, 100_000] {
        let (Some(&hb), Some(&lb)) = (
            selector_bytes.get(&(k, "bounded")),
            selector_bytes.get(&(k, "logmem")),
        ) else {
            continue;
        };
        println!(
            "selector state at K={k}: bounded {hb} B/stream ({:.0} streams/GB), \
             logmem {lb} B/stream ({:.0} streams/GB) — {:.0}x streams-per-GB",
            GB / hb as f64,
            GB / lb as f64,
            hb as f64 / lb as f64
        );
    }
    if let (Some(&hb), Some(&lb)) = (
        selector_bytes.get(&(100_000, "bounded")),
        selector_bytes.get(&(100_000, "logmem")),
    ) {
        let ratio = hb as f64 / lb as f64;
        if ratio < 10.0 {
            eprintln!(
                "FAIL: logmem streams-per-GB advantage at K=1e5 is {ratio:.1}x, \
                 below the >=10x ADR-010 bar"
            );
            std::process::exit(1);
        }
    }

    report_scaling(b.results());

    // Resolve relative to the crate manifest, not the process CWD: cargo
    // runs bench binaries with CWD = the package root (rust/), while the
    // baseline lives under the repository root's benches/.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("../benches/baselines/fleet_throughput.json");
    let path = path.as_path();
    if std::env::var_os("SHPTIER_BENCH_RECORD").is_some() {
        match std::fs::write(path, baseline_json(b.results()).dump()) {
            Ok(()) => println!("recorded baseline to {}", path.display()),
            Err(e) => println!("could not record baseline: {e}"),
        }
    } else {
        let strict = std::env::var_os("SHPTIER_BENCH_CHECK").is_some();
        match check_against_baseline(path, b.results()) {
            BaselineCheck::Compared(regressions) if regressions.is_empty() => {}
            BaselineCheck::Compared(regressions) => {
                for r in &regressions {
                    println!("REGRESSION: {r}");
                }
                if strict {
                    eprintln!(
                        "bench regression check failed ({} benchmarks below tolerance)",
                        regressions.len()
                    );
                    std::process::exit(1);
                }
            }
            BaselineCheck::SkippedBenign(note) => {
                println!("{note}");
                if strict {
                    // The CI gate must never pass vacuously: "no baseline"
                    // is benign for a dev run, but a checked run that
                    // compares nothing protects nothing.
                    eprintln!(
                        "SHPTIER_BENCH_CHECK=1 expects an armed gate, but the \
                         baseline at {} is missing or still the committed \
                         placeholder. Record one first:\n  SHPTIER_BENCH_RECORD=1 \
                         cargo bench --bench fleet_throughput",
                        path.display()
                    );
                    std::process::exit(1);
                }
            }
            BaselineCheck::Broken(note) => {
                println!("{note}");
                if strict {
                    eprintln!(
                        "bench baseline is unreadable but SHPTIER_BENCH_CHECK=1 \
                         expects an armed gate — fix or re-record the baseline"
                    );
                    std::process::exit(1);
                }
            }
        }
    }
}

/// Outcome of the baseline comparison.
enum BaselineCheck {
    /// Comparison ran; the payload is the list of regressions (empty = ok).
    Compared(Vec<String>),
    /// Deliberately skippable: no baseline recorded yet (placeholder file
    /// with an empty results array, or no file at all).
    SkippedBenign(String),
    /// The baseline exists but cannot be parsed — a corrupt gate, fatal
    /// under SHPTIER_BENCH_CHECK=1.
    Broken(String),
}

/// Compare current throughput against the recorded baseline.
fn check_against_baseline(path: &std::path::Path, results: &[BenchResult]) -> BaselineCheck {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            return BaselineCheck::SkippedBenign(format!(
                "(no baseline at {}: {e} — skipping check)",
                path.display()
            ))
        }
    };
    let json = match Json::parse(&text) {
        Ok(j) => j,
        Err(e) => {
            return BaselineCheck::Broken(format!(
                "(unparseable baseline {}: {e})",
                path.display()
            ))
        }
    };
    let Json::Obj(root) = &json else {
        return BaselineCheck::Broken("(baseline is not a JSON object)".to_string());
    };
    let rows = match root.get("results") {
        Some(Json::Arr(rows)) if !rows.is_empty() => rows,
        Some(Json::Arr(_)) => {
            return BaselineCheck::SkippedBenign(
                "(baseline has no recorded results — record one with \
                 SHPTIER_BENCH_RECORD=1 cargo bench --bench fleet_throughput)"
                    .to_string(),
            )
        }
        _ => return BaselineCheck::Broken("(baseline has no results array)".to_string()),
    };
    let tolerance = std::env::var("SHPTIER_BENCH_TOLERANCE")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .unwrap_or(0.25);
    let mut baseline: BTreeMap<String, f64> = BTreeMap::new();
    for row in rows {
        if let Json::Obj(o) = row {
            if let (Some(Json::Str(name)), Some(rate)) =
                (o.get("name"), o.get("docs_per_sec").and_then(|v| v.as_f64()))
            {
                baseline.insert(name.clone(), rate);
            }
        }
    }
    let mut regressions = Vec::new();
    let mut compared = 0usize;
    for r in results {
        let (Some(items), Some(&base_rate)) = (r.items_per_iter, baseline.get(&r.name)) else {
            continue;
        };
        if base_rate <= 0.0 {
            continue;
        }
        compared += 1;
        let rate = items / r.mean.as_secs_f64();
        if rate < tolerance * base_rate {
            regressions.push(format!(
                "{}: {:.0} docs/s vs baseline {:.0} (ratio {:.2} < tolerance {tolerance})",
                r.name,
                rate,
                base_rate,
                rate / base_rate
            ));
        }
    }
    println!(
        "baseline check: {compared} benchmarks compared at tolerance {tolerance}, \
         {} regression(s)",
        regressions.len()
    );
    BaselineCheck::Compared(regressions)
}

/// Print the 1→8 worker speedup against the ≥4x acceptance bar.
fn report_scaling(results: &[BenchResult]) {
    let rate = |name: &str| -> Option<f64> {
        results
            .iter()
            .find(|r| r.name == name)
            .and_then(|r| r.items_per_iter.map(|i| i / r.mean.as_secs_f64()))
    };
    if let (Some(r1), Some(r8)) = (
        rate("fleet_scaling/streams=16,workers=1"),
        rate("fleet_scaling/streams=16,workers=8"),
    ) {
        let speedup = r8 / r1;
        println!(
            "worker scaling 1→8 on 16 streams: {speedup:.2}x ({})",
            if speedup >= 4.0 { "meets the >=4x bar" } else { "BELOW the >=4x bar" }
        );
    }
    if let (Some(r1), Some(r8)) = (
        rate("fleet_skew/streams=8,workers=1"),
        rate("fleet_skew/streams=8,workers=8"),
    ) {
        let speedup = r8 / r1;
        println!(
            "work-stealing scaling 1→8 on the skewed fleet: {speedup:.2}x ({})",
            if speedup >= 3.0 { "meets the >=3x bar" } else { "BELOW the >=3x bar" }
        );
    }
    if let (Some(per_op), Some(group)) = (
        rate("fleet_journal/mode=per-op"),
        rate("fleet_journal/mode=group"),
    ) {
        let speedup = group / per_op;
        println!(
            "group commit on sync journaled fs ops: {speedup:.2}x ({})",
            if speedup >= 10.0 { "meets the >=10x bar" } else { "BELOW the >=10x bar" }
        );
    }
}

/// Serialize results into the baseline schema.
fn baseline_json(results: &[BenchResult]) -> Json {
    let unix_secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("fleet_throughput".to_string()));
    root.insert("docs_per_stream".to_string(), Json::Num(DOCS_PER_STREAM as f64));
    root.insert("recorded_unix_secs".to_string(), Json::Num(unix_secs as f64));
    root.insert(
        "host".to_string(),
        Json::Str(format!("{}-{}", std::env::consts::OS, std::env::consts::ARCH)),
    );
    let rows = results
        .iter()
        .map(|r| {
            let mut row = BTreeMap::new();
            row.insert("name".to_string(), Json::Str(r.name.clone()));
            row.insert("mean_ns".to_string(), Json::Num(r.mean.as_nanos() as f64));
            row.insert("iters".to_string(), Json::Num(r.iters as f64));
            if let Some(items) = r.items_per_iter {
                row.insert(
                    "docs_per_sec".to_string(),
                    Json::Num(items / r.mean.as_secs_f64()),
                );
            }
            Json::Obj(row)
        })
        .collect();
    root.insert("results".to_string(), Json::Arr(rows));
    Json::Obj(root)
}
