//! Bench: SHP process simulators (E1/E2 workloads) — how fast can we
//! Monte-Carlo the paper's equations.

use shptier::benchkit::Bencher;
use shptier::shp;
use shptier::util::Rng;

fn main() {
    println!("== shp_validation benches ==");
    let mut b = Bencher::from_env();

    let mut rng = Rng::new(1);
    b.bench("classic_shp_run/N=1000", 1000, || {
        shp::run_classic(1000, 368, &mut rng)
    });

    let mut rng2 = Rng::new(2);
    b.bench("algorithm_b_run/N=10000,K=1", 10_000, || {
        shp::run_overwrite(10_000, 1, &mut rng2)
    });

    let mut rng3 = Rng::new(3);
    b.bench("algorithm_b_run/N=10000,K=100", 10_000, || {
        shp::run_overwrite(10_000, 100, &mut rng3)
    });

    // analytic evaluations (the closed forms used by the optimizer)
    b.bench("expected_writes/N=1e8,K=1e6", 1, || {
        shptier::cost::expected_writes(100_000_000, 1_000_000)
    });

    let mut rng4 = Rng::new(4);
    let scores: Vec<f64> = (0..20_000).map(|_| rng4.next_f64()).collect();
    b.bench("fit_write_curve/N=20000,K=100", 20_000, || {
        shp::fit_write_curve(&scores, 100)
    });
    b.bench("spearman/N=20000", 20_000, || {
        shp::spearman_position_correlation(&scores)
    });
}
