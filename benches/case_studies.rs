//! Bench: the analytic cost model + optimizers (Tables I/II, Figs. 4/5
//! regeneration cost) and the trace-driven policy executor that validates
//! them.

use shptier::benchkit::Bencher;
use shptier::cost::{
    case_study_1, case_study_2, expected_cost, numeric_optimal_r, optimal_r, scaled, Strategy,
};
use shptier::policy::{run_policy, Changeover, ChangeoverMigrate};
use shptier::util::Rng;

fn main() {
    println!("== case_studies benches ==");
    let mut b = Bencher::from_env();

    let cs1 = case_study_1();
    let cs2 = case_study_2();

    b.bench("expected_cost/cs1_changeover", 1, || {
        expected_cost(&cs1, Strategy::Changeover { r: 41_233_169 })
    });
    b.bench("closed_form_r_star/cs1", 1, || optimal_r(&cs1, false));
    b.bench("numeric_r_star/cs1 (golden-section)", 1, || {
        numeric_optimal_r(&cs1, false)
    });
    b.bench("numeric_r_star/cs2_migrate", 1, || {
        numeric_optimal_r(&cs2, true)
    });

    // Fig. 4/5 full curve regeneration
    b.bench("fig4_curve/1000pts", 1000, || {
        shptier::exp::case_studies::fig4(1000)
    });
    b.bench("fig5_curve/2000pts", 2000, || {
        shptier::exp::case_studies::fig5(2000)
    });

    // trace-driven executor at simulation scale (the inner loop of A1)
    let m1 = scaled(&cs1, 10_000);
    let mut rng = Rng::new(7);
    let scores: Vec<f64> = (0..m1.n).map(|_| rng.next_f64()).collect();
    let r = optimal_r(&m1, false).r;
    b.bench("run_policy/cs1_scaled_N=10k_changeover", m1.n, || {
        let mut p = Changeover::new(r);
        run_policy(&scores, &m1, &mut p).unwrap()
    });
    let m2 = scaled(&cs2, 10_000);
    let scores2: Vec<f64> = (0..m2.n).map(|_| rng.next_f64()).collect();
    let r2 = optimal_r(&m2, true).r;
    b.bench("run_policy/cs2_scaled_N=10k_migrate", m2.n, || {
        let mut p = ChangeoverMigrate::new(r2);
        run_policy(&scores2, &m2, &mut p).unwrap()
    });
}
