//! Quickstart: the whole system in ~40 lines.
//!
//! Computes the paper-optimal changeover point for a two-tier economy,
//! streams a small Gillespie sweep through the pipeline (scored by the AOT
//! PJRT artifact when `make artifacts` has run, else the native fallback),
//! and reconciles the measured ledger against the analytic expectation.
//!
//!     cargo run --release --example quickstart

use shptier::config::LaunchConfig;
use shptier::cost::{expected_cost, Strategy};
use shptier::pipeline::{native_scorer_factory, run_pipeline};
use shptier::runtime::Manifest;
use shptier::ssa::oscillator_sweep;

fn main() -> anyhow::Result<()> {
    // 1. configuration: case-study-2 economics scaled to 2 000 documents
    let config = LaunchConfig::from_toml(
        r#"
[workload]
n_docs = 2000
[policy]
kind = "changeover-migrate"
"#,
    )?;
    println!(
        "economy: N={} K={} | policy: {:?}",
        config.model.n, config.model.k, config.policy
    );

    // 2. the workload: a parameter sweep over the Goodwin GRN oscillator
    let grid = oscillator_sweep(4, 2); // 4^5 = 1024 points × 2 replicates

    // 3. run the three-stage pipeline (producers → scorer → placer)
    let mut policy = config.policy.instantiate(&config.model);
    let report = run_pipeline(
        &config.pipeline,
        &grid,
        &config.model,
        policy.as_mut(),
        native_scorer_factory(Manifest::default_dir()),
    )?;
    println!("{}", report.summary());

    // 4. reconcile measured cost vs the paper's closed-form expectation
    if let shptier::config::PolicySpec::ChangeoverMigrate { r } = config.policy {
        let analytic = expected_cost(&config.model, Strategy::ChangeoverMigrate { r }).total();
        println!(
            "analytic ${:.4} vs measured ${:.4} ({:+.1}%)",
            analytic,
            report.run.total_cost(),
            (report.run.total_cost() / analytic - 1.0) * 100.0
        );
    }
    Ok(())
}
