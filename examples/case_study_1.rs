//! Case Study 1 (paper §VII-A, Table I, Fig. 4): producer in AWS with S3
//! local (tier A), consumer in Azure with Blob local (tier B), separated by
//! a 0.087 $/GB channel.
//!
//! Regenerates Table I, sweeps the Fig. 4 cost curve to results/, and
//! validates the closed-form optimum against a trace-driven simulation at
//! 1:10 000 scale.
//!
//!     cargo run --release --example case_study_1

use shptier::cost::{case_study_1, expected_cost, optimal_r, scaled, Strategy};
use shptier::exp::case_studies;
use shptier::policy::{run_policy, Changeover, SingleTier};
use shptier::storage::TierId;
use shptier::util::Rng;

fn main() -> anyhow::Result<()> {
    // ---- Table I ----------------------------------------------------------
    println!("{}", case_studies::table1().render());

    // ---- Fig. 4 curve -----------------------------------------------------
    let (series, table) = case_studies::fig4(1000);
    println!("{}", table.render());
    let path = series.write_csv(std::path::Path::new("results"))?;
    println!("wrote {}\n", path.display());

    // ---- trace-driven validation at reduced scale --------------------------
    let full = case_study_1();
    let m = scaled(&full, 10_000); // N=10 000, K=100, same per-doc economics
    let opt = optimal_r(&m, false);
    println!(
        "scaled simulation: N={} K={} r*={} (r*/N={:.4})",
        m.n, m.k, opt.r, opt.frac
    );

    let reps = 30;
    let mut rng = Rng::new(1);
    let mut totals = [0.0f64; 3]; // changeover, all-A, all-B
    for _ in 0..reps {
        let scores: Vec<f64> = (0..m.n).map(|_| rng.next_f64()).collect();
        let mut chg = Changeover::new(opt.r);
        totals[0] += run_policy(&scores, &m, &mut chg)?.total_cost();
        let mut a = SingleTier::new(TierId::A);
        totals[1] += run_policy(&scores, &m, &mut a)?.total_cost();
        let mut b = SingleTier::new(TierId::B);
        totals[2] += run_policy(&scores, &m, &mut b)?.total_cost();
    }
    let analytic = [
        expected_cost(&m, Strategy::Changeover { r: opt.r }).total(),
        expected_cost(&m, Strategy::AllA).total(),
        expected_cost(&m, Strategy::AllB).total(),
    ];
    println!("\nmeasured (mean of {reps} traces) vs analytic:");
    for (name, (meas, ana)) in ["changeover(r*)", "all-A", "all-B"]
        .iter()
        .zip(totals.iter().map(|t| t / reps as f64).zip(analytic))
    {
        println!(
            "  {name:<16} ${meas:.4}  vs  ${ana:.4}  ({:+.1}%)",
            (meas / ana - 1.0) * 100.0
        );
    }
    println!("\npaper's claim (Table I shape): changeover < all-A < all-B");
    Ok(())
}
