//! Case Study 2 (paper §VII-B, Table II, Fig. 5): EFS (tier A) and S3
//! (tier B) in the same cloud — rent-dominated, migration strategy wins.
//!
//! Regenerates Table II, sweeps the Fig. 5 cost curve to results/,
//! compares all four strategies in trace-driven simulation at 1:10 000
//! scale (including the no-migration rent bound the paper reports), and
//! finishes on the fleet path: the same economy as a multi-stream fleet,
//! keep vs migrate vs auto family through the engine's arbiter.
//!
//!     cargo run --release --example case_study_2

use shptier::cost::{case_study_2, expected_cost, optimal_r, scaled, Strategy};
use shptier::exp::case_studies;
use shptier::exp::fleet::{ample_capacity, compare_families_at_capacity};
use shptier::fleet::{SeriesProfile, StreamSpec};
use shptier::policy::{run_policy, Changeover, ChangeoverMigrate, SingleTier};
use shptier::report::Table;
use shptier::storage::TierId;
use shptier::util::Rng;

fn main() -> anyhow::Result<()> {
    // ---- Table II ----------------------------------------------------------
    println!("{}", case_studies::table2().render());

    // ---- Fig. 5 curve ------------------------------------------------------
    let (series, table) = case_studies::fig5(2000);
    println!("{}", table.render());
    let path = series.write_csv(std::path::Path::new("results"))?;
    println!("wrote {}\n", path.display());

    // ---- trace-driven strategy comparison at reduced scale -----------------
    let m = scaled(&case_study_2(), 10_000); // N=10 000, K=500
    let opt_mig = optimal_r(&m, true);
    let opt_no = optimal_r(&m, false);

    let reps = 20;
    let mut rng = Rng::new(2);
    let mut measured = [0.0f64; 4];
    for _ in 0..reps {
        let scores: Vec<f64> = (0..m.n).map(|_| rng.next_f64()).collect();
        let mut mig = ChangeoverMigrate::new(opt_mig.r);
        measured[0] += run_policy(&scores, &m, &mut mig)?.total_cost();
        let mut chg = Changeover::new(opt_no.r);
        measured[1] += run_policy(&scores, &m, &mut chg)?.total_cost();
        let mut a = SingleTier::new(TierId::A);
        measured[2] += run_policy(&scores, &m, &mut a)?.total_cost();
        let mut b = SingleTier::new(TierId::B);
        measured[3] += run_policy(&scores, &m, &mut b)?.total_cost();
    }
    let analytic = [
        expected_cost(&m, Strategy::ChangeoverMigrate { r: opt_mig.r }).total(),
        expected_cost(&m, Strategy::Changeover { r: opt_no.r }).total(),
        expected_cost(&m, Strategy::AllA).total(),
        expected_cost(&m, Strategy::AllB).total(),
    ];
    let names = [
        format!("changeover+migrate(r*={})", opt_mig.r),
        format!("changeover(r*={})", opt_no.r),
        "all-A".to_string(),
        "all-B".to_string(),
    ];
    let mut t = Table::new(
        &format!("trace-driven comparison, N={} K={} ({} traces)", m.n, m.k, reps),
        &["strategy", "measured $", "analytic $", "delta"],
    );
    for i in 0..4 {
        let meas = measured[i] / reps as f64;
        t.row(vec![
            names[i].clone(),
            format!("{meas:.4}"),
            format!("{:.4}", analytic[i]),
            format!("{:+.1}%", (meas / analytic[i] - 1.0) * 100.0),
        ]);
    }
    println!("{}", t.render());
    println!(
        "paper's claim (Table II shape): migrate beats all-A ({:.0} vs {:.0}) and the\n\
         no-migration rent bound; see DESIGN.md §5 item 4 for the all-B erratum.\n",
        measured[0] / reps as f64,
        measured[2] / reps as f64,
    );

    // ---- the same economy on the fleet path --------------------------------
    // Three CS2 streams share the engine; the arbiter hands each its
    // family's closed-form plan and the sessions execute the changeover
    // demotions (`migrate` should win, and `auto` should find it).
    let fleet_model = scaled(&case_study_2(), 25_000); // N=4000, K=200
    let specs: Vec<StreamSpec> = (0..3)
        .map(|i| {
            StreamSpec::new(
                i,
                fleet_model.clone(),
                SeriesProfile::Mixed { p_oscillatory: 0.4 },
            )
        })
        .collect();
    let cmp = compare_families_at_capacity(&specs, ample_capacity(&specs), 2, 64)?;
    let mut ft = Table::new(
        &format!(
            "case-study-2 fleet path — {} streams × N={} K={}, ample hot capacity {}",
            specs.len(),
            fleet_model.n,
            fleet_model.k,
            cmp.capacity
        ),
        &["family", "measured $", "analytic $"],
    );
    ft.row(vec![
        "keep".into(),
        format!("{:.4}", cmp.keep_total),
        format!("{:.4}", cmp.keep_analytic),
    ]);
    ft.row(vec![
        "migrate".into(),
        format!("{:.4}", cmp.migrate_total),
        format!("{:.4}", cmp.migrate_analytic),
    ]);
    ft.row(vec!["auto".into(), format!("{:.4}", cmp.auto_total), "-".into()]);
    println!("{}", ft.render());
    println!(
        "fleet path: migrate family saves {:+.1}% over keep on the CS2 economy",
        cmp.saving() * 100.0
    );
    Ok(())
}
