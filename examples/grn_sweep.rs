//! END-TO-END DRIVER (paper §VIII, Figs. 6–8): the full three-layer system
//! on a real workload.
//!
//! - L3 (this binary): sharded Gillespie producers sweep the Goodwin GRN
//!   oscillator; a batching scorer stage executes the AOT artifact via
//!   PJRT; the placement coordinator runs the paper's changeover+migrate
//!   policy over the simulated EFS/S3 tiers with exact cost accounting.
//! - L2/L1: the interestingness function (Pallas feature + RBF kernels in
//!   a JAX model), compiled by `make artifacts` — Python is NOT running.
//!
//! Prints the headline metrics recorded in EXPERIMENTS.md: the Fig. 7
//! interestingness trace, the Fig. 8 write-curve fit, the measured-vs-
//! analytic placement cost, and pipeline throughput.
//!
//!     make artifacts && cargo run --release --example grn_sweep

use shptier::cost::{case_study_2, expected_cost, optimal_r, scaled, Strategy};
use shptier::exp::grn;
use shptier::pipeline::{pjrt_scorer_factory, run_pipeline, PipelineConfig};
use shptier::runtime::Manifest;
use shptier::shp::spearman_position_correlation;
use shptier::ssa::oscillator_sweep;

fn main() -> anyhow::Result<()> {
    let n_docs: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(10_000);

    let artifacts = Manifest::default_dir();
    if !artifacts.join("manifest.json").exists() {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }
    let manifest = Manifest::load(&artifacts)?;
    println!(
        "artifacts: {} variants, t_len={}, train acc {:.3}",
        manifest.artifacts.len(),
        manifest.t_len,
        manifest.train_accuracy
    );

    // economics: case study 2 scaled to this stream; paper-optimal r*
    let model = scaled(&case_study_2(), case_study_2().n / n_docs);
    let opt = optimal_r(&model, true);
    println!(
        "economy: N={} K={} | r* = {} (r*/N = {:.4}, paper: 0.078)",
        model.n, model.k, opt.r, opt.frac
    );

    let config = PipelineConfig {
        n_docs,
        producers: 4,
        batch_max: 256,
        ..PipelineConfig::default()
    };
    let grid = oscillator_sweep(7, 1); // 16 807 parameter points
    let mut policy = shptier::policy::ChangeoverMigrate::new(opt.r);

    let report = run_pipeline(
        &config,
        &grid,
        &model,
        &mut policy,
        pjrt_scorer_factory(artifacts),
    )?;
    println!("\n{}\n", report.summary());

    // ---- Fig. 7: the interestingness trace --------------------------------
    let scores: Vec<f64> = report.score_trace.iter().map(|(_, h)| *h as f64).collect();
    let rho = spearman_position_correlation(&scores);
    println!(
        "Fig. 7 trace: {} docs, spearman(position, score) = {rho:.4} (≈0 → random-order model valid)",
        scores.len()
    );
    let mut fig7 = shptier::report::Series::new("fig7_interestingness_trace", &["index", "entropy"]);
    for (i, (_, h)) in report.score_trace.iter().enumerate().step_by(10) {
        fig7.push(vec![i as f64, *h as f64]);
    }
    println!("  {}", fig7.sparkline(1, 70));
    let p7 = fig7.write_csv(std::path::Path::new("results"))?;
    println!("  wrote {}", p7.display());

    // ---- Fig. 8: cumulative writes vs analytic ----------------------------
    let (fig8_series, fig8_table) = grn::fig8(&scores, 100);
    println!("\n{}", fig8_table.render());
    let p8 = fig8_series.write_csv(std::path::Path::new("results"))?;
    println!("wrote {}", p8.display());

    // ---- headline metric: measured vs analytic placement cost --------------
    let analytic = expected_cost(&model, Strategy::ChangeoverMigrate { r: opt.r }).total();
    let measured = report.run.total_cost();
    println!(
        "\nHEADLINE: measured placement cost ${measured:.4} vs analytic ${analytic:.4} ({:+.1}%)",
        (measured / analytic - 1.0) * 100.0
    );
    println!(
        "          throughput {:.0} docs/s end-to-end ({} PJRT batches, mean size {:.1})",
        report.throughput_docs_per_sec,
        report.scorer.batches,
        report.scorer.mean_batch()
    );
    Ok(())
}
