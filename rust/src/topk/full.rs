//! Full-ranking tracker: exact global ranks for every observed score.
//!
//! This mirrors the paper's listings (Fig. 2/3), which keep a sorted list
//! `H` and compute `h_rank = H.indexof(h_i)`. It is O(n) memory and O(n)
//! insert (Vec shift), which is fine for diagnostics, the classic-SHP
//! baseline, and trace analysis; the pipeline uses [`super::BoundedTopK`].

use super::{rank_cmp, Scored};

#[derive(Debug, Clone, Default)]
pub struct FullRankTracker {
    /// Sorted descending (best first).
    sorted: Vec<Scored>,
}

impl FullRankTracker {
    pub fn new() -> Self {
        Self { sorted: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        Self { sorted: Vec::with_capacity(n) }
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    /// Insert a score and return its 0-based rank among everything observed
    /// so far (0 = best). Equal scores rank behind the earlier document.
    pub fn insert(&mut self, s: Scored) -> usize {
        let pos = self
            .sorted
            .partition_point(|x| rank_cmp(x, &s) == std::cmp::Ordering::Greater);
        self.sorted.insert(pos, s);
        pos
    }

    /// Rank the score *would* get, without inserting.
    pub fn rank_of(&self, s: Scored) -> usize {
        self.sorted
            .partition_point(|x| rank_cmp(x, &s) == std::cmp::Ordering::Greater)
    }

    /// Is `s` better than every score observed so far?
    pub fn is_record(&self, s: Scored) -> bool {
        self.rank_of(s) == 0
    }

    /// The current top-K, best first (clamped to observed count).
    pub fn top_k(&self, k: usize) -> &[Scored] {
        &self.sorted[..k.min(self.sorted.len())]
    }

    /// The current best, if any.
    pub fn best(&self) -> Option<Scored> {
        self.sorted.first().copied()
    }

    /// Verify internal sortedness (property tests).
    pub fn check_invariants(&self) -> bool {
        self.sorted
            .windows(2)
            .all(|w| rank_cmp(&w[0], &w[1]) != std::cmp::Ordering::Less)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn ranks_are_exact() {
        let mut t = FullRankTracker::new();
        assert_eq!(t.insert(Scored::new(0, 5.0)), 0);
        assert_eq!(t.insert(Scored::new(1, 7.0)), 0);
        assert_eq!(t.insert(Scored::new(2, 6.0)), 1);
        assert_eq!(t.insert(Scored::new(3, 1.0)), 3);
        assert_eq!(
            t.top_k(2).iter().map(|s| s.index).collect::<Vec<_>>(),
            vec![1, 2]
        );
    }

    #[test]
    fn ties_rank_behind_earlier() {
        let mut t = FullRankTracker::new();
        t.insert(Scored::new(0, 1.0));
        let r = t.insert(Scored::new(1, 1.0));
        assert_eq!(r, 1);
        assert!(!t.is_record(Scored::new(2, 1.0)));
    }

    #[test]
    fn record_probability_matches_eq5() {
        // P(i-th doc is best so far) = 1/(i+1), paper eq. (5)
        let reps = 3000;
        let n = 50u64;
        let mut rng = Rng::new(99);
        let mut record_counts = vec![0u64; n as usize];
        for _ in 0..reps {
            let mut t = FullRankTracker::new();
            for i in 0..n {
                let s = Scored::new(i, rng.next_f64());
                if t.is_record(s) {
                    record_counts[i as usize] += 1;
                }
                t.insert(s);
            }
        }
        for i in [0usize, 1, 4, 9, 24, 49] {
            let p = record_counts[i] as f64 / reps as f64;
            let expect = 1.0 / (i as f64 + 1.0);
            assert!(
                (p - expect).abs() < 0.04 + 0.2 * expect,
                "i={i}: p={p} expect={expect}"
            );
        }
    }

    #[test]
    fn agrees_with_bounded_tracker() {
        let mut rng = Rng::new(5);
        let k = 8;
        let mut full = FullRankTracker::new();
        let mut bounded = super::super::BoundedTopK::new(k);
        for i in 0..1500u64 {
            let s = Scored::new(i, rng.next_f64());
            full.insert(s);
            bounded.offer(s);
            assert!(full.check_invariants());
        }
        let a: Vec<u64> = full.top_k(k).iter().map(|s| s.index).collect();
        let b: Vec<u64> = bounded.sorted_desc().iter().map(|s| s.index).collect();
        assert_eq!(a, b);
    }
}
