//! Capacity-K min-heap top-K tracker — the pipeline hot-path structure.

use super::{rank_cmp, Scored, Selector, SelectorKind};
use std::cmp::Ordering;

/// What happened when a candidate was offered to the tracker.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Eviction {
    /// Candidate rejected: it does not enter the current top-K.
    Rejected,
    /// Candidate accepted into spare capacity (no victim).
    Accepted,
    /// Candidate accepted, displacing `victim` (which leaves the top-K).
    Replaced { victim: Scored },
}

/// Min-heap of the current top-K scored documents.
///
/// `offer` is O(log K); membership of the heap *is* the current top-K set.
/// The heap root is the current K-th best (the threshold).
#[derive(Debug, Clone)]
pub struct BoundedTopK {
    k: usize,
    heap: Vec<Scored>, // min-heap by rank_cmp
}

impl BoundedTopK {
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "K must be positive");
        Self { k, heap: Vec::with_capacity(k) }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Current K-th best (the entry threshold), if the tracker is full.
    pub fn threshold(&self) -> Option<Scored> {
        if self.heap.len() == self.k {
            self.heap.first().copied()
        } else {
            None
        }
    }

    /// Would this candidate enter the top-K right now?
    pub fn would_accept(&self, candidate: Scored) -> bool {
        self.heap.len() < self.k
            || rank_cmp(&candidate, &self.heap[0]) == Ordering::Greater
    }

    /// Offer a candidate; returns what happened. A candidate equal to the
    /// threshold is rejected (strict improvement required, eq. (5)).
    pub fn offer(&mut self, candidate: Scored) -> Eviction {
        debug_assert!(
            candidate.score.is_finite(),
            "non-finite score reached BoundedTopK::offer — the observe() \
             guard should have rejected it"
        );
        if self.heap.len() < self.k {
            self.push(candidate);
            return Eviction::Accepted;
        }
        if rank_cmp(&candidate, &self.heap[0]) != Ordering::Greater {
            return Eviction::Rejected;
        }
        let victim = self.heap[0];
        self.heap[0] = candidate;
        self.sift_down(0);
        Eviction::Replaced { victim }
    }

    /// Snapshot of the current top-K, best first.
    pub fn sorted_desc(&self) -> Vec<Scored> {
        let mut v = self.heap.clone();
        v.sort_by(|a, b| rank_cmp(b, a));
        v
    }

    /// Iterate the current membership in heap order (no particular rank).
    pub fn iter(&self) -> impl Iterator<Item = &Scored> {
        self.heap.iter()
    }

    fn push(&mut self, s: Scored) {
        self.heap.push(s);
        self.sift_up(self.heap.len() - 1);
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if rank_cmp(&self.heap[i], &self.heap[parent]) == Ordering::Less {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut smallest = i;
            if l < n && rank_cmp(&self.heap[l], &self.heap[smallest]) == Ordering::Less {
                smallest = l;
            }
            if r < n && rank_cmp(&self.heap[r], &self.heap[smallest]) == Ordering::Less {
                smallest = r;
            }
            if smallest == i {
                return;
            }
            self.heap.swap(i, smallest);
            i = smallest;
        }
    }

    /// Debug-only heap-property check (used by property tests).
    pub fn check_invariants(&self) -> bool {
        if self.heap.len() > self.k {
            return false;
        }
        for i in 1..self.heap.len() {
            let parent = (i - 1) / 2;
            if rank_cmp(&self.heap[i], &self.heap[parent]) == Ordering::Less {
                return false;
            }
        }
        true
    }
}

impl Selector for BoundedTopK {
    fn kind(&self) -> SelectorKind {
        SelectorKind::Bounded
    }

    fn k(&self) -> usize {
        self.k
    }

    fn len(&self) -> usize {
        self.heap.len()
    }

    fn offer(&mut self, candidate: Scored) -> Eviction {
        BoundedTopK::offer(self, candidate)
    }

    fn threshold_score(&self) -> Option<f64> {
        self.threshold().map(|s| s.score)
    }

    fn retained(&self) -> Option<Vec<Scored>> {
        Some(self.sorted_desc())
    }

    fn resident_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.heap.capacity() * std::mem::size_of::<Scored>()
    }

    fn check_invariants(&self) -> bool {
        BoundedTopK::check_invariants(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn fills_then_replaces() {
        let mut t = BoundedTopK::new(2);
        assert_eq!(t.offer(Scored::new(0, 1.0)), Eviction::Accepted);
        assert_eq!(t.offer(Scored::new(1, 2.0)), Eviction::Accepted);
        assert_eq!(t.offer(Scored::new(2, 0.5)), Eviction::Rejected);
        match t.offer(Scored::new(3, 3.0)) {
            Eviction::Replaced { victim } => assert_eq!(victim.index, 0),
            other => panic!("expected replace, got {other:?}"),
        }
        let top = t.sorted_desc();
        assert_eq!(top[0].index, 3);
        assert_eq!(top[1].index, 1);
    }

    #[test]
    fn equal_score_does_not_displace() {
        let mut t = BoundedTopK::new(1);
        t.offer(Scored::new(0, 1.0));
        assert_eq!(t.offer(Scored::new(1, 1.0)), Eviction::Rejected);
        assert_eq!(t.sorted_desc()[0].index, 0);
    }

    #[test]
    fn threshold_only_when_full() {
        let mut t = BoundedTopK::new(3);
        assert!(t.threshold().is_none());
        for i in 0..3 {
            t.offer(Scored::new(i, i as f64));
        }
        assert_eq!(t.threshold().unwrap().index, 0);
    }

    #[test]
    fn matches_naive_on_random_streams() {
        let mut rng = Rng::new(123);
        for k in [1usize, 3, 17, 64] {
            let mut t = BoundedTopK::new(k);
            let mut all: Vec<Scored> = Vec::new();
            for i in 0..2_000u64 {
                let s = Scored::new(i, rng.next_f64());
                t.offer(s);
                all.push(s);
                assert!(t.check_invariants());
            }
            all.sort_by(|a, b| rank_cmp(b, a));
            let expect: Vec<u64> = all[..k].iter().map(|s| s.index).collect();
            let got: Vec<u64> = t.sorted_desc().iter().map(|s| s.index).collect();
            assert_eq!(got, expect, "k={k}");
        }
    }

    #[test]
    fn prop_bounded_agrees_with_full_on_random_streams() {
        // Property: across random streams (uniform scores, heavy-tie
        // discretized scores, and sorted adversarial orders), the bounded
        // tracker's final top-K membership and order match the exact
        // full-ranking tracker, its heap invariant always holds, and a
        // candidate is accepted exactly when its global rank at observation
        // time is inside the top-K.
        use crate::propcheck::{check, Config};
        use crate::topk::FullRankTracker;

        #[derive(Debug)]
        struct Case {
            k: usize,
            order: u8, // 0 random, 1 ascending, 2 descending, 3 second-half sorted
            scores: Vec<f64>,
        }

        let gen = |rng: &mut crate::util::Rng| {
            let n = 1 + rng.next_below(400) as usize;
            let k = 1 + rng.next_below(64) as usize;
            let order = rng.next_below(4) as u8;
            let discretize = rng.next_below(3) == 0;
            let mut scores: Vec<f64> = (0..n)
                .map(|_| {
                    if discretize {
                        rng.next_below(16) as f64 / 16.0 // force ties
                    } else {
                        rng.next_f64()
                    }
                })
                .collect();
            match order {
                1 => scores.sort_by(|a, b| a.partial_cmp(b).unwrap()),
                2 => {
                    scores.sort_by(|a, b| b.partial_cmp(a).unwrap());
                }
                3 => {
                    let half = scores.len() / 2;
                    scores[half..].sort_by(|a, b| a.partial_cmp(b).unwrap());
                }
                _ => {}
            }
            Case { k, order, scores }
        };

        check("bounded-vs-full", Config { cases: 120, seed: 0xB07B07 }, gen, |case| {
            let mut bounded = BoundedTopK::new(case.k);
            let mut full = FullRankTracker::new();
            for (i, &s) in case.scores.iter().enumerate() {
                let sc = Scored::new(i as u64, s);
                // acceptance ⇔ strict-rank entry (paper eq. (5) semantics)
                let enters = full.rank_of(sc) < case.k || full.len() < case.k;
                let accepted = !matches!(bounded.offer(sc), Eviction::Rejected);
                full.insert(sc);
                if accepted != enters {
                    return Err(format!(
                        "doc {i} (order {}): accepted={accepted} but rank-entry={enters}",
                        case.order
                    ));
                }
                if !bounded.check_invariants() {
                    return Err(format!("heap invariant broken at doc {i}"));
                }
            }
            let got: Vec<u64> = bounded.sorted_desc().iter().map(|s| s.index).collect();
            let want: Vec<u64> = full.top_k(case.k).iter().map(|s| s.index).collect();
            if got != want {
                return Err(format!("membership diverged: {got:?} != {want:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn write_count_matches_record_process() {
        // number of accepts+replaces over a random stream ≈ E[writes]
        let reps = 400;
        let (n, k) = (500u64, 5usize);
        let mut rng = Rng::new(7);
        let mut total_writes = 0u64;
        for _ in 0..reps {
            let mut t = BoundedTopK::new(k);
            for i in 0..n {
                match t.offer(Scored::new(i, rng.next_f64())) {
                    Eviction::Rejected => {}
                    _ => total_writes += 1,
                }
            }
        }
        let mean = total_writes as f64 / reps as f64;
        let expect = crate::cost::expected_writes(n, k as u64);
        assert!(
            (mean - expect).abs() / expect < 0.05,
            "mean {mean} vs analytic {expect}"
        );
    }
}
