//! Logarithmic-memory top-K admission per "Optimal k-Secretary with
//! Logarithmic Memory" (arXiv:2502.09834) — the massive-K selector.
//!
//! [`super::BoundedTopK`] holds the full K-entry heap, so a fleet of a
//! million streams at K = 10⁵ spends ~1.6 GB *per thousand streams* on
//! selector state alone. This selector replaces the exact heap with a
//! weighted tail-quantile sketch of the admitted scores: O(log K)
//! checkpoints, each a `(score, weight)` pair meaning "`weight` admitted
//! documents scored at least `score`". The admission rule is the same
//! admit-if-above-threshold shape as the exact selector, with the
//! threshold read off the sketch at cumulative weight K.
//!
//! ## Invariants (proved by construction, property-tested below)
//!
//! 1. **Lower-bound sketch.** Every admitted document is represented by
//!    exactly one unit of weight at a score ≤ its true score (merges only
//!    collapse a pair onto the *lower* of the two scores). Therefore the
//!    sketch threshold never exceeds the true running K-th best admitted
//!    score.
//! 2. **Superset admission.** Because the threshold is a lower bound and
//!    the admitted set always contains the true running top-K, any
//!    document of the final true top-K (distinct scores) is strictly
//!    above the threshold at its arrival and is admitted: the realized
//!    top-K overlap is 1, comfortably above the paper's 1 − O(1/√K).
//! 3. **Monotone threshold.** Insertions only push the K-th cumulative
//!    weight toward higher scores; merges and prunes never move it. The
//!    threshold never decreases, so admission never loosens over time.
//! 4. **Bounded overshoot.** The threshold lags the exact K-th best by at
//!    most the weight resolution of the sketch (the heaviest merged run
//!    near the tail), which the min-weight-pair merge policy keeps near
//!    2K/m for sketch capacity m. The admit-count overshoot is priced as
//!    `SelectorKind::LogMem.slack(k)` and property-tested against the
//!    exact selector.
//! 5. **Exact for small K.** While `K < sketch_capacity(K)` no merge ever
//!    happens, every entry has weight 1, and the sketch threshold *is*
//!    the exact K-th best admitted score.
//!
//! The selector never evicts: admission is append-only (the engine's
//! quota degradation already spills over-quota writes toward the sink
//! tier, and the cost model charges the slack up front — ADR-010).

use super::{Eviction, Scored, Selector, SelectorKind};

/// One sketch checkpoint: `weight` admitted documents scored ≥ `score`.
#[derive(Debug, Clone, Copy)]
struct SketchEntry {
    score: f64,
    weight: u64,
}

/// O(log K)-memory admission selector (see module docs).
#[derive(Debug, Clone)]
pub struct LogMemTopK {
    k: usize,
    cap: usize,
    /// Sorted by score, descending; weights ≥ 1; total weight ≤ admitted.
    entries: Vec<SketchEntry>,
    /// Documents admitted so far (the sketch never evicts).
    admitted: u64,
}

impl LogMemTopK {
    /// Sketch capacity for retained-set size `k`: 4·⌈log₂(k+1)⌉ + 32
    /// entries — a few dozen to ~100 checkpoints across any practical K.
    pub fn sketch_capacity(k: usize) -> usize {
        let log2 = (usize::BITS - k.next_power_of_two().leading_zeros()) as usize;
        4 * log2 + 32
    }

    pub fn new(k: usize) -> Self {
        assert!(k > 0, "K must be positive");
        let cap = Self::sketch_capacity(k);
        Self { k, cap, entries: Vec::with_capacity(cap + 1), admitted: 0 }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Documents admitted so far.
    pub fn admitted(&self) -> u64 {
        self.admitted
    }

    /// Live sketch entries (diagnostics; bounded by `sketch_capacity`).
    pub fn sketch_len(&self) -> usize {
        self.entries.len()
    }

    fn total_weight(&self) -> u64 {
        self.entries.iter().map(|e| e.weight).sum()
    }

    /// Admission threshold: the sketch score at cumulative weight K, once
    /// K admissions are represented. After compaction the tail entry *is*
    /// that checkpoint, because everything strictly beyond it is pruned.
    pub fn threshold(&self) -> Option<f64> {
        if self.total_weight() >= self.k as u64 {
            self.entries.last().map(|e| e.score)
        } else {
            None
        }
    }

    /// Offer a candidate: admitted iff no threshold is established yet or
    /// the score strictly exceeds it (same strict-improvement rule as the
    /// exact selector, eq. (5)).
    pub fn offer(&mut self, candidate: Scored) -> Eviction {
        debug_assert!(
            candidate.score.is_finite(),
            "non-finite score reached LogMemTopK::offer — the observe() \
             guard should have rejected it"
        );
        if let Some(th) = self.threshold() {
            if candidate.score <= th {
                return Eviction::Rejected;
            }
        }
        self.admitted += 1;
        let at = self.entries.partition_point(|e| e.score >= candidate.score);
        self.entries.insert(at, SketchEntry { score: candidate.score, weight: 1 });
        self.compact();
        Eviction::Accepted
    }

    /// Restore the sketch bounds after an insert: prune everything
    /// strictly past the K-th cumulative weight (those checkpoints can
    /// never be the threshold again — it is monotone), then merge
    /// min-combined-weight adjacent pairs onto the lower score until the
    /// entry count is back within capacity.
    fn compact(&mut self) {
        let mut cum = 0u64;
        for i in 0..self.entries.len() {
            cum += self.entries[i].weight;
            if cum >= self.k as u64 {
                self.entries.truncate(i + 1);
                break;
            }
        }
        while self.entries.len() > self.cap {
            let mut best = 0;
            let mut best_w = u64::MAX;
            for i in 0..self.entries.len() - 1 {
                let w = self.entries[i].weight + self.entries[i + 1].weight;
                if w < best_w {
                    best_w = w;
                    best = i;
                }
            }
            // collapse onto the *lower* score so every document keeps a
            // lower-bound representation (invariant 1)
            self.entries[best + 1].weight = best_w;
            self.entries.remove(best);
        }
    }

    /// Structure invariants (property-test hook): scores finite and
    /// non-increasing, weights positive, entry count within capacity,
    /// represented weight never exceeds admissions.
    pub fn check_invariants(&self) -> bool {
        if self.entries.len() > self.cap {
            return false;
        }
        for w in self.entries.windows(2) {
            if !(w[0].score >= w[1].score) {
                return false;
            }
        }
        if self.entries.iter().any(|e| e.weight == 0 || !e.score.is_finite()) {
            return false;
        }
        self.total_weight() <= self.admitted
    }
}

impl Selector for LogMemTopK {
    fn kind(&self) -> SelectorKind {
        SelectorKind::LogMem
    }

    fn k(&self) -> usize {
        self.k
    }

    fn len(&self) -> usize {
        self.admitted as usize
    }

    fn offer(&mut self, candidate: Scored) -> Eviction {
        LogMemTopK::offer(self, candidate)
    }

    fn threshold_score(&self) -> Option<f64> {
        self.threshold()
    }

    fn retained(&self) -> Option<Vec<Scored>> {
        None // membership is not tracked — the backend's resident set is
    }

    fn resident_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.entries.capacity() * std::mem::size_of::<SketchEntry>()
    }

    fn check_invariants(&self) -> bool {
        LogMemTopK::check_invariants(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topk::{rank_cmp, BoundedTopK};
    use crate::util::Rng;

    #[test]
    fn admits_everything_until_k_then_thresholds() {
        let mut t = LogMemTopK::new(3);
        assert!(t.threshold().is_none());
        for i in 0..3 {
            assert_eq!(t.offer(Scored::new(i, i as f64)), Eviction::Accepted);
        }
        // threshold now established at the 3rd-best admitted score (0.0)
        assert_eq!(t.threshold(), Some(0.0));
        assert_eq!(t.offer(Scored::new(3, 0.0)), Eviction::Rejected);
        assert_eq!(t.offer(Scored::new(4, -1.0)), Eviction::Rejected);
        assert_eq!(t.offer(Scored::new(5, 0.5)), Eviction::Accepted);
        assert_eq!(t.admitted(), 4);
        assert!(t.check_invariants());
    }

    #[test]
    fn small_k_matches_exact_selector_decisions() {
        // K below the sketch capacity: no merges, the threshold is exact,
        // so admit/reject decisions match BoundedTopK on any stream.
        let mut rng = Rng::new(42);
        for k in [1usize, 2, 7, 31] {
            let mut exact = BoundedTopK::new(k);
            let mut lm = LogMemTopK::new(k);
            let mut exact_admits = 0u64;
            for i in 0..3_000u64 {
                let s = Scored::new(i, rng.next_f64());
                let e = !matches!(exact.offer(s), Eviction::Rejected);
                let l = !matches!(LogMemTopK::offer(&mut lm, s), Eviction::Rejected);
                assert_eq!(e, l, "k={k} i={i}: exact={e} logmem={l}");
                exact_admits += e as u64;
                assert!(lm.check_invariants());
            }
            assert_eq!(lm.admitted(), exact_admits, "k={k}");
        }
    }

    #[test]
    fn threshold_is_monotone_nondecreasing() {
        let mut rng = Rng::new(7);
        let mut t = LogMemTopK::new(64);
        let mut last = f64::NEG_INFINITY;
        for i in 0..20_000u64 {
            t.offer(Scored::new(i, rng.next_f64()));
            if let Some(th) = t.threshold() {
                assert!(th >= last, "threshold regressed {last} -> {th} at {i}");
                last = th;
            }
        }
        assert!(t.sketch_len() <= LogMemTopK::sketch_capacity(64));
    }

    #[test]
    fn memory_stays_logarithmic_at_massive_k() {
        let mut rng = Rng::new(99);
        let k = 100_000;
        let mut t = LogMemTopK::new(k);
        for i in 0..50_000u64 {
            t.offer(Scored::new(i, rng.next_f64()));
            if i % 4096 == 0 {
                assert!(t.check_invariants());
            }
        }
        let lm_bytes = Selector::resident_bytes(&t);
        // the exact selector would hold ≥ min(seen, K) Scored entries
        let exact_bytes = 50_000 * std::mem::size_of::<Scored>();
        assert!(
            lm_bytes * 10 <= exact_bytes,
            "logmem {lm_bytes}B vs exact {exact_bytes}B: not ≥10× smaller"
        );
        assert!(t.sketch_len() <= LogMemTopK::sketch_capacity(k));
    }

    #[test]
    fn prop_competitive_ratio_and_priced_overshoot_vs_exact() {
        // The ISSUE-10 competitive-ratio property: on seeded random
        // streams the log-memory selector (a) admits a superset whose
        // overlap with the final true top-K beats the paper's
        // 1 − O(1/√K) bound, and (b) admits at most (1 + ε) times the
        // exact selector's admissions, where ε is the *priced* slack the
        // cost model charges (plus a tiny additive cushion for the
        // integer tail on short streams).
        use crate::propcheck::{check, Config};

        #[derive(Debug)]
        struct Case {
            k: usize,
            n: u64,
            seed: u64,
        }

        let gen = |rng: &mut Rng| {
            // mix sketch-exact (small K) and merging (large K) regimes
            let k = 1 + rng.next_below(300) as usize;
            let n = (k as u64 * 4) + rng.next_below(4_000);
            Case { k, n, seed: rng.next_below(u64::MAX / 2) }
        };

        check("logmem-competitive-ratio", Config { cases: 60, seed: 0x106_3E3 }, gen, |case| {
            let mut rng = Rng::new(case.seed);
            let mut exact = BoundedTopK::new(case.k);
            let mut lm = LogMemTopK::new(case.k);
            let mut all: Vec<Scored> = Vec::with_capacity(case.n as usize);
            let mut lm_set: Vec<u64> = Vec::new();
            let mut exact_admits = 0u64;
            for i in 0..case.n {
                let s = Scored::new(i, rng.next_f64());
                all.push(s);
                if !matches!(exact.offer(s), Eviction::Rejected) {
                    exact_admits += 1;
                }
                if !matches!(LogMemTopK::offer(&mut lm, s), Eviction::Rejected) {
                    lm_set.push(i);
                }
                if !lm.check_invariants() {
                    return Err(format!("sketch invariant broken at doc {i}"));
                }
            }
            // (a) realized overlap with the final true top-K
            all.sort_by(|a, b| rank_cmp(b, a));
            let top: std::collections::HashSet<u64> =
                all[..case.k.min(all.len())].iter().map(|s| s.index).collect();
            let overlap = lm_set.iter().filter(|i| top.contains(i)).count();
            let need = ((1.0 - 1.0 / (case.k as f64).sqrt()) * case.k as f64).floor() as usize;
            if overlap < need {
                return Err(format!(
                    "overlap {overlap}/{} below 1-1/sqrt(k) bound {need}",
                    case.k
                ));
            }
            // (b) admit-count overshoot within the priced epsilon
            let eps = SelectorKind::LogMem.slack(case.k as u64);
            let allowed = ((1.0 + eps) * exact_admits as f64).ceil() + 8.0;
            if (lm.admitted() as f64) > allowed {
                return Err(format!(
                    "admitted {} > (1+{eps:.3})·{exact_admits}+8 = {allowed} (k={}, n={})",
                    lm.admitted(),
                    case.k,
                    case.n
                ));
            }
            // logmem admissions are a superset of the exact selector's
            if lm.admitted() < exact_admits {
                return Err(format!(
                    "admitted {} < exact {exact_admits}: threshold exceeded the exact k-th best",
                    lm.admitted()
                ));
            }
            Ok(())
        });
    }
}
