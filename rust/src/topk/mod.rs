//! Online top-K tracking substrates.
//!
//! The paper's workflow (Fig. 2/3) needs, per document: insert its
//! interestingness into a ranked structure, learn its rank among everything
//! seen so far, and — if it enters the current top-K — learn which document
//! it evicts. Three implementations are provided:
//!
//! - [`BoundedTopK`] — a capacity-K min-heap; O(log K) per candidate,
//!   answers only "is this in the current top-K and whom does it evict".
//!   This is the exact production hot-path structure.
//! - [`LogMemTopK`] — an O(log K)-memory admission sketch per "Optimal
//!   k-Secretary with Logarithmic Memory" (arXiv:2502.09834): a weighted
//!   tail-quantile sketch stands in for the exact k-th-best threshold, so
//!   the selector admits a slight superset of the true top-K using a few
//!   dozen entries instead of K. The admit-rate overshoot is priced into
//!   the cost model via [`SelectorKind::slack`] (ADR-010).
//! - [`FullRankTracker`] — keeps *all* scores in sorted order; O(log n)
//!   search + O(n) insert, answers exact global ranks. Needed for the
//!   classic SHP baseline (rank among the first r−1) and for diagnostics.
//!
//! All are deterministic on ties: equal scores rank by earlier index first
//! (stable), matching the simulators' accounting.
//!
//! **Non-finite scores are a caller error.** [`rank_cmp`] has no total
//! order over NaN — the engine rejects non-finite scores at `observe()`
//! with a typed [`NonFiniteScore`] before any selector sees them, and the
//! selectors debug-assert the same contract.

mod bounded;
mod full;
mod logmem;

pub use bounded::{BoundedTopK, Eviction};
pub use full::FullRankTracker;
pub use logmem::LogMemTopK;

use anyhow::{bail, Result};

/// A scored document reference flowing through the trackers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Scored {
    /// Stream index of the document (0-based).
    pub index: u64,
    /// Interestingness value (higher = more interesting).
    pub score: f64,
}

impl Scored {
    pub fn new(index: u64, score: f64) -> Self {
        Self { index, score }
    }
}

/// Total order: by score, ties broken toward the *earlier* index winning
/// (an incumbent is never displaced by an equal score — the SHP "best so
/// far" must be strictly better, c.f. eq. (5)).
///
/// Only defined over finite scores: the engine rejects non-finite scores
/// with [`NonFiniteScore`] before any comparison happens, so the `None`
/// arm of the partial comparison is defensive-only (it falls back to the
/// deterministic index order instead of panicking in release builds).
pub fn rank_cmp(a: &Scored, b: &Scored) -> std::cmp::Ordering {
    debug_assert!(
        a.score.is_finite() && b.score.is_finite(),
        "non-finite score reached rank_cmp (a={}, b={}) — the observe() \
         guard should have rejected it",
        a.score,
        b.score
    );
    match a.score.partial_cmp(&b.score) {
        Some(std::cmp::Ordering::Equal) | None => b.index.cmp(&a.index),
        Some(o) => o,
    }
}

/// Typed rejection of a non-finite interestingness score at `observe()`.
///
/// NaN has no place in the ranking order ([`rank_cmp`] would silently map
/// it onto the tie-break arm and corrupt the retained set), and ±∞ would
/// pin the threshold forever. The engine refuses the observation *before*
/// consuming a stream index, so the caller can drop or sanitize the
/// document and continue the stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NonFiniteScore {
    /// Stream-local index the document would have occupied.
    pub index: u64,
    /// The offending score (NaN, +∞, or −∞).
    pub score: f64,
}

impl std::fmt::Display for NonFiniteScore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "non-finite interestingness score {} at stream index {} \
             (scores must be finite)",
            self.score, self.index
        )
    }
}

impl std::error::Error for NonFiniteScore {}

/// Which admission selector a session runs (ADR-010).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SelectorKind {
    /// Exact capacity-K min-heap ([`BoundedTopK`]): O(K) memory, zero
    /// admission slack.
    #[default]
    Bounded,
    /// Log-memory quantile-sketch selector ([`LogMemTopK`]): O(log K)
    /// memory, admit-rate overshoot priced via [`SelectorKind::slack`].
    LogMem,
}

impl SelectorKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "bounded" => Ok(Self::Bounded),
            "logmem" => Ok(Self::LogMem),
            other => bail!("unknown selector '{other}' (bounded | logmem)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::Bounded => "bounded",
            Self::LogMem => "logmem",
        }
    }

    /// A-priori admit-rate overshoot ε of this selector at retained-set
    /// size `k`: the selector is expected to admit at most `(1 + ε)×` the
    /// exact selector's admissions, because its threshold estimate lags
    /// the true k-th best by the sketch's weight resolution. The cost
    /// model inflates expected writes, hot demand, and rent integrals by
    /// this factor ([`crate::cost::selector_slack`]) so arbiters and
    /// admission control reserve for the overshoot instead of discovering
    /// it at runtime.
    ///
    /// `Bounded` is exact (ε = 0), as is `LogMem` whenever the sketch
    /// capacity covers K outright (small K: the sketch never merges and
    /// the threshold is exact).
    pub fn slack(&self, k: u64) -> f64 {
        match self {
            Self::Bounded => 0.0,
            Self::LogMem => {
                let cap = LogMemTopK::sketch_capacity(k.max(1) as usize);
                if (k as usize) < cap {
                    0.0 // sketch is exact: no merges ever happen
                } else {
                    (12.0 / cap as f64).min(0.5)
                }
            }
        }
    }

    /// Build a fresh selector of this kind for retained-set size `k`.
    pub fn build(&self, k: usize) -> Box<dyn Selector> {
        match self {
            Self::Bounded => Box::new(BoundedTopK::new(k)),
            Self::LogMem => Box::new(LogMemTopK::new(k)),
        }
    }
}

/// The admission-selector boundary of a session (ADR-010): everything the
/// engine's observe/finish lifecycle needs from a top-K structure, with
/// the membership snapshot optional so log-memory selectors can decline
/// to track it.
pub trait Selector: Send + Sync {
    /// Which kind this selector is (reporting + slack pricing).
    fn kind(&self) -> SelectorKind;

    /// Retained-set size K.
    fn k(&self) -> usize;

    /// Documents currently tracked (exact membership for bounded; sketch
    /// entries do not count documents individually for logmem, which
    /// reports its admitted count instead).
    fn len(&self) -> usize;

    /// Offer a candidate; says whether it was admitted and whom (if
    /// anyone) it displaced. Log-memory selectors never report
    /// [`Eviction::Replaced`] — they admit without tracking victims.
    fn offer(&mut self, candidate: Scored) -> Eviction;

    /// Current admission threshold score, if one is established.
    fn threshold_score(&self) -> Option<f64>;

    /// Exact retained membership, best first — `None` when the selector
    /// does not track membership (log-memory: the engine falls back to
    /// the backend's per-stream resident set, which *is* the admitted
    /// set because a logmem session never deletes).
    fn retained(&self) -> Option<Vec<Scored>>;

    /// Approximate resident heap bytes of the selector state (the bench
    /// dimension's streams-per-GB denominator).
    fn resident_bytes(&self) -> usize;

    /// Structure invariants hold (property-test hook).
    fn check_invariants(&self) -> bool;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_cmp_orders_by_score_then_earlier_index() {
        let a = Scored::new(5, 1.0);
        let b = Scored::new(9, 2.0);
        assert_eq!(rank_cmp(&a, &b), std::cmp::Ordering::Less);
        // equal scores: earlier index is "greater" (wins)
        let c = Scored::new(2, 1.0);
        assert_eq!(rank_cmp(&c, &a), std::cmp::Ordering::Greater);
    }

    #[test]
    fn non_finite_score_error_is_typed_and_descriptive() {
        let e = NonFiniteScore { index: 7, score: f64::NAN };
        let msg = e.to_string();
        assert!(msg.contains("index 7"), "{msg}");
        let any: anyhow::Error = e.into();
        let back = any.downcast_ref::<NonFiniteScore>().expect("downcast");
        assert_eq!(back.index, 7);
        assert!(back.score.is_nan());
    }

    #[test]
    fn selector_kind_parses_and_labels() {
        assert_eq!(SelectorKind::parse("bounded").unwrap(), SelectorKind::Bounded);
        assert_eq!(SelectorKind::parse("logmem").unwrap(), SelectorKind::LogMem);
        assert!(SelectorKind::parse("exact").is_err());
        assert_eq!(SelectorKind::Bounded.label(), "bounded");
        assert_eq!(SelectorKind::LogMem.label(), "logmem");
        assert_eq!(SelectorKind::default(), SelectorKind::Bounded);
    }

    #[test]
    fn slack_is_zero_for_bounded_and_for_exact_small_k() {
        assert_eq!(SelectorKind::Bounded.slack(1_000_000), 0.0);
        // small K: the sketch holds K outright, no merges, no slack
        assert_eq!(SelectorKind::LogMem.slack(8), 0.0);
        // massive K: slack is positive, bounded away from 1, and shrinks
        // as the sketch capacity grows with log K
        let big = SelectorKind::LogMem.slack(100_000);
        assert!(big > 0.0 && big <= 0.5, "slack {big}");
        assert!(SelectorKind::LogMem.slack(1_000_000) <= big);
    }

    #[test]
    fn build_constructs_the_matching_selector() {
        let b = SelectorKind::Bounded.build(4);
        assert_eq!(b.kind(), SelectorKind::Bounded);
        assert_eq!(b.k(), 4);
        let l = SelectorKind::LogMem.build(4);
        assert_eq!(l.kind(), SelectorKind::LogMem);
        assert_eq!(l.k(), 4);
    }
}
