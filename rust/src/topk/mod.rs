//! Online top-K tracking substrates.
//!
//! The paper's workflow (Fig. 2/3) needs, per document: insert its
//! interestingness into a ranked structure, learn its rank among everything
//! seen so far, and — if it enters the current top-K — learn which document
//! it evicts. Two implementations are provided:
//!
//! - [`BoundedTopK`] — a capacity-K min-heap; O(log K) per candidate,
//!   answers only "is this in the current top-K and whom does it evict".
//!   This is the production hot-path structure.
//! - [`FullRankTracker`] — keeps *all* scores in sorted order; O(log n)
//!   search + O(n) insert, answers exact global ranks. Needed for the
//!   classic SHP baseline (rank among the first r−1) and for diagnostics.
//!
//! Both are deterministic on ties: equal scores rank by earlier index first
//! (stable), matching the simulators' accounting.

mod bounded;
mod full;

pub use bounded::{BoundedTopK, Eviction};
pub use full::FullRankTracker;

/// A scored document reference flowing through the trackers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Scored {
    /// Stream index of the document (0-based).
    pub index: u64,
    /// Interestingness value (higher = more interesting).
    pub score: f64,
}

impl Scored {
    pub fn new(index: u64, score: f64) -> Self {
        Self { index, score }
    }
}

/// Total order: by score, ties broken toward the *earlier* index winning
/// (an incumbent is never displaced by an equal score — the SHP "best so
/// far" must be strictly better, c.f. eq. (5)).
pub fn rank_cmp(a: &Scored, b: &Scored) -> std::cmp::Ordering {
    match a.score.partial_cmp(&b.score) {
        Some(std::cmp::Ordering::Equal) | None => b.index.cmp(&a.index),
        Some(o) => o,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_cmp_orders_by_score_then_earlier_index() {
        let a = Scored::new(5, 1.0);
        let b = Scored::new(9, 2.0);
        assert_eq!(rank_cmp(&a, &b), std::cmp::Ordering::Less);
        // equal scores: earlier index is "greater" (wins)
        let c = Scored::new(2, 1.0);
        assert_eq!(rank_cmp(&c, &a), std::cmp::Ordering::Greater);
    }

    #[test]
    fn nan_scores_do_not_poison_order() {
        let a = Scored::new(0, f64::NAN);
        let b = Scored::new(1, 1.0);
        // NaN comparisons fall back to index ordering (deterministic)
        let _ = rank_cmp(&a, &b);
        let _ = rank_cmp(&b, &a);
    }
}
