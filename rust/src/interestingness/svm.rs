//! Native RBF kernel-machine scorer — the Rust mirror of the L2 JAX model
//! (`python/compile/model.py`).
//!
//! decision(x) = Σ_s α_s · exp(−γ‖x − sv_s‖²) + b
//! p(x)        = σ(platt_a · decision + platt_b)
//! H(x)        = −p log₂ p − (1−p) log₂(1−p)   (normalized label entropy)
//!
//! Parameters are trained at build time in JAX and exported into
//! `artifacts/manifest.json`; [`RbfScorer::from_json`] loads them so the
//! native and PJRT paths share identical weights.

use super::features::{extract, standardize, NUM_FEATURES};
use crate::serdes::Json;
use crate::util::math::{binary_entropy, sigmoid};
use anyhow::{anyhow, bail, Context, Result};

/// A trained RBF kernel machine with Platt calibration.
#[derive(Debug, Clone, PartialEq)]
pub struct RbfScorer {
    /// Support vectors in *standardized* feature space, S × D row-major.
    pub support: Vec<f32>,
    /// Dual coefficients (α_s, sign folded in), length S.
    pub alpha: Vec<f32>,
    /// RBF width.
    pub gamma: f32,
    /// Decision bias.
    pub bias: f32,
    /// Platt scaling.
    pub platt_a: f32,
    pub platt_b: f32,
    /// Feature standardization (length D each).
    pub feat_mu: Vec<f32>,
    pub feat_sigma: Vec<f32>,
}

impl RbfScorer {
    pub fn num_support(&self) -> usize {
        self.alpha.len()
    }

    /// Validate internal shape consistency.
    pub fn validate(&self) -> Result<()> {
        let s = self.alpha.len();
        if self.support.len() != s * NUM_FEATURES {
            bail!(
                "support matrix is {} floats, expected {}×{}",
                self.support.len(),
                s,
                NUM_FEATURES
            );
        }
        if self.feat_mu.len() != NUM_FEATURES || self.feat_sigma.len() != NUM_FEATURES {
            bail!("standardization vectors must have length {NUM_FEATURES}");
        }
        if !(self.gamma > 0.0) {
            bail!("gamma must be positive");
        }
        Ok(())
    }

    /// Decision value for a standardized feature vector.
    pub fn decision(&self, feat: &[f32; NUM_FEATURES]) -> f32 {
        let mut acc = self.bias;
        for s in 0..self.num_support() {
            let sv = &self.support[s * NUM_FEATURES..(s + 1) * NUM_FEATURES];
            let mut d2 = 0f32;
            for i in 0..NUM_FEATURES {
                let d = feat[i] - sv[i];
                d2 += d * d;
            }
            acc += self.alpha[s] * (-self.gamma * d2).exp();
        }
        acc
    }

    /// Class-1 probability via Platt scaling.
    pub fn probability(&self, feat: &[f32; NUM_FEATURES]) -> f32 {
        sigmoid((self.platt_a * self.decision(feat) + self.platt_b) as f64) as f32
    }

    /// Interestingness = normalized label entropy of the probability
    /// (paper §VIII: the classifier's *uncertainty* ranks documents).
    pub fn entropy(&self, feat: &[f32; NUM_FEATURES]) -> f32 {
        binary_entropy(self.probability(feat) as f64) as f32
    }

    /// End-to-end: raw series → standardized features → entropy.
    /// This is the exact function the AOT HLO artifact computes.
    pub fn score_series(&self, series: &[f32]) -> f32 {
        let mut f = extract(series);
        standardize(&mut f, &self.feat_mu, &self.feat_sigma);
        self.entropy(&f)
    }

    /// Probability + entropy for a raw series (diagnostics/Fig. 6).
    pub fn classify_series(&self, series: &[f32]) -> (f32, f32) {
        let mut f = extract(series);
        standardize(&mut f, &self.feat_mu, &self.feat_sigma);
        (self.probability(&f), self.entropy(&f))
    }

    /// Load from the `"scorer"` object of `artifacts/manifest.json`.
    pub fn from_json(j: &Json) -> Result<Self> {
        fn floats(j: &Json, key: &str) -> Result<Vec<f32>> {
            j.get(key)
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("manifest: missing array '{key}'"))?
                .iter()
                .map(|v| {
                    v.as_f64()
                        .map(|f| f as f32)
                        .ok_or_else(|| anyhow!("manifest: non-number in '{key}'"))
                })
                .collect()
        }
        fn float(j: &Json, key: &str) -> Result<f32> {
            j.get(key)
                .and_then(|v| v.as_f64())
                .map(|f| f as f32)
                .ok_or_else(|| anyhow!("manifest: missing number '{key}'"))
        }
        let scorer = Self {
            support: floats(j, "support")?,
            alpha: floats(j, "alpha")?,
            gamma: float(j, "gamma")?,
            bias: float(j, "bias")?,
            platt_a: float(j, "platt_a")?,
            platt_b: float(j, "platt_b")?,
            feat_mu: floats(j, "feat_mu")?,
            feat_sigma: floats(j, "feat_sigma")?,
        };
        scorer.validate().context("manifest scorer invalid")?;
        Ok(scorer)
    }

    /// A small deterministic scorer for tests and offline demos: two
    /// support points separating "high lag-16 anticorrelation" (oscillatory)
    /// from the rest, with mild Platt scaling.
    pub fn synthetic_demo() -> Self {
        let mut support = vec![0f32; 2 * NUM_FEATURES];
        // sv0: oscillatory prototype (negative lag-16 AC, high crossing)
        support[5] = -0.8;
        support[6] = 0.6;
        // sv1: quiescent prototype
        support[NUM_FEATURES + 5] = 0.2;
        support[NUM_FEATURES + 6] = 0.1;
        Self {
            support,
            alpha: vec![1.5, -1.5],
            gamma: 0.5,
            bias: 0.0,
            platt_a: 2.0,
            platt_b: 0.0,
            feat_mu: vec![0.0; NUM_FEATURES],
            feat_sigma: vec![1.0; NUM_FEATURES],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demo_scorer_validates() {
        assert!(RbfScorer::synthetic_demo().validate().is_ok());
    }

    #[test]
    fn entropy_peaks_at_uncertain_inputs() {
        let s = RbfScorer::synthetic_demo();
        // midpoint between prototypes → decision ≈ 0 → p ≈ 0.5 → H ≈ 1
        let mut mid = [0f32; NUM_FEATURES];
        mid[5] = -0.3;
        mid[6] = 0.35;
        let h_mid = s.entropy(&mid);
        // clearly oscillatory point → confident → low entropy
        let mut osc = [0f32; NUM_FEATURES];
        osc[5] = -0.8;
        osc[6] = 0.6;
        let h_osc = s.entropy(&osc);
        assert!(h_mid > h_osc, "H(mid)={h_mid} H(osc)={h_osc}");
        assert!(h_mid > 0.9);
    }

    #[test]
    fn probability_monotone_in_decision() {
        let s = RbfScorer::synthetic_demo();
        let mut near0 = [0f32; NUM_FEATURES];
        near0[5] = -0.8;
        near0[6] = 0.6;
        let mut near1 = [0f32; NUM_FEATURES];
        near1[5] = 0.2;
        near1[6] = 0.1;
        assert!(s.probability(&near0) > 0.5);
        assert!(s.probability(&near1) < 0.5);
    }

    #[test]
    fn json_roundtrip() {
        let s = RbfScorer::synthetic_demo();
        let mut obj = std::collections::BTreeMap::new();
        obj.insert(
            "support".into(),
            Json::Arr(s.support.iter().map(|&f| Json::Num(f as f64)).collect()),
        );
        obj.insert(
            "alpha".into(),
            Json::Arr(s.alpha.iter().map(|&f| Json::Num(f as f64)).collect()),
        );
        obj.insert("gamma".into(), Json::Num(s.gamma as f64));
        obj.insert("bias".into(), Json::Num(s.bias as f64));
        obj.insert("platt_a".into(), Json::Num(s.platt_a as f64));
        obj.insert("platt_b".into(), Json::Num(s.platt_b as f64));
        obj.insert(
            "feat_mu".into(),
            Json::Arr(s.feat_mu.iter().map(|&f| Json::Num(f as f64)).collect()),
        );
        obj.insert(
            "feat_sigma".into(),
            Json::Arr(s.feat_sigma.iter().map(|&f| Json::Num(f as f64)).collect()),
        );
        let j = Json::Obj(obj);
        let s2 = RbfScorer::from_json(&j).unwrap();
        assert_eq!(s, s2);
    }

    #[test]
    fn from_json_rejects_bad_shapes() {
        let j = Json::parse(
            r#"{"support":[1,2],"alpha":[1],"gamma":0.5,"bias":0,
                "platt_a":1,"platt_b":0,"feat_mu":[0],"feat_sigma":[1]}"#,
        )
        .unwrap();
        assert!(RbfScorer::from_json(&j).is_err());
    }

    #[test]
    fn score_series_separates_oscillatory_from_trend() {
        let s = RbfScorer::synthetic_demo();
        let osc: Vec<f32> = (0..256)
            .map(|i| (2.0 * std::f32::consts::PI * i as f32 / 32.0).sin())
            .collect();
        let flat: Vec<f32> = (0..256).map(|i| i as f32 * 0.01).collect();
        let (p_osc, _) = s.classify_series(&osc);
        let (p_flat, _) = s.classify_series(&flat);
        assert!(p_osc > p_flat, "p_osc={p_osc} p_flat={p_flat}");
    }
}
