//! Summary-statistic feature extraction from a document time series.
//!
//! **Contract:** this is the bit-level specification mirrored by the Pallas
//! kernel `python/compile/kernels/features.py`; parity is enforced by the
//! runtime tests (`rust/tests/runtime_parity.rs`) and the pytest suite.
//! Any change here must be mirrored there.
//!
//! Features (D = 8), for a series `x[0..T]`:
//! 0. mean
//! 1. population std
//! 2. range (max − min)
//! 3. lag-1 autocorrelation
//! 4. lag-4 autocorrelation
//! 5. lag-16 autocorrelation
//! 6. mean-crossing rate
//! 7. normalized half-window mean shift (trend indicator)
//!
//! Autocorrelations use the biased estimator `Σ_{i<T−L}(x_i−μ)(x_{i+L}−μ) /
//! Σ(x_i−μ)²` with 0 when the variance vanishes; the crossing rate counts
//! strict sign changes of `x − μ`. All math in f32 to match the kernel.

/// Feature dimensionality.
pub const NUM_FEATURES: usize = 8;

/// Autocorrelation lags used by features 3–5.
pub const AC_LAGS: [usize; 3] = [1, 4, 16];

/// Guard against division by ~zero, matching the kernel's epsilon.
pub const EPS: f32 = 1e-6;

/// Extract the 8 features from one series.
pub fn extract(series: &[f32]) -> [f32; NUM_FEATURES] {
    let t = series.len();
    assert!(t >= 2, "series too short");
    let tf = t as f32;

    let mean: f32 = series.iter().sum::<f32>() / tf;
    let var: f32 = series.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / tf;
    let std = var.sqrt();

    let mut min = f32::INFINITY;
    let mut max = f32::NEG_INFINITY;
    for &x in series {
        min = min.min(x);
        max = max.max(x);
    }
    let range = max - min;

    let denom: f32 = var * tf; // Σ(x−μ)²
    let mut acs = [0f32; 3];
    for (j, &lag) in AC_LAGS.iter().enumerate() {
        if lag < t && denom > EPS {
            let num: f32 = (0..t - lag)
                .map(|i| (series[i] - mean) * (series[i + lag] - mean))
                .sum();
            acs[j] = num / denom;
        }
    }

    // mean-crossing rate: fraction of adjacent pairs with opposite signs
    // of (x − mean); implemented as product < 0 (strict), matching jnp.
    let crossings = (0..t - 1)
        .filter(|&i| (series[i] - mean) * (series[i + 1] - mean) < 0.0)
        .count() as f32;
    let crossing_rate = crossings / (tf - 1.0);

    // half-window mean shift, normalized by std
    let half = t / 2;
    let m1: f32 = series[..half].iter().sum::<f32>() / half as f32;
    let m2: f32 = series[half..].iter().sum::<f32>() / (t - half) as f32;
    let shift = (m2 - m1) / (std + EPS);

    [mean, std, range, acs[0], acs[1], acs[2], crossing_rate, shift]
}

/// Batched extraction (row-major output, B × D).
pub fn extract_batch(series: &[Vec<f32>]) -> Vec<[f32; NUM_FEATURES]> {
    series.iter().map(|s| extract(s)).collect()
}

/// Standardize features in place with per-feature (mu, sigma).
pub fn standardize(f: &mut [f32; NUM_FEATURES], mu: &[f32], sigma: &[f32]) {
    for i in 0..NUM_FEATURES {
        f[i] = (f[i] - mu[i]) / (sigma[i] + EPS);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_series_features() {
        let s = vec![5.0f32; 64];
        let f = extract(&s);
        assert_eq!(f[0], 5.0); // mean
        assert_eq!(f[1], 0.0); // std
        assert_eq!(f[2], 0.0); // range
        assert_eq!(f[3], 0.0); // ACs guard to 0
        assert_eq!(f[6], 0.0); // no crossings
        assert_eq!(f[7], 0.0); // no shift
    }

    #[test]
    fn alternating_series_crossing_rate_is_one() {
        let s: Vec<f32> = (0..64).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let f = extract(&s);
        assert_eq!(f[0], 0.0);
        assert_eq!(f[6], 1.0, "every adjacent pair crosses the mean");
        // lag-1 AC of ±1 alternation is −1 (up to the biased-estimator edge)
        assert!(f[3] < -0.9, "lag-1 AC {}", f[3]);
    }

    #[test]
    fn linear_trend_shift_positive() {
        let s: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let f = extract(&s);
        assert!(f[7] > 1.0, "trend shift {}", f[7]);
        assert!((f[2] - 99.0).abs() < 1e-3);
    }

    #[test]
    fn sine_wave_has_periodic_autocorrelation() {
        // period-32 sine: lag-16 AC ≈ −1 (half period), lag-1 ≈ cos(2π/32)
        let s: Vec<f32> = (0..256)
            .map(|i| (2.0 * std::f32::consts::PI * i as f32 / 32.0).sin())
            .collect();
        let f = extract(&s);
        assert!(f[5] < -0.8, "lag-16 AC {}", f[5]);
        assert!(f[3] > 0.9, "lag-1 AC {}", f[3]);
    }

    #[test]
    fn standardize_centers() {
        let mut f = extract(&(0..64).map(|i| i as f32).collect::<Vec<_>>());
        let mu = f;
        let sigma = [1.0f32; NUM_FEATURES];
        standardize(&mut f, &mu, &sigma);
        for v in f {
            assert!(v.abs() < 1e-5);
        }
    }

    #[test]
    fn batch_matches_single() {
        let a: Vec<f32> = (0..32).map(|i| (i as f32).sin()).collect();
        let b: Vec<f32> = (0..32).map(|i| i as f32 * 0.5).collect();
        let batch = extract_batch(&[a.clone(), b.clone()]);
        assert_eq!(batch[0], extract(&a));
        assert_eq!(batch[1], extract(&b));
    }
}
