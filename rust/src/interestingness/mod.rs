//! Native interestingness function: the Rust mirror of the L2/L1 stack
//! (feature extraction → RBF kernel machine → Platt → label entropy).
//!
//! Used (a) as the parity oracle against the AOT PJRT artifact, (b) as a
//! CPU fallback scorer when artifacts are absent, and (c) by the Fig. 6/7
//! experiments.

pub mod features;
pub mod svm;

pub use features::{extract, extract_batch, standardize, AC_LAGS, EPS, NUM_FEATURES};
pub use svm::RbfScorer;
