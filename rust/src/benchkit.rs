//! Minimal benchmarking harness (the vendored crate set has no criterion).
//!
//! Criterion-style reporting: warmup, timed iterations, mean ± stddev,
//! optional throughput. Used by the `benches/` targets (harness = false).

use std::time::{Duration, Instant};

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub std_dev: Duration,
    pub min: Duration,
    pub max: Duration,
    /// Items processed per iteration (for throughput reporting).
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<44} {:>12} ± {:<10} (min {:?}, max {:?}, {} iters)",
            self.name,
            format_duration(self.mean),
            format_duration(self.std_dev),
            self.min,
            self.max,
            self.iters
        );
        if let Some(items) = self.items_per_iter {
            let per_sec = items / self.mean.as_secs_f64();
            s.push_str(&format!("  [{} items/s]", format_rate(per_sec)));
        }
        s
    }
}

fn format_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1_000.0)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1_000_000.0)
    } else {
        format!("{:.2}s", ns as f64 / 1_000_000_000.0)
    }
}

fn format_rate(r: f64) -> String {
    if r >= 1e9 {
        format!("{:.2}G", r / 1e9)
    } else if r >= 1e6 {
        format!("{:.2}M", r / 1e6)
    } else if r >= 1e3 {
        format!("{:.2}k", r / 1e3)
    } else {
        format!("{r:.1}")
    }
}

/// Benchmark runner with a fixed time budget per benchmark.
pub struct Bencher {
    warmup: Duration,
    measure: Duration,
    max_iters: u64,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            max_iters: 10_000,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new(warmup: Duration, measure: Duration) -> Self {
        Self { warmup, measure, max_iters: 10_000, results: Vec::new() }
    }

    /// Quick mode for CI (`SHPTIER_BENCH_QUICK=1`).
    pub fn from_env() -> Self {
        if std::env::var_os("SHPTIER_BENCH_QUICK").is_some() {
            Self::new(Duration::from_millis(50), Duration::from_millis(300))
        } else {
            Self::default()
        }
    }

    /// Time `f` repeatedly; `items` is the per-iteration workload size for
    /// throughput reporting (0 = none). The closure's return value is
    /// black-boxed to keep the optimizer honest.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, items: u64, mut f: F) -> &BenchResult {
        // warmup
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // measure
        let mut samples = Vec::new();
        let m0 = Instant::now();
        while m0.elapsed() < self.measure && (samples.len() as u64) < self.max_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        let n = samples.len().max(1) as u32;
        let total: Duration = samples.iter().sum();
        let mean = total / n;
        let mean_ns = mean.as_nanos() as f64;
        let var = samples
            .iter()
            .map(|s| {
                let d = s.as_nanos() as f64 - mean_ns;
                d * d
            })
            .sum::<f64>()
            / n as f64;
        let result = BenchResult {
            name: name.to_string(),
            iters: n as u64,
            mean,
            std_dev: Duration::from_nanos(var.sqrt() as u64),
            min: samples.iter().min().copied().unwrap_or_default(),
            max: samples.iter().max().copied().unwrap_or_default(),
            items_per_iter: if items > 0 { Some(items as f64) } else { None },
        };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher::new(Duration::from_millis(5), Duration::from_millis(30));
        let r = b.bench("noopish", 100, || {
            let mut x = 0u64;
            for i in 0..100 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(r.iters > 0);
        assert!(r.mean.as_nanos() > 0);
        assert!(r.items_per_iter == Some(100.0));
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(format_duration(Duration::from_nanos(500)), "500ns");
        assert!(format_duration(Duration::from_micros(1500)).contains("ms"));
        assert!(format_rate(2_500_000.0).contains('M'));
    }
}
