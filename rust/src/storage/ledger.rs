//! Exact cost accounting for the storage simulator.
//!
//! Every operation (write, read, delete, migration hop) and every
//! doc-window-fraction of rent is charged to the originating tier, so a
//! trace-driven run can be reconciled line-by-line against the analytic
//! expectations of [`crate::cost::analytic`].

use super::tier::TierId;
use std::collections::BTreeMap;

/// Per-tier accumulated charges.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TierCharges {
    pub writes: u64,
    pub write_cost: f64,
    pub reads: u64,
    pub read_cost: f64,
    pub deletes: u64,
    /// Accumulated resident doc-time, in units of (documents × window).
    pub rent_doc_windows: f64,
    pub rent_cost: f64,
    /// Writes/reads that were part of a bulk migration (also counted in
    /// `writes`/`reads`; tracked separately for reporting).
    pub migration_ops: u64,
    pub migration_cost: f64,
}

/// The run-wide ledger.
#[derive(Debug, Clone, Default)]
pub struct Ledger {
    tiers: BTreeMap<TierId, TierCharges>,
}

impl Ledger {
    pub fn new() -> Self {
        Self::default()
    }

    fn tier_mut(&mut self, t: TierId) -> &mut TierCharges {
        self.tiers.entry(t).or_default()
    }

    pub fn charge_write(&mut self, t: TierId, cost: f64) {
        let c = self.tier_mut(t);
        c.writes += 1;
        c.write_cost += cost;
    }

    pub fn charge_read(&mut self, t: TierId, cost: f64) {
        let c = self.tier_mut(t);
        c.reads += 1;
        c.read_cost += cost;
    }

    pub fn charge_delete(&mut self, t: TierId) {
        self.tier_mut(t).deletes += 1;
    }

    /// Charge rent for one document resident on `t` for `window_frac` of
    /// the stream window, at `rent_window` $ per full window.
    pub fn charge_rent(&mut self, t: TierId, window_frac: f64, rent_window: f64) {
        debug_assert!((0.0..=1.0 + 1e-9).contains(&window_frac), "frac={window_frac}");
        let c = self.tier_mut(t);
        c.rent_doc_windows += window_frac;
        c.rent_cost += window_frac * rent_window;
    }

    /// Record that the *last* write/read on `t` was a migration hop of the
    /// given cost (the op itself must already have been charged).
    pub fn tag_migration(&mut self, t: TierId, cost: f64) {
        let c = self.tier_mut(t);
        c.migration_ops += 1;
        c.migration_cost += cost;
    }

    /// Overwrite one tier's accumulated charges — journal-checkpoint
    /// restore only (normal accounting goes through the `charge_*` /
    /// `tag_migration` paths).
    pub(crate) fn restore_tier(&mut self, t: TierId, charges: TierCharges) {
        self.tiers.insert(t, charges);
    }

    pub fn tier(&self, t: TierId) -> TierCharges {
        self.tiers.get(&t).copied().unwrap_or_default()
    }

    pub fn tiers(&self) -> impl Iterator<Item = (&TierId, &TierCharges)> {
        self.tiers.iter()
    }

    /// Total $ across all tiers and charge classes.
    pub fn total(&self) -> f64 {
        self.tiers
            .values()
            .map(|c| c.write_cost + c.read_cost + c.rent_cost)
            .sum()
    }

    /// Total writes across tiers (migration hops included).
    pub fn total_writes(&self) -> u64 {
        self.tiers.values().map(|c| c.writes).sum()
    }

    pub fn total_reads(&self) -> u64 {
        self.tiers.values().map(|c| c.reads).sum()
    }

    /// Total $ of migration hops (subset of write+read cost).
    pub fn migration_total(&self) -> f64 {
        self.tiers.values().map(|c| c.migration_cost).sum()
    }

    /// Writes net of migration hops — comparable to the analytic
    /// record-process write count.
    pub fn organic_writes(&self) -> u64 {
        let migration_writes: u64 = self
            .tiers
            .values()
            .map(|c| c.migration_ops) // each hop = 1 read + 1 write; ops tagged on dst write and src read
            .sum();
        self.total_writes().saturating_sub(migration_writes / 2)
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        let mut parts = Vec::new();
        for (t, c) in &self.tiers {
            parts.push(format!(
                "{}: w={} (${:.4}) r={} (${:.4}) rent=${:.4}",
                t.label(),
                c.writes,
                c.write_cost,
                c.reads,
                c.read_cost,
                c.rent_cost
            ));
        }
        format!("{} | total=${:.4}", parts.join("  "), self.total())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charges_accumulate() {
        let mut l = Ledger::new();
        l.charge_write(TierId::A, 2.0);
        l.charge_write(TierId::A, 2.0);
        l.charge_read(TierId::B, 5.0);
        l.charge_rent(TierId::B, 0.5, 4.0);
        assert_eq!(l.tier(TierId::A).writes, 2);
        assert_eq!(l.tier(TierId::A).write_cost, 4.0);
        assert_eq!(l.tier(TierId::B).reads, 1);
        assert_eq!(l.tier(TierId::B).rent_cost, 2.0);
        assert_eq!(l.total(), 4.0 + 5.0 + 2.0);
        assert_eq!(l.total_writes(), 2);
        assert_eq!(l.total_reads(), 1);
    }

    #[test]
    fn unknown_tier_reads_zero() {
        let l = Ledger::new();
        assert_eq!(l.tier(TierId(9)), TierCharges::default());
        assert_eq!(l.total(), 0.0);
    }

    #[test]
    fn migration_tagging() {
        let mut l = Ledger::new();
        // one hop: read from A + write to B
        l.charge_read(TierId::A, 1.0);
        l.tag_migration(TierId::A, 1.0);
        l.charge_write(TierId::B, 3.0);
        l.tag_migration(TierId::B, 3.0);
        assert_eq!(l.migration_total(), 4.0);
        assert_eq!(l.total_writes(), 1);
        assert_eq!(l.organic_writes(), 0);
    }

    #[test]
    fn summary_contains_totals() {
        let mut l = Ledger::new();
        l.charge_write(TierId::A, 1.5);
        let s = l.summary();
        assert!(s.contains("A:"), "{s}");
        assert!(s.contains("total=$1.5"), "{s}");
    }
}
