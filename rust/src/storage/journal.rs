//! The write-ahead journal shared by every durable [`StorageBackend`]
//! (ADR-003 laid it down for the filesystem backend; ADR-005 extracts it
//! here so the object-store backend's manifest log is the same machinery).
//!
//! ## Record grammar
//!
//! One line per record; window fractions, costs, and ledger dollars are
//! hexadecimal `f64::to_bits`, so replay is bit-exact:
//!
//! ```text
//! shptier-fs v1 rent=<0|1> costs=<w:r:rw,...>      # header
//! put <doc> <tier> <at-bits> <owner|->
//! del <doc> <at-bits>
//! read <doc>
//! mig <doc> <to> <at-bits>
//! migall <from> <to> <at-bits>
//! migstream <stream> <from> <to> <at-bits>         # one record per bulk batch
//! settle <at-bits>
//! reg <stream> <w:r:rw,...> [note]                 # note: hex-encoded utf-8, optional
//! batch <n>                                        # group-commit frame: n op records follow
//! ckpt-begin <body-lines>                          # checkpoint block...
//! cdoc <doc> <tier> <at-bits> <owner|->            #   residency + rent clock
//! creg <stream> <w:r:rw,...> [note]                #   stream economics (+ tenancy note)
//! cled <stream|-> <tier> <charges...>              #   ledger rows (run + per-stream)
//! cpeak <tier> <peak>                              #   occupancy high-water marks
//! ckpt-end                                         # ...complete only with this
//! ```
//!
//! ## Group commit (ADR-009)
//!
//! With [`Journal::set_group_commit`] enabled, op records accumulate in
//! a bounded in-memory buffer and reach the file as one framed
//! `batch <n>` record — one `write_all`, one flush, at most one fsync —
//! when the buffer hits [`GROUP_COMMIT_BATCH_CAP`] records, a buffered
//! record gets older than [`GROUP_COMMIT_AGE`] (checked by
//! [`Journal::flush_if_due`]), or a forced barrier flushes explicitly
//! (checkpoint, bulk migration, engine close/drain, wedge, drop).
//!
//! A batch is atomic on replay: either all `n` records are complete and
//! apply, or the torn batch is dropped *whole* — the heal cut lands on
//! the byte before the `batch` frame, so recovery always observes a
//! prefix of the op stream cut at a batch boundary (the bounded
//! staleness window). Unframed op lines remain valid and replay exactly
//! as before, so per-op and group-commit appends can interleave in one
//! journal.
//!
//! ## Checkpoint / compaction (two-phase)
//!
//! [`Journal::checkpoint`] first *appends* a checkpoint block to the live
//! journal (a kill here leaves `header + ops + torn block`, and recovery
//! falls back to replaying the ops), then *compacts*: the journal is
//! rewritten as `header + block` into a temp file and atomically renamed
//! over the old one (a kill here leaves either file intact — never a
//! mix). After compaction the journal's length is a function of live
//! state only, never of operation count.
//!
//! ## Replay
//!
//! [`replay`] scans the journal once: the latest *complete* checkpoint
//! block resets the accounting state to its snapshot, op records apply on
//! top, a torn trailing line (or torn checkpoint block) is dropped, and a
//! torn *header* heals to a fresh journal. The file is healed in place so
//! subsequent appends land on a clean line.

use super::ledger::TierCharges;
use super::sim::StorageSim;
use super::tier::TierId;
use crate::cost::PerDocCosts;
use anyhow::{bail, Context, Result};
use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

pub(crate) const JOURNAL_MAGIC: &str = "shptier-fs";
pub(crate) const JOURNAL_VERSION: u32 = 1;

/// Op records a group-commit batch may buffer before a flush is forced
/// (the size cap).
pub(crate) const GROUP_COMMIT_BATCH_CAP: u64 = 64;

/// Oldest a buffered op record may get before [`Journal::flush_if_due`]
/// forces a flush (the age cap — this bounds the staleness window in
/// wall-clock terms for long-idle engines).
pub(crate) const GROUP_COMMIT_AGE: Duration = Duration::from_millis(10);

// ---- scalar encoding -------------------------------------------------------

pub(crate) fn fmt_bits(x: f64) -> String {
    format!("{:x}", x.to_bits())
}

pub(crate) fn parse_bits(s: &str) -> Result<f64> {
    u64::from_str_radix(s, 16)
        .map(f64::from_bits)
        .with_context(|| format!("bad f64 bits '{s}'"))
}

pub(crate) fn parse_u64(s: &str) -> Result<u64> {
    s.parse::<u64>().with_context(|| format!("bad integer '{s}'"))
}

pub(crate) fn fmt_costs(costs: &[PerDocCosts]) -> String {
    costs
        .iter()
        .map(|c| {
            format!(
                "{}:{}:{}",
                fmt_bits(c.write),
                fmt_bits(c.read),
                fmt_bits(c.rent_window)
            )
        })
        .collect::<Vec<_>>()
        .join(",")
}

pub(crate) fn parse_costs(s: &str) -> Result<Vec<PerDocCosts>> {
    s.split(',')
        .map(|entry| {
            let mut it = entry.split(':');
            let write = parse_bits(it.next().unwrap_or(""))?;
            let read = parse_bits(it.next().context("cost entry missing read")?)?;
            let rent_window = parse_bits(it.next().context("cost entry missing rent")?)?;
            if it.next().is_some() {
                bail!("cost entry '{entry}' has trailing fields");
            }
            Ok(PerDocCosts { write, read, rent_window })
        })
        .collect()
}

pub(crate) fn header_line(costs: &[PerDocCosts], charge_rent: bool) -> String {
    format!(
        "{JOURNAL_MAGIC} v{JOURNAL_VERSION} rent={} costs={}\n",
        u8::from(charge_rent),
        fmt_costs(costs)
    )
}

/// Encode a free-form stream note (serve-layer tenancy, ADR-009) as a
/// whitespace-free hex token so it can ride a space-separated record.
pub(crate) fn fmt_note(note: &str) -> String {
    let mut out = String::with_capacity(note.len() * 2);
    for b in note.bytes() {
        out.push_str(&format!("{b:02x}"));
    }
    out
}

pub(crate) fn parse_note(s: &str) -> Result<String> {
    if s.len() % 2 != 0 || s.is_empty() {
        bail!("bad note token '{s}'");
    }
    let mut bytes = Vec::with_capacity(s.len() / 2);
    for i in (0..s.len()).step_by(2) {
        let b = u8::from_str_radix(&s[i..i + 2], 16)
            .with_context(|| format!("bad note token '{s}'"))?;
        bytes.push(b);
    }
    String::from_utf8(bytes).with_context(|| format!("note token '{s}' is not utf-8"))
}

fn fmt_owner(owner: Option<u64>) -> String {
    match owner {
        Some(s) => s.to_string(),
        None => "-".into(),
    }
}

fn parse_owner(s: &str) -> Result<Option<u64>> {
    match s {
        "-" => Ok(None),
        other => Ok(Some(parse_u64(other)?)),
    }
}

fn fmt_charges(c: &TierCharges) -> String {
    format!(
        "{} {} {} {} {} {} {} {} {}",
        c.writes,
        fmt_bits(c.write_cost),
        c.reads,
        fmt_bits(c.read_cost),
        c.deletes,
        fmt_bits(c.rent_doc_windows),
        fmt_bits(c.rent_cost),
        c.migration_ops,
        fmt_bits(c.migration_cost)
    )
}

// ---- op replay -------------------------------------------------------------

/// Apply one journal op record to the accounting state. Op records are
/// only written for operations that already succeeded, so replay against
/// an uncapacitated fresh state must succeed too.
pub(crate) fn replay_line(state: &mut StorageSim, line: &str) -> Result<()> {
    let mut parts = line.split(' ');
    let op = parts.next().unwrap_or("");
    let mut next = |what: &str| -> Result<&str> {
        parts.next().with_context(|| format!("'{op}' record missing {what}"))
    };
    match op {
        "put" => {
            let doc = parse_u64(next("doc")?)?;
            let tier = parse_u64(next("tier")?)? as usize;
            let at = parse_bits(next("at")?)?;
            let owner = parse_owner(next("owner")?)?;
            state.set_attribution(owner);
            state.put(doc, TierId(tier), at)?;
        }
        "del" => {
            let doc = parse_u64(next("doc")?)?;
            let at = parse_bits(next("at")?)?;
            state.delete(doc, at)?;
        }
        "read" => {
            let doc = parse_u64(next("doc")?)?;
            state.read(doc)?;
        }
        "mig" => {
            let doc = parse_u64(next("doc")?)?;
            let to = parse_u64(next("to")?)? as usize;
            let at = parse_bits(next("at")?)?;
            state.migrate_doc(doc, TierId(to), at)?;
        }
        "migall" => {
            let from = parse_u64(next("from")?)? as usize;
            let to = parse_u64(next("to")?)? as usize;
            let at = parse_bits(next("at")?)?;
            state.migrate_all(TierId(from), TierId(to), at)?;
        }
        "migstream" => {
            let stream = parse_u64(next("stream")?)?;
            let from = parse_u64(next("from")?)? as usize;
            let to = parse_u64(next("to")?)? as usize;
            let at = parse_bits(next("at")?)?;
            state.migrate_stream(stream, TierId(from), TierId(to), at)?;
        }
        "settle" => {
            let at = parse_bits(next("at")?)?;
            state.settle_rent(at);
        }
        "reg" => {
            let stream = parse_u64(next("stream")?)?;
            let costs = parse_costs(next("costs")?)?;
            state.register_stream(stream, costs)?;
            if let Some(tok) = parts.next() {
                state.set_stream_note(stream, parse_note(tok)?);
            }
        }
        other => bail!("unknown journal op '{other}'"),
    }
    Ok(())
}

// ---- checkpoint encoding ---------------------------------------------------

/// Serialize the full accounting state as a checkpoint block (every line
/// `\n`-terminated, `ckpt-begin`/`ckpt-end` included). Deterministic:
/// docs, streams, and ledger rows come out sorted.
pub(crate) fn checkpoint_block(state: &StorageSim) -> String {
    let mut body: Vec<String> = Vec::new();
    for t in 0..state.num_tiers() {
        let tier = state.tier(TierId(t));
        for doc in tier.docs() {
            let r = tier.get(doc).expect("doc listed by its tier");
            body.push(format!(
                "cdoc {doc} {t} {} {}",
                fmt_bits(r.written_at),
                fmt_owner(r.owner)
            ));
        }
    }
    for (stream, costs) in state.registered_streams() {
        let mut line = format!("creg {stream} {}", fmt_costs(costs));
        if let Some(note) = state.stream_note(*stream) {
            line.push(' ');
            line.push_str(&fmt_note(note));
        }
        body.push(line);
    }
    for (tier, charges) in state.ledger().tiers() {
        body.push(format!("cled - {} {}", tier.0, fmt_charges(charges)));
    }
    for (stream, ledger) in state.stream_ledgers() {
        for (tier, charges) in ledger.tiers() {
            body.push(format!("cled {stream} {} {}", tier.0, fmt_charges(charges)));
        }
    }
    for t in 0..state.num_tiers() {
        let peak = state.tier(TierId(t)).peak_len();
        if peak > 0 {
            body.push(format!("cpeak {t} {peak}"));
        }
    }
    let mut out = format!("ckpt-begin {}\n", body.len());
    for line in &body {
        out.push_str(line);
        out.push('\n');
    }
    out.push_str("ckpt-end\n");
    out
}

/// Rebuild the accounting state from a complete checkpoint block body.
fn restore_checkpoint(
    body: &[&str],
    costs: &[PerDocCosts],
    charge_rent: bool,
) -> Result<StorageSim> {
    let mut state = StorageSim::with_tiers(costs.to_vec(), charge_rent);
    for line in body {
        let mut parts = line.split(' ');
        let op = parts.next().unwrap_or("");
        let mut next = |what: &str| -> Result<&str> {
            parts
                .next()
                .with_context(|| format!("'{op}' checkpoint record missing {what}"))
        };
        match op {
            "cdoc" => {
                let doc = parse_u64(next("doc")?)?;
                let tier = parse_u64(next("tier")?)? as usize;
                let at = parse_bits(next("at")?)?;
                let owner = parse_owner(next("owner")?)?;
                state.restore_resident(doc, TierId(tier), at, owner)?;
            }
            "creg" => {
                let stream = parse_u64(next("stream")?)?;
                let costs = parse_costs(next("costs")?)?;
                state.register_stream(stream, costs)?;
                if let Some(tok) = parts.next() {
                    state.set_stream_note(stream, parse_note(tok)?);
                }
            }
            "cled" => {
                let stream = parse_owner(next("stream")?)?;
                let tier = parse_u64(next("tier")?)? as usize;
                let charges = TierCharges {
                    writes: parse_u64(next("writes")?)?,
                    write_cost: parse_bits(next("write_cost")?)?,
                    reads: parse_u64(next("reads")?)?,
                    read_cost: parse_bits(next("read_cost")?)?,
                    deletes: parse_u64(next("deletes")?)?,
                    rent_doc_windows: parse_bits(next("rent_doc_windows")?)?,
                    rent_cost: parse_bits(next("rent_cost")?)?,
                    migration_ops: parse_u64(next("migration_ops")?)?,
                    migration_cost: parse_bits(next("migration_cost")?)?,
                };
                state.restore_tier_charges(stream, TierId(tier), charges);
            }
            "cpeak" => {
                let tier = parse_u64(next("tier")?)? as usize;
                let peak = parse_u64(next("peak")?)? as usize;
                state.restore_peak(TierId(tier), peak);
            }
            other => bail!("unknown checkpoint record '{other}'"),
        }
    }
    state.set_attribution(None);
    Ok(state)
}

// ---- replay ----------------------------------------------------------------

/// What a journal scan rebuilt and healed.
pub(crate) struct Replay {
    /// The rebuilt accounting state.
    pub state: StorageSim,
    /// Op records applied *on top of the latest complete checkpoint* —
    /// the replay suffix. Ops a loaded checkpoint folded away are not
    /// counted: their effect arrived via the snapshot, not replay.
    pub ops_replayed: u64,
    /// Complete checkpoint blocks loaded (the last one wins).
    pub checkpoints_loaded: u64,
    /// Whether a torn trailing line / torn checkpoint block was dropped,
    /// or a torn header healed.
    pub truncated_tail: bool,
}

/// Scan `path`, rebuild the accounting state (latest complete checkpoint
/// + op suffix), and heal the file in place: drop a torn tail or torn
/// checkpoint block, rewrite a torn header, remove a stale compaction
/// temp file. The declared `costs`/`charge_rent` must match the header.
pub(crate) fn replay(path: &Path, costs: &[PerDocCosts], charge_rent: bool) -> Result<Replay> {
    let _ = fs::remove_file(tmp_path(path)); // stale compaction attempt
    let text = fs::read_to_string(path)
        .with_context(|| format!("reading journal {}", path.display()))?;
    let mut state = StorageSim::with_tiers(costs.to_vec(), charge_rent);
    let mut ops_replayed = 0u64;
    let mut checkpoints_loaded = 0u64;
    let mut truncated_tail = false;
    let mut saw_header = false;
    let mut valid_len = 0usize;

    let segs: Vec<&str> = text.split_inclusive('\n').collect();
    let mut i = 0usize;
    while i < segs.len() {
        let seg = segs[i];
        if !seg.ends_with('\n') {
            // torn trailing write: the record never durably happened
            truncated_tail = true;
            break;
        }
        let line = &seg[..seg.len() - 1];
        if !saw_header {
            let expected = header_line(costs, charge_rent);
            if seg != expected {
                bail!(
                    "journal {} header mismatch: backend opened with different \
                     economics (journal '{}', expected '{}')",
                    path.display(),
                    line,
                    expected.trim_end()
                );
            }
            saw_header = true;
            valid_len += seg.len();
            i += 1;
            continue;
        }
        if line.is_empty() {
            valid_len += seg.len();
            i += 1;
            continue;
        }
        if let Some(rest) = line.strip_prefix("ckpt-begin ") {
            let declared = parse_u64(rest.trim())
                .with_context(|| format!("journal line {}", i + 1))?
                as usize;
            // collect the block: complete only if `ckpt-end` arrives on a
            // complete line
            let mut body: Vec<&str> = Vec::new();
            let mut block_len = seg.len();
            let mut j = i + 1;
            let mut complete = false;
            while j < segs.len() {
                let s = segs[j];
                if !s.ends_with('\n') {
                    break;
                }
                let l = &s[..s.len() - 1];
                block_len += s.len();
                j += 1;
                if l == "ckpt-end" {
                    complete = true;
                    break;
                }
                body.push(l);
            }
            if !complete {
                // torn checkpoint: the snapshot never durably finished —
                // keep the state replayed so far and drop the block
                truncated_tail = true;
                break;
            }
            if body.len() != declared {
                bail!(
                    "journal {} checkpoint at line {} declares {} records but \
                     carries {}",
                    path.display(),
                    i + 1,
                    declared,
                    body.len()
                );
            }
            state = restore_checkpoint(&body, costs, charge_rent)
                .with_context(|| format!("journal checkpoint at line {}", i + 1))?;
            checkpoints_loaded += 1;
            // the snapshot superseded everything replayed so far: the
            // replay suffix (and the report's op count) restarts here
            ops_replayed = 0;
            valid_len += block_len;
            i = j;
            continue;
        }
        if let Some(rest) = line.strip_prefix("batch ") {
            let declared = parse_u64(rest.trim())
                .with_context(|| format!("journal line {}", i + 1))?
                as usize;
            // A group-commit batch is atomic: either every one of its op
            // records is complete, or the torn batch is dropped whole —
            // the heal cut lands on the byte *before* the frame line, so
            // recovery is always a batch-boundary prefix of the op
            // stream.
            let mut body: Vec<&str> = Vec::new();
            let mut batch_len = seg.len();
            let mut j = i + 1;
            while j < segs.len() && body.len() < declared {
                let s = segs[j];
                if !s.ends_with('\n') {
                    break;
                }
                body.push(&s[..s.len() - 1]);
                batch_len += s.len();
                j += 1;
            }
            if body.len() != declared {
                truncated_tail = true;
                break;
            }
            for (off, l) in body.iter().enumerate() {
                replay_line(&mut state, l)
                    .with_context(|| format!("journal line {}", i + 2 + off))?;
            }
            ops_replayed += declared as u64;
            valid_len += batch_len;
            i = j;
            continue;
        }
        replay_line(&mut state, line)
            .with_context(|| format!("journal line {}", i + 1))?;
        ops_replayed += 1;
        valid_len += seg.len();
        i += 1;
    }
    if !saw_header {
        // No complete header means no operation was ever durably recorded
        // (records only follow a header): the process died while the
        // journal was being created. Heal with a fresh header instead of
        // bricking the root.
        truncated_tail = true;
    }
    state.set_attribution(None);

    // Heal in place so appends land on a clean line.
    if !saw_header {
        fs::write(path, header_line(costs, charge_rent))
            .context("rewriting torn journal header")?;
    } else if truncated_tail {
        let file = OpenOptions::new().write(true).open(path)?;
        file.set_len(valid_len as u64)
            .context("truncating torn journal tail")?;
    }
    Ok(Replay { state, ops_replayed, checkpoints_loaded, truncated_tail })
}

fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().unwrap_or_default().to_os_string();
    name.push(".tmp");
    path.with_file_name(name)
}

fn parent_dir(path: &Path) -> &Path {
    match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p,
        _ => Path::new("."),
    }
}

/// Make a rename/create inside `dir` durable. Directory entries live in
/// the directory's own blocks, which fsyncing the files *inside* it
/// never touches — skipping this is how a power loss can resurrect a
/// pre-compaction journal after a "successful" atomic rename.
fn sync_dir(dir: &Path) -> Result<()> {
    File::open(dir)
        .and_then(|d| d.sync_all())
        .with_context(|| format!("fsyncing directory {}", dir.display()))
}

// ---- the append handle -----------------------------------------------------

/// Append handle over a journal file. In per-op mode (the default)
/// every record is flushed (and optionally fsynced) before the caller
/// touches any substrate; in group-commit mode records buffer in memory
/// and reach the file as framed `batch <n>` records. The op counter
/// tracks the replay suffix on top of the latest checkpoint — buffered
/// records count too, so checkpoint policy sees the true suffix size.
pub(crate) struct Journal {
    path: PathBuf,
    writer: BufWriter<File>,
    sync_writes: bool,
    ops: u64,
    group_commit: bool,
    buf: String,
    buffered: u64,
    oldest_buffered: Option<Instant>,
}

impl Journal {
    /// Create a fresh journal holding only the header.
    pub fn create(path: PathBuf, costs: &[PerDocCosts], charge_rent: bool) -> Result<Self> {
        let mut file = File::create(&path)
            .with_context(|| format!("creating journal {}", path.display()))?;
        file.write_all(header_line(costs, charge_rent).as_bytes())
            .context("writing journal header")?;
        Ok(Self {
            path,
            writer: BufWriter::new(file),
            sync_writes: false,
            ops: 0,
            group_commit: false,
            buf: String::new(),
            buffered: 0,
            oldest_buffered: None,
        })
    }

    /// Reopen an existing (already healed) journal for appends.
    /// `suffix_ops` is the op count [`replay`] found past the latest
    /// checkpoint.
    pub fn open_append(path: PathBuf, suffix_ops: u64) -> Result<Self> {
        let file = OpenOptions::new()
            .append(true)
            .open(&path)
            .with_context(|| format!("reopening journal {}", path.display()))?;
        Ok(Self {
            path,
            writer: BufWriter::new(file),
            sync_writes: false,
            ops: suffix_ops,
            group_commit: false,
            buf: String::new(),
            buffered: 0,
            oldest_buffered: None,
        })
    }

    /// `fsync` on every durable append (power-loss durability, not just
    /// process death). Enabling also syncs everything already written —
    /// header included — plus the parent directory entry: the flag used
    /// to cover only *future* appends, leaving a freshly created
    /// journal's header (the line the replayer requires) vulnerable to
    /// power loss.
    pub fn set_sync(&mut self, sync: bool) -> Result<()> {
        self.sync_writes = sync;
        if sync {
            self.writer.flush().context("flushing journal for sync")?;
            self.writer
                .get_ref()
                .sync_data()
                .context("syncing journal header")?;
            sync_dir(parent_dir(&self.path))?;
        }
        Ok(())
    }

    /// Buffer op records in memory and durably append them as one
    /// framed `batch <n>` record (one `write_all`, at most one fsync)
    /// instead of flushing per op. Disabling flushes anything pending.
    pub fn set_group_commit(&mut self, enabled: bool) -> Result<()> {
        if !enabled {
            self.flush_batch()?;
        }
        self.group_commit = enabled;
        Ok(())
    }

    /// Op records currently in the replay suffix (0 right after a
    /// checkpoint or on a fresh journal). Buffered records are counted:
    /// they are committed work as far as accounting and checkpoint
    /// policy are concerned, just not yet durable.
    pub fn ops(&self) -> u64 {
        self.ops
    }

    /// Op records buffered in memory, not yet durable (always 0 in
    /// per-op mode and right after a barrier).
    pub fn buffered(&self) -> u64 {
        self.buffered
    }

    /// Durably write the pending batch, if any, as one framed record.
    /// Every forced barrier (checkpoint, bulk migration, engine
    /// close/drain, wedge) lands here.
    pub fn flush_batch(&mut self) -> Result<()> {
        if self.buffered == 0 {
            return Ok(());
        }
        let framed = format!("batch {}\n{}", self.buffered, self.buf);
        self.write_flush(framed.as_bytes())?;
        self.buf.clear();
        self.buffered = 0;
        self.oldest_buffered = None;
        Ok(())
    }

    /// Flush the pending batch if it hit the size cap or its oldest
    /// record aged past [`GROUP_COMMIT_AGE`].
    pub fn flush_if_due(&mut self) -> Result<()> {
        let due = self.buffered >= GROUP_COMMIT_BATCH_CAP
            || self
                .oldest_buffered
                .is_some_and(|t| t.elapsed() >= GROUP_COMMIT_AGE);
        if due {
            self.flush_batch()?;
        }
        Ok(())
    }

    fn write_flush(&mut self, bytes: &[u8]) -> Result<()> {
        self.writer.write_all(bytes)?;
        self.writer.flush()?;
        if self.sync_writes {
            self.writer.get_ref().sync_data()?;
        }
        Ok(())
    }

    /// Append one op record (no trailing newline in `line`). In
    /// group-commit mode the record buffers; the size cap flushes
    /// inline, the age cap via [`Journal::flush_if_due`].
    pub fn append_op(&mut self, line: &str) -> Result<()> {
        if self.group_commit {
            self.buf.push_str(line);
            self.buf.push('\n');
            self.buffered += 1;
            self.ops += 1;
            if self.oldest_buffered.is_none() {
                self.oldest_buffered = Some(Instant::now());
            }
            if self.buffered >= GROUP_COMMIT_BATCH_CAP {
                self.flush_batch()?;
            }
            return Ok(());
        }
        self.write_flush(format!("{line}\n").as_bytes())?;
        self.ops += 1;
        Ok(())
    }

    /// Checkpoint + compact (two-phase, see the module docs): append the
    /// state snapshot to the live journal, then atomically rewrite the
    /// journal as `header + snapshot`. On success the replay suffix is
    /// empty and the journal's size is a function of live state only.
    pub fn checkpoint(
        &mut self,
        state: &StorageSim,
        costs: &[PerDocCosts],
        charge_rent: bool,
    ) -> Result<()> {
        // phase 0: a checkpoint is a forced barrier — anything still
        // buffered must reach the log before the snapshot that
        // supersedes it
        self.flush_batch().context("flushing buffered batch before checkpoint")?;
        let block = checkpoint_block(state);
        // phase 1: the snapshot reaches the durable log before anything
        // is thrown away (a kill here replays the old history instead)
        self.write_flush(block.as_bytes()).context("appending checkpoint block")?;
        // phase 2: compact via temp file + atomic rename
        let tmp = tmp_path(&self.path);
        {
            let mut f = File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(header_line(costs, charge_rent).as_bytes())?;
            f.write_all(block.as_bytes())?;
            f.flush()?;
            if self.sync_writes {
                f.sync_all()?;
            }
        }
        fs::rename(&tmp, &self.path).context("installing compacted journal")?;
        if self.sync_writes {
            // the rename is only durable once the parent directory's
            // entry update is on disk — without this, power loss can
            // resurrect the pre-compaction journal
            sync_dir(parent_dir(&self.path))?;
        }
        let file = OpenOptions::new().append(true).open(&self.path)?;
        self.writer = BufWriter::new(file);
        self.ops = 0;
        Ok(())
    }
}

impl Drop for Journal {
    /// A dropped handle (engine close, clean process exit) is a forced
    /// barrier: buffered ops must not evaporate just because the owner
    /// went away without an explicit flush. A real kill never runs this
    /// — that is exactly the bounded staleness window recovery heals.
    fn drop(&mut self) {
        let _ = self.flush_batch();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn costs() -> Vec<PerDocCosts> {
        vec![
            PerDocCosts { write: 1.0, read: 10.0, rent_window: 100.0 },
            PerDocCosts { write: 2.0, read: 20.0, rent_window: 200.0 },
        ]
    }

    fn seeded_state() -> StorageSim {
        let mut s = StorageSim::with_tiers(costs(), true);
        s.register_stream(
            3,
            vec![
                PerDocCosts { write: 1.5, read: 9.0, rent_window: 50.0 },
                PerDocCosts { write: 2.5, read: 19.0, rent_window: 150.0 },
            ],
        )
        .unwrap();
        s.set_attribution(Some(3));
        s.put(1, TierId::A, 0.0).unwrap();
        s.put(2, TierId::A, 0.1).unwrap();
        s.set_attribution(None);
        s.put(5, TierId::B, 0.2).unwrap();
        s.read(1).unwrap();
        s.migrate_doc(2, TierId::B, 0.5).unwrap();
        s.delete(5, 0.6).unwrap();
        s
    }

    #[test]
    fn checkpoint_block_roundtrips_the_full_state() {
        let state = seeded_state();
        let block = checkpoint_block(&state);
        let body: Vec<&str> = block
            .lines()
            .filter(|l| !l.starts_with("ckpt-begin") && *l != "ckpt-end")
            .collect();
        let restored = restore_checkpoint(&body, &costs(), true).unwrap();
        assert_eq!(restored.resident_count(), state.resident_count());
        assert_eq!(restored.locate(1), state.locate(1));
        assert_eq!(restored.locate(2), state.locate(2));
        assert_eq!(restored.owner_of(1), Some(3));
        assert_eq!(restored.ledger().total().to_bits(), state.ledger().total().to_bits());
        assert_eq!(
            restored.stream_ledger(3).total().to_bits(),
            state.stream_ledger(3).total().to_bits()
        );
        assert_eq!(
            restored.tier(TierId::A).peak_len(),
            state.tier(TierId::A).peak_len()
        );
        // rent clocks survive: settling both charges identical rent
        let mut a = state;
        let mut b = restored;
        a.settle_rent(1.0);
        b.settle_rent(1.0);
        assert_eq!(a.ledger().total().to_bits(), b.ledger().total().to_bits());
    }

    #[test]
    fn checkpoint_declared_count_is_validated() {
        let root = crate::util::scratch_dir("journal-count");
        fs::create_dir_all(&root).unwrap();
        let path = root.join("journal.log");
        let mut text = header_line(&costs(), false);
        text.push_str("ckpt-begin 2\ncpeak 0 1\nckpt-end\n");
        fs::write(&path, text).unwrap();
        assert!(replay(&path, &costs(), false).is_err());
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn group_commit_buffers_then_writes_one_framed_batch() {
        let root = crate::util::scratch_dir("journal-batch");
        fs::create_dir_all(&root).unwrap();
        let path = root.join("journal.log");
        let mut j = Journal::create(path.clone(), &costs(), false).unwrap();
        j.set_group_commit(true).unwrap();
        j.append_op(&format!("put 1 0 {} -", fmt_bits(0.0))).unwrap();
        j.append_op(&format!("put 2 0 {} -", fmt_bits(0.1))).unwrap();
        j.append_op("read 1").unwrap();
        // nothing durable yet: the file holds only the header
        assert_eq!(j.buffered(), 3);
        assert_eq!(j.ops(), 3);
        assert_eq!(fs::read_to_string(&path).unwrap(), header_line(&costs(), false));
        j.flush_batch().unwrap();
        assert_eq!(j.buffered(), 0);
        let text = fs::read_to_string(&path).unwrap();
        assert!(text.contains("batch 3\n"), "framed batch missing: {text}");
        drop(j);
        let replayed = replay(&path, &costs(), false).unwrap();
        assert_eq!(replayed.ops_replayed, 3);
        assert!(!replayed.truncated_tail);
        assert_eq!(replayed.state.resident_count(), 2);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn dropped_journal_flushes_its_pending_batch() {
        let root = crate::util::scratch_dir("journal-drop-flush");
        fs::create_dir_all(&root).unwrap();
        let path = root.join("journal.log");
        let mut j = Journal::create(path.clone(), &costs(), false).unwrap();
        j.set_group_commit(true).unwrap();
        j.append_op(&format!("put 9 1 {} -", fmt_bits(0.3))).unwrap();
        drop(j);
        let replayed = replay(&path, &costs(), false).unwrap();
        assert_eq!(replayed.ops_replayed, 1);
        assert_eq!(replayed.state.locate(9), Some(TierId::B));
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn torn_batch_is_dropped_whole_and_healed_at_the_frame() {
        let root = crate::util::scratch_dir("journal-torn-batch");
        fs::create_dir_all(&root).unwrap();
        let path = root.join("journal.log");
        let mut text = header_line(&costs(), false);
        // one durable unframed op, then a batch torn mid-body: its
        // complete first record must NOT apply
        text.push_str(&format!("put 1 0 {} -\n", fmt_bits(0.0)));
        text.push_str(&format!("batch 2\nput 2 0 {} -\nput 3 0 ", fmt_bits(0.1)));
        fs::write(&path, &text).unwrap();
        let replayed = replay(&path, &costs(), false).unwrap();
        assert!(replayed.truncated_tail);
        assert_eq!(replayed.ops_replayed, 1, "torn batch must be dropped whole");
        assert_eq!(replayed.state.resident_count(), 1);
        assert_eq!(replayed.state.locate(2), None);
        // healed cut lands before the frame line, on a batch boundary
        let healed = fs::read_to_string(&path).unwrap();
        assert!(!healed.contains("batch"), "frame must be cut away: {healed}");
        assert!(healed.ends_with(&format!("put 1 0 {} -\n", fmt_bits(0.0))));
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn reg_note_roundtrips_through_ops_and_checkpoints() {
        let mut state = StorageSim::with_tiers(costs(), false);
        let line = format!("reg 7 {} {}", fmt_costs(&costs()), fmt_note("tenant=acme hot=3"));
        replay_line(&mut state, &line).unwrap();
        assert_eq!(state.stream_note(7), Some("tenant=acme hot=3"));
        let block = checkpoint_block(&state);
        let body: Vec<&str> = block
            .lines()
            .filter(|l| !l.starts_with("ckpt-begin") && *l != "ckpt-end")
            .collect();
        let restored = restore_checkpoint(&body, &costs(), false).unwrap();
        assert_eq!(restored.stream_note(7), Some("tenant=acme hot=3"));
        assert_eq!(parse_note(&fmt_note("")).ok(), None);
    }
}
