//! The write-ahead journal shared by every durable [`StorageBackend`]
//! (ADR-003 laid it down for the filesystem backend; ADR-005 extracts it
//! here so the object-store backend's manifest log is the same machinery).
//!
//! ## Record grammar
//!
//! One line per record; window fractions, costs, and ledger dollars are
//! hexadecimal `f64::to_bits`, so replay is bit-exact:
//!
//! ```text
//! shptier-fs v1 rent=<0|1> costs=<w:r:rw,...>      # header
//! put <doc> <tier> <at-bits> <owner|->
//! del <doc> <at-bits>
//! read <doc>
//! mig <doc> <to> <at-bits>
//! migall <from> <to> <at-bits>
//! migstream <stream> <from> <to> <at-bits>         # one record per bulk batch
//! settle <at-bits>
//! reg <stream> <w:r:rw,...>
//! ckpt-begin <body-lines>                          # checkpoint block...
//! cdoc <doc> <tier> <at-bits> <owner|->            #   residency + rent clock
//! creg <stream> <w:r:rw,...>                       #   stream economics
//! cled <stream|-> <tier> <charges...>              #   ledger rows (run + per-stream)
//! cpeak <tier> <peak>                              #   occupancy high-water marks
//! ckpt-end                                         # ...complete only with this
//! ```
//!
//! ## Checkpoint / compaction (two-phase)
//!
//! [`Journal::checkpoint`] first *appends* a checkpoint block to the live
//! journal (a kill here leaves `header + ops + torn block`, and recovery
//! falls back to replaying the ops), then *compacts*: the journal is
//! rewritten as `header + block` into a temp file and atomically renamed
//! over the old one (a kill here leaves either file intact — never a
//! mix). After compaction the journal's length is a function of live
//! state only, never of operation count.
//!
//! ## Replay
//!
//! [`replay`] scans the journal once: the latest *complete* checkpoint
//! block resets the accounting state to its snapshot, op records apply on
//! top, a torn trailing line (or torn checkpoint block) is dropped, and a
//! torn *header* heals to a fresh journal. The file is healed in place so
//! subsequent appends land on a clean line.

use super::ledger::TierCharges;
use super::sim::StorageSim;
use super::tier::TierId;
use crate::cost::PerDocCosts;
use anyhow::{bail, Context, Result};
use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

pub(crate) const JOURNAL_MAGIC: &str = "shptier-fs";
pub(crate) const JOURNAL_VERSION: u32 = 1;

// ---- scalar encoding -------------------------------------------------------

pub(crate) fn fmt_bits(x: f64) -> String {
    format!("{:x}", x.to_bits())
}

pub(crate) fn parse_bits(s: &str) -> Result<f64> {
    u64::from_str_radix(s, 16)
        .map(f64::from_bits)
        .with_context(|| format!("bad f64 bits '{s}'"))
}

pub(crate) fn parse_u64(s: &str) -> Result<u64> {
    s.parse::<u64>().with_context(|| format!("bad integer '{s}'"))
}

pub(crate) fn fmt_costs(costs: &[PerDocCosts]) -> String {
    costs
        .iter()
        .map(|c| {
            format!(
                "{}:{}:{}",
                fmt_bits(c.write),
                fmt_bits(c.read),
                fmt_bits(c.rent_window)
            )
        })
        .collect::<Vec<_>>()
        .join(",")
}

pub(crate) fn parse_costs(s: &str) -> Result<Vec<PerDocCosts>> {
    s.split(',')
        .map(|entry| {
            let mut it = entry.split(':');
            let write = parse_bits(it.next().unwrap_or(""))?;
            let read = parse_bits(it.next().context("cost entry missing read")?)?;
            let rent_window = parse_bits(it.next().context("cost entry missing rent")?)?;
            if it.next().is_some() {
                bail!("cost entry '{entry}' has trailing fields");
            }
            Ok(PerDocCosts { write, read, rent_window })
        })
        .collect()
}

pub(crate) fn header_line(costs: &[PerDocCosts], charge_rent: bool) -> String {
    format!(
        "{JOURNAL_MAGIC} v{JOURNAL_VERSION} rent={} costs={}\n",
        u8::from(charge_rent),
        fmt_costs(costs)
    )
}

fn fmt_owner(owner: Option<u64>) -> String {
    match owner {
        Some(s) => s.to_string(),
        None => "-".into(),
    }
}

fn parse_owner(s: &str) -> Result<Option<u64>> {
    match s {
        "-" => Ok(None),
        other => Ok(Some(parse_u64(other)?)),
    }
}

fn fmt_charges(c: &TierCharges) -> String {
    format!(
        "{} {} {} {} {} {} {} {} {}",
        c.writes,
        fmt_bits(c.write_cost),
        c.reads,
        fmt_bits(c.read_cost),
        c.deletes,
        fmt_bits(c.rent_doc_windows),
        fmt_bits(c.rent_cost),
        c.migration_ops,
        fmt_bits(c.migration_cost)
    )
}

// ---- op replay -------------------------------------------------------------

/// Apply one journal op record to the accounting state. Op records are
/// only written for operations that already succeeded, so replay against
/// an uncapacitated fresh state must succeed too.
pub(crate) fn replay_line(state: &mut StorageSim, line: &str) -> Result<()> {
    let mut parts = line.split(' ');
    let op = parts.next().unwrap_or("");
    let mut next = |what: &str| -> Result<&str> {
        parts.next().with_context(|| format!("'{op}' record missing {what}"))
    };
    match op {
        "put" => {
            let doc = parse_u64(next("doc")?)?;
            let tier = parse_u64(next("tier")?)? as usize;
            let at = parse_bits(next("at")?)?;
            let owner = parse_owner(next("owner")?)?;
            state.set_attribution(owner);
            state.put(doc, TierId(tier), at)?;
        }
        "del" => {
            let doc = parse_u64(next("doc")?)?;
            let at = parse_bits(next("at")?)?;
            state.delete(doc, at)?;
        }
        "read" => {
            let doc = parse_u64(next("doc")?)?;
            state.read(doc)?;
        }
        "mig" => {
            let doc = parse_u64(next("doc")?)?;
            let to = parse_u64(next("to")?)? as usize;
            let at = parse_bits(next("at")?)?;
            state.migrate_doc(doc, TierId(to), at)?;
        }
        "migall" => {
            let from = parse_u64(next("from")?)? as usize;
            let to = parse_u64(next("to")?)? as usize;
            let at = parse_bits(next("at")?)?;
            state.migrate_all(TierId(from), TierId(to), at)?;
        }
        "migstream" => {
            let stream = parse_u64(next("stream")?)?;
            let from = parse_u64(next("from")?)? as usize;
            let to = parse_u64(next("to")?)? as usize;
            let at = parse_bits(next("at")?)?;
            state.migrate_stream(stream, TierId(from), TierId(to), at)?;
        }
        "settle" => {
            let at = parse_bits(next("at")?)?;
            state.settle_rent(at);
        }
        "reg" => {
            let stream = parse_u64(next("stream")?)?;
            let costs = parse_costs(next("costs")?)?;
            state.register_stream(stream, costs)?;
        }
        other => bail!("unknown journal op '{other}'"),
    }
    Ok(())
}

// ---- checkpoint encoding ---------------------------------------------------

/// Serialize the full accounting state as a checkpoint block (every line
/// `\n`-terminated, `ckpt-begin`/`ckpt-end` included). Deterministic:
/// docs, streams, and ledger rows come out sorted.
pub(crate) fn checkpoint_block(state: &StorageSim) -> String {
    let mut body: Vec<String> = Vec::new();
    for t in 0..state.num_tiers() {
        let tier = state.tier(TierId(t));
        for doc in tier.docs() {
            let r = tier.get(doc).expect("doc listed by its tier");
            body.push(format!(
                "cdoc {doc} {t} {} {}",
                fmt_bits(r.written_at),
                fmt_owner(r.owner)
            ));
        }
    }
    for (stream, costs) in state.registered_streams() {
        body.push(format!("creg {stream} {}", fmt_costs(costs)));
    }
    for (tier, charges) in state.ledger().tiers() {
        body.push(format!("cled - {} {}", tier.0, fmt_charges(charges)));
    }
    for (stream, ledger) in state.stream_ledgers() {
        for (tier, charges) in ledger.tiers() {
            body.push(format!("cled {stream} {} {}", tier.0, fmt_charges(charges)));
        }
    }
    for t in 0..state.num_tiers() {
        let peak = state.tier(TierId(t)).peak_len();
        if peak > 0 {
            body.push(format!("cpeak {t} {peak}"));
        }
    }
    let mut out = format!("ckpt-begin {}\n", body.len());
    for line in &body {
        out.push_str(line);
        out.push('\n');
    }
    out.push_str("ckpt-end\n");
    out
}

/// Rebuild the accounting state from a complete checkpoint block body.
fn restore_checkpoint(
    body: &[&str],
    costs: &[PerDocCosts],
    charge_rent: bool,
) -> Result<StorageSim> {
    let mut state = StorageSim::with_tiers(costs.to_vec(), charge_rent);
    for line in body {
        let mut parts = line.split(' ');
        let op = parts.next().unwrap_or("");
        let mut next = |what: &str| -> Result<&str> {
            parts
                .next()
                .with_context(|| format!("'{op}' checkpoint record missing {what}"))
        };
        match op {
            "cdoc" => {
                let doc = parse_u64(next("doc")?)?;
                let tier = parse_u64(next("tier")?)? as usize;
                let at = parse_bits(next("at")?)?;
                let owner = parse_owner(next("owner")?)?;
                state.restore_resident(doc, TierId(tier), at, owner)?;
            }
            "creg" => {
                let stream = parse_u64(next("stream")?)?;
                let costs = parse_costs(next("costs")?)?;
                state.register_stream(stream, costs)?;
            }
            "cled" => {
                let stream = parse_owner(next("stream")?)?;
                let tier = parse_u64(next("tier")?)? as usize;
                let charges = TierCharges {
                    writes: parse_u64(next("writes")?)?,
                    write_cost: parse_bits(next("write_cost")?)?,
                    reads: parse_u64(next("reads")?)?,
                    read_cost: parse_bits(next("read_cost")?)?,
                    deletes: parse_u64(next("deletes")?)?,
                    rent_doc_windows: parse_bits(next("rent_doc_windows")?)?,
                    rent_cost: parse_bits(next("rent_cost")?)?,
                    migration_ops: parse_u64(next("migration_ops")?)?,
                    migration_cost: parse_bits(next("migration_cost")?)?,
                };
                state.restore_tier_charges(stream, TierId(tier), charges);
            }
            "cpeak" => {
                let tier = parse_u64(next("tier")?)? as usize;
                let peak = parse_u64(next("peak")?)? as usize;
                state.restore_peak(TierId(tier), peak);
            }
            other => bail!("unknown checkpoint record '{other}'"),
        }
    }
    state.set_attribution(None);
    Ok(state)
}

// ---- replay ----------------------------------------------------------------

/// What a journal scan rebuilt and healed.
pub(crate) struct Replay {
    /// The rebuilt accounting state.
    pub state: StorageSim,
    /// Op records applied *on top of the latest complete checkpoint* —
    /// the replay suffix. Ops a loaded checkpoint folded away are not
    /// counted: their effect arrived via the snapshot, not replay.
    pub ops_replayed: u64,
    /// Complete checkpoint blocks loaded (the last one wins).
    pub checkpoints_loaded: u64,
    /// Whether a torn trailing line / torn checkpoint block was dropped,
    /// or a torn header healed.
    pub truncated_tail: bool,
}

/// Scan `path`, rebuild the accounting state (latest complete checkpoint
/// + op suffix), and heal the file in place: drop a torn tail or torn
/// checkpoint block, rewrite a torn header, remove a stale compaction
/// temp file. The declared `costs`/`charge_rent` must match the header.
pub(crate) fn replay(path: &Path, costs: &[PerDocCosts], charge_rent: bool) -> Result<Replay> {
    let _ = fs::remove_file(tmp_path(path)); // stale compaction attempt
    let text = fs::read_to_string(path)
        .with_context(|| format!("reading journal {}", path.display()))?;
    let mut state = StorageSim::with_tiers(costs.to_vec(), charge_rent);
    let mut ops_replayed = 0u64;
    let mut checkpoints_loaded = 0u64;
    let mut truncated_tail = false;
    let mut saw_header = false;
    let mut valid_len = 0usize;

    let segs: Vec<&str> = text.split_inclusive('\n').collect();
    let mut i = 0usize;
    while i < segs.len() {
        let seg = segs[i];
        if !seg.ends_with('\n') {
            // torn trailing write: the record never durably happened
            truncated_tail = true;
            break;
        }
        let line = &seg[..seg.len() - 1];
        if !saw_header {
            let expected = header_line(costs, charge_rent);
            if seg != expected {
                bail!(
                    "journal {} header mismatch: backend opened with different \
                     economics (journal '{}', expected '{}')",
                    path.display(),
                    line,
                    expected.trim_end()
                );
            }
            saw_header = true;
            valid_len += seg.len();
            i += 1;
            continue;
        }
        if line.is_empty() {
            valid_len += seg.len();
            i += 1;
            continue;
        }
        if let Some(rest) = line.strip_prefix("ckpt-begin ") {
            let declared = parse_u64(rest.trim())
                .with_context(|| format!("journal line {}", i + 1))?
                as usize;
            // collect the block: complete only if `ckpt-end` arrives on a
            // complete line
            let mut body: Vec<&str> = Vec::new();
            let mut block_len = seg.len();
            let mut j = i + 1;
            let mut complete = false;
            while j < segs.len() {
                let s = segs[j];
                if !s.ends_with('\n') {
                    break;
                }
                let l = &s[..s.len() - 1];
                block_len += s.len();
                j += 1;
                if l == "ckpt-end" {
                    complete = true;
                    break;
                }
                body.push(l);
            }
            if !complete {
                // torn checkpoint: the snapshot never durably finished —
                // keep the state replayed so far and drop the block
                truncated_tail = true;
                break;
            }
            if body.len() != declared {
                bail!(
                    "journal {} checkpoint at line {} declares {} records but \
                     carries {}",
                    path.display(),
                    i + 1,
                    declared,
                    body.len()
                );
            }
            state = restore_checkpoint(&body, costs, charge_rent)
                .with_context(|| format!("journal checkpoint at line {}", i + 1))?;
            checkpoints_loaded += 1;
            // the snapshot superseded everything replayed so far: the
            // replay suffix (and the report's op count) restarts here
            ops_replayed = 0;
            valid_len += block_len;
            i = j;
            continue;
        }
        replay_line(&mut state, line)
            .with_context(|| format!("journal line {}", i + 1))?;
        ops_replayed += 1;
        valid_len += seg.len();
        i += 1;
    }
    if !saw_header {
        // No complete header means no operation was ever durably recorded
        // (records only follow a header): the process died while the
        // journal was being created. Heal with a fresh header instead of
        // bricking the root.
        truncated_tail = true;
    }
    state.set_attribution(None);

    // Heal in place so appends land on a clean line.
    if !saw_header {
        fs::write(path, header_line(costs, charge_rent))
            .context("rewriting torn journal header")?;
    } else if truncated_tail {
        let file = OpenOptions::new().write(true).open(path)?;
        file.set_len(valid_len as u64)
            .context("truncating torn journal tail")?;
    }
    Ok(Replay { state, ops_replayed, checkpoints_loaded, truncated_tail })
}

fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().unwrap_or_default().to_os_string();
    name.push(".tmp");
    path.with_file_name(name)
}

// ---- the append handle -----------------------------------------------------

/// Append handle over a journal file: every record is flushed (and
/// optionally fsynced) before the caller touches any substrate, and the
/// op counter tracks the replay suffix on top of the latest checkpoint.
pub(crate) struct Journal {
    path: PathBuf,
    writer: BufWriter<File>,
    sync_writes: bool,
    ops: u64,
}

impl Journal {
    /// Create a fresh journal holding only the header.
    pub fn create(path: PathBuf, costs: &[PerDocCosts], charge_rent: bool) -> Result<Self> {
        let mut file = File::create(&path)
            .with_context(|| format!("creating journal {}", path.display()))?;
        file.write_all(header_line(costs, charge_rent).as_bytes())
            .context("writing journal header")?;
        Ok(Self { path, writer: BufWriter::new(file), sync_writes: false, ops: 0 })
    }

    /// Reopen an existing (already healed) journal for appends.
    /// `suffix_ops` is the op count [`replay`] found past the latest
    /// checkpoint.
    pub fn open_append(path: PathBuf, suffix_ops: u64) -> Result<Self> {
        let file = OpenOptions::new()
            .append(true)
            .open(&path)
            .with_context(|| format!("reopening journal {}", path.display()))?;
        Ok(Self { path, writer: BufWriter::new(file), sync_writes: false, ops: suffix_ops })
    }

    /// `fsync` on every append (power-loss durability, not just process
    /// death).
    pub fn set_sync(&mut self, sync: bool) {
        self.sync_writes = sync;
    }

    /// Op records currently in the replay suffix (0 right after a
    /// checkpoint or on a fresh journal).
    pub fn ops(&self) -> u64 {
        self.ops
    }

    fn write_flush(&mut self, bytes: &[u8]) -> Result<()> {
        self.writer.write_all(bytes)?;
        self.writer.flush()?;
        if self.sync_writes {
            self.writer.get_ref().sync_data()?;
        }
        Ok(())
    }

    /// Append one op record (no trailing newline in `line`).
    pub fn append_op(&mut self, line: &str) -> Result<()> {
        self.write_flush(format!("{line}\n").as_bytes())?;
        self.ops += 1;
        Ok(())
    }

    /// Checkpoint + compact (two-phase, see the module docs): append the
    /// state snapshot to the live journal, then atomically rewrite the
    /// journal as `header + snapshot`. On success the replay suffix is
    /// empty and the journal's size is a function of live state only.
    pub fn checkpoint(
        &mut self,
        state: &StorageSim,
        costs: &[PerDocCosts],
        charge_rent: bool,
    ) -> Result<()> {
        let block = checkpoint_block(state);
        // phase 1: the snapshot reaches the durable log before anything
        // is thrown away (a kill here replays the old history instead)
        self.write_flush(block.as_bytes()).context("appending checkpoint block")?;
        // phase 2: compact via temp file + atomic rename
        let tmp = tmp_path(&self.path);
        {
            let mut f = File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(header_line(costs, charge_rent).as_bytes())?;
            f.write_all(block.as_bytes())?;
            f.flush()?;
            if self.sync_writes {
                f.sync_data()?;
            }
        }
        fs::rename(&tmp, &self.path).context("installing compacted journal")?;
        let file = OpenOptions::new().append(true).open(&self.path)?;
        self.writer = BufWriter::new(file);
        self.ops = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn costs() -> Vec<PerDocCosts> {
        vec![
            PerDocCosts { write: 1.0, read: 10.0, rent_window: 100.0 },
            PerDocCosts { write: 2.0, read: 20.0, rent_window: 200.0 },
        ]
    }

    fn seeded_state() -> StorageSim {
        let mut s = StorageSim::with_tiers(costs(), true);
        s.register_stream(
            3,
            vec![
                PerDocCosts { write: 1.5, read: 9.0, rent_window: 50.0 },
                PerDocCosts { write: 2.5, read: 19.0, rent_window: 150.0 },
            ],
        )
        .unwrap();
        s.set_attribution(Some(3));
        s.put(1, TierId::A, 0.0).unwrap();
        s.put(2, TierId::A, 0.1).unwrap();
        s.set_attribution(None);
        s.put(5, TierId::B, 0.2).unwrap();
        s.read(1).unwrap();
        s.migrate_doc(2, TierId::B, 0.5).unwrap();
        s.delete(5, 0.6).unwrap();
        s
    }

    #[test]
    fn checkpoint_block_roundtrips_the_full_state() {
        let state = seeded_state();
        let block = checkpoint_block(&state);
        let body: Vec<&str> = block
            .lines()
            .filter(|l| !l.starts_with("ckpt-begin") && *l != "ckpt-end")
            .collect();
        let restored = restore_checkpoint(&body, &costs(), true).unwrap();
        assert_eq!(restored.resident_count(), state.resident_count());
        assert_eq!(restored.locate(1), state.locate(1));
        assert_eq!(restored.locate(2), state.locate(2));
        assert_eq!(restored.owner_of(1), Some(3));
        assert_eq!(restored.ledger().total().to_bits(), state.ledger().total().to_bits());
        assert_eq!(
            restored.stream_ledger(3).total().to_bits(),
            state.stream_ledger(3).total().to_bits()
        );
        assert_eq!(
            restored.tier(TierId::A).peak_len(),
            state.tier(TierId::A).peak_len()
        );
        // rent clocks survive: settling both charges identical rent
        let mut a = state;
        let mut b = restored;
        a.settle_rent(1.0);
        b.settle_rent(1.0);
        assert_eq!(a.ledger().total().to_bits(), b.ledger().total().to_bits());
    }

    #[test]
    fn checkpoint_declared_count_is_validated() {
        let root = crate::util::scratch_dir("journal-count");
        fs::create_dir_all(&root).unwrap();
        let path = root.join("journal.log");
        let mut text = header_line(&costs(), false);
        text.push_str("ckpt-begin 2\ncpeak 0 1\nckpt-end\n");
        fs::write(&path, text).unwrap();
        assert!(replay(&path, &costs(), false).is_err());
        let _ = fs::remove_dir_all(&root);
    }
}
