//! Simulated tiered object storage with exact cost accounting — the
//! substrate for trace-driven validation of the analytic model (paper §VIII)
//! and for the streaming pipeline's placement decisions.

pub mod backend;
pub mod ledger;
pub mod sim;
pub mod tier;

pub use backend::StorageBackend;
pub use ledger::{Ledger, TierCharges};
pub use sim::StorageSim;
pub use tier::{Resident, TierId, TierState};
