//! Tiered object storage with exact cost accounting — the substrate for
//! trace-driven validation of the analytic model (paper §VIII) and for the
//! streaming pipeline's placement decisions. Two [`StorageBackend`]
//! implementations share one accounting contract: the in-memory
//! [`StorageSim`] (reference) and the real-filesystem [`FsBackend`]
//! (documents as files, write-ahead journal, crash recovery — ADR-003).

pub mod backend;
pub mod fs;
pub mod ledger;
pub mod sim;
pub mod tier;

pub use backend::StorageBackend;
pub use fs::{FsBackend, RecoveryReport};
pub use ledger::{Ledger, TierCharges};
pub use sim::StorageSim;
pub use tier::{Resident, TierId, TierState};
