//! Tiered object storage with exact cost accounting — the substrate for
//! trace-driven validation of the analytic model (paper §VIII) and for the
//! streaming pipeline's placement decisions. Three [`StorageBackend`]
//! implementations share one accounting contract: the in-memory
//! [`StorageSim`] (reference), the real-filesystem [`FsBackend`]
//! (documents as files — ADR-003), and the S3-style [`ObjectBackend`]
//! (bucket per tier, flat object keys, request-counted GET/PUT/DELETE/COPY
//! — ADR-005). The durable pair is one journaled state machine
//! ([`DurableBackend`]) over two [`DocStore`] substrates: write-ahead
//! journaling, checkpoint/compaction, and kill-and-restart recovery are
//! shared verbatim.

pub mod backend;
pub mod durable;
pub mod fs;
mod journal;
pub mod ledger;
pub mod object;
pub mod sim;
pub mod tier;

pub use backend::{CheckpointReport, StorageBackend};
pub use durable::{DocStore, DurableBackend, RecoveryReport};
pub use fs::{FsBackend, FsStore};
pub use ledger::{Ledger, TierCharges};
pub use object::{ObjectBackend, ObjectStore, RequestCounts};
pub use sim::StorageSim;
pub use tier::{Resident, TierId, TierState};
