//! Tier identity and per-tier simulated state.

use crate::cost::PerDocCosts;
use std::collections::HashMap;

/// Identifier of a storage tier. The paper's two-tier setup uses
/// [`TierId::A`] and [`TierId::B`]; the simulator supports more for the
/// multi-tier extension experiments.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TierId(pub usize);

impl TierId {
    pub const A: TierId = TierId(0);
    pub const B: TierId = TierId(1);

    pub fn label(&self) -> String {
        match self.0 {
            0 => "A".into(),
            1 => "B".into(),
            n => format!("T{n}"),
        }
    }
}

/// A resident object: when it was written, as a fraction of the stream
/// window (stream position i/N ↦ wall-clock fraction), and — under
/// multi-stream (fleet) runs — which stream owns it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Resident {
    /// Document stream index.
    pub doc: u64,
    /// Window fraction at write time, in [0, 1].
    pub written_at: f64,
    /// Owning stream id for ledger attribution (None in single-stream runs).
    pub owner: Option<u64>,
}

/// Simulated state of one tier: its effective per-document costs, an
/// optional capacity limit (resident-object count), and the set of
/// resident objects.
#[derive(Debug, Clone)]
pub struct TierState {
    pub id: TierId,
    pub costs: PerDocCosts,
    residents: HashMap<u64, Resident>,
    /// Maximum simultaneous residents (None = unbounded, the paper's model).
    capacity: Option<usize>,
    /// High-water mark of simultaneous residents over the run.
    peak_len: usize,
}

impl TierState {
    pub fn new(id: TierId, costs: PerDocCosts) -> Self {
        Self { id, costs, residents: HashMap::new(), capacity: None, peak_len: 0 }
    }

    pub fn len(&self) -> usize {
        self.residents.len()
    }

    pub fn is_empty(&self) -> bool {
        self.residents.is_empty()
    }

    /// Capacity limit in resident objects (None = unbounded).
    pub fn capacity(&self) -> Option<usize> {
        self.capacity
    }

    pub fn set_capacity(&mut self, capacity: Option<usize>) {
        self.capacity = capacity;
    }

    /// Whether an additional resident would exceed the capacity limit.
    pub fn is_full(&self) -> bool {
        matches!(self.capacity, Some(c) if self.residents.len() >= c)
    }

    /// Free resident slots (None = unbounded).
    pub fn remaining(&self) -> Option<usize> {
        self.capacity.map(|c| c.saturating_sub(self.residents.len()))
    }

    /// High-water mark of simultaneous residents.
    pub fn peak_len(&self) -> usize {
        self.peak_len
    }

    /// Raise the high-water mark to at least `peak` (journal-checkpoint
    /// restore: compaction erases the history the mark came from).
    pub fn note_peak(&mut self, peak: usize) {
        self.peak_len = self.peak_len.max(peak);
    }

    pub fn contains(&self, doc: u64) -> bool {
        self.residents.contains_key(&doc)
    }

    pub fn insert(&mut self, doc: u64, written_at: f64) -> Option<Resident> {
        self.insert_owned(doc, written_at, None)
    }

    /// Insert with stream attribution (fleet runs).
    pub fn insert_owned(
        &mut self,
        doc: u64,
        written_at: f64,
        owner: Option<u64>,
    ) -> Option<Resident> {
        let prev = self.residents.insert(doc, Resident { doc, written_at, owner });
        self.peak_len = self.peak_len.max(self.residents.len());
        prev
    }

    pub fn remove(&mut self, doc: u64) -> Option<Resident> {
        self.residents.remove(&doc)
    }

    pub fn get(&self, doc: u64) -> Option<&Resident> {
        self.residents.get(&doc)
    }

    /// Drain all residents (used by bulk migration).
    pub fn drain(&mut self) -> Vec<Resident> {
        let mut v: Vec<Resident> = self.residents.drain().map(|(_, r)| r).collect();
        v.sort_by_key(|r| r.doc);
        v
    }

    /// Snapshot of resident doc ids (sorted, deterministic).
    pub fn docs(&self) -> Vec<u64> {
        let mut v: Vec<u64> = self.residents.keys().copied().collect();
        v.sort_unstable();
        v
    }

    /// The longest-resident document (earliest `written_at`, ties broken by
    /// lowest doc id for determinism). Used by reactive demotion under
    /// capacity pressure.
    pub fn oldest(&self) -> Option<u64> {
        self.residents
            .values()
            .min_by(|a, b| {
                a.written_at
                    .partial_cmp(&b.written_at)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.doc.cmp(&b.doc))
            })
            .map(|r| r.doc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn costs() -> PerDocCosts {
        PerDocCosts { write: 1.0, read: 2.0, rent_window: 3.0 }
    }

    #[test]
    fn insert_remove_roundtrip() {
        let mut t = TierState::new(TierId::A, costs());
        assert!(t.insert(7, 0.25).is_none());
        assert!(t.contains(7));
        assert_eq!(t.len(), 1);
        let r = t.remove(7).unwrap();
        assert_eq!(r.doc, 7);
        assert!((r.written_at - 0.25).abs() < 1e-15);
        assert!(t.is_empty());
    }

    #[test]
    fn drain_is_sorted_and_empties() {
        let mut t = TierState::new(TierId::B, costs());
        for d in [5u64, 1, 9] {
            t.insert(d, 0.0);
        }
        let drained = t.drain();
        assert_eq!(drained.iter().map(|r| r.doc).collect::<Vec<_>>(), vec![1, 5, 9]);
        assert!(t.is_empty());
    }

    #[test]
    fn labels() {
        assert_eq!(TierId::A.label(), "A");
        assert_eq!(TierId::B.label(), "B");
        assert_eq!(TierId(4).label(), "T4");
    }

    #[test]
    fn capacity_and_fullness() {
        let mut t = TierState::new(TierId::A, costs());
        assert!(!t.is_full());
        assert_eq!(t.remaining(), None);
        t.set_capacity(Some(2));
        assert_eq!(t.remaining(), Some(2));
        t.insert(1, 0.0);
        assert!(!t.is_full());
        t.insert(2, 0.1);
        assert!(t.is_full());
        assert_eq!(t.remaining(), Some(0));
        t.remove(1);
        assert!(!t.is_full());
    }

    #[test]
    fn peak_len_tracks_high_water_mark() {
        let mut t = TierState::new(TierId::A, costs());
        for d in 0..5 {
            t.insert(d, 0.0);
        }
        for d in 0..4 {
            t.remove(d);
        }
        assert_eq!(t.len(), 1);
        assert_eq!(t.peak_len(), 5);
    }

    #[test]
    fn oldest_is_earliest_then_lowest_id() {
        let mut t = TierState::new(TierId::A, costs());
        t.insert(3, 0.5);
        t.insert(7, 0.1);
        t.insert(9, 0.1);
        assert_eq!(t.oldest(), Some(7));
        t.remove(7);
        assert_eq!(t.oldest(), Some(9));
    }

    #[test]
    fn ownership_preserved() {
        let mut t = TierState::new(TierId::A, costs());
        t.insert_owned(1, 0.0, Some(4));
        assert_eq!(t.get(1).unwrap().owner, Some(4));
        assert_eq!(t.get(1).unwrap().doc, 1);
    }
}
