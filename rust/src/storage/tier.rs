//! Tier identity and per-tier simulated state.

use crate::cost::PerDocCosts;
use std::collections::HashMap;

/// Identifier of a storage tier. The paper's two-tier setup uses
/// [`TierId::A`] and [`TierId::B`]; the simulator supports more for the
/// multi-tier extension experiments.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TierId(pub usize);

impl TierId {
    pub const A: TierId = TierId(0);
    pub const B: TierId = TierId(1);

    pub fn label(&self) -> String {
        match self.0 {
            0 => "A".into(),
            1 => "B".into(),
            n => format!("T{n}"),
        }
    }
}

/// A resident object: when it was written, as a fraction of the stream
/// window (stream position i/N ↦ wall-clock fraction).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Resident {
    /// Document stream index.
    pub doc: u64,
    /// Window fraction at write time, in [0, 1].
    pub written_at: f64,
}

/// Simulated state of one tier: its effective per-document costs and the
/// set of resident objects.
#[derive(Debug, Clone)]
pub struct TierState {
    pub id: TierId,
    pub costs: PerDocCosts,
    residents: HashMap<u64, Resident>,
}

impl TierState {
    pub fn new(id: TierId, costs: PerDocCosts) -> Self {
        Self { id, costs, residents: HashMap::new() }
    }

    pub fn len(&self) -> usize {
        self.residents.len()
    }

    pub fn is_empty(&self) -> bool {
        self.residents.is_empty()
    }

    pub fn contains(&self, doc: u64) -> bool {
        self.residents.contains_key(&doc)
    }

    pub fn insert(&mut self, doc: u64, written_at: f64) -> Option<Resident> {
        self.residents.insert(doc, Resident { doc, written_at })
    }

    pub fn remove(&mut self, doc: u64) -> Option<Resident> {
        self.residents.remove(&doc)
    }

    pub fn get(&self, doc: u64) -> Option<&Resident> {
        self.residents.get(&doc)
    }

    /// Drain all residents (used by bulk migration).
    pub fn drain(&mut self) -> Vec<Resident> {
        let mut v: Vec<Resident> = self.residents.drain().map(|(_, r)| r).collect();
        v.sort_by_key(|r| r.doc);
        v
    }

    /// Snapshot of resident doc ids (sorted, deterministic).
    pub fn docs(&self) -> Vec<u64> {
        let mut v: Vec<u64> = self.residents.keys().copied().collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn costs() -> PerDocCosts {
        PerDocCosts { write: 1.0, read: 2.0, rent_window: 3.0 }
    }

    #[test]
    fn insert_remove_roundtrip() {
        let mut t = TierState::new(TierId::A, costs());
        assert!(t.insert(7, 0.25).is_none());
        assert!(t.contains(7));
        assert_eq!(t.len(), 1);
        let r = t.remove(7).unwrap();
        assert_eq!(r.doc, 7);
        assert!((r.written_at - 0.25).abs() < 1e-15);
        assert!(t.is_empty());
    }

    #[test]
    fn drain_is_sorted_and_empties() {
        let mut t = TierState::new(TierId::B, costs());
        for d in [5u64, 1, 9] {
            t.insert(d, 0.0);
        }
        let drained = t.drain();
        assert_eq!(drained.iter().map(|r| r.doc).collect::<Vec<_>>(), vec![1, 5, 9]);
        assert!(t.is_empty());
    }

    #[test]
    fn labels() {
        assert_eq!(TierId::A.label(), "A");
        assert_eq!(TierId::B.label(), "B");
        assert_eq!(TierId(4).label(), "T4");
    }
}
