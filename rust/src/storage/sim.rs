//! The two-(or more-)tier storage simulator.
//!
//! `StorageSim` executes put/read/delete/migrate operations against
//! [`TierState`]s, charging every operation and every doc-window of rent to
//! the [`Ledger`]. Stream position is mapped linearly onto the stream
//! window: document `i` of `N` happens at window fraction `i/N`.

use super::ledger::Ledger;
use super::tier::{TierId, TierState};
use crate::cost::PerDocCosts;
use anyhow::{bail, Result};

#[derive(Debug, Clone)]
pub struct StorageSim {
    tiers: Vec<TierState>,
    ledger: Ledger,
    /// Whether rent is charged (mirrors `CostModel::include_rent`).
    charge_rent: bool,
}

impl StorageSim {
    /// Standard two-tier setup from effective per-doc costs.
    pub fn two_tier(a: PerDocCosts, b: PerDocCosts, charge_rent: bool) -> Self {
        Self {
            tiers: vec![TierState::new(TierId::A, a), TierState::new(TierId::B, b)],
            ledger: Ledger::new(),
            charge_rent,
        }
    }

    /// Arbitrary tier list (multi-tier extension).
    pub fn with_tiers(costs: Vec<PerDocCosts>, charge_rent: bool) -> Self {
        Self {
            tiers: costs
                .into_iter()
                .enumerate()
                .map(|(i, c)| TierState::new(TierId(i), c))
                .collect(),
            ledger: Ledger::new(),
            charge_rent,
        }
    }

    pub fn num_tiers(&self) -> usize {
        self.tiers.len()
    }

    pub fn tier(&self, t: TierId) -> &TierState {
        &self.tiers[t.0]
    }

    fn tier_mut(&mut self, t: TierId) -> &mut TierState {
        &mut self.tiers[t.0]
    }

    pub fn ledger(&self) -> &Ledger {
        &self.ledger
    }

    /// Locate a document (linear in tier count — tiers are few).
    pub fn locate(&self, doc: u64) -> Option<TierId> {
        self.tiers.iter().find(|t| t.contains(doc)).map(|t| t.id)
    }

    /// Write a document into `tier` at window fraction `at`.
    pub fn put(&mut self, doc: u64, tier: TierId, at: f64) -> Result<()> {
        if tier.0 >= self.tiers.len() {
            bail!("unknown tier {tier:?}");
        }
        if let Some(existing) = self.locate(doc) {
            bail!("doc {doc} already resident in tier {existing:?}");
        }
        let cost = self.tiers[tier.0].costs.write;
        self.tier_mut(tier).insert(doc, at);
        self.ledger.charge_write(tier, cost);
        Ok(())
    }

    /// Delete (prune) a document at window fraction `at`, settling its rent.
    pub fn delete(&mut self, doc: u64, at: f64) -> Result<TierId> {
        let tier = match self.locate(doc) {
            Some(t) => t,
            None => bail!("delete: doc {doc} not resident"),
        };
        let resident = self.tier_mut(tier).remove(doc).unwrap();
        if self.charge_rent {
            let frac = (at - resident.written_at).max(0.0);
            let rent_window = self.tiers[tier.0].costs.rent_window;
            self.ledger.charge_rent(tier, frac, rent_window);
        }
        self.ledger.charge_delete(tier);
        Ok(tier)
    }

    /// Consumer read of a resident document (does not remove it).
    pub fn read(&mut self, doc: u64) -> Result<TierId> {
        let tier = match self.locate(doc) {
            Some(t) => t,
            None => bail!("read: doc {doc} not resident"),
        };
        let cost = self.tiers[tier.0].costs.read;
        self.ledger.charge_read(tier, cost);
        Ok(tier)
    }

    /// Move one document `from → to` at window fraction `at`: settles rent
    /// on the source, charges a source read + destination write, tags both
    /// as migration ops.
    pub fn migrate_doc(&mut self, doc: u64, to: TierId, at: f64) -> Result<()> {
        let from = match self.locate(doc) {
            Some(t) => t,
            None => bail!("migrate: doc {doc} not resident"),
        };
        if from == to {
            return Ok(());
        }
        let resident = self.tier_mut(from).remove(doc).unwrap();
        if self.charge_rent {
            let frac = (at - resident.written_at).max(0.0);
            let rent_window = self.tiers[from.0].costs.rent_window;
            self.ledger.charge_rent(from, frac, rent_window);
        }
        let read_cost = self.tiers[from.0].costs.read;
        self.ledger.charge_read(from, read_cost);
        self.ledger.tag_migration(from, read_cost);
        let write_cost = self.tiers[to.0].costs.write;
        self.tier_mut(to).insert(doc, at);
        self.ledger.charge_write(to, write_cost);
        self.ledger.tag_migration(to, write_cost);
        Ok(())
    }

    /// Bulk-migrate every resident of `from` into `to` (paper Fig. 3,
    /// DO_MIGRATE branch at `i == r`).
    pub fn migrate_all(&mut self, from: TierId, to: TierId, at: f64) -> Result<u64> {
        let docs = self.tier(from).docs();
        let n = docs.len() as u64;
        for doc in docs {
            self.migrate_doc(doc, to, at)?;
        }
        Ok(n)
    }

    /// End of stream: settle rent for everything still resident (they
    /// occupied their tier until window fraction 1.0).
    pub fn settle_rent(&mut self, at: f64) {
        if !self.charge_rent {
            return;
        }
        for t in 0..self.tiers.len() {
            let tier = TierId(t);
            let rent_window = self.tiers[t].costs.rent_window;
            for doc in self.tiers[t].docs() {
                let resident = *self.tiers[t].get(doc).unwrap();
                let frac = (at - resident.written_at).max(0.0);
                self.ledger.charge_rent(tier, frac, rent_window);
                // reset the clock so double-settling is impossible
                self.tier_mut(tier).remove(doc);
                self.tier_mut(tier).insert(doc, at);
            }
        }
    }

    /// Total resident documents across tiers.
    pub fn resident_count(&self) -> usize {
        self.tiers.iter().map(|t| t.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> StorageSim {
        StorageSim::two_tier(
            PerDocCosts { write: 1.0, read: 10.0, rent_window: 100.0 },
            PerDocCosts { write: 2.0, read: 20.0, rent_window: 200.0 },
            true,
        )
    }

    #[test]
    fn put_read_delete_charges() {
        let mut s = sim();
        s.put(1, TierId::A, 0.0).unwrap();
        s.read(1).unwrap();
        s.delete(1, 0.5).unwrap();
        let a = s.ledger().tier(TierId::A);
        assert_eq!(a.writes, 1);
        assert_eq!(a.write_cost, 1.0);
        assert_eq!(a.reads, 1);
        assert_eq!(a.read_cost, 10.0);
        assert_eq!(a.deletes, 1);
        assert!((a.rent_cost - 50.0).abs() < 1e-12); // 0.5 window × $100
        assert_eq!(s.resident_count(), 0);
    }

    #[test]
    fn double_put_rejected() {
        let mut s = sim();
        s.put(1, TierId::A, 0.0).unwrap();
        assert!(s.put(1, TierId::B, 0.1).is_err());
    }

    #[test]
    fn missing_doc_operations_fail() {
        let mut s = sim();
        assert!(s.read(42).is_err());
        assert!(s.delete(42, 0.0).is_err());
        assert!(s.migrate_doc(42, TierId::B, 0.0).is_err());
    }

    #[test]
    fn migrate_doc_settles_rent_and_tags() {
        let mut s = sim();
        s.put(1, TierId::A, 0.0).unwrap();
        s.migrate_doc(1, TierId::B, 0.25).unwrap();
        assert_eq!(s.locate(1), Some(TierId::B));
        let a = s.ledger().tier(TierId::A);
        assert!((a.rent_cost - 25.0).abs() < 1e-12);
        assert_eq!(a.reads, 1); // migration read
        let b = s.ledger().tier(TierId::B);
        assert_eq!(b.writes, 1);
        assert!((s.ledger().migration_total() - (10.0 + 2.0)).abs() < 1e-12);
        // settle at end: doc in B from 0.25 → 1.0 = 0.75 × 200
        s.settle_rent(1.0);
        let b = s.ledger().tier(TierId::B);
        assert!((b.rent_cost - 150.0).abs() < 1e-12);
    }

    #[test]
    fn migrate_all_moves_everything() {
        let mut s = sim();
        for d in 0..5 {
            s.put(d, TierId::A, 0.1).unwrap();
        }
        let n = s.migrate_all(TierId::A, TierId::B, 0.5).unwrap();
        assert_eq!(n, 5);
        assert_eq!(s.tier(TierId::A).len(), 0);
        assert_eq!(s.tier(TierId::B).len(), 5);
    }

    #[test]
    fn settle_rent_idempotent() {
        let mut s = sim();
        s.put(1, TierId::A, 0.0).unwrap();
        s.settle_rent(1.0);
        let rent1 = s.ledger().tier(TierId::A).rent_cost;
        s.settle_rent(1.0);
        let rent2 = s.ledger().tier(TierId::A).rent_cost;
        assert!((rent1 - rent2).abs() < 1e-12, "settle must not double-charge");
    }

    #[test]
    fn rent_disabled_charges_nothing() {
        let mut s = StorageSim::two_tier(
            PerDocCosts { write: 1.0, read: 1.0, rent_window: 100.0 },
            PerDocCosts { write: 1.0, read: 1.0, rent_window: 100.0 },
            false,
        );
        s.put(1, TierId::A, 0.0).unwrap();
        s.delete(1, 1.0).unwrap();
        assert_eq!(s.ledger().tier(TierId::A).rent_cost, 0.0);
    }

    #[test]
    fn multi_tier_setup() {
        let costs = vec![
            PerDocCosts { write: 1.0, read: 1.0, rent_window: 1.0 };
            4
        ];
        let mut s = StorageSim::with_tiers(costs, true);
        assert_eq!(s.num_tiers(), 4);
        s.put(9, TierId(3), 0.0).unwrap();
        assert_eq!(s.locate(9), Some(TierId(3)));
    }
}
