//! The two-(or more-)tier storage simulator.
//!
//! `StorageSim` executes put/read/delete/migrate operations against
//! [`TierState`]s, charging every operation and every doc-window of rent to
//! the [`Ledger`]. Stream position is mapped linearly onto the stream
//! window: document `i` of `N` happens at window fraction `i/N`.
//!
//! ## Multi-stream extensions (fleet)
//!
//! - **Capacity**: each tier may carry a resident-count limit
//!   ([`StorageSim::set_capacity`]); `put`/`migrate_doc` refuse to overfill.
//! - **Attribution**: [`StorageSim::set_attribution`] names the stream that
//!   owns subsequently written documents. Every charge for a document —
//!   write, read, delete, rent, migration hop — is mirrored into the owning
//!   stream's private [`Ledger`], so the fleet-wide ledger always equals the
//!   sum of the per-stream ledgers.
//! - **Per-stream economics**: [`StorageSim::register_stream`] installs a
//!   stream-specific per-doc cost vector (one `PerDocCosts` per tier), so
//!   heterogeneous workloads (different doc sizes / channels) can share the
//!   same physical tiers. Unregistered owners fall back to the tier costs.

use super::ledger::Ledger;
use super::tier::{TierId, TierState};
use crate::cost::PerDocCosts;
use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct StorageSim {
    tiers: Vec<TierState>,
    ledger: Ledger,
    /// Whether rent is charged (mirrors `CostModel::include_rent`).
    charge_rent: bool,
    /// Stream that owns documents written by subsequent `put`s.
    attribution: Option<u64>,
    /// Per-stream ledger mirrors (fleet accounting).
    stream_ledgers: BTreeMap<u64, Ledger>,
    /// Per-stream per-tier effective costs (heterogeneous economics).
    stream_costs: BTreeMap<u64, Vec<PerDocCosts>>,
    /// Free-form per-stream annotations (serve-layer tenancy, ADR-009).
    /// Durable backends journal these with the `reg` record so ownership
    /// metadata survives crashes inside the engine transaction.
    stream_notes: BTreeMap<u64, String>,
}

impl StorageSim {
    /// Standard two-tier setup from effective per-doc costs.
    pub fn two_tier(a: PerDocCosts, b: PerDocCosts, charge_rent: bool) -> Self {
        Self::with_tiers(vec![a, b], charge_rent)
    }

    /// Arbitrary tier list (multi-tier extension).
    pub fn with_tiers(costs: Vec<PerDocCosts>, charge_rent: bool) -> Self {
        Self {
            tiers: costs
                .into_iter()
                .enumerate()
                .map(|(i, c)| TierState::new(TierId(i), c))
                .collect(),
            ledger: Ledger::new(),
            charge_rent,
            attribution: None,
            stream_ledgers: BTreeMap::new(),
            stream_costs: BTreeMap::new(),
            stream_notes: BTreeMap::new(),
        }
    }

    pub fn num_tiers(&self) -> usize {
        self.tiers.len()
    }

    pub fn tier(&self, t: TierId) -> &TierState {
        &self.tiers[t.0]
    }

    fn tier_mut(&mut self, t: TierId) -> &mut TierState {
        &mut self.tiers[t.0]
    }

    pub fn ledger(&self) -> &Ledger {
        &self.ledger
    }

    // ---- fleet extensions --------------------------------------------------

    /// Limit `tier` to `capacity` simultaneous residents (None = unbounded).
    pub fn set_capacity(&mut self, tier: TierId, capacity: Option<usize>) {
        self.tier_mut(tier).set_capacity(capacity);
    }

    /// Whether `tier` can accept one more resident.
    pub fn has_room(&self, tier: TierId) -> bool {
        !self.tier(tier).is_full()
    }

    /// High-water mark of simultaneous residents on `tier`.
    pub fn peak_occupancy(&self, tier: TierId) -> usize {
        self.tier(tier).peak_len()
    }

    /// Attribute subsequent writes to `stream` (None = unattributed).
    pub fn set_attribution(&mut self, stream: Option<u64>) {
        self.attribution = stream;
    }

    /// Install per-tier effective costs for one stream's documents.
    pub fn register_stream(&mut self, stream: u64, costs: Vec<PerDocCosts>) -> Result<()> {
        if costs.len() != self.tiers.len() {
            bail!(
                "register_stream: {} cost entries for {} tiers",
                costs.len(),
                self.tiers.len()
            );
        }
        self.stream_costs.insert(stream, costs);
        Ok(())
    }

    /// Attach a free-form annotation to a registered stream (tenancy
    /// metadata). Overwrites any prior note.
    pub fn set_stream_note(&mut self, stream: u64, note: String) {
        self.stream_notes.insert(stream, note);
    }

    /// The annotation attached to `stream`, if any.
    pub fn stream_note(&self, stream: u64) -> Option<&str> {
        self.stream_notes.get(&stream).map(String::as_str)
    }

    /// The accumulated ledger of one stream (empty if it never operated).
    pub fn stream_ledger(&self, stream: u64) -> Ledger {
        self.stream_ledgers.get(&stream).cloned().unwrap_or_default()
    }

    /// Iterate the per-stream ledgers.
    pub fn stream_ledgers(&self) -> impl Iterator<Item = (&u64, &Ledger)> {
        self.stream_ledgers.iter()
    }

    /// Owning stream of a resident document, if any.
    pub fn owner_of(&self, doc: u64) -> Option<u64> {
        self.tiers
            .iter()
            .find_map(|t| t.get(doc))
            .and_then(|r| r.owner)
    }

    /// The longest-resident document of `tier` (reactive-demotion victim).
    pub fn oldest_resident(&self, tier: TierId) -> Option<u64> {
        self.tier(tier).oldest()
    }

    /// Resident documents owned by `stream` within one tier (sorted) —
    /// the member set of a [`StorageSim::migrate_stream`] batch.
    pub fn stream_docs_in(&self, stream: u64, tier: TierId) -> Vec<u64> {
        if tier.0 >= self.tiers.len() {
            return Vec::new();
        }
        let t = self.tier(tier);
        let mut v: Vec<u64> = t
            .docs()
            .into_iter()
            .filter(|&d| t.get(d).and_then(|r| r.owner) == Some(stream))
            .collect();
        v.sort_unstable();
        v
    }

    /// Resident documents owned by `stream`, across all tiers (sorted).
    /// Used by the engine to release a closing session's residents.
    pub fn docs_of_stream(&self, stream: u64) -> Vec<u64> {
        let mut v: Vec<u64> = self
            .tiers
            .iter()
            .flat_map(|t| t.docs())
            .filter(|&d| self.owner_of(d) == Some(stream))
            .collect();
        v.sort_unstable();
        v
    }

    /// Effective costs of `tier` for documents owned by `owner`.
    fn costs_for(&self, owner: Option<u64>, tier: TierId) -> PerDocCosts {
        owner
            .and_then(|sid| self.stream_costs.get(&sid))
            .map(|v| v[tier.0])
            .unwrap_or(self.tiers[tier.0].costs)
    }

    // ---- attributed charge helpers ----------------------------------------

    fn charge_write_to(&mut self, owner: Option<u64>, t: TierId, cost: f64) {
        self.ledger.charge_write(t, cost);
        if let Some(sid) = owner {
            self.stream_ledgers.entry(sid).or_default().charge_write(t, cost);
        }
    }

    fn charge_read_to(&mut self, owner: Option<u64>, t: TierId, cost: f64) {
        self.ledger.charge_read(t, cost);
        if let Some(sid) = owner {
            self.stream_ledgers.entry(sid).or_default().charge_read(t, cost);
        }
    }

    fn charge_delete_to(&mut self, owner: Option<u64>, t: TierId) {
        self.ledger.charge_delete(t);
        if let Some(sid) = owner {
            self.stream_ledgers.entry(sid).or_default().charge_delete(t);
        }
    }

    fn charge_rent_to(&mut self, owner: Option<u64>, t: TierId, frac: f64, rent_window: f64) {
        self.ledger.charge_rent(t, frac, rent_window);
        if let Some(sid) = owner {
            self.stream_ledgers
                .entry(sid)
                .or_default()
                .charge_rent(t, frac, rent_window);
        }
    }

    fn tag_migration_to(&mut self, owner: Option<u64>, t: TierId, cost: f64) {
        self.ledger.tag_migration(t, cost);
        if let Some(sid) = owner {
            self.stream_ledgers.entry(sid).or_default().tag_migration(t, cost);
        }
    }

    // ---- operations --------------------------------------------------------

    /// Locate a document (linear in tier count — tiers are few).
    pub fn locate(&self, doc: u64) -> Option<TierId> {
        self.tiers.iter().find(|t| t.contains(doc)).map(|t| t.id)
    }

    /// Write a document into `tier` at window fraction `at`, owned by the
    /// current attribution stream. Fails if the tier is at capacity.
    pub fn put(&mut self, doc: u64, tier: TierId, at: f64) -> Result<()> {
        if tier.0 >= self.tiers.len() {
            bail!("unknown tier {tier:?}");
        }
        if let Some(existing) = self.locate(doc) {
            bail!("doc {doc} already resident in tier {existing:?}");
        }
        if self.tiers[tier.0].is_full() {
            bail!(
                "put: tier {} at capacity ({})",
                tier.label(),
                self.tiers[tier.0].capacity().unwrap_or(0)
            );
        }
        let owner = self.attribution;
        let cost = self.costs_for(owner, tier).write;
        self.tier_mut(tier).insert_owned(doc, at, owner);
        self.charge_write_to(owner, tier, cost);
        Ok(())
    }

    /// Delete (prune) a document at window fraction `at`, settling its rent.
    pub fn delete(&mut self, doc: u64, at: f64) -> Result<TierId> {
        let tier = match self.locate(doc) {
            Some(t) => t,
            None => bail!("delete: doc {doc} not resident"),
        };
        let resident = self.tier_mut(tier).remove(doc).unwrap();
        let owner = resident.owner;
        if self.charge_rent {
            let frac = (at - resident.written_at).max(0.0);
            let rent_window = self.costs_for(owner, tier).rent_window;
            self.charge_rent_to(owner, tier, frac, rent_window);
        }
        self.charge_delete_to(owner, tier);
        Ok(tier)
    }

    /// Consumer read of a resident document (does not remove it).
    pub fn read(&mut self, doc: u64) -> Result<TierId> {
        let tier = match self.locate(doc) {
            Some(t) => t,
            None => bail!("read: doc {doc} not resident"),
        };
        let owner = self.tiers[tier.0].get(doc).unwrap().owner;
        let cost = self.costs_for(owner, tier).read;
        self.charge_read_to(owner, tier, cost);
        Ok(tier)
    }

    /// Move one document `from → to` at window fraction `at`: settles rent
    /// on the source, charges a source read + destination write, tags both
    /// as migration ops. Charges go to the document's owner. Fails if the
    /// destination tier is at capacity.
    pub fn migrate_doc(&mut self, doc: u64, to: TierId, at: f64) -> Result<()> {
        let from = match self.locate(doc) {
            Some(t) => t,
            None => bail!("migrate: doc {doc} not resident"),
        };
        if from == to {
            return Ok(());
        }
        if to.0 >= self.tiers.len() {
            bail!("unknown tier {to:?}");
        }
        if self.tiers[to.0].is_full() {
            bail!(
                "migrate: tier {} at capacity ({})",
                to.label(),
                self.tiers[to.0].capacity().unwrap_or(0)
            );
        }
        let resident = self.tier_mut(from).remove(doc).unwrap();
        let owner = resident.owner;
        if self.charge_rent {
            let frac = (at - resident.written_at).max(0.0);
            let rent_window = self.costs_for(owner, from).rent_window;
            self.charge_rent_to(owner, from, frac, rent_window);
        }
        let read_cost = self.costs_for(owner, from).read;
        self.charge_read_to(owner, from, read_cost);
        self.tag_migration_to(owner, from, read_cost);
        let write_cost = self.costs_for(owner, to).write;
        self.tier_mut(to).insert_owned(doc, at, owner);
        self.charge_write_to(owner, to, write_cost);
        self.tag_migration_to(owner, to, write_cost);
        Ok(())
    }

    /// Bulk-migrate every resident of `from` into `to` (paper Fig. 3,
    /// DO_MIGRATE branch at `i == r`).
    ///
    /// All-or-nothing: destination headroom is checked up front, so a
    /// doomed bulk migration fails without moving a single document —
    /// residency, rent clocks, and the ledger are untouched. (It used to
    /// fail partway, leaving the backend half-migrated with rent clocks
    /// split across two tiers.)
    pub fn migrate_all(&mut self, from: TierId, to: TierId, at: f64) -> Result<u64> {
        if from.0 >= self.tiers.len() {
            bail!("unknown tier {from:?}");
        }
        if to.0 >= self.tiers.len() {
            bail!("unknown tier {to:?}");
        }
        if from == to {
            return Ok(0);
        }
        let docs = self.tier(from).docs();
        if let Some(free) = self.tier(to).remaining() {
            if free < docs.len() {
                bail!(
                    "migrate_all: tier {} has {} free slots for {} documents — \
                     aborted with nothing moved",
                    to.label(),
                    free,
                    docs.len()
                );
            }
        }
        let n = docs.len() as u64;
        for doc in docs {
            self.migrate_doc(doc, to, at)?;
        }
        Ok(n)
    }

    /// Bulk-migrate every resident of `from` *owned by `stream`* into
    /// `to` — the per-stream changeover-demotion batch (ADR-005). Charges
    /// are identical to the equivalent sequence of [`StorageSim::migrate_doc`]
    /// hops; durable backends journal the whole batch as one record.
    ///
    /// All-or-nothing: destination headroom is pre-checked against the
    /// batch size, so a doomed batch fails without moving a document.
    /// Returns the number of documents moved (0 for an empty batch or
    /// `from == to`).
    pub fn migrate_stream(
        &mut self,
        stream: u64,
        from: TierId,
        to: TierId,
        at: f64,
    ) -> Result<u64> {
        Ok(self.migrate_stream_docs(stream, from, to, at)?.len() as u64)
    }

    /// [`StorageSim::migrate_stream`], returning the moved doc ids — the
    /// durable backends reuse the batch's one tier scan for their
    /// substrate moves instead of recomputing it.
    pub(crate) fn migrate_stream_docs(
        &mut self,
        stream: u64,
        from: TierId,
        to: TierId,
        at: f64,
    ) -> Result<Vec<u64>> {
        if from.0 >= self.tiers.len() {
            bail!("unknown tier {from:?}");
        }
        if to.0 >= self.tiers.len() {
            bail!("unknown tier {to:?}");
        }
        if from == to {
            return Ok(Vec::new());
        }
        let docs = self.stream_docs_in(stream, from);
        if docs.is_empty() {
            return Ok(docs);
        }
        if let Some(free) = self.tier(to).remaining() {
            if free < docs.len() {
                bail!(
                    "migrate_stream: tier {} has {} free slots for stream {}'s \
                     {} documents — aborted with nothing moved",
                    to.label(),
                    free,
                    stream,
                    docs.len()
                );
            }
        }
        for &doc in &docs {
            self.migrate_doc(doc, to, at)?;
        }
        Ok(docs)
    }

    // ---- checkpoint restore (journal recovery, ADR-005) --------------------

    /// Re-seat a resident exactly as a checkpoint recorded it — residency,
    /// rent clock, and ownership, with *no* charge (the ledger rows are
    /// restored separately). Rejects double residency and unknown tiers.
    pub(crate) fn restore_resident(
        &mut self,
        doc: u64,
        tier: TierId,
        written_at: f64,
        owner: Option<u64>,
    ) -> Result<()> {
        if tier.0 >= self.tiers.len() {
            bail!("unknown tier {tier:?}");
        }
        if let Some(existing) = self.locate(doc) {
            bail!("doc {doc} already resident in tier {existing:?}");
        }
        self.tier_mut(tier).insert_owned(doc, written_at, owner);
        Ok(())
    }

    /// Restore a tier's occupancy high-water mark (checkpoints preserve
    /// peaks the compacted history can no longer reproduce).
    pub(crate) fn restore_peak(&mut self, tier: TierId, peak: usize) {
        if tier.0 < self.tiers.len() {
            self.tier_mut(tier).note_peak(peak);
        }
    }

    /// Restore one ledger row (run-wide for `stream = None`, else the
    /// stream's mirror).
    pub(crate) fn restore_tier_charges(
        &mut self,
        stream: Option<u64>,
        tier: TierId,
        charges: super::ledger::TierCharges,
    ) {
        match stream {
            None => self.ledger.restore_tier(tier, charges),
            Some(s) => {
                self.stream_ledgers.entry(s).or_default().restore_tier(tier, charges)
            }
        }
    }

    /// Iterate the registered per-stream cost tables (checkpoint
    /// serialization).
    pub(crate) fn registered_streams(
        &self,
    ) -> impl Iterator<Item = (&u64, &Vec<PerDocCosts>)> {
        self.stream_costs.iter()
    }

    /// Every stream id ever registered, sorted ascending (BTreeMap order).
    pub fn stream_ids(&self) -> Vec<u64> {
        self.stream_costs.keys().copied().collect()
    }

    /// End of stream: settle rent for everything still resident (they
    /// occupied their tier until window fraction 1.0).
    pub fn settle_rent(&mut self, at: f64) {
        if !self.charge_rent {
            return;
        }
        for t in 0..self.tiers.len() {
            let tier = TierId(t);
            for doc in self.tiers[t].docs() {
                let resident = *self.tiers[t].get(doc).unwrap();
                let owner = resident.owner;
                let frac = (at - resident.written_at).max(0.0);
                let rent_window = self.costs_for(owner, tier).rent_window;
                self.charge_rent_to(owner, tier, frac, rent_window);
                // reset the clock so double-settling is impossible
                self.tier_mut(tier).remove(doc);
                self.tier_mut(tier).insert_owned(doc, at, owner);
            }
        }
    }

    /// Total resident documents across tiers.
    pub fn resident_count(&self) -> usize {
        self.tiers.iter().map(|t| t.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> StorageSim {
        StorageSim::two_tier(
            PerDocCosts { write: 1.0, read: 10.0, rent_window: 100.0 },
            PerDocCosts { write: 2.0, read: 20.0, rent_window: 200.0 },
            true,
        )
    }

    #[test]
    fn put_read_delete_charges() {
        let mut s = sim();
        s.put(1, TierId::A, 0.0).unwrap();
        s.read(1).unwrap();
        s.delete(1, 0.5).unwrap();
        let a = s.ledger().tier(TierId::A);
        assert_eq!(a.writes, 1);
        assert_eq!(a.write_cost, 1.0);
        assert_eq!(a.reads, 1);
        assert_eq!(a.read_cost, 10.0);
        assert_eq!(a.deletes, 1);
        assert!((a.rent_cost - 50.0).abs() < 1e-12); // 0.5 window × $100
        assert_eq!(s.resident_count(), 0);
    }

    #[test]
    fn double_put_rejected() {
        let mut s = sim();
        s.put(1, TierId::A, 0.0).unwrap();
        assert!(s.put(1, TierId::B, 0.1).is_err());
    }

    #[test]
    fn missing_doc_operations_fail() {
        let mut s = sim();
        assert!(s.read(42).is_err());
        assert!(s.delete(42, 0.0).is_err());
        assert!(s.migrate_doc(42, TierId::B, 0.0).is_err());
    }

    #[test]
    fn migrate_doc_settles_rent_and_tags() {
        let mut s = sim();
        s.put(1, TierId::A, 0.0).unwrap();
        s.migrate_doc(1, TierId::B, 0.25).unwrap();
        assert_eq!(s.locate(1), Some(TierId::B));
        let a = s.ledger().tier(TierId::A);
        assert!((a.rent_cost - 25.0).abs() < 1e-12);
        assert_eq!(a.reads, 1); // migration read
        let b = s.ledger().tier(TierId::B);
        assert_eq!(b.writes, 1);
        assert!((s.ledger().migration_total() - (10.0 + 2.0)).abs() < 1e-12);
        // settle at end: doc in B from 0.25 → 1.0 = 0.75 × 200
        s.settle_rent(1.0);
        let b = s.ledger().tier(TierId::B);
        assert!((b.rent_cost - 150.0).abs() < 1e-12);
    }

    #[test]
    fn migrate_all_moves_everything() {
        let mut s = sim();
        for d in 0..5 {
            s.put(d, TierId::A, 0.1).unwrap();
        }
        let n = s.migrate_all(TierId::A, TierId::B, 0.5).unwrap();
        assert_eq!(n, 5);
        assert_eq!(s.tier(TierId::A).len(), 0);
        assert_eq!(s.tier(TierId::B).len(), 5);
    }

    #[test]
    fn doomed_migrate_all_is_a_noop() {
        let mut s = sim();
        for d in 0..4 {
            s.put(d, TierId::A, 0.1).unwrap();
        }
        s.put(10, TierId::B, 0.1).unwrap();
        s.set_capacity(TierId::B, Some(3)); // room for 2 more, 4 needed
        let residents_before = s.tier(TierId::A).docs();
        let ledger_before = s.ledger().clone();
        assert!(s.migrate_all(TierId::A, TierId::B, 0.5).is_err());
        // all-or-nothing: nothing moved, nothing charged
        assert_eq!(s.tier(TierId::A).docs(), residents_before);
        assert_eq!(s.tier(TierId::B).len(), 1);
        assert_eq!(s.ledger().total(), ledger_before.total());
        assert_eq!(s.ledger().total_writes(), ledger_before.total_writes());
        assert_eq!(s.ledger().migration_total(), 0.0);
        // rent clocks untouched: a later full migration settles from 0.1
        s.set_capacity(TierId::B, None);
        s.migrate_all(TierId::A, TierId::B, 0.5).unwrap();
        let a = s.ledger().tier(TierId::A);
        assert!((a.rent_cost - 4.0 * 0.4 * 100.0).abs() < 1e-9, "rent {}", a.rent_cost);
    }

    #[test]
    fn migrate_all_same_tier_is_trivially_empty() {
        let mut s = sim();
        s.put(1, TierId::A, 0.0).unwrap();
        let before = s.ledger().total();
        assert_eq!(s.migrate_all(TierId::A, TierId::A, 0.5).unwrap(), 0);
        assert_eq!(s.ledger().total(), before);
        assert_eq!(s.locate(1), Some(TierId::A));
    }

    #[test]
    fn settle_rent_idempotent() {
        let mut s = sim();
        s.put(1, TierId::A, 0.0).unwrap();
        s.settle_rent(1.0);
        let rent1 = s.ledger().tier(TierId::A).rent_cost;
        s.settle_rent(1.0);
        let rent2 = s.ledger().tier(TierId::A).rent_cost;
        assert!((rent1 - rent2).abs() < 1e-12, "settle must not double-charge");
    }

    #[test]
    fn rent_disabled_charges_nothing() {
        let mut s = StorageSim::two_tier(
            PerDocCosts { write: 1.0, read: 1.0, rent_window: 100.0 },
            PerDocCosts { write: 1.0, read: 1.0, rent_window: 100.0 },
            false,
        );
        s.put(1, TierId::A, 0.0).unwrap();
        s.delete(1, 1.0).unwrap();
        assert_eq!(s.ledger().tier(TierId::A).rent_cost, 0.0);
    }

    #[test]
    fn multi_tier_setup() {
        let costs = vec![
            PerDocCosts { write: 1.0, read: 1.0, rent_window: 1.0 };
            4
        ];
        let mut s = StorageSim::with_tiers(costs, true);
        assert_eq!(s.num_tiers(), 4);
        s.put(9, TierId(3), 0.0).unwrap();
        assert_eq!(s.locate(9), Some(TierId(3)));
    }

    #[test]
    fn capacity_rejects_overfill_put_and_migrate() {
        let mut s = sim();
        s.set_capacity(TierId::A, Some(2));
        s.put(1, TierId::A, 0.0).unwrap();
        s.put(2, TierId::A, 0.0).unwrap();
        assert!(!s.has_room(TierId::A));
        assert!(s.put(3, TierId::A, 0.1).is_err());
        s.put(3, TierId::B, 0.1).unwrap();
        assert!(s.migrate_doc(3, TierId::A, 0.2).is_err());
        // freeing a slot re-admits
        s.delete(1, 0.3).unwrap();
        s.put(4, TierId::A, 0.3).unwrap();
        assert_eq!(s.peak_occupancy(TierId::A), 2);
    }

    #[test]
    fn attribution_mirrors_charges_per_stream() {
        let mut s = sim();
        s.set_attribution(Some(0));
        s.put(1, TierId::A, 0.0).unwrap();
        s.set_attribution(Some(1));
        s.put(2, TierId::B, 0.0).unwrap();
        // reads/deletes follow the *owner*, not the current attribution
        s.set_attribution(Some(0));
        s.read(2).unwrap();
        s.migrate_doc(1, TierId::B, 0.5).unwrap();
        s.settle_rent(1.0);
        let total = s.ledger().total();
        let split: f64 = s.stream_ledgers().map(|(_, l)| l.total()).sum();
        assert!((total - split).abs() < 1e-9, "fleet {total} vs Σstreams {split}");
        // ownership is per-doc, regardless of the ambient attribution
        assert_eq!(s.owner_of(1), Some(0));
        assert_eq!(s.owner_of(2), Some(1));
        assert_eq!(s.owner_of(99), None);
        // stream 1 owns doc 2: its ledger got the read
        assert_eq!(s.stream_ledger(1).total_reads(), 1);
        // stream 0 owns doc 1: its ledger got the migration hop
        assert!((s.stream_ledger(0).migration_total() - 12.0).abs() < 1e-12);
    }

    #[test]
    fn per_stream_costs_override_tier_costs() {
        let mut s = sim();
        s.register_stream(
            7,
            vec![
                PerDocCosts { write: 5.0, read: 0.5, rent_window: 10.0 },
                PerDocCosts { write: 6.0, read: 0.6, rent_window: 20.0 },
            ],
        )
        .unwrap();
        s.set_attribution(Some(7));
        s.put(1, TierId::A, 0.0).unwrap();
        assert_eq!(s.ledger().tier(TierId::A).write_cost, 5.0);
        // unattributed writes still use tier defaults
        s.set_attribution(None);
        s.put(2, TierId::A, 0.0).unwrap();
        assert_eq!(s.ledger().tier(TierId::A).write_cost, 6.0);
        // wrong arity rejected
        assert!(s.register_stream(8, vec![]).is_err());
    }

    #[test]
    fn migrate_stream_moves_only_the_streams_batch() {
        let mut s = sim();
        s.set_attribution(Some(0));
        s.put(1, TierId::A, 0.1).unwrap();
        s.put(2, TierId::A, 0.2).unwrap();
        s.set_attribution(Some(1));
        s.put(3, TierId::A, 0.3).unwrap();
        assert_eq!(s.stream_docs_in(0, TierId::A), vec![1, 2]);
        assert_eq!(s.migrate_stream(0, TierId::A, TierId::B, 0.5).unwrap(), 2);
        assert_eq!(s.locate(1), Some(TierId::B));
        assert_eq!(s.locate(3), Some(TierId::A), "stream 1's doc stays");
        // charges landed on the owning stream, tagged as migration hops
        assert!(s.stream_ledger(0).migration_total() > 0.0);
        assert_eq!(s.stream_ledger(1).migration_total(), 0.0);
        // empty batch and same-tier are free no-ops
        assert_eq!(s.migrate_stream(9, TierId::A, TierId::B, 0.6).unwrap(), 0);
        assert_eq!(s.migrate_stream(1, TierId::A, TierId::A, 0.6).unwrap(), 0);
    }

    #[test]
    fn doomed_migrate_stream_is_all_or_nothing() {
        let mut s = sim();
        s.set_attribution(Some(0));
        for d in 0..3 {
            s.put(d, TierId::A, 0.1).unwrap();
        }
        s.set_capacity(TierId::B, Some(2));
        let before = s.ledger().total();
        assert!(s.migrate_stream(0, TierId::A, TierId::B, 0.5).is_err());
        assert_eq!(s.tier(TierId::A).len(), 3);
        assert_eq!(s.ledger().total(), before);
        assert_eq!(s.ledger().migration_total(), 0.0);
    }

    #[test]
    fn migrate_stream_matches_per_doc_hops_bit_for_bit() {
        let drive = |bulk: bool| -> StorageSim {
            let mut s = sim();
            s.set_attribution(Some(4));
            for d in 0..5 {
                s.put(d, TierId::A, 0.05 * d as f64).unwrap();
            }
            if bulk {
                s.migrate_stream(4, TierId::A, TierId::B, 0.5).unwrap();
            } else {
                for d in s.stream_docs_in(4, TierId::A) {
                    s.migrate_doc(d, TierId::B, 0.5).unwrap();
                }
            }
            s.settle_rent(1.0);
            s
        };
        let (a, b) = (drive(true), drive(false));
        assert_eq!(a.ledger().total().to_bits(), b.ledger().total().to_bits());
        assert_eq!(
            a.stream_ledger(4).total().to_bits(),
            b.stream_ledger(4).total().to_bits()
        );
        assert_eq!(a.ledger().migration_total(), b.ledger().migration_total());
    }

    #[test]
    fn oldest_resident_for_demotion() {
        let mut s = sim();
        s.put(5, TierId::A, 0.2).unwrap();
        s.put(6, TierId::A, 0.1).unwrap();
        assert_eq!(s.oldest_resident(TierId::A), Some(6));
        assert_eq!(s.oldest_resident(TierId::B), None);
    }
}
