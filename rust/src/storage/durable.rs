//! `storage::durable` — the journaled [`StorageBackend`] shared by every
//! durable substrate (ADR-005).
//!
//! ADR-003 built the real-filesystem backend as *one accounting state
//! machine, two substrates*: an inner [`StorageSim`] owns all residency
//! bookkeeping and charge accounting (so ledger parity with the simulator
//! is structural), and real IO plus a write-ahead journal layer on top.
//! This module extracts that layering so the filesystem backend
//! ([`super::fs::FsBackend`]) and the S3-style object-store backend
//! ([`super::object::ObjectBackend`]) are the *same* backend over
//! different [`DocStore`] substrates — journaling, checkpoint/compaction,
//! crash recovery, and wedge-on-failure semantics are written once.
//!
//! ## Durability contract
//!
//! Every state-changing operation appends one journal record *before*
//! touching the substrate (see [`super::journal`] for the grammar).
//! Opening a root that already holds a journal replays it (latest
//! complete checkpoint + op suffix), then reconciles the substrate's
//! documents against the replayed residency — recreating what is
//! missing, removing what nothing owns, rewriting torn payloads.
//! Capacities and the ambient attribution stream are *runtime*
//! configuration, not durable state: callers re-apply them after open.
//!
//! If a journal append or substrate operation fails mid-run the backend
//! wedges: every subsequent operation errors until the backend is
//! reopened from the journal, which restores the invariant that the
//! journal is the single source of truth.
//!
//! ## Group commit (ADR-009)
//!
//! With [`StorageBackend::set_group_commit`] enabled, journal records
//! buffer in a bounded in-memory batch and reach the log as one framed
//! `batch <n>` write. The substrate may then run *ahead* of the durable
//! journal inside the staleness window; recovery converges anyway,
//! because replay rebuilds the accounting state from the journal's
//! batch-boundary prefix and [`reconcile_store`] then removes substrate
//! payloads nothing owns (and recreates what is missing). Forced
//! barriers — checkpoint, `migrate_all`/`migrate_stream`, wedge, drop,
//! [`StorageBackend::journal_flush`] — empty the buffer before
//! returning.

use super::backend::{CheckpointReport, StorageBackend};
use super::journal::{self, Journal};
use super::ledger::Ledger;
use super::sim::StorageSim;
use super::tier::{Resident, TierId};
use crate::cost::PerDocCosts;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// A substrate that physically holds one document payload per resident:
/// files in tier directories ([`super::fs::FsStore`]) or objects in
/// per-tier buckets ([`super::object::ObjectStore`]). All residency
/// *logic* lives above, in [`DurableBackend`]; implementations only move
/// bytes and report what exists.
pub trait DocStore: Send {
    /// Substrate name for reports (e.g. `"fs"`).
    fn name(&self) -> &'static str;

    /// Create the per-tier containers under the root (idempotent).
    fn prepare(&mut self, tiers: usize) -> Result<()>;

    /// Store `doc`'s payload in `tier` (overwriting any stale copy).
    fn write_doc(&mut self, tier: TierId, doc: u64, at: f64) -> Result<()>;

    /// Remove `doc` from `tier`. Already-missing payloads succeed (the
    /// crash window between journal append and substrate op).
    fn remove_doc(&mut self, tier: TierId, doc: u64) -> Result<()>;

    /// Move `doc` between tiers. A missing source is repaired by writing
    /// a fresh payload at the destination.
    fn move_doc(&mut self, from: TierId, to: TierId, doc: u64, at: f64) -> Result<()>;

    /// Serve a consumer read of `doc` from `tier`, verifying the payload.
    fn read_doc(&mut self, tier: TierId, doc: u64) -> Result<()>;

    /// Doc ids whose payloads exist in `tier` (foreign entries skipped).
    fn list_docs(&mut self, tier: TierId) -> Result<Vec<u64>>;

    /// Whether `doc`'s payload in `tier` is intact (recovery validation).
    fn doc_intact(&mut self, tier: TierId, doc: u64) -> bool;
}

/// The 16-byte document payload every substrate stores: the doc id (LE)
/// followed by the written-at `f64` bits (LE) — real bytes the read path
/// verifies, not a zero-length marker. Shared here so the format cannot
/// drift between substrates.
pub(crate) fn doc_payload(doc: u64, at: f64) -> [u8; 16] {
    let mut bytes = [0u8; 16];
    bytes[..8].copy_from_slice(&doc.to_le_bytes());
    bytes[8..].copy_from_slice(&at.to_bits().to_le_bytes());
    bytes
}

/// Whether stored `bytes` serve `doc` — the shared read-path/recovery
/// intactness check (the id prefix must match).
pub(crate) fn payload_intact(bytes: &[u8], doc: u64) -> bool {
    bytes.len() >= 8 && bytes[..8] == doc.to_le_bytes()
}

/// Scan one substrate container for managed document keys: entries named
/// `<doc><suffix>` parse to ids, foreign entries are skipped, output
/// sorted. Shared by both substrates so the key grammar cannot drift.
pub(crate) fn scan_keys(dir: &Path, suffix: &str) -> Result<Vec<u64>> {
    let mut docs = Vec::new();
    for entry in
        std::fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))?
    {
        let name = entry?.file_name();
        let Some(stem) = name.to_string_lossy().strip_suffix(suffix).map(String::from)
        else {
            continue; // not a managed entry
        };
        if let Ok(doc) = stem.parse::<u64>() {
            docs.push(doc);
        }
    }
    docs.sort_unstable();
    Ok(docs)
}

/// What opening over a pre-existing journal rebuilt.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RecoveryReport {
    /// Journal op records replayed into the accounting state (records
    /// folded into a loaded checkpoint are not re-counted).
    pub ops_replayed: u64,
    /// Complete checkpoint blocks loaded (the latest one seeds the state).
    pub checkpoints_loaded: u64,
    /// Resident document payloads that were missing (or torn) on the
    /// substrate and recreated.
    pub files_recreated: u64,
    /// Substrate payloads with no resident backing them, removed.
    pub files_removed: u64,
    /// Whether a torn trailing record (or torn checkpoint block) was
    /// dropped, or a torn header healed.
    pub truncated_tail: bool,
}

/// A [`StorageBackend`] that journals every operation and stores one
/// payload per resident in a [`DocStore`] substrate. See the module docs
/// for the layout and the durability contract; `FsBackend` and
/// `ObjectBackend` are the two instantiations.
pub struct DurableBackend<S: DocStore> {
    pub(crate) store: S,
    /// The accounting + residency state machine (same code as the sim).
    state: StorageSim,
    journal: Journal,
    costs: Vec<PerDocCosts>,
    charge_rent: bool,
    /// Mirror of the sim's ambient attribution (journaled per `put`).
    attribution: Option<u64>,
    /// Set on a failed journal append / substrate op: the in-memory state
    /// and the durable record may disagree, so all further ops refuse.
    wedged: Option<String>,
    recovery: Option<RecoveryReport>,
}

/// Open (or recover) a durable backend: substrate `store`, journal at
/// `journal_path`. If the journal exists, the accounting state is rebuilt
/// from it and the substrate reconciled; the declared `costs` and
/// `charge_rent` must match the journal header exactly.
pub(crate) fn open_durable<S: DocStore>(
    mut store: S,
    journal_path: PathBuf,
    costs: Vec<PerDocCosts>,
    charge_rent: bool,
) -> Result<DurableBackend<S>> {
    if costs.len() < 2 {
        bail!(
            "{} backend needs at least two tiers (got {})",
            store.name(),
            costs.len()
        );
    }
    store.prepare(costs.len())?;
    let (state, journal, recovery) = if journal_path.exists() {
        let replay = journal::replay(&journal_path, &costs, charge_rent)?;
        let mut report = RecoveryReport {
            ops_replayed: replay.ops_replayed,
            checkpoints_loaded: replay.checkpoints_loaded,
            truncated_tail: replay.truncated_tail,
            ..RecoveryReport::default()
        };
        reconcile_store(&mut store, &replay.state, &mut report)?;
        let journal = Journal::open_append(journal_path, replay.ops_replayed)?;
        (replay.state, journal, Some(report))
    } else {
        let journal = Journal::create(journal_path, &costs, charge_rent)?;
        (StorageSim::with_tiers(costs.clone(), charge_rent), journal, None)
    };
    Ok(DurableBackend {
        store,
        state,
        journal,
        costs,
        charge_rent,
        attribution: None,
        wedged: None,
        recovery,
    })
}

/// Reconcile the substrate's payloads against the replayed residency:
/// recreate what is missing, rewrite what is torn, remove what nothing
/// owns.
fn reconcile_store<S: DocStore>(
    store: &mut S,
    state: &StorageSim,
    report: &mut RecoveryReport,
) -> Result<()> {
    for t in 0..state.num_tiers() {
        let tier = TierId(t);
        let mut expected: BTreeMap<u64, f64> = state
            .tier(tier)
            .docs()
            .into_iter()
            .map(|d| (d, state.tier(tier).get(d).expect("doc listed").written_at))
            .collect();
        for doc in store.list_docs(tier)? {
            match expected.remove(&doc) {
                Some(at) => {
                    // a crash mid-write can leave a torn payload under a
                    // matching key — validate what read_doc will check and
                    // rewrite from the replayed state if it is corrupt
                    if !store.doc_intact(tier, doc) {
                        store.write_doc(tier, doc, at).with_context(|| {
                            format!("rewriting torn payload for doc {doc}")
                        })?;
                        report.files_recreated += 1;
                    }
                }
                None => {
                    store
                        .remove_doc(tier, doc)
                        .with_context(|| format!("removing orphan payload {doc}"))?;
                    report.files_removed += 1;
                }
            }
        }
        for (doc, at) in expected {
            store
                .write_doc(tier, doc, at)
                .with_context(|| format!("recreating payload for doc {doc}"))?;
            report.files_recreated += 1;
        }
    }
    Ok(())
}

impl<S: DocStore> DurableBackend<S> {
    /// `fsync` the journal on every durable append (power-loss
    /// durability, not just process death). Off by default:
    /// process-death durability only needs the flush. Enabling also
    /// syncs the already-written header + parent directory (see
    /// [`Journal::set_sync`]); if that sync fails the backend wedges
    /// rather than run with durability silently degraded.
    pub fn with_sync(mut self, sync: bool) -> Self {
        if let Err(e) = self.journal.set_sync(sync) {
            self.wedged = Some(format!("enabling sync_writes failed: {e:#}"));
        }
        self
    }

    /// The recovery report, if this backend was opened over an existing
    /// journal (None on a fresh root).
    pub fn recovery(&self) -> Option<&RecoveryReport> {
        self.recovery.as_ref()
    }

    /// Declared per-tier cost tables (the journal-header economics).
    pub fn tier_costs(&self) -> &[PerDocCosts] {
        &self.costs
    }

    fn ensure_live(&self) -> Result<()> {
        if let Some(why) = &self.wedged {
            bail!(
                "{} backend is wedged ({why}) — reopen from the journal to recover",
                self.store.name()
            );
        }
        Ok(())
    }

    /// Append one journal record. A failure wedges the backend: the
    /// applied state is no longer durably recorded.
    fn append(&mut self, line: String) -> Result<()> {
        let res = self.journal.append_op(&line);
        if let Err(e) = &res {
            self.wedged = Some(format!("journal append failed: {e:#}"));
        }
        res
    }

    /// Durably flush any buffered journal batch now (a forced barrier),
    /// wedging the backend if the flush fails.
    fn flush_now(&mut self) -> Result<()> {
        let res = self.journal.flush_batch();
        if let Err(e) = &res {
            self.wedged = Some(format!("journal flush failed: {e:#}"));
        }
        res
    }

    /// Run a substrate operation, wedging the backend on failure (the
    /// journal already records the op, so only a reopen can reconcile).
    /// A wedge is a forced barrier: buffered journal records are
    /// flushed best-effort so the reopen replays everything that was
    /// committed before the failure.
    fn store_op(&mut self, res: Result<()>, what: &str) -> Result<()> {
        match res {
            Ok(()) => Ok(()),
            Err(e) => {
                let _ = self.journal.flush_batch();
                self.wedged = Some(format!("{what}: {e:#}"));
                bail!("{what}: {e:#} (backend wedged; reopen to recover from the journal)");
            }
        }
    }
}

impl<S: DocStore> StorageBackend for DurableBackend<S> {
    fn backend_name(&self) -> String {
        self.store.name().into()
    }

    fn num_tiers(&self) -> usize {
        self.state.num_tiers()
    }

    fn put(&mut self, doc: u64, tier: TierId, at: f64) -> Result<()> {
        self.ensure_live()?;
        self.state.put(doc, tier, at)?;
        let owner = match self.attribution {
            Some(s) => s.to_string(),
            None => "-".into(),
        };
        self.append(format!("put {doc} {} {} {owner}", tier.0, journal::fmt_bits(at)))?;
        let res = self.store.write_doc(tier, doc, at);
        self.store_op(res, "writing document payload")
    }

    fn delete(&mut self, doc: u64, at: f64) -> Result<TierId> {
        self.ensure_live()?;
        let tier = self.state.delete(doc, at)?;
        self.append(format!("del {doc} {}", journal::fmt_bits(at)))?;
        let res = self.store.remove_doc(tier, doc);
        self.store_op(res, "removing document payload").map(|()| tier)
    }

    fn read(&mut self, doc: u64) -> Result<TierId> {
        self.ensure_live()?;
        let Some(tier) = self.state.locate(doc) else {
            bail!("read: doc {doc} not resident");
        };
        self.store.read_doc(tier, doc)?;
        self.state.read(doc)?;
        self.append(format!("read {doc}"))?;
        Ok(tier)
    }

    fn migrate_doc(&mut self, doc: u64, to: TierId, at: f64) -> Result<()> {
        self.ensure_live()?;
        let Some(from) = self.state.locate(doc) else {
            bail!("migrate: doc {doc} not resident");
        };
        if from == to {
            return Ok(());
        }
        self.state.migrate_doc(doc, to, at)?;
        self.append(format!("mig {doc} {} {}", to.0, journal::fmt_bits(at)))?;
        let res = self.store.move_doc(from, to, doc, at);
        self.store_op(res, "moving document payload")
    }

    fn migrate_all(&mut self, from: TierId, to: TierId, at: f64) -> Result<u64> {
        self.ensure_live()?;
        let tiers = self.state.num_tiers();
        if from.0 >= tiers || to.0 >= tiers {
            // delegate the bounds error (moves nothing)
            return self.state.migrate_all(from, to, at);
        }
        let docs = self.state.tier(from).docs();
        // all-or-nothing headroom check happens inside the state machine;
        // a doomed migration journals and moves nothing
        let n = self.state.migrate_all(from, to, at)?;
        if n == 0 {
            return Ok(0); // same-tier or empty source: nothing to record
        }
        self.append(format!("migall {} {} {}", from.0, to.0, journal::fmt_bits(at)))?;
        // a bulk migration is a forced barrier: the record (and anything
        // buffered before it) must be durable before payloads move
        self.flush_now()?;
        for doc in docs {
            let res = self.store.move_doc(from, to, doc, at);
            self.store_op(res, "moving document payload")?;
        }
        Ok(n)
    }

    fn migrate_stream(&mut self, stream: u64, from: TierId, to: TierId, at: f64) -> Result<u64> {
        self.ensure_live()?;
        // all-or-nothing headroom check inside the state machine, which
        // hands back the member set so the substrate moves reuse its scan
        let docs = self.state.migrate_stream_docs(stream, from, to, at)?;
        let n = docs.len() as u64;
        if n == 0 {
            return Ok(0);
        }
        // ONE journal record for the whole batch — replay recomputes the
        // member set deterministically from the journal prefix
        self.append(format!(
            "migstream {stream} {} {} {}",
            from.0,
            to.0,
            journal::fmt_bits(at)
        ))?;
        // bulk migrations are forced barriers, like migrate_all
        self.flush_now()?;
        for doc in docs {
            let res = self.store.move_doc(from, to, doc, at);
            self.store_op(res, "moving document payload")?;
        }
        Ok(n)
    }

    fn settle_rent(&mut self, at: f64) -> Result<()> {
        self.ensure_live()?;
        self.state.settle_rent(at);
        self.append(format!("settle {}", journal::fmt_bits(at)))
    }

    fn checkpoint(&mut self) -> Result<CheckpointReport> {
        self.ensure_live()?;
        let ops_folded = self.journal.ops();
        let res = self
            .journal
            .checkpoint(&self.state, &self.costs, self.charge_rent);
        if let Err(e) = &res {
            self.wedged = Some(format!("checkpoint failed: {e:#}"));
        }
        res?;
        Ok(CheckpointReport {
            ops_folded,
            live_docs: self.state.resident_count() as u64,
            ops_after: self.journal.ops(),
        })
    }

    fn journal_ops(&self) -> u64 {
        self.journal.ops()
    }

    fn set_group_commit(&mut self, enabled: bool) {
        if self.journal.set_group_commit(enabled).is_err() {
            // disabling flushes; a failed flush leaves records buffered
            self.wedged = Some("journal flush failed while toggling group commit".into());
        }
    }

    fn journal_flush(&mut self) -> Result<()> {
        self.ensure_live()?;
        self.flush_now()
    }

    fn journal_tick(&mut self) -> Result<()> {
        self.ensure_live()?;
        let res = self.journal.flush_if_due();
        if let Err(e) = &res {
            self.wedged = Some(format!("journal flush failed: {e:#}"));
        }
        res
    }

    fn journal_buffered(&self) -> u64 {
        self.journal.buffered()
    }

    fn set_sync_writes(&mut self, sync: bool) {
        if let Err(e) = self.journal.set_sync(sync) {
            self.wedged = Some(format!("enabling sync_writes failed: {e:#}"));
        }
    }

    fn locate(&self, doc: u64) -> Option<TierId> {
        self.state.locate(doc)
    }

    fn resident_len(&self, tier: TierId) -> usize {
        self.state.tier(tier).len()
    }

    fn residents(&self, tier: TierId) -> Vec<Resident> {
        let t = self.state.tier(tier);
        let mut v: Vec<Resident> = t.docs().iter().map(|d| *t.get(*d).unwrap()).collect();
        v.sort_by_key(|r| r.doc);
        v
    }

    fn resident_count(&self) -> usize {
        self.state.resident_count()
    }

    fn oldest_resident(&self, tier: TierId) -> Option<u64> {
        self.state.oldest_resident(tier)
    }

    fn owner_of(&self, doc: u64) -> Option<u64> {
        self.state.owner_of(doc)
    }

    fn docs_of_stream(&self, stream: u64) -> Vec<u64> {
        self.state.docs_of_stream(stream)
    }

    fn set_capacity(&mut self, tier: TierId, capacity: Option<usize>) {
        self.state.set_capacity(tier, capacity);
    }

    fn capacity(&self, tier: TierId) -> Option<usize> {
        self.state.tier(tier).capacity()
    }

    fn has_room(&self, tier: TierId) -> bool {
        self.state.has_room(tier)
    }

    fn peak_occupancy(&self, tier: TierId) -> usize {
        self.state.peak_occupancy(tier)
    }

    fn set_attribution(&mut self, stream: Option<u64>) {
        self.attribution = stream;
        self.state.set_attribution(stream);
    }

    fn register_stream(&mut self, stream: u64, costs: Vec<PerDocCosts>) -> Result<()> {
        self.ensure_live()?;
        self.state.register_stream(stream, costs.clone())?;
        self.append(format!("reg {stream} {}", journal::fmt_costs(&costs)))
    }

    fn register_stream_with_note(
        &mut self,
        stream: u64,
        costs: Vec<PerDocCosts>,
        note: &str,
    ) -> Result<()> {
        self.ensure_live()?;
        if note.is_empty() {
            // an empty note has no hex token to carry — plain record
            return self.register_stream(stream, costs);
        }
        self.state.register_stream(stream, costs.clone())?;
        self.state.set_stream_note(stream, note.to_string());
        // ONE record: registration and ownership metadata are atomic on
        // disk, so a crash cannot orphan the stream's attribution
        self.append(format!(
            "reg {stream} {} {}",
            journal::fmt_costs(&costs),
            journal::fmt_note(note)
        ))
    }

    fn set_stream_note(&mut self, stream: u64, note: &str) {
        self.state.set_stream_note(stream, note.to_string());
    }

    fn stream_note(&self, stream: u64) -> Option<String> {
        self.state.stream_note(stream).map(str::to_string)
    }

    fn ledger(&self) -> &Ledger {
        self.state.ledger()
    }

    fn stream_ledger(&self, stream: u64) -> Ledger {
        self.state.stream_ledger(stream)
    }

    fn stream_ids(&self) -> Vec<u64> {
        // journal replay re-registers every stream into the substrate, so
        // a reopened backend reports the full historical id set
        self.state.stream_ids()
    }
}
