//! `storage::object` — the S3-style object-store [`StorageBackend`]
//! (ADR-005).
//!
//! The paper's two cloud case studies price tier placement against
//! object-store economics: per-request GET/PUT plus occupancy rent. This
//! backend executes plans against exactly that surface — an
//! [`ObjectStore`] keyspace with **one bucket per tier** and **flat
//! object keys** (`<doc>.obj`), where every operation is an explicit,
//! counted request:
//!
//! - organic writes are `PUT`s, consumer reads are verified `GET`s,
//!   prunes are `DELETE`s;
//! - a migration hop is the S3 idiom `COPY` + `DELETE` (objects are
//!   immutable; there is no rename);
//! - crash recovery reconciles each bucket with `LIST` + repair
//!   `PUT`/`DELETE`s.
//!
//! Request counts are surfaced per verb ([`ObjectBackend::request_counts`])
//! so a run can be reconciled against a priced request budget, and the
//! store carries two simulation knobs for failure-mode testing:
//! per-request latency ([`ObjectBackend::with_latency`]) and an injected
//! outage ([`ObjectBackend::with_failure_after`] — every request past the
//! first `n` fails, wedging the backend exactly as a real endpoint outage
//! would).
//!
//! Durability: the backend is an instantiation of the shared
//! [`DurableBackend`] machinery (see ADR-005 and [`super::durable`]); its
//! **manifest log** (`<root>/manifest.log`, outside the keyspace) is the
//! same write-ahead journal as the filesystem backend's, with the same
//! checkpoint/compaction and torn-record healing. The keyspace itself is
//! hosted on local directories — the store is a faithful *semantic* model
//! of an object endpoint (flat keys, copy-not-rename, per-request
//! accounting), not an HTTP client; swapping in a real client behind
//! [`ObjectStore`]'s verbs is a ROADMAP follow-up.
//!
//! [`StorageBackend`]: super::backend::StorageBackend

use super::durable::{
    doc_payload, open_durable, payload_intact, scan_keys, DocStore, DurableBackend,
};
use super::tier::TierId;
use anyhow::{bail, Context, Result};
use std::fs;
use std::path::{Path, PathBuf};
use std::time::Duration;

const MANIFEST_FILE: &str = "manifest.log";

/// Requests issued to the object store, by verb.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RequestCounts {
    pub get: u64,
    pub put: u64,
    pub delete: u64,
    pub copy: u64,
    pub list: u64,
}

impl RequestCounts {
    /// Total requests across verbs.
    pub fn total(&self) -> u64 {
        self.get + self.put + self.delete + self.copy + self.list
    }
}

/// An S3-style keyspace over local directories: one bucket per tier, flat
/// object keys, request-counted verbs, simulated latency and outage
/// injection. All residency logic lives above, in [`DurableBackend`].
pub struct ObjectStore {
    root: PathBuf,
    counts: RequestCounts,
    /// Simulated per-request latency (None = no delay).
    latency: Option<Duration>,
    /// Injected outage: requests beyond the first `n` fail.
    fail_after: Option<u64>,
}

impl ObjectStore {
    fn new(root: PathBuf) -> Self {
        Self { root, counts: RequestCounts::default(), latency: None, fail_after: None }
    }

    fn bucket_dir(&self, tier: TierId) -> PathBuf {
        self.root.join(format!("tier-{}", tier.0))
    }

    fn key(doc: u64) -> String {
        format!("{doc}.obj")
    }

    fn object_path(&self, tier: TierId, doc: u64) -> PathBuf {
        self.bucket_dir(tier).join(Self::key(doc))
    }

    /// Account one request: apply the latency knob, then the outage knob.
    fn request(&mut self, verb: &str) -> Result<()> {
        if let Some(d) = self.latency {
            std::thread::sleep(d);
        }
        let issued = self.counts.total();
        match verb {
            "GET" => self.counts.get += 1,
            "PUT" => self.counts.put += 1,
            "DELETE" => self.counts.delete += 1,
            "COPY" => self.counts.copy += 1,
            "LIST" => self.counts.list += 1,
            other => unreachable!("unknown verb {other}"),
        }
        if let Some(n) = self.fail_after {
            if issued >= n {
                bail!("injected object-store outage: {verb} request #{} refused", issued + 1);
            }
        }
        Ok(())
    }

    // ---- the verb surface (counted requests) -------------------------------

    fn put_object(&mut self, tier: TierId, doc: u64, at: f64) -> Result<()> {
        self.request("PUT")?;
        let path = self.object_path(tier, doc);
        fs::write(&path, doc_payload(doc, at))
            .with_context(|| format!("PUT {}", path.display()))
    }

    fn get_object(&mut self, tier: TierId, doc: u64) -> Result<Vec<u8>> {
        self.request("GET")?;
        let path = self.object_path(tier, doc);
        fs::read(&path).with_context(|| format!("GET {}", path.display()))
    }

    /// S3 semantics: deleting a missing key succeeds.
    fn delete_object(&mut self, tier: TierId, doc: u64) -> Result<()> {
        self.request("DELETE")?;
        let path = self.object_path(tier, doc);
        match fs::remove_file(&path) {
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            res => res.with_context(|| format!("DELETE {}", path.display())),
        }
    }

    /// Errors if the source object is missing (the caller repairs).
    fn copy_object(&mut self, from: TierId, to: TierId, doc: u64) -> Result<()> {
        self.request("COPY")?;
        let src = self.object_path(from, doc);
        let dst = self.object_path(to, doc);
        fs::copy(&src, &dst)
            .map(|_| ())
            .with_context(|| format!("COPY {} -> {}", src.display(), dst.display()))
    }

    fn list_bucket(&mut self, tier: TierId) -> Result<Vec<u64>> {
        self.request("LIST")?;
        scan_keys(&self.bucket_dir(tier), ".obj")
    }
}

impl DocStore for ObjectStore {
    fn name(&self) -> &'static str {
        "object"
    }

    fn prepare(&mut self, tiers: usize) -> Result<()> {
        fs::create_dir_all(&self.root)
            .with_context(|| format!("creating backend root {}", self.root.display()))?;
        for i in 0..tiers {
            let dir = self.bucket_dir(TierId(i));
            fs::create_dir_all(&dir)
                .with_context(|| format!("creating bucket {}", dir.display()))?;
        }
        Ok(())
    }

    fn write_doc(&mut self, tier: TierId, doc: u64, at: f64) -> Result<()> {
        self.put_object(tier, doc, at)
    }

    fn remove_doc(&mut self, tier: TierId, doc: u64) -> Result<()> {
        self.delete_object(tier, doc)
    }

    fn move_doc(&mut self, from: TierId, to: TierId, doc: u64, at: f64) -> Result<()> {
        // the S3 idiom: objects are immutable, a move is COPY + DELETE
        match self.copy_object(from, to, doc) {
            Ok(()) => self.delete_object(from, doc),
            // crash window between journal append and object op: repair
            // by writing a fresh payload at the destination (a COPY that
            // failed for another reason — e.g. an outage — propagates)
            Err(_) if !self.object_path(from, doc).exists() => self.put_object(to, doc, at),
            Err(e) => Err(e),
        }
    }

    fn read_doc(&mut self, tier: TierId, doc: u64) -> Result<()> {
        let bytes = self.get_object(tier, doc)?;
        if !payload_intact(&bytes, doc) {
            bail!("corrupt object {}", self.object_path(tier, doc).display());
        }
        Ok(())
    }

    fn list_docs(&mut self, tier: TierId) -> Result<Vec<u64>> {
        self.list_bucket(tier)
    }

    fn doc_intact(&mut self, tier: TierId, doc: u64) -> bool {
        self.get_object(tier, doc)
            .map(|b| payload_intact(&b, doc))
            .unwrap_or(false)
    }
}

/// A [`StorageBackend`] backed by an S3-style object keyspace (bucket per
/// tier, flat keys, COPY+DELETE migrations) with a manifest log for crash
/// recovery. See the module docs.
///
/// [`StorageBackend`]: super::backend::StorageBackend
pub type ObjectBackend = DurableBackend<ObjectStore>;

impl DurableBackend<ObjectStore> {
    /// Whether `root` already holds a manifest log from a previous backend
    /// instance (the fresh-root guard of the demo/fleet surfaces).
    pub fn has_manifest(root: impl AsRef<Path>) -> bool {
        Self::manifest_path(root).exists()
    }

    /// Where a backend rooted at `root` keeps its manifest log — the
    /// single source of the file name (tests and tooling resolve it here
    /// instead of hardcoding the literal).
    pub fn manifest_path(root: impl AsRef<Path>) -> PathBuf {
        root.as_ref().join(MANIFEST_FILE)
    }

    /// Open (or create) an object backend rooted at `root`, one bucket per
    /// tier. If `root` already holds a manifest log, the accounting state
    /// is rebuilt from it and the buckets are reconciled; the declared
    /// `costs` and `charge_rent` must match the manifest header exactly.
    pub fn open(
        root: impl Into<PathBuf>,
        costs: Vec<crate::cost::PerDocCosts>,
        charge_rent: bool,
    ) -> Result<Self> {
        let root = root.into();
        let manifest = Self::manifest_path(&root);
        open_durable(ObjectStore::new(root), manifest, costs, charge_rent)
    }

    /// Backend root directory (the keyspace host).
    pub fn root(&self) -> &Path {
        &self.store.root
    }

    /// Requests issued so far, by verb (recovery reconciliation included).
    pub fn request_counts(&self) -> RequestCounts {
        self.store.counts
    }

    /// Simulate per-request latency (None = no delay).
    pub fn with_latency(mut self, latency: Option<Duration>) -> Self {
        self.store.latency = latency;
        self
    }

    /// Inject an outage: every request past the first `n` fails, wedging
    /// the backend mid-operation exactly as a real endpoint outage would.
    pub fn with_failure_after(mut self, n: u64) -> Self {
        self.store.fail_after = Some(n);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::super::backend::StorageBackend;
    use super::super::fs::FsBackend;
    use super::super::sim::StorageSim;
    use super::*;
    use crate::cost::PerDocCosts;

    fn scratch(tag: &str) -> PathBuf {
        crate::util::scratch_dir(&format!("obj-{tag}"))
    }

    fn costs() -> Vec<PerDocCosts> {
        vec![
            PerDocCosts { write: 1.0, read: 10.0, rent_window: 100.0 },
            PerDocCosts { write: 2.0, read: 20.0, rent_window: 200.0 },
        ]
    }

    // the canonical parity op sequence, shared with the fs suite
    use crate::util::backends::exercise_mixed_ops as mixed_ops;

    #[test]
    fn object_matches_sim_and_fs_ledgers_exactly() {
        let obj_root = scratch("parity");
        let fs_root = scratch("parity-fs");
        let mut sim: Box<dyn StorageBackend> = Box::new(StorageSim::with_tiers(costs(), true));
        let mut fsb: Box<dyn StorageBackend> =
            Box::new(FsBackend::open(&fs_root, costs(), true).unwrap());
        let mut obj: Box<dyn StorageBackend> =
            Box::new(ObjectBackend::open(&obj_root, costs(), true).unwrap());
        mixed_ops(sim.as_mut());
        mixed_ops(fsb.as_mut());
        mixed_ops(obj.as_mut());
        assert_eq!(obj.backend_name(), "object");
        assert_eq!(obj.ledger().total().to_bits(), sim.ledger().total().to_bits());
        assert_eq!(obj.ledger().total().to_bits(), fsb.ledger().total().to_bits());
        for s in [0, 1] {
            assert_eq!(
                obj.stream_ledger(s).total().to_bits(),
                sim.stream_ledger(s).total().to_bits(),
                "stream {s} ledgers diverge"
            );
        }
        assert_eq!(obj.locate(2), sim.locate(2));
        assert_eq!(obj.resident_count(), sim.resident_count());
        let _ = fs::remove_dir_all(&obj_root);
        let _ = fs::remove_dir_all(&fs_root);
    }

    #[test]
    fn requests_are_counted_per_verb_and_migrations_are_copy_delete() {
        let root = scratch("verbs");
        let mut b = ObjectBackend::open(&root, costs(), false).unwrap();
        assert_eq!(b.request_counts(), RequestCounts::default());
        b.put(7, TierId::A, 0.0).unwrap();
        assert_eq!(b.request_counts().put, 1);
        assert!(root.join("tier-0").join("7.obj").exists());
        b.read(7).unwrap();
        assert_eq!(b.request_counts().get, 1);
        b.migrate_doc(7, TierId::B, 0.5).unwrap();
        let c = b.request_counts();
        assert_eq!((c.copy, c.delete), (1, 1), "a hop is COPY + DELETE");
        assert!(!root.join("tier-0").join("7.obj").exists());
        assert!(root.join("tier-1").join("7.obj").exists());
        b.delete(7, 0.9).unwrap();
        assert_eq!(b.request_counts().delete, 2);
        assert!(!root.join("tier-1").join("7.obj").exists());
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn reopen_rebuilds_from_the_manifest_log() {
        let root = scratch("reopen");
        let total;
        {
            let mut b = ObjectBackend::open(&root, costs(), true).unwrap();
            mixed_ops(&mut b);
            total = b.ledger().total();
            // dropped without clean shutdown: a process kill
        }
        assert!(ObjectBackend::has_manifest(&root));
        let b = ObjectBackend::open(&root, costs(), true).unwrap();
        let rec = b.recovery().expect("reopen must report recovery");
        assert!(rec.ops_replayed >= 8);
        assert_eq!(rec.files_recreated, 0);
        assert_eq!(rec.files_removed, 0);
        assert_eq!(b.ledger().total().to_bits(), total.to_bits());
        assert_eq!(b.locate(2), Some(TierId::B));
        // recovery reconciliation itself issued counted requests
        assert!(b.request_counts().list >= 2, "one LIST per bucket");
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn recovery_reconciles_missing_torn_and_orphan_objects() {
        let root = scratch("reconcile");
        {
            let mut b = ObjectBackend::open(&root, costs(), false).unwrap();
            b.put(1, TierId::A, 0.0).unwrap();
            b.put(2, TierId::B, 0.1).unwrap();
        }
        fs::remove_file(root.join("tier-0").join("1.obj")).unwrap();
        fs::write(root.join("tier-1").join("2.obj"), b"xx").unwrap();
        fs::write(root.join("tier-1").join("99.obj"), b"stray").unwrap();
        let mut b = ObjectBackend::open(&root, costs(), false).unwrap();
        let rec = b.recovery().unwrap().clone();
        assert_eq!(rec.files_recreated, 2, "missing object + torn payload");
        assert_eq!(rec.files_removed, 1);
        assert_eq!(b.read(1).unwrap(), TierId::A);
        assert_eq!(b.read(2).unwrap(), TierId::B);
        assert!(!root.join("tier-1").join("99.obj").exists());
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn injected_outage_wedges_and_reopen_recovers() {
        let root = scratch("outage");
        {
            let mut b = ObjectBackend::open(&root, costs(), false).unwrap().with_failure_after(2);
            b.put(1, TierId::A, 0.0).unwrap();
            b.put(2, TierId::A, 0.1).unwrap();
            // request #3 is refused mid-operation: journaled but not stored
            let err = b.put(3, TierId::A, 0.2).unwrap_err();
            assert!(format!("{err:#}").contains("outage"), "{err:#}");
            // wedged: even previously-fine ops now refuse
            let err = b.read(1).unwrap_err();
            assert!(format!("{err:#}").contains("wedged"), "{err:#}");
        }
        // reopen without the knob: the journal is the source of truth and
        // the missing object is recreated
        let mut b = ObjectBackend::open(&root, costs(), false).unwrap();
        assert!(b.recovery().unwrap().files_recreated >= 1);
        assert_eq!(b.read(3).unwrap(), TierId::A);
        assert_eq!(b.resident_count(), 3);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn latency_knob_delays_requests() {
        let root = scratch("latency");
        let mut b = ObjectBackend::open(&root, costs(), false)
            .unwrap()
            .with_latency(Some(Duration::from_millis(2)));
        let started = std::time::Instant::now();
        for d in 0..5 {
            b.put(d, TierId::A, 0.0).unwrap();
        }
        // 5 PUTs × ≥2ms simulated round-trips
        assert!(started.elapsed() >= Duration::from_millis(10));
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn killed_group_commit_batch_rolls_back_to_the_boundary() {
        let root = scratch("gc-kill");
        {
            let mut b = ObjectBackend::open(&root, costs(), false).unwrap();
            b.set_group_commit(true);
            b.put(1, TierId::A, 0.0).unwrap();
            b.put(2, TierId::A, 0.1).unwrap();
            b.journal_flush().unwrap(); // batch boundary: docs 1 and 2 durable
            b.put(3, TierId::A, 0.2).unwrap(); // buffered only — object already PUT
            assert!(root.join("tier-0").join("3.obj").exists());
            assert_eq!(b.journal_buffered(), 1);
            // SIGKILL stand-in: leak the backend so Drop (the clean-close
            // flush barrier) never runs and the buffered record dies here
            std::mem::forget(b);
        }
        let b = ObjectBackend::open(&root, costs(), false).unwrap();
        let rec = b.recovery().unwrap().clone();
        assert_eq!(rec.ops_replayed, 2, "replay is the batch-boundary prefix");
        assert_eq!(b.locate(3), None, "the unflushed op rolled back");
        assert!(
            rec.files_removed >= 1,
            "the substrate ran ahead of the journal; reconcile removes the orphan"
        );
        assert!(!root.join("tier-0").join("3.obj").exists());
        assert_eq!(b.resident_count(), 2);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn checkpoint_compacts_the_manifest() {
        let root = scratch("ckpt");
        let mut b = ObjectBackend::open(&root, costs(), true).unwrap();
        mixed_ops(&mut b);
        let ops = b.journal_ops();
        assert!(ops >= 8);
        let report = b.checkpoint().unwrap();
        assert_eq!((report.ops_folded, report.ops_after), (ops, 0));
        let total = b.ledger().total();
        drop(b);
        let b = ObjectBackend::open(&root, costs(), true).unwrap();
        let rec = b.recovery().unwrap();
        assert_eq!(rec.checkpoints_loaded, 1);
        assert_eq!(rec.ops_replayed, 0);
        assert_eq!(b.ledger().total().to_bits(), total.to_bits());
        let _ = fs::remove_dir_all(&root);
    }
}
