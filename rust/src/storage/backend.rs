//! The storage abstraction behind the engine: a backend-agnostic view of
//! tiered object storage.
//!
//! [`StorageBackend`] is extracted from the concrete [`StorageSim`] so the
//! placement engine ([`crate::engine`]), the policies
//! ([`crate::policy::PlacementPolicy::on_step`]), and the fleet wrappers
//! all program against a trait instead of the simulator struct. Three
//! implementations share the contract: the simulator (reference), the
//! real-filesystem [`super::fs::FsBackend`] (one directory per tier,
//! documents as files — ADR-003), and the S3-style
//! [`super::object::ObjectBackend`] (bucket per tier, flat object keys,
//! request-counted verbs — ADR-005); the latter two are the same
//! journaled machinery over different substrates
//! ([`super::durable::DurableBackend`]).
//!
//! Contract notes, normative for every implementation:
//!
//! - Tiers are addressed by [`TierId`] with indices `0..num_tiers()`,
//!   ordered hot → cold by convention.
//! - Time is the stream-window fraction `at ∈ [0, 1]`; rent accrues from a
//!   document's write (or last settle) to its delete/migrate/settle.
//! - Every charge lands in the run-wide [`Ledger`]; when an attribution
//!   stream is set, charges for documents owned by stream `s` are mirrored
//!   into `stream_ledger(s)` so `ledger().total() == Σ stream totals`.
//! - `put`/`migrate_doc` must refuse to overfill a capacity-limited tier;
//!   callers degrade or demote explicitly (the arbiter's
//!   degradation-over-rejection rule lives above the backend).

use super::ledger::Ledger;
use super::sim::StorageSim;
use super::tier::{Resident, TierId};
use crate::cost::PerDocCosts;
use anyhow::Result;

/// What a [`StorageBackend::checkpoint`] accomplished.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CheckpointReport {
    /// Journal op records the snapshot made replay-redundant (0 on
    /// memory-only backends, which have no replay history to fold).
    pub ops_folded: u64,
    /// Live documents captured in the snapshot.
    pub live_docs: u64,
    /// Journal op records remaining after compaction (0 when the
    /// compaction completed).
    pub ops_after: u64,
}

/// Backend-agnostic tiered storage, as required by the placement engine.
///
/// Object-safe on purpose: the engine holds `Box<dyn StorageBackend>` and
/// policies receive `&dyn StorageBackend` in
/// [`crate::policy::PlacementPolicy::on_step`].
pub trait StorageBackend: Send {
    /// Implementation name for reports (e.g. `"sim"`).
    fn backend_name(&self) -> String;

    /// Number of tiers, hot → cold.
    fn num_tiers(&self) -> usize;

    // ---- operations --------------------------------------------------------

    /// Write `doc` into `tier` at window fraction `at`, owned by the
    /// current attribution stream. Fails if the tier is at capacity or the
    /// document is already resident.
    fn put(&mut self, doc: u64, tier: TierId, at: f64) -> Result<()>;

    /// Delete (prune) `doc` at window fraction `at`, settling its rent.
    /// Returns the tier it was resident in.
    fn delete(&mut self, doc: u64, at: f64) -> Result<TierId>;

    /// Consumer read of a resident document (does not remove it). Returns
    /// the serving tier.
    fn read(&mut self, doc: u64) -> Result<TierId>;

    /// Move `doc` to `to` at window fraction `at`: settle source rent,
    /// charge a source read + destination write, tag both as migration
    /// hops. Fails if the destination is at capacity.
    fn migrate_doc(&mut self, doc: u64, to: TierId, at: f64) -> Result<()>;

    /// Bulk-migrate every resident of `from` into `to`. Returns the number
    /// of documents moved. All-or-nothing: implementations must pre-check
    /// destination headroom so a doomed bulk migration fails without
    /// moving a single document (residency, rent clocks, and the ledger
    /// stay untouched).
    fn migrate_all(&mut self, from: TierId, to: TierId, at: f64) -> Result<u64>;

    /// Bulk-migrate every resident of `from` *owned by `stream`* into
    /// `to` — the per-stream changeover-demotion batch. Charges must be
    /// identical to the equivalent sequence of `migrate_doc` hops, and
    /// all-or-nothing like `migrate_all` (destination headroom pre-checked
    /// against the batch size). Durable implementations journal the whole
    /// batch as ONE record, so a demotion of S documents costs O(1)
    /// journal writes, not O(S). Returns the number of documents moved.
    fn migrate_stream(&mut self, stream: u64, from: TierId, to: TierId, at: f64) -> Result<u64>;

    /// Settle rent for everything still resident as of window fraction
    /// `at`, resetting the rent clocks (idempotent at a fixed `at`).
    /// Fallible because durable backends journal the settlement.
    fn settle_rent(&mut self, at: f64) -> Result<()>;

    /// Snapshot residency + ledgers into the journal and compact it, so
    /// the replay history (and the journal's size) becomes a function of
    /// live state instead of op count. Accounting is unchanged — a
    /// checkpoint charges nothing. Memory-only backends (the sim) ARE
    /// their own snapshot: the call is a free no-op that reports zero
    /// folded ops.
    fn checkpoint(&mut self) -> Result<CheckpointReport>;

    /// Op records a reopen would replay on top of the latest checkpoint
    /// (0 on memory-only backends and right after a compaction).
    fn journal_ops(&self) -> u64;

    // ---- group commit (ADR-009) --------------------------------------------
    //
    // Default no-ops so memory-only backends (which have no journal and
    // therefore no staleness window) satisfy the contract for free.

    /// Enable/disable group commit: journal op records buffer in a
    /// bounded in-memory batch and reach the log as one framed write
    /// instead of one flush (+fsync) per op. Crash recovery then
    /// replays to a *batch-boundary prefix* of the op stream instead of
    /// the full stream — the bounded staleness window. No-op on
    /// memory-only backends.
    fn set_group_commit(&mut self, _enabled: bool) {}

    /// Forced barrier: durably flush any buffered journal batch now.
    /// Checkpoints, bulk migrations, engine close/drain, and wedges all
    /// force this; after it returns, `journal_buffered() == 0`.
    fn journal_flush(&mut self) -> Result<()> {
        Ok(())
    }

    /// Journal maintenance tick: flush the buffered batch if it hit the
    /// size cap or the age cap. The engine calls this after every
    /// backend-touching observation batch, so buffered ops age out even
    /// on quiet roots.
    fn journal_tick(&mut self) -> Result<()> {
        Ok(())
    }

    /// Op records buffered in memory awaiting a group-commit flush
    /// (always 0 in per-op mode, on memory-only backends, and right
    /// after a barrier).
    fn journal_buffered(&self) -> u64 {
        0
    }

    /// `fsync` journal (and sidecar-style) appends for power-loss
    /// durability, not just process death. No-op on memory-only
    /// backends.
    fn set_sync_writes(&mut self, _sync: bool) {}

    // ---- residency views ---------------------------------------------------

    /// Tier currently holding `doc`, if any.
    fn locate(&self, doc: u64) -> Option<TierId>;

    /// Number of residents of `tier`.
    fn resident_len(&self, tier: TierId) -> usize;

    /// Snapshot of `tier`'s residents, sorted by doc id (deterministic).
    fn residents(&self, tier: TierId) -> Vec<Resident>;

    /// Total resident documents across tiers.
    fn resident_count(&self) -> usize;

    /// The longest-resident document of `tier` (reactive-demotion victim).
    fn oldest_resident(&self, tier: TierId) -> Option<u64>;

    /// Owning stream of a resident document, if any.
    fn owner_of(&self, doc: u64) -> Option<u64>;

    /// Resident documents owned by `stream`, across all tiers, sorted.
    fn docs_of_stream(&self, stream: u64) -> Vec<u64>;

    // ---- capacity ----------------------------------------------------------

    /// Limit `tier` to `capacity` simultaneous residents (None = unbounded).
    fn set_capacity(&mut self, tier: TierId, capacity: Option<usize>);

    /// Capacity limit of `tier` (None = unbounded).
    fn capacity(&self, tier: TierId) -> Option<usize>;

    /// Whether `tier` can accept one more resident.
    fn has_room(&self, tier: TierId) -> bool;

    /// High-water mark of simultaneous residents on `tier`.
    fn peak_occupancy(&self, tier: TierId) -> usize;

    // ---- accounting --------------------------------------------------------

    /// Attribute subsequent writes to `stream` (None = unattributed).
    fn set_attribution(&mut self, stream: Option<u64>);

    /// Install per-tier effective costs for one stream's documents. The
    /// vector length must equal `num_tiers()`.
    fn register_stream(&mut self, stream: u64, costs: Vec<PerDocCosts>) -> Result<()>;

    /// Like [`StorageBackend::register_stream`], with a free-form note
    /// (serve-layer tenancy metadata) attached atomically in the same
    /// journal record — so a crash can never leave a registered stream
    /// whose ownership metadata was lost in a side channel (the ADR-006
    /// open-vs-sidecar attribution race).
    fn register_stream_with_note(
        &mut self,
        stream: u64,
        costs: Vec<PerDocCosts>,
        note: &str,
    ) -> Result<()> {
        self.register_stream(stream, costs)?;
        self.set_stream_note(stream, note);
        Ok(())
    }

    /// Attach/overwrite the free-form note on a registered stream.
    fn set_stream_note(&mut self, _stream: u64, _note: &str) {}

    /// The note attached to `stream`, if any. Durable backends recover
    /// notes from the journal (`reg`/`creg` records).
    fn stream_note(&self, _stream: u64) -> Option<String> {
        None
    }

    /// The run-wide ledger.
    fn ledger(&self) -> &Ledger;

    /// The accumulated ledger of one stream (empty if it never operated).
    fn stream_ledger(&self, stream: u64) -> Ledger;

    /// Every stream id ever registered, sorted ascending. Durable
    /// backends recover these from the journal, so an engine built over a
    /// reopened root can continue the id sequence instead of reissuing
    /// ids that already own documents and ledger lines.
    fn stream_ids(&self) -> Vec<u64>;
}

impl StorageBackend for StorageSim {
    fn backend_name(&self) -> String {
        "sim".into()
    }

    fn num_tiers(&self) -> usize {
        StorageSim::num_tiers(self)
    }

    fn put(&mut self, doc: u64, tier: TierId, at: f64) -> Result<()> {
        StorageSim::put(self, doc, tier, at)
    }

    fn delete(&mut self, doc: u64, at: f64) -> Result<TierId> {
        StorageSim::delete(self, doc, at)
    }

    fn read(&mut self, doc: u64) -> Result<TierId> {
        StorageSim::read(self, doc)
    }

    fn migrate_doc(&mut self, doc: u64, to: TierId, at: f64) -> Result<()> {
        StorageSim::migrate_doc(self, doc, to, at)
    }

    fn migrate_all(&mut self, from: TierId, to: TierId, at: f64) -> Result<u64> {
        StorageSim::migrate_all(self, from, to, at)
    }

    fn migrate_stream(&mut self, stream: u64, from: TierId, to: TierId, at: f64) -> Result<u64> {
        StorageSim::migrate_stream(self, stream, from, to, at)
    }

    fn settle_rent(&mut self, at: f64) -> Result<()> {
        StorageSim::settle_rent(self, at);
        Ok(())
    }

    fn checkpoint(&mut self) -> Result<CheckpointReport> {
        // the in-memory state is its own snapshot; nothing to fold
        Ok(CheckpointReport {
            ops_folded: 0,
            live_docs: StorageSim::resident_count(self) as u64,
            ops_after: 0,
        })
    }

    fn journal_ops(&self) -> u64 {
        0
    }

    fn locate(&self, doc: u64) -> Option<TierId> {
        StorageSim::locate(self, doc)
    }

    fn resident_len(&self, tier: TierId) -> usize {
        self.tier(tier).len()
    }

    fn residents(&self, tier: TierId) -> Vec<Resident> {
        let t = self.tier(tier);
        let mut v: Vec<Resident> = t.docs().iter().map(|d| *t.get(*d).unwrap()).collect();
        v.sort_by_key(|r| r.doc);
        v
    }

    fn resident_count(&self) -> usize {
        StorageSim::resident_count(self)
    }

    fn oldest_resident(&self, tier: TierId) -> Option<u64> {
        StorageSim::oldest_resident(self, tier)
    }

    fn owner_of(&self, doc: u64) -> Option<u64> {
        StorageSim::owner_of(self, doc)
    }

    fn docs_of_stream(&self, stream: u64) -> Vec<u64> {
        StorageSim::docs_of_stream(self, stream)
    }

    fn set_capacity(&mut self, tier: TierId, capacity: Option<usize>) {
        StorageSim::set_capacity(self, tier, capacity)
    }

    fn capacity(&self, tier: TierId) -> Option<usize> {
        self.tier(tier).capacity()
    }

    fn has_room(&self, tier: TierId) -> bool {
        StorageSim::has_room(self, tier)
    }

    fn peak_occupancy(&self, tier: TierId) -> usize {
        StorageSim::peak_occupancy(self, tier)
    }

    fn set_attribution(&mut self, stream: Option<u64>) {
        StorageSim::set_attribution(self, stream)
    }

    fn register_stream(&mut self, stream: u64, costs: Vec<PerDocCosts>) -> Result<()> {
        StorageSim::register_stream(self, stream, costs)
    }

    fn set_stream_note(&mut self, stream: u64, note: &str) {
        StorageSim::set_stream_note(self, stream, note.to_string())
    }

    fn stream_note(&self, stream: u64) -> Option<String> {
        StorageSim::stream_note(self, stream).map(str::to_string)
    }

    fn ledger(&self) -> &Ledger {
        StorageSim::ledger(self)
    }

    fn stream_ledger(&self, stream: u64) -> Ledger {
        StorageSim::stream_ledger(self, stream)
    }

    fn stream_ids(&self) -> Vec<u64> {
        StorageSim::stream_ids(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> StorageSim {
        StorageSim::two_tier(
            PerDocCosts { write: 1.0, read: 2.0, rent_window: 3.0 },
            PerDocCosts { write: 2.0, read: 1.0, rent_window: 1.0 },
            true,
        )
    }

    #[test]
    fn sim_implements_backend_roundtrip() {
        let mut b: Box<dyn StorageBackend> = Box::new(sim());
        assert_eq!(b.backend_name(), "sim");
        assert_eq!(b.num_tiers(), 2);
        b.set_attribution(Some(3));
        b.put(1, TierId::A, 0.0).unwrap();
        b.put(2, TierId::B, 0.1).unwrap();
        assert_eq!(b.locate(1), Some(TierId::A));
        assert_eq!(b.resident_len(TierId::A), 1);
        assert_eq!(b.resident_count(), 2);
        assert_eq!(b.owner_of(2), Some(3));
        assert_eq!(b.docs_of_stream(3), vec![1, 2]);
        let rs = b.residents(TierId::A);
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].doc, 1);
        assert_eq!(b.read(1).unwrap(), TierId::A);
        b.migrate_doc(1, TierId::B, 0.5).unwrap();
        assert_eq!(b.locate(1), Some(TierId::B));
        b.settle_rent(1.0).unwrap();
        assert!(b.ledger().total() > 0.0);
        assert!((b.ledger().total() - b.stream_ledger(3).total()).abs() < 1e-12);
        assert_eq!(b.delete(1, 1.0).unwrap(), TierId::B);
    }

    #[test]
    fn backend_capacity_view() {
        let mut b: Box<dyn StorageBackend> = Box::new(sim());
        assert_eq!(b.capacity(TierId::A), None);
        b.set_capacity(TierId::A, Some(1));
        assert_eq!(b.capacity(TierId::A), Some(1));
        assert!(b.has_room(TierId::A));
        b.put(7, TierId::A, 0.0).unwrap();
        assert!(!b.has_room(TierId::A));
        assert!(b.put(8, TierId::A, 0.0).is_err());
        assert_eq!(b.peak_occupancy(TierId::A), 1);
        assert_eq!(b.oldest_resident(TierId::A), Some(7));
    }

    #[test]
    fn sim_checkpoint_is_a_free_noop_with_no_journal() {
        let mut b: Box<dyn StorageBackend> = Box::new(sim());
        b.set_attribution(Some(2));
        b.put(1, TierId::A, 0.0).unwrap();
        b.put(2, TierId::B, 0.1).unwrap();
        assert_eq!(b.journal_ops(), 0, "memory-only: no replay history");
        let before = b.ledger().total();
        let report = b.checkpoint().unwrap();
        assert_eq!(report, CheckpointReport { ops_folded: 0, live_docs: 2, ops_after: 0 });
        assert_eq!(b.ledger().total(), before, "a checkpoint charges nothing");
    }

    #[test]
    fn sim_group_commit_hooks_are_free_noops() {
        let mut b: Box<dyn StorageBackend> = Box::new(sim());
        b.set_group_commit(true);
        b.set_sync_writes(true);
        b.put(1, TierId::A, 0.0).unwrap();
        assert_eq!(b.journal_buffered(), 0, "memory-only: nothing ever buffers");
        b.journal_tick().unwrap();
        b.journal_flush().unwrap();
        assert_eq!(b.journal_ops(), 0);
    }

    #[test]
    fn stream_notes_ride_registration_through_the_trait() {
        let mut b: Box<dyn StorageBackend> = Box::new(sim());
        let costs = vec![
            PerDocCosts { write: 1.0, read: 2.0, rent_window: 3.0 },
            PerDocCosts { write: 2.0, read: 1.0, rent_window: 1.0 },
        ];
        b.register_stream_with_note(4, costs, "tenant=acme").unwrap();
        assert_eq!(b.stream_note(4).as_deref(), Some("tenant=acme"));
        assert_eq!(b.stream_note(5), None);
        b.set_stream_note(4, "tenant=beta");
        assert_eq!(b.stream_note(4).as_deref(), Some("tenant=beta"));
    }

    #[test]
    fn sim_migrate_stream_through_the_trait() {
        let mut b: Box<dyn StorageBackend> = Box::new(sim());
        b.set_attribution(Some(5));
        b.put(1, TierId::A, 0.0).unwrap();
        b.put(2, TierId::A, 0.1).unwrap();
        b.set_attribution(Some(6));
        b.put(3, TierId::A, 0.2).unwrap();
        assert_eq!(b.migrate_stream(5, TierId::A, TierId::B, 0.5).unwrap(), 2);
        assert_eq!(b.locate(3), Some(TierId::A));
        assert_eq!(b.docs_of_stream(5), vec![1, 2]);
        assert_eq!(b.resident_len(TierId::B), 2);
    }
}
