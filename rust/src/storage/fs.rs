//! `storage::fs` — the real-filesystem [`StorageBackend`] (ADR-003).
//!
//! Where [`StorageSim`] only pretends to move bytes, `FsBackend` places
//! real files on real directories: one directory per tier (point them at
//! tmpfs-vs-disk roots to get genuinely different media), one file per
//! resident document, capacity enforced by resident count, and rent /
//! transport charges computed from the same [`PerDocCosts`] tables as the
//! simulator.
//!
//! Since ADR-005 the backend is an instantiation of the shared
//! [`DurableBackend`] machinery: [`FsStore`] supplies the file substrate
//! (write/rename/remove under `<root>/tier-<i>/<doc>.doc`), and the
//! journaling, checkpoint/compaction, crash recovery, and
//! wedge-on-failure semantics live in [`super::durable`] /
//! [`super::journal`] — shared verbatim with the object-store backend.
//! The write-ahead journal lives at `<root>/journal.log`.
//!
//! [`StorageSim`]: super::sim::StorageSim
//! [`PerDocCosts`]: crate::cost::PerDocCosts

use super::durable::{
    doc_payload, open_durable, payload_intact, scan_keys, DocStore, DurableBackend,
};
use anyhow::{bail, Context, Result};
use std::fs;
use std::path::{Path, PathBuf};

use super::tier::TierId;

const JOURNAL_FILE: &str = "journal.log";

fn write_doc_file(path: &Path, doc: u64, at: f64) -> std::io::Result<()> {
    fs::write(path, doc_payload(doc, at))
}

/// The filesystem substrate: one directory per tier, one `<doc>.doc` file
/// per resident, migrations as renames.
pub struct FsStore {
    root: PathBuf,
}

impl FsStore {
    fn tier_dir(&self, tier: TierId) -> PathBuf {
        self.root.join(format!("tier-{}", tier.0))
    }

    fn doc_path(&self, tier: TierId, doc: u64) -> PathBuf {
        self.tier_dir(tier).join(format!("{doc}.doc"))
    }
}

impl DocStore for FsStore {
    fn name(&self) -> &'static str {
        "fs"
    }

    fn prepare(&mut self, tiers: usize) -> Result<()> {
        fs::create_dir_all(&self.root)
            .with_context(|| format!("creating backend root {}", self.root.display()))?;
        for i in 0..tiers {
            let dir = self.tier_dir(TierId(i));
            fs::create_dir_all(&dir)
                .with_context(|| format!("creating tier directory {}", dir.display()))?;
        }
        Ok(())
    }

    fn write_doc(&mut self, tier: TierId, doc: u64, at: f64) -> Result<()> {
        let path = self.doc_path(tier, doc);
        write_doc_file(&path, doc, at)
            .with_context(|| format!("writing {}", path.display()))
    }

    fn remove_doc(&mut self, tier: TierId, doc: u64) -> Result<()> {
        let path = self.doc_path(tier, doc);
        match fs::remove_file(&path) {
            // already gone: a crash window earlier never materialized it
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            res => res.with_context(|| format!("removing {}", path.display())),
        }
    }

    fn move_doc(&mut self, from: TierId, to: TierId, doc: u64, at: f64) -> Result<()> {
        let src = self.doc_path(from, doc);
        let dst = self.doc_path(to, doc);
        match fs::rename(&src, &dst) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                // crash window between journal append and file op: repair
                // by recreating the payload at the destination
                write_doc_file(&dst, doc, at)
                    .with_context(|| format!("recreating migrated file {}", dst.display()))
            }
            Err(e) => {
                Err(e).with_context(|| format!("moving {} to {}", src.display(), dst.display()))
            }
        }
    }

    fn read_doc(&mut self, tier: TierId, doc: u64) -> Result<()> {
        let path = self.doc_path(tier, doc);
        let bytes =
            fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        if !payload_intact(&bytes, doc) {
            bail!("corrupt document file {}", path.display());
        }
        Ok(())
    }

    fn list_docs(&mut self, tier: TierId) -> Result<Vec<u64>> {
        scan_keys(&self.tier_dir(tier), ".doc")
    }

    fn doc_intact(&mut self, tier: TierId, doc: u64) -> bool {
        fs::read(self.doc_path(tier, doc))
            .map(|b| payload_intact(&b, doc))
            .unwrap_or(false)
    }
}

/// A [`StorageBackend`] backed by real directories and files, with a
/// write-ahead journal for crash recovery.
///
/// [`StorageBackend`]: super::backend::StorageBackend
pub type FsBackend = DurableBackend<FsStore>;

impl DurableBackend<FsStore> {
    /// Whether `root` already holds a write-ahead journal from a previous
    /// backend instance. The fresh-root guards of the demo/fleet surfaces
    /// use this (their stream and document ids restart at 0, so journaled
    /// residents from an earlier run would collide).
    pub fn has_journal(root: impl AsRef<Path>) -> bool {
        Self::journal_path(root).exists()
    }

    /// Where a backend rooted at `root` keeps its write-ahead journal —
    /// the single source of the file name (tests and tooling resolve it
    /// here instead of hardcoding the literal).
    pub fn journal_path(root: impl AsRef<Path>) -> PathBuf {
        root.as_ref().join(JOURNAL_FILE)
    }

    /// Open (or create) a backend rooted at `root` with one directory per
    /// tier. If `root` already holds a journal, the accounting state is
    /// rebuilt from it and the document files are reconciled; the declared
    /// `costs` and `charge_rent` must match the journal header exactly.
    pub fn open(
        root: impl Into<PathBuf>,
        costs: Vec<crate::cost::PerDocCosts>,
        charge_rent: bool,
    ) -> Result<Self> {
        let root = root.into();
        let journal_path = Self::journal_path(&root);
        open_durable(FsStore { root }, journal_path, costs, charge_rent)
    }

    /// Backend root directory.
    pub fn root(&self) -> &Path {
        &self.store.root
    }
}

#[cfg(test)]
mod tests {
    use super::super::backend::StorageBackend;
    use super::super::sim::StorageSim;
    use super::*;
    use crate::cost::PerDocCosts;
    use std::fs::OpenOptions;
    use std::io::Write;

    fn scratch(tag: &str) -> PathBuf {
        crate::util::scratch_dir(&format!("fs-{tag}"))
    }

    fn costs() -> Vec<PerDocCosts> {
        vec![
            PerDocCosts { write: 1.0, read: 10.0, rent_window: 100.0 },
            PerDocCosts { write: 2.0, read: 20.0, rent_window: 200.0 },
        ]
    }

    fn ledgers_equal(a: &super::super::Ledger, b: &super::super::Ledger) -> bool {
        (a.total() - b.total()).abs() < 1e-12
            && a.total_writes() == b.total_writes()
            && a.total_reads() == b.total_reads()
            && (a.migration_total() - b.migration_total()).abs() < 1e-12
    }

    // the canonical parity op sequence, shared with the object suite
    use crate::util::backends::exercise_mixed_ops as mixed_ops;

    #[test]
    fn fs_matches_sim_ledger_exactly() {
        let root = scratch("parity");
        let mut sim: Box<dyn StorageBackend> = Box::new(StorageSim::with_tiers(costs(), true));
        let mut fsb: Box<dyn StorageBackend> =
            Box::new(FsBackend::open(&root, costs(), true).unwrap());
        mixed_ops(sim.as_mut());
        mixed_ops(fsb.as_mut());
        assert_eq!(fsb.backend_name(), "fs");
        assert!(ledgers_equal(sim.ledger(), fsb.ledger()));
        for s in [0, 1] {
            assert!(
                ledgers_equal(&sim.stream_ledger(s), &fsb.stream_ledger(s)),
                "stream {s} ledgers diverge"
            );
        }
        assert_eq!(sim.locate(1), fsb.locate(1));
        assert_eq!(sim.locate(2), fsb.locate(2));
        assert_eq!(sim.resident_count(), fsb.resident_count());
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn documents_are_real_files_that_follow_migrations() {
        let root = scratch("files");
        let mut b = FsBackend::open(&root, costs(), false).unwrap();
        b.put(7, TierId::A, 0.0).unwrap();
        assert!(root.join("tier-0").join("7.doc").exists());
        b.migrate_doc(7, TierId::B, 0.5).unwrap();
        assert!(!root.join("tier-0").join("7.doc").exists());
        assert!(root.join("tier-1").join("7.doc").exists());
        b.delete(7, 0.9).unwrap();
        assert!(!root.join("tier-1").join("7.doc").exists());
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn reopen_rebuilds_residency_and_ledger_from_journal() {
        let root = scratch("reopen");
        let total;
        let stream0;
        let residents_a;
        {
            let mut b = FsBackend::open(&root, costs(), true).unwrap();
            mixed_ops(&mut b);
            total = b.ledger().total();
            stream0 = b.stream_ledger(0).total();
            residents_a = b.residents(TierId::A);
            // dropped without any clean-shutdown step: the journal is all
            // that survives a kill
        }
        let b = FsBackend::open(&root, costs(), true).unwrap();
        let rec = b.recovery().expect("reopen must report recovery").clone();
        assert!(rec.ops_replayed >= 8, "replayed {} ops", rec.ops_replayed);
        assert_eq!(rec.files_recreated, 0);
        assert_eq!(rec.files_removed, 0);
        assert!(!rec.truncated_tail);
        assert!((b.ledger().total() - total).abs() < 1e-12);
        assert!((b.stream_ledger(0).total() - stream0).abs() < 1e-12);
        assert_eq!(b.residents(TierId::A), residents_a);
        assert_eq!(b.locate(2), Some(TierId::B));
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn recovery_reconciles_missing_and_orphan_files() {
        let root = scratch("reconcile");
        {
            let mut b = FsBackend::open(&root, costs(), false).unwrap();
            b.put(1, TierId::A, 0.0).unwrap();
            b.put(2, TierId::B, 0.1).unwrap();
        }
        // simulate crash windows: resident 1's file vanished, resident 2's
        // payload was torn mid-write, and an unjournaled stray appeared
        fs::remove_file(root.join("tier-0").join("1.doc")).unwrap();
        fs::write(root.join("tier-1").join("2.doc"), b"xx").unwrap();
        write_doc_file(&root.join("tier-1").join("99.doc"), 99, 0.5).unwrap();
        let mut b = FsBackend::open(&root, costs(), false).unwrap();
        let rec = b.recovery().unwrap().clone();
        assert_eq!(rec.files_recreated, 2, "missing file + torn payload");
        assert_eq!(rec.files_removed, 1);
        assert!(root.join("tier-0").join("1.doc").exists());
        assert!(!root.join("tier-1").join("99.doc").exists());
        // the repaired files serve reads again
        assert_eq!(b.read(1).unwrap(), TierId::A);
        assert_eq!(b.read(2).unwrap(), TierId::B);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn torn_journal_tail_is_dropped() {
        let root = scratch("torn");
        {
            let mut b = FsBackend::open(&root, costs(), false).unwrap();
            b.put(1, TierId::A, 0.0).unwrap();
            b.put(2, TierId::A, 0.1).unwrap();
        }
        // simulate a torn append: a partial line with no newline
        let mut f = OpenOptions::new()
            .append(true)
            .open(root.join(JOURNAL_FILE))
            .unwrap();
        f.write_all(b"put 3 0 3fb99999").unwrap();
        drop(f);
        let mut b = FsBackend::open(&root, costs(), false).unwrap();
        let rec = b.recovery().unwrap().clone();
        assert!(rec.truncated_tail);
        assert_eq!(rec.ops_replayed, 2);
        assert_eq!(b.locate(3), None);
        // appends after recovery land on a clean line
        b.put(4, TierId::B, 0.5).unwrap();
        drop(b);
        let b = FsBackend::open(&root, costs(), false).unwrap();
        assert_eq!(b.locate(4), Some(TierId::B));
        assert!(!b.recovery().unwrap().truncated_tail);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn torn_journal_header_is_healed_not_bricked() {
        let root = scratch("torn-header");
        fs::create_dir_all(&root).unwrap();
        // a kill mid-creation: partial header, no newline
        fs::write(root.join(JOURNAL_FILE), "shptier-fs v1 ren").unwrap();
        let mut b = FsBackend::open(&root, costs(), false).unwrap();
        let rec = b.recovery().unwrap().clone();
        assert!(rec.truncated_tail);
        assert_eq!(rec.ops_replayed, 0);
        b.put(1, TierId::A, 0.0).unwrap();
        drop(b);
        // the healed journal round-trips
        let b = FsBackend::open(&root, costs(), false).unwrap();
        assert_eq!(b.locate(1), Some(TierId::A));
        assert_eq!(b.recovery().unwrap().ops_replayed, 1);
        let _ = fs::remove_dir_all(&root);

        // an empty journal file (created, header never written) heals too
        let root = scratch("empty-journal");
        fs::create_dir_all(&root).unwrap();
        fs::write(root.join(JOURNAL_FILE), "").unwrap();
        let b = FsBackend::open(&root, costs(), false).unwrap();
        assert!(b.recovery().unwrap().truncated_tail);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn doomed_migrate_all_is_a_noop_on_fs() {
        let root = scratch("migall");
        let mut b = FsBackend::open(&root, costs(), true).unwrap();
        for d in 0..4 {
            b.put(d, TierId::A, 0.1).unwrap();
        }
        b.set_capacity(TierId::B, Some(2));
        let before = b.ledger().total();
        assert!(b.migrate_all(TierId::A, TierId::B, 0.5).is_err());
        assert_eq!(b.resident_len(TierId::A), 4);
        assert_eq!(b.ledger().total(), before);
        for d in 0..4u64 {
            assert!(root.join("tier-0").join(format!("{d}.doc")).exists());
        }
        // and the failed attempt was not journaled: a reopen agrees
        drop(b);
        let b = FsBackend::open(&root, costs(), true).unwrap();
        assert_eq!(b.resident_len(TierId::A), 4);
        assert_eq!(b.resident_len(TierId::B), 0);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn reopen_with_different_economics_is_rejected() {
        let root = scratch("econ");
        drop(FsBackend::open(&root, costs(), true).unwrap());
        let mut other = costs();
        other[0].write = 9.0;
        assert!(FsBackend::open(&root, other, true).is_err());
        assert!(FsBackend::open(&root, costs(), false).is_err(), "rent flag is economics too");
        assert!(FsBackend::open(&root, costs(), true).is_ok());
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn capacity_is_runtime_config_not_durable_state() {
        let root = scratch("cap");
        {
            let mut b = FsBackend::open(&root, costs(), false).unwrap();
            b.set_capacity(TierId::A, Some(1));
            b.put(1, TierId::A, 0.0).unwrap();
            assert!(b.put(2, TierId::A, 0.1).is_err());
        }
        let mut b = FsBackend::open(&root, costs(), false).unwrap();
        // unbounded until the caller re-applies the topology
        assert_eq!(b.capacity(TierId::A), None);
        b.set_capacity(TierId::A, Some(1));
        assert!(!b.has_room(TierId::A));
        let _ = fs::remove_dir_all(&root);
    }

    fn journal_op_lines(root: &Path) -> Vec<String> {
        fs::read_to_string(root.join(JOURNAL_FILE))
            .unwrap()
            .lines()
            .skip(1) // header
            .map(String::from)
            .collect()
    }

    #[test]
    fn migrate_stream_journals_one_record_per_batch() {
        let root = scratch("migstream");
        let mut b = FsBackend::open(&root, costs(), false).unwrap();
        b.set_attribution(Some(7));
        for d in 0..6 {
            b.put(d, TierId::A, 0.1).unwrap();
        }
        b.set_attribution(Some(8));
        b.put(100, TierId::A, 0.1).unwrap();
        let ops_before = b.journal_ops();
        assert_eq!(b.migrate_stream(7, TierId::A, TierId::B, 0.5).unwrap(), 6);
        assert_eq!(b.journal_ops(), ops_before + 1, "one record for six documents");
        let last = journal_op_lines(&root).pop().unwrap();
        assert!(last.starts_with("migstream 7 0 1 "), "{last}");
        // only stream 7's documents moved, files followed
        assert_eq!(b.resident_len(TierId::A), 1);
        assert_eq!(b.resident_len(TierId::B), 6);
        assert!(root.join("tier-0").join("100.doc").exists());
        assert!(root.join("tier-1").join("3.doc").exists());
        // a kill-and-reopen replays the batch from the single record
        drop(b);
        let b = FsBackend::open(&root, costs(), false).unwrap();
        assert_eq!(b.resident_len(TierId::B), 6);
        assert_eq!(b.owner_of(100), Some(8));
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn doomed_migrate_stream_is_a_noop() {
        let root = scratch("migstream-doomed");
        let mut b = FsBackend::open(&root, costs(), true).unwrap();
        b.set_attribution(Some(1));
        for d in 0..4 {
            b.put(d, TierId::A, 0.1).unwrap();
        }
        b.set_capacity(TierId::B, Some(2));
        let before = b.ledger().total();
        let ops = b.journal_ops();
        assert!(b.migrate_stream(1, TierId::A, TierId::B, 0.5).is_err());
        assert_eq!(b.resident_len(TierId::A), 4, "all-or-nothing");
        assert_eq!(b.ledger().total(), before);
        assert_eq!(b.journal_ops(), ops, "a doomed batch is not journaled");
        // a stream with no residents in the source is an empty batch
        assert_eq!(b.migrate_stream(9, TierId::A, TierId::B, 0.5).unwrap(), 0);
        assert_eq!(b.journal_ops(), ops);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn group_commit_batches_appends_and_barriers_flush() {
        let root = scratch("group-commit");
        {
            let mut b = FsBackend::open(&root, costs(), false).unwrap();
            b.set_group_commit(true);
            for d in 0..5 {
                b.put(d, TierId::A, 0.1).unwrap();
            }
            assert_eq!(b.journal_buffered(), 5);
            assert_eq!(b.journal_ops(), 5, "ops() counts buffered records");
            assert_eq!(
                journal_op_lines(&root).len(),
                0,
                "nothing durable before the flush"
            );
            b.journal_flush().unwrap();
            assert_eq!(b.journal_buffered(), 0);
            let lines = journal_op_lines(&root);
            assert_eq!(lines[0], "batch 5", "ops land framed, not bare");
            assert_eq!(lines.len(), 6);
            // bulk migration is a forced barrier: its record (and anything
            // buffered before it) is durable before any file moves
            b.set_attribution(Some(3));
            b.put(50, TierId::A, 0.2).unwrap();
            assert_eq!(b.migrate_stream(3, TierId::A, TierId::B, 0.5).unwrap(), 1);
            assert_eq!(b.journal_buffered(), 0, "migrate_stream flushed the batch");
            // dropped here: a clean close is a barrier too (Journal::drop)
        }
        let b = FsBackend::open(&root, costs(), false).unwrap();
        assert_eq!(b.resident_count(), 6);
        assert_eq!(b.locate(50), Some(TierId::B));
        assert!(!b.recovery().unwrap().truncated_tail);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn checkpoint_compacts_and_reopen_replays_suffix() {
        let root = scratch("ckpt");
        let total;
        let stream0;
        {
            let mut b = FsBackend::open(&root, costs(), true).unwrap();
            mixed_ops(&mut b);
            let ops = b.journal_ops();
            assert!(ops >= 8);
            let report = b.checkpoint().unwrap();
            assert_eq!(report.ops_folded, ops);
            assert_eq!(report.ops_after, 0);
            assert_eq!(report.live_docs, b.resident_count() as u64);
            assert_eq!(b.journal_ops(), 0);
            // post-checkpoint ops form the replay suffix
            b.put(50, TierId::A, 0.7).unwrap();
            b.read(50).unwrap();
            assert_eq!(b.journal_ops(), 2);
            total = b.ledger().total();
            stream0 = b.stream_ledger(0).total();
            // killed here
        }
        let b = FsBackend::open(&root, costs(), true).unwrap();
        let rec = b.recovery().unwrap().clone();
        assert_eq!(rec.checkpoints_loaded, 1);
        assert_eq!(rec.ops_replayed, 2, "only the suffix replays");
        assert!((b.ledger().total() - total).abs() < 1e-12);
        assert!((b.stream_ledger(0).total() - stream0).abs() < 1e-12);
        assert_eq!(b.locate(50), Some(TierId::A));
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn checkpointed_journal_size_tracks_live_state_not_op_count() {
        let root = scratch("ckpt-size");
        let mut b = FsBackend::open(&root, costs(), false).unwrap();
        // churn: many ops, tiny live state
        for round in 0..50u64 {
            b.put(round, TierId::A, 0.0).unwrap();
            b.migrate_doc(round, TierId::B, 0.4).unwrap();
            b.delete(round, 0.8).unwrap();
        }
        b.put(1000, TierId::A, 0.9).unwrap();
        b.checkpoint().unwrap();
        let lines = fs::read_to_string(root.join(JOURNAL_FILE)).unwrap().lines().count();
        // header + begin/end + 1 cdoc + ledger rows (2 tiers) + peaks (2)
        assert!(lines <= 10, "compacted journal has {lines} lines");
        drop(b);
        let b = FsBackend::open(&root, costs(), false).unwrap();
        assert_eq!(b.locate(1000), Some(TierId::A));
        assert_eq!(b.resident_count(), 1);
        let _ = fs::remove_dir_all(&root);
    }
}
