//! `storage::fs` — the real-filesystem [`StorageBackend`] (ADR-003).
//!
//! Where [`StorageSim`] only pretends to move bytes, `FsBackend` places
//! real files on real directories: one directory per tier (point them at
//! tmpfs-vs-disk roots to get genuinely different media), one file per
//! resident document, capacity enforced by resident count, and rent /
//! transport charges computed from the same [`PerDocCosts`] tables as the
//! simulator.
//!
//! ## Design: one accounting state machine, two substrates
//!
//! The backend delegates *all* residency bookkeeping and charge accounting
//! to an inner [`StorageSim`] — the exact code path the simulator runs —
//! and layers real file IO plus a durable write-ahead journal on top. This
//! makes ledger parity between `sim` and `fs` structural rather than
//! coincidental: the reconciliation harness
//! ([`crate::engine::demo::reconcile_backends`]) asserts it end-to-end.
//!
//! ## Write-ahead journal and crash recovery
//!
//! Every state-changing operation appends one line to `<root>/journal.log`
//! *before* touching any document file:
//!
//! ```text
//! shptier-fs v1 rent=<0|1> costs=<w:r:rw,...>      # header (f64 hex bits)
//! put <doc> <tier> <at-bits> <owner|->
//! del <doc> <at-bits>
//! read <doc>
//! mig <doc> <to> <at-bits>
//! migall <from> <to> <at-bits>
//! settle <at-bits>
//! reg <stream> <w:r:rw,...>
//! ```
//!
//! Window fractions and costs are encoded as hexadecimal `f64::to_bits`,
//! so replay is bit-exact. [`FsBackend::open`] on a root with an existing
//! journal replays it into a fresh accounting state (`locate` /
//! `residents` / ledger totals are rebuilt exactly), drops a torn trailing
//! line if the process died mid-append, and then reconciles the document
//! files against the replayed residency — recreating missing files and
//! removing orphans. Capacities and the ambient attribution stream are
//! *runtime* configuration, not durable state: callers (the engine
//! builder) re-apply them after open, exactly as they do for a fresh
//! simulator.
//!
//! If a journal append or file operation fails mid-run the backend wedges:
//! every subsequent operation errors until the backend is reopened from
//! the journal, which restores the invariant that the journal is the
//! single source of truth.

use super::backend::StorageBackend;
use super::ledger::Ledger;
use super::sim::StorageSim;
use super::tier::{Resident, TierId};
use crate::cost::PerDocCosts;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

const JOURNAL_FILE: &str = "journal.log";
const JOURNAL_MAGIC: &str = "shptier-fs";
const JOURNAL_VERSION: u32 = 1;

/// What [`FsBackend::open`] rebuilt from a pre-existing journal.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RecoveryReport {
    /// Journal operations replayed into the accounting state.
    pub ops_replayed: u64,
    /// Resident document files that were missing on disk and recreated.
    pub files_recreated: u64,
    /// On-disk document files with no resident backing them, removed.
    pub files_removed: u64,
    /// Whether a torn (partially written) trailing line was dropped.
    pub truncated_tail: bool,
}

/// A [`StorageBackend`] backed by real directories and files, with a
/// write-ahead journal for crash recovery. See the module docs for the
/// layout and the durability contract.
pub struct FsBackend {
    root: PathBuf,
    /// The accounting + residency state machine (same code as the sim).
    state: StorageSim,
    journal: BufWriter<File>,
    costs: Vec<PerDocCosts>,
    /// Mirror of the sim's ambient attribution (journaled per `put`).
    attribution: Option<u64>,
    /// `fsync` the journal on every append (durable against power loss,
    /// not just process death). Off by default: process-death durability
    /// only needs the flush.
    sync_writes: bool,
    /// Set on a failed journal append / file op: the in-memory state and
    /// the durable record may disagree, so all further ops are refused.
    wedged: Option<String>,
    recovery: Option<RecoveryReport>,
}

impl FsBackend {
    /// Whether `root` already holds a write-ahead journal from a previous
    /// backend instance. The fresh-root guards of the demo/fleet surfaces
    /// use this (their stream and document ids restart at 0, so journaled
    /// residents from an earlier run would collide).
    pub fn has_journal(root: impl AsRef<Path>) -> bool {
        root.as_ref().join(JOURNAL_FILE).exists()
    }

    /// Open (or create) a backend rooted at `root` with one directory per
    /// tier. If `root` already holds a journal, the accounting state is
    /// rebuilt from it and the document files are reconciled; the declared
    /// `costs` and `charge_rent` must match the journal header exactly.
    pub fn open(
        root: impl Into<PathBuf>,
        costs: Vec<PerDocCosts>,
        charge_rent: bool,
    ) -> Result<Self> {
        let root = root.into();
        if costs.len() < 2 {
            bail!("fs backend needs at least two tiers (got {})", costs.len());
        }
        fs::create_dir_all(&root)
            .with_context(|| format!("creating backend root {}", root.display()))?;
        for i in 0..costs.len() {
            let dir = root.join(format!("tier-{i}"));
            fs::create_dir_all(&dir)
                .with_context(|| format!("creating tier directory {}", dir.display()))?;
        }
        let journal_path = root.join(JOURNAL_FILE);
        let (state, recovery, journal) = if journal_path.exists() {
            recover(&root, &journal_path, &costs, charge_rent)?
        } else {
            let mut file = File::create(&journal_path)
                .with_context(|| format!("creating journal {}", journal_path.display()))?;
            file.write_all(header_line(&costs, charge_rent).as_bytes())
                .context("writing journal header")?;
            (StorageSim::with_tiers(costs.clone(), charge_rent), None, file)
        };
        Ok(Self {
            root,
            state,
            journal: BufWriter::new(journal),
            costs,
            attribution: None,
            sync_writes: false,
            wedged: None,
            recovery,
        })
    }

    /// `fsync` the journal on every append (power-loss durability).
    pub fn with_sync(mut self, sync: bool) -> Self {
        self.sync_writes = sync;
        self
    }

    /// Backend root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// The recovery report, if this backend was opened over an existing
    /// journal (None on a fresh root).
    pub fn recovery(&self) -> Option<&RecoveryReport> {
        self.recovery.as_ref()
    }

    /// Declared per-tier cost tables (the journal-header economics).
    pub fn tier_costs(&self) -> &[PerDocCosts] {
        &self.costs
    }

    fn doc_path(&self, tier: TierId, doc: u64) -> PathBuf {
        self.root.join(format!("tier-{}", tier.0)).join(format!("{doc}.doc"))
    }

    fn ensure_live(&self) -> Result<()> {
        if let Some(why) = &self.wedged {
            bail!("fs backend is wedged ({why}) — reopen from the journal to recover");
        }
        Ok(())
    }

    /// Append one journal line (flushing, optionally fsyncing). A failure
    /// wedges the backend: the applied state is no longer durably
    /// recorded.
    fn append(&mut self, line: String) -> Result<()> {
        let res = (|| -> Result<()> {
            self.journal.write_all(line.as_bytes())?;
            self.journal.write_all(b"\n")?;
            self.journal.flush()?;
            if self.sync_writes {
                self.journal.get_ref().sync_data()?;
            }
            Ok(())
        })();
        if let Err(e) = &res {
            self.wedged = Some(format!("journal append failed: {e:#}"));
        }
        res
    }

    /// Run a document-file operation, wedging the backend on failure (the
    /// journal already records the op, so only a reopen can reconcile).
    fn file_op(&mut self, res: std::io::Result<()>, what: &str) -> Result<()> {
        match res {
            Ok(()) => Ok(()),
            Err(e) => {
                self.wedged = Some(format!("{what}: {e}"));
                bail!("{what}: {e} (backend wedged; reopen to recover from the journal)");
            }
        }
    }

    /// Move a document file between tier directories. A missing source
    /// (crash window between journal append and file op) is repaired by
    /// recreating the file at the destination.
    fn move_doc_file(&mut self, from: TierId, to: TierId, doc: u64, at: f64) -> Result<()> {
        let src = self.doc_path(from, doc);
        let dst = self.doc_path(to, doc);
        match fs::rename(&src, &dst) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                let res = write_doc_file(&dst, doc, at);
                self.file_op(res, "recreating migrated document file")
            }
            Err(e) => self.file_op(Err(e), "moving document file"),
        }
    }
}

// ---- journal encoding ------------------------------------------------------

fn fmt_bits(x: f64) -> String {
    format!("{:x}", x.to_bits())
}

fn parse_bits(s: &str) -> Result<f64> {
    u64::from_str_radix(s, 16)
        .map(f64::from_bits)
        .with_context(|| format!("bad f64 bits '{s}'"))
}

fn fmt_costs(costs: &[PerDocCosts]) -> String {
    costs
        .iter()
        .map(|c| {
            format!(
                "{}:{}:{}",
                fmt_bits(c.write),
                fmt_bits(c.read),
                fmt_bits(c.rent_window)
            )
        })
        .collect::<Vec<_>>()
        .join(",")
}

fn parse_costs(s: &str) -> Result<Vec<PerDocCosts>> {
    s.split(',')
        .map(|entry| {
            let mut it = entry.split(':');
            let write = parse_bits(it.next().unwrap_or(""))?;
            let read = parse_bits(it.next().context("cost entry missing read")?)?;
            let rent_window = parse_bits(it.next().context("cost entry missing rent")?)?;
            if it.next().is_some() {
                bail!("cost entry '{entry}' has trailing fields");
            }
            Ok(PerDocCosts { write, read, rent_window })
        })
        .collect()
}

fn header_line(costs: &[PerDocCosts], charge_rent: bool) -> String {
    format!(
        "{JOURNAL_MAGIC} v{JOURNAL_VERSION} rent={} costs={}\n",
        u8::from(charge_rent),
        fmt_costs(costs)
    )
}

fn parse_u64(s: &str) -> Result<u64> {
    s.parse::<u64>().with_context(|| format!("bad integer '{s}'"))
}

/// Apply one journal line to the accounting state. Journal lines are only
/// written for operations that already succeeded, so replay against an
/// uncapacitated fresh state must succeed too.
fn replay_line(state: &mut StorageSim, line: &str) -> Result<()> {
    let mut parts = line.split(' ');
    let op = parts.next().unwrap_or("");
    let mut next = |what: &str| -> Result<&str> {
        parts.next().with_context(|| format!("'{op}' record missing {what}"))
    };
    match op {
        "put" => {
            let doc = parse_u64(next("doc")?)?;
            let tier = parse_u64(next("tier")?)? as usize;
            let at = parse_bits(next("at")?)?;
            let owner = match next("owner")? {
                "-" => None,
                s => Some(parse_u64(s)?),
            };
            state.set_attribution(owner);
            state.put(doc, TierId(tier), at)?;
        }
        "del" => {
            let doc = parse_u64(next("doc")?)?;
            let at = parse_bits(next("at")?)?;
            state.delete(doc, at)?;
        }
        "read" => {
            let doc = parse_u64(next("doc")?)?;
            state.read(doc)?;
        }
        "mig" => {
            let doc = parse_u64(next("doc")?)?;
            let to = parse_u64(next("to")?)? as usize;
            let at = parse_bits(next("at")?)?;
            state.migrate_doc(doc, TierId(to), at)?;
        }
        "migall" => {
            let from = parse_u64(next("from")?)? as usize;
            let to = parse_u64(next("to")?)? as usize;
            let at = parse_bits(next("at")?)?;
            state.migrate_all(TierId(from), TierId(to), at)?;
        }
        "settle" => {
            let at = parse_bits(next("at")?)?;
            state.settle_rent(at);
        }
        "reg" => {
            let stream = parse_u64(next("stream")?)?;
            let costs = parse_costs(next("costs")?)?;
            state.register_stream(stream, costs)?;
        }
        other => bail!("unknown journal op '{other}'"),
    }
    Ok(())
}

// ---- document files --------------------------------------------------------

/// Document payload: the doc id plus its written-at bits — real bytes the
/// read path verifies, not a zero-length marker.
fn write_doc_file(path: &Path, doc: u64, at: f64) -> std::io::Result<()> {
    let mut bytes = [0u8; 16];
    bytes[..8].copy_from_slice(&doc.to_le_bytes());
    bytes[8..].copy_from_slice(&at.to_bits().to_le_bytes());
    fs::write(path, bytes)
}

// ---- recovery --------------------------------------------------------------

fn recover(
    root: &Path,
    journal_path: &Path,
    costs: &[PerDocCosts],
    charge_rent: bool,
) -> Result<(StorageSim, Option<RecoveryReport>, File)> {
    let text = fs::read_to_string(journal_path)
        .with_context(|| format!("reading journal {}", journal_path.display()))?;
    let mut report = RecoveryReport::default();
    // Replay with unbounded capacities: the journal only records
    // operations that succeeded, and capacity is runtime configuration
    // that the caller re-applies after open.
    let mut state = StorageSim::with_tiers(costs.to_vec(), charge_rent);
    let mut valid_len = 0usize;
    let mut saw_header = false;
    for (idx, seg) in text.split_inclusive('\n').enumerate() {
        if !seg.ends_with('\n') {
            // torn trailing write: the op never durably happened
            report.truncated_tail = true;
            break;
        }
        let line = &seg[..seg.len() - 1];
        if !saw_header {
            let expected = header_line(costs, charge_rent);
            if seg != expected {
                bail!(
                    "journal {} header mismatch: backend opened with different \
                     economics (journal '{}', expected '{}')",
                    journal_path.display(),
                    line,
                    expected.trim_end()
                );
            }
            saw_header = true;
        } else if !line.is_empty() {
            replay_line(&mut state, line)
                .with_context(|| format!("journal line {}", idx + 1))?;
            report.ops_replayed += 1;
        }
        valid_len += seg.len();
    }
    if !saw_header {
        // No complete header means no operation was ever durably recorded
        // (ops only follow a header): the process died while the journal
        // was being created. Heal with a fresh header (below) instead of
        // bricking the root; the reconcile pass removes any stray files.
        report.truncated_tail = true;
    }
    state.set_attribution(None);

    // Reconcile document files against the replayed residency: recreate
    // what is missing, remove what nothing owns.
    for t in 0..costs.len() {
        let tier = TierId(t);
        let mut expected: BTreeMap<u64, f64> = state
            .tier(tier)
            .docs()
            .into_iter()
            .map(|d| (d, state.tier(tier).get(d).expect("doc listed").written_at))
            .collect();
        let dir = root.join(format!("tier-{t}"));
        for entry in
            fs::read_dir(&dir).with_context(|| format!("listing {}", dir.display()))?
        {
            let entry = entry?;
            let name = entry.file_name();
            let Some(stem) = name.to_string_lossy().strip_suffix(".doc").map(String::from)
            else {
                continue; // not a managed document file
            };
            let resident_at = stem.parse::<u64>().ok().and_then(|doc| {
                expected.remove(&doc).map(|at| (doc, at))
            });
            match resident_at {
                Some((doc, at)) => {
                    // a crash mid-write can leave a torn payload under a
                    // matching name — validate what read() will check and
                    // rewrite from the replayed state if it is corrupt
                    let intact = fs::read(entry.path())
                        .map(|b| b.len() >= 8 && b[..8] == doc.to_le_bytes())
                        .unwrap_or(false);
                    if !intact {
                        write_doc_file(&entry.path(), doc, at).with_context(|| {
                            format!("rewriting torn file {}", entry.path().display())
                        })?;
                        report.files_recreated += 1;
                    }
                }
                None => {
                    fs::remove_file(entry.path()).with_context(|| {
                        format!("removing orphan file {}", entry.path().display())
                    })?;
                    report.files_removed += 1;
                }
            }
        }
        for (doc, at) in expected {
            let path = dir.join(format!("{doc}.doc"));
            write_doc_file(&path, doc, at)
                .with_context(|| format!("recreating {}", path.display()))?;
            report.files_recreated += 1;
        }
    }

    // Drop the torn tail (if any) so appends start on a clean line; a
    // torn *header* resets the whole journal to a fresh header.
    if !saw_header {
        fs::write(journal_path, header_line(costs, charge_rent))
            .context("rewriting torn journal header")?;
    } else if report.truncated_tail {
        let file = OpenOptions::new().write(true).open(journal_path)?;
        file.set_len(valid_len as u64)
            .context("truncating torn journal tail")?;
    }
    let file = OpenOptions::new().append(true).open(journal_path)?;
    Ok((state, Some(report), file))
}

// ---- the StorageBackend impl -----------------------------------------------

impl StorageBackend for FsBackend {
    fn backend_name(&self) -> String {
        "fs".into()
    }

    fn num_tiers(&self) -> usize {
        self.state.num_tiers()
    }

    fn put(&mut self, doc: u64, tier: TierId, at: f64) -> Result<()> {
        self.ensure_live()?;
        self.state.put(doc, tier, at)?;
        let owner = match self.attribution {
            Some(s) => s.to_string(),
            None => "-".into(),
        };
        self.append(format!("put {doc} {} {} {owner}", tier.0, fmt_bits(at)))?;
        let res = write_doc_file(&self.doc_path(tier, doc), doc, at);
        self.file_op(res, "writing document file")
    }

    fn delete(&mut self, doc: u64, at: f64) -> Result<TierId> {
        self.ensure_live()?;
        let tier = self.state.delete(doc, at)?;
        self.append(format!("del {doc} {}", fmt_bits(at)))?;
        match fs::remove_file(self.doc_path(tier, doc)) {
            // already gone: a crash window earlier never materialized it
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(tier),
            res => self.file_op(res, "removing document file").map(|()| tier),
        }
    }

    fn read(&mut self, doc: u64) -> Result<TierId> {
        self.ensure_live()?;
        let Some(tier) = self.state.locate(doc) else {
            bail!("read: doc {doc} not resident");
        };
        let path = self.doc_path(tier, doc);
        let bytes =
            fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() < 8 || bytes[..8] != doc.to_le_bytes() {
            bail!("corrupt document file {}", path.display());
        }
        self.state.read(doc)?;
        self.append(format!("read {doc}"))?;
        Ok(tier)
    }

    fn migrate_doc(&mut self, doc: u64, to: TierId, at: f64) -> Result<()> {
        self.ensure_live()?;
        let Some(from) = self.state.locate(doc) else {
            bail!("migrate: doc {doc} not resident");
        };
        if from == to {
            return Ok(());
        }
        self.state.migrate_doc(doc, to, at)?;
        self.append(format!("mig {doc} {} {}", to.0, fmt_bits(at)))?;
        self.move_doc_file(from, to, doc, at)
    }

    fn migrate_all(&mut self, from: TierId, to: TierId, at: f64) -> Result<u64> {
        self.ensure_live()?;
        let tiers = self.state.num_tiers();
        if from.0 >= tiers || to.0 >= tiers {
            // delegate the bounds error (moves nothing)
            return self.state.migrate_all(from, to, at);
        }
        let docs = self.state.tier(from).docs();
        // all-or-nothing headroom check happens inside the state machine;
        // a doomed migration journals and moves nothing
        let n = self.state.migrate_all(from, to, at)?;
        if n == 0 {
            return Ok(0); // same-tier or empty source: nothing to record
        }
        self.append(format!("migall {} {} {}", from.0, to.0, fmt_bits(at)))?;
        for doc in docs {
            self.move_doc_file(from, to, doc, at)?;
        }
        Ok(n)
    }

    fn settle_rent(&mut self, at: f64) -> Result<()> {
        self.ensure_live()?;
        self.state.settle_rent(at);
        self.append(format!("settle {}", fmt_bits(at)))
    }

    fn locate(&self, doc: u64) -> Option<TierId> {
        self.state.locate(doc)
    }

    fn resident_len(&self, tier: TierId) -> usize {
        self.state.tier(tier).len()
    }

    fn residents(&self, tier: TierId) -> Vec<Resident> {
        let t = self.state.tier(tier);
        let mut v: Vec<Resident> = t.docs().iter().map(|d| *t.get(*d).unwrap()).collect();
        v.sort_by_key(|r| r.doc);
        v
    }

    fn resident_count(&self) -> usize {
        self.state.resident_count()
    }

    fn oldest_resident(&self, tier: TierId) -> Option<u64> {
        self.state.oldest_resident(tier)
    }

    fn owner_of(&self, doc: u64) -> Option<u64> {
        self.state.owner_of(doc)
    }

    fn docs_of_stream(&self, stream: u64) -> Vec<u64> {
        self.state.docs_of_stream(stream)
    }

    fn set_capacity(&mut self, tier: TierId, capacity: Option<usize>) {
        self.state.set_capacity(tier, capacity);
    }

    fn capacity(&self, tier: TierId) -> Option<usize> {
        self.state.tier(tier).capacity()
    }

    fn has_room(&self, tier: TierId) -> bool {
        self.state.has_room(tier)
    }

    fn peak_occupancy(&self, tier: TierId) -> usize {
        self.state.peak_occupancy(tier)
    }

    fn set_attribution(&mut self, stream: Option<u64>) {
        self.attribution = stream;
        self.state.set_attribution(stream);
    }

    fn register_stream(&mut self, stream: u64, costs: Vec<PerDocCosts>) -> Result<()> {
        self.ensure_live()?;
        self.state.register_stream(stream, costs.clone())?;
        self.append(format!("reg {stream} {}", fmt_costs(&costs)))
    }

    fn ledger(&self) -> &Ledger {
        self.state.ledger()
    }

    fn stream_ledger(&self, stream: u64) -> Ledger {
        self.state.stream_ledger(stream)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(tag: &str) -> PathBuf {
        crate::util::scratch_dir(&format!("fs-{tag}"))
    }

    fn costs() -> Vec<PerDocCosts> {
        vec![
            PerDocCosts { write: 1.0, read: 10.0, rent_window: 100.0 },
            PerDocCosts { write: 2.0, read: 20.0, rent_window: 200.0 },
        ]
    }

    fn ledgers_equal(a: &Ledger, b: &Ledger) -> bool {
        (a.total() - b.total()).abs() < 1e-12
            && a.total_writes() == b.total_writes()
            && a.total_reads() == b.total_reads()
            && (a.migration_total() - b.migration_total()).abs() < 1e-12
    }

    /// Drive the same op sequence through the sim and the fs backend.
    fn mixed_ops(b: &mut dyn StorageBackend) {
        b.set_attribution(Some(0));
        b.register_stream(
            0,
            vec![
                PerDocCosts { write: 1.5, read: 9.0, rent_window: 50.0 },
                PerDocCosts { write: 2.5, read: 19.0, rent_window: 150.0 },
            ],
        )
        .unwrap();
        b.put(1, TierId::A, 0.0).unwrap();
        b.put(2, TierId::A, 0.1).unwrap();
        b.set_attribution(Some(1));
        b.put(3, TierId::B, 0.2).unwrap();
        b.read(1).unwrap();
        b.migrate_doc(2, TierId::B, 0.5).unwrap();
        b.delete(3, 0.6).unwrap();
        b.settle_rent(1.0).unwrap();
    }

    #[test]
    fn fs_matches_sim_ledger_exactly() {
        let root = scratch("parity");
        let mut sim: Box<dyn StorageBackend> = Box::new(StorageSim::with_tiers(costs(), true));
        let mut fsb: Box<dyn StorageBackend> =
            Box::new(FsBackend::open(&root, costs(), true).unwrap());
        mixed_ops(sim.as_mut());
        mixed_ops(fsb.as_mut());
        assert_eq!(fsb.backend_name(), "fs");
        assert!(ledgers_equal(sim.ledger(), fsb.ledger()));
        for s in [0, 1] {
            assert!(
                ledgers_equal(&sim.stream_ledger(s), &fsb.stream_ledger(s)),
                "stream {s} ledgers diverge"
            );
        }
        assert_eq!(sim.locate(1), fsb.locate(1));
        assert_eq!(sim.locate(2), fsb.locate(2));
        assert_eq!(sim.resident_count(), fsb.resident_count());
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn documents_are_real_files_that_follow_migrations() {
        let root = scratch("files");
        let mut b = FsBackend::open(&root, costs(), false).unwrap();
        b.put(7, TierId::A, 0.0).unwrap();
        assert!(root.join("tier-0").join("7.doc").exists());
        b.migrate_doc(7, TierId::B, 0.5).unwrap();
        assert!(!root.join("tier-0").join("7.doc").exists());
        assert!(root.join("tier-1").join("7.doc").exists());
        b.delete(7, 0.9).unwrap();
        assert!(!root.join("tier-1").join("7.doc").exists());
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn reopen_rebuilds_residency_and_ledger_from_journal() {
        let root = scratch("reopen");
        let total;
        let stream0;
        let residents_a;
        {
            let mut b = FsBackend::open(&root, costs(), true).unwrap();
            mixed_ops(&mut b);
            total = b.ledger().total();
            stream0 = b.stream_ledger(0).total();
            residents_a = b.residents(TierId::A);
            // dropped without any clean-shutdown step: the journal is all
            // that survives a kill
        }
        let b = FsBackend::open(&root, costs(), true).unwrap();
        let rec = b.recovery().expect("reopen must report recovery").clone();
        assert!(rec.ops_replayed >= 8, "replayed {} ops", rec.ops_replayed);
        assert_eq!(rec.files_recreated, 0);
        assert_eq!(rec.files_removed, 0);
        assert!(!rec.truncated_tail);
        assert!((b.ledger().total() - total).abs() < 1e-12);
        assert!((b.stream_ledger(0).total() - stream0).abs() < 1e-12);
        assert_eq!(b.residents(TierId::A), residents_a);
        assert_eq!(b.locate(2), Some(TierId::B));
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn recovery_reconciles_missing_and_orphan_files() {
        let root = scratch("reconcile");
        {
            let mut b = FsBackend::open(&root, costs(), false).unwrap();
            b.put(1, TierId::A, 0.0).unwrap();
            b.put(2, TierId::B, 0.1).unwrap();
        }
        // simulate crash windows: resident 1's file vanished, resident 2's
        // payload was torn mid-write, and an unjournaled stray appeared
        fs::remove_file(root.join("tier-0").join("1.doc")).unwrap();
        fs::write(root.join("tier-1").join("2.doc"), b"xx").unwrap();
        write_doc_file(&root.join("tier-1").join("99.doc"), 99, 0.5).unwrap();
        let mut b = FsBackend::open(&root, costs(), false).unwrap();
        let rec = b.recovery().unwrap().clone();
        assert_eq!(rec.files_recreated, 2, "missing file + torn payload");
        assert_eq!(rec.files_removed, 1);
        assert!(root.join("tier-0").join("1.doc").exists());
        assert!(!root.join("tier-1").join("99.doc").exists());
        // the repaired files serve reads again
        assert_eq!(b.read(1).unwrap(), TierId::A);
        assert_eq!(b.read(2).unwrap(), TierId::B);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn torn_journal_tail_is_dropped() {
        let root = scratch("torn");
        {
            let mut b = FsBackend::open(&root, costs(), false).unwrap();
            b.put(1, TierId::A, 0.0).unwrap();
            b.put(2, TierId::A, 0.1).unwrap();
        }
        // simulate a torn append: a partial line with no newline
        let mut f = OpenOptions::new()
            .append(true)
            .open(root.join(JOURNAL_FILE))
            .unwrap();
        f.write_all(b"put 3 0 3fb99999").unwrap();
        drop(f);
        let mut b = FsBackend::open(&root, costs(), false).unwrap();
        let rec = b.recovery().unwrap().clone();
        assert!(rec.truncated_tail);
        assert_eq!(rec.ops_replayed, 2);
        assert_eq!(b.locate(3), None);
        // appends after recovery land on a clean line
        b.put(4, TierId::B, 0.5).unwrap();
        drop(b);
        let b = FsBackend::open(&root, costs(), false).unwrap();
        assert_eq!(b.locate(4), Some(TierId::B));
        assert!(!b.recovery().unwrap().truncated_tail);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn torn_journal_header_is_healed_not_bricked() {
        let root = scratch("torn-header");
        fs::create_dir_all(&root).unwrap();
        // a kill mid-creation: partial header, no newline
        fs::write(root.join(JOURNAL_FILE), "shptier-fs v1 ren").unwrap();
        let mut b = FsBackend::open(&root, costs(), false).unwrap();
        let rec = b.recovery().unwrap().clone();
        assert!(rec.truncated_tail);
        assert_eq!(rec.ops_replayed, 0);
        b.put(1, TierId::A, 0.0).unwrap();
        drop(b);
        // the healed journal round-trips
        let b = FsBackend::open(&root, costs(), false).unwrap();
        assert_eq!(b.locate(1), Some(TierId::A));
        assert_eq!(b.recovery().unwrap().ops_replayed, 1);
        let _ = fs::remove_dir_all(&root);

        // an empty journal file (created, header never written) heals too
        let root = scratch("empty-journal");
        fs::create_dir_all(&root).unwrap();
        fs::write(root.join(JOURNAL_FILE), "").unwrap();
        let b = FsBackend::open(&root, costs(), false).unwrap();
        assert!(b.recovery().unwrap().truncated_tail);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn doomed_migrate_all_is_a_noop_on_fs() {
        let root = scratch("migall");
        let mut b = FsBackend::open(&root, costs(), true).unwrap();
        for d in 0..4 {
            b.put(d, TierId::A, 0.1).unwrap();
        }
        b.set_capacity(TierId::B, Some(2));
        let before = b.ledger().total();
        assert!(b.migrate_all(TierId::A, TierId::B, 0.5).is_err());
        assert_eq!(b.resident_len(TierId::A), 4);
        assert_eq!(b.ledger().total(), before);
        for d in 0..4u64 {
            assert!(root.join("tier-0").join(format!("{d}.doc")).exists());
        }
        // and the failed attempt was not journaled: a reopen agrees
        drop(b);
        let b = FsBackend::open(&root, costs(), true).unwrap();
        assert_eq!(b.resident_len(TierId::A), 4);
        assert_eq!(b.resident_len(TierId::B), 0);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn reopen_with_different_economics_is_rejected() {
        let root = scratch("econ");
        drop(FsBackend::open(&root, costs(), true).unwrap());
        let mut other = costs();
        other[0].write = 9.0;
        assert!(FsBackend::open(&root, other, true).is_err());
        assert!(FsBackend::open(&root, costs(), false).is_err(), "rent flag is economics too");
        assert!(FsBackend::open(&root, costs(), true).is_ok());
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn capacity_is_runtime_config_not_durable_state() {
        let root = scratch("cap");
        {
            let mut b = FsBackend::open(&root, costs(), false).unwrap();
            b.set_capacity(TierId::A, Some(1));
            b.put(1, TierId::A, 0.0).unwrap();
            assert!(b.put(2, TierId::A, 0.1).is_err());
        }
        let mut b = FsBackend::open(&root, costs(), false).unwrap();
        // unbounded until the caller re-applies the topology
        assert_eq!(b.capacity(TierId::A), None);
        b.set_capacity(TierId::A, Some(1));
        assert!(!b.has_room(TierId::A));
        let _ = fs::remove_dir_all(&root);
    }
}
