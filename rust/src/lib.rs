//! # shptier
//!
//! A production-oriented reproduction of *"Adapting The Secretary Hiring
//! Problem for Optimal Hot-Cold Tier Placement under Top-K Workloads"*
//! (Blamey et al., CS.DC 2019).
//!
//! The crate is the Layer-3 (Rust) coordinator of a three-layer stack:
//!
//! - **L3 (this crate)** — streaming orchestrator: producers, PJRT-backed
//!   interestingness scoring, online top-K ranking, SHP-derived proactive
//!   tier placement, storage simulation with exact cost accounting, and the
//!   paper's analytic cost model + optimizers.
//! - **L2 (`python/compile/model.py`)** — the interestingness model (feature
//!   extraction → RBF kernel machine → Platt → label entropy) in JAX,
//!   AOT-lowered to HLO text at build time.
//! - **L1 (`python/compile/kernels/`)** — Pallas kernels for the scoring
//!   hot-spot, lowered into the same HLO.
//!
//! Python never runs on the request path: `make artifacts` emits
//! `artifacts/*.hlo.txt` + `manifest.json`, and [`runtime`] loads them via
//! the PJRT C API.
//!
//! ## The engine
//!
//! Every placement surface runs through one codepath: [`engine`], a
//! session-based, N-tier, backend-agnostic API. An [`engine::Engine`] is
//! built over a [`storage::StorageBackend`] — the simulator
//! [`storage::StorageSim`] (reference), the real-filesystem
//! [`storage::FsBackend`] (documents as files; ADR-003), or the
//! S3-style [`storage::ObjectBackend`] (bucket per tier, flat object
//! keys, request-counted verbs; ADR-005), the durable pair sharing one
//! write-ahead journal with checkpoint/compaction, bulk `migrate_stream`
//! batching, and crash recovery — and an
//! [`engine::TierTopology`]; [`engine::Engine::open_stream`] hands out
//! dynamic [`engine::StreamSession`]s that score/place/finish
//! independently, and every open/close/changeover event re-runs the
//! [`engine::Arbiter`]'s closed-form quota computation over the live
//! sessions (online re-arbitration). Sessions run either of the paper's
//! strategy families ([`policy::PlanFamily`]): keep, or DO_MIGRATE —
//! N-tier migrate schedules whose changeover demotions return hot
//! capacity to the pool mid-run (time-phased quota lending; ADR-004).
//! The single-stream batch executor ([`policy::run_policy`]), the
//! streaming [`pipeline`], and the multi-stream [`fleet`] are thin
//! compatibility wrappers over it (see `docs/adr/ADR-002-engine-api.md`).
//!
//! Start with [`cost::case_study_1`], [`policy`], [`engine`], and
//! [`pipeline`]; the `shptier` binary exposes every paper
//! experiment via `shptier exp --id <E#>`. Multi-tenant serving —
//! many concurrent top-K streams arbitrated over shared, capacity-limited
//! tiers — lives in [`fleet`] (`shptier fleet --streams 16`), and
//! `shptier engine` demos a 3-tier fleet with a mid-run stream closure
//! triggering online re-arbitration. [`serve`] wraps the engine in a
//! long-running, multi-tenant HTTP placement service (`shptier serve`)
//! with quota-class admission control, per-tenant invoicing from the
//! attributed ledgers, and journal-backed crash recovery (ADR-006).
//! [`adaptive`] closes the observe→estimate→re-plan loop the paper's
//! a-priori model leaves open: per-session admission-curve estimation,
//! drift detection under a false-positive budget, suffix-restart cut
//! re-derivation through the same re-arbitration path, and a bandit over
//! plan families (ADR-007; `--adaptive` on `shptier engine|fleet`,
//! experiment E-DRIFT).

pub mod adaptive;
pub mod benchkit;
pub mod config;
pub mod cost;
pub mod engine;
pub mod exp;
pub mod fleet;
pub mod interestingness;
pub mod pipeline;
pub mod report;
pub mod runtime;
pub mod policy;
pub mod propcheck;
pub mod serve;
pub mod ssa;
pub mod serdes;
pub mod shp;
pub mod storage;
pub mod topk;
pub mod util;

/// Crate version string (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
