//! Fleet run reporting: per-stream reconciliation of measured ledgers
//! against the arbiter's analytic expectations, plus fleet-wide telemetry.

use super::arbiter::Arbitration;
use super::scheduler::FleetMode;
use crate::report::Table;
use crate::storage::Ledger;
use std::time::Duration;

/// Per-stream slice of a fleet report.
#[derive(Debug, Clone)]
pub struct StreamReport {
    pub id: u64,
    pub n: u64,
    pub k: u64,
    /// Hot-tier demand `min(r*, K)`.
    pub demand: u64,
    /// Assigned quota (equals demand when not oversubscribed; unused in
    /// naive mode).
    pub quota: u64,
    /// Changeover parameter the stream actually ran.
    pub r_effective: u64,
    /// Analytic expected cost at the parameter it ran.
    pub analytic: f64,
    /// Measured total from the stream's attributed ledger.
    pub measured: f64,
    /// Final top-K reads served hot / cold.
    pub hot_reads: u64,
    pub cold_reads: u64,
    /// Reactive demotions this stream triggered (naive mode).
    pub demotions_caused: u64,
}

/// Outcome of a whole fleet run.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub mode: FleetMode,
    pub hot_capacity: u64,
    pub workers: usize,
    pub streams: Vec<StreamReport>,
    pub arbitration: Arbitration,
    /// The shared simulator's fleet-wide ledger.
    pub ledger: Ledger,
    /// High-water mark of hot-tier occupancy over the run.
    pub hot_peak: u64,
    /// Sessions whose drift detector fired (counted on every run; ADR-007).
    pub drift_detections: u64,
    /// Drift-triggered re-arbitrations (only under `--adaptive`).
    pub drift_rederivations: u64,
    pub docs_processed: u64,
    pub wall: Duration,
    pub throughput_docs_per_sec: f64,
}

impl FleetReport {
    /// Fleet-wide measured cost (the shared ledger total).
    pub fn total_cost(&self) -> f64 {
        self.ledger.total()
    }

    /// Deterministic fingerprint of the run's *outcome*: FNV-1a 64 over
    /// every placement-relevant field — mode, capacity, hot peak, drift
    /// counters, document totals, and each stream's full report row
    /// (float fields hashed by their bit patterns). Timing fields (wall,
    /// throughput), the worker count, and the run-ledger total (whose
    /// float summation order varies across schedules) are deliberately
    /// excluded, so an arbitrated fleet must produce the *same* digest
    /// at every worker count — the CI parity gate and the
    /// `fleet_throughput` bench both compare exactly this value.
    pub fn digest(&self) -> u64 {
        fn put(h: &mut u64, v: u64) {
            for b in v.to_le_bytes() {
                *h = (*h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        put(&mut h, match self.mode {
            FleetMode::Arbitrated => 0,
            FleetMode::Naive => 1,
        });
        put(&mut h, self.hot_capacity);
        put(&mut h, self.hot_peak);
        put(&mut h, self.drift_detections);
        put(&mut h, self.drift_rederivations);
        put(&mut h, self.docs_processed);
        put(&mut h, self.arbitration.aggregate_demand);
        put(&mut h, self.arbitration.oversubscribed as u64);
        put(&mut h, self.streams.len() as u64);
        for s in &self.streams {
            put(&mut h, s.id);
            put(&mut h, s.n);
            put(&mut h, s.k);
            put(&mut h, s.demand);
            put(&mut h, s.quota);
            put(&mut h, s.r_effective);
            put(&mut h, s.analytic.to_bits());
            put(&mut h, s.measured.to_bits());
            put(&mut h, s.hot_reads);
            put(&mut h, s.cold_reads);
            put(&mut h, s.demotions_caused);
        }
        h
    }

    /// Σ of per-stream attributed ledger totals — must equal
    /// [`FleetReport::total_cost`] (the conservation invariant).
    pub fn per_stream_total(&self) -> f64 {
        self.streams.iter().map(|s| s.measured).sum()
    }

    /// Total reactive demotions across streams (0 in arbitrated mode).
    pub fn demotions(&self) -> u64 {
        self.streams.iter().map(|s| s.demotions_caused).sum()
    }

    /// Per-stream reconciliation table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "fleet run — {:?}, {} streams, hot capacity {} (demand {}), {} workers",
                self.mode,
                self.streams.len(),
                self.hot_capacity,
                self.arbitration.aggregate_demand,
                self.workers
            ),
            &[
                "stream", "N", "K", "demand", "quota", "r_eff", "analytic $", "measured $",
                "Δ", "hot/cold reads", "demotions",
            ],
        );
        for s in &self.streams {
            let delta = if s.analytic.abs() > 1e-12 {
                format!("{:+.1}%", (s.measured / s.analytic - 1.0) * 100.0)
            } else {
                "-".to_string()
            };
            t.row(vec![
                s.id.to_string(),
                s.n.to_string(),
                s.k.to_string(),
                s.demand.to_string(),
                s.quota.to_string(),
                s.r_effective.to_string(),
                format!("{:.4}", s.analytic),
                format!("{:.4}", s.measured),
                delta,
                format!("{}/{}", s.hot_reads, s.cold_reads),
                s.demotions_caused.to_string(),
            ]);
        }
        let analytic_total: f64 = self.streams.iter().map(|s| s.analytic).sum();
        t.row(vec![
            "TOTAL".to_string(),
            "-".to_string(),
            "-".to_string(),
            self.arbitration.aggregate_demand.to_string(),
            self.streams.iter().map(|s| s.quota).sum::<u64>().to_string(),
            "-".to_string(),
            format!("{analytic_total:.4}"),
            format!("{:.4}", self.total_cost()),
            "-".to_string(),
            "-".to_string(),
            self.demotions().to_string(),
        ]);
        t
    }

    /// One-paragraph summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "fleet: {} streams, {} docs in {:.2?} ({:.0} docs/s, {} workers)\n\
             hot tier: capacity {} | peak occupancy {} | aggregate demand {}{}\n\
             drift: {} detections | {} re-derivations\n\
             cost: measured ${:.4} (Σ per-stream ${:.4}) | thrash ${:.4} over {} demotions\n\
             ledger: {}",
            self.streams.len(),
            self.docs_processed,
            self.wall,
            self.throughput_docs_per_sec,
            self.workers,
            self.hot_capacity,
            self.hot_peak,
            self.arbitration.aggregate_demand,
            if self.arbitration.oversubscribed { " (OVERSUBSCRIBED)" } else { "" },
            self.drift_detections,
            self.drift_rederivations,
            self.total_cost(),
            self.per_stream_total(),
            self.ledger.migration_total(),
            self.demotions(),
            self.ledger.summary(),
        )
    }
}
