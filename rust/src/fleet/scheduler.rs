//! The fleet scheduler: M concurrent top-K streams multiplexed over the
//! shared capacity-limited storage by a work-stealing worker pool
//! (ADR-008) — a thin compatibility wrapper over
//! [`crate::engine::Engine`] since ADR-002.
//!
//! Thread topology:
//!
//! ```text
//!   deque 0: [task task ...] <── worker 0 ──┐  pop own front,
//!   deque 1: [task ...]      <── worker 1 ──┤  steal victims' back,
//!       ...                       ...       ┘  observe inline
//! ```
//!
//! Each *task* owns one stream end-to-end: its seeded generator state
//! plus its engine [`StreamSession`]. A worker pops a task, produces and
//! places one batch inline — synthetic series generation from the
//! stream's interestingness profile, native RBF scoring, then `observe`
//! straight into the sharded engine core — and requeues the task at its
//! own deque's back. There is no placer thread and no channel anymore:
//! since ADR-008 the observe hot path takes only the stream's shard
//! lock, so workers place concurrently instead of serializing behind a
//! single engine-owning thread. Idle workers steal from the *back* of
//! other workers' deques, so a worker stuck behind an 8× longer stream
//! (see [`crate::fleet::skewed_fleet`]) sheds its queued work to the
//! fleet instead of stranding it.
//!
//! Determinism at any worker count:
//!
//! - a task lives in exactly one deque at a time, so each stream's
//!   documents are produced and observed in stream order no matter which
//!   workers end up running it;
//! - per-stream score sequences are seeded independently of the worker
//!   partitioning ([`stream_seed`]);
//! - arbitrated keep-family placement is interleaving-insensitive by
//!   construction: quotas sum to at most the hot capacity, so a
//!   placement depends only on the owning session's state, never on
//!   which other stream's document raced it to the backend.
//!
//! Together these make arbitrated fleet reports bitwise identical
//! ([`FleetReport::digest`]) across worker counts — the CI parity gate.
//! Migrate-family fleets re-lend freed capacity mid-run and remain
//! interleaving-sensitive, exactly as before ADR-008.

use super::arbiter::{arbitrate_full, Arbitration};
use super::report::{FleetReport, StreamReport};
use super::stream::{generate_series, StreamSpec, HOT};
use crate::engine::{BackendSpec, Engine, StreamSession, TierTopology};
use crate::interestingness::RbfScorer;
use crate::policy::PlanFamily;
use anyhow::{bail, Context, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// How the fleet handles hot-tier contention.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetMode {
    /// Quota arbitration: per-stream budgets from the analytic model;
    /// over-quota placements degrade proactively to cold.
    Arbitrated,
    /// Capacity-oblivious per-stream optima: every stream runs its own
    /// unconstrained r*; contention is resolved reactively by demoting the
    /// oldest hot resident (shared-cache behaviour).
    Naive,
}

/// Fleet-wide run configuration.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Shared hot-tier capacity in resident documents.
    pub hot_capacity: u64,
    /// Worker-pool size (clamped to the stream count).
    pub workers: usize,
    /// Batches-in-flight knob of the pre-ADR-008 channel pipeline. The
    /// work-stealing scheduler places inline and has no channel, so the
    /// field is ignored; it is kept so existing configs and TOML launch
    /// files parse unchanged.
    pub channel_capacity: usize,
    /// Documents scored per scheduling quantum (one deque pop).
    pub batch: usize,
    /// Synthetic series length per document.
    pub t_len: usize,
    /// Fleet seed; per-stream generators fork deterministically from it.
    pub seed: u64,
    pub mode: FleetMode,
    /// Strategy family every stream runs (`keep` | `migrate` | `auto`).
    /// Migrate-family streams bulk-demote at their changeover and the
    /// freed hot capacity is re-lent mid-run, which makes contended
    /// migrate runs sensitive to cross-stream arrival interleaving (and
    /// therefore to the worker count).
    pub family: PlanFamily,
    /// Storage substrate: the in-memory simulator, the real-filesystem
    /// backend (`fs:<root>`, ADR-003), or the S3-style object store
    /// (`obj:<root>`, ADR-005) — durable roots must be fresh.
    pub backend: BackendSpec,
    /// Run the fleet under the drift-aware [`crate::adaptive::AdaptiveArbiter`]
    /// with the engine's drift→re-derivation trigger armed (ADR-007).
    /// On a durable backend the bandit's learned state is persisted to
    /// `<root>/bandit.state` at checkpoint time (ADR-008).
    pub adaptive: bool,
    /// Batch journal appends into group commits on durable backends
    /// (ADR-009); a free no-op on the simulator.
    pub group_commit: bool,
    /// Admission selector every stream runs (ADR-010): `bounded` (exact
    /// capacity-K heap, O(K) resident memory per stream) or `logmem`
    /// (O(log K) quantile-sketch admission with priced overshoot slack).
    pub selector: crate::topk::SelectorKind,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            hot_capacity: 256,
            workers: 4,
            channel_capacity: 256,
            batch: 16,
            t_len: 256,
            seed: 20190412,
            mode: FleetMode::Arbitrated,
            family: PlanFamily::Keep,
            backend: BackendSpec::Sim,
            adaptive: false,
            group_commit: false,
            selector: crate::topk::SelectorKind::Bounded,
        }
    }
}

/// A stream's producer-side state inside a task.
struct WorkerStream {
    id: u64,
    remaining: u64,
    /// Documents already produced (the shift index is a produced-count).
    produced: u64,
    rng: crate::util::Rng,
    profile: super::stream::SeriesProfile,
    shift: Option<super::stream::ScoreShift>,
}

/// One stream's unit of scheduling: generator state + engine session.
/// Exactly one deque (or one worker's hands) holds a task at any moment,
/// which is what preserves per-stream document order under stealing.
struct StreamTask {
    ws: WorkerStream,
    session: StreamSession,
}

/// Per-stream RNG seed, independent of worker partitioning so results are
/// reproducible across worker counts (also used by the staggered-admission
/// experiment so its score sequences match `run_fleet`'s).
pub(crate) fn stream_seed(fleet_seed: u64, stream_id: u64) -> u64 {
    fleet_seed ^ stream_id.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Run a fleet of `specs` under `config`. Returns the reconciled report.
pub fn run_fleet(specs: &[StreamSpec], config: &FleetConfig) -> Result<FleetReport> {
    if specs.is_empty() {
        bail!("fleet: no streams");
    }
    for (i, s) in specs.iter().enumerate() {
        if s.id != i as u64 {
            bail!("fleet: stream ids must be contiguous (spec {} has id {})", i, s.id);
        }
    }
    let started = Instant::now();
    // Static admission-time arbitration for the report; the engine
    // recomputes the identical verdict internally as the sessions open
    // (changeover demotions may re-arbitrate it away mid-run).
    let arbitration: Arbitration =
        arbitrate_full(specs, config.hot_capacity, config.family, config.selector);

    // ---- engine over the shared capacity-limited backend -------------------
    let charge_rent = specs.iter().any(|s| s.model.include_rent);
    let capacity = usize::try_from(config.hot_capacity).unwrap_or(usize::MAX);
    let mut builder = Engine::builder()
        .topology(
            TierTopology::two_tier(specs[0].model.a, specs[0].model.b)
                .with_capacity(HOT, Some(capacity)),
        )
        .charge_rent(charge_rent);
    let costs = vec![specs[0].model.a, specs[0].model.b];
    if let Some(durable) = config.backend.open_fresh(costs, charge_rent, "fleet")? {
        builder = builder.backend(durable);
    }
    builder = builder.group_commit(config.group_commit);
    if config.adaptive {
        // durable roots get a durable bandit: rewards learned this run
        // are written at checkpoint time and reloaded by whoever reopens
        // the root (a restart resumes the learning, not a cold start)
        let arbiter = match &config.backend {
            BackendSpec::Fs { root } | BackendSpec::Obj { root } => {
                crate::adaptive::AdaptiveArbiter::with_state_file(root.join("bandit.state"))
            }
            BackendSpec::Sim => crate::adaptive::AdaptiveArbiter::new(),
        };
        builder = builder.arbiter(Box::new(arbiter)).adaptive(true);
    }
    let engine = builder.build()?;
    let naive = config.mode == FleetMode::Naive;
    let sessions: Vec<StreamSession> = engine.open_streams(
        specs
            .iter()
            .map(|s| s.session_spec_full(naive, config.family, config.selector))
            .collect(),
    )?;
    let total_docs: u64 = specs.iter().map(|s| s.model.n).sum();

    // ---- work-stealing worker pool -----------------------------------------
    let workers = config.workers.max(1).min(specs.len());
    let batch = config.batch.max(1);
    let t_len = config.t_len.max(2);
    // initial partition: round-robin, same as the pre-ADR-008 fixed
    // assignment — stealing only changes who *runs* a task, not its seeds
    let deques: Vec<Mutex<VecDeque<StreamTask>>> =
        (0..workers).map(|_| Mutex::new(VecDeque::new())).collect();
    for (spec, session) in specs.iter().zip(sessions) {
        let task = StreamTask {
            ws: WorkerStream {
                id: spec.id,
                remaining: spec.model.n,
                produced: 0,
                rng: crate::util::Rng::new(stream_seed(config.seed, spec.id)),
                profile: spec.profile,
                shift: spec.shift,
            },
            session,
        };
        deques[spec.id as usize % workers].lock().unwrap().push_back(task);
    }
    let live = AtomicUsize::new(specs.len());
    let produced = AtomicU64::new(0);
    let stop = AtomicBool::new(false);
    let error: Mutex<Option<anyhow::Error>> = Mutex::new(None);
    let completed: Mutex<Vec<StreamTask>> = Mutex::new(Vec::with_capacity(specs.len()));

    {
        let deques = &deques;
        let live = &live;
        let produced = &produced;
        let stop = &stop;
        let error = &error;
        let completed = &completed;
        std::thread::scope(|scope| -> Result<()> {
            for w in 0..workers {
                std::thread::Builder::new()
                    .name(format!("fleet-worker-{w}"))
                    .spawn_scoped(scope, move || {
                        let scorer = RbfScorer::synthetic_demo();
                        loop {
                            if stop.load(Ordering::Acquire) {
                                return;
                            }
                            // own deque front first (affinity), then scan
                            // the victims and steal from their backs
                            let mut task = deques[w].lock().unwrap().pop_front();
                            if task.is_none() {
                                for off in 1..deques.len() {
                                    let victim = (w + off) % deques.len();
                                    if let Some(t) =
                                        deques[victim].lock().unwrap().pop_back()
                                    {
                                        task = Some(t);
                                        break;
                                    }
                                }
                            }
                            let Some(mut t) = task else {
                                if live.load(Ordering::Acquire) == 0 {
                                    return;
                                }
                                // someone else holds the last tasks — the
                                // requeue (or completion) will show up
                                std::thread::yield_now();
                                continue;
                            };
                            let take = batch.min(t.ws.remaining as usize);
                            for _ in 0..take {
                                let series =
                                    generate_series(t.ws.profile, t_len, &mut t.ws.rng);
                                let mut score = scorer.score_series(&series);
                                // distribution shift in f32, before the f64
                                // widening, so shifted runs stay bit-exact
                                // regardless of worker partitioning
                                if let Some(sh) = t.ws.shift {
                                    if t.ws.produced >= sh.at {
                                        score += sh.boost;
                                    }
                                }
                                t.ws.produced += 1;
                                if let Err(e) = t.session.observe(score as f64) {
                                    let mut slot = error.lock().unwrap();
                                    if slot.is_none() {
                                        *slot = Some(e);
                                    }
                                    drop(slot);
                                    stop.store(true, Ordering::Release);
                                    live.fetch_sub(1, Ordering::AcqRel);
                                    return;
                                }
                            }
                            t.ws.remaining -= take as u64;
                            produced.fetch_add(take as u64, Ordering::Relaxed);
                            if t.ws.remaining == 0 {
                                completed.lock().unwrap().push(t);
                                live.fetch_sub(1, Ordering::AcqRel);
                            } else {
                                deques[w].lock().unwrap().push_back(t);
                            }
                        }
                    })
                    .context("spawning fleet worker")?;
            }
            Ok(())
        })?;
    }
    if let Some(e) = error.into_inner().unwrap_or_else(|p| p.into_inner()) {
        return Err(e);
    }
    let produced = produced.into_inner();
    let mut tasks = completed.into_inner().unwrap_or_else(|p| p.into_inner());
    if produced != total_docs || tasks.len() != specs.len() {
        bail!(
            "fleet: produced {produced} docs over {} finished streams, expected \
             {total_docs} over {}",
            tasks.len(),
            specs.len()
        );
    }
    tasks.sort_by_key(|t| t.ws.id);
    let sessions: Vec<StreamSession> = tasks.into_iter().map(|t| t.session).collect();

    // ---- settle + finish ---------------------------------------------------
    engine.settle_rent(1.0)?;
    // capture the plans the streams actually ran BEFORE finishing anything:
    // every finish re-arbitrates the survivors, mutating their plans
    let r_effectives: Vec<u64> = sessions
        .iter()
        .map(|s| s.plan().map(|p| p.r()).unwrap_or(0))
        .collect();
    let mut streams = Vec::with_capacity(sessions.len());
    for ((session, r_effective), (spec, plan)) in sessions
        .into_iter()
        .zip(r_effectives)
        .zip(specs.iter().zip(arbitration.plans.iter()))
    {
        let outcome = session.finish()?;
        let analytic = match config.mode {
            FleetMode::Arbitrated => plan.analytic_budgeted,
            FleetMode::Naive => plan.analytic_unconstrained,
        };
        streams.push(StreamReport {
            id: spec.id,
            n: spec.model.n,
            k: spec.model.k,
            demand: plan.demand,
            quota: plan.quota,
            r_effective,
            analytic,
            measured: engine.stream_ledger(spec.id).total(),
            hot_reads: outcome.hot_reads(),
            cold_reads: outcome.cold_reads(),
            demotions_caused: outcome.demotions_caused,
        });
    }
    if config.adaptive {
        // flush learned state (the bandit rides Arbiter::on_checkpoint;
        // a free no-op on the sim backend)
        engine.checkpoint()?;
    }

    let wall = started.elapsed();
    let throughput = if wall.as_secs_f64() > 0.0 {
        total_docs as f64 / wall.as_secs_f64()
    } else {
        0.0
    };
    Ok(FleetReport {
        mode: config.mode,
        hot_capacity: config.hot_capacity,
        workers,
        streams,
        arbitration,
        ledger: engine.ledger(),
        hot_peak: engine.peak_occupancy(HOT) as u64,
        drift_detections: engine.drift_detections(),
        drift_rederivations: engine.drift_rederivations(),
        docs_processed: total_docs,
        wall,
        throughput_docs_per_sec: throughput,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::demo_fleet;

    fn tiny_config(mode: FleetMode, capacity: u64, workers: usize) -> FleetConfig {
        FleetConfig {
            hot_capacity: capacity,
            workers,
            channel_capacity: 16,
            batch: 8,
            t_len: 64,
            seed: 7,
            mode,
            ..FleetConfig::default()
        }
    }

    #[test]
    fn fleet_completes_and_conserves_ledger() {
        let specs = demo_fleet(4, 300, 8, true, 1);
        let expected_docs: u64 = specs.iter().map(|s| s.model.n).sum();
        let report =
            run_fleet(&specs, &tiny_config(FleetMode::Arbitrated, 16, 2)).unwrap();
        assert_eq!(report.docs_processed, expected_docs);
        assert_eq!(report.streams.len(), 4);
        let total = report.total_cost();
        assert!(total > 0.0);
        assert!(
            (total - report.per_stream_total()).abs() < 1e-6 * total.max(1.0),
            "fleet ${total} vs Σ streams ${}",
            report.per_stream_total()
        );
        // every stream retained its full top-K
        for s in &report.streams {
            assert_eq!(s.hot_reads + s.cold_reads, s.k.min(s.n));
        }
    }

    #[test]
    fn arbitrated_respects_capacity_with_zero_demotions() {
        let specs = demo_fleet(6, 250, 10, true, 3);
        let cap = 12u64;
        let report =
            run_fleet(&specs, &tiny_config(FleetMode::Arbitrated, cap, 3)).unwrap();
        assert!(report.arbitration.oversubscribed);
        assert!(report.hot_peak <= cap, "peak {} > capacity {cap}", report.hot_peak);
        assert_eq!(report.demotions(), 0);
    }

    #[test]
    fn naive_respects_capacity_via_demotion() {
        let specs = demo_fleet(6, 250, 10, true, 3);
        let cap = 12u64;
        let report = run_fleet(&specs, &tiny_config(FleetMode::Naive, cap, 1)).unwrap();
        assert!(report.hot_peak <= cap, "peak {} > capacity {cap}", report.hot_peak);
        assert!(report.demotions() > 0, "pressure must thrash the naive fleet");
        let total = report.total_cost();
        assert!((total - report.per_stream_total()).abs() < 1e-6 * total.max(1.0));
    }

    #[test]
    fn deterministic_across_worker_counts_in_arbitrated_mode() {
        // Arbitrated placement depends only on per-stream sequences, which
        // are seeded independently of worker partitioning.
        let specs = demo_fleet(5, 200, 6, true, 11);
        let a = run_fleet(&specs, &tiny_config(FleetMode::Arbitrated, 10, 1)).unwrap();
        let b = run_fleet(&specs, &tiny_config(FleetMode::Arbitrated, 10, 5)).unwrap();
        // per-stream ledgers accumulate in per-stream order → bitwise equal;
        // the fleet total only differs by float summation order.
        for (x, y) in a.streams.iter().zip(b.streams.iter()) {
            assert_eq!(x.measured, y.measured, "stream {}", x.id);
        }
        assert_eq!(a.digest(), b.digest(), "report digests must match bitwise");
        let rel = (a.total_cost() - b.total_cost()).abs() / a.total_cost().max(1e-12);
        assert!(rel < 1e-9, "fleet totals diverged: rel {rel}");
    }

    #[test]
    fn work_stealing_preserves_digests_on_a_skewed_fleet() {
        // every fourth stream is 8× longer: a fixed partition strands the
        // long streams, stealing rebalances them — and neither stealing
        // nor the worker count may leak into the report digest, drop a
        // batch, or deliver one twice (docs_processed + per-stream fields
        // are all digest inputs)
        let specs = crate::fleet::skewed_fleet(6, 120, 6, 3);
        let expected_docs: u64 = specs.iter().map(|s| s.model.n).sum();
        let mut digests = std::collections::BTreeSet::new();
        for workers in [1usize, 2, 4, 8] {
            let report = run_fleet(
                &specs,
                &tiny_config(FleetMode::Arbitrated, 12, workers),
            )
            .unwrap();
            assert_eq!(report.docs_processed, expected_docs, "{workers} workers");
            assert_eq!(report.streams.len(), specs.len(), "{workers} workers");
            digests.insert(report.digest());
        }
        assert_eq!(digests.len(), 1, "digests diverged across worker counts");
    }

    #[test]
    fn adaptive_fleet_detects_drift_and_stays_deterministic() {
        // shifted streams trip their drift detectors; with ample hot
        // capacity (m·k) the streams stay decoupled, so per-stream
        // outcomes are bitwise identical across worker counts even with
        // drift-triggered re-arbitrations in play (ADR-007)
        let specs = crate::fleet::drift_fleet(3, 600, 8, Some(300), 11);
        let mut cfg = tiny_config(FleetMode::Arbitrated, 24, 1);
        cfg.adaptive = true;
        let a = run_fleet(&specs, &cfg).unwrap();
        cfg.workers = 3;
        let b = run_fleet(&specs, &cfg).unwrap();
        assert!(a.drift_detections > 0, "the shift must be detected");
        assert_eq!(
            a.drift_rederivations, a.drift_detections,
            "adaptive fleets re-derive on every detection"
        );
        assert_eq!(a.drift_detections, b.drift_detections);
        for (x, y) in a.streams.iter().zip(b.streams.iter()) {
            assert_eq!(x.measured, y.measured, "stream {}", x.id);
        }
        assert_eq!(a.digest(), b.digest());
        // without --adaptive the detectors still count, but nothing re-derives
        cfg.adaptive = false;
        let plain = run_fleet(&specs, &cfg).unwrap();
        assert!(plain.drift_detections > 0);
        assert_eq!(plain.drift_rederivations, 0);
    }

    #[test]
    fn logmem_fleet_completes_and_stays_deterministic() {
        use crate::topk::SelectorKind;
        // a log-memory fleet admits a small superset per stream (every
        // admitted doc stays resident — the sketch tracks no membership,
        // so nothing is ever evicted) and must remain bitwise
        // deterministic across worker counts like the bounded fleet
        let specs = demo_fleet(4, 200, 6, true, 9);
        let mut cfg = tiny_config(FleetMode::Arbitrated, 10, 1);
        cfg.selector = SelectorKind::LogMem;
        let a = run_fleet(&specs, &cfg).unwrap();
        cfg.workers = 4;
        let b = run_fleet(&specs, &cfg).unwrap();
        assert_eq!(a.digest(), b.digest(), "logmem digests diverged across workers");
        for (s, spec) in a.streams.iter().zip(specs.iter()) {
            // finish() reads back the full admitted set — at least the
            // exact top-K, typically a few more (the priced overshoot)
            assert!(
                s.hot_reads + s.cold_reads >= spec.model.k.min(spec.model.n),
                "stream {} read back fewer docs than K",
                s.id
            );
        }
        // capacity is still respected: the slack is priced into quotas,
        // not absorbed by overcommitting the tier
        assert!(a.hot_peak <= 10, "peak {} > capacity", a.hot_peak);
    }

    #[test]
    fn r_effective_reflects_engine_plans() {
        let specs = demo_fleet(4, 200, 8, true, 5);
        let contended = run_fleet(&specs, &tiny_config(FleetMode::Arbitrated, 6, 2)).unwrap();
        for (s, p) in contended.streams.iter().zip(contended.arbitration.plans.iter()) {
            assert_eq!(s.r_effective, p.r_budgeted, "stream {}", s.id);
        }
        let naive = run_fleet(&specs, &tiny_config(FleetMode::Naive, 6, 2)).unwrap();
        for (s, p) in naive.streams.iter().zip(naive.arbitration.plans.iter()) {
            assert_eq!(s.r_effective, p.r_unconstrained, "stream {}", s.id);
        }
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(run_fleet(&[], &FleetConfig::default()).is_err());
        let mut specs = demo_fleet(2, 50, 3, false, 1);
        specs[1].id = 5;
        assert!(run_fleet(&specs, &FleetConfig::default()).is_err());
    }

    #[test]
    fn migrate_family_fleet_completes_and_conserves() {
        let specs = crate::fleet::rent_dominated_fleet(3, 300, 10, 2);
        let mut cfg = tiny_config(FleetMode::Arbitrated, 64, 1);
        cfg.family = crate::policy::PlanFamily::Migrate;
        let report = run_fleet(&specs, &cfg).unwrap();
        assert_eq!(report.streams.len(), 3);
        // the changeover demotions actually happened
        assert!(report.ledger.migration_total() > 0.0, "no changeover demotion fired");
        // conservation holds with mid-run bulk demotions in play
        let total = report.total_cost();
        assert!(
            (total - report.per_stream_total()).abs() < 1e-6 * total.max(1.0),
            "fleet ${total} vs Σ streams ${}",
            report.per_stream_total()
        );
        for s in &report.streams {
            assert_eq!(s.hot_reads + s.cold_reads, s.k.min(s.n));
            assert_eq!(s.hot_reads, 0, "migrated streams read everything cold");
        }
    }

    #[test]
    fn fleet_runs_on_the_fs_backend() {
        let specs = demo_fleet(2, 80, 4, true, 5);
        let root = crate::util::scratch_dir("fleet-fs");
        let mut cfg = tiny_config(FleetMode::Arbitrated, 8, 1);
        cfg.backend = BackendSpec::Fs { root: root.clone() };
        let fs_report = run_fleet(&specs, &cfg).unwrap();
        // parity with the sim on the identical seeded run
        let sim_report =
            run_fleet(&specs, &tiny_config(FleetMode::Arbitrated, 8, 1)).unwrap();
        assert!(
            (fs_report.total_cost() - sim_report.total_cost()).abs()
                < 1e-9 * sim_report.total_cost().max(1.0),
            "fs ${} vs sim ${}",
            fs_report.total_cost(),
            sim_report.total_cost()
        );
        // a stale root is refused, not silently corrupted
        assert!(run_fleet(&specs, &cfg).is_err());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn fleet_runs_on_the_object_backend() {
        let specs = demo_fleet(2, 80, 4, true, 5);
        let root = crate::util::scratch_dir("fleet-obj");
        let mut cfg = tiny_config(FleetMode::Arbitrated, 8, 1);
        cfg.backend = BackendSpec::Obj { root: root.clone() };
        let obj_report = run_fleet(&specs, &cfg).unwrap();
        // parity with the sim on the identical seeded run
        let sim_report =
            run_fleet(&specs, &tiny_config(FleetMode::Arbitrated, 8, 1)).unwrap();
        assert!(
            (obj_report.total_cost() - sim_report.total_cost()).abs()
                < 1e-9 * sim_report.total_cost().max(1.0),
            "obj ${} vs sim ${}",
            obj_report.total_cost(),
            sim_report.total_cost()
        );
        // a stale root is refused, not silently corrupted
        assert!(run_fleet(&specs, &cfg).is_err());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn adaptive_fleet_persists_bandit_state_on_durable_roots() {
        // Auto + rent-dominated economics exercise the bandit; after the
        // run the learned rewards must sit next to the journal
        let specs = crate::fleet::rent_dominated_fleet(3, 200, 8, 4);
        let root = crate::util::scratch_dir("fleet-bandit");
        let mut cfg = tiny_config(FleetMode::Arbitrated, 64, 2);
        cfg.family = crate::policy::PlanFamily::Auto;
        cfg.backend = BackendSpec::Fs { root: root.clone() };
        cfg.adaptive = true;
        run_fleet(&specs, &cfg).unwrap();
        let state = std::fs::read_to_string(root.join("bandit.state")).unwrap();
        let bandit = crate::adaptive::FamilyBandit::decode(&state)
            .expect("persisted record must parse");
        let (keep, migrate) = bandit.pulls();
        assert_eq!(
            keep + migrate,
            specs.len() as u64,
            "every finished Auto stream rewards an arm"
        );
        let _ = std::fs::remove_dir_all(&root);
    }
}
