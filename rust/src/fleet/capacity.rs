//! Hot-tier capacity planning: per-stream demand curves and the
//! proportional quota allocation used by the arbiter.
//!
//! [`allocate_proportional`] is tier-agnostic and is invoked once per
//! capacity-limited tier by the engine's N-tier
//! [`crate::engine::ProportionalArbiter`] (hot → cold, so clamped load
//! cascades toward the sink tier).
//!
//! Each stream's *demand* is the expected peak number of its documents
//! simultaneously resident in the hot tier under its unconstrained optimum
//! (`min(r*, K)`, see [`crate::cost::hot_demand`]); the analytic occupancy
//! *curve* over stream position comes from the closed form of paper eq. (15)
//! ([`crate::cost::analytic::expected_occupancy_a`]). When aggregate demand
//! exceeds the shared capacity, quotas are assigned proportionally to
//! demand with largest-remainder rounding — deterministic, exact-sum, and
//! never above a stream's own demand.

use crate::cost::analytic::expected_occupancy_a;

/// Proportionally allocate `capacity` hot-tier slots across streams with
/// the given `demands`. Returns one quota per stream with:
///
/// - `quota[i] <= demands[i]` (no stream gets more than it can use),
/// - `Σ quota = min(capacity, Σ demands)` (exact, via largest-remainder
///   rounding; remainder ties break toward the lower stream index).
pub fn allocate_proportional(capacity: u64, demands: &[u64]) -> Vec<u64> {
    let total: u64 = demands.iter().sum();
    if total <= capacity {
        return demands.to_vec();
    }
    if capacity == 0 || total == 0 {
        return vec![0; demands.len()];
    }
    // real-valued shares, floored; distribute the remainder by fractional part
    let mut quotas: Vec<u64> = Vec::with_capacity(demands.len());
    let mut fracs: Vec<(usize, f64)> = Vec::with_capacity(demands.len());
    let mut assigned = 0u64;
    for (i, &d) in demands.iter().enumerate() {
        let share = capacity as f64 * d as f64 / total as f64;
        let floor = share.floor() as u64;
        quotas.push(floor);
        assigned += floor;
        fracs.push((i, share - floor as f64));
    }
    let mut remainder = capacity.saturating_sub(assigned);
    // largest fractional remainder first; ties toward lower index
    fracs.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
    for (i, _) in fracs {
        if remainder == 0 {
            break;
        }
        if quotas[i] < demands[i] {
            quotas[i] += 1;
            remainder -= 1;
        }
    }
    quotas
}

/// Peak of a stream's expected hot-occupancy curve under changeover at
/// `r` with retained-set size `k`: `min(r, K)`. The full curve over stream
/// position is [`expected_occupancy_a`] (paper eq. (15) i.u.d. form).
pub fn peak_occupancy(r: u64, k: u64) -> u64 {
    r.min(k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn under_capacity_everyone_gets_demand() {
        let q = allocate_proportional(100, &[10, 20, 30]);
        assert_eq!(q, vec![10, 20, 30]);
    }

    #[test]
    fn over_capacity_sums_exactly_and_caps_at_demand() {
        let demands = [50u64, 30, 20];
        let q = allocate_proportional(60, &demands);
        assert_eq!(q.iter().sum::<u64>(), 60);
        for (qi, di) in q.iter().zip(demands.iter()) {
            assert!(qi <= di);
        }
        // proportionality: 50/100 → 30, 30/100 → 18, 20/100 → 12
        assert_eq!(q, vec![30, 18, 12]);
    }

    #[test]
    fn rounding_distributes_remainder_deterministically() {
        // shares 10/3 = 3.33.. each → floors 3,3,3, remainder 1 to index 0
        let q = allocate_proportional(10, &[7, 7, 7]);
        assert_eq!(q.iter().sum::<u64>(), 10);
        assert_eq!(q, vec![4, 3, 3]);
    }

    #[test]
    fn zero_capacity_and_zero_demand_edges() {
        assert_eq!(allocate_proportional(0, &[5, 5]), vec![0, 0]);
        assert_eq!(allocate_proportional(10, &[0, 0]), vec![0, 0]);
        assert_eq!(allocate_proportional(10, &[]), Vec::<u64>::new());
        // a zero-demand stream never receives quota under pressure
        let q = allocate_proportional(5, &[0, 10, 10]);
        assert_eq!(q[0], 0);
        assert_eq!(q.iter().sum::<u64>(), 5);
    }

    #[test]
    fn occupancy_curve_peaks_at_min_r_k() {
        assert_eq!(peak_occupancy(500, 20), 20);
        assert_eq!(peak_occupancy(5, 20), 5);
        // curve: at t = r the occupancy is min(K, t)·1
        assert!((expected_occupancy_a(100, 100, 20) - 20.0).abs() < 1e-12);
        // decays after r: K·r/t, so the peak bounds the whole curve
        assert!((expected_occupancy_a(200, 100, 20) - 10.0).abs() < 1e-12);
        for t in [1u64, 50, 100, 150, 400] {
            assert!(expected_occupancy_a(t, 100, 20) <= peak_occupancy(100, 20) as f64 + 1e-12);
        }
    }
}
