//! One stream of a fleet: its spec (workload + economics + interestingness
//! profile) and its placer-side runtime state against the shared simulator.
//!
//! Mirrors [`crate::policy::PlacementEngine`]'s observe/finish lifecycle,
//! but operates on a *shared* [`StorageSim`]: document ids are namespaced
//! per stream, every operation is attributed to the owning stream, and the
//! hot-tier write path is capacity-aware — arbitrated streams degrade
//! over-quota writes to the cold tier, naive streams reactively demote the
//! oldest hot resident (cross-stream interference included) to make room.

use crate::cost::CostModel;
use crate::policy::QuotaChangeover;
use crate::storage::{StorageSim, TierId};
use crate::topk::{BoundedTopK, Eviction, Scored};
use crate::util::Rng;
use anyhow::{bail, Result};

/// The shared hot tier (capacity-limited) of a fleet run.
pub const HOT: TierId = TierId::A;
/// The shared cold tier (unbounded) of a fleet run.
pub const COLD: TierId = TierId::B;

/// Bits of the global document id reserved for the stream-local index.
const INDEX_BITS: u32 = 40;

/// Shape of a stream's synthetic document series — its "interestingness
/// profile". Scores come from running the generated series through the
/// native RBF scorer, so score distributions differ per profile.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SeriesProfile {
    /// White noise around a baseline: mostly low-entropy documents.
    Noisy { level: f64 },
    /// Noisy sinusoid with random phase and amplitude per document.
    Oscillatory { period: f64 },
    /// Per-document mixture: oscillatory with probability `p_oscillatory`.
    Mixed { p_oscillatory: f64 },
}

/// Generate one synthetic document series for a profile.
pub fn generate_series(profile: SeriesProfile, t_len: usize, rng: &mut Rng) -> Vec<f32> {
    match profile {
        SeriesProfile::Noisy { level } => {
            (0..t_len).map(|_| (100.0 + level * rng.normal()) as f32).collect()
        }
        SeriesProfile::Oscillatory { period } => {
            let phase = rng.range_f64(0.0, std::f64::consts::TAU);
            let amp = rng.range_f64(20.0, 60.0);
            (0..t_len)
                .map(|t| {
                    (100.0
                        + amp * ((std::f64::consts::TAU * t as f64 / period) + phase).sin()
                        + 5.0 * rng.normal()) as f32
                })
                .collect()
        }
        SeriesProfile::Mixed { p_oscillatory } => {
            if rng.next_f64() < p_oscillatory {
                let period = rng.range_f64(16.0, 64.0);
                generate_series(SeriesProfile::Oscillatory { period }, t_len, rng)
            } else {
                generate_series(SeriesProfile::Noisy { level: 10.0 }, t_len, rng)
            }
        }
    }
}

/// Full specification of one fleet stream.
#[derive(Debug, Clone)]
pub struct StreamSpec {
    /// Stream id; must equal the stream's position in the fleet (0-based).
    pub id: u64,
    /// Per-stream economics and workload geometry (N, K, per-doc costs).
    pub model: CostModel,
    /// Interestingness profile driving the synthetic score stream.
    pub profile: SeriesProfile,
}

impl StreamSpec {
    pub fn new(id: u64, model: CostModel, profile: SeriesProfile) -> Self {
        Self { id, model, profile }
    }
}

/// Outcome of one finished stream.
#[derive(Debug, Clone)]
pub struct StreamOutcome {
    pub id: u64,
    /// Final top-K stream-local indices (best first).
    pub retained: Vec<u64>,
    /// Final reads served from the hot tier.
    pub hot_reads: u64,
    /// Final reads served from the cold tier.
    pub cold_reads: u64,
    /// Reactive demotions this stream triggered (naive mode only).
    pub demotions_caused: u64,
}

/// Placer-side runtime state of one stream.
pub struct StreamState {
    pub id: u64,
    pub n: u64,
    pub k: u64,
    /// Effective changeover index (budgeted in arbitrated mode).
    r: u64,
    /// Hot-tier quota in simultaneous residents (ignored in naive mode).
    quota: usize,
    /// Naive mode: ignore the quota, demote reactively on pressure.
    naive: bool,
    tracker: BoundedTopK,
    next_index: u64,
    hot_in_use: usize,
    demotions_caused: u64,
}

impl StreamState {
    pub fn new(spec: &StreamSpec, r: u64, quota: usize, naive: bool) -> Self {
        assert!(spec.id < 1u64 << (64 - INDEX_BITS), "stream id too large");
        assert!(spec.model.n < 1u64 << INDEX_BITS, "stream too long");
        let k = (spec.model.k as usize).min(spec.model.n as usize);
        Self {
            id: spec.id,
            n: spec.model.n,
            k: spec.model.k,
            r,
            quota,
            naive,
            tracker: BoundedTopK::new(k),
            next_index: 0,
            hot_in_use: 0,
            demotions_caused: 0,
        }
    }

    /// Namespaced global document id for this stream's `index`.
    pub fn gid(&self, index: u64) -> u64 {
        (self.id << INDEX_BITS) | index
    }

    pub fn observed(&self) -> u64 {
        self.next_index
    }

    pub fn done(&self) -> bool {
        self.next_index >= self.n
    }

    pub fn effective_r(&self) -> u64 {
        self.r
    }

    /// Observe the stream's next document (must be called in stream order).
    pub fn observe(&mut self, sim: &mut StorageSim, score: f64) -> Result<()> {
        let i = self.next_index;
        if i >= self.n {
            bail!("stream {} longer than declared N={}", self.id, self.n);
        }
        self.next_index += 1;
        let at = i as f64 / self.n as f64;
        sim.set_attribution(Some(self.id));
        match self.tracker.offer(Scored::new(i, score)) {
            Eviction::Rejected => {}
            Eviction::Accepted => self.write(sim, i, at)?,
            Eviction::Replaced { victim } => {
                let vgid = self.gid(victim.index);
                if sim.locate(vgid) == Some(HOT) {
                    self.hot_in_use = self.hot_in_use.saturating_sub(1);
                }
                sim.delete(vgid, at)?;
                self.write(sim, i, at)?;
            }
        }
        Ok(())
    }

    /// Capacity-aware write of an accepted document.
    fn write(&mut self, sim: &mut StorageSim, index: u64, at: f64) -> Result<()> {
        let gid = self.gid(index);
        let wants_hot = if self.naive {
            // capacity-oblivious: the stream believes its unconstrained r*
            index < self.r
        } else {
            QuotaChangeover::wants_hot(self.r, self.quota, index, self.hot_in_use)
        };
        if !wants_hot {
            sim.put(gid, COLD, at)?;
            return Ok(());
        }
        if !sim.has_room(HOT) {
            if self.naive {
                // Reactive demotion (shared-cache behaviour): push the
                // oldest hot resident — possibly another stream's — cold,
                // paying a migration hop, then take the freed slot.
                match sim.oldest_resident(HOT) {
                    Some(victim) => {
                        sim.migrate_doc(victim, COLD, at)?;
                        self.demotions_caused += 1;
                    }
                    None => {
                        // hot capacity is zero: nothing to demote
                        sim.put(gid, COLD, at)?;
                        return Ok(());
                    }
                }
            } else {
                // Arbitrated quotas make this unreachable (Σ quotas ≤ C);
                // degrade to cold as a safety net rather than failing.
                sim.put(gid, COLD, at)?;
                return Ok(());
            }
        }
        sim.put(gid, HOT, at)?;
        self.hot_in_use += 1;
        Ok(())
    }

    /// End of stream: consumer reads the retained top-K. The caller settles
    /// rent fleet-wide (once) before finishing any stream.
    pub fn finish(&mut self, sim: &mut StorageSim) -> Result<StreamOutcome> {
        sim.set_attribution(Some(self.id));
        let retained: Vec<u64> = self.tracker.sorted_desc().iter().map(|s| s.index).collect();
        let mut hot_reads = 0u64;
        let mut cold_reads = 0u64;
        for &d in &retained {
            if sim.read(self.gid(d))? == HOT {
                hot_reads += 1;
            } else {
                cold_reads += 1;
            }
        }
        Ok(StreamOutcome {
            id: self.id,
            retained,
            hot_reads,
            cold_reads,
            demotions_caused: self.demotions_caused,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PerDocCosts;
    use crate::policy::{run_policy, Changeover};

    fn model(n: u64, k: u64) -> CostModel {
        CostModel::new(
            n,
            k,
            PerDocCosts { write: 1.0, read: 4.0, rent_window: 0.3 },
            PerDocCosts { write: 3.0, read: 0.5, rent_window: 0.1 },
        )
    }

    fn random_scores(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.next_f64()).collect()
    }

    #[test]
    fn single_stream_matches_placement_engine() {
        // An unconstrained stream on an uncapped shared sim must reproduce
        // the single-stream Changeover run exactly (same economics).
        let m = model(600, 10);
        let scores = random_scores(600, 42);
        let r_cut = 250u64;

        let mut plain = Changeover::new(r_cut);
        let reference = run_policy(&scores, &m, &mut plain).unwrap();

        let spec = StreamSpec::new(0, m.clone(), SeriesProfile::Mixed { p_oscillatory: 0.5 });
        let mut sim = StorageSim::two_tier(m.a, m.b, m.include_rent);
        let mut st = StreamState::new(&spec, r_cut, m.k as usize, false);
        for &s in &scores {
            st.observe(&mut sim, s).unwrap();
        }
        assert!(st.done());
        sim.settle_rent(1.0);
        let out = st.finish(&mut sim).unwrap();
        assert_eq!(out.retained, reference.retained);
        let total = sim.ledger().total();
        assert!(
            (total - reference.total_cost()).abs() < 1e-9,
            "fleet stream ${total} vs engine ${}",
            reference.total_cost()
        );
        // and the per-stream ledger equals the whole ledger (single stream)
        assert!((sim.stream_ledger(0).total() - total).abs() < 1e-12);
    }

    #[test]
    fn quota_zero_stream_writes_only_cold() {
        let m = model(200, 5);
        let spec = StreamSpec::new(0, m.clone(), SeriesProfile::Noisy { level: 10.0 });
        let mut sim = StorageSim::two_tier(m.a, m.b, false);
        let mut st = StreamState::new(&spec, 100, 0, false);
        for &s in &random_scores(200, 7) {
            st.observe(&mut sim, s).unwrap();
        }
        assert_eq!(sim.tier(HOT).peak_len(), 0);
    }

    #[test]
    fn naive_stream_demotes_under_pressure() {
        let m = model(300, 8);
        let spec = StreamSpec::new(0, m.clone(), SeriesProfile::Noisy { level: 10.0 });
        let mut sim = StorageSim::two_tier(m.a, m.b, false);
        sim.set_capacity(HOT, Some(3));
        let mut st = StreamState::new(&spec, 200, usize::MAX, true);
        for &s in &random_scores(300, 9) {
            st.observe(&mut sim, s).unwrap();
        }
        assert!(st.demotions_caused > 0, "pressure must trigger demotions");
        assert!(sim.peak_occupancy(HOT) <= 3);
        assert!(sim.ledger().migration_total() > 0.0);
    }

    #[test]
    fn gid_namespacing_is_disjoint() {
        let m = model(100, 3);
        let noisy = SeriesProfile::Noisy { level: 1.0 };
        let a = StreamState::new(&StreamSpec::new(1, m.clone(), noisy), 10, 3, false);
        let b = StreamState::new(&StreamSpec::new(2, m, noisy), 10, 3, false);
        assert_ne!(a.gid(5), b.gid(5));
        assert_eq!(a.gid(5) >> INDEX_BITS, 1);
    }

    #[test]
    fn profiles_generate_finite_series() {
        let mut rng = Rng::new(3);
        for p in [
            SeriesProfile::Noisy { level: 10.0 },
            SeriesProfile::Oscillatory { period: 32.0 },
            SeriesProfile::Mixed { p_oscillatory: 0.5 },
        ] {
            let s = generate_series(p, 128, &mut rng);
            assert_eq!(s.len(), 128);
            assert!(s.iter().all(|v| v.is_finite()));
        }
    }
}
