//! One stream of a fleet: its spec (workload + economics + interestingness
//! profile) and the synthetic series generators that drive it.
//!
//! The per-stream *runtime* state that used to live here (`StreamState`)
//! moved into the engine as [`crate::engine::StreamSession`] (ADR-002):
//! the fleet scheduler now opens one engine session per stream and the
//! observe/place/finish lifecycle — gid namespacing, attributed charges,
//! quota degradation, reactive demotion — is the engine's single
//! implementation, shared with the pipeline.

use crate::cost::CostModel;
use crate::storage::TierId;
use crate::util::Rng;

/// The shared hot tier (capacity-limited) of a fleet run.
pub const HOT: TierId = TierId::A;
/// The shared cold tier (unbounded) of a fleet run.
pub const COLD: TierId = TierId::B;

/// Shape of a stream's synthetic document series — its "interestingness
/// profile". Scores come from running the generated series through the
/// native RBF scorer, so score distributions differ per profile.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SeriesProfile {
    /// White noise around a baseline: mostly low-entropy documents.
    Noisy { level: f64 },
    /// Noisy sinusoid with random phase and amplitude per document.
    Oscillatory { period: f64 },
    /// Per-document mixture: oscillatory with probability `p_oscillatory`.
    Mixed { p_oscillatory: f64 },
}

/// Generate one synthetic document series for a profile.
pub fn generate_series(profile: SeriesProfile, t_len: usize, rng: &mut Rng) -> Vec<f32> {
    match profile {
        SeriesProfile::Noisy { level } => {
            (0..t_len).map(|_| (100.0 + level * rng.normal()) as f32).collect()
        }
        SeriesProfile::Oscillatory { period } => {
            let phase = rng.range_f64(0.0, std::f64::consts::TAU);
            let amp = rng.range_f64(20.0, 60.0);
            (0..t_len)
                .map(|t| {
                    (100.0
                        + amp * ((std::f64::consts::TAU * t as f64 / period) + phase).sin()
                        + 5.0 * rng.normal()) as f32
                })
                .collect()
        }
        SeriesProfile::Mixed { p_oscillatory } => {
            if rng.next_f64() < p_oscillatory {
                let period = rng.range_f64(16.0, 64.0);
                generate_series(SeriesProfile::Oscillatory { period }, t_len, rng)
            } else {
                generate_series(SeriesProfile::Noisy { level: 10.0 }, t_len, rng)
            }
        }
    }
}

/// A mid-stream distribution shift: from document index `at` onward the
/// stream's scores get a flat additive `boost` (applied in the scorer's
/// f32 domain, before widening to f64, so shifted runs stay bit-exact
/// across worker counts). Drives the E-DRIFT experiment (ADR-007): a
/// large boost makes late documents dominate the top-K, invalidating the
/// a-priori secretary admission law the static cuts were derived from.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScoreShift {
    /// First document index (0-based, per stream) the boost applies to.
    pub at: u64,
    /// Additive score boost for documents at or after `at`.
    pub boost: f32,
}

/// Full specification of one fleet stream.
#[derive(Debug, Clone)]
pub struct StreamSpec {
    /// Stream id; must equal the stream's position in the fleet (0-based).
    pub id: u64,
    /// Per-stream economics and workload geometry (N, K, per-doc costs).
    pub model: CostModel,
    /// Interestingness profile driving the synthetic score stream.
    pub profile: SeriesProfile,
    /// Optional mid-stream distribution shift (E-DRIFT workloads).
    pub shift: Option<ScoreShift>,
}

impl StreamSpec {
    pub fn new(id: u64, model: CostModel, profile: SeriesProfile) -> Self {
        Self { id, model, profile, shift: None }
    }

    /// Apply a [`ScoreShift`] at document index `at` with additive `boost`.
    pub fn with_shift(mut self, at: u64, boost: f32) -> Self {
        self.shift = Some(ScoreShift { at, boost });
        self
    }

    /// The engine session spec for this stream (fleet mode decides naive).
    pub fn session_spec(&self, naive: bool) -> crate::engine::SessionSpec {
        crate::engine::SessionSpec::from_model(&self.model).with_naive(naive)
    }

    /// [`StreamSpec::session_spec`] with an explicit strategy family.
    pub fn session_spec_with(
        &self,
        naive: bool,
        family: crate::policy::PlanFamily,
    ) -> crate::engine::SessionSpec {
        self.session_spec(naive).with_family(family)
    }

    /// [`StreamSpec::session_spec_with`] plus an explicit admission
    /// selector (ADR-010): `bounded` or `logmem`.
    pub fn session_spec_full(
        &self,
        naive: bool,
        family: crate::policy::PlanFamily,
        selector: crate::topk::SelectorKind,
    ) -> crate::engine::SessionSpec {
        self.session_spec_with(naive, family).with_selector(selector)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PerDocCosts;
    use crate::engine::{Engine, TierTopology};
    use crate::policy::{run_policy, Changeover};

    fn model(n: u64, k: u64) -> CostModel {
        CostModel::new(
            n,
            k,
            PerDocCosts { write: 1.0, read: 4.0, rent_window: 0.3 },
            PerDocCosts { write: 3.0, read: 0.5, rent_window: 0.1 },
        )
    }

    fn random_scores(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.next_f64()).collect()
    }

    #[test]
    fn single_session_matches_batch_changeover() {
        // An unconstrained engine session running its plan on an uncapped
        // backend must reproduce the single-stream Changeover run exactly
        // when the plan's cut equals the policy's r (same economics).
        let m = model(600, 10);
        let scores = random_scores(600, 42);

        let engine = Engine::builder()
            .topology(TierTopology::from_model(&m))
            .charge_rent(m.include_rent)
            .build()
            .unwrap();
        let spec = StreamSpec::new(0, m.clone(), SeriesProfile::Mixed { p_oscillatory: 0.5 });
        let mut session = engine.open_stream(spec.session_spec(false)).unwrap();
        let r_cut = session.plan().unwrap().r();

        let mut plain = Changeover::new(r_cut);
        let reference = run_policy(&scores, &m, &mut plain).unwrap();

        for &s in &scores {
            session.observe(s).unwrap();
        }
        assert!(session.done());
        engine.settle_rent(1.0).unwrap();
        let out = session.finish().unwrap();
        assert_eq!(out.retained, reference.retained);
        let total = engine.ledger().total();
        assert!(
            (total - reference.total_cost()).abs() < 1e-9,
            "engine session ${total} vs batch ${}",
            reference.total_cost()
        );
        // and the per-stream ledger equals the whole ledger (one session)
        assert!((engine.stream_ledger(0).total() - total).abs() < 1e-12);
    }

    #[test]
    fn profiles_generate_finite_series() {
        let mut rng = Rng::new(3);
        for p in [
            SeriesProfile::Noisy { level: 10.0 },
            SeriesProfile::Oscillatory { period: 32.0 },
            SeriesProfile::Mixed { p_oscillatory: 0.5 },
        ] {
            let s = generate_series(p, 128, &mut rng);
            assert_eq!(s.len(), 128);
            assert!(s.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn with_shift_records_the_shift() {
        let spec = StreamSpec::new(0, model(100, 5), SeriesProfile::Noisy { level: 1.0 });
        assert_eq!(spec.shift, None);
        let shifted = spec.with_shift(40, 1000.0);
        assert_eq!(shifted.shift, Some(ScoreShift { at: 40, boost: 1000.0 }));
    }

    #[test]
    fn session_spec_carries_economics_and_mode() {
        let spec = StreamSpec::new(3, model(100, 5), SeriesProfile::Noisy { level: 1.0 });
        let s = spec.session_spec(true);
        assert!(s.naive);
        assert_eq!(s.n, 100);
        assert_eq!(s.k, 5);
        assert_eq!(s.tier_costs.as_ref().unwrap().len(), 2);
        assert!(s.include_rent);
    }
}
