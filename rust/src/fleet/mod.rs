//! `fleet` — a capacity-aware multi-stream scheduler that runs many
//! concurrent top-K workloads against shared tiered storage.
//!
//! The paper's model is one stream with unbounded tiers; a production
//! service multiplexes many heterogeneous scenarios (each with its own N,
//! K, interestingness profile, and economics) over hot storage with a hard
//! capacity. This subsystem brings that regime into the codebase:
//!
//! - [`arbiter`] uses the closed-form analytic model as an *allocation
//!   oracle*: each stream's expected hot-tier occupancy (paper eq. 15)
//!   yields its demand; quotas are split proportionally when aggregate
//!   demand exceeds capacity; each stream's changeover parameter is
//!   recomputed under its shrunken budget
//!   ([`crate::cost::optimal_r_budgeted`]). Over-quota writes degrade to
//!   cold placement — never rejected. Since ADR-002 the math lives in
//!   [`crate::engine::arbiter`] (where it is also re-run online); this
//!   module keeps the static admission-time surface.
//! - [`scheduler`] runs the streams on a worker pool with bounded channels
//!   (the [`crate::pipeline`] thread topology), placing through one
//!   [`crate::engine::StreamSession`] per stream over a shared
//!   capacity-limited [`crate::storage::StorageBackend`].
//! - [`FleetMode::Naive`] is the ablation baseline: capacity-oblivious
//!   per-stream optima with reactive oldest-first demotion on contention —
//!   the shared-cache behaviour the arbiter is designed to beat (see the
//!   `fleet` experiment, `shptier exp --id fleet`).
//!
//! See `docs/adr/ADR-001-fleet-subsystem.md` for the design rationale and
//! `docs/adr/ADR-002-engine-api.md` for the engine port.

pub mod arbiter;
pub mod capacity;
pub mod report;
pub mod scheduler;
pub mod stream;

pub use arbiter::{arbitrate, arbitrate_full, arbitrate_with, Arbitration, StreamPlan};
pub use capacity::allocate_proportional;
pub use report::{FleetReport, StreamReport};
pub use scheduler::{run_fleet, FleetConfig, FleetMode};
pub use stream::{generate_series, ScoreShift, SeriesProfile, StreamSpec, COLD, HOT};

use crate::cost::{CostModel, PerDocCosts};

/// Build a deterministic demo fleet of `m` heterogeneous streams.
///
/// Streams cycle through three economy classes (all transaction-dominated,
/// rent excluded, hot tier = A):
///
/// 0. *balanced*: hot cheap to write, dear to read → interior r*/N ≈ 0.57;
/// 1. *hot-hungry*: hot dominates everywhere → r* ≈ N (demand = K);
/// 2. *cold-leaning*: small interior optimum r*/N = 0.2.
///
/// With `heterogeneous`, K and N are additionally scaled per class so
/// demand, value, and stream length all differ; otherwise every stream is
/// class 0 with the base geometry. `salt` perturbs the profile mix only.
pub fn demo_fleet(
    m: usize,
    n_per_stream: u64,
    k_base: u64,
    heterogeneous: bool,
    salt: u64,
) -> Vec<StreamSpec> {
    let classes = [
        (
            PerDocCosts { write: 1.0, read: 4.0, rent_window: 0.0 },
            PerDocCosts { write: 3.0, read: 0.5, rent_window: 0.0 },
        ),
        (
            PerDocCosts { write: 0.5, read: 1.0, rent_window: 0.0 },
            PerDocCosts { write: 2.5, read: 2.0, rent_window: 0.0 },
        ),
        (
            PerDocCosts { write: 1.0, read: 2.0, rent_window: 0.0 },
            PerDocCosts { write: 1.2, read: 1.0, rent_window: 0.0 },
        ),
    ];
    (0..m)
        .map(|i| {
            let class = if heterogeneous { i % classes.len() } else { 0 };
            let (a, b) = classes[class];
            let (n_mul, k_mul) = if heterogeneous {
                match class {
                    0 => (1, 1),
                    1 => (1, 2),
                    _ => (2, 1),
                }
            } else {
                (1, 1)
            };
            let n = n_per_stream * n_mul;
            let k = (k_base * k_mul).clamp(1, n);
            let profile = match (i as u64 + salt) % 3 {
                0 => SeriesProfile::Mixed { p_oscillatory: 0.3 },
                1 => SeriesProfile::Oscillatory { period: 32.0 },
                _ => SeriesProfile::Noisy { level: 12.0 },
            };
            StreamSpec::new(
                i as u64,
                CostModel::new(n, k, a, b).with_rent(false),
                profile,
            )
        })
        .collect()
}

/// Build a deterministic rent-dominated demo fleet of `m` streams — the
/// case-study-2 economy shape at fleet scale: the hot tier writes and
/// reads for free but charges dearly for occupancy (EFS-like), the cold
/// tier is the reverse (S3-like), rent included. The DO_MIGRATE closed
/// form has an interior optimum at `r*/N = w_B / (rent_A − rent_B) = 0.2`
/// and beats the best keep-family parameter — the regime the migrate
/// family exists for. `salt` perturbs the interestingness profile mix
/// only (economics stay fixed so the family comparison is clean).
pub fn rent_dominated_fleet(
    m: usize,
    n_per_stream: u64,
    k_base: u64,
    salt: u64,
) -> Vec<StreamSpec> {
    let a = PerDocCosts { write: 0.0, read: 0.0, rent_window: 2.0 };
    let b = PerDocCosts { write: 0.4, read: 0.01, rent_window: 0.1 };
    (0..m)
        .map(|i| {
            let n = n_per_stream.max(1);
            let k = k_base.clamp(1, n);
            let profile = match (i as u64 + salt) % 3 {
                0 => SeriesProfile::Mixed { p_oscillatory: 0.3 },
                1 => SeriesProfile::Oscillatory { period: 32.0 },
                _ => SeriesProfile::Noisy { level: 12.0 },
            };
            StreamSpec::new(i as u64, CostModel::new(n, k, a, b), profile)
        })
        .collect()
}

/// Build a deterministic skewed-length demo fleet of `m` streams: the
/// class-0 balanced economy of [`demo_fleet`] (interior `r*/N ≈ 0.57`,
/// rent excluded) with every fourth stream `8×` longer than the base.
/// The length skew is the work-stealing scheduler's stress shape
/// (ADR-008): a fixed `id % workers` partition strands the long streams
/// on a few workers while the rest idle, whereas deque stealing
/// rebalances them — `benches/fleet_throughput.rs` sweeps worker counts
/// over exactly this fleet and asserts the report digest never moves.
/// `salt` perturbs the interestingness profile mix only.
pub fn skewed_fleet(m: usize, n_base: u64, k_base: u64, salt: u64) -> Vec<StreamSpec> {
    let a = PerDocCosts { write: 1.0, read: 4.0, rent_window: 0.0 };
    let b = PerDocCosts { write: 3.0, read: 0.5, rent_window: 0.0 };
    (0..m)
        .map(|i| {
            let n = n_base.max(1) * if i % 4 == 0 { 8 } else { 1 };
            let k = k_base.clamp(1, n);
            let profile = match (i as u64 + salt) % 3 {
                0 => SeriesProfile::Mixed { p_oscillatory: 0.3 },
                1 => SeriesProfile::Oscillatory { period: 32.0 },
                _ => SeriesProfile::Noisy { level: 12.0 },
            };
            StreamSpec::new(
                i as u64,
                CostModel::new(n, k, a, b).with_rent(false),
                profile,
            )
        })
        .collect()
}

/// Build a deterministic drift-demo fleet of `m` streams (experiment
/// E-DRIFT, ADR-007). Every stream runs the class-0 balanced economy of
/// [`demo_fleet`] (interior `r*/N ≈ 0.57`, rent excluded) with the usual
/// salted profile mix; with `shift_at = Some(s)` each stream's scores get
/// a flat `+1000.0` boost from document `s` onward, so post-shift
/// documents dominate the top-K and the a-priori secretary admission law
/// breaks mid-stream. `shift_at = None` is the no-drift control fleet
/// (identical economics and seeds, no shift).
pub fn drift_fleet(
    m: usize,
    n_per_stream: u64,
    k_base: u64,
    shift_at: Option<u64>,
    salt: u64,
) -> Vec<StreamSpec> {
    let a = PerDocCosts { write: 1.0, read: 4.0, rent_window: 0.0 };
    let b = PerDocCosts { write: 3.0, read: 0.5, rent_window: 0.0 };
    (0..m)
        .map(|i| {
            let n = n_per_stream.max(1);
            let k = k_base.clamp(1, n);
            let profile = match (i as u64 + salt) % 3 {
                0 => SeriesProfile::Mixed { p_oscillatory: 0.3 },
                1 => SeriesProfile::Oscillatory { period: 32.0 },
                _ => SeriesProfile::Noisy { level: 12.0 },
            };
            let spec = StreamSpec::new(
                i as u64,
                CostModel::new(n, k, a, b).with_rent(false),
                profile,
            );
            match shift_at {
                Some(at) => spec.with_shift(at, 1000.0),
                None => spec,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demo_fleet_shapes() {
        let specs = demo_fleet(7, 400, 10, true, 0);
        assert_eq!(specs.len(), 7);
        for (i, s) in specs.iter().enumerate() {
            assert_eq!(s.id, i as u64);
            assert!(s.model.k >= 1 && s.model.k <= s.model.n);
        }
        // heterogeneity: at least two distinct K values and N values
        let ks: std::collections::BTreeSet<u64> = specs.iter().map(|s| s.model.k).collect();
        let ns: std::collections::BTreeSet<u64> = specs.iter().map(|s| s.model.n).collect();
        assert!(ks.len() >= 2);
        assert!(ns.len() >= 2);

        let homo = demo_fleet(4, 400, 10, false, 0);
        assert!(homo.iter().all(|s| s.model.k == 10 && s.model.n == 400));
    }

    #[test]
    fn demo_fleet_demands_are_positive() {
        for s in demo_fleet(6, 500, 8, true, 2) {
            assert!(crate::cost::hot_demand(&s.model, false) >= 1, "stream {}", s.id);
        }
    }

    #[test]
    fn skewed_fleet_shapes() {
        let specs = skewed_fleet(6, 100, 8, 2);
        assert_eq!(specs.len(), 6);
        for (i, s) in specs.iter().enumerate() {
            assert_eq!(s.id, i as u64);
            let expect_n = if i % 4 == 0 { 800 } else { 100 };
            assert_eq!(s.model.n, expect_n, "stream {i}");
            assert_eq!(s.model.k, 8);
            assert!(!s.model.include_rent);
            assert!(s.shift.is_none());
        }
        // the skew is real: the long tail dominates a fixed partition
        let total: u64 = specs.iter().map(|s| s.model.n).sum();
        assert_eq!(total, 2 * 800 + 4 * 100);
    }

    #[test]
    fn drift_fleet_shapes_and_shift() {
        let shifted = drift_fleet(4, 1_000, 8, Some(400), 1);
        assert_eq!(shifted.len(), 4);
        for (i, s) in shifted.iter().enumerate() {
            assert_eq!(s.id, i as u64);
            assert!(!s.model.include_rent);
            assert_eq!(s.shift, Some(ScoreShift { at: 400, boost: 1000.0 }));
        }
        let control = drift_fleet(4, 1_000, 8, None, 1);
        assert!(control.iter().all(|s| s.shift.is_none()));
        // identical apart from the shift, so the control is a fair baseline
        for (a, b) in shifted.iter().zip(control.iter()) {
            assert_eq!(a.profile, b.profile);
            assert_eq!(a.model.n, b.model.n);
            assert_eq!(a.model.k, b.model.k);
        }
    }

    #[test]
    fn rent_dominated_fleet_prefers_the_migrate_family() {
        use crate::cost::{expected_cost, optimal_r, Strategy};
        for s in rent_dominated_fleet(4, 2000, 32, 0) {
            assert!(s.model.include_rent);
            let mig = optimal_r(&s.model, true);
            assert!(mig.interior, "migrate optimum must be interior");
            // the DO_MIGRATE optimum undercuts both single-tier baselines
            // and the best keep-family parameter
            let all_b = expected_cost(&s.model, Strategy::AllB).total();
            let all_a = expected_cost(&s.model, Strategy::AllA).total();
            let keep = optimal_r(&s.model, false);
            assert!(mig.cost < all_b, "stream {}: {} !< AllB {all_b}", s.id, mig.cost);
            assert!(mig.cost < all_a, "stream {}: {} !< AllA {all_a}", s.id, mig.cost);
            assert!(
                mig.cost < keep.cost,
                "stream {}: migrate {} !< keep {}",
                s.id,
                mig.cost,
                keep.cost
            );
        }
    }
}
