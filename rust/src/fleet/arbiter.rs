//! The fleet arbiter: turns per-stream analytic demand into hot-tier
//! quotas and budget-constrained changeover parameters.
//!
//! For each stream the arbiter evaluates the closed-form optimum
//! ([`crate::cost::optimal_r`]) and its hot-tier demand `min(r*, K)`
//! ([`crate::cost::hot_demand`]). If aggregate demand fits the shared hot
//! capacity every stream runs unconstrained; otherwise quotas are assigned
//! proportionally to demand ([`super::capacity::allocate_proportional`])
//! and each stream's changeover parameter is *recomputed under its
//! shrunken budget* ([`crate::cost::optimal_r_budgeted`]) — over-quota
//! documents degrade to cold placement rather than being rejected.

use super::capacity::{allocate_proportional, peak_occupancy};
use super::stream::StreamSpec;
use crate::cost::{budget_clamp, optimal_r};

/// Per-stream slice of an arbitration outcome.
#[derive(Debug, Clone, Copy)]
pub struct StreamPlan {
    /// Unconstrained optimal changeover index.
    pub r_unconstrained: u64,
    /// Hot-tier demand `min(r*, K)` in resident documents.
    pub demand: u64,
    /// Assigned hot quota (≤ demand).
    pub quota: u64,
    /// Budget-constrained changeover index under the quota.
    pub r_budgeted: u64,
    /// Analytic expected cost at the unconstrained optimum.
    pub analytic_unconstrained: f64,
    /// Analytic expected cost at the budgeted parameter.
    pub analytic_budgeted: f64,
}

/// Outcome of arbitrating a fleet against a hot-tier capacity.
#[derive(Debug, Clone)]
pub struct Arbitration {
    pub hot_capacity: u64,
    pub plans: Vec<StreamPlan>,
    /// Σ demand across streams.
    pub aggregate_demand: u64,
    /// Whether aggregate demand exceeds the capacity (quotas bind).
    pub oversubscribed: bool,
}

impl Arbitration {
    /// Σ analytic expected cost at the unconstrained optima (the infeasible
    /// "everyone owns the whole tier" lower bound).
    pub fn analytic_unconstrained_total(&self) -> f64 {
        self.plans.iter().map(|p| p.analytic_unconstrained).sum()
    }

    /// Σ analytic expected cost at the budgeted parameters (what the
    /// arbitrated fleet should measure, in expectation).
    pub fn analytic_budgeted_total(&self) -> f64 {
        self.plans.iter().map(|p| p.analytic_budgeted).sum()
    }
}

/// Compute quotas and budgeted changeover parameters for `specs` sharing
/// `hot_capacity` resident slots of tier A.
pub fn arbitrate(specs: &[StreamSpec], hot_capacity: u64) -> Arbitration {
    // one optimizer run per stream; demand and the budget clamp reuse it
    let unconstrained: Vec<_> = specs.iter().map(|s| optimal_r(&s.model, false)).collect();
    let demands: Vec<u64> = specs
        .iter()
        .zip(unconstrained.iter())
        .map(|(s, unc)| peak_occupancy(unc.r, s.model.k))
        .collect();
    let aggregate_demand: u64 = demands.iter().sum();
    let quotas = allocate_proportional(hot_capacity, &demands);

    let plans = specs
        .iter()
        .zip(unconstrained.iter())
        .zip(demands.iter().zip(quotas.iter()))
        .map(|((spec, unc), (&demand, &quota))| {
            let budgeted = budget_clamp(&spec.model, false, *unc, quota);
            StreamPlan {
                r_unconstrained: unc.r,
                demand,
                quota,
                r_budgeted: budgeted.r,
                analytic_unconstrained: unc.cost,
                analytic_budgeted: budgeted.cost,
            }
        })
        .collect();

    Arbitration {
        hot_capacity,
        plans,
        aggregate_demand,
        oversubscribed: aggregate_demand > hot_capacity,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::{CostModel, PerDocCosts};
    use crate::fleet::stream::SeriesProfile;

    fn spec(id: u64, n: u64, k: u64) -> StreamSpec {
        StreamSpec::new(
            id,
            CostModel::new(
                n,
                k,
                PerDocCosts { write: 1.0, read: 4.0, rent_window: 0.0 },
                PerDocCosts { write: 3.0, read: 0.5, rent_window: 0.0 },
            )
            .with_rent(false),
            SeriesProfile::Mixed { p_oscillatory: 0.5 },
        )
    }

    #[test]
    fn ample_capacity_leaves_streams_unconstrained() {
        let specs: Vec<_> = (0..3).map(|i| spec(i, 1000, 20)).collect();
        let arb = arbitrate(&specs, 10_000);
        assert!(!arb.oversubscribed);
        for p in &arb.plans {
            assert_eq!(p.quota, p.demand);
            assert_eq!(p.r_budgeted, p.r_unconstrained);
            assert!((p.analytic_budgeted - p.analytic_unconstrained).abs() < 1e-12);
        }
    }

    #[test]
    fn oversubscription_binds_quotas_and_raises_cost() {
        let specs: Vec<_> = (0..4).map(|i| spec(i, 1000, 50)).collect();
        let arb = arbitrate(&specs, 40); // demand = 4 × min(r*, 50) ≫ 40
        assert!(arb.oversubscribed);
        let total_quota: u64 = arb.plans.iter().map(|p| p.quota).sum();
        assert!(total_quota <= 40);
        for p in &arb.plans {
            assert!(p.quota < p.demand);
            assert!(p.r_budgeted <= p.quota);
            assert!(p.analytic_budgeted >= p.analytic_unconstrained);
        }
        assert!(arb.analytic_budgeted_total() > arb.analytic_unconstrained_total());
    }

    #[test]
    fn heterogeneous_demand_splits_proportionally() {
        let specs = vec![spec(0, 1000, 60), spec(1, 1000, 20), spec(2, 1000, 20)];
        let arb = arbitrate(&specs, 50);
        // demands 60/20/20 (r* interior and > K) → quotas 30/10/10
        assert_eq!(arb.plans[0].demand, 60);
        assert_eq!(arb.plans[0].quota, 30);
        assert_eq!(arb.plans[1].quota, 10);
        assert_eq!(arb.plans[2].quota, 10);
    }
}
