//! The fleet arbiter: compatibility wrapper over the engine's
//! [`crate::engine::ProportionalArbiter`] for the two-tier fleet.
//!
//! Since the `shptier::engine` redesign (ADR-002) the quota math — per-
//! stream closed-form optima, demands `min(r*, K)`, proportional
//! largest-remainder allocation, budget-clamped changeover parameters —
//! lives in [`crate::engine::arbiter`], where it generalizes to N-tier
//! topologies and is re-run online on every session open/close. This
//! module keeps the original static two-tier surface (`arbitrate` over a
//! spec list, one shot) that the fleet reports and the E-FLEET experiment
//! are written against; its numbers are bit-identical to the engine's
//! verdict at admission time.

use super::stream::{StreamSpec, HOT};
use crate::engine::{Arbiter as _, ProportionalArbiter, SessionSnapshot, TierTopology};
use crate::policy::PlanFamily;

/// Per-stream slice of an arbitration outcome.
#[derive(Debug, Clone, Copy)]
pub struct StreamPlan {
    /// The strategy family the arbiter resolved for the stream.
    pub family: PlanFamily,
    /// Unconstrained optimal changeover index.
    pub r_unconstrained: u64,
    /// Hot-tier demand `min(r*, K)` in resident documents.
    pub demand: u64,
    /// Assigned hot quota (≤ demand).
    pub quota: u64,
    /// Budget-constrained changeover index under the quota.
    pub r_budgeted: u64,
    /// Analytic expected cost at the unconstrained optimum.
    pub analytic_unconstrained: f64,
    /// Analytic expected cost at the budgeted parameter.
    pub analytic_budgeted: f64,
}

/// Outcome of arbitrating a fleet against a hot-tier capacity.
#[derive(Debug, Clone)]
pub struct Arbitration {
    pub hot_capacity: u64,
    pub plans: Vec<StreamPlan>,
    /// Σ demand across streams.
    pub aggregate_demand: u64,
    /// Whether aggregate demand exceeds the capacity (quotas bind).
    pub oversubscribed: bool,
}

impl Arbitration {
    /// Σ analytic expected cost at the unconstrained optima (the infeasible
    /// "everyone owns the whole tier" lower bound).
    pub fn analytic_unconstrained_total(&self) -> f64 {
        self.plans.iter().map(|p| p.analytic_unconstrained).sum()
    }

    /// Σ analytic expected cost at the budgeted parameters (what the
    /// arbitrated fleet should measure, in expectation).
    pub fn analytic_budgeted_total(&self) -> f64 {
        self.plans.iter().map(|p| p.analytic_budgeted).sum()
    }
}

/// The admission-time [`SessionSnapshot`] of one fleet stream under a
/// strategy family (nothing observed, nothing resident).
pub(crate) fn snapshot_of(spec: &StreamSpec, family: PlanFamily) -> SessionSnapshot {
    SessionSnapshot::fresh(
        spec.id,
        spec.model.n,
        spec.model.k,
        vec![spec.model.a, spec.model.b],
        spec.model.include_rent,
        family,
    )
}

/// Compute quotas and budgeted changeover parameters for `specs` sharing
/// `hot_capacity` resident slots of tier A (static admission-time view of
/// the engine's online arbitration), keep family.
pub fn arbitrate(specs: &[StreamSpec], hot_capacity: u64) -> Arbitration {
    arbitrate_with(specs, hot_capacity, PlanFamily::Keep)
}

/// [`arbitrate`] with an explicit strategy family for every stream
/// (`Auto` resolves per stream to the analytically cheaper family).
pub fn arbitrate_with(
    specs: &[StreamSpec],
    hot_capacity: u64,
    family: PlanFamily,
) -> Arbitration {
    arbitrate_full(specs, hot_capacity, family, crate::topk::SelectorKind::Bounded)
}

/// [`arbitrate_with`] plus an explicit admission selector (ADR-010): the
/// snapshots carry the selector, so a log-memory fleet's quotas are
/// derived at the slack-adjusted K′ — exactly what the engine computes
/// internally when the same selector rides the session specs.
pub fn arbitrate_full(
    specs: &[StreamSpec],
    hot_capacity: u64,
    family: PlanFamily,
    selector: crate::topk::SelectorKind,
) -> Arbitration {
    if specs.is_empty() {
        return Arbitration {
            hot_capacity,
            plans: Vec::new(),
            aggregate_demand: 0,
            oversubscribed: false,
        };
    }
    let capacity = usize::try_from(hot_capacity).unwrap_or(usize::MAX);
    let topology = TierTopology::two_tier(specs[0].model.a, specs[0].model.b)
        .with_capacity(HOT, Some(capacity));
    let snapshots: Vec<SessionSnapshot> = specs
        .iter()
        .map(|s| snapshot_of(s, family).with_selector(selector))
        .collect();
    let assignments = ProportionalArbiter.arbitrate(&snapshots, &topology);
    let plans: Vec<StreamPlan> = assignments
        .iter()
        .map(|a| StreamPlan {
            family: a.family,
            r_unconstrained: a.unconstrained.r(),
            demand: a.demand[HOT.0],
            quota: a.quota[HOT.0].unwrap_or(0),
            r_budgeted: a.plan.r(),
            analytic_unconstrained: a.analytic_unconstrained,
            analytic_budgeted: a.analytic_budgeted,
        })
        .collect();
    let aggregate_demand: u64 = plans.iter().map(|p| p.demand).sum();
    Arbitration {
        hot_capacity,
        plans,
        aggregate_demand,
        oversubscribed: aggregate_demand > hot_capacity,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::{CostModel, PerDocCosts};
    use crate::fleet::stream::SeriesProfile;

    fn spec(id: u64, n: u64, k: u64) -> StreamSpec {
        StreamSpec::new(
            id,
            CostModel::new(
                n,
                k,
                PerDocCosts { write: 1.0, read: 4.0, rent_window: 0.0 },
                PerDocCosts { write: 3.0, read: 0.5, rent_window: 0.0 },
            )
            .with_rent(false),
            SeriesProfile::Mixed { p_oscillatory: 0.5 },
        )
    }

    #[test]
    fn ample_capacity_leaves_streams_unconstrained() {
        let specs: Vec<_> = (0..3).map(|i| spec(i, 1000, 20)).collect();
        let arb = arbitrate(&specs, 10_000);
        assert!(!arb.oversubscribed);
        for p in &arb.plans {
            assert_eq!(p.quota, p.demand);
            assert_eq!(p.r_budgeted, p.r_unconstrained);
            assert!((p.analytic_budgeted - p.analytic_unconstrained).abs() < 1e-12);
        }
    }

    #[test]
    fn oversubscription_binds_quotas_and_raises_cost() {
        let specs: Vec<_> = (0..4).map(|i| spec(i, 1000, 50)).collect();
        let arb = arbitrate(&specs, 40); // demand = 4 × min(r*, 50) ≫ 40
        assert!(arb.oversubscribed);
        let total_quota: u64 = arb.plans.iter().map(|p| p.quota).sum();
        assert!(total_quota <= 40);
        for p in &arb.plans {
            assert!(p.quota < p.demand);
            assert!(p.r_budgeted <= p.quota);
            assert!(p.analytic_budgeted >= p.analytic_unconstrained);
        }
        assert!(arb.analytic_budgeted_total() > arb.analytic_unconstrained_total());
    }

    #[test]
    fn heterogeneous_demand_splits_proportionally() {
        let specs = vec![spec(0, 1000, 60), spec(1, 1000, 20), spec(2, 1000, 20)];
        let arb = arbitrate(&specs, 50);
        // demands 60/20/20 (r* interior and > K) → quotas 30/10/10
        assert_eq!(arb.plans[0].demand, 60);
        assert_eq!(arb.plans[0].quota, 30);
        assert_eq!(arb.plans[1].quota, 10);
        assert_eq!(arb.plans[2].quota, 10);
    }

    #[test]
    fn matches_closed_form_budget_clamp() {
        // parity with the pre-engine arbiter: every number reproduces the
        // optimal_r / budget_clamp closed forms directly
        let specs: Vec<_> = (0..4).map(|i| spec(i, 1000, 50)).collect();
        let arb = arbitrate(&specs, 40);
        for (s, p) in specs.iter().zip(arb.plans.iter()) {
            let unc = crate::cost::optimal_r(&s.model, false);
            assert_eq!(p.r_unconstrained, unc.r);
            assert_eq!(p.demand, unc.r.min(s.model.k));
            let clamped = crate::cost::budget_clamp(&s.model, false, unc, p.quota);
            assert_eq!(p.r_budgeted, clamped.r);
            assert!((p.analytic_budgeted - clamped.cost).abs() < 1e-12);
            assert!((p.analytic_unconstrained - unc.cost).abs() < 1e-12);
        }
    }

    #[test]
    fn empty_fleet_is_trivial() {
        let arb = arbitrate(&[], 16);
        assert!(arb.plans.is_empty());
        assert!(!arb.oversubscribed);
        assert_eq!(arb.aggregate_demand, 0);
    }

    #[test]
    fn migrate_family_reproduces_the_migrate_closed_form() {
        // rent-dominated stream: the migrate r* comes from eq. 21 and the
        // budget clamp runs against the same family
        let specs: Vec<_> = (0..3)
            .map(|i| {
                StreamSpec::new(
                    i,
                    CostModel::new(
                        2000,
                        32,
                        PerDocCosts { write: 0.0, read: 0.0, rent_window: 2.0 },
                        PerDocCosts { write: 0.4, read: 0.01, rent_window: 0.1 },
                    ),
                    SeriesProfile::Mixed { p_oscillatory: 0.5 },
                )
            })
            .collect();
        let arb = arbitrate_with(&specs, 1000, PlanFamily::Migrate);
        for (s, p) in specs.iter().zip(arb.plans.iter()) {
            assert_eq!(p.family, PlanFamily::Migrate);
            let unc = crate::cost::optimal_r(&s.model, true);
            assert_eq!(p.r_unconstrained, unc.r);
            assert_eq!(p.demand, unc.r.min(s.model.k));
            assert!((p.analytic_unconstrained - unc.cost).abs() < 1e-12);
        }
        // under pressure the clamp prices the *migrate* family
        let tight = arbitrate_with(&specs, 12, PlanFamily::Migrate);
        for (s, p) in specs.iter().zip(tight.plans.iter()) {
            let clamped = crate::cost::optimal_r_budgeted(&s.model, true, p.quota);
            assert_eq!(p.r_budgeted, clamped.r);
            assert!((p.analytic_budgeted - clamped.cost).abs() < 1e-12);
        }
    }
}
