//! Probability-model diagnostics: does a real interestingness trace behave
//! like the randomly-ordered stream the paper assumes (§IX "So long as
//! documents are sorted randomly ...")?
//!
//! The key check, used for Fig. 8 and the ordering ablation (A2): compare a
//! trace's empirical cumulative-write curve against eqs. (11)–(12), and
//! quantify order randomness with rank autocorrelation.

use crate::cost::expected_writes;
use crate::shp::overwrite::run_overwrite_scores;

/// Comparison of an empirical cumulative-write curve against the analytic
/// record-process prediction.
#[derive(Debug, Clone)]
pub struct WriteCurveFit {
    /// Empirical cumulative writes after each document.
    pub empirical: Vec<u64>,
    /// Analytic expectation at each index (eqs. 11–12, exact harmonic form).
    pub analytic: Vec<f64>,
    /// max_i |empirical − analytic| / analytic (over i ≥ K).
    pub max_rel_err: f64,
    /// Final-count relative error.
    pub final_rel_err: f64,
}

/// Run the K-overwrite process on a score trace and fit the analytic curve.
pub fn fit_write_curve(scores: &[f64], k: usize) -> WriteCurveFit {
    let outcome = run_overwrite_scores(scores, k);
    // Incremental harmonic recurrence: W(i+1) = W(i) + K/(i+1) for i ≥ K —
    // O(N) for the whole curve instead of O(N) per point (§Perf).
    let analytic: Vec<f64> = {
        let kf = k as f64;
        let mut acc = 0.0f64;
        (0..scores.len())
            .map(|i| {
                if i < k {
                    acc = (i + 1) as f64;
                } else {
                    acc += kf / (i + 1) as f64;
                }
                acc
            })
            .collect()
    };
    debug_assert!(
        scores.is_empty()
            || (analytic.last().unwrap()
                - expected_writes(scores.len() as u64, k as u64))
            .abs()
                < 1e-6 * analytic.last().unwrap().max(1.0)
    );
    let mut max_rel = 0f64;
    for i in k..scores.len() {
        let rel = (outcome.cumulative_writes[i] as f64 - analytic[i]).abs() / analytic[i];
        max_rel = max_rel.max(rel);
    }
    let final_rel = if scores.is_empty() {
        0.0
    } else {
        let last = scores.len() - 1;
        (outcome.cumulative_writes[last] as f64 - analytic[last]).abs() / analytic[last]
    };
    WriteCurveFit {
        empirical: outcome.cumulative_writes,
        analytic,
        max_rel_err: max_rel,
        final_rel_err: final_rel,
    }
}

/// Spearman rank correlation between stream position and score — ≈0 for a
/// randomly ordered stream, ±1 for sorted streams. This is the cheap a
/// priori test for the model's validity on a given interestingness trace.
pub fn spearman_position_correlation(scores: &[f64]) -> f64 {
    let n = scores.len();
    if n < 2 {
        return 0.0;
    }
    // rank of each score (average ranks for ties are unnecessary here:
    // deterministic tie-break by index keeps the statistic well-defined)
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        scores[a]
            .partial_cmp(&scores[b])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut rank = vec![0f64; n];
    for (r, &i) in order.iter().enumerate() {
        rank[i] = r as f64;
    }
    // Pearson on (position, rank)
    let nf = n as f64;
    let mean = (nf - 1.0) / 2.0;
    let mut num = 0f64;
    let mut den_a = 0f64;
    let mut den_b = 0f64;
    for (i, &r) in rank.iter().enumerate() {
        let da = i as f64 - mean;
        let db = r - mean;
        num += da * db;
        den_a += da * da;
        den_b += db * db;
    }
    if den_a == 0.0 || den_b == 0.0 {
        0.0
    } else {
        num / (den_a * den_b).sqrt()
    }
}

/// Empirical per-position write rate over `reps` shuffles of the same score
/// multiset — validates eq. (10) for a *given* score distribution
/// (ties and duplicates included), isolating ordering effects.
pub fn empirical_write_rate(
    scores: &[f64],
    k: usize,
    reps: u64,
    rng: &mut crate::util::Rng,
) -> Vec<f64> {
    let n = scores.len();
    let mut counts = vec![0u64; n];
    let mut work = scores.to_vec();
    for _ in 0..reps {
        rng.shuffle(&mut work);
        let o = run_overwrite_scores(&work, k);
        let mut prev = 0u64;
        for (i, &c) in o.cumulative_writes.iter().enumerate() {
            if c > prev {
                counts[i] += 1;
            }
            prev = c;
        }
    }
    counts.iter().map(|&c| c as f64 / reps as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::p_write;
    use crate::util::Rng;

    #[test]
    fn random_trace_fits_analytic_curve() {
        let mut rng = Rng::new(31);
        let scores: Vec<f64> = (0..20_000).map(|_| rng.next_f64()).collect();
        let fit = fit_write_curve(&scores, 100);
        assert!(
            fit.final_rel_err < 0.10,
            "final rel err {}",
            fit.final_rel_err
        );
    }

    #[test]
    fn sorted_trace_breaks_the_model() {
        // ascending scores: every document is a record → writes = N ≫ analytic
        let scores: Vec<f64> = (0..5000).map(|i| i as f64).collect();
        let fit = fit_write_curve(&scores, 10);
        assert!(fit.final_rel_err > 5.0, "err {}", fit.final_rel_err);
    }

    #[test]
    fn spearman_detects_order() {
        let mut rng = Rng::new(17);
        let random: Vec<f64> = (0..5000).map(|_| rng.next_f64()).collect();
        assert!(spearman_position_correlation(&random).abs() < 0.05);
        let asc: Vec<f64> = (0..5000).map(|i| i as f64).collect();
        assert!((spearman_position_correlation(&asc) - 1.0).abs() < 1e-9);
        let desc: Vec<f64> = (0..5000).map(|i| -(i as f64)).collect();
        assert!((spearman_position_correlation(&desc) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn empirical_rate_matches_eq10() {
        let mut rng = Rng::new(23);
        let scores: Vec<f64> = (0..400).map(|_| rng.next_f64()).collect();
        let rate = empirical_write_rate(&scores, 5, 2000, &mut rng);
        for &i in &[0usize, 4, 20, 100, 399] {
            let expect = p_write(i as u64, 5);
            assert!(
                (rate[i] - expect).abs() < 0.03 + 0.1 * expect,
                "i={i}: rate={} expect={expect}",
                rate[i]
            );
        }
    }

    #[test]
    fn spearman_edge_cases() {
        assert_eq!(spearman_position_correlation(&[]), 0.0);
        assert_eq!(spearman_position_correlation(&[1.0]), 0.0);
        // constant scores: ranks follow index → correlation 1 by tie-break,
        // but zero-variance guard yields a finite number
        let c = spearman_position_correlation(&[2.0; 100]);
        assert!(c.is_finite());
    }
}
