//! The secretary-hiring-problem processes the paper builds on:
//! Algorithm A (classic stopping, §V), Algorithm B (simple overwrite, §VI),
//! and diagnostics for the random-order assumption (§IX, Fig. 8).
//!
//! Algorithm C (the two-tier changeover strategies, §VII) is realized as
//! placement policies over the storage simulator — see [`crate::policy`]
//! and [`crate::storage`].

pub mod analysis;
pub mod classic;
pub mod overwrite;

pub use analysis::{
    empirical_write_rate, fit_write_curve, spearman_position_correlation, WriteCurveFit,
};
pub use classic::{
    optimal_r as classic_optimal_r, p_hire_best, p_hire_best_analytic, run_classic,
    ClassicOutcome,
};
pub use overwrite::{
    mean_cumulative_writes, mean_writes, run_overwrite, run_overwrite_scores, OverwriteOutcome,
};
