//! Algorithm A — the classic Secretary Hiring Problem (paper §V).
//!
//! N ranked candidates are interviewed in random order; after observing the
//! first `r − 1`, hire the first candidate beating the best of those.
//! Dynkin's optimal threshold is `r = N/e`, achieving
//! `P(best hired) → 1/e` and exactly one (irrevocable) "write" — paper
//! eqs. (2)–(4).

use crate::topk::{FullRankTracker, Scored};
use crate::util::Rng;

/// Outcome of one classic-SHP run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClassicOutcome {
    /// Index of the hired candidate (None if nobody beat the benchmark —
    /// by convention the last candidate is then taken).
    pub hired: u64,
    /// Whether the hired candidate was the overall best.
    pub hired_best: bool,
    /// Number of hires performed (always ≤ 1 in Algorithm A; kept for
    /// symmetry with Algorithm B statistics).
    pub writes: u64,
}

/// Run the classic stopping rule on a random permutation of N distinct
/// scores: observe `r.saturating_sub(1)` candidates, then hire the first
/// record-breaker.
pub fn run_classic(n: u64, r: u64, rng: &mut Rng) -> ClassicOutcome {
    assert!(n > 0);
    let perm = rng.permutation(n as usize);
    // perm[i] is the *rank-value* of candidate i: larger = better.
    let best_overall = (0..n).max_by_key(|&i| perm[i as usize]).unwrap();

    let observe = r.saturating_sub(1).min(n);
    let mut tracker = FullRankTracker::new();
    for i in 0..observe {
        tracker.insert(Scored::new(i, perm[i as usize] as f64));
    }
    for i in observe..n {
        let s = Scored::new(i, perm[i as usize] as f64);
        if tracker.is_record(s) || i == n - 1 {
            return ClassicOutcome {
                hired: i,
                hired_best: i == best_overall,
                writes: 1,
            };
        }
        tracker.insert(s);
    }
    // observe == n: forced to take the last
    ClassicOutcome {
        hired: n - 1,
        hired_best: n - 1 == best_overall,
        writes: 1,
    }
}

/// Monte-Carlo estimate of `P(hire the overall best)` for threshold `r`.
pub fn p_hire_best(n: u64, r: u64, reps: u64, rng: &mut Rng) -> f64 {
    let mut hits = 0u64;
    for _ in 0..reps {
        if run_classic(n, r, rng).hired_best {
            hits += 1;
        }
    }
    hits as f64 / reps as f64
}

/// The analytic success probability of threshold r (exact finite-N form):
/// `P(r) = (r−1)/N · Σ_{j=r}^{N} 1/(j−1)` for r > 1, and `1/N` for r ≤ 1.
pub fn p_hire_best_analytic(n: u64, r: u64) -> f64 {
    if n == 0 {
        return 0.0;
    }
    if r <= 1 {
        return 1.0 / n as f64;
    }
    let rr = r.min(n);
    let sum: f64 = (rr..=n).map(|j| 1.0 / (j - 1) as f64).sum();
    (rr - 1) as f64 / n as f64 * sum
}

/// Dynkin's optimal threshold `N/e`, rounded (paper eq. (2)).
pub fn optimal_r(n: u64) -> u64 {
    ((n as f64 / std::f64::consts::E).round() as u64).clamp(1, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_maximum_is_near_n_over_e() {
        let n = 1000u64;
        let (mut best_r, mut best_p) = (1, 0.0);
        for r in 1..=n {
            let p = p_hire_best_analytic(n, r);
            if p > best_p {
                best_p = p;
                best_r = r;
            }
        }
        let e_r = optimal_r(n);
        assert!(
            (best_r as i64 - e_r as i64).abs() <= 2,
            "argmax {best_r} vs N/e {e_r}"
        );
        assert!((best_p - 1.0 / std::f64::consts::E).abs() < 0.01);
    }

    #[test]
    fn monte_carlo_matches_one_over_e() {
        let mut rng = Rng::new(2019);
        let n = 200u64;
        let p = p_hire_best(n, optimal_r(n), 4000, &mut rng);
        assert!(
            (p - 1.0 / std::f64::consts::E).abs() < 0.03,
            "p={p} vs 1/e"
        );
    }

    #[test]
    fn monte_carlo_matches_analytic_at_various_r() {
        let mut rng = Rng::new(7);
        let n = 100u64;
        for r in [2u64, 10, 37, 60, 90] {
            let mc = p_hire_best(n, r, 4000, &mut rng);
            let an = p_hire_best_analytic(n, r);
            assert!((mc - an).abs() < 0.03, "r={r}: mc={mc} analytic={an}");
        }
    }

    #[test]
    fn always_exactly_one_write() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let o = run_classic(50, 19, &mut rng);
            assert_eq!(o.writes, 1);
            assert!(o.hired < 50);
        }
    }

    #[test]
    fn r_one_hires_first_record_which_is_first_candidate() {
        let mut rng = Rng::new(3);
        let o = run_classic(10, 1, &mut rng);
        assert_eq!(o.hired, 0);
    }
}
