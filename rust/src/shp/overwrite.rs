//! Algorithm B — "Simple Overwrite" (paper §VI): keep the best-so-far
//! (generally: top-K) by overwriting, guaranteeing the overall best is
//! retained (eq. 8) at an expected cost of `H_N` writes (eqs. 6–7), or the
//! exact record-process count for K > 1.

use crate::topk::{BoundedTopK, Eviction, Scored};
use crate::util::Rng;

/// Statistics of one Algorithm-B run.
#[derive(Debug, Clone, PartialEq)]
pub struct OverwriteOutcome {
    /// Total writes performed (accepts + replacements).
    pub writes: u64,
    /// Cumulative writes after each document (len N) — Fig. 8's y-axis.
    pub cumulative_writes: Vec<u64>,
    /// Final retained set (best first).
    pub retained: Vec<Scored>,
    /// Whether the overall best document was retained (must be true).
    pub saved_best: bool,
}

/// Run Algorithm B (one tier, capacity K) over an explicit score stream.
pub fn run_overwrite_scores(scores: &[f64], k: usize) -> OverwriteOutcome {
    let mut tracker = BoundedTopK::new(k);
    let mut writes = 0u64;
    let mut cumulative = Vec::with_capacity(scores.len());
    let mut best = f64::NEG_INFINITY;
    let mut best_idx = 0u64;
    for (i, &h) in scores.iter().enumerate() {
        if h > best {
            best = h;
            best_idx = i as u64;
        }
        match tracker.offer(Scored::new(i as u64, h)) {
            Eviction::Rejected => {}
            _ => writes += 1,
        }
        cumulative.push(writes);
    }
    let retained = tracker.sorted_desc();
    let saved_best = retained.iter().any(|s| s.index == best_idx);
    OverwriteOutcome { writes, cumulative_writes: cumulative, retained, saved_best }
}

/// Run Algorithm B over a fresh random-order stream (i.i.d. uniform scores).
pub fn run_overwrite(n: u64, k: usize, rng: &mut Rng) -> OverwriteOutcome {
    let scores: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
    run_overwrite_scores(&scores, k)
}

/// Monte-Carlo mean writes over `reps` runs.
pub fn mean_writes(n: u64, k: usize, reps: u64, rng: &mut Rng) -> f64 {
    let mut total = 0u64;
    for _ in 0..reps {
        total += run_overwrite(n, k, rng).writes;
    }
    total as f64 / reps as f64
}

/// Mean cumulative-writes curve over `reps` runs (for Fig. 8 overlays).
pub fn mean_cumulative_writes(n: u64, k: usize, reps: u64, rng: &mut Rng) -> Vec<f64> {
    let mut acc = vec![0f64; n as usize];
    for _ in 0..reps {
        let o = run_overwrite(n, k, rng);
        for (a, w) in acc.iter_mut().zip(o.cumulative_writes) {
            *a += w as f64;
        }
    }
    for a in acc.iter_mut() {
        *a /= reps as f64;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::{algorithm_b_expected_writes, expected_writes};
    use crate::util::math::EULER_MASCHERONI;

    #[test]
    fn always_saves_best_eq8() {
        let mut rng = Rng::new(11);
        for _ in 0..200 {
            let o = run_overwrite(200, 1, &mut rng);
            assert!(o.saved_best, "P(saving best) must be 1 (paper eq. 8)");
        }
    }

    #[test]
    fn k1_writes_match_harmonic_eq6_eq7() {
        let mut rng = Rng::new(42);
        let n = 1000u64;
        let mean = mean_writes(n, 1, 2000, &mut rng);
        let exact = algorithm_b_expected_writes(n);
        assert!((mean - exact).abs() < 0.15, "mean={mean} H_N={exact}");
        // and eq. (7)'s approximation ln N + 0.57722
        let approx = (n as f64).ln() + EULER_MASCHERONI;
        assert!((mean - approx).abs() < 0.2);
    }

    #[test]
    fn k_gt_1_matches_record_process() {
        let mut rng = Rng::new(21);
        let (n, k) = (800u64, 10usize);
        let mean = mean_writes(n, k, 800, &mut rng);
        let exact = expected_writes(n, k as u64);
        assert!(
            (mean - exact).abs() / exact < 0.03,
            "mean={mean} analytic={exact}"
        );
    }

    #[test]
    fn cumulative_curve_tracks_eq11_eq12() {
        let mut rng = Rng::new(77);
        let (n, k) = (2000u64, 100usize);
        let curve = mean_cumulative_writes(n, k, 300, &mut rng);
        // first K documents are always written (paper Fig. 8 note)
        assert!((curve[k - 1] - k as f64).abs() < 1e-9);
        for &i in &[150u64, 500, 1000, 1999] {
            let analytic = expected_writes(i + 1, k as u64);
            let got = curve[i as usize];
            assert!(
                (got - analytic).abs() / analytic < 0.03,
                "i={i}: mc={got} analytic={analytic}"
            );
        }
    }

    #[test]
    fn cumulative_is_monotone_and_bounded() {
        let mut rng = Rng::new(8);
        let o = run_overwrite(500, 7, &mut rng);
        for w in o.cumulative_writes.windows(2) {
            assert!(w[1] >= w[0] && w[1] - w[0] <= 1);
        }
        assert_eq!(o.retained.len(), 7);
        assert_eq!(*o.cumulative_writes.last().unwrap(), o.writes);
    }

    #[test]
    fn deterministic_stream_fixed_outcome() {
        // strictly increasing scores: every doc is a record → N writes
        let scores: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let o = run_overwrite_scores(&scores, 1);
        assert_eq!(o.writes, 100);
        // strictly decreasing: only the first doc is written
        let scores: Vec<f64> = (0..100).map(|i| -(i as f64)).collect();
        let o = run_overwrite_scores(&scores, 1);
        assert_eq!(o.writes, 1);
    }
}
