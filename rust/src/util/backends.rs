//! Test-support: the backend conformance harness (ADR-005).
//!
//! Every [`StorageBackend`] invariant should hold on every
//! implementation, so the integration suites (`engine_invariants`,
//! `backend_parity`, the conservation properties in
//! `property_invariants`) parametrize over ONE list of backends instead
//! of hand-copying sim/fs pairs: add a backend kind here and the whole
//! conformance surface runs against it.
//!
//! Like [`super::scratch`], this is test-support code compiled into the
//! library so unit suites and integration suites share one copy.

use crate::cost::PerDocCosts;
use crate::storage::{FsBackend, ObjectBackend, StorageBackend, StorageSim, TierId};
use std::path::{Path, PathBuf};

/// One [`StorageBackend`] implementation, as the conformance harness
/// names it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// The in-memory reference simulator.
    Sim,
    /// The real-filesystem backend (ADR-003).
    Fs,
    /// The S3-style object-store backend (ADR-005).
    Object,
}

/// Every implementation, in reference-first order.
pub const ALL_BACKENDS: [BackendKind; 3] =
    [BackendKind::Sim, BackendKind::Fs, BackendKind::Object];

/// The journaled implementations — the ones kill-and-restart recovery
/// invariants apply to.
pub const DURABLE_BACKENDS: [BackendKind; 2] = [BackendKind::Fs, BackendKind::Object];

/// Whether the conformance suites should run durable backends with
/// group commit on (ADR-009). CI sets `SHPTIER_GROUP_COMMIT=1` for one
/// job so the whole invariant surface also holds under batched appends;
/// the default stays per-op so failures bisect cleanly.
pub fn group_commit_enabled() -> bool {
    std::env::var("SHPTIER_GROUP_COMMIT").map_or(false, |v| v == "1")
}

impl BackendKind {
    pub fn label(self) -> &'static str {
        match self {
            Self::Sim => "sim",
            Self::Fs => "fs",
            Self::Object => "object",
        }
    }

    /// Open a fresh backend of this kind. Durable kinds get a new scratch
    /// root (returned so the caller can reopen after a simulated kill and
    /// remove it when done); the sim returns `None`.
    pub fn open(
        self,
        tag: &str,
        costs: Vec<PerDocCosts>,
        charge_rent: bool,
    ) -> anyhow::Result<(Box<dyn StorageBackend>, Option<PathBuf>)> {
        let (mut b, root): (Box<dyn StorageBackend>, Option<PathBuf>) = match self {
            Self::Sim => (Box::new(StorageSim::with_tiers(costs, charge_rent)), None),
            Self::Fs => {
                let root = super::scratch_dir(&format!("conf-fs-{tag}"));
                let b = FsBackend::open(&root, costs, charge_rent)?;
                (Box::new(b), Some(root))
            }
            Self::Object => {
                let root = super::scratch_dir(&format!("conf-obj-{tag}"));
                let b = ObjectBackend::open(&root, costs, charge_rent)?;
                (Box::new(b), Some(root))
            }
        };
        if group_commit_enabled() {
            b.set_group_commit(true);
        }
        Ok((b, root))
    }

    /// The durable log a backend of this kind keeps under `root` (`None`
    /// for the sim) — resolved through the backends' own path helpers so
    /// tests never hardcode the file names.
    pub fn journal_path(self, root: &Path) -> Option<PathBuf> {
        match self {
            Self::Sim => None,
            Self::Fs => Some(FsBackend::journal_path(root)),
            Self::Object => Some(ObjectBackend::manifest_path(root)),
        }
    }

    /// Reopen a durable backend from its root (journal recovery). The sim
    /// has no durable state: reopening it is a fresh, empty simulator —
    /// which is exactly why recovery invariants iterate
    /// [`DURABLE_BACKENDS`].
    pub fn reopen(
        self,
        root: Option<&Path>,
        costs: Vec<PerDocCosts>,
        charge_rent: bool,
    ) -> anyhow::Result<Box<dyn StorageBackend>> {
        let mut b: Box<dyn StorageBackend> = match (self, root) {
            (Self::Sim, _) => Box::new(StorageSim::with_tiers(costs, charge_rent)),
            (Self::Fs, Some(root)) => Box::new(FsBackend::open(root, costs, charge_rent)?),
            (Self::Object, Some(root)) => {
                Box::new(ObjectBackend::open(root, costs, charge_rent)?)
            }
            (kind, None) => anyhow::bail!("{} backend needs its root to reopen", kind.label()),
        };
        if group_commit_enabled() {
            b.set_group_commit(true);
        }
        Ok(b)
    }
}

/// The canonical mixed op sequence the per-backend unit suites drive for
/// ledger-parity checks on a two-tier backend: a stream registration,
/// attributed puts from two streams, a consumer read, a per-doc
/// migration, a delete, and an end-of-window settle. One copy on
/// purpose — extend it here and every backend's parity coverage moves
/// together.
pub fn exercise_mixed_ops(b: &mut dyn StorageBackend) {
    b.set_attribution(Some(0));
    b.register_stream(
        0,
        vec![
            PerDocCosts { write: 1.5, read: 9.0, rent_window: 50.0 },
            PerDocCosts { write: 2.5, read: 19.0, rent_window: 150.0 },
        ],
    )
    .unwrap();
    b.put(1, TierId::A, 0.0).unwrap();
    b.put(2, TierId::A, 0.1).unwrap();
    b.set_attribution(Some(1));
    b.put(3, TierId::B, 0.2).unwrap();
    b.read(1).unwrap();
    b.migrate_doc(2, TierId::B, 0.5).unwrap();
    b.delete(3, 0.6).unwrap();
    b.settle_rent(1.0).unwrap();
}

/// Run one invariant against every backend implementation, panicking
/// with the backend's label on the first failure (mirrors the
/// `propcheck` Result<(), String> convention).
pub fn for_each_backend<F>(tag: &str, mut f: F)
where
    F: FnMut(BackendKind) -> Result<(), String>,
{
    for kind in ALL_BACKENDS {
        if let Err(e) = f(kind) {
            panic!("[{tag}] backend '{}': {e}", kind.label());
        }
    }
}

/// [`for_each_backend`], restricted to the journaled implementations.
pub fn for_each_durable_backend<F>(tag: &str, mut f: F)
where
    F: FnMut(BackendKind) -> Result<(), String>,
{
    for kind in DURABLE_BACKENDS {
        if let Err(e) = f(kind) {
            panic!("[{tag}] backend '{}': {e}", kind.label());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::TierId;

    fn costs() -> Vec<PerDocCosts> {
        vec![
            PerDocCosts { write: 1.0, read: 2.0, rent_window: 0.0 },
            PerDocCosts { write: 2.0, read: 1.0, rent_window: 0.0 },
        ]
    }

    #[test]
    fn every_kind_opens_operates_and_labels() {
        for_each_backend("harness-smoke", |kind| {
            let (mut b, root) =
                kind.open("smoke", costs(), false).map_err(|e| e.to_string())?;
            if b.backend_name() != kind.label() {
                return Err(format!("label {} != {}", b.backend_name(), kind.label()));
            }
            b.put(1, TierId::A, 0.0).map_err(|e| e.to_string())?;
            if b.locate(1) != Some(TierId::A) {
                return Err("lost the document".into());
            }
            if let Some(root) = root {
                let _ = std::fs::remove_dir_all(root);
            }
            Ok(())
        });
    }

    #[test]
    fn durable_kinds_survive_reopen_and_sim_does_not() {
        for kind in ALL_BACKENDS {
            let (mut b, root) = kind.open("reopen", costs(), false).unwrap();
            b.put(9, TierId::B, 0.2).unwrap();
            drop(b);
            let reopened = kind.reopen(root.as_deref(), costs(), false).unwrap();
            let expect = if DURABLE_BACKENDS.contains(&kind) { Some(TierId::B) } else { None };
            assert_eq!(reopened.locate(9), expect, "kind {}", kind.label());
            if let Some(root) = root {
                let _ = std::fs::remove_dir_all(root);
            }
        }
    }

    #[test]
    #[should_panic(expected = "backend 'sim'")]
    fn harness_panics_name_the_backend() {
        for_each_backend("harness-panics", |_| Err("injected".into()));
    }
}
