//! Dependency-free utilities: deterministic RNG, numeric helpers, and
//! test support (scratch directories, the backend conformance harness).

pub mod backends;
pub mod math;
pub mod rng;
pub mod scratch;

pub use backends::{
    for_each_backend, for_each_durable_backend, BackendKind, ALL_BACKENDS,
    DURABLE_BACKENDS,
};
pub use math::{
    binary_entropy, golden_section_min, grid_min, harmonic, harmonic_diff, mean,
    percentile_sorted, rel_err, sigmoid, std_dev, EULER_MASCHERONI,
};
pub use rng::{Rng, SplitMix64};
pub use scratch::scratch_dir;
