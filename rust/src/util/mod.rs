//! Dependency-free utilities: deterministic RNG, numeric helpers, and
//! test-support scratch directories.

pub mod math;
pub mod rng;
pub mod scratch;

pub use math::{
    binary_entropy, golden_section_min, grid_min, harmonic, harmonic_diff, mean,
    percentile_sorted, rel_err, sigmoid, std_dev, EULER_MASCHERONI,
};
pub use rng::{Rng, SplitMix64};
pub use scratch::scratch_dir;
