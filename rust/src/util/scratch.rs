//! Scratch directories for tests that exercise real file IO (the
//! vendored crate set has no `tempfile`). Test-support code, but compiled
//! into the library so the `storage::fs` unit tests and the integration
//! suites (`engine_invariants`, `backend_parity`) share one copy.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

/// A unique, pre-cleaned directory path under the system temp dir:
/// `<tmp>/shptier-<tag>-<pid>-<counter>`. The directory itself is NOT
/// created (backends create their own roots); any leftover from a
/// recycled pid is removed. Callers clean up with `remove_dir_all` when
/// done (best-effort).
pub fn scratch_dir(tag: &str) -> PathBuf {
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    let p = std::env::temp_dir()
        .join(format!("shptier-{tag}-{}-{n}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scratch_dirs_are_unique() {
        let a = scratch_dir("x");
        let b = scratch_dir("x");
        assert_ne!(a, b);
        assert!(!a.exists());
    }
}
