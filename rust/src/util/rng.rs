//! Deterministic, dependency-free PRNGs for simulation and property tests.
//!
//! We intentionally avoid external RNG crates: every simulation in this
//! repository must be exactly reproducible from a `u64` seed recorded in
//! EXPERIMENTS.md. `SplitMix64` is used for seeding, `Xoshiro256**` for the
//! bulk streams (same generators JAX/NumPy ecosystems rely on for
//! non-cryptographic work).

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — fast, high-quality, deterministic PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Construct from a seed; any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in (0, 1] — safe as input to `ln()` for exponentials.
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n). `n` must be > 0.
    ///
    /// Lemire's nearly-divisionless method; bias is rejected exactly.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (n.wrapping_neg() % n) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Exponential with rate `lambda` (mean `1/lambda`).
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -self.next_f64_open().ln() / lambda
    }

    /// Standard normal via Box–Muller (single value; second is discarded to
    /// keep the generator stateless w.r.t. call pattern).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64_open();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }

    /// Fork a statistically independent child generator (for shards).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.next_f64_open();
            assert!(y > 0.0 && y <= 1.0);
        }
    }

    #[test]
    fn next_below_bounds_and_rough_uniformity() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.next_below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_500..11_500).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(5);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Rng::new(9);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 4);
    }
}
