//! Numeric helpers shared across the analytic cost model and simulators.

/// Euler–Mascheroni constant (the paper's `0.57722` in eq. (7)).
pub const EULER_MASCHERONI: f64 = 0.577_215_664_901_532_9;

/// Crossover below which `H_n` is computed by direct summation. Above it
/// the 4-term asymptotic expansion is already accurate to ≲1e-16 relative
/// (next omitted term is 1/(252·n⁶) ≈ 3e-21 at n=4096), so raising the
/// threshold buys nothing; lowering it from the original 1e6 turned the
/// Case-Study-1 cost evaluation from 3.1 ms into ~40 ns (EXPERIMENTS.md
/// §Perf).
const HARMONIC_DIRECT_MAX: u64 = 4096;

/// Partial harmonic sum `H_n = sum_{j=1..n} 1/j`, exact to double precision.
///
/// Direct backward summation for `n <= 4096`; the asymptotic expansion
/// `ln n + γ + 1/(2n) − 1/(12n²) + 1/(120n⁴)` above. `harmonic(0) == 0`.
pub fn harmonic(n: u64) -> f64 {
    if n == 0 {
        return 0.0;
    }
    if n <= HARMONIC_DIRECT_MAX {
        let mut s = 0.0;
        let mut j = n;
        while j >= 1 {
            s += 1.0 / j as f64;
            j -= 1;
        }
        s
    } else {
        let x = n as f64;
        x.ln() + EULER_MASCHERONI + 1.0 / (2.0 * x) - 1.0 / (12.0 * x * x)
            + 1.0 / (120.0 * x.powi(4))
    }
}

/// `H_b − H_a` (b ≥ a), computed stably for large arguments.
pub fn harmonic_diff(a: u64, b: u64) -> f64 {
    assert!(b >= a, "harmonic_diff requires b >= a (got a={a}, b={b})");
    if a == b {
        return 0.0;
    }
    // When both ends are in the asymptotic regime, difference of expansions
    // is far more accurate than difference of sums.
    if a > HARMONIC_DIRECT_MAX {
        let (xa, xb) = (a as f64, b as f64);
        (xb / xa).ln() + 0.5 * (1.0 / xb - 1.0 / xa)
            - (1.0 / (xb * xb) - 1.0 / (xa * xa)) / 12.0
            + (1.0 / xb.powi(4) - 1.0 / xa.powi(4)) / 120.0
    } else if b <= 2 * HARMONIC_DIRECT_MAX {
        let mut s = 0.0;
        let mut j = b;
        while j > a {
            s += 1.0 / j as f64;
            j -= 1;
        }
        s
    } else {
        harmonic(b) - harmonic(a)
    }
}

/// Golden-section minimization of a unimodal function on [lo, hi].
///
/// Returns `(argmin, min)`. Used to cross-check the closed-form `r*`
/// solutions of eqs. (17)/(21) without assuming their sign conventions.
pub fn golden_section_min<F: Fn(f64) -> f64>(
    f: F,
    mut lo: f64,
    mut hi: f64,
    tol: f64,
) -> (f64, f64) {
    assert!(hi > lo);
    const INVPHI: f64 = 0.618_033_988_749_894_8;
    let mut c = hi - INVPHI * (hi - lo);
    let mut d = lo + INVPHI * (hi - lo);
    let mut fc = f(c);
    let mut fd = f(d);
    while (hi - lo).abs() > tol {
        if fc < fd {
            hi = d;
            d = c;
            fd = fc;
            c = hi - INVPHI * (hi - lo);
            fc = f(c);
        } else {
            lo = c;
            c = d;
            fc = fd;
            d = lo + INVPHI * (hi - lo);
            fd = f(d);
        }
    }
    let x = 0.5 * (lo + hi);
    (x, f(x))
}

/// Dense grid minimization — robust fallback when unimodality is uncertain
/// (e.g. when validating the cost surface shape itself). Returns `(argmin, min)`.
pub fn grid_min<F: Fn(f64) -> f64>(f: F, lo: f64, hi: f64, steps: usize) -> (f64, f64) {
    assert!(steps >= 2 && hi > lo);
    let mut best_x = lo;
    let mut best = f(lo);
    for i in 1..=steps {
        let x = lo + (hi - lo) * i as f64 / steps as f64;
        let y = f(x);
        if y < best {
            best = y;
            best_x = x;
        }
    }
    (best_x, best)
}

/// Binary entropy in bits: `H(p) = −p·log2 p − (1−p)·log2(1−p)`, with the
/// conventional limits `H(0)=H(1)=0`. This is the paper's "normalized label
/// entropy" interestingness for a binary classifier.
pub fn binary_entropy(p: f64) -> f64 {
    if p <= 0.0 || p >= 1.0 {
        return 0.0;
    }
    -(p * p.log2() + (1.0 - p) * (1.0 - p).log2())
}

/// Numerically stable logistic sigmoid.
pub fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        let e = (-x).exp();
        1.0 / (1.0 + e)
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on a *sorted* slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Relative error |a−b| / max(|b|, eps).
pub fn rel_err(a: f64, b: f64) -> f64 {
    (a - b).abs() / b.abs().max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harmonic_small_values() {
        assert_eq!(harmonic(0), 0.0);
        assert_eq!(harmonic(1), 1.0);
        assert!((harmonic(2) - 1.5).abs() < 1e-15);
        assert!((harmonic(4) - (1.0 + 0.5 + 1.0 / 3.0 + 0.25)).abs() < 1e-15);
    }

    #[test]
    fn harmonic_matches_paper_approximation() {
        // Paper eq. (7): H_N ≈ ln N + 0.57722
        for n in [100u64, 10_000, 1_000_000] {
            let approx = (n as f64).ln() + EULER_MASCHERONI;
            assert!(rel_err(harmonic(n), approx) < 1e-2);
        }
    }

    #[test]
    fn harmonic_asymptotic_continuity() {
        // direct sum at the crossover vs expansion just above must agree
        let direct = harmonic(4096);
        let expansion = harmonic(4097);
        assert!(
            (direct + 1.0 / 4097.0 - expansion).abs() < 1e-13,
            "discontinuity at crossover: {} vs {}",
            direct + 1.0 / 4097.0,
            expansion
        );
        // spot-check the expansion against brute force well above it
        let brute: f64 = (1..=100_000u64).map(|j| 1.0 / j as f64).sum();
        assert!((harmonic(100_000) - brute).abs() < 1e-10);
    }

    #[test]
    fn harmonic_diff_consistency() {
        assert!((harmonic_diff(10, 100) - (harmonic(100) - harmonic(10))).abs() < 1e-12);
        assert_eq!(harmonic_diff(5, 5), 0.0);
        // large regime
        let d = harmonic_diff(10_000_000, 100_000_000);
        assert!(rel_err(d, (10f64).ln()) < 1e-6, "d={d}");
    }

    #[test]
    #[should_panic]
    fn harmonic_diff_requires_order() {
        harmonic_diff(10, 5);
    }

    #[test]
    fn golden_section_finds_parabola_min() {
        let (x, y) = golden_section_min(|x| (x - 3.0) * (x - 3.0) + 1.0, 0.0, 10.0, 1e-9);
        assert!((x - 3.0).abs() < 1e-6);
        assert!((y - 1.0).abs() < 1e-9);
    }

    #[test]
    fn grid_min_finds_min() {
        let (x, _) = grid_min(|x| (x - 0.25).abs(), 0.0, 1.0, 1000);
        assert!((x - 0.25).abs() < 2e-3);
    }

    #[test]
    fn binary_entropy_properties() {
        assert_eq!(binary_entropy(0.0), 0.0);
        assert_eq!(binary_entropy(1.0), 0.0);
        assert!((binary_entropy(0.5) - 1.0).abs() < 1e-12);
        // symmetric
        for p in [0.1, 0.3, 0.45] {
            assert!((binary_entropy(p) - binary_entropy(1.0 - p)).abs() < 1e-12);
        }
    }

    #[test]
    fn sigmoid_stable_and_symmetric() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-15);
        assert!(sigmoid(1000.0) <= 1.0 && sigmoid(1000.0) > 0.999);
        assert!(sigmoid(-1000.0) >= 0.0 && sigmoid(-1000.0) < 1e-3);
        for x in [-3.0, -0.5, 0.7, 2.0] {
            assert!((sigmoid(x) + sigmoid(-x) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn percentile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&v, 0.0), 1.0);
        assert_eq!(percentile_sorted(&v, 100.0), 4.0);
        assert!((percentile_sorted(&v, 50.0) - 2.5).abs() < 1e-12);
    }
}
