//! Minimal TOML parser for launcher configs.
//!
//! Supports the subset used by `configs/*.toml`: top-level and nested
//! `[table.subtable]` headers, `key = value` with strings, integers, floats,
//! booleans, and homogeneous arrays, plus `#` comments. No multi-line
//! strings, datetimes, or array-of-tables — configs stay simple by design.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
    Table(BTreeMap<String, TomlValue>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct TomlError {
    pub msg: String,
    pub line: usize,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

impl TomlValue {
    /// Parse a document into its root table.
    pub fn parse(src: &str) -> Result<TomlValue, TomlError> {
        let mut root: BTreeMap<String, TomlValue> = BTreeMap::new();
        let mut current_path: Vec<String> = Vec::new();

        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| TomlError { msg: msg.to_string(), line: lineno + 1 };
            if let Some(rest) = line.strip_prefix('[') {
                let inner = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err("unterminated table header"))?;
                if inner.starts_with('[') {
                    return Err(err("array-of-tables not supported"));
                }
                current_path = inner
                    .split('.')
                    .map(|s| s.trim().to_string())
                    .collect::<Vec<_>>();
                if current_path.iter().any(|p| p.is_empty()) {
                    return Err(err("empty table name component"));
                }
                // materialize path
                ensure_table(&mut root, &current_path).map_err(|m| err(&m))?;
            } else {
                let eq = line.find('=').ok_or_else(|| err("expected key = value"))?;
                let key = line[..eq].trim();
                if key.is_empty() {
                    return Err(err("empty key"));
                }
                let (val, rest) = parse_value(line[eq + 1..].trim()).map_err(|m| err(&m))?;
                if !rest.trim().is_empty() {
                    return Err(err("trailing data after value"));
                }
                let table = ensure_table(&mut root, &current_path).map_err(|m| err(&m))?;
                if table.insert(key.trim_matches('"').to_string(), val).is_some() {
                    return Err(err(&format!("duplicate key '{key}'")));
                }
            }
        }
        Ok(TomlValue::Table(root))
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        match self {
            TomlValue::Table(t) => t.get(key),
            _ => None,
        }
    }

    /// Dotted-path lookup: `get_path("pipeline.batch_size")`.
    pub fn get_path(&self, path: &str) -> Option<&TomlValue> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as u64),
            _ => None,
        }
    }

    /// Floats accept integer literals too (`3` as `3.0`).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_table(&self) -> Option<&BTreeMap<String, TomlValue>> {
        match self {
            TomlValue::Table(t) => Some(t),
            _ => None,
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' outside a string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn ensure_table<'a>(
    root: &'a mut BTreeMap<String, TomlValue>,
    path: &[String],
) -> Result<&'a mut BTreeMap<String, TomlValue>, String> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| TomlValue::Table(BTreeMap::new()));
        match entry {
            TomlValue::Table(t) => cur = t,
            _ => return Err(format!("'{part}' is not a table")),
        }
    }
    Ok(cur)
}

/// Parse one value, returning (value, rest-of-input).
fn parse_value(s: &str) -> Result<(TomlValue, &str), String> {
    let s = s.trim_start();
    if s.is_empty() {
        return Err("missing value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let mut out = String::new();
        let mut chars = rest.char_indices();
        while let Some((i, c)) = chars.next() {
            match c {
                '\\' => match chars.next() {
                    Some((_, 'n')) => out.push('\n'),
                    Some((_, 't')) => out.push('\t'),
                    Some((_, '"')) => out.push('"'),
                    Some((_, '\\')) => out.push('\\'),
                    _ => return Err("bad string escape".into()),
                },
                '"' => return Ok((TomlValue::Str(out), &rest[i + 1..])),
                c => out.push(c),
            }
        }
        return Err("unterminated string".into());
    }
    if let Some(rest) = s.strip_prefix('[') {
        let mut items = Vec::new();
        let mut rem = rest.trim_start();
        if let Some(r) = rem.strip_prefix(']') {
            return Ok((TomlValue::Arr(items), r));
        }
        loop {
            let (v, r) = parse_value(rem)?;
            items.push(v);
            rem = r.trim_start();
            if let Some(r) = rem.strip_prefix(',') {
                rem = r.trim_start();
                // allow trailing comma
                if let Some(r2) = rem.strip_prefix(']') {
                    return Ok((TomlValue::Arr(items), r2));
                }
            } else if let Some(r) = rem.strip_prefix(']') {
                return Ok((TomlValue::Arr(items), r));
            } else {
                return Err("expected ',' or ']' in array".into());
            }
        }
    }
    if let Some(r) = s.strip_prefix("true") {
        return Ok((TomlValue::Bool(true), r));
    }
    if let Some(r) = s.strip_prefix("false") {
        return Ok((TomlValue::Bool(false), r));
    }
    // number: take the maximal run of number-ish chars
    let end = s
        .find(|c: char| !(c.is_ascii_digit() || "+-._eE".contains(c)))
        .unwrap_or(s.len());
    let tok = &s[..end];
    let rest = &s[end..];
    if tok.is_empty() {
        return Err(format!("unrecognized value near '{s}'"));
    }
    let clean = tok.replace('_', "");
    if !clean.contains('.') && !clean.contains('e') && !clean.contains('E') {
        if let Ok(i) = clean.parse::<i64>() {
            return Ok((TomlValue::Int(i), rest));
        }
    }
    clean
        .parse::<f64>()
        .map(|f| (TomlValue::Float(f), rest))
        .map_err(|_| format!("bad number '{tok}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic_document() {
        let doc = r#"
# pipeline config
name = "cs1"
n = 100000000
frac = 0.41
enabled = true

[tiers.a]
kind = "s3"
write_txn = 5e-6

[tiers.b]
kind = "azure"
sizes = [1, 2, 3]
"#;
        let t = TomlValue::parse(doc).unwrap();
        assert_eq!(t.get_path("name").unwrap().as_str(), Some("cs1"));
        assert_eq!(t.get_path("n").unwrap().as_u64(), Some(100_000_000));
        assert_eq!(t.get_path("frac").unwrap().as_f64(), Some(0.41));
        assert_eq!(t.get_path("enabled").unwrap().as_bool(), Some(true));
        assert_eq!(t.get_path("tiers.a.kind").unwrap().as_str(), Some("s3"));
        assert_eq!(t.get_path("tiers.a.write_txn").unwrap().as_f64(), Some(5e-6));
        let sizes = t.get_path("tiers.b.sizes").unwrap().as_arr().unwrap();
        assert_eq!(sizes.len(), 3);
        assert_eq!(sizes[2].as_i64(), Some(3));
    }

    #[test]
    fn int_vs_float() {
        let t = TomlValue::parse("a = 3\nb = 3.0\nc = 1_000\n").unwrap();
        assert_eq!(t.get("a").unwrap().as_i64(), Some(3));
        assert_eq!(t.get("a").unwrap().as_f64(), Some(3.0)); // int coerces
        assert!(matches!(t.get("b").unwrap(), TomlValue::Float(_)));
        assert_eq!(t.get("c").unwrap().as_i64(), Some(1000));
    }

    #[test]
    fn comments_and_strings_with_hash() {
        let t = TomlValue::parse("a = \"x # not a comment\" # real comment\n").unwrap();
        assert_eq!(t.get("a").unwrap().as_str(), Some("x # not a comment"));
    }

    #[test]
    fn errors() {
        assert!(TomlValue::parse("a =\n").is_err());
        assert!(TomlValue::parse("[unclosed\n").is_err());
        assert!(TomlValue::parse("a = 1\na = 2\n").is_err());
        assert!(TomlValue::parse("a = [1, \"x\"\n").is_err());
        let e = TomlValue::parse("ok = 1\nbad\n").unwrap_err();
        assert_eq!(e.line, 2);
    }

    #[test]
    fn nested_table_merge() {
        let t = TomlValue::parse("[a]\nx = 1\n[a.b]\ny = 2\n").unwrap();
        assert_eq!(t.get_path("a.x").unwrap().as_i64(), Some(1));
        assert_eq!(t.get_path("a.b.y").unwrap().as_i64(), Some(2));
    }

    #[test]
    fn empty_and_trailing_comma_arrays() {
        let t = TomlValue::parse("a = []\nb = [1, 2,]\n").unwrap();
        assert_eq!(t.get("a").unwrap().as_arr().unwrap().len(), 0);
        assert_eq!(t.get("b").unwrap().as_arr().unwrap().len(), 2);
    }
}
