//! Hand-rolled serialization substrates (no serde in the vendored crate set).

pub mod json;
pub mod toml;

pub use json::{Json, JsonError};
pub use toml::{TomlError, TomlValue};
