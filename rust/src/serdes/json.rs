//! Minimal JSON parser/emitter.
//!
//! The build environment vendors no serde/serde_json, so the runtime's
//! artifact manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`) is read with this hand-rolled parser. It
//! supports the full JSON grammar except for `\u` surrogate pairs beyond the
//! BMP (sufficient for machine-generated manifests).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns None on any miss.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap();
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 char
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.25").unwrap(), Json::Num(3.25));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x\n", "c": null}], "d": true}"#).unwrap();
        assert_eq!(j.get("d"), Some(&Json::Bool(true)));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_u64(), Some(1));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\n"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s","é"],"n":null,"o":{"x":-3}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("a\"b\\c\nd\t\u{1}".into());
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn errors_have_offsets() {
        let e = Json::parse("[1, ").unwrap_err();
        assert!(e.offset >= 3);
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("[1] x").is_err());
    }

    #[test]
    fn unicode_content() {
        let j = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo ☃"));
    }
}
