//! Minimal JSON parser/emitter.
//!
//! The build environment vendors no serde/serde_json, so the runtime's
//! artifact manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`) is read with this hand-rolled parser. It
//! supports the full JSON grammar except for `\u` surrogate pairs beyond the
//! BMP (sufficient for machine-generated manifests).
//!
//! The parser also fronts the serve layer's network protocol, so it is
//! hardened against untrusted input: numbers whose magnitude overflows
//! `f64` are rejected (instead of silently becoming `inf`, which
//! [`Json::dump`] could never round-trip), and nesting is limited to
//! [`MAX_DEPTH`] so a bomb of `[[[[…` cannot blow the parse stack.

use std::collections::BTreeMap;
use std::fmt;

/// Maximum container nesting the parser accepts. Deep enough for any
/// payload this crate emits, shallow enough that recursive descent on
/// hostile input cannot exhaust the stack.
pub const MAX_DEPTH: usize = 64;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns None on any miss.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        let n = text.parse::<f64>().map_err(|_| self.err("bad number"))?;
        // `"1e999".parse::<f64>()` is Ok(inf): reject it here, because a
        // non-finite Num has no JSON representation to round-trip through
        if !n.is_finite() {
            return Err(self.err("number out of range"));
        }
        Ok(Json::Num(n))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap();
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 char
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    /// Guard one level of container nesting (errors abort the parse, so
    /// the counter only needs unwinding on success paths).
    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        Ok(())
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        self.enter()?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        self.enter()?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.25").unwrap(), Json::Num(3.25));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x\n", "c": null}], "d": true}"#).unwrap();
        assert_eq!(j.get("d"), Some(&Json::Bool(true)));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_u64(), Some(1));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\n"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s","é"],"n":null,"o":{"x":-3}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("a\"b\\c\nd\t\u{1}".into());
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn errors_have_offsets() {
        let e = Json::parse("[1, ").unwrap_err();
        assert!(e.offset >= 3);
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("[1] x").is_err());
    }

    #[test]
    fn unicode_content() {
        let j = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo ☃"));
    }

    #[test]
    fn overflowing_numbers_are_rejected_not_inf() {
        for src in ["1e999", "-1e999", "[1, 2e400]", "{\"x\": 1e309}"] {
            let e = Json::parse(src).unwrap_err();
            assert!(e.msg.contains("out of range"), "{src}: {e}");
        }
        // the largest finite doubles still parse
        assert!(Json::parse("1.7976931348623157e308").is_ok());
        assert!(Json::parse("-1.7976931348623157e308").is_ok());
    }

    #[test]
    fn nesting_bomb_is_rejected_at_max_depth() {
        let deep_ok = format!("{}1{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(Json::parse(&deep_ok).is_ok());
        let too_deep =
            format!("{}1{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        let e = Json::parse(&too_deep).unwrap_err();
        assert!(e.msg.contains("nesting too deep"), "{e}");
        // an unclosed bomb (the classic DoS shape) also fails cleanly
        let bomb = "[".repeat(100_000);
        assert!(Json::parse(&bomb).is_err());
        // mixed array/object nesting counts every level
        let mixed = "{\"a\":".repeat(40) + &"[".repeat(40) + "1"
            + &"]".repeat(40)
            + &"}".repeat(40);
        assert!(Json::parse(&mixed).is_err());
        // siblings do not accumulate depth
        let wide = format!("[{}]", vec!["[1]"; 1000].join(","));
        assert!(Json::parse(&wide).is_ok());
    }
}
