//! The experiment harness: every table and figure in the paper's
//! evaluation, regenerable by id (`shptier exp --id <id>`).
//!
//! See DESIGN.md §4 for the experiment index (E1–E10, A1–A2).

pub mod ablations;
pub mod case_studies;
pub mod drift;
pub mod fleet;
pub mod grn;
pub mod validation;

use crate::pipeline::native_scorer_factory;
use crate::report::Series;
use crate::runtime::Manifest;
use anyhow::{bail, Result};
use std::path::PathBuf;

/// Where CSV outputs go.
pub fn results_dir() -> PathBuf {
    std::env::var_os("SHPTIER_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("results"))
}

fn emit(series: &Series) -> Result<()> {
    let path = series.write_csv(&results_dir())?;
    println!("wrote {}", path.display());
    Ok(())
}

/// All known experiment ids (for `--id list` / CLI help).
pub const EXPERIMENT_IDS: &[&str] = &[
    "shp-classic",
    "alg-b",
    "table1",
    "fig4",
    "table2",
    "fig5",
    "fig6",
    "fig7",
    "fig8",
    "sweep-sizing",
    "ablation-policies",
    "ablation-ordering",
    "fleet",
    "fleet-family",
    "fleet-family-ablation",
    "fleet-staggered",
    "drift",
    "all",
];

/// Run one experiment by id, printing tables and writing CSVs.
///
/// `quick` shrinks Monte-Carlo reps / workload sizes for CI-speed runs.
pub fn run(id: &str, seed: u64, quick: bool) -> Result<()> {
    match id {
        "shp-classic" => {
            let reps = if quick { 500 } else { 20_000 };
            println!("{}", validation::shp_classic(seed, reps).render());
        }
        "alg-b" => {
            let reps = if quick { 300 } else { 5_000 };
            println!("{}", validation::algorithm_b(seed, reps).render());
        }
        "table1" => println!("{}", case_studies::table1().render()),
        "fig4" => {
            let (series, table) = case_studies::fig4(if quick { 100 } else { 1000 });
            println!("{}", table.render());
            emit(&series)?;
        }
        "table2" => println!("{}", case_studies::table2().render()),
        "fig5" => {
            let (series, table) = case_studies::fig5(if quick { 200 } else { 2000 });
            println!("{}", table.render());
            emit(&series)?;
        }
        "fig6" => {
            let docs = if quick { 30 } else { 200 };
            let dir = Manifest::default_dir();
            let native = crate::runtime::NativeScorer::from_manifest_dir(&dir)
                .unwrap_or_else(|_| {
                    eprintln!("warning: no artifacts; using demo scorer");
                    crate::runtime::NativeScorer::new(
                        crate::interestingness::RbfScorer::synthetic_demo(),
                    )
                });
            let (series, table) = grn::fig6_native(&native, docs, 256, seed);
            println!("{}", table.render());
            emit(&series)?;
        }
        "fig7" | "fig8" => {
            let n_docs = if quick { 1_000 } else { 10_000 };
            let factory = native_scorer_factory(Manifest::default_dir());
            let (report, series7, table7) = grn::fig7(n_docs, factory, seed);
            println!("{}", table7.render());
            emit(&series7)?;
            let scores: Vec<f64> =
                report.score_trace.iter().map(|(_, h)| *h as f64).collect();
            let (series8, table8) = grn::fig8(&scores, 100.min(scores.len() / 10).max(2));
            println!("{}", table8.render());
            emit(&series8)?;
            println!("{}", report.summary());
        }
        "sweep-sizing" => println!("{}", grn::sweep_sizing_table().render()),
        "ablation-policies" => {
            let reps = if quick { 5 } else { 30 };
            println!(
                "{}",
                ablations::ablation_policies(&crate::cost::case_study_1(), 20_000, reps, seed)
                    .render()
            );
            println!(
                "{}",
                ablations::ablation_policies(&crate::cost::case_study_2(), 50_000, reps, seed)
                    .render()
            );
        }
        "ablation-ordering" => {
            let n = if quick { 3_000 } else { 20_000 };
            println!("{}", ablations::ablation_ordering(n, 100, seed).render());
        }
        "fleet" => {
            let (m, n, k, points) = if quick { (4, 300, 8, 3) } else { (8, 1_500, 24, 5) };
            let t_len = if quick { 64 } else { 256 };
            let specs = crate::fleet::demo_fleet(m, n, k, true, seed);
            let (table, series, _) = fleet::e_fleet(&specs, seed, t_len, points)?;
            println!("{}", table.render());
            emit(&series)?;
        }
        "fleet-family" => {
            // rent-dominated (case-study-2 shape) fleet: keep vs migrate
            // vs auto, measured against the closed forms
            let (m, n, k) = if quick { (3, 400, 10) } else { (8, 2_000, 32) };
            let t_len = if quick { 48 } else { 128 };
            let specs = crate::fleet::rent_dominated_fleet(m, n, k, seed);
            let (table, series, cmp) = fleet::e_fleet_family(&specs, seed, t_len)?;
            println!("{}", table.render());
            emit(&series)?;
            println!(
                "migrate family saves {:+.1}% over keep at ample capacity \
                 (measured ${:.4} vs ${:.4})",
                cmp.saving() * 100.0,
                cmp.migrate_total,
                cmp.keep_total
            );
        }
        "fleet-family-ablation" => {
            // the full 2×2 {arbitrated, naive} × {keep, migrate} grid on
            // a contended rent-dominated fleet (ROADMAP: the naive-migrate
            // cell was the missing quadrant)
            let (m, n, k) = if quick { (3, 400, 10) } else { (8, 2_000, 32) };
            let t_len = if quick { 48 } else { 128 };
            let specs = crate::fleet::rent_dominated_fleet(m, n, k, seed);
            let (table, series, cells) = fleet::e_fleet_family_ablation(&specs, seed, t_len)?;
            println!("{}", table.render());
            emit(&series)?;
            let naive_migrate = cells
                .iter()
                .find(|c| {
                    c.mode == crate::fleet::FleetMode::Naive
                        && c.family == crate::policy::PlanFamily::Migrate
                })
                .expect("the 2x2 grid has its naive-migrate cell");
            println!(
                "naive-migrate cell: ${:.4} with {} reactive demotions (hot peak {})",
                naive_migrate.total, naive_migrate.demotions, naive_migrate.hot_peak
            );
        }
        "fleet-staggered" => {
            // arrival process: streams open over time; online
            // re-arbitration + quota lending vs static t=0 quotas
            let (m, n, k) = if quick { (4, 300, 8) } else { (8, 1_500, 24) };
            let t_len = if quick { 48 } else { 128 };
            let specs = crate::fleet::rent_dominated_fleet(m, n, k, seed);
            let capacity = (m as u64 * k / 2).max(1); // contended: half Σ K
            let stride = n / (m as u64).max(1);
            let (table, series, _) =
                fleet::e_fleet_staggered(&specs, capacity, stride, seed, t_len)?;
            println!("{}", table.render());
            emit(&series)?;
        }
        "drift" => {
            // mid-stream distribution shift: static a-priori cuts vs the
            // drift-aware adaptive arbiter vs a shift-aware oracle, plus
            // the no-drift control (acceptance gates asserted inline)
            let (m, n, k, shift, t_len) =
                if quick { (3, 1_200, 8, 600, 48) } else { (6, 4_000, 16, 2_000, 128) };
            let (table, series, out) = drift::e_drift(m, n, k, shift, seed, t_len)?;
            println!("{}", table.render());
            emit(&series)?;
            println!(
                "adaptive saves {:+.1}% over static cuts under drift \
                 ({} detections, {} re-derivations); {:+.1}% vs the shift-aware \
                 oracle; no-drift overhead {:.2}%",
                out.adaptive_saving() * 100.0,
                out.drift_detections,
                out.drift_rederivations,
                out.oracle_gap() * 100.0,
                out.nodrift_overhead() * 100.0
            );
        }
        "all" => {
            for id in EXPERIMENT_IDS.iter().filter(|&&i| i != "all" && i != "fig8") {
                println!("──────────────────────────────────────────────────");
                run(id, seed, quick)?;
            }
        }
        other => bail!(
            "unknown experiment '{other}'; known ids: {}",
            EXPERIMENT_IDS.join(", ")
        ),
    }
    Ok(())
}
