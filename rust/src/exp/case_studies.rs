//! E3–E6: paper Tables I–II and Figures 4–5 (the two cloud case studies).

use crate::cost::{
    case_study_1, case_study_2, closed_form_frac_migration, closed_form_frac_no_migration,
    expected_cost, optimal_r, rent_bound_no_migration, CostModel, Strategy,
};
use crate::report::{Series, Table};

/// E3 — Table I (Case Study 1: S3 producer-local ↔ Azure consumer-local).
/// Paper column shows the printed Table I values; errata in DESIGN.md §5.
pub fn table1() -> Table {
    let m = case_study_1();
    let mut t = Table::new(
        "E3 / Table I: Case Study 1 — 2 tiers in different clouds (N=1e8, K=1e6, 0.1 MB)",
        &["quantity", "ours", "paper"],
    );
    let frac = closed_form_frac_no_migration(&m).expect("interior optimum");
    t.row(vec!["r_opt / N".to_string(), format!("{frac:.8}"), "0.41233169".into()]);

    let opt = optimal_r(&m, false);
    t.row(vec![
        "total @ r_opt (no migration, rent-bounded)".to_string(),
        format!("{:.2}", opt.cost),
        "35.19".into(),
    ]);
    let mig = optimal_r(&m, true);
    t.row(vec![
        "total @ r_opt (with migration)".to_string(),
        format!("{:.2}", mig.cost),
        "49.29".into(),
    ]);
    t.row(vec![
        "cost all storage A".to_string(),
        format!("{:.2}", expected_cost(&m, Strategy::AllA).total()),
        "37.20".into(),
    ]);
    let all_b = expected_cost(&m, Strategy::AllB).total();
    t.row(vec![
        "cost all storage B (eq. 13 accounting)".to_string(),
        format!("{all_b:.2}"),
        "99.12 (†)".into(),
    ]);
    // the paper's all-B is only derivable with a doubled channel charge
    // (see DESIGN.md §5 item 3); show that reconstruction too:
    let w = crate::cost::expected_writes(m.n, m.k);
    let double_channel = w * (m.b.write + 0.087 * 1e-4) + m.k as f64 * m.b.read;
    t.row(vec![
        "cost all storage B (paper's double-channel reconstruction)".to_string(),
        format!("{double_channel:.2}"),
        "99.12".into(),
    ]);
    t
}

/// E5 — Table II (Case Study 2: EFS + S3, same cloud, rent-dominated).
pub fn table2() -> Table {
    let m = case_study_2();
    let mut t = Table::new(
        "E5 / Table II: Case Study 2 — 2 tiers in the same cloud (N=1e8, K=5e6, 1 MB, 7 days)",
        &["quantity", "ours", "paper"],
    );
    let frac = closed_form_frac_migration(&m).expect("interior optimum");
    t.row(vec!["r_opt / N".to_string(), format!("{frac:.4}"), "0.078".into()]);

    let mig = optimal_r(&m, true);
    t.row(vec![
        "total @ r_opt (with migration)".to_string(),
        format!("{:.2}", mig.cost),
        "142.82".into(),
    ]);
    let mig_no_final_read = mig.cost - m.k as f64 * m.b.read;
    t.row(vec![
        "  └ without the final read (paper appears to omit it)".to_string(),
        format!("{mig_no_final_read:.2}"),
        "142.82".into(),
    ]);
    t.row(vec![
        "cost all storage A".to_string(),
        format!("{:.2}", expected_cost(&m, Strategy::AllA).total()),
        "350.00".into(),
    ]);
    let all_b = expected_cost(&m, Strategy::AllB).total();
    t.row(vec![
        "cost all storage B (eq. 13 accounting)".to_string(),
        format!("{all_b:.2}"),
        "503.78 (†)".into(),
    ]);
    let all_b_all_docs =
        m.n as f64 * m.b.write + m.k as f64 * (m.b.read + m.b.rent_window);
    t.row(vec![
        "cost all storage B (paper's all-N-PUTs reconstruction)".to_string(),
        format!("{all_b_all_docs:.2}"),
        "503.78".into(),
    ]);
    let no_mig = {
        let mut c = expected_cost(&m, Strategy::Changeover { r: mig.r });
        c.rent = rent_bound_no_migration(&m);
        c.total()
    };
    t.row(vec![
        "total @ r_opt (no migration, rent upper bound)".to_string(),
        format!("{no_mig:.2}"),
        "415.67".into(),
    ]);
    t
}

/// Cost-vs-r sweep used by Figures 4 and 5.
fn cost_sweep(m: &CostModel, migrate: bool, rent_bound: bool, points: usize) -> Series {
    let mut s = Series::new(
        if migrate { "fig5_cost_vs_r" } else { "fig4_cost_vs_r" },
        &["r_frac", "total", "writes_a", "writes_b", "reads", "rent", "migration"],
    );
    for i in 1..points {
        let frac = i as f64 / points as f64;
        let r = (frac * m.n as f64) as u64;
        if r <= m.k || r >= m.n {
            continue;
        }
        let strat = if migrate {
            Strategy::ChangeoverMigrate { r }
        } else {
            Strategy::Changeover { r }
        };
        let mut c = expected_cost(m, strat);
        if rent_bound && !migrate {
            c.rent = if m.include_rent { rent_bound_no_migration(m) } else { 0.0 };
        }
        s.push(vec![frac, c.total(), c.writes_a, c.writes_b, c.reads, c.rent, c.migration]);
    }
    s
}

/// E4 — Figure 4: expected overall cost vs r, Case Study 1 (no migration).
pub fn fig4(points: usize) -> (Series, Table) {
    let m = case_study_1();
    let s = cost_sweep(&m, false, true, points);
    let opt = optimal_r(&m, false);
    let mut t = Table::new("E4 / Fig. 4: cost vs r, Case Study 1", &["metric", "value"]);
    t.row(vec!["argmin r/N (numeric)".to_string(), format!("{:.5}", opt.frac)]);
    t.row(vec!["min cost".to_string(), format!("{:.2}", opt.cost)]);
    t.row(vec!["curve".to_string(), s.sparkline(1, 60)]);
    (s, t)
}

/// E6 — Figure 5: expected overall cost vs r, Case Study 2 (with migration).
pub fn fig5(points: usize) -> (Series, Table) {
    let m = case_study_2();
    let s = cost_sweep(&m, true, false, points);
    let opt = optimal_r(&m, true);
    let mut t = Table::new("E6 / Fig. 5: cost vs r, Case Study 2", &["metric", "value"]);
    t.row(vec!["argmin r/N (numeric)".to_string(), format!("{:.5}", opt.frac)]);
    t.row(vec!["min cost".to_string(), format!("{:.2}", opt.cost)]);
    t.row(vec!["curve".to_string(), s.sparkline(1, 60)]);
    (s, t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_reproduces_r_star() {
        let t = table1();
        let ours: f64 = t.rows[0][1].parse().unwrap();
        assert!((ours - 0.41233169).abs() < 5e-4);
        // total at r_opt within 1% of paper
        let total: f64 = t.rows[1][1].parse().unwrap();
        assert!((total - 35.19).abs() / 35.19 < 0.01, "{total}");
    }

    #[test]
    fn table2_reproduces_r_star() {
        let t = table2();
        let ours: f64 = t.rows[0][1].parse().unwrap();
        assert!((ours - 0.078).abs() < 2e-3);
        // without final read within 2% of paper total
        let total: f64 = t.rows[2][1].parse().unwrap();
        assert!((total - 142.82).abs() / 142.82 < 0.02, "{total}");
        // all-A exact
        let all_a: f64 = t.rows[3][1].parse().unwrap();
        assert!((all_a - 350.0).abs() < 1.0);
    }

    #[test]
    fn fig4_curve_is_unimodal_near_min() {
        let (s, _) = fig4(200);
        // find min; neighbors on each side should be increasing
        let (mut best_i, mut best) = (0, f64::INFINITY);
        for (i, row) in s.rows.iter().enumerate() {
            if row[1] < best {
                best = row[1];
                best_i = i;
            }
        }
        assert!(best_i > 5 && best_i < s.rows.len() - 5, "interior min");
        assert!(s.rows[best_i - 5][1] > best);
        assert!(s.rows[best_i + 5][1] > best);
        // argmin near 0.41
        assert!((s.rows[best_i][0] - 0.412).abs() < 0.02);
    }

    #[test]
    fn fig5_curve_min_near_paper() {
        let (s, _) = fig5(400);
        let (mut best_i, mut best) = (0, f64::INFINITY);
        for (i, row) in s.rows.iter().enumerate() {
            if row[1] < best {
                best = row[1];
                best_i = i;
            }
        }
        assert!((s.rows[best_i][0] - 0.078).abs() < 0.01, "argmin {}", s.rows[best_i][0]);
    }
}
