//! E7–E9: the §VIII trace-driven GRN experiments (paper Figs. 6–8).

use crate::cost::{expected_writes, scaled};
use crate::pipeline::{run_pipeline, PipelineConfig, PipelineReport, ScorerFactory};
use crate::policy::Changeover;
use crate::report::{Series, Table};
use crate::runtime::{NativeScorer, Scorer};
use crate::shp::{fit_write_curve, spearman_position_correlation};
use crate::ssa::{neg_feedback_oscillator, oscillator_sweep, simulate, OscillatorParams};
use crate::util::Rng;

/// E7 — Fig. 6: the interestingness classifier on labeled GRN simulations.
/// The paper shows an SVM scatter; we report per-class probability stats +
/// accuracy, and emit a (probability, entropy, label) CSV for plotting.
pub fn fig6(scorer: &dyn Scorer, docs_per_class: usize, t_len: usize, seed: u64) -> (Series, Table) {
    let mut rng = Rng::new(seed);
    let osc = neg_feedback_oscillator(OscillatorParams::oscillatory());
    let qui = neg_feedback_oscillator(OscillatorParams::quiescent());
    let mut series = Series::new("fig6_classifier", &["probability", "entropy", "label"]);

    let mut stats = [(0.0f64, 0usize), (0.0f64, 0usize)]; // (sum p, correct)
    for (label, net) in [(1.0, &osc), (0.0, &qui)] {
        for _ in 0..docs_per_class {
            let tr = simulate(net, 60.0, t_len, 50_000_000, &mut rng);
            let doc = tr.species_f32(0);
            let h = scorer.score(&[doc.clone()]).expect("score")[0] as f64;
            // probability is recoverable only from the native mirror; use
            // entropy + the class to report separability. For the CSV we
            // re-derive p via the native scorer when available.
            let p = h_to_p_proxy(h, label);
            series.push(vec![p, h, label]);
            let idx = label as usize;
            stats[idx].0 += p;
            if (p > 0.5) == (label > 0.5) {
                stats[idx].1 += 1;
            }
        }
    }
    let mut t = Table::new(
        "E7 / Fig. 6: interestingness classifier on GRN simulations",
        &["class", "docs", "mean p(interesting)", "accuracy"],
    );
    for (label, name) in [(1usize, "oscillatory"), (0usize, "quiescent")] {
        t.row(vec![
            name.to_string(),
            docs_per_class.to_string(),
            format!("{:.3}", stats[label].0 / docs_per_class as f64),
            format!("{:.3}", stats[label].1 as f64 / docs_per_class as f64),
        ]);
    }
    (series, t)
}

// entropy→probability is two-valued; disambiguate with the true label side.
// (Only used for reporting separability; the real Fig. 6 CSV uses the
// native scorer's classify_series via fig6_native.)
fn h_to_p_proxy(h: f64, label: f64) -> f64 {
    // invert H(p) = h on [0, 0.5] by bisection, then mirror
    let mut lo = 0.0f64;
    let mut hi = 0.5f64;
    for _ in 0..40 {
        let mid = 0.5 * (lo + hi);
        if crate::util::math::binary_entropy(mid) < h {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let p_low = 0.5 * (lo + hi);
    if label > 0.5 {
        1.0 - p_low
    } else {
        p_low
    }
}

/// E7 (exact variant) — Fig. 6 with the native scorer: true (p, H) pairs.
pub fn fig6_native(
    native: &NativeScorer,
    docs_per_class: usize,
    t_len: usize,
    seed: u64,
) -> (Series, Table) {
    let mut rng = Rng::new(seed);
    let osc = neg_feedback_oscillator(OscillatorParams::oscillatory());
    let qui = neg_feedback_oscillator(OscillatorParams::quiescent());
    let mut series = Series::new("fig6_classifier", &["probability", "entropy", "label"]);
    let mut correct = [0usize; 2];
    let mut psum = [0.0f64; 2];
    for (label, net) in [(1.0f64, &osc), (0.0, &qui)] {
        for _ in 0..docs_per_class {
            let tr = simulate(net, 60.0, t_len, 50_000_000, &mut rng);
            let (p, h) = native.scorer.classify_series(&tr.species_f32(0));
            series.push(vec![p as f64, h as f64, label]);
            let idx = label as usize;
            psum[idx] += p as f64;
            if (p > 0.5) == (label > 0.5) {
                correct[idx] += 1;
            }
        }
    }
    let mut t = Table::new(
        "E7 / Fig. 6: interestingness classifier on GRN simulations (native mirror)",
        &["class", "docs", "mean p(interesting)", "accuracy"],
    );
    for (label, name) in [(1usize, "oscillatory"), (0usize, "quiescent")] {
        t.row(vec![
            name.to_string(),
            docs_per_class.to_string(),
            format!("{:.3}", psum[label] / docs_per_class as f64),
            format!("{:.3}", correct[label] as f64 / docs_per_class as f64),
        ]);
    }
    (series, t)
}

/// E8 — Fig. 7: the interestingness trace of a 10^4-point smart sweep,
/// streamed through the full pipeline (SSA producers → scorer → placer).
pub fn fig7(
    n_docs: u64,
    scorer_factory: ScorerFactory,
    seed: u64,
) -> (PipelineReport, Series, Table) {
    let grid = oscillator_sweep(7, 1); // 7^5 = 16807 points ≥ 1e4
    let model = scaled(&crate::cost::case_study_2(), crate::cost::case_study_2().n / n_docs);
    let config = PipelineConfig {
        n_docs,
        seed,
        ..PipelineConfig::default()
    };
    let r = (0.078 * n_docs as f64) as u64;
    let mut policy = Changeover::new(r.max(model.k + 1));
    let report = run_pipeline(&config, &grid, &model, &mut policy, scorer_factory)
        .expect("pipeline run");

    let mut series = Series::new("fig7_interestingness_trace", &["index", "entropy"]);
    // paper subsamples every 10th point for clarity
    for (i, (_, h)) in report.score_trace.iter().enumerate().step_by(10) {
        series.push(vec![i as f64, *h as f64]);
    }
    let scores: Vec<f64> = report.score_trace.iter().map(|(_, h)| *h as f64).collect();
    let rho = spearman_position_correlation(&scores);
    let mut t = Table::new(
        "E8 / Fig. 7: interestingness trace of the smart sweep",
        &["metric", "value"],
    );
    t.row(vec!["documents".to_string(), report.docs_processed.to_string()]);
    t.row(vec!["spearman(position, score)".to_string(), format!("{rho:.4}")]);
    t.row(vec![
        "entropy range".to_string(),
        format!(
            "[{:.3}, {:.3}]",
            scores.iter().cloned().fold(f64::INFINITY, f64::min),
            scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
        ),
    ]);
    t.row(vec!["trace".to_string(), series.sparkline(1, 60)]);
    (report, series, t)
}

/// E9 — Fig. 8: cumulative document writes on the trace vs the analytic
/// solution (eqs. 11–12), K = 100.
pub fn fig8(scores: &[f64], k: usize) -> (Series, Table) {
    let fit = fit_write_curve(scores, k);
    let mut series = Series::new(
        "fig8_cumulative_writes",
        &["index", "empirical", "analytic"],
    );
    let step = (scores.len() / 500).max(1);
    for i in (0..scores.len()).step_by(step) {
        series.push(vec![i as f64, fit.empirical[i] as f64, fit.analytic[i]]);
    }
    let mut t = Table::new(
        "E9 / Fig. 8: cumulative writes, trace vs analytic (eqs. 11-12)",
        &["metric", "value"],
    );
    let n = scores.len();
    t.row(vec!["N".to_string(), n.to_string()]);
    t.row(vec!["K".to_string(), k.to_string()]);
    t.row(vec![
        format!("first K writes (paper: 'first K all written')"),
        format!("{} (expect {k})", fit.empirical[k - 1]),
    ]);
    t.row(vec![
        "final writes (empirical)".to_string(),
        fit.empirical[n - 1].to_string(),
    ]);
    t.row(vec![
        "final writes (analytic)".to_string(),
        format!("{:.1}", fit.analytic[n - 1]),
    ]);
    t.row(vec![
        "final relative error".to_string(),
        format!("{:.3}", fit.final_rel_err),
    ]);
    t.row(vec![
        "empirical curve".to_string(),
        series.sparkline(1, 60),
    ]);
    (series, t)
}

/// E10 — §VIII sizing claim (M=3, d=15, 10 samples → 143e6 docs, 14.8 TB).
pub fn sweep_sizing_table() -> Table {
    let mut t = Table::new(
        "E10: §VIII sweep sizing (N = M^d × samples)",
        &["M", "d", "samples", "points", "documents", "TB @ 0.1035 MB/doc", "paper"],
    );
    for (m, d, samples) in [(3u64, 15u32, 10u64), (3, 10, 10), (2, 15, 10)] {
        let s = crate::ssa::sweep_sizing(m, d, samples, 0.1035);
        t.row(vec![
            m.to_string(),
            d.to_string(),
            samples.to_string(),
            s.points.to_string(),
            s.documents.to_string(),
            format!("{:.1}", s.total_tb),
            if m == 3 && d == 15 { "143e6 docs, 14.8 TB".into() } else { "-".to_string() },
        ]);
    }
    t
}

/// Writes-vs-analytic on the *pipeline's* write series (cross-check of the
/// streaming path against eq. 11–12, used by the E2E example).
pub fn write_series_vs_analytic(report: &PipelineReport, k: u64) -> (f64, f64) {
    let n = report.run.cumulative_writes.len();
    assert!(n > 0, "pipeline did not record the write series");
    let final_emp = report.run.cumulative_writes[n - 1] as f64;
    let final_ana = expected_writes(n as u64, k);
    (final_emp, final_ana)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interestingness::RbfScorer;

    #[test]
    fn fig6_demo_scorer_separates_classes() {
        let native = NativeScorer::new(RbfScorer::synthetic_demo());
        let (series, t) = fig6_native(&native, 10, 128, 5);
        assert_eq!(series.rows.len(), 20);
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    fn fig8_on_random_trace_matches() {
        let mut rng = Rng::new(3);
        let scores: Vec<f64> = (0..20_000).map(|_| rng.next_f64()).collect();
        let (series, t) = fig8(&scores, 100);
        assert!(!series.rows.is_empty());
        let err: f64 = t.rows[5][1].parse().unwrap();
        assert!(err < 0.15, "final rel err {err}");
    }

    #[test]
    fn sizing_table_has_paper_row() {
        let t = sweep_sizing_table();
        assert!(t.rows[0][4] == "143489070");
    }
}
