//! E-FLEET: shared-capacity arbitration vs naive per-stream optima.
//!
//! Runs the same heterogeneous fleet twice over identical per-stream score
//! sequences — once with the arbiter's proactive quota degradation, once
//! capacity-oblivious with reactive oldest-first demotion — across a sweep
//! of hot-tier capacities, and compares measured fleet-wide cost.
//!
//! The claim under test: whenever aggregate analytic demand exceeds the
//! hot-tier capacity, arbitration achieves lower total cost (the naive
//! fleet pays a migration hop per contended hot write — thrash); with
//! ample capacity the two coincide exactly.

use crate::fleet::{run_fleet, FleetConfig, FleetMode, StreamSpec};
use crate::report::{Series, Table};
use anyhow::Result;

/// Totals of one capacity point, both modes on identical streams.
#[derive(Debug, Clone, Copy)]
pub struct FleetComparison {
    pub capacity: u64,
    pub aggregate_demand: u64,
    pub arbitrated_total: f64,
    pub naive_total: f64,
    pub naive_demotions: u64,
}

impl FleetComparison {
    /// Relative saving of arbitration over the naive baseline.
    pub fn saving(&self) -> f64 {
        if self.naive_total.abs() < 1e-12 {
            0.0
        } else {
            1.0 - self.arbitrated_total / self.naive_total
        }
    }
}

/// Run both modes at one capacity. Single worker → fully deterministic.
pub fn compare_at_capacity(
    specs: &[StreamSpec],
    capacity: u64,
    seed: u64,
    t_len: usize,
) -> Result<FleetComparison> {
    let base = |mode: FleetMode| FleetConfig {
        hot_capacity: capacity,
        workers: 1,
        channel_capacity: 64,
        batch: 16,
        t_len,
        seed,
        mode,
    };
    let arbitrated = run_fleet(specs, &base(FleetMode::Arbitrated))?;
    let naive = run_fleet(specs, &base(FleetMode::Naive))?;
    Ok(FleetComparison {
        capacity,
        aggregate_demand: arbitrated.arbitration.aggregate_demand,
        arbitrated_total: arbitrated.total_cost(),
        naive_total: naive.total_cost(),
        naive_demotions: naive.demotions(),
    })
}

/// E-FLEET: sweep hot capacity as a fraction of aggregate demand and
/// compare the two modes. Returns the comparison table and the CSV series.
pub fn e_fleet(
    specs: &[StreamSpec],
    seed: u64,
    t_len: usize,
    points: usize,
) -> Result<(Table, Series, Vec<FleetComparison>)> {
    assert!(points >= 2);
    let demand: u64 = specs
        .iter()
        .map(|s| crate::cost::hot_demand(&s.model, false))
        .sum();
    let mut table = Table::new(
        &format!(
            "E-FLEET: arbitrated vs naive fleet cost, {} streams, aggregate demand {}",
            specs.len(),
            demand
        ),
        &["capacity", "cap/demand", "arbitrated $", "naive $", "saving", "naive demotions"],
    );
    let mut series = Series::new(
        "fleet_capacity_sweep",
        &["capacity", "cap_over_demand", "arbitrated_total", "naive_total", "naive_demotions"],
    );
    let mut out = Vec::with_capacity(points);
    for i in 1..=points {
        let frac = i as f64 / points as f64;
        let capacity = ((demand as f64 * frac).round() as u64).max(1);
        let cmp = compare_at_capacity(specs, capacity, seed, t_len)?;
        table.row(vec![
            capacity.to_string(),
            format!("{frac:.2}"),
            format!("{:.4}", cmp.arbitrated_total),
            format!("{:.4}", cmp.naive_total),
            format!("{:+.1}%", cmp.saving() * 100.0),
            cmp.naive_demotions.to_string(),
        ]);
        series.push(vec![
            capacity as f64,
            frac,
            cmp.arbitrated_total,
            cmp.naive_total,
            cmp.naive_demotions as f64,
        ]);
        out.push(cmp);
    }
    Ok((table, series, out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::demo_fleet;

    #[test]
    fn arbitration_beats_naive_whenever_oversubscribed() {
        // The acceptance claim: shared-capacity arbitration achieves lower
        // total cost than naive per-stream optima whenever aggregate demand
        // exceeds hot-tier capacity.
        let specs = demo_fleet(6, 400, 12, true, 1);
        let demand: u64 = specs
            .iter()
            .map(|s| crate::cost::hot_demand(&s.model, false))
            .sum();
        for frac in [0.2f64, 0.5] {
            let cap = ((demand as f64 * frac) as u64).max(1);
            let cmp = compare_at_capacity(&specs, cap, 3, 64).unwrap();
            assert!(cap < cmp.aggregate_demand);
            assert!(
                cmp.arbitrated_total < cmp.naive_total,
                "cap {cap}: arbitrated {} !< naive {}",
                cmp.arbitrated_total,
                cmp.naive_total
            );
            assert!(cmp.naive_demotions > 0);
        }
    }

    #[test]
    fn modes_coincide_with_ample_capacity() {
        let specs = demo_fleet(4, 300, 8, true, 2);
        let demand: u64 = specs
            .iter()
            .map(|s| crate::cost::hot_demand(&s.model, false))
            .sum();
        let cmp = compare_at_capacity(&specs, demand, 9, 64).unwrap();
        // no contention → identical placements, identical cost
        let rel = (cmp.arbitrated_total - cmp.naive_total).abs()
            / cmp.naive_total.max(1e-12);
        assert!(rel < 1e-9, "ample capacity should equalise modes (rel {rel})");
        assert_eq!(cmp.naive_demotions, 0);
    }

    #[test]
    fn sweep_table_shape() {
        let specs = demo_fleet(3, 200, 6, true, 4);
        let (table, series, cmps) = e_fleet(&specs, 5, 64, 3).unwrap();
        assert_eq!(table.rows.len(), 3);
        assert_eq!(series.rows.len(), 3);
        assert_eq!(cmps.len(), 3);
        // the last point is at full demand → saving ≈ 0
        assert!(cmps[2].saving().abs() < 1e-6);
    }
}
