//! E-FLEET: shared-capacity arbitration vs naive per-stream optima, plus
//! the two follow-on comparisons the migrate family unlocks:
//!
//! - [`e_fleet`]: the original capacity sweep — arbitrated quota
//!   degradation vs capacity-oblivious reactive demotion on identical
//!   per-stream score sequences.
//! - [`e_fleet_family`]: keep vs migrate vs auto on a rent-dominated
//!   (case-study-2 shape) fleet — measured fleet cost against the
//!   closed-form prediction per family. The claim under test: whenever
//!   rent dominates transport, the migrate family's measured cost beats
//!   the keep family's and tracks `cost::analytic`.
//! - [`e_fleet_family_ablation`]: the full 2×2 {arbitrated, naive} ×
//!   {keep, migrate} grid on a contended rent-dominated fleet — the
//!   capacity-oblivious naive-migrate quadrant (reactive demotion and
//!   changeover bulk-demotion interacting on one shared tier) completes
//!   the ablation the two experiments above each covered half of.
//! - [`e_fleet_staggered`]: streams arrive over time (one every `stride`
//!   ticks) and close with `finish_release`; online re-arbitration +
//!   time-phased quota lending is compared against frozen t=0 quotas
//!   ([`crate::engine::StaticArbiter`]) on identical score sequences.

use crate::engine::{Engine, StaticArbiter, TierTopology};
use crate::fleet::arbiter::snapshot_of;
use crate::fleet::scheduler::stream_seed;
use crate::fleet::{
    arbitrate_with, generate_series, run_fleet, FleetConfig, FleetMode, StreamSpec, HOT,
};
use crate::interestingness::RbfScorer;
use crate::policy::PlanFamily;
use crate::report::{Series, Table};
use anyhow::Result;

/// Totals of one capacity point, both modes on identical streams.
#[derive(Debug, Clone, Copy)]
pub struct FleetComparison {
    pub capacity: u64,
    pub aggregate_demand: u64,
    pub arbitrated_total: f64,
    pub naive_total: f64,
    pub naive_demotions: u64,
}

impl FleetComparison {
    /// Relative saving of arbitration over the naive baseline.
    pub fn saving(&self) -> f64 {
        if self.naive_total.abs() < 1e-12 {
            0.0
        } else {
            1.0 - self.arbitrated_total / self.naive_total
        }
    }
}

/// Run both modes at one capacity. Single worker → fully deterministic.
pub fn compare_at_capacity(
    specs: &[StreamSpec],
    capacity: u64,
    seed: u64,
    t_len: usize,
) -> Result<FleetComparison> {
    let base = |mode: FleetMode| FleetConfig {
        hot_capacity: capacity,
        workers: 1,
        channel_capacity: 64,
        batch: 16,
        t_len,
        seed,
        mode,
        ..FleetConfig::default()
    };
    let arbitrated = run_fleet(specs, &base(FleetMode::Arbitrated))?;
    let naive = run_fleet(specs, &base(FleetMode::Naive))?;
    Ok(FleetComparison {
        capacity,
        aggregate_demand: arbitrated.arbitration.aggregate_demand,
        arbitrated_total: arbitrated.total_cost(),
        naive_total: naive.total_cost(),
        naive_demotions: naive.demotions(),
    })
}

/// E-FLEET: sweep hot capacity as a fraction of aggregate demand and
/// compare the two modes. Returns the comparison table and the CSV series.
pub fn e_fleet(
    specs: &[StreamSpec],
    seed: u64,
    t_len: usize,
    points: usize,
) -> Result<(Table, Series, Vec<FleetComparison>)> {
    assert!(points >= 2);
    let demand: u64 = specs
        .iter()
        .map(|s| crate::cost::hot_demand(&s.model, false))
        .sum();
    let mut table = Table::new(
        &format!(
            "E-FLEET: arbitrated vs naive fleet cost, {} streams, aggregate demand {}",
            specs.len(),
            demand
        ),
        &["capacity", "cap/demand", "arbitrated $", "naive $", "saving", "naive demotions"],
    );
    let mut series = Series::new(
        "fleet_capacity_sweep",
        &["capacity", "cap_over_demand", "arbitrated_total", "naive_total", "naive_demotions"],
    );
    let mut out = Vec::with_capacity(points);
    for i in 1..=points {
        let frac = i as f64 / points as f64;
        let capacity = ((demand as f64 * frac).round() as u64).max(1);
        let cmp = compare_at_capacity(specs, capacity, seed, t_len)?;
        table.row(vec![
            capacity.to_string(),
            format!("{frac:.2}"),
            format!("{:.4}", cmp.arbitrated_total),
            format!("{:.4}", cmp.naive_total),
            format!("{:+.1}%", cmp.saving() * 100.0),
            cmp.naive_demotions.to_string(),
        ]);
        series.push(vec![
            capacity as f64,
            frac,
            cmp.arbitrated_total,
            cmp.naive_total,
            cmp.naive_demotions as f64,
        ]);
        out.push(cmp);
    }
    Ok((table, series, out))
}

// ---- plan-family comparison (rent-dominated economies) ---------------------

/// Totals of one family-comparison point: the same fleet, same seeded
/// score sequences, run once per strategy family.
#[derive(Debug, Clone, Copy)]
pub struct FamilyComparison {
    pub capacity: u64,
    pub keep_total: f64,
    pub migrate_total: f64,
    pub auto_total: f64,
    /// Closed-form fleet totals at the budgeted parameters.
    pub keep_analytic: f64,
    pub migrate_analytic: f64,
}

impl FamilyComparison {
    /// Relative saving of the migrate family over keep.
    pub fn saving(&self) -> f64 {
        if self.keep_total.abs() < 1e-12 {
            0.0
        } else {
            1.0 - self.migrate_total / self.keep_total
        }
    }
}

/// Run the fleet once per family at one capacity. Single worker → fully
/// deterministic, identical per-stream score sequences across families.
pub fn compare_families_at_capacity(
    specs: &[StreamSpec],
    capacity: u64,
    seed: u64,
    t_len: usize,
) -> Result<FamilyComparison> {
    let base = |family: PlanFamily| FleetConfig {
        hot_capacity: capacity,
        workers: 1,
        channel_capacity: 64,
        batch: 16,
        t_len,
        seed,
        mode: FleetMode::Arbitrated,
        family,
        ..FleetConfig::default()
    };
    let keep = run_fleet(specs, &base(PlanFamily::Keep))?;
    let migrate = run_fleet(specs, &base(PlanFamily::Migrate))?;
    let auto = run_fleet(specs, &base(PlanFamily::Auto))?;
    Ok(FamilyComparison {
        capacity,
        keep_total: keep.total_cost(),
        migrate_total: migrate.total_cost(),
        auto_total: auto.total_cost(),
        keep_analytic: arbitrate_with(specs, capacity, PlanFamily::Keep)
            .analytic_budgeted_total(),
        migrate_analytic: arbitrate_with(specs, capacity, PlanFamily::Migrate)
            .analytic_budgeted_total(),
    })
}

/// Ample hot capacity for `specs` under either family: Σ per-stream
/// `max(min(r*_keep, K), min(r*_migrate, K))` — quotas never bind, so the
/// family effect is isolated from contention.
pub fn ample_capacity(specs: &[StreamSpec]) -> u64 {
    specs
        .iter()
        .map(|s| {
            crate::cost::hot_demand(&s.model, false)
                .max(crate::cost::hot_demand(&s.model, true))
        })
        .sum::<u64>()
        .max(1)
}

/// E-FLEET-FAMILY: keep vs migrate vs auto at ample capacity and at half
/// of it, on a rent-dominated fleet. Returns the table, the CSV series,
/// and the ample-capacity comparison (the acceptance point).
pub fn e_fleet_family(
    specs: &[StreamSpec],
    seed: u64,
    t_len: usize,
) -> Result<(Table, Series, FamilyComparison)> {
    let ample = ample_capacity(specs);
    let mut table = Table::new(
        &format!(
            "E-FLEET-FAMILY: keep vs migrate vs auto, {} streams (rent-dominated), \
             ample hot capacity {}",
            specs.len(),
            ample
        ),
        &[
            "capacity", "keep $", "migrate $", "auto $", "keep analytic $",
            "migrate analytic $", "migrate saving",
        ],
    );
    let mut series = Series::new(
        "fleet_family",
        &[
            "capacity", "keep_total", "migrate_total", "auto_total", "keep_analytic",
            "migrate_analytic",
        ],
    );
    let mut at_ample = None;
    for capacity in [ample, (ample / 2).max(1)] {
        let cmp = compare_families_at_capacity(specs, capacity, seed, t_len)?;
        table.row(vec![
            capacity.to_string(),
            format!("{:.4}", cmp.keep_total),
            format!("{:.4}", cmp.migrate_total),
            format!("{:.4}", cmp.auto_total),
            format!("{:.4}", cmp.keep_analytic),
            format!("{:.4}", cmp.migrate_analytic),
            format!("{:+.1}%", cmp.saving() * 100.0),
        ]);
        series.push(vec![
            capacity as f64,
            cmp.keep_total,
            cmp.migrate_total,
            cmp.auto_total,
            cmp.keep_analytic,
            cmp.migrate_analytic,
        ]);
        at_ample.get_or_insert(cmp);
    }
    Ok((table, series, at_ample.expect("at least one capacity point")))
}

// ---- the 2×2 mode × family ablation ----------------------------------------

/// One cell of the E-FLEET-FAMILY-ABLATION grid: the same fleet and
/// seeded score sequences under one (contention mode, strategy family)
/// pair.
#[derive(Debug, Clone, Copy)]
pub struct AblationCell {
    pub mode: FleetMode,
    pub family: PlanFamily,
    pub total: f64,
    /// Reactive demotions the mode caused (0 in arbitrated mode).
    pub demotions: u64,
    pub hot_peak: u64,
}

/// E-FLEET-FAMILY-ABLATION: the full 2×2 grid — {arbitrated, naive} ×
/// {keep, migrate} — on a contended rent-dominated fleet (half the ample
/// capacity), identical per-stream score sequences in every cell. The
/// ROADMAP gap this closes: E-FLEET compared modes under keep only, and
/// E-FLEET-FAMILY compared families under arbitration only; the
/// capacity-oblivious **naive-migrate** fleet (reactive demotion *and*
/// changeover bulk-demotion interacting on a shared tier) was never
/// measured.
pub fn e_fleet_family_ablation(
    specs: &[StreamSpec],
    seed: u64,
    t_len: usize,
) -> Result<(Table, Series, Vec<AblationCell>)> {
    let capacity = (ample_capacity(specs) / 2).max(1);
    let mut table = Table::new(
        &format!(
            "E-FLEET-FAMILY-ABLATION: mode × family 2×2, {} streams \
             (rent-dominated), contended hot capacity {}",
            specs.len(),
            capacity
        ),
        &["mode", "family", "total $", "reactive demotions", "hot peak"],
    );
    let mut series = Series::new(
        "fleet_family_ablation",
        &["mode", "family", "total", "demotions", "hot_peak"],
    );
    let mut cells = Vec::with_capacity(4);
    for (mi, mode) in [FleetMode::Arbitrated, FleetMode::Naive].into_iter().enumerate() {
        for (fi, family) in [PlanFamily::Keep, PlanFamily::Migrate].into_iter().enumerate()
        {
            let config = FleetConfig {
                hot_capacity: capacity,
                workers: 1,
                channel_capacity: 64,
                batch: 16,
                t_len,
                seed,
                mode,
                family,
                ..FleetConfig::default()
            };
            let report = run_fleet(specs, &config)?;
            let cell = AblationCell {
                mode,
                family,
                total: report.total_cost(),
                demotions: report.demotions(),
                hot_peak: report.hot_peak,
            };
            table.row(vec![
                format!("{mode:?}").to_lowercase(),
                family.label().to_string(),
                format!("{:.4}", cell.total),
                cell.demotions.to_string(),
                cell.hot_peak.to_string(),
            ]);
            series.push(vec![
                mi as f64,
                fi as f64,
                cell.total,
                cell.demotions as f64,
                cell.hot_peak as f64,
            ]);
            cells.push(cell);
        }
    }
    Ok((table, series, cells))
}

// ---- staggered admission (arrival process) ---------------------------------

/// Totals of one staggered-admission comparison: identical arrivals and
/// score sequences, online re-arbitration vs frozen t=0 quotas.
#[derive(Debug, Clone, Copy)]
pub struct StaggeredComparison {
    pub family: PlanFamily,
    pub capacity: u64,
    /// Ticks between consecutive stream arrivals.
    pub stride: u64,
    pub online_total: f64,
    pub static_total: f64,
    pub online_hot_peak: u64,
    pub static_hot_peak: u64,
}

impl StaggeredComparison {
    /// Relative saving of online re-arbitration over static quotas.
    pub fn saving(&self) -> f64 {
        if self.static_total.abs() < 1e-12 {
            0.0
        } else {
            1.0 - self.online_total / self.static_total
        }
    }
}

/// Run `specs` with stream `s` arriving at tick `s·stride`, each open
/// stream observing one document per tick and closing with
/// `finish_release` (its capacity returns to the pool). With
/// `static_quotas` the engine runs the frozen t=0 verdict over the whole
/// expected fleet ([`StaticArbiter`]); otherwise every open/close/
/// changeover re-arbitrates online. Returns (fleet total $, hot peak).
fn run_staggered(
    specs: &[StreamSpec],
    capacity: u64,
    stride: u64,
    seed: u64,
    t_len: usize,
    family: PlanFamily,
    static_quotas: bool,
) -> Result<(f64, u64)> {
    let cap = usize::try_from(capacity).unwrap_or(usize::MAX);
    let topology = TierTopology::two_tier(specs[0].model.a, specs[0].model.b)
        .with_capacity(HOT, Some(cap));
    let mut builder = Engine::builder()
        .topology(topology.clone())
        .charge_rent(specs.iter().any(|s| s.model.include_rent));
    if static_quotas {
        let snaps: Vec<_> = specs.iter().map(|s| snapshot_of(s, family)).collect();
        builder = builder.arbiter(Box::new(StaticArbiter::precompute(&snaps, &topology)));
    }
    let engine = builder.build()?;

    let scorer = RbfScorer::synthetic_demo();
    let mut rngs: Vec<crate::util::Rng> = specs
        .iter()
        .map(|s| crate::util::Rng::new(stream_seed(seed, s.id)))
        .collect();
    let mut live: Vec<Option<crate::engine::StreamSession>> =
        specs.iter().map(|_| None).collect();
    let mut done = vec![false; specs.len()];
    let mut tick = 0u64;
    while done.iter().any(|d| !d) {
        // arrivals due at this tick (stream ids stay aligned with spec
        // ids because thresholds are monotone in the spec index)
        for (s, spec) in specs.iter().enumerate() {
            if live[s].is_none() && !done[s] && tick >= s as u64 * stride {
                live[s] = Some(engine.open_stream(spec.session_spec_with(false, family))?);
            }
        }
        for s in 0..specs.len() {
            let finished = match live[s].as_mut() {
                Some(sess) if sess.done() => true,
                Some(sess) => {
                    let series = generate_series(specs[s].profile, t_len, &mut rngs[s]);
                    sess.observe(scorer.score_series(&series) as f64)?;
                    false
                }
                None => false,
            };
            if finished {
                let sess = live[s].take().expect("session is live");
                sess.finish_release()?;
                done[s] = true;
            }
        }
        tick += 1;
    }
    Ok((engine.ledger().total(), engine.peak_occupancy(HOT) as u64))
}

/// One staggered-admission comparison point (identical arrivals/scores,
/// two arbitration regimes).
pub fn compare_staggered(
    specs: &[StreamSpec],
    capacity: u64,
    stride: u64,
    seed: u64,
    t_len: usize,
    family: PlanFamily,
) -> Result<StaggeredComparison> {
    let (online_total, online_hot_peak) =
        run_staggered(specs, capacity, stride, seed, t_len, family, false)?;
    let (static_total, static_hot_peak) =
        run_staggered(specs, capacity, stride, seed, t_len, family, true)?;
    Ok(StaggeredComparison {
        family,
        capacity,
        stride,
        online_total,
        static_total,
        online_hot_peak,
        static_hot_peak,
    })
}

/// E-FLEET-STAGGERED: the arrival-process experiment — streams open one
/// every `stride` ticks over a contended hot tier, per family. Measures
/// the value of online re-arbitration + quota lending vs static t=0
/// quotas.
pub fn e_fleet_staggered(
    specs: &[StreamSpec],
    capacity: u64,
    stride: u64,
    seed: u64,
    t_len: usize,
) -> Result<(Table, Series, Vec<StaggeredComparison>)> {
    let mut table = Table::new(
        &format!(
            "E-FLEET-STAGGERED: online re-arbitration vs static t=0 quotas, {} streams, \
             hot capacity {}, arrival stride {}",
            specs.len(),
            capacity,
            stride
        ),
        &["family", "online $", "static $", "saving", "online peak", "static peak"],
    );
    let mut series = Series::new(
        "fleet_staggered",
        &["family", "online_total", "static_total", "online_peak", "static_peak"],
    );
    let mut out = Vec::new();
    for (fi, family) in [PlanFamily::Keep, PlanFamily::Migrate].into_iter().enumerate() {
        let cmp = compare_staggered(specs, capacity, stride, seed, t_len, family)?;
        table.row(vec![
            family.label().to_string(),
            format!("{:.4}", cmp.online_total),
            format!("{:.4}", cmp.static_total),
            format!("{:+.1}%", cmp.saving() * 100.0),
            cmp.online_hot_peak.to_string(),
            cmp.static_hot_peak.to_string(),
        ]);
        series.push(vec![
            fi as f64,
            cmp.online_total,
            cmp.static_total,
            cmp.online_hot_peak as f64,
            cmp.static_hot_peak as f64,
        ]);
        out.push(cmp);
    }
    Ok((table, series, out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::demo_fleet;

    #[test]
    fn arbitration_beats_naive_whenever_oversubscribed() {
        // The acceptance claim: shared-capacity arbitration achieves lower
        // total cost than naive per-stream optima whenever aggregate demand
        // exceeds hot-tier capacity.
        let specs = demo_fleet(6, 400, 12, true, 1);
        let demand: u64 = specs
            .iter()
            .map(|s| crate::cost::hot_demand(&s.model, false))
            .sum();
        for frac in [0.2f64, 0.5] {
            let cap = ((demand as f64 * frac) as u64).max(1);
            let cmp = compare_at_capacity(&specs, cap, 3, 64).unwrap();
            assert!(cap < cmp.aggregate_demand);
            assert!(
                cmp.arbitrated_total < cmp.naive_total,
                "cap {cap}: arbitrated {} !< naive {}",
                cmp.arbitrated_total,
                cmp.naive_total
            );
            assert!(cmp.naive_demotions > 0);
        }
    }

    #[test]
    fn modes_coincide_with_ample_capacity() {
        let specs = demo_fleet(4, 300, 8, true, 2);
        let demand: u64 = specs
            .iter()
            .map(|s| crate::cost::hot_demand(&s.model, false))
            .sum();
        let cmp = compare_at_capacity(&specs, demand, 9, 64).unwrap();
        // no contention → identical placements, identical cost
        let rel = (cmp.arbitrated_total - cmp.naive_total).abs()
            / cmp.naive_total.max(1e-12);
        assert!(rel < 1e-9, "ample capacity should equalise modes (rel {rel})");
        assert_eq!(cmp.naive_demotions, 0);
    }

    #[test]
    fn sweep_table_shape() {
        let specs = demo_fleet(3, 200, 6, true, 4);
        let (table, series, cmps) = e_fleet(&specs, 5, 64, 3).unwrap();
        assert_eq!(table.rows.len(), 3);
        assert_eq!(series.rows.len(), 3);
        assert_eq!(cmps.len(), 3);
        // the last point is at full demand → saving ≈ 0
        assert!(cmps[2].saving().abs() < 1e-6);
    }

    /// The PR's acceptance claim: on a rent-dominated (case-study-2 shape)
    /// economy the migrate family's measured fleet cost beats the keep
    /// family's and tracks the closed-form prediction.
    #[test]
    fn migrate_family_beats_keep_on_rent_dominated_fleet() {
        let specs = crate::fleet::rent_dominated_fleet(8, 2000, 32, 1);
        // ample capacity: the family effect, isolated from contention
        let cmp = compare_families_at_capacity(&specs, ample_capacity(&specs), 3, 48)
            .unwrap();
        assert!(
            cmp.migrate_total < cmp.keep_total,
            "migrate ${} !< keep ${}",
            cmp.migrate_total,
            cmp.keep_total
        );
        let rel = (cmp.migrate_total - cmp.migrate_analytic).abs() / cmp.migrate_analytic;
        assert!(
            rel < 0.15,
            "measured ${} vs analytic ${} (rel {rel})",
            cmp.migrate_total,
            cmp.migrate_analytic
        );
        // auto resolves to the migrate family here → identical plans on
        // identical score sequences → identical measured cost
        assert!(
            (cmp.auto_total - cmp.migrate_total).abs()
                < 1e-9 * cmp.migrate_total.max(1.0),
            "auto ${} != migrate ${}",
            cmp.auto_total,
            cmp.migrate_total
        );
    }

    /// The 2×2 ablation: every cell completes on identical scores, the
    /// hot-capacity invariant holds in all four, only naive cells demote
    /// reactively, and under contention the arbitrated migrate fleet
    /// does not lose to the capacity-oblivious migrate fleet.
    #[test]
    fn family_ablation_fills_the_2x2_grid() {
        let specs = crate::fleet::rent_dominated_fleet(4, 500, 10, 3);
        let (table, series, cells) = e_fleet_family_ablation(&specs, 7, 48).unwrap();
        assert_eq!(table.rows.len(), 4);
        assert_eq!(series.rows.len(), 4);
        assert_eq!(cells.len(), 4);
        let capacity = (ample_capacity(&specs) / 2).max(1);
        for cell in &cells {
            assert!(cell.total.is_finite() && cell.total > 0.0);
            assert!(cell.hot_peak <= capacity, "{:?}/{:?}", cell.mode, cell.family);
            if cell.mode == FleetMode::Arbitrated {
                assert_eq!(cell.demotions, 0, "arbitrated cells never thrash");
            }
        }
        let by = |mode: FleetMode, family: PlanFamily| {
            cells
                .iter()
                .find(|c| c.mode == mode && c.family == family)
                .copied()
                .expect("cell present")
        };
        // the new cell used the hot tier (the migrate family's hot band
        // is interior on rent-dominated economies, unlike keep's)...
        let naive_migrate = by(FleetMode::Naive, PlanFamily::Migrate);
        assert!(naive_migrate.hot_peak > 0, "naive-migrate never placed hot");
        // ...and is a genuinely different regime, not a relabel: the
        // family dimension changes the naive fleet's measured cost
        let naive_keep = by(FleetMode::Naive, PlanFamily::Keep);
        assert!(
            (naive_migrate.total - naive_keep.total).abs()
                > 1e-9 * naive_keep.total.max(1.0),
            "naive migrate ${} indistinguishable from naive keep ${}",
            naive_migrate.total,
            naive_keep.total
        );
    }

    /// Staggered arrivals: online re-arbitration + quota lending never
    /// loses to frozen t=0 quotas on identical arrivals and scores, and
    /// capacity holds in both regimes.
    #[test]
    fn staggered_admission_online_beats_static_quotas() {
        let specs = crate::fleet::rent_dominated_fleet(4, 500, 8, 2);
        let capacity = 16; // Σ demand = 32 → contended
        let (table, series, cmps) = e_fleet_staggered(&specs, capacity, 150, 9, 48).unwrap();
        assert_eq!(table.rows.len(), 2);
        assert_eq!(series.rows.len(), 2);
        for cmp in &cmps {
            assert!(cmp.online_total.is_finite() && cmp.online_total > 0.0);
            assert!(cmp.online_hot_peak <= capacity, "online peak breaks capacity");
            assert!(cmp.static_hot_peak <= capacity, "static peak breaks capacity");
            // lending is weakly better: early/solo streams run closer to
            // their unconstrained optima (tiny slack for float ties)
            assert!(
                cmp.online_total <= cmp.static_total * 1.001,
                "{}: online ${} > static ${}",
                cmp.family.label(),
                cmp.online_total,
                cmp.static_total
            );
        }
    }
}
