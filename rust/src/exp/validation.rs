//! E1/E2: Monte-Carlo validation of the paper's §V–§VI equations.

use crate::report::Table;
use crate::shp;
use crate::util::math::EULER_MASCHERONI;
use crate::util::Rng;

/// E1 — classic SHP (paper eqs. 2–4): at r = N/e the success probability is
/// ≈ 1/e and exactly one write happens.
pub fn shp_classic(seed: u64, reps: u64) -> Table {
    let mut rng = Rng::new(seed);
    let mut t = Table::new(
        "E1: classic SHP (paper eqs. 2-4) — P(hire best) at r = N/e",
        &["N", "r=N/e", "P(best) MC", "P(best) analytic", "paper 1/e", "E[writes]"],
    );
    for n in [100u64, 1_000, 10_000] {
        let r = shp::classic_optimal_r(n);
        let mc = shp::p_hire_best(n, r, reps, &mut rng);
        let an = shp::p_hire_best_analytic(n, r);
        t.row(vec![
            n.to_string(),
            r.to_string(),
            format!("{mc:.4}"),
            format!("{an:.4}"),
            format!("{:.4}", 1.0 / std::f64::consts::E),
            "1".to_string(),
        ]);
    }
    t
}

/// E2 — Algorithm B (paper eqs. 6–8): expected writes = H_N ≈ ln N + γ, and
/// the best document is always saved.
pub fn algorithm_b(seed: u64, reps: u64) -> Table {
    let mut rng = Rng::new(seed);
    let mut t = Table::new(
        "E2: Algorithm B simple overwrite (paper eqs. 6-8), K = 1",
        &["N", "E[writes] MC", "H_N exact", "paper lnN+0.57722", "P(best saved)"],
    );
    for n in [100u64, 1_000, 10_000] {
        let mc = shp::mean_writes(n, 1, reps, &mut rng);
        let exact = crate::cost::algorithm_b_expected_writes(n);
        let paper = (n as f64).ln() + EULER_MASCHERONI;
        // verify best saved on a sample of runs
        let mut all_saved = true;
        for _ in 0..50 {
            if !shp::run_overwrite(n, 1, &mut rng).saved_best {
                all_saved = false;
            }
        }
        t.row(vec![
            n.to_string(),
            format!("{mc:.3}"),
            format!("{exact:.3}"),
            format!("{paper:.3}"),
            if all_saved { "1.0 (50/50 runs)".into() } else { "VIOLATION".to_string() },
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e1_table_has_expected_shape() {
        let t = shp_classic(1, 300);
        assert_eq!(t.rows.len(), 3);
        // MC column near 1/e
        for row in &t.rows {
            let mc: f64 = row[2].parse().unwrap();
            assert!((mc - 0.3679).abs() < 0.06, "{mc}");
        }
    }

    #[test]
    fn e2_table_mc_tracks_harmonic() {
        let t = algorithm_b(2, 300);
        for row in &t.rows {
            let mc: f64 = row[1].parse().unwrap();
            let exact: f64 = row[2].parse().unwrap();
            assert!((mc - exact).abs() / exact < 0.1);
            assert!(row[4].starts_with("1.0"));
        }
    }
}
