//! A1/A2 ablations: policy comparison on identical traces, and what happens
//! when the random-order assumption is violated.

use crate::cost::{optimal_r, scaled, CostModel};
use crate::policy::{
    run_policy, AgeBasedDemotion, Changeover, ChangeoverMigrate, PlacementPolicy, SingleTier,
    SkiRental,
};
use crate::report::Table;
use crate::shp::{fit_write_curve, spearman_position_correlation};
use crate::storage::TierId;
use crate::util::Rng;

/// A1 — run every policy on the same random traces under a case-study
/// economy (scaled down for simulation speed) and rank by measured cost.
pub fn ablation_policies(base: &CostModel, scale: u64, reps: u64, seed: u64) -> Table {
    let m = scaled(base, scale);
    let n = m.n as usize;
    let mut rng = Rng::new(seed);

    let r_no_mig = optimal_r(&m, false).r;
    let r_mig = optimal_r(&m, true).r;

    // policy constructors (fresh per trace — policies carry state)
    type Ctor = Box<dyn Fn(&CostModel) -> Box<dyn PlacementPolicy>>;
    let ctors: Vec<(String, Ctor)> = vec![
        ("all-A".into(), Box::new(|_| Box::new(SingleTier::new(TierId::A)))),
        ("all-B".into(), Box::new(|_| Box::new(SingleTier::new(TierId::B)))),
        (
            format!("changeover(r*={r_no_mig})"),
            Box::new(move |_| Box::new(Changeover::new(r_no_mig))),
        ),
        (
            format!("changeover+migrate(r*={r_mig})"),
            Box::new(move |_| Box::new(ChangeoverMigrate::new(r_mig))),
        ),
        (
            "age-demotion(0.05)".into(),
            Box::new(|_| Box::new(AgeBasedDemotion::new(0.05))),
        ),
        (
            "ski-rental".into(),
            Box::new(|m: &CostModel| Box::new(SkiRental::from_model(m))),
        ),
    ];

    let mut totals = vec![0.0f64; ctors.len()];
    for _ in 0..reps {
        let scores: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        for (i, (_, ctor)) in ctors.iter().enumerate() {
            let mut policy = ctor(&m);
            let r = run_policy(&scores, &m, policy.as_mut()).expect("run");
            totals[i] += r.total_cost();
        }
    }

    let mut rows: Vec<(String, f64)> = ctors
        .iter()
        .zip(&totals)
        .map(|((name, _), &t)| (name.clone(), t / reps as f64))
        .collect();
    rows.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    let best = rows[0].1;

    let mut t = Table::new(
        &format!(
            "A1: policy ablation (N={}, K={}, {} traces, measured ledger $)",
            m.n, m.k, reps
        ),
        &["rank", "policy", "mean cost", "vs best"],
    );
    for (i, (name, cost)) in rows.iter().enumerate() {
        t.row(vec![
            (i + 1).to_string(),
            name.clone(),
            format!("{cost:.4}"),
            format!("{:+.1}%", (cost / best - 1.0) * 100.0),
        ]);
    }
    t
}

/// A2 — violate the random-order assumption: compare write counts and
/// costs on shuffled vs sorted vs adversarial (ascending-score) streams.
pub fn ablation_ordering(n: usize, k: usize, seed: u64) -> Table {
    let mut rng = Rng::new(seed);
    let base: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();

    let mut sorted_asc = base.clone();
    sorted_asc.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut sorted_desc = sorted_asc.clone();
    sorted_desc.reverse();
    // half-sorted: first half random, second half ascending (drift regime)
    let mut half = base.clone();
    half[n / 2..].sort_by(|a, b| a.partial_cmp(b).unwrap());

    let cases = [
        ("random (model holds)", &base),
        ("ascending (worst case)", &sorted_asc),
        ("descending (best case)", &sorted_desc),
        ("second-half sorted", &half),
    ];

    let mut t = Table::new(
        &format!("A2: ordering-assumption ablation (N={n}, K={k})"),
        &["stream order", "spearman", "writes", "analytic", "rel err"],
    );
    for (name, scores) in cases {
        let rho = spearman_position_correlation(scores);
        let fit = fit_write_curve(scores, k);
        let writes = *fit.empirical.last().unwrap();
        let analytic = *fit.analytic.last().unwrap();
        t.row(vec![
            name.to_string(),
            format!("{rho:.3}"),
            writes.to_string(),
            format!("{analytic:.1}"),
            format!("{:.2}", fit.final_rel_err),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::case_study_1;

    #[test]
    fn a1_shp_policy_wins_under_cs1_economics() {
        let t = ablation_policies(&case_study_1(), 20_000, 10, 7);
        // rank-1 row should be the changeover policy (the paper's claim)
        assert!(
            t.rows[0][1].starts_with("changeover"),
            "winner was {}",
            t.rows[0][1]
        );
    }

    #[test]
    fn a2_detects_order_violations() {
        let t = ablation_ordering(5_000, 20, 3);
        // random row: small rel err; ascending row: large
        let rand_err: f64 = t.rows[0][4].parse().unwrap();
        let asc_err: f64 = t.rows[1][4].parse().unwrap();
        assert!(rand_err < 0.15, "random err {rand_err}");
        assert!(asc_err > 5.0, "ascending err {asc_err}");
    }
}
