//! E-DRIFT: the value of drift-aware adaptation (ADR-007).
//!
//! A fleet of streams suffers a mid-stream distribution shift: from
//! document `shift_at` onward every score gets a flat boost, so the
//! late documents dominate the top-K and the secretary k/i admission law
//! the a-priori cuts were derived from stops describing the stream. Three
//! arms run on identical score sequences:
//!
//! - **static**: the a-priori closed-form cuts, never revisited — the
//!   paper's regime. The cut lands before the shift, so everything the
//!   post-shift regime admits is already placed cold.
//! - **adaptive**: the [`crate::adaptive::AdaptiveArbiter`] with the
//!   engine's drift trigger armed. Each stream's detector flags the
//!   realized admission curve shortly after the shift and the arbiter
//!   re-derives suffix-restart cuts through the ordinary re-arbitration
//!   path.
//! - **oracle**: a [`crate::engine::StaticArbiter`] handed
//!   suffix-restart plans derived from the *true* shift index — the
//!   best any detector-driven scheme could do, with zero detection lag.
//!
//! A control fleet with identical economics, seeds, and profiles but no
//! shift measures the cost of running adaptive when nothing drifts (the
//! no-thrash requirement). The acceptance gates — adaptive beats static,
//! adaptive within 20% of the oracle, adaptive within 2% of static on
//! the no-drift fleet — are asserted inline, so every run (including the
//! CI smoke run) enforces them. Worker-count determinism is asserted by
//! running the adaptive arm at 1 and 4 workers and requiring bitwise
//! equal per-stream ledgers.

use crate::adaptive::suffix_restart_plan;
use crate::engine::{Engine, PlanAssignment, StaticArbiter, TierTopology};
use crate::fleet::scheduler::stream_seed;
use crate::fleet::{
    drift_fleet, generate_series, run_fleet, FleetConfig, FleetMode, StreamSpec, COLD, HOT,
};
use crate::interestingness::RbfScorer;
use crate::policy::PlanFamily;
use crate::report::{Series, Table};
use anyhow::{ensure, Result};

/// Totals of one E-DRIFT run, all arms on identical score sequences.
#[derive(Debug, Clone, Copy)]
pub struct DriftOutcome {
    /// Shifted fleet under the frozen a-priori cuts.
    pub static_total: f64,
    /// Shifted fleet under the drift-aware adaptive arbiter.
    pub adaptive_total: f64,
    /// Shifted fleet under shift-aware oracle plans (zero detection lag).
    pub oracle_total: f64,
    /// Control (no-shift) fleet under the a-priori cuts.
    pub nodrift_static_total: f64,
    /// Control (no-shift) fleet under the adaptive arbiter.
    pub nodrift_adaptive_total: f64,
    /// Detector firings in the adaptive shifted run.
    pub drift_detections: u64,
    /// Drift-triggered re-arbitrations in the adaptive shifted run.
    pub drift_rederivations: u64,
}

impl DriftOutcome {
    /// Relative saving of adaptive over static cuts under drift.
    pub fn adaptive_saving(&self) -> f64 {
        1.0 - self.adaptive_total / self.static_total
    }

    /// How far adaptive sits above the shift-aware oracle (can be
    /// negative: a restart slightly after the shift may price the
    /// remaining suffix cheaper than the oracle's earlier cut).
    pub fn oracle_gap(&self) -> f64 {
        self.adaptive_total / self.oracle_total - 1.0
    }

    /// |adaptive − static| / static on the no-drift control fleet.
    pub fn nodrift_overhead(&self) -> f64 {
        (self.nodrift_adaptive_total - self.nodrift_static_total).abs()
            / self.nodrift_static_total.max(1e-12)
    }
}

fn drift_cfg(
    capacity: u64,
    workers: usize,
    t_len: usize,
    seed: u64,
    adaptive: bool,
) -> FleetConfig {
    FleetConfig {
        hot_capacity: capacity,
        workers,
        channel_capacity: 64,
        batch: 16,
        t_len,
        seed,
        mode: FleetMode::Arbitrated,
        family: PlanFamily::Keep,
        adaptive,
        ..FleetConfig::default()
    }
}

/// Drive `specs` under shift-aware oracle plans: every stream runs the
/// suffix-restart plan derived from the *true* shift index, frozen in a
/// [`StaticArbiter`]. Scoring replicates the fleet workers exactly —
/// per-stream RNG seeded by [`stream_seed`], RBF scoring in f32, the
/// shift boost applied in f32 before widening — so the oracle sees the
/// same score sequences as the fleet arms.
fn run_oracle(
    specs: &[StreamSpec],
    capacity: u64,
    shift_at: u64,
    seed: u64,
    t_len: usize,
) -> Result<f64> {
    let costs = vec![specs[0].model.a, specs[0].model.b];
    let topology = TierTopology::two_tier(specs[0].model.a, specs[0].model.b)
        .with_capacity(HOT, Some(usize::try_from(capacity).unwrap_or(usize::MAX)));
    let assignments: Vec<PlanAssignment> = specs
        .iter()
        .map(|s| {
            let plan = suffix_restart_plan(
                &costs,
                s.model.n,
                s.model.k,
                false,
                PlanFamily::Keep,
                shift_at,
            );
            let analytic = plan.analytic_cost(&costs, false);
            PlanAssignment {
                id: s.id,
                family: plan.family(),
                unconstrained: plan.clone(),
                demand: vec![plan.demand(HOT), plan.demand(COLD)],
                quota: vec![None, None],
                plan,
                analytic_unconstrained: analytic,
                analytic_budgeted: analytic,
            }
        })
        .collect();
    let engine = Engine::builder()
        .topology(topology)
        .charge_rent(false)
        .arbiter(Box::new(StaticArbiter::new(assignments)))
        .build()?;

    let scorer = RbfScorer::synthetic_demo();
    let mut sessions = Vec::with_capacity(specs.len());
    for s in specs {
        sessions.push(engine.open_stream(s.session_spec_with(false, PlanFamily::Keep))?);
    }
    for (session, spec) in sessions.iter_mut().zip(specs) {
        let mut rng = crate::util::Rng::new(stream_seed(seed, spec.id));
        for i in 0..spec.model.n {
            let series = generate_series(spec.profile, t_len, &mut rng);
            let mut score = scorer.score_series(&series);
            if let Some(sh) = spec.shift {
                if i >= sh.at {
                    score += sh.boost;
                }
            }
            session.observe(score as f64)?;
        }
    }
    engine.settle_rent(1.0)?;
    for session in sessions {
        session.finish()?;
    }
    Ok(engine.ledger().total())
}

/// E-DRIFT: static a-priori cuts vs adaptive vs shift-aware oracle on a
/// fleet whose score distribution shifts at `shift_at`, plus the
/// no-drift control. Hot capacity is ample (`m·K`) so streams stay
/// decoupled and every arm is deterministic at any worker count.
pub fn e_drift(
    m: usize,
    n_per_stream: u64,
    k: u64,
    shift_at: u64,
    seed: u64,
    t_len: usize,
) -> Result<(Table, Series, DriftOutcome)> {
    let capacity = m as u64 * k;
    let shifted = drift_fleet(m, n_per_stream, k, Some(shift_at), seed);
    let control = drift_fleet(m, n_per_stream, k, None, seed);

    let static_rep = run_fleet(&shifted, &drift_cfg(capacity, 1, t_len, seed, false))?;
    let adaptive_rep = run_fleet(&shifted, &drift_cfg(capacity, 1, t_len, seed, true))?;
    let adaptive_rep4 = run_fleet(&shifted, &drift_cfg(capacity, 4, t_len, seed, true))?;
    for (a, b) in adaptive_rep.streams.iter().zip(adaptive_rep4.streams.iter()) {
        ensure!(
            a.measured == b.measured,
            "adaptive arm diverged across worker counts (stream {}: ${} vs ${})",
            a.id,
            a.measured,
            b.measured
        );
    }
    let oracle_total = run_oracle(&shifted, capacity, shift_at, seed, t_len)?;
    let nodrift_static = run_fleet(&control, &drift_cfg(capacity, 1, t_len, seed, false))?;
    let nodrift_adaptive = run_fleet(&control, &drift_cfg(capacity, 1, t_len, seed, true))?;

    let out = DriftOutcome {
        static_total: static_rep.total_cost(),
        adaptive_total: adaptive_rep.total_cost(),
        oracle_total,
        nodrift_static_total: nodrift_static.total_cost(),
        nodrift_adaptive_total: nodrift_adaptive.total_cost(),
        drift_detections: adaptive_rep.drift_detections,
        drift_rederivations: adaptive_rep.drift_rederivations,
    };

    // the acceptance gates, enforced on every run (incl. the CI smoke)
    ensure!(
        out.drift_detections > 0 && out.drift_rederivations > 0,
        "the shift was never detected ({} detections, {} re-derivations)",
        out.drift_detections,
        out.drift_rederivations
    );
    ensure!(
        out.adaptive_total < out.static_total,
        "adaptive (${:.4}) must beat static a-priori cuts (${:.4}) under drift",
        out.adaptive_total,
        out.static_total
    );
    ensure!(
        out.adaptive_total <= out.oracle_total * 1.20,
        "adaptive (${:.4}) must be within 20% of the shift-aware oracle (${:.4})",
        out.adaptive_total,
        out.oracle_total
    );
    ensure!(
        out.nodrift_overhead() <= 0.02,
        "adaptive (${:.4}) must stay within 2% of static (${:.4}) when nothing drifts",
        out.nodrift_adaptive_total,
        out.nodrift_static_total
    );

    let mut table = Table::new(
        &format!(
            "E-DRIFT: {} streams × {} docs (K={}), shift at {}, hot capacity {}",
            m, n_per_stream, k, shift_at, capacity
        ),
        &["arm", "fleet $", "vs static", "detections", "re-derivations"],
    );
    let vs = |total: f64, baseline: f64| format!("{:+.1}%", (total / baseline - 1.0) * 100.0);
    let rows: [(&str, f64, f64, u64, u64); 5] = [
        ("static (shift)", out.static_total, out.static_total, static_rep.drift_detections, 0),
        (
            "adaptive (shift)",
            out.adaptive_total,
            out.static_total,
            out.drift_detections,
            out.drift_rederivations,
        ),
        ("oracle (shift)", out.oracle_total, out.static_total, 0, 0),
        (
            "static (no drift)",
            out.nodrift_static_total,
            out.nodrift_static_total,
            nodrift_static.drift_detections,
            0,
        ),
        (
            "adaptive (no drift)",
            out.nodrift_adaptive_total,
            out.nodrift_static_total,
            nodrift_adaptive.drift_detections,
            nodrift_adaptive.drift_rederivations,
        ),
    ];
    let mut series = Series::new(
        "drift",
        &["arm", "fleet_total", "drift_detections", "drift_rederivations"],
    );
    for (i, (label, total, baseline, det, red)) in rows.iter().enumerate() {
        table.row(vec![
            label.to_string(),
            format!("{total:.4}"),
            vs(*total, *baseline),
            det.to_string(),
            red.to_string(),
        ]);
        series.push(vec![i as f64, *total, *det as f64, *red as f64]);
    }
    Ok((table, series, out))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e_drift_quick_passes_its_acceptance_gates() {
        // the CI smoke sizes; e_drift asserts the acceptance gates
        // (adaptive < static, within 20% of oracle, no-drift within 2%)
        // inline, so an Ok return IS the pass
        let (_, series, out) = e_drift(3, 1_200, 8, 600, 7, 48).unwrap();
        assert_eq!(series.name, "drift");
        assert!(out.adaptive_saving() > 0.0);
        assert!(out.drift_rederivations >= 3, "every stream should re-derive once");
    }

    #[test]
    fn oracle_drive_is_deterministic() {
        let specs = drift_fleet(2, 600, 8, Some(300), 7);
        let a = run_oracle(&specs, 16, 300, 7, 48).unwrap();
        let b = run_oracle(&specs, 16, 300, 7, 48).unwrap();
        assert_eq!(a, b);
        assert!(a > 0.0);
    }
}
