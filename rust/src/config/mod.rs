//! Launcher configuration: TOML schema → typed config, with validation.
//!
//! Example (`configs/case_study_2.toml`):
//!
//! ```toml
//! [workload]
//! n_docs = 10000
//! k = 500
//! t_len = 256
//! seed = 42
//! sweep_values_per_dim = 7
//! sweep_samples_per_point = 1
//!
//! [pipeline]
//! producers = 4
//! batch_max = 64
//! channel_capacity = 256
//! scorer = "pjrt"          # pjrt | native | auto
//!
//! [economics]
//! preset = "case-study-2"  # case-study-1 | case-study-2 | custom
//! scale_to_n = true        # scale preset N/K down to n_docs
//!
//! [policy]
//! kind = "changeover"      # all-a | all-b | changeover | changeover-migrate
//!                          #   | age-demotion | ski-rental
//! r_frac = 0.078           # omit to use the closed-form optimum
//! ```

use crate::cost::{case_study_1, case_study_2, optimal_r, CostModel, PerDocCosts};
use crate::pipeline::PipelineConfig;
use crate::policy::{
    AgeBasedDemotion, Changeover, ChangeoverMigrate, PlacementPolicy, PlanFamily, SingleTier,
    SkiRental,
};
use crate::serdes::TomlValue;
use crate::storage::TierId;
use anyhow::{anyhow, bail, Context, Result};

/// Parsed launcher configuration.
#[derive(Debug, Clone)]
pub struct LaunchConfig {
    pub pipeline: PipelineConfig,
    pub sweep_values_per_dim: usize,
    pub sweep_samples_per_point: u64,
    pub model: CostModel,
    pub scorer: ScorerKind,
    pub policy: PolicySpec,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScorerKind {
    Pjrt,
    Native,
    Auto,
}

/// Declarative policy spec (instantiated per run — policies are stateful).
#[derive(Debug, Clone, PartialEq)]
pub enum PolicySpec {
    AllA,
    AllB,
    Changeover { r: u64 },
    ChangeoverMigrate { r: u64 },
    AgeDemotion { age_frac: f64 },
    SkiRental,
}

impl PolicySpec {
    pub fn instantiate(&self, model: &CostModel) -> Box<dyn PlacementPolicy> {
        match *self {
            PolicySpec::AllA => Box::new(SingleTier::new(TierId::A)),
            PolicySpec::AllB => Box::new(SingleTier::new(TierId::B)),
            PolicySpec::Changeover { r } => Box::new(Changeover::new(r)),
            PolicySpec::ChangeoverMigrate { r } => Box::new(ChangeoverMigrate::new(r)),
            PolicySpec::AgeDemotion { age_frac } => Box::new(AgeBasedDemotion::new(age_frac)),
            PolicySpec::SkiRental => Box::new(SkiRental::from_model(model)),
        }
    }
}

impl LaunchConfig {
    /// Parse a TOML document (see module docs for the schema).
    pub fn from_toml(text: &str) -> Result<Self> {
        let t = TomlValue::parse(text).context("parsing config TOML")?;

        let get_u64 = |path: &str, default: u64| -> Result<u64> {
            match t.get_path(path) {
                None => Ok(default),
                Some(v) => v
                    .as_u64()
                    .ok_or_else(|| anyhow!("config: {path} must be a non-negative integer")),
            }
        };
        let n_docs = get_u64("workload.n_docs", 10_000)?;
        let k = get_u64("workload.k", (n_docs / 100).max(1))?;
        let t_len = get_u64("workload.t_len", 256)? as usize;
        let seed = get_u64("workload.seed", 20190412)?;
        let values_per_dim = get_u64("workload.sweep_values_per_dim", 7)? as usize;
        let samples = get_u64("workload.sweep_samples_per_point", 1)?;

        let producers = get_u64("pipeline.producers", 4)? as usize;
        let batch_max = get_u64("pipeline.batch_max", 64)? as usize;
        let channel_capacity = get_u64("pipeline.channel_capacity", 256)? as usize;
        let scorer = match t
            .get_path("pipeline.scorer")
            .and_then(|v| v.as_str())
            .unwrap_or("auto")
        {
            "pjrt" => ScorerKind::Pjrt,
            "native" => ScorerKind::Native,
            "auto" => ScorerKind::Auto,
            other => bail!("config: unknown scorer '{other}'"),
        };

        // economics
        let preset = t
            .get_path("economics.preset")
            .and_then(|v| v.as_str())
            .unwrap_or("case-study-2");
        let mut model = match preset {
            "case-study-1" => case_study_1(),
            "case-study-2" => case_study_2(),
            "custom" => parse_custom_economics(&t)?,
            other => bail!("config: unknown economics preset '{other}'"),
        };
        let scale_to_n = t
            .get_path("economics.scale_to_n")
            .and_then(|v| v.as_bool())
            .unwrap_or(true);
        if scale_to_n && preset != "custom" {
            let scale = (model.n / n_docs.max(1)).max(1);
            model = crate::cost::scaled(&model, scale);
        }
        // k override
        if t.get_path("workload.k").is_some() {
            model = CostModel::new(model.n, k.min(model.n), model.a, model.b)
                .with_rent(model.include_rent);
        }

        // policy
        let kind = t
            .get_path("policy.kind")
            .and_then(|v| v.as_str())
            .unwrap_or("changeover");
        let r = match t.get_path("policy.r_frac").and_then(|v| v.as_f64()) {
            Some(f) => {
                if !(0.0..=1.0).contains(&f) {
                    bail!("config: policy.r_frac must be in [0,1]");
                }
                (f * model.n as f64) as u64
            }
            None => optimal_r(&model, kind == "changeover-migrate").r,
        };
        let policy = match kind {
            "all-a" => PolicySpec::AllA,
            "all-b" => PolicySpec::AllB,
            "changeover" => PolicySpec::Changeover { r },
            "changeover-migrate" => PolicySpec::ChangeoverMigrate { r },
            "age-demotion" => PolicySpec::AgeDemotion {
                age_frac: t
                    .get_path("policy.age_frac")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(0.05),
            },
            "ski-rental" => PolicySpec::SkiRental,
            other => bail!("config: unknown policy kind '{other}'"),
        };

        Ok(Self {
            pipeline: PipelineConfig {
                n_docs: n_docs.min(model.n),
                t_len,
                t_end: 60.0,
                producers,
                batch_max,
                channel_capacity,
                seed,
                record_series: true,
                record_scores: true,
            },
            sweep_values_per_dim: values_per_dim,
            sweep_samples_per_point: samples,
            model,
            scorer,
            policy,
        })
    }

    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_toml(&text)
    }
}

/// Parsed fleet launcher configuration (`shptier fleet --config <path>`).
///
/// Schema:
///
/// ```toml
/// [fleet]
/// streams = 16
/// workers = 4
/// hot_capacity = 64        # omit → half the aggregate analytic demand
/// mode = "arbitrated"      # arbitrated | naive
/// family = "keep"          # keep | migrate | auto (strategy family)
/// backend = "sim"          # sim | fs:<root> | obj:<root>  (fresh root;
///                          #   ADR-003 fs, ADR-005 object store)
/// adaptive = false         # drift-aware arbiter + re-derivation (ADR-007)
/// group_commit = false     # batch journal appends (ADR-009; durable backends)
/// selector = "bounded"     # bounded | logmem (admission selector, ADR-010)
/// seed = 7
/// t_len = 256
/// batch = 16
/// channel_capacity = 256
///
/// [fleet.workload]
/// n_docs = 2000            # per-stream base length
/// k = 32                   # per-stream base top-K
/// heterogeneous = true     # cycle economy classes / K / N across streams
/// economy = "demo"         # demo | rent-dominated (case-study-2 shape)
/// ```
#[derive(Debug, Clone)]
pub struct FleetLaunchConfig {
    pub specs: Vec<crate::fleet::StreamSpec>,
    pub config: crate::fleet::FleetConfig,
}

impl FleetLaunchConfig {
    pub fn from_toml(text: &str) -> Result<Self> {
        let t = TomlValue::parse(text).context("parsing fleet config TOML")?;
        let get_u64 = |path: &str, default: u64| -> Result<u64> {
            match t.get_path(path) {
                None => Ok(default),
                Some(v) => v
                    .as_u64()
                    .ok_or_else(|| anyhow!("config: {path} must be a non-negative integer")),
            }
        };
        let streams = get_u64("fleet.streams", 8)?.max(1) as usize;
        let workers = get_u64("fleet.workers", 4)?.max(1) as usize;
        let seed = get_u64("fleet.seed", 20190412)?;
        let t_len = get_u64("fleet.t_len", 256)? as usize;
        let batch = get_u64("fleet.batch", 16)? as usize;
        let channel_capacity = get_u64("fleet.channel_capacity", 256)? as usize;
        let mode = match t
            .get_path("fleet.mode")
            .and_then(|v| v.as_str())
            .unwrap_or("arbitrated")
        {
            "arbitrated" => crate::fleet::FleetMode::Arbitrated,
            "naive" => crate::fleet::FleetMode::Naive,
            other => bail!("config: unknown fleet mode '{other}'"),
        };
        let family = PlanFamily::parse(
            t.get_path("fleet.family").and_then(|v| v.as_str()).unwrap_or("keep"),
        )
        .map_err(|e| anyhow!("config: fleet.family: {e}"))?;
        let backend = crate::engine::BackendSpec::parse(
            t.get_path("fleet.backend").and_then(|v| v.as_str()).unwrap_or("sim"),
        )
        .map_err(|e| anyhow!("config: fleet.backend: {e}"))?;
        let adaptive = t
            .get_path("fleet.adaptive")
            .and_then(|v| v.as_bool())
            .unwrap_or(false);
        let group_commit = t
            .get_path("fleet.group_commit")
            .and_then(|v| v.as_bool())
            .unwrap_or(false);
        let selector = crate::topk::SelectorKind::parse(
            t.get_path("fleet.selector").and_then(|v| v.as_str()).unwrap_or("bounded"),
        )
        .map_err(|e| anyhow!("config: fleet.selector: {e}"))?;
        let n_docs = get_u64("fleet.workload.n_docs", 2_000)?.max(1);
        let k = get_u64("fleet.workload.k", 32)?.max(1);
        let heterogeneous = t
            .get_path("fleet.workload.heterogeneous")
            .and_then(|v| v.as_bool())
            .unwrap_or(true);
        let specs = match t
            .get_path("fleet.workload.economy")
            .and_then(|v| v.as_str())
            .unwrap_or("demo")
        {
            "demo" => crate::fleet::demo_fleet(streams, n_docs, k, heterogeneous, seed),
            "rent-dominated" => {
                crate::fleet::rent_dominated_fleet(streams, n_docs, k, seed)
            }
            other => bail!("config: unknown fleet economy '{other}'"),
        };
        // the default-capacity heuristic uses the demand of the family
        // the streams will actually run; Auto resolves per stream, so it
        // reserves for whichever family is hungrier. The demand is quoted
        // slack-adjusted (ADR-010): a log-memory selector admits an
        // ε-overshoot superset of the exact top-K, and a capacity sized
        // from the slack-free plan would over-admit against it.
        let aggregate_demand: u64 = specs
            .iter()
            .map(|s| {
                let eps = selector.slack(s.model.k);
                match family {
                    PlanFamily::Keep => {
                        crate::cost::hot_demand_with_slack(&s.model, false, eps)
                    }
                    PlanFamily::Migrate => {
                        crate::cost::hot_demand_with_slack(&s.model, true, eps)
                    }
                    PlanFamily::Auto => crate::cost::hot_demand_with_slack(
                        &s.model, false, eps,
                    )
                    .max(crate::cost::hot_demand_with_slack(&s.model, true, eps)),
                }
            })
            .sum();
        let hot_capacity = match t.get_path("fleet.hot_capacity") {
            Some(v) => v
                .as_u64()
                .ok_or_else(|| anyhow!("config: fleet.hot_capacity must be an integer"))?,
            // default: a contended tier at half the aggregate demand
            None => (aggregate_demand / 2).max(1),
        };

        Ok(Self {
            specs,
            config: crate::fleet::FleetConfig {
                hot_capacity,
                workers,
                channel_capacity,
                batch,
                t_len,
                seed,
                mode,
                family,
                backend,
                adaptive,
                group_commit,
                selector,
            },
        })
    }

    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_toml(&text)
    }
}

/// Parsed `shptier engine` demo configuration (`[engine]` TOML section):
/// an N-tier engine fleet with a mid-run stream closure, demonstrating
/// online re-arbitration.
///
/// Schema (all keys optional):
///
/// ```toml
/// [engine]
/// streams = 4              # concurrent sessions
/// docs = 1200              # per-stream length
/// k = 24                   # per-stream top-K
/// tiers = 3                # 2..=4 (hot → cold)
/// hot_capacity = 16        # hottest-tier slots (0 → half aggregate demand)
/// seed = 7
/// close_percent = 50       # close session 0 after this % of its stream
/// backend = "sim"          # sim | fs:<root> | obj:<root>
///                          #   (fs = ADR-003, object store = ADR-005)
/// family = "keep"          # keep | migrate | auto (strategy family)
/// adaptive = false         # drift-aware arbiter + re-derivation (ADR-007)
/// group_commit = false     # batch journal appends (ADR-009; durable backends)
/// selector = "bounded"     # bounded | logmem (admission selector, ADR-010)
/// ```
#[derive(Debug, Clone)]
pub struct EngineDemoConfig {
    pub streams: usize,
    pub docs: u64,
    pub k: u64,
    pub tiers: usize,
    /// 0 means "derive a contended default" (half the aggregate demand).
    pub hot_capacity: u64,
    pub seed: u64,
    /// Percentage of session 0's stream after which it closes mid-run.
    pub close_percent: u64,
    /// Storage backend selector: `sim`, `fs:<root>`, or `obj:<root>`
    /// (see [`crate::engine::BackendSpec::parse`]).
    pub backend: String,
    /// Strategy family the demo sessions run (keep | migrate | auto).
    pub family: PlanFamily,
    /// Run under the drift-aware [`crate::adaptive::AdaptiveArbiter`] with
    /// the drift→re-derivation trigger armed (ADR-007).
    pub adaptive: bool,
    /// Batch journal appends into group commits (ADR-009). A no-op on
    /// the in-memory simulator.
    pub group_commit: bool,
    /// Admission selector the demo sessions run (ADR-010): `bounded`
    /// (exact top-K heap) or `logmem` (O(log K)-memory sketch).
    pub selector: crate::topk::SelectorKind,
}

impl EngineDemoConfig {
    pub fn from_toml(text: &str) -> Result<Self> {
        let t = TomlValue::parse(text).context("parsing engine config TOML")?;
        let get_u64 = |path: &str, default: u64| -> Result<u64> {
            match t.get_path(path) {
                None => Ok(default),
                Some(v) => v
                    .as_u64()
                    .ok_or_else(|| anyhow!("config: {path} must be a non-negative integer")),
            }
        };
        Self {
            streams: get_u64("engine.streams", 4)? as usize,
            docs: get_u64("engine.docs", 1200)?,
            k: get_u64("engine.k", 24)?,
            tiers: get_u64("engine.tiers", 3)? as usize,
            hot_capacity: get_u64("engine.hot_capacity", 0)?,
            seed: get_u64("engine.seed", 20190412)?,
            close_percent: get_u64("engine.close_percent", 50)?,
            backend: t
                .get_path("engine.backend")
                .and_then(|v| v.as_str())
                .unwrap_or("sim")
                .to_string(),
            family: PlanFamily::parse(
                t.get_path("engine.family").and_then(|v| v.as_str()).unwrap_or("keep"),
            )
            .map_err(|e| anyhow!("config: engine.family: {e}"))?,
            adaptive: t
                .get_path("engine.adaptive")
                .and_then(|v| v.as_bool())
                .unwrap_or(false),
            group_commit: t
                .get_path("engine.group_commit")
                .and_then(|v| v.as_bool())
                .unwrap_or(false),
            selector: crate::topk::SelectorKind::parse(
                t.get_path("engine.selector").and_then(|v| v.as_str()).unwrap_or("bounded"),
            )
            .map_err(|e| anyhow!("config: engine.selector: {e}"))?,
        }
        .normalized()
    }

    /// The single validation/clamping rule set, shared by the TOML path
    /// and the CLI flag-override path (`shptier engine`): clamp the soft
    /// knobs, reject the nonsensical ones.
    pub fn normalized(mut self) -> Result<Self> {
        if !(2..=4).contains(&self.tiers) {
            bail!("config: engine.tiers must be in 2..=4 (got {})", self.tiers);
        }
        if self.close_percent > 100 {
            bail!("config: engine.close_percent must be in 0..=100");
        }
        // validate the backend selector early, with the config-file spelling
        crate::engine::BackendSpec::parse(&self.backend)
            .map_err(|e| anyhow!("config: engine.backend: {e}"))?;
        self.streams = self.streams.max(2);
        self.docs = self.docs.max(10);
        self.k = self.k.max(1);
        Ok(self)
    }

    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_toml(&text)
    }

    /// The demo tier hierarchy: interior changeover economics at every
    /// boundary (each tier cheaper to write and dearer to read than the
    /// next colder one), rent excluded.
    pub fn tier_costs(&self) -> Vec<PerDocCosts> {
        let presets = [
            PerDocCosts { write: 1.0, read: 4.0, rent_window: 0.0 },
            PerDocCosts { write: 2.0, read: 1.9, rent_window: 0.0 },
            PerDocCosts { write: 3.0, read: 0.2, rent_window: 0.0 },
            PerDocCosts { write: 4.0, read: 0.05, rent_window: 0.0 },
        ];
        presets[..self.tiers].to_vec()
    }
}

fn parse_custom_economics(t: &TomlValue) -> Result<CostModel> {
    let read = |tier: &str, field: &str| -> Result<f64> {
        t.get_path(&format!("economics.{tier}.{field}"))
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow!("config: economics.{tier}.{field} required for custom"))
    };
    let n = t
        .get_path("economics.n")
        .and_then(|v| v.as_u64())
        .ok_or_else(|| anyhow!("config: economics.n required for custom"))?;
    let k = t
        .get_path("economics.k")
        .and_then(|v| v.as_u64())
        .ok_or_else(|| anyhow!("config: economics.k required for custom"))?;
    let a = PerDocCosts {
        write: read("tier_a", "write")?,
        read: read("tier_a", "read")?,
        rent_window: read("tier_a", "rent_window")?,
    };
    let b = PerDocCosts {
        write: read("tier_b", "write")?,
        read: read("tier_b", "read")?,
        rent_window: read("tier_b", "rent_window")?,
    };
    let include_rent = t
        .get_path("economics.include_rent")
        .and_then(|v| v.as_bool())
        .unwrap_or(true);
    Ok(CostModel::new(n, k, a, b).with_rent(include_rent))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_config_with_defaults() {
        let c = LaunchConfig::from_toml("").unwrap();
        assert_eq!(c.pipeline.n_docs, 10_000);
        assert_eq!(c.scorer, ScorerKind::Auto);
        assert!(matches!(c.policy, PolicySpec::Changeover { .. }));
        // CS2 preset scaled to 10k docs
        assert_eq!(c.model.n, 10_000);
    }

    #[test]
    fn parses_full_config() {
        let c = LaunchConfig::from_toml(
            r#"
[workload]
n_docs = 500
k = 25
seed = 7

[pipeline]
producers = 2
scorer = "native"

[economics]
preset = "case-study-1"

[policy]
kind = "changeover-migrate"
r_frac = 0.25
"#,
        )
        .unwrap();
        assert_eq!(c.pipeline.n_docs, 500);
        assert_eq!(c.model.k, 25);
        assert_eq!(c.scorer, ScorerKind::Native);
        assert_eq!(c.policy, PolicySpec::ChangeoverMigrate { r: 125 });
    }

    #[test]
    fn custom_economics() {
        let c = LaunchConfig::from_toml(
            r#"
[economics]
preset = "custom"
n = 1000
k = 10
include_rent = false
[economics.tier_a]
write = 1.0
read = 2.0
rent_window = 0.0
[economics.tier_b]
write = 3.0
read = 0.5
rent_window = 0.0
"#,
        )
        .unwrap();
        assert_eq!(c.model.n, 1000);
        assert!(!c.model.include_rent);
        assert_eq!(c.model.b.write, 3.0);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(LaunchConfig::from_toml("[policy]\nkind = \"nope\"\n").is_err());
        assert!(LaunchConfig::from_toml("[policy]\nr_frac = 1.5\n").is_err());
        assert!(LaunchConfig::from_toml("[pipeline]\nscorer = \"gpu\"\n").is_err());
        assert!(LaunchConfig::from_toml("[economics]\npreset = \"custom\"\n").is_err());
    }

    #[test]
    fn policy_spec_instantiates() {
        let c = LaunchConfig::from_toml("").unwrap();
        let p = c.policy.instantiate(&c.model);
        assert!(p.name().starts_with("changeover"));
    }

    #[test]
    fn fleet_config_defaults() {
        let c = FleetLaunchConfig::from_toml("").unwrap();
        assert_eq!(c.specs.len(), 8);
        assert!(c.config.hot_capacity >= 1);
        assert_eq!(c.config.mode, crate::fleet::FleetMode::Arbitrated);
        // default capacity = half the aggregate demand → contended
        let demand: u64 = c
            .specs
            .iter()
            .map(|s| crate::cost::hot_demand(&s.model, false))
            .sum();
        assert_eq!(c.config.hot_capacity, (demand / 2).max(1));
    }

    #[test]
    fn fleet_config_full() {
        let c = FleetLaunchConfig::from_toml(
            r#"
[fleet]
streams = 3
workers = 2
hot_capacity = 9
mode = "naive"
seed = 5

[fleet.workload]
n_docs = 100
k = 4
heterogeneous = false
"#,
        )
        .unwrap();
        assert_eq!(c.specs.len(), 3);
        assert_eq!(c.config.hot_capacity, 9);
        assert_eq!(c.config.workers, 2);
        assert_eq!(c.config.mode, crate::fleet::FleetMode::Naive);
        assert!(c.specs.iter().all(|s| s.model.n == 100 && s.model.k == 4));
    }

    #[test]
    fn fleet_config_rejects_bad_mode() {
        assert!(FleetLaunchConfig::from_toml("[fleet]\nmode = \"chaos\"\n").is_err());
    }

    #[test]
    fn fleet_config_family_backend_and_economy() {
        let c = FleetLaunchConfig::from_toml(
            "[fleet]\nfamily = \"migrate\"\nbackend = \"fs:/tmp/x\"\n\
             [fleet.workload]\neconomy = \"rent-dominated\"\n",
        )
        .unwrap();
        assert_eq!(c.config.family, PlanFamily::Migrate);
        assert!(matches!(c.config.backend, crate::engine::BackendSpec::Fs { .. }));
        assert!(c.specs.iter().all(|s| s.model.include_rent));
        // the object-store backend parses through the same selector
        let o = FleetLaunchConfig::from_toml("[fleet]\nbackend = \"obj:/tmp/b\"\n").unwrap();
        assert!(matches!(o.config.backend, crate::engine::BackendSpec::Obj { .. }));
        // defaults stay keep/sim/demo
        let d = FleetLaunchConfig::from_toml("").unwrap();
        assert_eq!(d.config.family, PlanFamily::Keep);
        assert_eq!(d.config.backend, crate::engine::BackendSpec::Sim);
        // bad selectors are rejected with the config spelling
        assert!(FleetLaunchConfig::from_toml("[fleet]\nfamily = \"x\"\n").is_err());
        assert!(FleetLaunchConfig::from_toml("[fleet]\nbackend = \"s3\"\n").is_err());
        assert!(FleetLaunchConfig::from_toml("[fleet]\nbackend = \"obj:\"\n").is_err());
        assert!(
            FleetLaunchConfig::from_toml("[fleet.workload]\neconomy = \"x\"\n").is_err()
        );
    }

    #[test]
    fn fleet_and_engine_adaptive_keys() {
        let d = FleetLaunchConfig::from_toml("").unwrap();
        assert!(!d.config.adaptive);
        let c = FleetLaunchConfig::from_toml("[fleet]\nadaptive = true\n").unwrap();
        assert!(c.config.adaptive);
        let e = EngineDemoConfig::from_toml("").unwrap();
        assert!(!e.adaptive);
        let e = EngineDemoConfig::from_toml("[engine]\nadaptive = true\n").unwrap();
        assert!(e.adaptive);
    }

    #[test]
    fn fleet_and_engine_group_commit_keys() {
        let d = FleetLaunchConfig::from_toml("").unwrap();
        assert!(!d.config.group_commit, "group commit defaults off");
        let c = FleetLaunchConfig::from_toml("[fleet]\ngroup_commit = true\n").unwrap();
        assert!(c.config.group_commit);
        let e = EngineDemoConfig::from_toml("").unwrap();
        assert!(!e.group_commit, "group commit defaults off");
        let e = EngineDemoConfig::from_toml("[engine]\ngroup_commit = true\n").unwrap();
        assert!(e.group_commit);
    }

    #[test]
    fn fleet_and_engine_selector_keys() {
        use crate::topk::SelectorKind;
        let d = FleetLaunchConfig::from_toml("").unwrap();
        assert_eq!(d.config.selector, SelectorKind::Bounded, "selector defaults bounded");
        let c = FleetLaunchConfig::from_toml("[fleet]\nselector = \"logmem\"\n").unwrap();
        assert_eq!(c.config.selector, SelectorKind::LogMem);
        assert!(FleetLaunchConfig::from_toml("[fleet]\nselector = \"exact\"\n").is_err());
        let e = EngineDemoConfig::from_toml("").unwrap();
        assert_eq!(e.selector, SelectorKind::Bounded);
        let e = EngineDemoConfig::from_toml("[engine]\nselector = \"logmem\"\n").unwrap();
        assert_eq!(e.selector, SelectorKind::LogMem);
        assert!(EngineDemoConfig::from_toml("[engine]\nselector = \"x\"\n").is_err());
    }

    /// Satellite regression (ADR-010): the fleet's default-capacity
    /// heuristic must quote *slack-adjusted* analytic demand. The old
    /// path summed `hot_demand` from the slack-free plan, so a logmem
    /// fleet at massive K got a tier sized for the exact selector and
    /// over-admitted against the ε-superset the sketch actually admits.
    #[test]
    fn fleet_default_capacity_reserves_for_selector_slack() {
        use crate::topk::SelectorKind;
        let toml = |sel: &str| {
            format!(
                "[fleet]\nselector = \"{sel}\"\n\
                 [fleet.workload]\nn_docs = 400000\nk = 100000\nheterogeneous = false\n"
            )
        };
        let bounded = FleetLaunchConfig::from_toml(&toml("bounded")).unwrap();
        let logmem = FleetLaunchConfig::from_toml(&toml("logmem")).unwrap();
        // same workload, same slack-free analytic demand …
        let slack_free: u64 = bounded
            .specs
            .iter()
            .map(|s| crate::cost::hot_demand(&s.model, false))
            .sum();
        assert_eq!(bounded.config.hot_capacity, (slack_free / 2).max(1));
        // … but the logmem fleet reserves strictly more (the old path
        // returned the slack-free figure here — the over-admission bug)
        let eps = SelectorKind::LogMem.slack(100_000);
        assert!(eps > 0.0);
        assert!(
            logmem.config.hot_capacity > bounded.config.hot_capacity,
            "logmem default capacity {} must exceed slack-free {}",
            logmem.config.hot_capacity,
            bounded.config.hot_capacity
        );
        let slacked: u64 = logmem
            .specs
            .iter()
            .map(|s| crate::cost::hot_demand_with_slack(&s.model, false, eps))
            .sum();
        assert_eq!(logmem.config.hot_capacity, (slacked / 2).max(1));
    }

    #[test]
    fn engine_config_defaults_and_tiers() {
        let c = EngineDemoConfig::from_toml("").unwrap();
        assert_eq!(c.tiers, 3);
        assert_eq!(c.streams, 4);
        assert_eq!(c.close_percent, 50);
        assert_eq!(c.tier_costs().len(), 3);
        // write costs increase, read costs decrease hot → cold
        let costs = c.tier_costs();
        for w in costs.windows(2) {
            assert!(w[0].write < w[1].write);
            assert!(w[0].read > w[1].read);
        }
    }

    #[test]
    fn engine_config_full_and_validation() {
        let c = EngineDemoConfig::from_toml(
            "[engine]\nstreams = 6\ndocs = 500\nk = 8\ntiers = 2\nhot_capacity = 9\n\
             close_percent = 25\n",
        )
        .unwrap();
        assert_eq!(c.streams, 6);
        assert_eq!(c.docs, 500);
        assert_eq!(c.tiers, 2);
        assert_eq!(c.hot_capacity, 9);
        assert_eq!(c.close_percent, 25);
        assert!(EngineDemoConfig::from_toml("[engine]\ntiers = 7\n").is_err());
        assert!(EngineDemoConfig::from_toml("[engine]\nclose_percent = 101\n").is_err());
    }

    #[test]
    fn engine_config_family_selection() {
        let c = EngineDemoConfig::from_toml("").unwrap();
        assert_eq!(c.family, PlanFamily::Keep);
        let c = EngineDemoConfig::from_toml("[engine]\nfamily = \"auto\"\n").unwrap();
        assert_eq!(c.family, PlanFamily::Auto);
        assert!(EngineDemoConfig::from_toml("[engine]\nfamily = \"x\"\n").is_err());
    }

    #[test]
    fn engine_config_backend_selection() {
        let c = EngineDemoConfig::from_toml("").unwrap();
        assert_eq!(c.backend, "sim");
        let c =
            EngineDemoConfig::from_toml("[engine]\nbackend = \"fs:/tmp/shptier\"\n").unwrap();
        assert_eq!(c.backend, "fs:/tmp/shptier");
        let c =
            EngineDemoConfig::from_toml("[engine]\nbackend = \"obj:/tmp/shp\"\n").unwrap();
        assert_eq!(c.backend, "obj:/tmp/shp");
        assert!(EngineDemoConfig::from_toml("[engine]\nbackend = \"s3\"\n").is_err());
        assert!(EngineDemoConfig::from_toml("[engine]\nbackend = \"fs:\"\n").is_err());
        assert!(EngineDemoConfig::from_toml("[engine]\nbackend = \"obj:\"\n").is_err());
    }
}
