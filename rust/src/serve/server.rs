//! The placement server: routing, session registry, billing records,
//! sidecar invoicing log, and lifecycle (graceful drain / crash
//! recovery).
//!
//! Threading model: one acceptor thread feeds accepted connections into
//! an `mpsc` channel drained by a fixed pool of worker threads (the
//! classic shared-`Receiver` pool — no dependencies). Connections are
//! persistent (HTTP/1.1 keep-alive, ADR-008): a worker serves requests
//! off one connection until the client closes, sends
//! `Connection: close`, or goes idle past [`KEEP_ALIVE_IDLE`]. Two
//! guards keep the pool fair with more connections than workers: after
//! every response the worker yields its pinned connection whenever
//! another connection is waiting in the accept queue (clients reconnect
//! transparently — see `serve::client`), and between requests the
//! connection only gets the short idle budget instead of the full read
//! timeout, so drains and shutdowns stay prompt. Session state lives in
//! the registry, not on a thread, so a handful of workers still serve
//! thousands of concurrent *sessions*.
//!
//! Durability: engine state (residency, ledgers) recovers through the
//! backend journal — and since ADR-009 so does tenant attribution: the
//! open handler encodes `reserved_hot`/`degraded`/tenant into the
//! [`SessionSpec`] note, which the backend journals *inside the same
//! registration record that creates the stream*. A kill between "stream
//! exists" and "stream attributed" is therefore impossible (the old
//! append-to-`serve.log`-before-responding dance could lose attribution
//! for a stream whose registration had already been journaled). The
//! sidecar log (`serve.log` beside the journal) remains for what the
//! journal genuinely cannot know: serve-level completion (`fin` — the
//! client saw the finish response) and per-tenant `settled` aggregates
//! folded at graceful shutdown. Its `open` lines are now a read-optimized
//! cache, rebuilt from the journal on restart and refreshed best-effort.
//! When the engine runs `sync_writes`, sidecar appends fsync too — the
//! two logs share one durability posture.

use std::collections::BTreeMap;
use std::io::Write as _;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::engine::{BackendSpec, Engine, SessionSpec, StreamSession};
use crate::serve::http::{self, ReadError, Request};
use crate::serve::tenancy::{AdmissionControl, AdmissionVerdict};
use crate::serve::wire::{
    self, ErrorBody, FinishResponse, Invoice, InvoiceLine, ObserveRequest, ObserveResponse,
    OpenRequest, OpenResponse, Status, TenantStatus, TierStatus,
};
use crate::serve::ServeConfig;
use crate::storage::{FsBackend, ObjectBackend, StorageBackend, StorageSim, TierId};
use crate::util::SplitMix64;

/// Open the storage backend for serving. Unlike the demo surfaces'
/// `open_fresh` (which refuses roots with prior state, because demo ids
/// restart at 0), serving *wants* prior state: durable roots are opened
/// with journal replay, and the engine continues the stream-id sequence
/// past whatever was recovered.
pub fn open_serving_backend(
    spec: &BackendSpec,
    costs: Vec<crate::cost::PerDocCosts>,
    charge_rent: bool,
    sync_writes: bool,
) -> Result<Box<dyn StorageBackend>> {
    let mut backend: Box<dyn StorageBackend> = match spec {
        BackendSpec::Sim => Box::new(StorageSim::with_tiers(costs, charge_rent)),
        BackendSpec::Fs { root } => Box::new(FsBackend::open(root, costs, charge_rent)?),
        BackendSpec::Obj { root } => Box::new(ObjectBackend::open(root, costs, charge_rent)?),
    };
    if sync_writes {
        backend.set_sync_writes(true);
    }
    Ok(backend)
}

/// Where the sidecar invoicing log lives for a durable root (`None` for
/// the in-memory simulator: its state dies with the process anyway).
fn sidecar_path(spec: &BackendSpec) -> Option<PathBuf> {
    match spec {
        BackendSpec::Sim => None,
        BackendSpec::Fs { root } | BackendSpec::Obj { root } => Some(root.join("serve.log")),
    }
}

/// Billing record for one stream, live or historical.
#[derive(Debug, Clone)]
struct StreamRecord {
    tenant: String,
    degraded: bool,
    reserved_hot: u64,
    completed: bool,
}

/// Encode the tenancy facts journaled with a stream's registration
/// (ADR-009: the [`SessionSpec`] note). Same shape as the sidecar `open`
/// payload — the tenant name ends the note so names may contain spaces.
fn encode_attribution(reserved_hot: u64, degraded: bool, tenant: &str) -> String {
    format!("{reserved_hot} {} {tenant}", u8::from(degraded))
}

/// Parse a registration note back into a (not-yet-completed) billing
/// record. `None` for notes this server did not write — foreign notes are
/// ignored rather than misattributed.
fn parse_attribution(note: &str) -> Option<StreamRecord> {
    let mut f = note.splitn(3, ' ');
    let reserved_hot = f.next()?.parse::<u64>().ok()?;
    let degraded = f.next()?.parse::<u64>().ok()? != 0;
    let tenant = f.next()?.to_string();
    Some(StreamRecord { tenant, degraded, reserved_hot, completed: false })
}

/// Per-tenant aggregate of completed streams folded out of the sidecar
/// log at a past graceful shutdown. Their per-stream records are gone;
/// the invoice carries these totals instead.
#[derive(Debug, Clone, Copy, Default)]
struct SettledTotals {
    streams: u64,
    cost: f64,
}

/// Live session entry behind its session token.
struct SessionEntry {
    /// `None` once finished (finish consumes the engine handle).
    session: Option<StreamSession>,
    stream_id: u64,
    tenant_id: usize,
    n: u64,
    observed: u64,
    reserved_hot: u64,
    degraded: bool,
}

/// Append-only sidecar log (see module docs). Lines:
///
/// ```text
/// open <stream_id> <reserved_hot> <degraded 0|1> <tenant name…>
/// fin <stream_id>
/// settled <streams> <cost bits hex> <tenant name…>
/// ```
///
/// The tenant name ends the line so names may contain spaces. `settled`
/// lines are written only by the graceful-shutdown fold: finished
/// streams collapse into one per-tenant aggregate (cost stored as f64
/// bits so the fold is exact), keeping the log proportional to *live*
/// streams instead of all streams ever served.
struct Sidecar {
    file: Option<std::fs::File>,
    path: Option<PathBuf>,
    /// Mirror of the journal's `sync_writes`: when the engine fsyncs its
    /// appends, attribution must be no less durable than the state it
    /// attributes, so sidecar appends fsync too.
    sync: bool,
}

impl Sidecar {
    fn append(&mut self, line: &str) -> Result<()> {
        if let Some(f) = &mut self.file {
            writeln!(f, "{line}").context("appending to serve.log")?;
            // Flush to the OS: survives process death (SIGKILL). Matches
            // the journal's own durability posture — fsync only when the
            // engine itself runs `sync_writes`.
            f.flush().context("flushing serve.log")?;
            if self.sync {
                f.sync_data().context("fsyncing serve.log")?;
            }
        }
        Ok(())
    }
}

type SidecarState = (BTreeMap<u64, StreamRecord>, BTreeMap<String, SettledTotals>);

fn load_sidecar(path: &std::path::Path) -> Result<SidecarState> {
    let mut records = BTreeMap::new();
    let mut settled: BTreeMap<String, SettledTotals> = BTreeMap::new();
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok((records, settled)),
        Err(e) => return Err(anyhow!("reading {}: {e}", path.display())),
    };
    for (lineno, line) in text.lines().enumerate() {
        if line.is_empty() {
            continue;
        }
        let mut parts = line.splitn(2, ' ');
        let verb = parts.next().unwrap_or("");
        let rest = parts.next().unwrap_or("");
        match verb {
            "open" => {
                let mut f = rest.splitn(4, ' ');
                let parse = |s: Option<&str>, what: &str| -> Result<u64> {
                    s.and_then(|v| v.parse::<u64>().ok()).ok_or_else(|| {
                        anyhow!("serve.log line {}: bad {what}: {line:?}", lineno + 1)
                    })
                };
                let id = parse(f.next(), "stream id")?;
                let reserved_hot = parse(f.next(), "reservation")?;
                let degraded = parse(f.next(), "degraded flag")? != 0;
                let tenant = f
                    .next()
                    .ok_or_else(|| anyhow!("serve.log line {}: missing tenant", lineno + 1))?
                    .to_string();
                records.insert(id, StreamRecord { tenant, degraded, reserved_hot, completed: false });
            }
            "fin" => {
                let id = rest.trim().parse::<u64>().map_err(|_| {
                    anyhow!("serve.log line {}: bad stream id: {line:?}", lineno + 1)
                })?;
                if let Some(r) = records.get_mut(&id) {
                    r.completed = true;
                }
            }
            "settled" => {
                let mut f = rest.splitn(3, ' ');
                let streams = f
                    .next()
                    .and_then(|v| v.parse::<u64>().ok())
                    .ok_or_else(|| {
                        anyhow!("serve.log line {}: bad settled count: {line:?}", lineno + 1)
                    })?;
                let cost = f
                    .next()
                    .and_then(|v| u64::from_str_radix(v, 16).ok())
                    .map(f64::from_bits)
                    .filter(|c| c.is_finite())
                    .ok_or_else(|| {
                        anyhow!("serve.log line {}: bad settled cost: {line:?}", lineno + 1)
                    })?;
                let tenant = f
                    .next()
                    .ok_or_else(|| anyhow!("serve.log line {}: missing tenant", lineno + 1))?
                    .to_string();
                let e = settled.entry(tenant).or_default();
                e.streams += streams;
                e.cost += cost;
            }
            other => bail!("serve.log line {}: unknown verb {other:?}", lineno + 1),
        }
    }
    Ok((records, settled))
}

/// Everything the workers share.
struct ServerState {
    engine: Engine,
    config: ServeConfig,
    backend_label: String,
    admission: Mutex<AdmissionControl>,
    /// Session token → live entry. Lock order: this map before an entry.
    sessions: Mutex<BTreeMap<String, Arc<Mutex<SessionEntry>>>>,
    /// Stream id → billing record (live and historical).
    records: Mutex<BTreeMap<u64, StreamRecord>>,
    /// Tenant name → totals folded out of the sidecar at past shutdowns.
    settled: Mutex<BTreeMap<String, SettledTotals>>,
    sidecar: Mutex<Sidecar>,
    nonce: Mutex<SplitMix64>,
    /// Set by `POST /v1/shutdown`; `RunningServer::wait` watches it.
    shutdown_requested: AtomicBool,
    /// Tells the acceptor to stop accepting.
    stop_accepting: AtomicBool,
}

/// A started server: address, threads, shared state.
pub struct RunningServer {
    addr: SocketAddr,
    state: Arc<ServerState>,
    acceptor: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl RunningServer {
    /// Bind, recover, and start serving.
    pub fn start(config: ServeConfig, backend: BackendSpec) -> Result<Self> {
        let costs = config.tier_costs();
        let storage =
            open_serving_backend(&backend, costs, config.charge_rent, config.sync_writes)?;
        let engine = Engine::builder()
            .topology(config.topology()?)
            .backend(storage)
            .charge_rent(config.charge_rent)
            .checkpoint_factor(config.checkpoint_factor)
            .group_commit(config.group_commit)
            .build()?;

        let mut admission = AdmissionControl::new(&config.book);
        let mut records = BTreeMap::new();
        let mut settled = BTreeMap::new();
        let side_path = sidecar_path(&backend);
        if let Some(path) = &side_path {
            (records, settled) = load_sidecar(path)?;
            // The journal is the authority on who opened what (ADR-009:
            // attribution rides the registration record, inside the same
            // transaction that created the stream). The sidecar is a read
            // cache: keep its `fin` flags, but let the journal win on
            // attribution and resurrect any open the cache lost.
            for id in engine.stream_ids() {
                if let Some(rec) =
                    engine.stream_note(id).as_deref().and_then(parse_attribution)
                {
                    let completed =
                        records.get(&id).map_or(false, |r| r.completed);
                    records.insert(id, StreamRecord { completed, ..rec });
                }
            }
            for r in records.values() {
                if !r.completed {
                    // The stream's documents were replayed into residency
                    // but its session died with the old process: keep its
                    // hot reservation counted against the tenant.
                    if let Some(t) = config.book.by_name(&r.tenant) {
                        admission.restore(t, r.reserved_hot);
                    }
                }
            }
        }
        let sidecar = Sidecar {
            file: match &side_path {
                Some(path) => Some(
                    std::fs::OpenOptions::new()
                        .create(true)
                        .append(true)
                        .open(path)
                        .with_context(|| format!("opening {}", path.display()))?,
                ),
                None => None,
            },
            path: side_path,
            sync: config.sync_writes,
        };

        let listener = TcpListener::bind(&config.addr)
            .with_context(|| format!("binding {}", config.addr))?;
        let addr = listener.local_addr()?;

        let nonce_seed = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0)
            ^ u64::from(addr.port());
        let state = Arc::new(ServerState {
            engine,
            backend_label: backend.label(),
            config,
            admission: Mutex::new(admission),
            sessions: Mutex::new(BTreeMap::new()),
            records: Mutex::new(records),
            settled: Mutex::new(settled),
            sidecar: Mutex::new(sidecar),
            nonce: Mutex::new(SplitMix64::new(nonce_seed)),
            shutdown_requested: AtomicBool::new(false),
            stop_accepting: AtomicBool::new(false),
        });

        let (tx, rx) = mpsc::channel::<TcpStream>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::new();
        for _ in 0..state.config.workers {
            let rx = Arc::clone(&rx);
            let state = Arc::clone(&state);
            workers.push(std::thread::spawn(move || loop {
                let conn = rx.lock().unwrap_or_else(|e| e.into_inner()).recv();
                match conn {
                    Ok(stream) => handle_connection(&state, stream, &rx),
                    Err(_) => break, // acceptor gone, queue drained
                }
            }));
        }
        let acceptor = {
            let state = Arc::clone(&state);
            std::thread::spawn(move || {
                for conn in listener.incoming() {
                    if state.stop_accepting.load(Ordering::SeqCst) {
                        break; // tx drops here; workers drain and exit
                    }
                    if let Ok(stream) = conn {
                        if tx.send(stream).is_err() {
                            break;
                        }
                    }
                }
            })
        };

        Ok(Self { addr, state, acceptor: Some(acceptor), workers })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until a client posts `/v1/shutdown`, then shut down
    /// gracefully. This is what `shptier serve` runs.
    pub fn wait(self) -> Result<()> {
        while !self.state.shutdown_requested.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(25));
        }
        self.shutdown()
    }

    /// Graceful shutdown: stop accepting, drain in-flight requests, fold
    /// finished streams out of the sidecar log, then checkpoint the
    /// backend so a later reopen replays a compact journal. (Both are
    /// free no-ops on the simulator.)
    pub fn shutdown(mut self) -> Result<()> {
        self.stop_threads();
        self.fold_sidecar()?;
        self.state.engine.checkpoint()?;
        Ok(())
    }

    /// The sidecar counterpart of the journal checkpoint: completed
    /// streams no longer need per-stream attribution (their ledgers are
    /// frozen in the engine checkpoint), so their `open`/`fin` pairs
    /// collapse into one `settled` aggregate per tenant and the log is
    /// rewritten atomically (tmp + rename) to hold only settled lines
    /// plus the still-unfinished opens. A SIGKILL never reaches this, so
    /// an aborted process leaves the append-only log untouched for
    /// replay.
    fn fold_sidecar(&self) -> Result<()> {
        let mut side = self.state.sidecar.lock().unwrap_or_else(|e| e.into_inner());
        let Some(path) = side.path.clone() else {
            return Ok(());
        };
        let mut records = self.state.records.lock().unwrap_or_else(|e| e.into_inner());
        let mut settled = self.state.settled.lock().unwrap_or_else(|e| e.into_inner());
        let done: Vec<u64> =
            records.iter().filter(|(_, r)| r.completed).map(|(id, _)| *id).collect();
        for id in done {
            let r = records.remove(&id).expect("id was just listed");
            let cost = self.state.engine.stream_ledger(id).total();
            let e = settled.entry(r.tenant).or_default();
            e.streams += 1;
            e.cost += cost;
        }
        let mut text = String::new();
        for (tenant, s) in settled.iter() {
            text.push_str(&format!("settled {} {:016x} {tenant}\n", s.streams, s.cost.to_bits()));
        }
        for (id, r) in records.iter() {
            text.push_str(&format!(
                "open {id} {} {} {}\n",
                r.reserved_hot,
                u8::from(r.degraded),
                r.tenant
            ));
        }
        let tmp = path.with_extension("log.tmp");
        std::fs::write(&tmp, &text).with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("renaming {} over {}", tmp.display(), path.display()))?;
        // the append handle points at the replaced inode; drop it
        side.file = None;
        Ok(())
    }

    /// Ungraceful stop for crash-recovery tests: threads are torn down
    /// but *no* checkpoint is taken, leaving the journal exactly as a
    /// killed process would — recovery must come from replay alone.
    pub fn abort(mut self) {
        self.stop_threads();
    }

    fn stop_threads(&mut self) {
        self.state.stop_accepting.store(true, Ordering::SeqCst);
        // Wake the acceptor out of its blocking accept.
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Request handling

/// Idle budget a kept-alive connection gets between requests before the
/// worker reclaims itself. Clients that pause longer simply reconnect
/// (the client retries a request whose reused connection died — safe,
/// because the server only ever closes *between* requests).
const KEEP_ALIVE_IDLE: Duration = Duration::from_millis(250);

fn handle_connection(
    state: &ServerState,
    stream: TcpStream,
    waiting: &Mutex<mpsc::Receiver<TcpStream>>,
) {
    let mut current = stream;
    let _ = current
        .set_read_timeout(Some(Duration::from_millis(state.config.read_timeout_ms)));
    loop {
        let keep = match http::read_request(&mut current, state.config.max_body_bytes) {
            Ok(req) => {
                let (status, body) = route(state, &req);
                if http::write_response_with(
                    &mut current,
                    status,
                    &body.dump(),
                    req.keep_alive,
                )
                .is_err()
                {
                    return;
                }
                req.keep_alive
            }
            Err(ReadError::TooLarge { limit }) => {
                let body = ErrorBody::with_reason(
                    format!("request body exceeds the {limit}-byte limit"),
                    "body-too-large",
                );
                let _ = http::write_response(&mut current, 413, &body.to_json().dump());
                false
            }
            Err(ReadError::BadRequest(msg)) => {
                let body = ErrorBody::message(format!("bad request: {msg}"));
                let _ = http::write_response(&mut current, 400, &body.to_json().dump());
                false
            }
            // Timeout or disconnect: the peer is gone, stalled, or spent
            // its keep-alive idle budget. Drop the connection.
            Err(ReadError::Io(_)) => return,
        };
        if !keep {
            return;
        }
        // Fairness with more connections than workers: if another
        // connection is waiting in the accept queue, hand this (idle)
        // one back to its client — who reconnects transparently — and
        // serve the newcomer instead of starving it.
        if let Ok(next) = waiting.lock().unwrap_or_else(|e| e.into_inner()).try_recv() {
            current = next;
            let _ = current.set_read_timeout(Some(Duration::from_millis(
                state.config.read_timeout_ms,
            )));
            continue;
        }
        // Between requests only the short idle budget applies, so drains
        // and shutdowns never wait out the full read timeout.
        let _ = current.set_read_timeout(Some(KEEP_ALIVE_IDLE));
    }
}

fn error(status: u16, body: ErrorBody) -> (u16, crate::serdes::Json) {
    (status, body.to_json())
}

fn route(state: &ServerState, req: &Request) -> (u16, crate::serdes::Json) {
    let path = req.path.split('?').next().unwrap_or("");
    let segs: Vec<&str> = path.trim_matches('/').split('/').collect();
    match (req.method.as_str(), segs.as_slice()) {
        ("POST", ["v1", "streams"]) => handle_open(state, &req.body),
        ("POST", ["v1", "streams", token, "observe"]) => handle_observe(state, token, &req.body),
        ("POST", ["v1", "streams", token, "finish"]) => handle_finish(state, token),
        ("GET", ["v1", "tenants", name, "invoice"]) => handle_invoice(state, req, name),
        ("GET", ["v1", "status"]) => handle_status(state, req),
        ("POST", ["v1", "shutdown"]) => {
            state.shutdown_requested.store(true, Ordering::SeqCst);
            (200, wire::json_obj(vec![("draining", crate::serdes::Json::Bool(true))]))
        }
        // known path, wrong verb
        (_, ["v1", "streams"]) | (_, ["v1", "status"]) | (_, ["v1", "shutdown"]) => {
            error(405, ErrorBody::message(format!("{} not allowed here", req.method)))
        }
        _ => error(
            404,
            ErrorBody::with_reason(format!("no such route {}", req.path), "unknown-route"),
        ),
    }
}

fn handle_open(state: &ServerState, body: &[u8]) -> (u16, crate::serdes::Json) {
    let json = match wire::parse_body(body) {
        Ok(j) => j,
        Err(e) => return error(400, e),
    };
    let open = match OpenRequest::from_json(&json) {
        Ok(o) => o,
        Err(msg) => return error(400, ErrorBody::message(msg)),
    };
    let Some(tenant_id) = state.config.book.authenticate(&open.token) else {
        return error(401, ErrorBody::with_reason("unknown tenant token", "bad-token"));
    };
    let tenant_name = state.config.book.tenant(tenant_id).name.clone();

    let costs = match &open.economics {
        Some(custom) => {
            if custom.len() != state.config.tiers {
                return error(
                    400,
                    ErrorBody::message(format!(
                        "economics has {} tiers but the server topology has {}",
                        custom.len(),
                        state.config.tiers
                    )),
                );
            }
            custom.clone()
        }
        None => state.config.tier_costs(),
    };
    if open.n == 0 || open.k == 0 || open.k > open.n {
        return error(
            400,
            ErrorBody::message(format!("need 0 < k <= n, got n={} k={}", open.n, open.k)),
        );
    }

    let demand = crate::serve::tenancy::analytic_hot_demand(
        &costs,
        open.n,
        open.k,
        open.include_rent,
        open.family,
        state.config.selector,
    );
    let verdict = {
        let mut adm = state.admission.lock().unwrap_or_else(|e| e.into_inner());
        adm.admit(&state.config.book, tenant_id, demand)
    };
    let (degraded, reserved_hot) = match verdict {
        AdmissionVerdict::Rejected { reason } => {
            return error(
                429,
                ErrorBody::with_reason(
                    format!("tenant {tenant_name} exceeded its {reason}"),
                    reason,
                ),
            );
        }
        AdmissionVerdict::Admitted { degraded, reserved_hot } => (degraded, reserved_hot),
    };

    // The note journals tenancy inside the engine transaction: the
    // backend writes it into the very registration record that creates
    // the stream, so a kill can never separate "stream exists" from
    // "stream attributed" (ADR-009).
    let mut spec = SessionSpec::new(open.n, open.k)
        .with_family(open.family)
        .with_rent(open.include_rent)
        .with_pinned_cold(degraded)
        .with_selector(state.config.selector)
        .with_note(encode_attribution(reserved_hot, degraded, &tenant_name));
    if open.economics.is_some() {
        spec = spec.with_costs(costs);
    }
    let session = match state.engine.open_stream(spec) {
        Ok(s) => s,
        Err(e) => {
            let mut adm = state.admission.lock().unwrap_or_else(|e| e.into_inner());
            adm.release(tenant_id, reserved_hot);
            return error(400, ErrorBody::message(format!("open failed: {e}")));
        }
    };
    let stream_id = session.id();

    // Attribution is already durable: it was journaled inside the
    // `open_stream` transaction above. The in-memory record serves live
    // invoices; the sidecar `open` line is a read-optimized cache
    // (restart rebuilds from the journal), so its append is best-effort
    // and no longer gates the response.
    state
        .records
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .insert(
            stream_id,
            StreamRecord {
                tenant: tenant_name.clone(),
                degraded,
                reserved_hot,
                completed: false,
            },
        );
    {
        let mut side = state.sidecar.lock().unwrap_or_else(|e| e.into_inner());
        let _ = side.append(&format!(
            "open {stream_id} {reserved_hot} {} {tenant_name}",
            u8::from(degraded)
        ));
    }

    let token = {
        let mut nonce = state.nonce.lock().unwrap_or_else(|e| e.into_inner());
        format!("s-{stream_id}-{:016x}", nonce.next_u64())
    };
    let entry = SessionEntry {
        session: Some(session),
        stream_id,
        tenant_id,
        n: open.n,
        observed: 0,
        reserved_hot,
        degraded,
    };
    state
        .sessions
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .insert(token.clone(), Arc::new(Mutex::new(entry)));

    (
        200,
        OpenResponse { stream: token, id: stream_id, degraded, reserved_hot }.to_json(),
    )
}

fn lookup_session(
    state: &ServerState,
    token: &str,
) -> Option<Arc<Mutex<SessionEntry>>> {
    state
        .sessions
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .get(token)
        .cloned()
}

fn handle_observe(state: &ServerState, token: &str, body: &[u8]) -> (u16, crate::serdes::Json) {
    let json = match wire::parse_body(body) {
        Ok(j) => j,
        Err(e) => return error(400, e),
    };
    let req = match ObserveRequest::from_json(&json) {
        Ok(r) => r,
        Err(msg) => return error(400, ErrorBody::message(msg)),
    };
    let Some(entry) = lookup_session(state, token) else {
        return error(404, ErrorBody::with_reason("no such stream", "unknown-stream"));
    };
    let mut e = entry.lock().unwrap_or_else(|e| e.into_inner());
    let Some(session) = e.session.as_mut() else {
        return error(400, ErrorBody::with_reason("stream already finished", "stream-finished"));
    };
    for (i, score) in req.scores.iter().enumerate() {
        if !score.is_finite() {
            return error(400, ErrorBody::message(format!("scores[{i}] is not finite")));
        }
        if let Err(err) = session.observe(*score) {
            return error(400, ErrorBody::message(format!("observe failed: {err}")));
        }
        e.observed += 1;
    }
    let resp = ObserveResponse { observed: e.observed, done: e.observed >= e.n };
    (200, resp.to_json())
}

fn handle_finish(state: &ServerState, token: &str) -> (u16, crate::serdes::Json) {
    let Some(entry) = lookup_session(state, token) else {
        return error(404, ErrorBody::with_reason("no such stream", "unknown-stream"));
    };
    let mut e = entry.lock().unwrap_or_else(|e| e.into_inner());
    let Some(session) = e.session.take() else {
        return error(400, ErrorBody::with_reason("stream already finished", "stream-finished"));
    };
    let outcome = match session.finish() {
        Ok(o) => o,
        Err(err) => {
            // The handle is consumed either way; the stream is done for.
            return error(500, ErrorBody::message(format!("finish failed: {err}")));
        }
    };
    let cost = state.engine.stream_ledger(e.stream_id).total();

    // Journal completion before answering: a client that saw this
    // response must find the stream invoiced as completed after a crash.
    {
        let mut side = state.sidecar.lock().unwrap_or_else(|e| e.into_inner());
        if let Err(err) = side.append(&format!("fin {}", e.stream_id)) {
            return error(500, ErrorBody::message(format!("sidecar log: {err}")));
        }
    }
    if let Some(r) = state
        .records
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .get_mut(&e.stream_id)
    {
        r.completed = true;
    }
    {
        let mut adm = state.admission.lock().unwrap_or_else(|e| e.into_inner());
        adm.release(e.tenant_id, e.reserved_hot);
    }
    state
        .sessions
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .remove(token);

    let resp = FinishResponse {
        retained: outcome.retained.len() as u64,
        hot_reads: outcome.hot_reads(),
        cold_reads: outcome.cold_reads(),
        cost,
    };
    (200, resp.to_json())
}

/// Resolve the request's bearer token to a tenant id, or produce the 401
/// the caller should answer with. Auth runs *before* any path-derived
/// name resolution, so unauthenticated probes cannot distinguish
/// existing tenants from unknown ones.
fn authenticate(
    state: &ServerState,
    req: &Request,
) -> Result<usize, (u16, crate::serdes::Json)> {
    let Some(token) = req.bearer.as_deref() else {
        return Err(error(
            401,
            ErrorBody::with_reason("missing bearer token", "missing-token"),
        ));
    };
    state
        .config
        .book
        .authenticate(token)
        .ok_or_else(|| error(401, ErrorBody::with_reason("unknown tenant token", "bad-token")))
}

fn handle_invoice(state: &ServerState, req: &Request, name: &str) -> (u16, crate::serdes::Json) {
    let caller = match authenticate(state, req) {
        Ok(id) => id,
        Err(resp) => return resp,
    };
    let Some(tenant_id) = state.config.book.by_name(name) else {
        return error(404, ErrorBody::with_reason("no such tenant", "unknown-tenant"));
    };
    if caller != tenant_id {
        return error(
            403,
            ErrorBody::with_reason(
                format!("token does not grant access to tenant {name}'s invoice"),
                "wrong-tenant",
            ),
        );
    }
    let tenant = state.config.book.tenant(tenant_id);
    let records = state.records.lock().unwrap_or_else(|e| e.into_inner()).clone();
    let settled = state
        .settled
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .get(&tenant.name)
        .copied()
        .unwrap_or_default();
    let mut streams = Vec::new();
    let mut cost_total = settled.cost;
    let mut billed_total = settled.cost * tenant.price_multiplier;
    for (id, r) in records.iter().filter(|(_, r)| r.tenant == tenant.name) {
        let cost = state.engine.stream_ledger(*id).total();
        let billed = cost * tenant.price_multiplier;
        cost_total += cost;
        billed_total += billed;
        streams.push(InvoiceLine {
            stream_id: *id,
            completed: r.completed,
            degraded: r.degraded,
            cost,
            billed,
        });
    }
    let inv = Invoice {
        tenant: tenant.name.clone(),
        price_multiplier: tenant.price_multiplier,
        streams,
        settled_streams: settled.streams,
        settled_cost: settled.cost,
        cost_total,
        billed_total,
    };
    (200, inv.to_json())
}

fn handle_status(state: &ServerState, req: &Request) -> (u16, crate::serdes::Json) {
    if let Err(resp) = authenticate(state, req) {
        return resp;
    }
    let tiers: Vec<TierStatus> = (0..state.config.tiers)
        .map(|i| TierStatus {
            occupancy: state.engine.resident_len(TierId(i)) as u64,
            capacity: if i == 0 { Some(state.config.hot_capacity) } else { None },
            peak: state.engine.peak_occupancy(TierId(i)) as u64,
        })
        .collect();
    let tenants: Vec<TenantStatus> = {
        let adm = state.admission.lock().unwrap_or_else(|e| e.into_inner());
        state
            .config
            .book
            .tenants()
            .iter()
            .zip(adm.usage())
            .map(|(t, u)| TenantStatus {
                tenant: t.name.clone(),
                live_streams: u.live_streams,
                reserved_hot: u.reserved_hot,
                admitted: u.admitted,
                degraded: u.degraded,
                rejected: u.rejected,
                last_rejection: u.last_rejection.map(str::to_string),
            })
            .collect()
    };
    let status = Status {
        backend: state.backend_label.clone(),
        arbiter: state.engine.arbiter_name(),
        live_sessions: state.engine.live_sessions() as u64,
        rearbitrations: state.engine.rearbitrations(),
        overcommitted_tiers: state.engine.overcommits().len() as u64,
        journal_ops: state.engine.journal_ops(),
        auto_checkpoints: state.engine.auto_checkpoints(),
        drift_detections: state.engine.drift_detections(),
        drift_rederivations: state.engine.drift_rederivations(),
        ledger_total: state.engine.ledger().total(),
        tiers,
        tenants,
    };
    (200, status.to_json())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::client::{Client, OpenOutcome};

    fn test_config(extra: &str) -> ServeConfig {
        ServeConfig::from_toml(&format!(
            "[serve]\nworkers = 4\nread_timeout_ms = 2000\n\
             [engine]\ntiers = 2\nhot_capacity = 64\n{extra}\
             [tenants.alpha]\ntoken = \"tok-alpha\"\n"
        ))
        .unwrap()
    }

    #[test]
    fn open_observe_finish_invoice_round_trip() {
        let server = RunningServer::start(test_config(""), BackendSpec::Sim).unwrap();
        let client = Client::new(server.local_addr());

        let opened = client.open("tok-alpha", 20, 4, "keep", None).unwrap();
        let OpenOutcome::Admitted(open) = opened else {
            panic!("expected admission, got {opened:?}");
        };
        assert!(!open.degraded);

        let scores: Vec<f64> = (0..20).map(|i| (i as f64) / 20.0).collect();
        let obs = client.observe(&open.stream, &scores).unwrap();
        assert_eq!(obs.observed, 20);
        assert!(obs.done);

        let fin = client.finish(&open.stream).unwrap();
        assert_eq!(fin.retained, 4);
        assert!(fin.cost > 0.0);

        let inv = client.invoice("alpha", "tok-alpha").unwrap();
        assert_eq!(inv.streams.len(), 1);
        assert!(inv.streams[0].completed);
        assert!((inv.cost_total - fin.cost).abs() < 1e-9);

        let status = client.status("tok-alpha").unwrap();
        assert_eq!(status.live_sessions, 0);
        assert_eq!(status.tenants.len(), 1);
        assert_eq!(status.tenants[0].admitted, 1);
        assert!((status.ledger_total - inv.cost_total).abs() < 1e-9 * inv.cost_total.abs().max(1.0));

        client.request_shutdown().unwrap();
        server.wait().unwrap();
    }

    #[test]
    fn logmem_selector_serves_streams_end_to_end() {
        let config = test_config("selector = \"logmem\"\n");
        assert_eq!(config.selector, crate::topk::SelectorKind::LogMem);
        let server = RunningServer::start(config, BackendSpec::Sim).unwrap();
        let client = Client::new(server.local_addr());

        let opened = client.open("tok-alpha", 24, 4, "keep", None).unwrap();
        let OpenOutcome::Admitted(open) = opened else {
            panic!("expected admission, got {opened:?}");
        };
        let scores: Vec<f64> = (0..24).map(|i| ((i * 7) % 24) as f64 / 24.0).collect();
        let obs = client.observe(&open.stream, &scores).unwrap();
        assert_eq!(obs.observed, 24);
        assert!(obs.done);

        // The sketch admits a superset of the exact top-K (it never
        // evicts), so the finish retains at least K documents.
        let fin = client.finish(&open.stream).unwrap();
        assert!(fin.retained >= 4, "logmem retains an admitted superset, got {}", fin.retained);
        assert!(fin.cost > 0.0);

        let inv = client.invoice("alpha", "tok-alpha").unwrap();
        assert_eq!(inv.streams.len(), 1);
        assert!(inv.streams[0].completed);

        server.shutdown().unwrap();
    }

    #[test]
    fn bad_tokens_and_routes_get_clean_errors() {
        let server = RunningServer::start(test_config(""), BackendSpec::Sim).unwrap();
        let client = Client::new(server.local_addr());

        let opened = client.open("wrong-token", 10, 2, "keep", None).unwrap();
        assert!(
            matches!(&opened, OpenOutcome::Rejected { status: 401, reason, .. }
                if reason.as_deref() == Some("bad-token")),
            "got {opened:?}"
        );
        let err = client.observe("s-99-beef", &[0.5]).unwrap_err();
        assert!(err.contains("404"), "got {err}");
        let err = client.invoice("nobody", "tok-alpha").unwrap_err();
        assert!(err.contains("404"), "got {err}");

        server.shutdown().unwrap();
    }

    #[test]
    fn attribution_rides_the_engine_journal_not_the_sidecar() {
        let root = crate::util::scratch_dir("serve-attrib");
        let spec = BackendSpec::Fs { root: root.clone() };
        let server = RunningServer::start(test_config(""), spec.clone()).unwrap();
        let client = Client::new(server.local_addr());
        let OpenOutcome::Admitted(open) = client.open("tok-alpha", 8, 2, "keep", None).unwrap()
        else {
            panic!()
        };
        client.observe(&open.stream, &[0.3, 0.9, 0.1]).unwrap();
        server.abort(); // SIGKILL stand-in: no fold, no checkpoint

        // Lose the sidecar cache entirely. The registration note in the
        // engine journal must still know whose stream this was.
        std::fs::remove_file(root.join("serve.log")).unwrap();
        let server = RunningServer::start(test_config(""), spec).unwrap();
        let client = Client::new(server.local_addr());
        let inv = client.invoice("alpha", "tok-alpha").unwrap();
        assert_eq!(inv.streams.len(), 1, "attribution must survive via the journal");
        assert_eq!(inv.streams[0].stream_id, open.id);
        assert!(!inv.streams[0].completed, "fin never happened");
        server.shutdown().unwrap();
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn group_commit_server_settles_across_a_graceful_restart() {
        let root = crate::util::scratch_dir("serve-gc");
        let spec = BackendSpec::Fs { root: root.clone() };
        let config = || test_config("group_commit = true\n");
        let server = RunningServer::start(config(), spec.clone()).unwrap();
        let client = Client::new(server.local_addr());
        let OpenOutcome::Admitted(open) = client.open("tok-alpha", 12, 3, "keep", None).unwrap()
        else {
            panic!()
        };
        let scores: Vec<f64> = (0..12).map(|i| (i as f64) / 12.0).collect();
        client.observe(&open.stream, &scores).unwrap();
        let fin = client.finish(&open.stream).unwrap();
        assert!(fin.cost > 0.0);
        // Graceful shutdown is a barrier: the checkpoint flushes any
        // buffered batch, so the restart replays everything.
        server.shutdown().unwrap();

        let server = RunningServer::start(config(), spec).unwrap();
        let client = Client::new(server.local_addr());
        let inv = client.invoice("alpha", "tok-alpha").unwrap();
        assert_eq!(inv.settled_streams, 1, "finished stream folded into settled totals");
        assert!((inv.settled_cost - fin.cost).abs() < 1e-9 * fin.cost.abs().max(1.0));
        server.shutdown().unwrap();
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn double_finish_is_rejected_not_wedged() {
        let server = RunningServer::start(test_config(""), BackendSpec::Sim).unwrap();
        let client = Client::new(server.local_addr());
        let OpenOutcome::Admitted(open) = client.open("tok-alpha", 5, 1, "keep", None).unwrap()
        else {
            panic!()
        };
        client.observe(&open.stream, &[0.1, 0.9, 0.2, 0.3, 0.4]).unwrap();
        client.finish(&open.stream).unwrap();
        let err = client.finish(&open.stream).unwrap_err();
        assert!(err.contains("404"), "finished stream should be gone, got {err}");
        server.shutdown().unwrap();
    }
}
