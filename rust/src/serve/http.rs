//! Minimal HTTP/1.1 framing on plain `std::io` streams.
//!
//! Just enough of RFC 9112 for the placement API, with the hardening the
//! issue demands and nothing else: requests are `METHOD PATH HTTP/1.1`
//! with a `Content-Length` body (no chunked transfer). Connections are
//! **persistent** since ADR-008: both directions are framed by
//! `Content-Length`, requests default to keep-alive per HTTP/1.1 (an
//! explicit `Connection: close` — or HTTP/1.0 — opts out), and responses
//! echo the request's choice, so one TCP connection carries a whole
//! open→observe…→finish session instead of a connect per request.
//! Oversized bodies are cut off at `max_body` *before* being buffered
//! ([`ReadError::TooLarge`] → 413), malformed framing is
//! [`ReadError::BadRequest`] → 400, and a stalled peer surfaces as an io
//! timeout the server maps to a dropped connection. The reader is
//! generic over [`Read`] so every failure mode unit-tests against an
//! in-memory cursor as well as a raw `TcpStream`.

use std::io::{Read, Write};

/// Upper bound on the request head (request line + headers).
pub const MAX_HEAD_BYTES: usize = 8 * 1024;

/// A parsed request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub method: String,
    pub path: String,
    /// The token from an `Authorization: Bearer …` header, if one was
    /// sent. Routes that require auth decide what its absence means.
    pub bearer: Option<String>,
    pub body: Vec<u8>,
    /// Whether the client wants the connection kept open after the
    /// response: the HTTP/1.1 default unless `Connection: close` was
    /// sent (HTTP/1.0 defaults to close).
    pub keep_alive: bool,
}

/// Why a request could not be read.
#[derive(Debug)]
pub enum ReadError {
    /// Declared or actual body beyond `max_body` (HTTP 413).
    TooLarge { limit: usize },
    /// Malformed framing (HTTP 400).
    BadRequest(String),
    /// Transport error — includes read timeouts; no response is owed.
    Io(std::io::Error),
}

impl std::fmt::Display for ReadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::TooLarge { limit } => write!(f, "body exceeds {limit} bytes"),
            Self::BadRequest(msg) => write!(f, "bad request: {msg}"),
            Self::Io(e) => write!(f, "io: {e}"),
        }
    }
}

/// Read one request. `max_body` bounds the `Content-Length` a client may
/// declare; the head is bounded by [`MAX_HEAD_BYTES`].
pub fn read_request<R: Read>(r: &mut R, max_body: usize) -> Result<Request, ReadError> {
    // Accumulate until the blank line ends the head. Anything read past
    // it is the start of the body.
    let mut buf: Vec<u8> = Vec::with_capacity(512);
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        if buf.len() > MAX_HEAD_BYTES {
            return Err(ReadError::BadRequest(format!(
                "request head exceeds {MAX_HEAD_BYTES} bytes"
            )));
        }
        let mut chunk = [0u8; 1024];
        let n = r.read(&mut chunk).map_err(ReadError::Io)?;
        if n == 0 {
            if buf.is_empty() {
                // Peer connected and said nothing; not worth a 400.
                return Err(ReadError::Io(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "connection closed before request",
                )));
            }
            return Err(ReadError::BadRequest("truncated request head".to_string()));
        }
        buf.extend_from_slice(&chunk[..n]);
    };

    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| ReadError::BadRequest("request head is not utf-8".to_string()))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let (method, path, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v), None) if !m.is_empty() && p.starts_with('/') => (m, p, v),
        _ => {
            return Err(ReadError::BadRequest(format!(
                "malformed request line {request_line:?}"
            )))
        }
    };
    if !version.starts_with("HTTP/1.") {
        return Err(ReadError::BadRequest(format!("unsupported version {version:?}")));
    }

    let mut content_length: usize = 0;
    let mut bearer: Option<String> = None;
    let mut keep_alive = version != "HTTP/1.0";
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(ReadError::BadRequest(format!("malformed header line {line:?}")));
        };
        if name.trim().eq_ignore_ascii_case("connection") {
            let value = value.trim();
            if value.eq_ignore_ascii_case("close") {
                keep_alive = false;
            } else if value.eq_ignore_ascii_case("keep-alive") {
                keep_alive = true;
            }
        } else if name.trim().eq_ignore_ascii_case("content-length") {
            content_length = value
                .trim()
                .parse::<usize>()
                .map_err(|_| ReadError::BadRequest(format!("bad content-length {value:?}")))?;
        } else if name.trim().eq_ignore_ascii_case("authorization") {
            // Only the Bearer scheme is meaningful here; any other
            // scheme leaves `bearer` unset and the route answers 401.
            let value = value.trim();
            if let Some(scheme) = value.get(..7) {
                if scheme.eq_ignore_ascii_case("bearer ") {
                    bearer = Some(value[7..].trim().to_string());
                }
            }
        }
    }
    if content_length > max_body {
        return Err(ReadError::TooLarge { limit: max_body });
    }

    let mut body = buf[head_end + 4..].to_vec();
    if body.len() > content_length {
        return Err(ReadError::BadRequest(
            "body longer than declared content-length".to_string(),
        ));
    }
    while body.len() < content_length {
        let mut chunk = vec![0u8; (content_length - body.len()).min(4096)];
        let n = r.read(&mut chunk).map_err(ReadError::Io)?;
        if n == 0 {
            return Err(ReadError::BadRequest("truncated body".to_string()));
        }
        body.extend_from_slice(&chunk[..n]);
    }

    Ok(Request { method: method.to_string(), path: path.to_string(), bearer, body, keep_alive })
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Canonical reason phrase for the statuses this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        401 => "Unauthorized",
        403 => "Forbidden",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write a complete JSON response, advertising whether the server will
/// keep the connection open afterwards. `Content-Length` is always
/// present, so keep-alive peers can frame the body without waiting for
/// EOF.
pub fn write_response_with<W: Write>(
    w: &mut W,
    status: u16,
    body: &str,
    keep_alive: bool,
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n{}",
        status,
        reason(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
        body
    )?;
    w.flush()
}

/// Write a complete JSON response and close the connection.
pub fn write_response<W: Write>(w: &mut W, status: u16, body: &str) -> std::io::Result<()> {
    write_response_with(w, status, body, false)
}

/// A response as read back by the client: status code + body bytes.
#[derive(Debug, Clone)]
pub struct RawResponse {
    pub status: u16,
    pub body: Vec<u8>,
}

/// Read a full response. Framed by `Content-Length` — never by EOF — so
/// the same connection can carry the next request afterwards
/// (keep-alive); a response without `Content-Length` falls back to
/// read-to-EOF for compatibility with close-framed peers.
pub fn read_response<R: Read>(r: &mut R) -> Result<RawResponse, String> {
    let mut buf: Vec<u8> = Vec::with_capacity(512);
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        if buf.len() > MAX_HEAD_BYTES {
            return Err(format!("response head exceeds {MAX_HEAD_BYTES} bytes"));
        }
        let mut chunk = [0u8; 1024];
        let n = r.read(&mut chunk).map_err(|e| format!("reading response: {e}"))?;
        if n == 0 {
            return Err(if buf.is_empty() {
                "connection closed before response".to_string()
            } else {
                "truncated response head".to_string()
            });
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head =
        std::str::from_utf8(&buf[..head_end]).map_err(|_| "response head is not utf-8")?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let status = status_line
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| format!("malformed status line {status_line:?}"))?;
    let mut content_length: Option<usize> = None;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = Some(value.trim().parse::<usize>().map_err(|_| {
                    format!("bad response content-length {:?}", value.trim())
                })?);
            }
        }
    }
    let mut body = buf[head_end + 4..].to_vec();
    match content_length {
        Some(len) => {
            if body.len() > len {
                return Err("body longer than declared content-length".to_string());
            }
            while body.len() < len {
                let mut chunk = vec![0u8; (len - body.len()).min(4096)];
                let n =
                    r.read(&mut chunk).map_err(|e| format!("reading response: {e}"))?;
                if n == 0 {
                    return Err("truncated response body".to_string());
                }
                body.extend_from_slice(&chunk[..n]);
            }
        }
        None => {
            r.read_to_end(&mut body).map_err(|e| format!("reading response: {e}"))?;
        }
    }
    Ok(RawResponse { status, body })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn req(raw: &str) -> Result<Request, ReadError> {
        read_request(&mut Cursor::new(raw.as_bytes().to_vec()), 1024)
    }

    #[test]
    fn parses_post_with_body() {
        let r = req("POST /v1/streams HTTP/1.1\r\nContent-Length: 4\r\n\r\n{\"\"}").unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/v1/streams");
        assert_eq!(r.body, b"{\"\"}");
    }

    #[test]
    fn parses_get_without_body() {
        let r = req("GET /v1/status HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!(r.method, "GET");
        assert!(r.body.is_empty());
        assert_eq!(r.bearer, None);
    }

    #[test]
    fn connection_semantics_follow_http_1_1_defaults() {
        // HTTP/1.1: keep-alive unless told otherwise
        assert!(req("GET /x HTTP/1.1\r\n\r\n").unwrap().keep_alive);
        assert!(req("GET /x HTTP/1.1\r\nConnection: keep-alive\r\n\r\n").unwrap().keep_alive);
        assert!(!req("GET /x HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap().keep_alive);
        assert!(!req("GET /x HTTP/1.1\r\nconnection: CLOSE\r\n\r\n").unwrap().keep_alive);
        // HTTP/1.0: close unless told otherwise
        assert!(!req("GET /x HTTP/1.0\r\n\r\n").unwrap().keep_alive);
        assert!(req("GET /x HTTP/1.0\r\nConnection: keep-alive\r\n\r\n").unwrap().keep_alive);
    }

    #[test]
    fn bearer_tokens_parse_case_insensitively() {
        let r = req("GET /v1/status HTTP/1.1\r\nAuthorization: Bearer tok-a\r\n\r\n").unwrap();
        assert_eq!(r.bearer.as_deref(), Some("tok-a"));
        let r = req("GET /v1/status HTTP/1.1\r\nauthorization: bearer  tok-b \r\n\r\n").unwrap();
        assert_eq!(r.bearer.as_deref(), Some("tok-b"));
        // a non-Bearer scheme is not a bearer token
        let r = req("GET /v1/status HTTP/1.1\r\nAuthorization: Basic dXNlcg==\r\n\r\n").unwrap();
        assert_eq!(r.bearer, None);
    }

    #[test]
    fn oversized_declared_body_is_too_large() {
        let e = req("POST /x HTTP/1.1\r\nContent-Length: 9999\r\n\r\n").unwrap_err();
        assert!(matches!(e, ReadError::TooLarge { limit: 1024 }));
    }

    #[test]
    fn malformed_request_lines_are_bad_requests() {
        for raw in [
            "GARBAGE\r\n\r\n",
            "GET\r\n\r\n",
            "GET /x SPDY/3\r\n\r\n",
            "GET nopath HTTP/1.1\r\n\r\n",
            "POST /x HTTP/1.1\r\nContent-Length: notanumber\r\n\r\n",
            "POST /x HTTP/1.1\r\nnocolonheader\r\n\r\n",
        ] {
            let e = req(raw).unwrap_err();
            assert!(matches!(e, ReadError::BadRequest(_)), "{raw:?} gave {e:?}");
        }
    }

    #[test]
    fn unbounded_head_is_rejected() {
        let raw = format!("GET /x HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "a".repeat(MAX_HEAD_BYTES * 2));
        let e = req(&raw).unwrap_err();
        assert!(matches!(e, ReadError::BadRequest(_)));
    }

    #[test]
    fn truncated_body_is_a_bad_request() {
        let e = req("POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc").unwrap_err();
        assert!(matches!(e, ReadError::BadRequest(_)));
    }

    #[test]
    fn response_round_trips() {
        let mut out = Vec::new();
        write_response(&mut out, 429, "{\"error\":\"quota\"}").unwrap();
        let resp = read_response(&mut Cursor::new(out)).unwrap();
        assert_eq!(resp.status, 429);
        assert_eq!(resp.body, b"{\"error\":\"quota\"}");
        let text = String::from_utf8(
            {
                let mut o = Vec::new();
                write_response(&mut o, 404, "{}").unwrap();
                o
            },
        )
        .unwrap();
        assert!(text.starts_with("HTTP/1.1 404 Not Found\r\n"));
        assert!(text.contains("Connection: close"));
        let keep = String::from_utf8({
            let mut o = Vec::new();
            write_response_with(&mut o, 200, "{}", true).unwrap();
            o
        })
        .unwrap();
        assert!(keep.contains("Connection: keep-alive"));
    }

    #[test]
    fn responses_are_framed_by_content_length_not_eof() {
        // two pipelined responses on one stream: Content-Length framing
        // must stop at the first body and leave the second readable —
        // the property persistent connections stand on
        let mut out = Vec::new();
        write_response_with(&mut out, 200, "{\"a\":1}", true).unwrap();
        write_response_with(&mut out, 429, "{\"b\":22}", true).unwrap();
        let mut cursor = Cursor::new(out);
        let first = read_response(&mut cursor).unwrap();
        assert_eq!(first.status, 200);
        assert_eq!(first.body, b"{\"a\":1}");
        let second = read_response(&mut cursor).unwrap();
        assert_eq!(second.status, 429);
        assert_eq!(second.body, b"{\"b\":22}");
        // a truncated keep-alive body is an error, not a silent short read
        let mut partial = Vec::new();
        write_response_with(&mut partial, 200, "{\"a\":1}", true).unwrap();
        partial.truncate(partial.len() - 3);
        let err = read_response(&mut Cursor::new(partial)).unwrap_err();
        assert!(err.contains("truncated"), "got {err}");
    }
}
