//! Blocking std-only client for the placement server.
//!
//! Connections are persistent (HTTP/1.1 keep-alive, ADR-008): each
//! `Client` instance caches one TCP connection and reuses it across
//! requests; responses are framed by `Content-Length`, never by EOF.
//! The server may close a cached connection *between* requests (idle
//! reclaim or yielding its worker to a waiting connection), so a
//! request that fails on a *reused* connection before any response
//! byte arrives is retried exactly once on a fresh connection — safe,
//! because the failure proves the server never processed it. `Clone`
//! hands each clone its own empty connection slot, so concurrent
//! threads never serialize on a shared socket. Typed payloads come
//! from [`crate::serve::wire`]. Used by the `serve_*` test suites and
//! the `shptier serve-soak` harness; it is deliberately the *only*
//! HTTP client in the tree, so protocol drift between server and
//! consumers shows up as a unit-test failure here rather than in an
//! external tool.

use std::io::Write as _;
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::cost::PerDocCosts;
use crate::policy::PlanFamily;
use crate::serdes::Json;
use crate::serve::http;
use crate::serve::wire::{
    ErrorBody, FinishResponse, Invoice, ObserveRequest, ObserveResponse, OpenRequest,
    OpenResponse, Status,
};

/// Outcome of an open attempt: servers say no with structure, and
/// admission rejections are expected behaviour, not transport errors.
#[derive(Debug, Clone)]
pub enum OpenOutcome {
    Admitted(OpenResponse),
    /// 4xx with the machine-readable reason (`stream-quota`,
    /// `hot-quota`, `bad-token`, …).
    Rejected { status: u16, reason: Option<String>, error: String },
}

/// Blocking client bound to one server address, holding one cached
/// keep-alive connection. Cloning yields a client with its own (empty)
/// connection slot — see the module docs.
#[derive(Debug)]
pub struct Client {
    addr: SocketAddr,
    timeout: Duration,
    conn: Arc<Mutex<Option<TcpStream>>>,
}

impl Clone for Client {
    fn clone(&self) -> Self {
        Self {
            addr: self.addr,
            timeout: self.timeout,
            conn: Arc::new(Mutex::new(None)),
        }
    }
}

impl Client {
    pub fn new(addr: SocketAddr) -> Self {
        Self { addr, timeout: Duration::from_secs(30), conn: Arc::new(Mutex::new(None)) }
    }

    pub fn with_timeout(mut self, timeout: Duration) -> Self {
        self.timeout = timeout;
        self
    }

    fn connect(&self) -> Result<TcpStream, String> {
        let stream = TcpStream::connect(self.addr).map_err(|e| format!("connect: {e}"))?;
        stream.set_read_timeout(Some(self.timeout)).map_err(|e| format!("timeout: {e}"))?;
        stream.set_write_timeout(Some(self.timeout)).map_err(|e| format!("timeout: {e}"))?;
        Ok(stream)
    }

    /// Transport half of a request: send the pre-rendered bytes, read
    /// one `Content-Length`-framed response. No JSON parsing here —
    /// the retry decision in [`Client::call_with`] must distinguish
    /// "the server never saw this request" from post-response errors.
    fn exchange(stream: &mut TcpStream, request: &str) -> Result<http::RawResponse, String> {
        stream.write_all(request.as_bytes()).map_err(|e| format!("send: {e}"))?;
        stream.flush().map_err(|e| format!("send: {e}"))?;
        http::read_response(stream)
    }

    fn call(
        &self,
        method: &str,
        path: &str,
        body: Option<&Json>,
        bearer: Option<&str>,
    ) -> Result<(u16, Json), String> {
        self.call_with(method, path, body, bearer, true)
    }

    fn call_with(
        &self,
        method: &str,
        path: &str,
        body: Option<&Json>,
        bearer: Option<&str>,
        keep_alive: bool,
    ) -> Result<(u16, Json), String> {
        let payload = body.map(|j| j.dump()).unwrap_or_default();
        let auth = bearer
            .map(|t| format!("Authorization: Bearer {t}\r\n"))
            .unwrap_or_default();
        let connection = if keep_alive { "" } else { "Connection: close\r\n" };
        let request = format!(
            "{method} {path} HTTP/1.1\r\nHost: shptier\r\n{auth}Content-Length: {}\r\n{connection}\r\n{payload}",
            payload.len()
        );
        let mut slot = self.conn.lock().unwrap_or_else(|e| e.into_inner());
        let mut reused = slot.is_some();
        let resp = loop {
            let mut stream = match slot.take() {
                Some(s) => s,
                None => self.connect()?,
            };
            match Self::exchange(&mut stream, &request) {
                Ok(resp) => {
                    if keep_alive {
                        *slot = Some(stream);
                    }
                    break resp;
                }
                // The server only closes a connection *between*
                // requests, so a reused connection failing at send time
                // or at the transport layer before a framed response
                // arrived (clean EOF or an RST from the race with the
                // server's close) means our request was never processed:
                // retry once on a fresh connection. A *truncated*
                // response means the request ran — never retry those,
                // nor any failure on a fresh connection.
                Err(e)
                    if reused
                        && (e.starts_with("send:")
                            || e.starts_with("reading response:")
                            || e.contains("connection closed before response")) =>
                {
                    reused = false;
                }
                Err(e) => return Err(e),
            }
        };
        drop(slot);
        let text = String::from_utf8(resp.body).map_err(|_| "response body is not utf-8")?;
        let json = if text.is_empty() {
            Json::Null
        } else {
            Json::parse(&text).map_err(|e| format!("response body: {e} in {text:?}"))?
        };
        Ok((resp.status, json))
    }

    fn expect_200(
        &self,
        method: &str,
        path: &str,
        body: Option<&Json>,
        bearer: Option<&str>,
    ) -> Result<Json, String> {
        let (status, json) = self.call(method, path, body, bearer)?;
        if status == 200 {
            Ok(json)
        } else {
            let detail = ErrorBody::from_json(&json)
                .map(|e| e.error)
                .unwrap_or_else(|_| json.dump());
            Err(format!("{status}: {detail}"))
        }
    }

    /// Open a stream with the server's configured economics.
    pub fn open(
        &self,
        token: &str,
        n: u64,
        k: u64,
        family: &str,
        economics: Option<Vec<PerDocCosts>>,
    ) -> Result<OpenOutcome, String> {
        let family = PlanFamily::parse(family).map_err(|e| e.to_string())?;
        self.open_request(&OpenRequest {
            token: token.to_string(),
            n,
            k,
            family,
            include_rent: true,
            economics,
        })
    }

    /// Open with full control over the request payload.
    pub fn open_request(&self, req: &OpenRequest) -> Result<OpenOutcome, String> {
        let (status, json) = self.call("POST", "/v1/streams", Some(&req.to_json()), None)?;
        if status == 200 {
            return Ok(OpenOutcome::Admitted(OpenResponse::from_json(&json)?));
        }
        let err = ErrorBody::from_json(&json)
            .unwrap_or_else(|_| ErrorBody::message(json.dump()));
        Ok(OpenOutcome::Rejected { status, reason: err.reason, error: err.error })
    }

    /// Observe a batch of scores.
    pub fn observe(&self, stream: &str, scores: &[f64]) -> Result<ObserveResponse, String> {
        let body = ObserveRequest { scores: scores.to_vec() }.to_json();
        let json =
            self.expect_200("POST", &format!("/v1/streams/{stream}/observe"), Some(&body), None)?;
        ObserveResponse::from_json(&json)
    }

    /// Finish the stream: consumer-read the top-K, close, bill.
    pub fn finish(&self, stream: &str) -> Result<FinishResponse, String> {
        let json = self.expect_200("POST", &format!("/v1/streams/{stream}/finish"), None, None)?;
        FinishResponse::from_json(&json)
    }

    /// Read a tenant's invoice. The bearer `token` must belong to that
    /// same tenant — the server answers 403 otherwise.
    pub fn invoice(&self, tenant: &str, token: &str) -> Result<Invoice, String> {
        let json = self.expect_200(
            "GET",
            &format!("/v1/tenants/{tenant}/invoice"),
            None,
            Some(token),
        )?;
        Invoice::from_json(&json)
    }

    /// Read the server status report. Any configured tenant's token is
    /// accepted (status is fleet-wide, not tenant-scoped).
    pub fn status(&self, token: &str) -> Result<Status, String> {
        let json = self.expect_200("GET", "/v1/status", None, Some(token))?;
        Status::from_json(&json)
    }

    /// Ask the server to drain and shut down (`shptier serve` exits
    /// after its next poll of the flag). Sent `Connection: close` —
    /// there is nothing left to keep a connection alive for.
    pub fn request_shutdown(&self) -> Result<(), String> {
        let (status, json) = self.call_with("POST", "/v1/shutdown", None, None, false)?;
        if status == 200 {
            Ok(())
        } else {
            let detail = ErrorBody::from_json(&json)
                .map(|e| e.error)
                .unwrap_or_else(|_| json.dump());
            Err(format!("{status}: {detail}"))
        }
    }
}
