//! Tenancy: tenant book, quota classes, and admission control.
//!
//! The tenant book is static configuration (`configs/serve.toml`): who may
//! call the server (bearer tokens), what quota class each tenant belongs
//! to, and each tenant's price multiplier (the per-tenant "price book" —
//! raw ledger cost × multiplier = billed amount on the invoice).
//!
//! Admission is decided *before* the engine sees the stream, from the
//! plan's analytic hot-tier demand ([`PlacementPlan::demand`]): the server
//! reserves that many hot slots for the stream's lifetime. A stream that
//! would push its tenant past `max_hot_docs` is either rejected (HTTP 429,
//! machine-readable reason) or — under the `degrade` policy — admitted
//! with every placement pinned to the sink tier
//! (`SessionSpec::with_pinned_cold`), so it consumes no hot capacity at
//! all. Exceeding `max_streams` always rejects: a degraded stream is
//! still a live stream, so degrading could not relieve that quota.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::cost::PerDocCosts;
use crate::policy::{PlacementPlan, PlanFamily};
use crate::serdes::TomlValue;
use crate::storage::TierId;

/// What to do when a stream would exceed its tenant's `max_hot_docs`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExceedPolicy {
    /// Refuse admission (HTTP 429, reason `hot-quota`).
    Reject,
    /// Admit, but pin every placement to the sink tier.
    Degrade,
}

impl ExceedPolicy {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "reject" => Ok(Self::Reject),
            "degrade" => Ok(Self::Degrade),
            other => bail!("serve config: on_exceed must be \"reject\" or \"degrade\", got {other:?}"),
        }
    }
}

/// A quota class shared by any number of tenants.
#[derive(Debug, Clone)]
pub struct QuotaClass {
    pub name: String,
    /// Maximum concurrently-live streams per tenant.
    pub max_streams: u64,
    /// Maximum summed hot-tier demand across a tenant's live streams.
    pub max_hot_docs: u64,
    pub on_exceed: ExceedPolicy,
}

impl QuotaClass {
    fn unlimited() -> Self {
        Self {
            name: "default".to_string(),
            max_streams: u64::MAX,
            max_hot_docs: u64::MAX,
            on_exceed: ExceedPolicy::Reject,
        }
    }
}

/// One configured tenant.
#[derive(Debug, Clone)]
pub struct Tenant {
    pub name: String,
    /// Bearer token presented in the open request.
    pub token: String,
    pub class: QuotaClass,
    /// Invoice multiplier: `billed = cost × price_multiplier`.
    pub price_multiplier: f64,
}

/// The static tenant roster. Tenant ids are indices into [`tenants`]
/// (sorted by name — `BTreeMap` iteration order of the config table).
///
/// [`tenants`]: TenantBook::tenants
#[derive(Debug, Clone)]
pub struct TenantBook {
    tenants: Vec<Tenant>,
}

impl TenantBook {
    pub fn from_toml(t: &TomlValue) -> Result<Self> {
        let mut classes: BTreeMap<String, QuotaClass> = BTreeMap::new();
        if let Some(v) = t.get("classes") {
            let table = v
                .as_table()
                .ok_or_else(|| anyhow!("serve config: [classes] must be a table"))?;
            for (name, body) in table {
                let body = body
                    .as_table()
                    .ok_or_else(|| anyhow!("serve config: [classes.{name}] must be a table"))?;
                let field_u64 = |key: &str| -> Result<u64> {
                    match body.get(key) {
                        Some(v) => v.as_u64().ok_or_else(|| {
                            anyhow!("serve config: classes.{name}.{key} must be a non-negative integer")
                        }),
                        None => Ok(u64::MAX),
                    }
                };
                let on_exceed = match body.get("on_exceed") {
                    Some(v) => ExceedPolicy::parse(v.as_str().ok_or_else(|| {
                        anyhow!("serve config: classes.{name}.on_exceed must be a string")
                    })?)?,
                    None => ExceedPolicy::Reject,
                };
                classes.insert(
                    name.clone(),
                    QuotaClass {
                        name: name.clone(),
                        max_streams: field_u64("max_streams")?,
                        max_hot_docs: field_u64("max_hot_docs")?,
                        on_exceed,
                    },
                );
            }
        }
        let mut tenants = Vec::new();
        if let Some(v) = t.get("tenants") {
            let table = v
                .as_table()
                .ok_or_else(|| anyhow!("serve config: [tenants] must be a table"))?;
            for (name, body) in table {
                let body = body
                    .as_table()
                    .ok_or_else(|| anyhow!("serve config: [tenants.{name}] must be a table"))?;
                let token = body
                    .get("token")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("serve config: tenants.{name}.token (string) is required"))?
                    .to_string();
                if token.is_empty() {
                    bail!("serve config: tenants.{name}.token must be non-empty");
                }
                let class = match body.get("class") {
                    Some(v) => {
                        let cname = v.as_str().ok_or_else(|| {
                            anyhow!("serve config: tenants.{name}.class must be a string")
                        })?;
                        classes
                            .get(cname)
                            .cloned()
                            .ok_or_else(|| {
                                anyhow!("serve config: tenants.{name}.class references unknown class {cname:?}")
                            })?
                    }
                    None => QuotaClass::unlimited(),
                };
                let price_multiplier = match body.get("price_multiplier") {
                    Some(v) => {
                        let m = v.as_f64().ok_or_else(|| {
                            anyhow!("serve config: tenants.{name}.price_multiplier must be a number")
                        })?;
                        if !(m.is_finite() && m >= 0.0) {
                            bail!("serve config: tenants.{name}.price_multiplier must be finite and non-negative");
                        }
                        m
                    }
                    None => 1.0,
                };
                tenants.push(Tenant { name: name.clone(), token, class, price_multiplier });
            }
        }
        for i in 0..tenants.len() {
            for j in (i + 1)..tenants.len() {
                if tenants[i].token == tenants[j].token {
                    bail!(
                        "serve config: tenants {} and {} share a token",
                        tenants[i].name,
                        tenants[j].name
                    );
                }
            }
        }
        Ok(Self { tenants })
    }

    pub fn tenants(&self) -> &[Tenant] {
        &self.tenants
    }

    /// Token → tenant id.
    pub fn authenticate(&self, token: &str) -> Option<usize> {
        self.tenants.iter().position(|t| t.token == token)
    }

    /// Name → tenant id (invoice/status routes address tenants by name).
    pub fn by_name(&self, name: &str) -> Option<usize> {
        self.tenants.iter().position(|t| t.name == name)
    }

    pub fn tenant(&self, id: usize) -> &Tenant {
        &self.tenants[id]
    }
}

/// Outcome of an admission decision.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmissionVerdict {
    /// Admitted; `reserved_hot` hot slots are now held until release.
    Admitted { degraded: bool, reserved_hot: u64 },
    /// Rejected with a machine-readable reason (`stream-quota` or
    /// `hot-quota`).
    Rejected { reason: &'static str },
}

/// Live per-tenant usage and verdict counters (surfaced in `/v1/status`).
#[derive(Debug, Clone, Default)]
pub struct TenantUsage {
    pub live_streams: u64,
    pub reserved_hot: u64,
    pub admitted: u64,
    pub degraded: u64,
    pub rejected: u64,
    pub last_rejection: Option<&'static str>,
}

/// Runtime admission state over a [`TenantBook`].
#[derive(Debug)]
pub struct AdmissionControl {
    usage: Vec<TenantUsage>,
}

impl AdmissionControl {
    pub fn new(book: &TenantBook) -> Self {
        Self { usage: vec![TenantUsage::default(); book.tenants().len()] }
    }

    /// Decide admission for a stream with the given analytic hot demand.
    /// On admission the reservation is taken immediately; the caller must
    /// [`release`](Self::release) it when the stream finishes.
    pub fn admit(&mut self, book: &TenantBook, tenant: usize, hot_demand: u64) -> AdmissionVerdict {
        let class = &book.tenant(tenant).class;
        let u = &mut self.usage[tenant];
        if u.live_streams >= class.max_streams {
            u.rejected += 1;
            u.last_rejection = Some("stream-quota");
            return AdmissionVerdict::Rejected { reason: "stream-quota" };
        }
        if u.reserved_hot.saturating_add(hot_demand) > class.max_hot_docs {
            match class.on_exceed {
                ExceedPolicy::Reject => {
                    u.rejected += 1;
                    u.last_rejection = Some("hot-quota");
                    return AdmissionVerdict::Rejected { reason: "hot-quota" };
                }
                ExceedPolicy::Degrade => {
                    // Pinned-cold streams place nothing hot, so they
                    // reserve nothing.
                    u.live_streams += 1;
                    u.degraded += 1;
                    return AdmissionVerdict::Admitted { degraded: true, reserved_hot: 0 };
                }
            }
        }
        u.live_streams += 1;
        u.reserved_hot += hot_demand;
        u.admitted += 1;
        AdmissionVerdict::Admitted { degraded: false, reserved_hot: hot_demand }
    }

    /// Re-assert the hot reservation of an unfinished stream recovered
    /// from the sidecar log after a restart. Journal replay rebuilds the
    /// stream's residency but not its in-memory session, so the stream
    /// can never finish: its documents keep holding hot capacity, and
    /// this keeps the tenant's hot quota honest about that. The stream
    /// quota is *not* restored — a dead session cannot be drained, and
    /// counting it would wedge `max_streams` permanently. No verdict
    /// counters are bumped.
    pub fn restore(&mut self, tenant: usize, reserved_hot: u64) {
        self.usage[tenant].reserved_hot += reserved_hot;
    }

    /// Return a finished stream's reservation to the pool.
    pub fn release(&mut self, tenant: usize, reserved_hot: u64) {
        let u = &mut self.usage[tenant];
        u.live_streams = u.live_streams.saturating_sub(1);
        u.reserved_hot = u.reserved_hot.saturating_sub(reserved_hot);
    }

    pub fn usage(&self) -> &[TenantUsage] {
        &self.usage
    }
}

/// Analytic hot-tier demand of the plan the engine will run for these
/// parameters — the quantity admission reserves against `max_hot_docs`.
///
/// The demand is quoted at the *slack-adjusted* K′ of the stream's
/// admission selector (ADR-010): the engine's arbiter derives the actual
/// plan at K′ too, so reserving the slack-free figure for a log-memory
/// stream would under-reserve by the selector's admit-rate overshoot and
/// over-admit the tenant against its hot quota.
pub fn analytic_hot_demand(
    tier_costs: &[PerDocCosts],
    n: u64,
    k: u64,
    include_rent: bool,
    family: PlanFamily,
    selector: crate::topk::SelectorKind,
) -> u64 {
    let k_planned = crate::cost::slack_adjusted_k(k, selector.slack(k)).min(n);
    PlacementPlan::optimal_family(tier_costs, n, k_planned, include_rent, family)
        .demand(TierId(0))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn book(max_streams: u64, max_hot: u64, policy: &str) -> TenantBook {
        let toml = format!(
            "[classes.c]\nmax_streams = {max_streams}\nmax_hot_docs = {max_hot}\non_exceed = \"{policy}\"\n\
             [tenants.t]\ntoken = \"tok\"\nclass = \"c\"\n"
        );
        TenantBook::from_toml(&TomlValue::parse(&toml).unwrap()).unwrap()
    }

    #[test]
    fn stream_quota_rejects_even_under_degrade_policy() {
        let b = book(2, 1_000, "degrade");
        let mut ac = AdmissionControl::new(&b);
        assert_eq!(
            ac.admit(&b, 0, 10),
            AdmissionVerdict::Admitted { degraded: false, reserved_hot: 10 }
        );
        assert_eq!(
            ac.admit(&b, 0, 10),
            AdmissionVerdict::Admitted { degraded: false, reserved_hot: 10 }
        );
        // a degraded stream is still a live stream: stream-quota binds
        assert_eq!(
            ac.admit(&b, 0, 10),
            AdmissionVerdict::Rejected { reason: "stream-quota" }
        );
        assert_eq!(ac.usage()[0].rejected, 1);
        assert_eq!(ac.usage()[0].last_rejection, Some("stream-quota"));
        ac.release(0, 10);
        assert_eq!(
            ac.admit(&b, 0, 10),
            AdmissionVerdict::Admitted { degraded: false, reserved_hot: 10 }
        );
    }

    #[test]
    fn hot_quota_rejects_or_degrades_by_policy() {
        let b = book(100, 15, "reject");
        let mut ac = AdmissionControl::new(&b);
        assert!(matches!(ac.admit(&b, 0, 10), AdmissionVerdict::Admitted { degraded: false, .. }));
        assert_eq!(ac.admit(&b, 0, 10), AdmissionVerdict::Rejected { reason: "hot-quota" });
        assert_eq!(ac.usage()[0].last_rejection, Some("hot-quota"));

        let b = book(100, 15, "degrade");
        let mut ac = AdmissionControl::new(&b);
        assert!(matches!(ac.admit(&b, 0, 10), AdmissionVerdict::Admitted { degraded: false, .. }));
        let v = ac.admit(&b, 0, 10);
        assert_eq!(v, AdmissionVerdict::Admitted { degraded: true, reserved_hot: 0 });
        // the degraded stream reserved nothing, so a small stream still fits
        assert!(matches!(ac.admit(&b, 0, 5), AdmissionVerdict::Admitted { degraded: false, .. }));
        assert_eq!(ac.usage()[0].degraded, 1);
        assert_eq!(ac.usage()[0].reserved_hot, 15);
        assert_eq!(ac.usage()[0].live_streams, 3);
    }

    #[test]
    fn restore_rebuilds_hot_reservation_without_counting_verdicts() {
        let b = book(100, 100, "reject");
        let mut ac = AdmissionControl::new(&b);
        ac.restore(0, 7);
        assert_eq!(ac.usage()[0].live_streams, 0);
        assert_eq!(ac.usage()[0].reserved_hot, 7);
        assert_eq!(ac.usage()[0].admitted, 0);
    }

    #[test]
    fn analytic_demand_is_positive_when_hot_is_cheap_to_read() {
        use crate::topk::SelectorKind;
        let costs = vec![
            PerDocCosts { write: 1.0, read: 0.1, rent_window: 0.0 },
            PerDocCosts { write: 1.0, read: 10.0, rent_window: 0.0 },
        ];
        let d =
            analytic_hot_demand(&costs, 100, 10, false, PlanFamily::Keep, SelectorKind::Bounded);
        assert!(d >= 10, "hot-favouring economics should demand at least K hot, got {d}");
    }

    /// Satellite regression (ADR-010): admission must reserve the
    /// slack-adjusted demand for log-memory streams. The old path quoted
    /// the slack-free plan, so a logmem stream at massive K reserved K
    /// while the engine planned (and placed) up to K′ > K hot — the
    /// tenant's hot quota silently over-admitted.
    #[test]
    fn admission_reserves_slack_adjusted_demand_for_logmem() {
        use crate::topk::SelectorKind;
        let costs = vec![
            PerDocCosts { write: 1.0, read: 0.1, rent_window: 0.0 },
            PerDocCosts { write: 1.0, read: 10.0, rent_window: 0.0 },
        ];
        let (n, k) = (400_000, 100_000);
        let exact =
            analytic_hot_demand(&costs, n, k, false, PlanFamily::Keep, SelectorKind::Bounded);
        let slacked =
            analytic_hot_demand(&costs, n, k, false, PlanFamily::Keep, SelectorKind::LogMem);
        assert!(
            slacked > exact,
            "logmem demand {slacked} must exceed the slack-free {exact}"
        );
        assert_eq!(
            slacked,
            crate::cost::slack_adjusted_k(k, SelectorKind::LogMem.slack(k)),
            "hot-favouring economics: the whole K′ band is demanded"
        );
        // with the slack-adjusted reservation, a quota sized for one exact
        // stream refuses the logmem stream instead of over-admitting it
        let b = book(100, exact, "reject");
        let mut ac = AdmissionControl::new(&b);
        assert_eq!(
            ac.admit(&b, 0, slacked),
            AdmissionVerdict::Rejected { reason: "hot-quota" },
            "the old slack-free path admitted here and overcommitted the tier"
        );
        assert!(matches!(
            ac.admit(&b, 0, exact),
            AdmissionVerdict::Admitted { degraded: false, .. }
        ));
    }
}
