//! `serve` — the multi-tenant placement server over the session engine
//! (ADR-006).
//!
//! The paper's a-priori placement makes tier allocation cheap enough to
//! decide per stream with no reactive monitoring loop, which makes it
//! natural to offer as a shared service: many tenants, one
//! capacity-limited [`crate::engine::TierTopology`], analytic arbitration
//! instead of telemetry. This module is that service — transport and
//! tenancy at the edge, policy kept pure in [`crate::engine`]:
//!
//! ```text
//!   shptier serve --backend fs:<root> --config configs/serve.toml
//!       │
//!       ├─ http    minimal HTTP/1.1 on std::net (no dependencies),
//!       │          fixed worker pool, serdes::json bodies
//!       ├─ tenancy TenantBook: tokens → tenants, quota classes,
//!       │          admission (reject 429 / degrade-to-cold)
//!       ├─ billing per-tenant invoices from the per-stream ledger
//!       │          attribution the backends already track
//!       └─ lifecycle graceful drain + checkpoint on shutdown;
//!                  kill-and-restart recovers via journal replay
//! ```
//!
//! Protocol (all bodies JSON):
//!
//! - `POST /v1/streams` — open: tenant token, `n`, `k`, plan family,
//!   optional per-tier economics → session token (or `429` with a
//!   machine-readable reason, or a degraded-to-cold admission).
//! - `POST /v1/streams/{token}/observe` — a batch of scores.
//! - `POST /v1/streams/{token}/finish` — consumer-read the top-K, close,
//!   and bill the stream.
//! - `GET /v1/tenants/{name}/invoice` — the tenant's invoice.
//! - `GET /v1/status` — arbitration report, per-tier occupancy,
//!   admission verdicts, journal health.
//!
//! [`client`] is the blocking std-only client used by the tests and the
//! `shptier serve-soak` harness; [`soak`] drives thousands of concurrent
//! sessions across tenants and verifies ledger conservation and
//! exactly-once invoicing across a kill-and-restart.

pub mod client;
pub mod http;
pub mod server;
pub mod soak;
pub mod tenancy;
pub mod wire;

pub use client::{Client, OpenOutcome};
pub use server::{open_serving_backend, RunningServer};
pub use tenancy::{AdmissionVerdict, ExceedPolicy, QuotaClass, Tenant, TenantBook};

use crate::cost::PerDocCosts;
use crate::serdes::TomlValue;
use anyhow::{anyhow, bail, Context, Result};

/// Server configuration, parsed from `configs/serve.toml`.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Listen address (`port 0` = ephemeral, printed at startup).
    pub addr: String,
    /// Fixed worker-thread pool size.
    pub workers: usize,
    /// Per-connection read timeout in milliseconds (stalled clients are
    /// dropped so they cannot pin a worker).
    pub read_timeout_ms: u64,
    /// Maximum request body size in bytes (`413` beyond it).
    pub max_body_bytes: usize,
    /// Tier count (2–4, preset economics hot → cold).
    pub tiers: usize,
    /// Capacity of the hot tier (colder tiers are unbounded).
    pub hot_capacity: u64,
    /// Whether the backend charges rent.
    pub charge_rent: bool,
    /// Auto-checkpoint factor (`engine.checkpoint_factor`): checkpoint
    /// when `journal_ops > factor × live docs`; 0 disables.
    pub checkpoint_factor: u64,
    /// fsync journal appends *and* sidecar appends
    /// (`engine.sync_writes`). The two logs always share one durability
    /// posture — a synced journal with an unsynced sidecar would let
    /// attribution lag the state it attributes.
    pub sync_writes: bool,
    /// Group-commit journal batching (`engine.group_commit`, ADR-009):
    /// op records batch in memory and flush on size cap, age cap, or
    /// barrier, trading a bounded staleness window for write throughput.
    pub group_commit: bool,
    /// Admission selector every served stream runs (`engine.selector`,
    /// ADR-010): `bounded` (exact top-K heap) or `logmem` (O(log K)
    /// sketch; admission reserves its slack-adjusted demand).
    pub selector: crate::topk::SelectorKind,
    /// The tenant book: tokens, quota classes, price books.
    pub book: TenantBook,
}

impl ServeConfig {
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading serve config {path}"))?;
        Self::from_toml(&text)
    }

    pub fn from_toml(text: &str) -> Result<Self> {
        let t = TomlValue::parse(text).map_err(|e| anyhow!("serve config: {e}"))?;
        let get_u64 = |path: &str, default: u64| -> Result<u64> {
            match t.get_path(path) {
                Some(v) => v
                    .as_u64()
                    .ok_or_else(|| anyhow!("serve config: {path} must be a non-negative integer")),
                None => Ok(default),
            }
        };
        let addr = match t.get_path("serve.addr") {
            Some(v) => v
                .as_str()
                .ok_or_else(|| anyhow!("serve config: serve.addr must be a string"))?
                .to_string(),
            None => "127.0.0.1:0".to_string(),
        };
        let workers = get_u64("serve.workers", 8)? as usize;
        if workers == 0 {
            bail!("serve config: serve.workers must be at least 1");
        }
        let read_timeout_ms = get_u64("serve.read_timeout_ms", 5_000)?;
        if read_timeout_ms == 0 {
            bail!("serve config: serve.read_timeout_ms must be positive");
        }
        let max_body_bytes = get_u64("serve.max_body_bytes", 256 * 1024)? as usize;
        if max_body_bytes == 0 {
            bail!("serve config: serve.max_body_bytes must be positive");
        }
        let tiers = get_u64("engine.tiers", 2)? as usize;
        if !(2..=4).contains(&tiers) {
            bail!("serve config: engine.tiers must be between 2 and 4");
        }
        let hot_capacity = get_u64("engine.hot_capacity", 256)?;
        if hot_capacity == 0 {
            bail!("serve config: engine.hot_capacity must be positive");
        }
        let charge_rent = match t.get_path("engine.charge_rent") {
            Some(v) => v
                .as_bool()
                .ok_or_else(|| anyhow!("serve config: engine.charge_rent must be a bool"))?,
            None => true,
        };
        let checkpoint_factor = get_u64("engine.checkpoint_factor", 8)?;
        let get_bool = |path: &str, default: bool| -> Result<bool> {
            match t.get_path(path) {
                Some(v) => v
                    .as_bool()
                    .ok_or_else(|| anyhow!("serve config: {path} must be a bool")),
                None => Ok(default),
            }
        };
        let sync_writes = get_bool("engine.sync_writes", false)?;
        let group_commit = get_bool("engine.group_commit", false)?;
        let selector = crate::topk::SelectorKind::parse(
            t.get_path("engine.selector").and_then(|v| v.as_str()).unwrap_or("bounded"),
        )
        .map_err(|e| anyhow!("serve config: engine.selector: {e}"))?;
        let book = TenantBook::from_toml(&t)?;
        Ok(Self {
            addr,
            workers,
            read_timeout_ms,
            max_body_bytes,
            tiers,
            hot_capacity,
            charge_rent,
            checkpoint_factor,
            sync_writes,
            group_commit,
            selector,
            book,
        })
    }

    /// Preset per-tier economics, hot → cold (same presets as the engine
    /// demo config: write costs increase, read costs decrease).
    pub fn tier_costs(&self) -> Vec<PerDocCosts> {
        let presets = [
            PerDocCosts { write: 1.0, read: 4.0, rent_window: 0.2 },
            PerDocCosts { write: 2.0, read: 1.9, rent_window: 0.1 },
            PerDocCosts { write: 3.0, read: 0.2, rent_window: 0.02 },
            PerDocCosts { write: 4.0, read: 0.05, rent_window: 0.005 },
        ];
        presets[..self.tiers].to_vec()
    }

    /// The serve topology: hot tier capacity-limited, the rest unbounded
    /// with the sink coldest.
    pub fn topology(&self) -> Result<crate::engine::TierTopology> {
        use crate::storage::TierId;
        Ok(crate::engine::TierTopology::from_costs(self.tier_costs())?
            .with_capacity(TierId(0), Some(self.hot_capacity as usize)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
[serve]
addr = "127.0.0.1:0"
workers = 4
read_timeout_ms = 1000
max_body_bytes = 4096

[engine]
tiers = 3
hot_capacity = 32
checkpoint_factor = 4
sync_writes = true
group_commit = true

[classes.standard]
max_streams = 8
max_hot_docs = 64
on_exceed = "reject"

[classes.bulk]
max_streams = 4
max_hot_docs = 2
on_exceed = "degrade"

[tenants.acme]
token = "tok-acme"
class = "standard"
price_multiplier = 1.5

[tenants.bity]
token = "tok-bity"
class = "bulk"
"#;

    #[test]
    fn parses_sample_config() {
        let c = ServeConfig::from_toml(SAMPLE).unwrap();
        assert_eq!(c.workers, 4);
        assert_eq!(c.tiers, 3);
        assert_eq!(c.hot_capacity, 32);
        assert_eq!(c.checkpoint_factor, 4);
        assert!(c.sync_writes);
        assert!(c.group_commit);
        assert_eq!(c.max_body_bytes, 4096);
        assert_eq!(c.tier_costs().len(), 3);
        assert_eq!(c.book.tenants().len(), 2);
        let acme = c.book.authenticate("tok-acme").unwrap();
        assert_eq!(c.book.tenant(acme).name, "acme");
        assert!((c.book.tenant(acme).price_multiplier - 1.5).abs() < 1e-12);
        assert_eq!(c.book.tenant(acme).class.max_streams, 8);
        assert_eq!(c.book.tenant(acme).class.on_exceed, ExceedPolicy::Reject);
        let bity = c.book.authenticate("tok-bity").unwrap();
        assert_eq!(c.book.tenant(bity).class.on_exceed, ExceedPolicy::Degrade);
        assert!(c.book.authenticate("nope").is_none());
    }

    #[test]
    fn defaults_and_validation() {
        let c = ServeConfig::from_toml("[tenants.t]\ntoken = \"x\"\n").unwrap();
        assert_eq!(c.workers, 8);
        assert_eq!(c.tiers, 2);
        assert_eq!(c.checkpoint_factor, 8);
        assert!(!c.sync_writes, "durability modes default off");
        assert!(!c.group_commit, "group commit defaults off");
        assert!(
            ServeConfig::from_toml("[engine]\ngroup_commit = 3\n[tenants.t]\ntoken = \"x\"\n")
                .is_err()
        );
        assert_eq!(c.book.tenants().len(), 1);
        assert!(ServeConfig::from_toml("[serve]\nworkers = 0\n").is_err());
        assert!(ServeConfig::from_toml("[engine]\ntiers = 9\n").is_err());
        assert!(ServeConfig::from_toml("[engine]\nhot_capacity = 0\n").is_err());
        // a tenant without a token is unusable
        assert!(ServeConfig::from_toml("[tenants.t]\nclass = \"standard\"\n").is_err());
        // an unknown class is a config error, not a runtime surprise
        assert!(
            ServeConfig::from_toml("[tenants.t]\ntoken = \"x\"\nclass = \"nope\"\n").is_err()
        );
        // duplicate tokens would make authentication ambiguous
        assert!(ServeConfig::from_toml(
            "[tenants.a]\ntoken = \"x\"\n[tenants.b]\ntoken = \"x\"\n"
        )
        .is_err());
    }

    #[test]
    fn topology_matches_tier_count() {
        let c = ServeConfig::from_toml(SAMPLE).unwrap();
        let topo = c.topology().unwrap();
        assert_eq!(topo.num_tiers(), 3);
        assert_eq!(topo.tier(crate::storage::TierId(0)).capacity, Some(32));
        assert_eq!(topo.tier(crate::storage::TierId(2)).capacity, None);
    }
}
