//! Wire payloads of the placement protocol: typed request/response
//! structs with `to_json`/`from_json`, built on the hardened
//! [`crate::serdes::json`] codec.
//!
//! Every payload satisfies `from_json(parse(dump(to_json(x)))) == x`
//! exactly — `dump` emits the shortest round-tripping decimal for
//! finite floats and the parser rejects non-finite numbers outright —
//! and the property tests at the bottom of this file pin that down over
//! randomized invoices, status reports, and error bodies.

use std::collections::BTreeMap;

use crate::cost::PerDocCosts;
use crate::policy::PlanFamily;
use crate::serdes::Json;

// ---------------------------------------------------------------------------
// Json construction/extraction helpers

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn unum(n: u64) -> Json {
    Json::Num(n as f64)
}

fn str_field(j: &Json, key: &str) -> Result<String, String> {
    j.get(key)
        .and_then(|v| v.as_str())
        .map(str::to_string)
        .ok_or_else(|| format!("missing or non-string field {key:?}"))
}

fn u64_field(j: &Json, key: &str) -> Result<u64, String> {
    j.get(key)
        .and_then(|v| v.as_u64())
        .ok_or_else(|| format!("missing or non-integer field {key:?}"))
}

fn f64_field(j: &Json, key: &str) -> Result<f64, String> {
    j.get(key)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| format!("missing or non-numeric field {key:?}"))
}

fn bool_field(j: &Json, key: &str) -> Result<bool, String> {
    j.get(key)
        .and_then(|v| v.as_bool())
        .ok_or_else(|| format!("missing or non-bool field {key:?}"))
}

fn arr_field<'a>(j: &'a Json, key: &str) -> Result<&'a [Json], String> {
    j.get(key)
        .and_then(|v| v.as_arr())
        .ok_or_else(|| format!("missing or non-array field {key:?}"))
}

// ---------------------------------------------------------------------------
// Requests

/// `POST /v1/streams` body.
#[derive(Debug, Clone, PartialEq)]
pub struct OpenRequest {
    pub token: String,
    pub n: u64,
    pub k: u64,
    pub family: PlanFamily,
    pub include_rent: bool,
    /// Optional per-tier economics override (hot → cold, arity must match
    /// the server topology); `None` = the server's configured presets.
    pub economics: Option<Vec<PerDocCosts>>,
}

impl OpenRequest {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("token", Json::Str(self.token.clone())),
            ("n", unum(self.n)),
            ("k", unum(self.k)),
            ("family", Json::Str(self.family.label().to_string())),
            ("include_rent", Json::Bool(self.include_rent)),
        ];
        if let Some(tiers) = &self.economics {
            fields.push((
                "economics",
                Json::Arr(
                    tiers
                        .iter()
                        .map(|c| {
                            obj(vec![
                                ("write", Json::Num(c.write)),
                                ("read", Json::Num(c.read)),
                                ("rent_window", Json::Num(c.rent_window)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let family = PlanFamily::parse(&str_field(j, "family")?).map_err(|e| e.to_string())?;
        let economics = match j.get("economics") {
            None | Some(Json::Null) => None,
            Some(v) => {
                let arr = v.as_arr().ok_or("field \"economics\" must be an array")?;
                let mut tiers = Vec::with_capacity(arr.len());
                for (i, t) in arr.iter().enumerate() {
                    let costs = PerDocCosts {
                        write: f64_field(t, "write")
                            .map_err(|e| format!("economics[{i}]: {e}"))?,
                        read: f64_field(t, "read").map_err(|e| format!("economics[{i}]: {e}"))?,
                        rent_window: f64_field(t, "rent_window")
                            .map_err(|e| format!("economics[{i}]: {e}"))?,
                    };
                    tiers.push(costs);
                }
                Some(tiers)
            }
        };
        Ok(Self {
            token: str_field(j, "token")?,
            n: u64_field(j, "n")?,
            k: u64_field(j, "k")?,
            family,
            include_rent: bool_field(j, "include_rent").unwrap_or(true),
            economics,
        })
    }
}

/// `POST /v1/streams/{token}/observe` body.
#[derive(Debug, Clone, PartialEq)]
pub struct ObserveRequest {
    pub scores: Vec<f64>,
}

impl ObserveRequest {
    pub fn to_json(&self) -> Json {
        obj(vec![("scores", Json::Arr(self.scores.iter().map(|s| Json::Num(*s)).collect()))])
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let arr = arr_field(j, "scores")?;
        let mut scores = Vec::with_capacity(arr.len());
        for (i, s) in arr.iter().enumerate() {
            scores.push(s.as_f64().ok_or_else(|| format!("scores[{i}] must be a number"))?);
        }
        Ok(Self { scores })
    }
}

// ---------------------------------------------------------------------------
// Responses

/// Success body for open.
#[derive(Debug, Clone, PartialEq)]
pub struct OpenResponse {
    /// Session token to use in stream routes.
    pub stream: String,
    /// Engine stream id (ledger attribution key).
    pub id: u64,
    /// True when admission degraded the stream to pinned-cold placement.
    pub degraded: bool,
    /// Hot slots reserved against the tenant's quota for this stream.
    pub reserved_hot: u64,
}

impl OpenResponse {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("stream", Json::Str(self.stream.clone())),
            ("id", unum(self.id)),
            ("degraded", Json::Bool(self.degraded)),
            ("reserved_hot", unum(self.reserved_hot)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        Ok(Self {
            stream: str_field(j, "stream")?,
            id: u64_field(j, "id")?,
            degraded: bool_field(j, "degraded")?,
            reserved_hot: u64_field(j, "reserved_hot")?,
        })
    }
}

/// Success body for observe.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ObserveResponse {
    /// Documents observed so far (across all batches).
    pub observed: u64,
    /// True once all `n` documents have been observed.
    pub done: bool,
}

impl ObserveResponse {
    pub fn to_json(&self) -> Json {
        obj(vec![("observed", unum(self.observed)), ("done", Json::Bool(self.done))])
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        Ok(Self { observed: u64_field(j, "observed")?, done: bool_field(j, "done")? })
    }
}

/// Success body for finish.
#[derive(Debug, Clone, PartialEq)]
pub struct FinishResponse {
    pub retained: u64,
    pub hot_reads: u64,
    pub cold_reads: u64,
    /// The stream's attributed ledger total at finish time.
    pub cost: f64,
}

impl FinishResponse {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("retained", unum(self.retained)),
            ("hot_reads", unum(self.hot_reads)),
            ("cold_reads", unum(self.cold_reads)),
            ("cost", Json::Num(self.cost)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        Ok(Self {
            retained: u64_field(j, "retained")?,
            hot_reads: u64_field(j, "hot_reads")?,
            cold_reads: u64_field(j, "cold_reads")?,
            cost: f64_field(j, "cost")?,
        })
    }
}

/// Error body: machine-readable `reason` for admission rejections, byte
/// `offset` for JSON parse errors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ErrorBody {
    pub error: String,
    pub reason: Option<String>,
    pub offset: Option<u64>,
}

impl ErrorBody {
    pub fn message(error: impl Into<String>) -> Self {
        Self { error: error.into(), reason: None, offset: None }
    }

    pub fn with_reason(error: impl Into<String>, reason: impl Into<String>) -> Self {
        Self { error: error.into(), reason: Some(reason.into()), offset: None }
    }

    pub fn parse_failure(e: &crate::serdes::JsonError) -> Self {
        Self {
            error: format!("invalid json: {}", e.msg),
            reason: Some("bad-json".to_string()),
            offset: Some(e.offset as u64),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![("error", Json::Str(self.error.clone()))];
        if let Some(r) = &self.reason {
            fields.push(("reason", Json::Str(r.clone())));
        }
        if let Some(o) = self.offset {
            fields.push(("offset", unum(o)));
        }
        obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        Ok(Self {
            error: str_field(j, "error")?,
            reason: j.get("reason").and_then(|v| v.as_str()).map(str::to_string),
            offset: j.get("offset").and_then(|v| v.as_u64()),
        })
    }
}

// ---------------------------------------------------------------------------
// Invoice

/// One stream line on an invoice.
#[derive(Debug, Clone, PartialEq)]
pub struct InvoiceLine {
    pub stream_id: u64,
    pub completed: bool,
    pub degraded: bool,
    /// Raw attributed ledger total (conserved against the engine ledger).
    pub cost: f64,
    /// `cost × price_multiplier` — what the tenant owes.
    pub billed: f64,
}

/// `GET /v1/tenants/{name}/invoice` body.
#[derive(Debug, Clone, PartialEq)]
pub struct Invoice {
    pub tenant: String,
    pub price_multiplier: f64,
    pub streams: Vec<InvoiceLine>,
    /// Completed streams folded out of the sidecar log at a past
    /// graceful shutdown — no per-stream lines survive for them, only
    /// this aggregate (ADR-007 satellite).
    pub settled_streams: u64,
    /// Raw ledger cost of the settled streams, captured at fold time.
    pub settled_cost: f64,
    /// Includes `settled_cost`.
    pub cost_total: f64,
    /// Includes `settled_cost × price_multiplier`.
    pub billed_total: f64,
}

impl Invoice {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("tenant", Json::Str(self.tenant.clone())),
            ("price_multiplier", Json::Num(self.price_multiplier)),
            ("settled_streams", unum(self.settled_streams)),
            ("settled_cost", Json::Num(self.settled_cost)),
            (
                "streams",
                Json::Arr(
                    self.streams
                        .iter()
                        .map(|s| {
                            obj(vec![
                                ("stream_id", unum(s.stream_id)),
                                ("completed", Json::Bool(s.completed)),
                                ("degraded", Json::Bool(s.degraded)),
                                ("cost", Json::Num(s.cost)),
                                ("billed", Json::Num(s.billed)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("cost_total", Json::Num(self.cost_total)),
            ("billed_total", Json::Num(self.billed_total)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let mut streams = Vec::new();
        for (i, s) in arr_field(j, "streams")?.iter().enumerate() {
            streams.push(InvoiceLine {
                stream_id: u64_field(s, "stream_id").map_err(|e| format!("streams[{i}]: {e}"))?,
                completed: bool_field(s, "completed").map_err(|e| format!("streams[{i}]: {e}"))?,
                degraded: bool_field(s, "degraded").map_err(|e| format!("streams[{i}]: {e}"))?,
                cost: f64_field(s, "cost").map_err(|e| format!("streams[{i}]: {e}"))?,
                billed: f64_field(s, "billed").map_err(|e| format!("streams[{i}]: {e}"))?,
            });
        }
        Ok(Self {
            tenant: str_field(j, "tenant")?,
            price_multiplier: f64_field(j, "price_multiplier")?,
            streams,
            settled_streams: u64_field(j, "settled_streams")?,
            settled_cost: f64_field(j, "settled_cost")?,
            cost_total: f64_field(j, "cost_total")?,
            billed_total: f64_field(j, "billed_total")?,
        })
    }
}

// ---------------------------------------------------------------------------
// Status

/// Per-tier occupancy line in the status report.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TierStatus {
    pub occupancy: u64,
    /// `None` = unbounded tier.
    pub capacity: Option<u64>,
    pub peak: u64,
}

/// Per-tenant admission line in the status report (the admission half of
/// the arbitration report: verdicts must be visible here, not only in
/// HTTP responses).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantStatus {
    pub tenant: String,
    pub live_streams: u64,
    pub reserved_hot: u64,
    pub admitted: u64,
    pub degraded: u64,
    pub rejected: u64,
    pub last_rejection: Option<String>,
}

/// `GET /v1/status` body.
#[derive(Debug, Clone, PartialEq)]
pub struct Status {
    pub backend: String,
    pub arbiter: String,
    pub live_sessions: u64,
    pub rearbitrations: u64,
    /// Tiers whose orphaned residents swallowed their capacity at the
    /// last arbitration (0 = healthy).
    pub overcommitted_tiers: u64,
    pub journal_ops: u64,
    pub auto_checkpoints: u64,
    /// Admission-curve drift detections across all sessions (ADR-007).
    pub drift_detections: u64,
    /// Drift-triggered cut re-derivations (0 unless the engine runs the
    /// adaptive arbiter with the drift trigger armed).
    pub drift_rederivations: u64,
    pub ledger_total: f64,
    pub tiers: Vec<TierStatus>,
    pub tenants: Vec<TenantStatus>,
}

impl Status {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("backend", Json::Str(self.backend.clone())),
            ("arbiter", Json::Str(self.arbiter.clone())),
            ("live_sessions", unum(self.live_sessions)),
            ("rearbitrations", unum(self.rearbitrations)),
            ("overcommitted_tiers", unum(self.overcommitted_tiers)),
            ("journal_ops", unum(self.journal_ops)),
            ("auto_checkpoints", unum(self.auto_checkpoints)),
            ("drift_detections", unum(self.drift_detections)),
            ("drift_rederivations", unum(self.drift_rederivations)),
            ("ledger_total", Json::Num(self.ledger_total)),
            (
                "tiers",
                Json::Arr(
                    self.tiers
                        .iter()
                        .map(|t| {
                            obj(vec![
                                ("occupancy", unum(t.occupancy)),
                                (
                                    "capacity",
                                    t.capacity.map_or(Json::Null, unum),
                                ),
                                ("peak", unum(t.peak)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "tenants",
                Json::Arr(
                    self.tenants
                        .iter()
                        .map(|t| {
                            obj(vec![
                                ("tenant", Json::Str(t.tenant.clone())),
                                ("live_streams", unum(t.live_streams)),
                                ("reserved_hot", unum(t.reserved_hot)),
                                ("admitted", unum(t.admitted)),
                                ("degraded", unum(t.degraded)),
                                ("rejected", unum(t.rejected)),
                                (
                                    "last_rejection",
                                    t.last_rejection
                                        .as_ref()
                                        .map_or(Json::Null, |r| Json::Str(r.clone())),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let mut tiers = Vec::new();
        for (i, t) in arr_field(j, "tiers")?.iter().enumerate() {
            let capacity = match t.get("capacity") {
                None | Some(Json::Null) => None,
                Some(v) => {
                    Some(v.as_u64().ok_or_else(|| format!("tiers[{i}].capacity must be an integer"))?)
                }
            };
            tiers.push(TierStatus {
                occupancy: u64_field(t, "occupancy").map_err(|e| format!("tiers[{i}]: {e}"))?,
                capacity,
                peak: u64_field(t, "peak").map_err(|e| format!("tiers[{i}]: {e}"))?,
            });
        }
        let mut tenants = Vec::new();
        for (i, t) in arr_field(j, "tenants")?.iter().enumerate() {
            tenants.push(TenantStatus {
                tenant: str_field(t, "tenant").map_err(|e| format!("tenants[{i}]: {e}"))?,
                live_streams: u64_field(t, "live_streams")
                    .map_err(|e| format!("tenants[{i}]: {e}"))?,
                reserved_hot: u64_field(t, "reserved_hot")
                    .map_err(|e| format!("tenants[{i}]: {e}"))?,
                admitted: u64_field(t, "admitted").map_err(|e| format!("tenants[{i}]: {e}"))?,
                degraded: u64_field(t, "degraded").map_err(|e| format!("tenants[{i}]: {e}"))?,
                rejected: u64_field(t, "rejected").map_err(|e| format!("tenants[{i}]: {e}"))?,
                last_rejection: t
                    .get("last_rejection")
                    .and_then(|v| v.as_str())
                    .map(str::to_string),
            });
        }
        Ok(Self {
            backend: str_field(j, "backend")?,
            arbiter: str_field(j, "arbiter")?,
            live_sessions: u64_field(j, "live_sessions")?,
            rearbitrations: u64_field(j, "rearbitrations")?,
            overcommitted_tiers: u64_field(j, "overcommitted_tiers")?,
            journal_ops: u64_field(j, "journal_ops")?,
            auto_checkpoints: u64_field(j, "auto_checkpoints")?,
            drift_detections: u64_field(j, "drift_detections")?,
            drift_rederivations: u64_field(j, "drift_rederivations")?,
            ledger_total: f64_field(j, "ledger_total")?,
            tiers,
            tenants,
        })
    }
}

/// Parse a request body and map failures to a 400-with-offset error.
pub fn parse_body(body: &[u8]) -> Result<Json, ErrorBody> {
    let text = std::str::from_utf8(body).map_err(|_| ErrorBody {
        error: "body is not utf-8".to_string(),
        reason: Some("bad-json".to_string()),
        offset: None,
    })?;
    Json::parse(text).map_err(|e| ErrorBody::parse_failure(&e))
}

// keep `obj` available to the server module for ad-hoc payloads
pub(crate) fn json_obj(fields: Vec<(&str, Json)>) -> Json {
    obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::propcheck::{check, Config};
    use crate::util::Rng;

    // Generators. All floats are finite by construction; integers stay
    // below 2^53 so Json::Num holds them exactly.

    fn gen_money(rng: &mut Rng) -> f64 {
        // exercise negative, fractional, large, and tiny magnitudes
        let scale = match rng.next_below(4) {
            0 => 1e-7,
            1 => 1.0,
            2 => 1e9,
            _ => 1e15,
        };
        (rng.next_f64() - 0.5) * scale
    }

    fn gen_name(rng: &mut Rng) -> String {
        // include chars that need JSON escaping
        let alphabet = ["acme", "b\"quote", "uni\u{2603}code", "tab\there", "x\\y", ""];
        alphabet[rng.next_below(alphabet.len() as u64) as usize].to_string()
    }

    fn gen_invoice(rng: &mut Rng) -> Invoice {
        let n = rng.next_below(6) as usize;
        let streams: Vec<InvoiceLine> = (0..n)
            .map(|_| InvoiceLine {
                stream_id: rng.next_below(1 << 24),
                completed: rng.next_below(2) == 0,
                degraded: rng.next_below(2) == 0,
                cost: gen_money(rng),
                billed: gen_money(rng),
            })
            .collect();
        let settled_cost = gen_money(rng);
        Invoice {
            tenant: gen_name(rng),
            price_multiplier: rng.next_f64() * 3.0,
            settled_streams: rng.next_below(1 << 20),
            settled_cost,
            cost_total: streams.iter().map(|s| s.cost).sum::<f64>() + settled_cost,
            billed_total: streams.iter().map(|s| s.billed).sum(),
            streams,
        }
    }

    fn gen_status(rng: &mut Rng) -> Status {
        let tiers: Vec<TierStatus> = (0..(2 + rng.next_below(3) as usize))
            .map(|_| TierStatus {
                occupancy: rng.next_below(1 << 20),
                capacity: if rng.next_below(2) == 0 { None } else { Some(rng.next_below(1 << 20)) },
                peak: rng.next_below(1 << 20),
            })
            .collect();
        let tenants: Vec<TenantStatus> = (0..rng.next_below(5) as usize)
            .map(|_| TenantStatus {
                tenant: gen_name(rng),
                live_streams: rng.next_below(1000),
                reserved_hot: rng.next_below(1 << 30),
                admitted: rng.next_below(1 << 30),
                degraded: rng.next_below(100),
                rejected: rng.next_below(100),
                last_rejection: if rng.next_below(2) == 0 {
                    None
                } else {
                    Some("hot-quota".to_string())
                },
            })
            .collect();
        Status {
            backend: gen_name(rng),
            arbiter: "greedy".to_string(),
            live_sessions: rng.next_below(2000),
            rearbitrations: rng.next_below(1 << 40),
            overcommitted_tiers: rng.next_below(4),
            journal_ops: rng.next_below(1 << 50),
            auto_checkpoints: rng.next_below(1000),
            drift_detections: rng.next_below(1 << 20),
            drift_rederivations: rng.next_below(1 << 20),
            ledger_total: gen_money(rng),
            tiers,
            tenants,
        }
    }

    fn gen_error(rng: &mut Rng) -> ErrorBody {
        ErrorBody {
            error: gen_name(rng),
            reason: if rng.next_below(2) == 0 { None } else { Some("stream-quota".to_string()) },
            offset: if rng.next_below(2) == 0 { None } else { Some(rng.next_below(1 << 40)) },
        }
    }

    fn round_trip<T, F, G>(x: &T, to: F, from: G) -> Result<(), String>
    where
        T: PartialEq + std::fmt::Debug,
        F: Fn(&T) -> Json,
        G: Fn(&Json) -> Result<T, String>,
    {
        let wire = to(x).dump();
        let parsed = Json::parse(&wire).map_err(|e| format!("reparse failed: {e} in {wire}"))?;
        let back = from(&parsed)?;
        if &back == x {
            Ok(())
        } else {
            Err(format!("round trip drifted:\n  sent {x:?}\n  got  {back:?}\n  wire {wire}"))
        }
    }

    #[test]
    fn invoices_round_trip_exactly() {
        check("invoice-roundtrip", Config::default(), gen_invoice, |inv| {
            round_trip(inv, Invoice::to_json, Invoice::from_json)
        });
    }

    #[test]
    fn status_round_trips_exactly() {
        check("status-roundtrip", Config::default(), gen_status, |st| {
            round_trip(st, Status::to_json, Status::from_json)
        });
    }

    #[test]
    fn errors_round_trip_exactly() {
        check("error-roundtrip", Config::default(), gen_error, |e| {
            round_trip(e, ErrorBody::to_json, ErrorBody::from_json)
        });
    }

    #[test]
    fn open_and_observe_round_trip() {
        check(
            "open-roundtrip",
            Config { cases: 64, ..Config::default() },
            |rng: &mut Rng| OpenRequest {
                token: gen_name(rng),
                n: 1 + rng.next_below(1 << 30),
                k: 1 + rng.next_below(1 << 10),
                family: [PlanFamily::Keep, PlanFamily::Migrate, PlanFamily::Auto]
                    [rng.next_below(3) as usize],
                include_rent: rng.next_below(2) == 0,
                economics: if rng.next_below(2) == 0 {
                    None
                } else {
                    Some(
                        (0..(2 + rng.next_below(3) as usize))
                            .map(|_| PerDocCosts {
                                write: rng.next_f64() * 10.0,
                                read: rng.next_f64() * 10.0,
                                rent_window: rng.next_f64(),
                            })
                            .collect(),
                    )
                },
            },
            |req| round_trip(req, OpenRequest::to_json, OpenRequest::from_json),
        );
        check(
            "observe-roundtrip",
            Config { cases: 64, ..Config::default() },
            crate::propcheck::gens::score_vec(0, 50),
            |scores| {
                round_trip(
                    &ObserveRequest { scores: scores.clone() },
                    ObserveRequest::to_json,
                    ObserveRequest::from_json,
                )
            },
        );
    }

    #[test]
    fn non_finite_payloads_cannot_cross_the_wire() {
        // dump() of a non-finite Num yields text the hardened parser
        // refuses, so a corrupt in-memory value cannot silently reach a
        // client as something else.
        for bad in [f64::INFINITY, f64::NEG_INFINITY, f64::NAN] {
            let inv = Invoice {
                tenant: "t".to_string(),
                price_multiplier: 1.0,
                streams: vec![],
                settled_streams: 0,
                settled_cost: 0.0,
                cost_total: bad,
                billed_total: 0.0,
            };
            let wire = inv.to_json().dump();
            assert!(
                Json::parse(&wire).is_err(),
                "non-finite {bad} round-tripped via {wire}"
            );
        }
    }

    #[test]
    fn parse_body_reports_byte_offset() {
        let e = parse_body(b"{\"scores\": [1, 2, oops]}").unwrap_err();
        assert_eq!(e.reason.as_deref(), Some("bad-json"));
        assert_eq!(e.offset, Some(18));
        let e = parse_body(&[0xff, 0xfe]).unwrap_err();
        assert!(e.error.contains("utf-8"));
    }

    #[test]
    fn deeply_nested_bodies_are_rejected() {
        let bomb = format!("{}1{}", "[".repeat(100_000), "]".repeat(100_000));
        let e = parse_body(bomb.as_bytes()).unwrap_err();
        assert!(e.error.contains("nesting too deep"), "got {e:?}");
    }
}
