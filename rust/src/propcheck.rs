//! Minimal property-based testing harness.
//!
//! The vendored crate set has no `proptest`, so this module provides the
//! subset the test suite needs: seeded generators, a case runner that
//! reports the failing seed, and simple shrinking for integer/vec sizes.
//! Every property runs `cases` times with deterministic per-case seeds, so
//! a failure message like `property failed (seed 0xABCD, case 17)` is
//! exactly reproducible.

use crate::util::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub cases: u32,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 128, seed: 0x5_151_515 }
    }
}

/// A generator of values from an RNG.
pub trait Gen<T> {
    fn generate(&self, rng: &mut Rng) -> T;
}

impl<T, F: Fn(&mut Rng) -> T> Gen<T> for F {
    fn generate(&self, rng: &mut Rng) -> T {
        self(rng)
    }
}

/// Run a property over generated inputs; panics with the reproducing seed
/// on failure. The property returns `Err(msg)` (or panics) to fail.
pub fn check<T: std::fmt::Debug, G: Gen<T>, P: Fn(&T) -> Result<(), String>>(
    name: &str,
    config: Config,
    gen: G,
    prop: P,
) {
    for case in 0..config.cases {
        let case_seed = config.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let input = gen.generate(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed (case {case}, seed {case_seed:#x}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Common generators.
pub mod gens {
    use crate::util::Rng;

    /// Uniform u64 in [lo, hi].
    pub fn u64_in(lo: u64, hi: u64) -> impl Fn(&mut Rng) -> u64 {
        move |rng| lo + rng.next_below(hi - lo + 1)
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(lo: f64, hi: f64) -> impl Fn(&mut Rng) -> f64 {
        move |rng| rng.range_f64(lo, hi)
    }

    /// Vec of uniform scores with random length in [min_len, max_len].
    pub fn score_vec(min_len: usize, max_len: usize) -> impl Fn(&mut Rng) -> Vec<f64> {
        move |rng| {
            let n = min_len + rng.next_below((max_len - min_len + 1) as u64) as usize;
            (0..n).map(|_| rng.next_f64()).collect()
        }
    }

    /// Vec of f32 series values with occasional extreme magnitudes.
    pub fn f32_series(len: usize) -> impl Fn(&mut Rng) -> Vec<f32> {
        move |rng| {
            let scale = match rng.next_below(4) {
                0 => 1e-3,
                1 => 1.0,
                2 => 1e3,
                _ => 1e6,
            };
            (0..len).map(|_| (rng.next_f64() as f32 - 0.5) * scale).collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check(
            "sum-commutes",
            Config { cases: 50, ..Default::default() },
            gens::score_vec(0, 20),
            |v| {
                let a: f64 = v.iter().sum();
                let b: f64 = v.iter().rev().sum();
                if (a - b).abs() < 1e-9 {
                    Ok(())
                } else {
                    Err(format!("{a} != {b}"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports_seed() {
        check(
            "always-fails",
            Config { cases: 3, ..Default::default() },
            gens::u64_in(0, 10),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn generators_respect_bounds() {
        let mut rng = crate::util::Rng::new(1);
        for _ in 0..1000 {
            let x = gens::u64_in(5, 9)(&mut rng);
            assert!((5..=9).contains(&x));
            let f = gens::f64_in(-1.0, 1.0)(&mut rng);
            assert!((-1.0..1.0).contains(&f));
        }
        let v = gens::score_vec(3, 7)(&mut rng);
        assert!((3..=7).contains(&v.len()));
    }
}
