//! Cloud pricing presets — the 2018 list prices used in paper Tables I–II.
//!
//! Prices are $ per unit as quoted by the provider (footnotes 5–7 of the
//! paper): S3 EU-Ireland, Azure Blob GPv1 North-Europe, Azure egress
//! North-Europe, EFS. Presets return `TierPricing` with the location set by
//! the caller's scenario.

use crate::cost::model::{Channel, CostModel, DocSpec, Location, TierPricing};

/// AWS S3 Standard (EU, Ireland, 2018): PUT $0.005/1k, GET $0.0004/1k,
/// storage $0.023/GB·month.
pub fn s3_standard(location: Location) -> TierPricing {
    TierPricing {
        name: "AWS S3 Standard".into(),
        put_per_doc: 0.005 / 1_000.0,
        get_per_doc: 0.0004 / 1_000.0,
        storage_gb_month: 0.023,
        ingress_gb: 0.0,
        egress_gb: 0.0, // the cross-cloud hop is charged via Channel
        location,
    }
}

/// Azure Blob Storage GPv1 (North Europe, 2018): $0.00036/10k transactions,
/// storage $0.024/GB·month.
pub fn azure_blob_gpv1(location: Location) -> TierPricing {
    TierPricing {
        name: "Azure Blob GPv1".into(),
        put_per_doc: 0.00036 / 10_000.0,
        get_per_doc: 0.00036 / 10_000.0,
        storage_gb_month: 0.024,
        ingress_gb: 0.0,
        egress_gb: 0.0,
        location,
    }
}

/// AWS EFS (2018): no per-transaction charge, $0.30/GB·month.
pub fn efs(location: Location) -> TierPricing {
    TierPricing {
        name: "AWS EFS".into(),
        put_per_doc: 0.0,
        get_per_doc: 0.0,
        storage_gb_month: 0.30,
        ingress_gb: 0.0,
        egress_gb: 0.0,
        location,
    }
}

/// The paper's inter-cloud channel price (Azure egress, North Europe 2018).
pub fn inter_cloud_channel() -> Channel {
    Channel { cost_gb: 0.087 }
}

/// Case Study 1 (paper Table I): producer in AWS with S3 local (tier A),
/// consumer in Azure with Blob local (tier B); N=1e8 docs of 0.1 MB over a
/// 1-day window; K = N/100. Transaction-dominated → rent excluded (the
/// paper uses a constant bound; see `rent_bound_no_migration`).
pub fn case_study_1() -> CostModel {
    let n: u64 = 100_000_000;
    let k: u64 = n / 100;
    let doc = DocSpec::from_mb_days(0.1, 1.0);
    let channel = inter_cloud_channel();
    let a = s3_standard(Location::Producer).per_doc(doc, channel);
    let b = azure_blob_gpv1(Location::Consumer).per_doc(doc, channel);
    CostModel::new(n, k, a, b).with_rent(false)
}

/// Case Study 2 (paper Table II): EFS (tier A) and S3 (tier B) in the same
/// cloud as the consumer; N=1e8 docs of 1 MB over a 7-day window; K = 5% of
/// N. Rent-dominated → rent included; migration variant is the winner.
pub fn case_study_2() -> CostModel {
    let n: u64 = 100_000_000;
    let k: u64 = 5_000_000;
    let doc = DocSpec::from_mb_days(1.0, 7.0);
    let channel = Channel::free();
    let a = efs(Location::Consumer).per_doc(doc, channel);
    // paper quotes S3 read/write as $0.000005/doc in Table II
    let mut s3 = s3_standard(Location::Consumer);
    s3.get_per_doc = 0.000005;
    let b = s3.per_doc(doc, channel);
    CostModel::new(n, k, a, b)
}

/// Downscaled variants for trace-driven simulation (same per-doc economics,
/// smaller N/K so a discrete-event run finishes quickly). `scale` divides
/// both N and K.
pub fn scaled(model: &CostModel, scale: u64) -> CostModel {
    assert!(scale >= 1);
    let n = (model.n / scale).max(1);
    let k = (model.k / scale).max(1);
    CostModel::new(n, k, model.a, model.b).with_rent(model.include_rent)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::analytic::expected_cost;
    use crate::cost::model::Strategy;
    use crate::cost::optimizer::{closed_form_frac_no_migration, optimal_r};

    #[test]
    fn case_study_1_per_doc_costs() {
        let m = case_study_1();
        // A = S3 producer-local: write is a plain PUT
        assert!((m.a.write - 5e-6).abs() < 1e-12);
        // read crosses the channel: GET + 0.087 $/GB × 1e-4 GB
        assert!((m.a.read - (4e-7 + 0.087 * 1e-4)).abs() < 1e-12);
        // B = Azure consumer-local: write crosses, read is local
        assert!((m.b.write - (3.6e-8 + 0.087 * 1e-4)).abs() < 1e-12);
        assert!((m.b.read - 3.6e-8).abs() < 1e-12);
    }

    #[test]
    fn case_study_1_reproduces_paper_r_star() {
        // Paper Table I: r_opt/N = 0.41233169. Our consistent closed form
        // gives 0.4122 (paper value reproduced to 3 decimals).
        let m = case_study_1();
        let frac = closed_form_frac_no_migration(&m).expect("interior");
        assert!(
            (frac - 0.41233169).abs() < 5e-4,
            "frac={frac} vs paper 0.41233169"
        );
    }

    #[test]
    fn case_study_1_reproduces_paper_totals() {
        let m = case_study_1();
        let opt = optimal_r(&m, false);
        // Paper: total 35.19 at r*, all-A 37.20 (we reproduce within 1%)
        assert!((opt.cost - 35.19).abs() / 35.19 < 0.01, "opt={}", opt.cost);
        let all_a = expected_cost(&m, Strategy::AllA).total();
        assert!((all_a - 37.20).abs() / 37.20 < 0.01, "allA={all_a}");
        // ordering of Table I strategies: changeover < all-A < all-B
        let all_b = expected_cost(&m, Strategy::AllB).total();
        assert!(opt.cost < all_a && all_a < all_b);
    }

    #[test]
    fn case_study_2_reproduces_paper_r_star() {
        // Paper Table II: r_opt/N = 0.078 (migration strategy)
        let m = case_study_2();
        let frac = crate::cost::optimizer::closed_form_frac_migration(&m)
            .expect("interior");
        assert!((frac - 0.078).abs() < 2e-3, "frac={frac} vs paper 0.078");
    }

    #[test]
    fn case_study_2_reproduces_paper_totals() {
        let m = case_study_2();
        // all-A = 350.00 exactly (K docs × 1e-3 GB × 0.30 × 7/30)
        let all_a_rent = m.k as f64 * m.a.rent_window;
        assert!((all_a_rent - 350.0).abs() < 0.5, "allA rent={all_a_rent}");
        // migration winner ≈ paper's 142.82 (our derivable model: 165.8,
        // or 140.8 without the final read the paper appears to omit;
        // see DESIGN.md §5 item 4). Assert the *shape*: migrate < all-A and
        // migrate < the no-migration rent bound, and the magnitude is in
        // the paper's ballpark (±20%).
        let mig = optimal_r(&m, true);
        let all_a = expected_cost(&m, Strategy::AllA).total();
        assert!(mig.cost < all_a, "mig {} vs allA {all_a}", mig.cost);
        let no_mig = {
            let mut c = expected_cost(&m, Strategy::Changeover { r: mig.r });
            c.rent = crate::cost::analytic::rent_bound_no_migration(&m);
            c.total()
        };
        assert!(mig.cost < no_mig, "mig {} vs no-mig bound {no_mig}", mig.cost);
        assert!(
            (mig.cost - 142.82).abs() / 142.82 < 0.20,
            "mig total={}",
            mig.cost
        );
        // Paper's all-B = 503.78 is only derivable by charging all N
        // documents (1e8 × 5e-6 = 500 $ of PUTs); with the paper's own
        // eq. (13) record-process accounting all-B ≈ 151.7 and would win.
        // We reproduce the paper's number under the all-N accounting:
        let all_b_all_docs = m.n as f64 * m.b.write
            + m.k as f64 * (m.b.read + m.b.rent_window);
        assert!(
            (all_b_all_docs - 503.78).abs() / 503.78 < 0.10,
            "all-N accounting all-B = {all_b_all_docs}"
        );
    }

    #[test]
    fn scaled_preserves_economics() {
        let m = case_study_1();
        let s = scaled(&m, 10_000);
        assert_eq!(s.n, 10_000);
        assert_eq!(s.k, 100);
        assert_eq!(s.a, m.a);
        // r*/N is scale-free (it depends only on per-doc costs)
        let f1 = closed_form_frac_no_migration(&m).unwrap();
        let f2 = closed_form_frac_no_migration(&s).unwrap();
        assert!((f1 - f2).abs() < 1e-12);
    }
}
