//! The paper's analytic cost model (§IV, §VI–VII): effective per-document
//! costs, expected-cost closed forms, and `r*` optimizers, plus the 2018
//! cloud pricing presets behind Tables I–II.

pub mod analytic;
pub mod model;
pub mod optimizer;
pub mod pricing;

pub use analytic::{
    algorithm_b_expected_writes, expected_cost, expected_rent_no_migration,
    expected_writes, expected_writes_with_slack, p_survivor_in_a, p_write,
    rent_bound_no_migration, selector_slack, slack_adjusted_demand, slack_adjusted_k,
};
pub use model::{
    Channel, CostBreakdown, CostModel, DocSpec, Location, PerDocCosts, Strategy, TierPricing,
};
pub use optimizer::{
    budget_clamp, closed_form_frac_migration, closed_form_frac_no_migration, hot_demand,
    hot_demand_with_slack, numeric_optimal_r, optimal_cuts, optimal_cuts_family, optimal_r,
    optimal_r_budgeted, rank_strategies, OptimalR,
};
pub use pricing::{
    azure_blob_gpv1, case_study_1, case_study_2, efs, inter_cloud_channel, s3_standard, scaled,
};
