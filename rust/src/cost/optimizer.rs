//! Optimal changeover point `r*` — closed forms (eqs. 17/21) plus numeric
//! cross-checks.
//!
//! Differentiating the expected total cost w.r.t. `r` (using the log
//! approximation of the harmonic sums, valid for `K ≪ r ≪ N`):
//!
//! no migration (transaction-dominated, rent bounded/constant):
//!   d/dr = K·(c_wA − c_wB)/r + K·(c_rA − c_rB)/N = 0
//!   ⇒ r*/N = (c_wB − c_wA) / (c_rA − c_rB)               (†)
//!
//! with migration (rent linear in r, reads constant):
//!   d/dr = K·(c_wA − c_wB)/r + K·(c_sA − c_sB)/N = 0
//!   ⇒ r*/N = (c_wB − c_wA) / (c_sA − c_sB)               (‡)
//!
//! (†)/(‡) are the paper's eqs. (17)/(21) with the A/B read labels made
//! consistent with "first r to A" (DESIGN.md §5). A changeover interior
//! optimum exists iff `c_wA < c_wB` *and* the denominator is positive
//! (A is cheaper to write early, dearer to read/rent late) — the curve is
//! then strictly convex in `ln r` between the endpoints.

use crate::cost::analytic::expected_cost;
use crate::cost::model::{CostModel, Strategy};
use crate::util::math::golden_section_min;

/// Outcome of `r*` optimization for one strategy family.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OptimalR {
    /// Optimal changeover index.
    pub r: u64,
    /// `r / N`.
    pub frac: f64,
    /// Expected total cost at `r` (including rent per the model flag).
    pub cost: f64,
    /// Whether eq. (22) `K < r < N` holds — if false, a single-tier
    /// strategy dominates and `r` is the clamped best endpoint.
    pub interior: bool,
}

/// Closed-form `r*/N` for the no-migration strategy (consistent eq. 17).
/// Returns `None` when no interior optimum exists (degenerate denominator
/// or ratio outside (0, 1)).
pub fn closed_form_frac_no_migration(model: &CostModel) -> Option<f64> {
    let num = model.b.write - model.a.write;
    let den = model.a.read - model.b.read;
    frac_from_ratio(num, den)
}

/// Closed-form `r*/N` for the migration strategy (consistent eq. 21).
pub fn closed_form_frac_migration(model: &CostModel) -> Option<f64> {
    let num = model.b.write - model.a.write;
    let den = model.a.rent_window - model.b.rent_window;
    frac_from_ratio(num, den)
}

fn frac_from_ratio(num: f64, den: f64) -> Option<f64> {
    if den.abs() < 1e-300 {
        return None;
    }
    let frac = num / den;
    if frac.is_finite() && frac > 0.0 && frac < 1.0 {
        Some(frac)
    } else {
        None
    }
}

/// Numerically minimize expected cost over `r ∈ [K+1, N−1]` for the given
/// strategy family, by golden-section on log r (the cost is convex in
/// `ln r` when an interior optimum exists) with endpoint comparison.
pub fn numeric_optimal_r(model: &CostModel, migrate: bool) -> OptimalR {
    let n = model.n;
    let k = model.k;
    let strategy = |r: u64| {
        if migrate {
            Strategy::ChangeoverMigrate { r }
        } else {
            Strategy::Changeover { r }
        }
    };
    let eval = |r: u64| expected_cost(model, strategy(r)).total();

    let lo = (k + 1).min(n);
    let hi = n.saturating_sub(1).max(lo);
    if lo >= hi {
        let r = lo;
        return OptimalR { r, frac: r as f64 / n as f64, cost: eval(r), interior: false };
    }
    let f_log = |x: f64| eval(x.exp().round().max(lo as f64).min(hi as f64) as u64);
    let (x, _) = golden_section_min(f_log, (lo as f64).ln(), (hi as f64).ln(), 1e-12);
    let mut best_r = x.exp().round() as u64;
    let mut best = eval(best_r);
    // polish ±2 around the continuous optimum and compare endpoints
    for cand in [
        best_r.saturating_sub(2),
        best_r.saturating_sub(1),
        best_r + 1,
        best_r + 2,
        lo,
        hi,
    ] {
        let c = cand.clamp(lo, hi);
        let v = eval(c);
        if v < best {
            best = v;
            best_r = c;
        }
    }
    OptimalR {
        r: best_r,
        frac: best_r as f64 / n as f64,
        cost: best,
        interior: best_r > k && best_r < n,
    }
}

/// Closed-form `r*` with validity check (eq. 22), falling back to the
/// numeric optimizer when the closed form does not apply.
pub fn optimal_r(model: &CostModel, migrate: bool) -> OptimalR {
    let frac = if migrate {
        closed_form_frac_migration(model)
    } else {
        closed_form_frac_no_migration(model)
    };
    match frac {
        Some(f) => {
            let r = ((f * model.n as f64).round() as u64).clamp(1, model.n);
            let strategy = if migrate {
                Strategy::ChangeoverMigrate { r }
            } else {
                Strategy::Changeover { r }
            };
            let interior = r > model.k && r < model.n;
            let cost = expected_cost(model, strategy).total();
            if interior {
                OptimalR { r, frac: r as f64 / model.n as f64, cost, interior }
            } else {
                numeric_optimal_r(model, migrate)
            }
        }
        None => numeric_optimal_r(model, migrate),
    }
}

/// Hot-tier demand of one stream: the expected peak simultaneous tier-A
/// occupancy under its unconstrained optimum, `min(r*, K)` residents.
///
/// Under "first r to A", at most `min(r, K)` documents are ever resident in
/// A at once (only indices `< r` are written there, and the live set is the
/// current top-K), so this is the capacity a shared hot tier must reserve
/// for the stream to run its optimum unthrottled.
pub fn hot_demand(model: &CostModel, migrate: bool) -> u64 {
    optimal_r(model, migrate).r.min(model.k)
}

/// Hot-tier demand under selector admission slack (ADR-010): the same
/// `min(r*, K)` reservation evaluated at the slack-adjusted `K'` — a
/// near-optimal selector with overshoot ε admits like the exact process
/// run at `K' = K + ⌈ε·K⌉`, so its peak hot occupancy (and therefore the
/// capacity an admission heuristic must reserve) inflates accordingly.
/// With ε = 0 this is exactly [`hot_demand`].
pub fn hot_demand_with_slack(model: &CostModel, migrate: bool, epsilon: f64) -> u64 {
    if epsilon <= 0.0 {
        return hot_demand(model, migrate);
    }
    let mut m = model.clone();
    m.k = crate::cost::slack_adjusted_k(m.k, epsilon).min(m.n);
    hot_demand(&m, migrate)
}

/// Budget-constrained optimal changeover point: the cheapest `r` whose peak
/// expected tier-A occupancy `min(r, K)` fits within `hot_quota` residents.
///
/// The expected cost is convex in `ln r` in the interior regime, so the
/// constrained optimum is the unconstrained `r*` when its demand fits and
/// the boundary clamp `r = hot_quota` otherwise. `hot_quota = 0` degrades
/// the stream fully to tier B (equivalent to `AllB`). This is the fleet
/// arbiter's per-stream entry point.
pub fn optimal_r_budgeted(model: &CostModel, migrate: bool, hot_quota: u64) -> OptimalR {
    budget_clamp(model, migrate, optimal_r(model, migrate), hot_quota)
}

/// The clamp step of [`optimal_r_budgeted`], for callers that already hold
/// the unconstrained optimum (the arbiter computes it once per stream).
pub fn budget_clamp(
    model: &CostModel,
    migrate: bool,
    unconstrained: OptimalR,
    hot_quota: u64,
) -> OptimalR {
    if unconstrained.r.min(model.k) <= hot_quota {
        return unconstrained;
    }
    let r = hot_quota.min(model.n);
    let strategy = if migrate {
        Strategy::ChangeoverMigrate { r }
    } else {
        Strategy::Changeover { r }
    };
    OptimalR {
        r,
        frac: r as f64 / model.n as f64,
        cost: expected_cost(model, strategy).total(),
        interior: r > model.k && r < model.n,
    }
}

/// Closed-form optimal changeover cuts for an N-tier hierarchy (hot →
/// cold): each boundary's cut is the two-tier optimum between its
/// adjacent tiers, made nondecreasing by a running maximum (a document
/// never returns to a hotter tier later in the stream). For two tiers
/// this is exactly `optimal_r(...).r`. The engine's N-tier
/// [`crate::policy::PlacementPlan`] is built from these cuts.
pub fn optimal_cuts(
    tier_costs: &[crate::cost::PerDocCosts],
    n: u64,
    k: u64,
    include_rent: bool,
) -> Vec<u64> {
    optimal_cuts_family(tier_costs, n, k, include_rent, false)
}

/// [`optimal_cuts`] generalized over the strategy family: with
/// `migrate = true` each boundary's cut comes from the DO_MIGRATE closed
/// form (paper eq. 21 per adjacent pair — the rent-ratio form), the basis
/// of [`crate::policy::PlacementPlan::optimal_migrate`]. For two tiers
/// this is exactly `optimal_r(model, migrate).r`.
pub fn optimal_cuts_family(
    tier_costs: &[crate::cost::PerDocCosts],
    n: u64,
    k: u64,
    include_rent: bool,
    migrate: bool,
) -> Vec<u64> {
    assert!(tier_costs.len() >= 2, "need at least two tiers");
    let mut cuts = Vec::with_capacity(tier_costs.len() - 1);
    let mut floor = 0u64;
    for pair in tier_costs.windows(2) {
        let model = CostModel::new(n, k, pair[0], pair[1]).with_rent(include_rent);
        let r = optimal_r(&model, migrate).r.min(n);
        floor = floor.max(r);
        cuts.push(floor);
    }
    cuts
}

/// Compare all four strategies (AllA, AllB, changeover at r*, migrate at
/// r*) and return them sorted by expected total cost (cheapest first).
pub fn rank_strategies(model: &CostModel) -> Vec<(Strategy, f64)> {
    let no_mig = optimal_r(model, false);
    let mig = optimal_r(model, true);
    let mut out = vec![
        (Strategy::AllA, expected_cost(model, Strategy::AllA).total()),
        (Strategy::AllB, expected_cost(model, Strategy::AllB).total()),
        (Strategy::Changeover { r: no_mig.r }, no_mig.cost),
        (Strategy::ChangeoverMigrate { r: mig.r }, mig.cost),
    ];
    out.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::model::PerDocCosts;

    /// A model with a genuine interior optimum: A cheap to write, dear to
    /// read; B the reverse.
    fn interior_model() -> CostModel {
        CostModel::new(
            100_000,
            100,
            PerDocCosts { write: 1e-6, read: 1e-4, rent_window: 0.0 },
            PerDocCosts { write: 5e-5, read: 1e-6, rent_window: 0.0 },
        )
        .with_rent(false)
    }

    #[test]
    fn closed_form_matches_numeric_no_migration() {
        let m = interior_model();
        let cf = closed_form_frac_no_migration(&m).expect("interior optimum");
        let num = numeric_optimal_r(&m, false);
        assert!(num.interior);
        assert!(
            (cf - num.frac).abs() < 0.02,
            "closed-form {cf} vs numeric {}",
            num.frac
        );
    }

    #[test]
    fn closed_form_matches_numeric_migration() {
        let m = CostModel::new(
            100_000,
            100,
            PerDocCosts { write: 0.0, read: 0.0, rent_window: 7e-5 },
            PerDocCosts { write: 5e-6, read: 5e-6, rent_window: 5.4e-6 },
        );
        let cf = closed_form_frac_migration(&m).expect("interior optimum");
        let num = numeric_optimal_r(&m, true);
        assert!(num.interior);
        assert!(
            (cf - num.frac).abs() < 0.02,
            "closed-form {cf} vs numeric {}",
            num.frac
        );
    }

    #[test]
    fn optimum_beats_endpoints() {
        let m = interior_model();
        let opt = optimal_r(&m, false);
        let all_a = expected_cost(&m, Strategy::AllA).total();
        let all_b = expected_cost(&m, Strategy::AllB).total();
        assert!(opt.cost <= all_a && opt.cost <= all_b);
    }

    #[test]
    fn no_interior_when_one_tier_dominates() {
        // B strictly better everywhere → no interior optimum, AllB wins.
        let m = CostModel::new(
            10_000,
            10,
            PerDocCosts { write: 2.0, read: 2.0, rent_window: 0.0 },
            PerDocCosts { write: 1.0, read: 1.0, rent_window: 0.0 },
        )
        .with_rent(false);
        assert!(closed_form_frac_no_migration(&m).is_none());
        let ranked = rank_strategies(&m);
        // cheapest is AllB or a degenerate changeover equal to it
        let best_cost = ranked[0].1;
        let all_b = expected_cost(&m, Strategy::AllB).total();
        assert!((best_cost - all_b).abs() / all_b < 0.01);
    }

    #[test]
    fn validity_condition_eq22() {
        // closed-form frac < K/N → not interior; optimal_r falls back
        let m = CostModel::new(
            1_000,
            500, // huge K
            PerDocCosts { write: 1e-6, read: 1e-4, rent_window: 0.0 },
            PerDocCosts { write: 2e-6, read: 1e-6, rent_window: 0.0 },
        )
        .with_rent(false);
        let opt = optimal_r(&m, false);
        assert!(opt.r >= 1 && opt.r <= 1000);
    }

    #[test]
    fn rank_strategies_sorted() {
        let m = interior_model();
        let ranked = rank_strategies(&m);
        for w in ranked.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        assert_eq!(ranked.len(), 4);
    }

    #[test]
    fn budgeted_equals_unconstrained_when_quota_ample() {
        let m = interior_model();
        let unc = optimal_r(&m, false);
        let b = optimal_r_budgeted(&m, false, m.k); // quota = K always fits
        assert_eq!(b.r, unc.r);
        assert_eq!(b.cost, unc.cost);
    }

    #[test]
    fn budgeted_clamps_to_quota_under_pressure() {
        let m = interior_model(); // K = 100, r* interior ≫ K
        let quota = 10u64;
        let b = optimal_r_budgeted(&m, false, quota);
        assert_eq!(b.r, quota);
        assert!(!b.interior);
        let unc = optimal_r(&m, false);
        assert!(b.cost >= unc.cost, "constraint cannot reduce cost");
        // convexity: the clamp beats any smaller feasible r
        for r in [0u64, 1, 5] {
            let c = expected_cost(&m, Strategy::Changeover { r }).total();
            assert!(b.cost <= c + 1e-9, "r={r}: {c} < clamp {}", b.cost);
        }
    }

    #[test]
    fn budgeted_zero_quota_is_all_b() {
        let m = interior_model();
        let b = optimal_r_budgeted(&m, false, 0);
        assert_eq!(b.r, 0);
        let all_b = expected_cost(&m, Strategy::AllB).total();
        assert!((b.cost - all_b).abs() < 1e-9);
    }

    #[test]
    fn hot_demand_is_min_rstar_k() {
        let m = interior_model();
        let unc = optimal_r(&m, false);
        assert_eq!(hot_demand(&m, false), unc.r.min(m.k));
    }

    #[test]
    fn optimal_cuts_degenerates_and_is_monotone() {
        let m = interior_model();
        let cuts = optimal_cuts(&[m.a, m.b], m.n, m.k, false);
        assert_eq!(cuts, vec![optimal_r(&m, false).r]);
        // three tiers: nondecreasing cuts within [0, n]
        let warm = PerDocCosts { write: 2e-5, read: 3e-5, rent_window: 0.0 };
        let cuts3 = optimal_cuts(&[m.a, warm, m.b], m.n, m.k, false);
        assert_eq!(cuts3.len(), 2);
        assert!(cuts3[0] <= cuts3[1]);
        assert!(cuts3[1] <= m.n);
    }

    #[test]
    fn grid_cross_check_full_surface() {
        // dense grid over r confirms golden-section result (unimodality)
        let m = interior_model();
        let num = numeric_optimal_r(&m, false);
        let mut best = f64::INFINITY;
        let mut best_r = 0u64;
        let mut r = 101u64;
        while r < 100_000 {
            let c = expected_cost(&m, Strategy::Changeover { r }).total();
            if c < best {
                best = c;
                best_r = r;
            }
            r = (r as f64 * 1.05) as u64 + 1;
        }
        assert!(
            (num.cost - best).abs() / best < 1e-3,
            "numeric {} vs grid {} (r {} vs {})",
            num.cost,
            best,
            num.r,
            best_r
        );
    }
}
