//! Core cost-model types.
//!
//! The paper (§IV, §VII) models read and write costs independently for two
//! storage tiers, for a producer and a consumer that may be separated by a
//! costly communication channel. All costs here are reduced to *effective
//! per-document* costs — transaction cost plus any channel cost incurred by
//! the hop — plus a per-document *rental* cost for occupying the tier for
//! the whole stream window.

use std::fmt;

/// Where an actor or a tier lives. Crossing locations incurs the channel
/// charge (per GB) in addition to the tier's transaction cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Location {
    Producer,
    Consumer,
}

/// Raw price book of one storage tier, in the units cloud providers quote.
#[derive(Debug, Clone, PartialEq)]
pub struct TierPricing {
    /// Human-readable name, e.g. "S3 Standard (EU-Ireland)".
    pub name: String,
    /// $ per write transaction (PUT), per document.
    pub put_per_doc: f64,
    /// $ per read transaction (GET), per document.
    pub get_per_doc: f64,
    /// $ per GB·month of occupancy.
    pub storage_gb_month: f64,
    /// $ per GB transferred *into* the tier (ingress).
    pub ingress_gb: f64,
    /// $ per GB transferred *out of* the tier (egress).
    pub egress_gb: f64,
    /// Which side of the channel the tier is on.
    pub location: Location,
}

/// The workload's document geometry (paper Tables I & II headers).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DocSpec {
    /// Document size in (decimal) GB.
    pub size_gb: f64,
    /// Stream window duration, in 30-day months (the paper's billing month).
    pub window_months: f64,
}

impl DocSpec {
    pub fn new(size_gb: f64, window_months: f64) -> Self {
        assert!(size_gb >= 0.0 && window_months >= 0.0);
        Self { size_gb, window_months }
    }

    /// Convenience: document size given in MB (decimal), window in days.
    pub fn from_mb_days(size_mb: f64, window_days: f64) -> Self {
        Self::new(size_mb / 1000.0, window_days / 30.0)
    }
}

/// Effective per-document costs for one tier under one workload, with all
/// channel charges folded in. This is the quantity the closed forms
/// (eqs. 14–21) operate on.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PerDocCosts {
    /// $ to write one document into the tier (from the producer).
    pub write: f64,
    /// $ for the consumer to read one document from the tier.
    pub read: f64,
    /// $ to keep one document resident for the *full* stream window.
    pub rent_window: f64,
}

/// The channel between producer and consumer locations.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Channel {
    /// $ per GB for any document crossing producer↔consumer, either way.
    /// The paper's Case Study 1 charges 0.087 $/GB (Azure egress list price)
    /// for the inter-cloud hop.
    pub cost_gb: f64,
}

impl Channel {
    pub fn free() -> Self {
        Self { cost_gb: 0.0 }
    }
}

impl TierPricing {
    /// Reduce the price book to effective per-document costs for `doc`,
    /// given the channel. Writes originate at the producer; the final read
    /// is issued by the consumer.
    pub fn per_doc(&self, doc: DocSpec, channel: Channel) -> PerDocCosts {
        let cross_write = self.location == Location::Consumer;
        let cross_read = self.location == Location::Producer;
        let write = self.put_per_doc
            + doc.size_gb * (self.ingress_gb + if cross_write { channel.cost_gb } else { 0.0 });
        let read = self.get_per_doc
            + doc.size_gb * (self.egress_gb + if cross_read { channel.cost_gb } else { 0.0 });
        let rent_window = doc.size_gb * self.storage_gb_month * doc.window_months;
        PerDocCosts { write, read, rent_window }
    }
}

/// A fully-specified two-tier placement problem: the inputs to every
/// strategy evaluation and optimizer in this crate.
///
/// Tier `A` receives the first `r` documents ("near"/early tier), tier `B`
/// the rest — the naming of paper Algorithm C (Fig. 3).
#[derive(Debug, Clone, PartialEq)]
pub struct CostModel {
    /// Stream length.
    pub n: u64,
    /// Retained set size (top-K).
    pub k: u64,
    /// Effective per-doc costs of tier A.
    pub a: PerDocCosts,
    /// Effective per-doc costs of tier B.
    pub b: PerDocCosts,
    /// Whether rental costs are included in strategy totals. The paper's
    /// Case Study 1 is transaction-dominated and excludes rent (uses a
    /// bound); Case Study 2 includes it.
    pub include_rent: bool,
}

impl CostModel {
    pub fn new(n: u64, k: u64, a: PerDocCosts, b: PerDocCosts) -> Self {
        assert!(n > 0, "stream length must be positive");
        assert!(k > 0 && k <= n, "require 0 < K <= N (got K={k}, N={n})");
        Self { n, k, a, b, include_rent: true }
    }

    pub fn with_rent(mut self, include: bool) -> Self {
        self.include_rent = include;
        self
    }

    /// Per-doc costs of the tier holding a given stream index under the
    /// changeover rule "first r to A".
    pub fn tier_for(&self, index: u64, r: u64) -> &PerDocCosts {
        if index < r {
            &self.a
        } else {
            &self.b
        }
    }
}

/// A placement strategy from the paper (§VII) plus the degenerate
/// single-tier baselines of Tables I–II.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Strategy {
    /// Everything to tier A.
    AllA,
    /// Everything to tier B.
    AllB,
    /// First `r` documents to A, the rest to B; no migration
    /// (DO_MIGRATE = false). Paper eq. (14)–(17).
    Changeover { r: u64 },
    /// First `r` to A; at `i == r` migrate all residents A→B, then write
    /// the rest to B (DO_MIGRATE = true). Paper eq. (18)–(21).
    ChangeoverMigrate { r: u64 },
}

impl Strategy {
    pub fn label(&self) -> String {
        match self {
            Strategy::AllA => "all-A".into(),
            Strategy::AllB => "all-B".into(),
            Strategy::Changeover { r } => format!("changeover(r={r})"),
            Strategy::ChangeoverMigrate { r } => format!("changeover+migrate(r={r})"),
        }
    }
}

/// Itemized expected cost of a strategy. `total()` is eq. (16)/(20).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct CostBreakdown {
    /// Expected $ of writes landing in tier A.
    pub writes_a: f64,
    /// Expected $ of writes landing in tier B.
    pub writes_b: f64,
    /// Expected $ of the final top-K read.
    pub reads: f64,
    /// Expected $ of rental over the window (0 when `include_rent=false`).
    pub rent: f64,
    /// $ of the bulk migration (0 unless `ChangeoverMigrate`).
    pub migration: f64,
}

impl CostBreakdown {
    pub fn total(&self) -> f64 {
        self.writes_a + self.writes_b + self.reads + self.rent + self.migration
    }
}

impl fmt::Display for CostBreakdown {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "total={:.2} (writesA={:.2} writesB={:.2} reads={:.2} rent={:.2} migration={:.2})",
            self.total(),
            self.writes_a,
            self.writes_b,
            self.reads,
            self.rent,
            self.migration
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tier(loc: Location) -> TierPricing {
        TierPricing {
            name: "t".into(),
            put_per_doc: 1e-6,
            get_per_doc: 2e-6,
            storage_gb_month: 0.02,
            ingress_gb: 0.0,
            egress_gb: 0.01,
            location: loc,
        }
    }

    #[test]
    fn per_doc_folds_channel_on_cross_hops() {
        let doc = DocSpec::from_mb_days(1.0, 30.0); // 1e-3 GB, 1 month
        let ch = Channel { cost_gb: 0.1 };
        // consumer-local tier: writes cross, reads do not.
        let c = tier(Location::Consumer).per_doc(doc, ch);
        assert!((c.write - (1e-6 + 1e-3 * 0.1)).abs() < 1e-15);
        assert!((c.read - (2e-6 + 1e-3 * 0.01)).abs() < 1e-15);
        // producer-local tier: reads cross, writes do not.
        let p = tier(Location::Producer).per_doc(doc, ch);
        assert!((p.write - 1e-6).abs() < 1e-15);
        assert!((p.read - (2e-6 + 1e-3 * (0.01 + 0.1))).abs() < 1e-15);
        // rent: size * price * months
        assert!((p.rent_window - 1e-3 * 0.02).abs() < 1e-15);
    }

    #[test]
    fn doc_spec_conversions() {
        let d = DocSpec::from_mb_days(0.1, 1.0);
        assert!((d.size_gb - 1e-4).abs() < 1e-18);
        assert!((d.window_months - 1.0 / 30.0).abs() < 1e-15);
    }

    #[test]
    #[should_panic]
    fn model_rejects_k_zero() {
        let pd = PerDocCosts { write: 0.0, read: 0.0, rent_window: 0.0 };
        CostModel::new(10, 0, pd, pd);
    }

    #[test]
    fn tier_for_changeover_boundary() {
        let pd_a = PerDocCosts { write: 1.0, read: 0.0, rent_window: 0.0 };
        let pd_b = PerDocCosts { write: 2.0, read: 0.0, rent_window: 0.0 };
        let m = CostModel::new(10, 1, pd_a, pd_b);
        assert_eq!(m.tier_for(4, 5).write, 1.0);
        assert_eq!(m.tier_for(5, 5).write, 2.0);
    }

    #[test]
    fn breakdown_total_sums() {
        let b = CostBreakdown {
            writes_a: 1.0,
            writes_b: 2.0,
            reads: 3.0,
            rent: 4.0,
            migration: 5.0,
        };
        assert_eq!(b.total(), 15.0);
    }
}
