//! Closed-form expected costs for the top-K record process (paper §VI–VII).
//!
//! Under the random-order assumption, document `i` (0-indexed) enters the
//! top-K at observation time with probability 1 for `i < K` and `K/(i+1)`
//! otherwise (eqs. 9–10). The expected cumulative number of writes after
//! observing documents `0..i` is therefore an exact harmonic sum
//! (eqs. 11–12); everything below is built from it.

use crate::cost::model::{CostBreakdown, CostModel, Strategy};
use crate::util::math::{harmonic, harmonic_diff};

/// `P(document i enters the top-K when observed)` — eqs. (9)–(10),
/// 0-indexed as in the paper's eq. (5).
pub fn p_write(i: u64, k: u64) -> f64 {
    if i < k {
        1.0
    } else {
        k as f64 / (i + 1) as f64
    }
}

/// Expected number of writes among documents `0..count` (i.e. after `count`
/// documents have been observed) — exact form of eqs. (11)–(12):
/// `count` if `count <= K`, else `K + K·(H_count − H_K)`.
pub fn expected_writes(count: u64, k: u64) -> f64 {
    if count <= k {
        count as f64
    } else {
        k as f64 + k as f64 * harmonic_diff(k, count)
    }
}

/// Selector admission slack (ADR-010): the multiplicative envelope a
/// near-optimal (memory-bounded) selector puts on the exact write rate.
/// A selector whose admit-count overshoots the exact top-K process by a
/// relative ε admits — and therefore writes — at most `(1 + ε)×` the
/// eqs. (11)–(12) expectation, so hot-tier demand and rent integrals must
/// be priced against the inflated rate. Negative inputs clamp to the
/// exact process (ε = 0).
pub fn selector_slack(epsilon: f64) -> f64 {
    1.0 + epsilon.max(0.0)
}

/// Effective retained-set size under selector slack: the admission
/// process of a near-optimal selector with overshoot ε behaves like the
/// exact process run at `K' = K + ⌈ε·K⌉` (its threshold lags the true
/// K-th best by the sketch resolution). Feeding `K'` through the eq. (12)
/// closed forms prices both the extra writes and the wider hot band.
pub fn slack_adjusted_k(k: u64, epsilon: f64) -> u64 {
    k + (k as f64 * epsilon.max(0.0)).ceil() as u64
}

/// Slack-inflated per-tier demand: a selector with overshoot ε places up
/// to `⌈(1 + ε)·demand⌉` documents where the exact selector would place
/// `demand`. Admission control and capacity heuristics must reserve the
/// inflated figure or a logmem fleet systematically over-admits.
pub fn slack_adjusted_demand(demand: u64, epsilon: f64) -> u64 {
    demand + (demand as f64 * epsilon.max(0.0)).ceil() as u64
}

/// Expected writes under selector slack: eqs. (11)–(12) evaluated at the
/// slack-adjusted K (see [`slack_adjusted_k`]).
pub fn expected_writes_with_slack(count: u64, k: u64, epsilon: f64) -> f64 {
    expected_writes(count, slack_adjusted_k(k, epsilon))
}

/// The paper's *printed* approximation of eq. (12), `K + K·ln(i+1)`,
/// kept for the errata comparison in EXPERIMENTS.md (it overestimates by
/// `K·H_K ≈ K·ln K`; see DESIGN.md §5).
pub fn expected_writes_paper_eq12(count: u64, k: u64) -> f64 {
    if count <= k {
        count as f64
    } else {
        k as f64 + k as f64 * (count as f64).ln()
    }
}

/// Expected number of writes for Algorithm B (K = 1, one tier):
/// the harmonic number `H_N` — eq. (6), approximated by eq. (7).
pub fn algorithm_b_expected_writes(n: u64) -> f64 {
    harmonic(n)
}

/// Probability that a document surviving to the final read was written while
/// index `< r` — the i.u.d.-over-the-stream assumption behind eq. (15).
pub fn p_survivor_in_a(r: u64, n: u64) -> f64 {
    (r.min(n)) as f64 / n as f64
}

/// Expected occupancy of tier A at observation time `t` (documents of the
/// current top-K written before `r`), under the same i.u.d. approximation:
/// `K·min(1, r/t)` (for `t ≥ K`). Used for the exact-rent variant of the
/// no-migration strategy; the paper instead bounds rent by the dearer tier.
pub fn expected_occupancy_a(t: u64, r: u64, k: u64) -> f64 {
    if t == 0 {
        return 0.0;
    }
    let frac = (r as f64 / t as f64).min(1.0);
    (k.min(t)) as f64 * frac
}

/// Expected cost breakdown of a strategy — eqs. (13)–(16) and (18)–(20),
/// with exact harmonic sums instead of the log approximations.
///
/// Conventions (see DESIGN.md §5 for the sign errata):
/// - writes to A: `W(r)`, writes to B: `W(N) − W(r)`, where
///   `W(c) = expected_writes(c, K)`.
/// - no-migration reads: a surviving doc is read from A w.p. `r/N`
///   (paper eq. (15) swaps the labels; this is the consistent form).
/// - no-migration rent (when `include_rent`): integrated expected occupancy
///   `∫ occupancy · rent/window dt`, a refinement of the paper's
///   constant upper bound (`rent_bound_no_migration` reproduces the bound).
/// - migration at `i = r`: K residents each pay `read_A + write_B`
///   (eq. 19); rent splits linearly at `r/N` (eq. 18); final read from B.
pub fn expected_cost(model: &CostModel, strategy: Strategy) -> CostBreakdown {
    let n = model.n;
    let k = model.k;
    let kf = k as f64;
    match strategy {
        Strategy::AllA => {
            let writes = expected_writes(n, k);
            CostBreakdown {
                writes_a: writes * model.a.write,
                writes_b: 0.0,
                reads: kf * model.a.read,
                rent: if model.include_rent { kf * model.a.rent_window } else { 0.0 },
                migration: 0.0,
            }
        }
        Strategy::AllB => {
            let writes = expected_writes(n, k);
            CostBreakdown {
                writes_a: 0.0,
                writes_b: writes * model.b.write,
                reads: kf * model.b.read,
                rent: if model.include_rent { kf * model.b.rent_window } else { 0.0 },
                migration: 0.0,
            }
        }
        Strategy::Changeover { r } => {
            let r = r.min(n);
            let w_a = expected_writes(r, k);
            let w_b = expected_writes(n, k) - w_a;
            let p_a = p_survivor_in_a(r, n);
            let reads = kf * (p_a * model.a.read + (1.0 - p_a) * model.b.read);
            let rent = if model.include_rent {
                expected_rent_no_migration(model, r)
            } else {
                0.0
            };
            CostBreakdown {
                writes_a: w_a * model.a.write,
                writes_b: w_b * model.b.write,
                reads,
                rent,
                migration: 0.0,
            }
        }
        Strategy::ChangeoverMigrate { r } => {
            let r = r.min(n);
            let w_a = expected_writes(r, k);
            let w_b = expected_writes(n, k) - w_a;
            let frac = r as f64 / n as f64;
            // Everything lives in B after i=r, so the final read is from B.
            let reads = kf * model.b.read;
            let rent = if model.include_rent {
                kf * (frac * model.a.rent_window + (1.0 - frac) * model.b.rent_window)
            } else {
                0.0
            };
            // K residents migrate (bounded by how many exist at i=r).
            let residents = k.min(r) as f64;
            let migration = residents * (model.a.read + model.b.write);
            CostBreakdown {
                writes_a: w_a * model.a.write,
                writes_b: w_b * model.b.write,
                reads,
                rent,
                migration,
            }
        }
    }
}

/// The paper's rent *bound* for the no-migration strategy: all K docs pay
/// the dearer tier for the whole window (constant in `r`, §VII).
pub fn rent_bound_no_migration(model: &CostModel) -> f64 {
    model.k as f64 * model.a.rent_window.max(model.b.rent_window)
}

/// Exact-ish expected rent without migration: integrate expected occupancy
/// of each tier over the stream. Documents pay rent from their write until
/// overwritten or end-of-window; equivalently, at each instant `t` the K
/// resident documents split between tiers as `expected_occupancy_a(t,r,K)`.
/// The stream is mapped linearly onto the window.
pub fn expected_rent_no_migration(model: &CostModel, r: u64) -> f64 {
    let n = model.n;
    let k = model.k as f64;
    let r = r.min(n);
    // ∫_0^N occA(t) dt / N, piecewise:
    //   t in (0, r): occA = min(t,K)  (all residents are in A)
    //   t in (r, N): occA = K·r/t    (i.u.d. thinning)
    // Using continuous approximations of the sums (error O(1/N)).
    let (nf, rf) = (n as f64, r as f64);
    let occ_a_time = if r == 0 {
        0.0
    } else {
        // ∫_0^min(K,r) t dt + ∫_min(K,r)^r K dt  (fill-up phase)
        let kk = k.min(rf);
        let fill = 0.5 * kk * kk + k * (rf - kk).max(0.0);
        // ∫_r^N K·r/t dt = K·r·ln(N/r)
        let tail = if n > r { k * rf * (nf / rf).ln() } else { 0.0 };
        (fill + tail) / nf
    };
    // total resident doc-time: same integral with occ = min(t, K)
    let kk = k.min(nf);
    let occ_total_time = (0.5 * kk * kk + k * (nf - kk).max(0.0)) / nf;
    let occ_b_time = (occ_total_time - occ_a_time).max(0.0);
    // doc-time is in units of "fraction of window × documents"
    occ_a_time * model.a.rent_window + occ_b_time * model.b.rent_window
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::model::PerDocCosts;
    use crate::util::math::EULER_MASCHERONI;

    fn model(n: u64, k: u64) -> CostModel {
        CostModel::new(
            n,
            k,
            PerDocCosts { write: 2.0, read: 5.0, rent_window: 0.1 },
            PerDocCosts { write: 3.0, read: 7.0, rent_window: 0.2 },
        )
    }

    #[test]
    fn p_write_matches_eqs_9_10() {
        assert_eq!(p_write(0, 3), 1.0);
        assert_eq!(p_write(2, 3), 1.0);
        assert!((p_write(3, 3) - 3.0 / 4.0).abs() < 1e-15);
        assert!((p_write(99, 3) - 0.03).abs() < 1e-15);
    }

    #[test]
    fn expected_writes_is_sum_of_p_write() {
        for (n, k) in [(1u64, 1u64), (10, 1), (10, 3), (100, 7), (1000, 100)] {
            let direct: f64 = (0..n).map(|i| p_write(i, k)).sum();
            assert!(
                (expected_writes(n, k) - direct).abs() < 1e-9,
                "n={n} k={k}"
            );
        }
    }

    #[test]
    fn algorithm_b_matches_eq7() {
        // E[#writes] = H_N ≈ ln N + γ (paper eq. 7)
        let n = 100_000u64;
        let e = algorithm_b_expected_writes(n);
        assert!((e - ((n as f64).ln() + EULER_MASCHERONI)).abs() < 1e-4);
    }

    #[test]
    fn writes_split_adds_up() {
        let m = model(1000, 10);
        for r in [10u64, 100, 500, 999] {
            let c = expected_cost(&m, Strategy::Changeover { r });
            let total_writes = c.writes_a / m.a.write + c.writes_b / m.b.write;
            assert!(
                (total_writes - expected_writes(1000, 10)).abs() < 1e-9,
                "r={r}"
            );
        }
    }

    #[test]
    fn changeover_extremes_match_single_tier() {
        let m = model(1000, 10).with_rent(false);
        let all_a = expected_cost(&m, Strategy::AllA);
        let c_n = expected_cost(&m, Strategy::Changeover { r: 1000 });
        assert!((all_a.total() - c_n.total()).abs() < 1e-9);
        let all_b = expected_cost(&m, Strategy::AllB);
        let c_0 = expected_cost(&m, Strategy::Changeover { r: 0 });
        assert!((all_b.total() - c_0.total()).abs() < 1e-9);
    }

    #[test]
    fn migration_cost_is_k_residents() {
        let m = model(1000, 10);
        let c = expected_cost(&m, Strategy::ChangeoverMigrate { r: 500 });
        assert!((c.migration - 10.0 * (5.0 + 3.0)).abs() < 1e-12);
        // with r < K only r residents exist
        let c2 = expected_cost(&m, Strategy::ChangeoverMigrate { r: 4 });
        assert!((c2.migration - 4.0 * (5.0 + 3.0)).abs() < 1e-12);
    }

    #[test]
    fn migrate_reads_always_from_b() {
        let m = model(1000, 10).with_rent(false);
        let c = expected_cost(&m, Strategy::ChangeoverMigrate { r: 500 });
        assert!((c.reads - 10.0 * 7.0).abs() < 1e-12);
    }

    #[test]
    fn rent_bound_dominates_exact_rent() {
        let m = model(10_000, 50);
        for r in [100u64, 1000, 5000, 9999] {
            let exact = expected_rent_no_migration(&m, r);
            assert!(exact <= rent_bound_no_migration(&m) + 1e-9, "r={r}");
            assert!(exact >= 0.0);
        }
    }

    #[test]
    fn rent_monotone_in_tier_prices() {
        // all-in-A rent should be ~K * rentA for large N (fill-up negligible)
        let m = model(1_000_000, 100);
        let rent_all_a = expected_rent_no_migration(&m, 1_000_000);
        assert!((rent_all_a - 100.0 * 0.1).abs() / (100.0 * 0.1) < 0.01);
        let rent_all_b = expected_rent_no_migration(&m, 0);
        assert!((rent_all_b - 100.0 * 0.2).abs() / (100.0 * 0.2) < 0.01);
    }

    #[test]
    fn paper_eq12_overestimates_by_k_harmonic_k() {
        // documented erratum: printed eq. (12) = exact + K·H_K
        let (n, k) = (100_000u64, 100u64);
        let exact = expected_writes(n, k);
        let printed = expected_writes_paper_eq12(n, k);
        // gap = K·(H_K − γ) − O(K/n): the printed form replaces
        // K·(H_n − H_K) with K·ln n, i.e. drops −K·H_K and adds
        // K·(ln n − H_n) ≈ −K·γ.
        let gap = printed - exact;
        let expect = k as f64 * (harmonic(k) - crate::util::math::EULER_MASCHERONI);
        assert!(
            (gap - expect).abs() < k as f64 * 1e-3,
            "gap={gap} expect={expect}"
        );
    }

    #[test]
    fn selector_slack_is_a_clamped_multiplier() {
        assert_eq!(selector_slack(0.0), 1.0);
        assert_eq!(selector_slack(-0.3), 1.0);
        assert!((selector_slack(0.1) - 1.1).abs() < 1e-15);
        assert_eq!(slack_adjusted_k(100, 0.0), 100);
        assert_eq!(slack_adjusted_k(100, 0.08), 108);
        assert_eq!(slack_adjusted_demand(50, 0.0), 50);
        assert_eq!(slack_adjusted_demand(50, 0.1), 55);
        assert_eq!(slack_adjusted_demand(0, 0.5), 0);
    }

    #[test]
    fn slack_inflates_expected_writes_monotonically() {
        let (n, k) = (10_000u64, 100u64);
        let exact = expected_writes(n, k);
        let slacked = expected_writes_with_slack(n, k, 0.1);
        assert!(slacked > exact, "{slacked} <= {exact}");
        // and the inflation stays within the naive (1+ε) envelope on the
        // write count (K' log-term grows sublinearly in K')
        assert!(slacked <= selector_slack(0.1) * exact * 1.001);
        // zero slack is exactly the exact process
        assert_eq!(expected_writes_with_slack(n, k, 0.0), exact);
    }

    #[test]
    fn survivor_probability_clamps() {
        assert_eq!(p_survivor_in_a(2000, 1000), 1.0);
        assert_eq!(p_survivor_in_a(0, 1000), 0.0);
        assert!((p_survivor_in_a(250, 1000) - 0.25).abs() < 1e-15);
    }
}
