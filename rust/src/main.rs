//! `shptier` — CLI launcher for the SHP tier-placement framework.
//!
//! Subcommands:
//!   run [--config <path>]        run the streaming pipeline from a TOML config
//!   fleet [--streams M] [...]    run M concurrent top-K streams over shared tiers
//!   engine [--tiers 3] [...]     N-tier engine demo with online re-arbitration
//!                                (--backend fs:<root> | obj:<root> for the
//!                                 durable backends, --reconcile for
//!                                 sim-vs-durable ledger parity)
//!   exp --id <id> [--quick]      regenerate a paper table/figure (see DESIGN.md §4)
//!   serve --config <toml>        multi-tenant HTTP placement server (ADR-006)
//!   serve-soak [--kill]          concurrency + crash-recovery soak against serve
//!   optimize [--preset <p>]      print r* and the strategy ranking for an economy
//!   validate [--quick]           Monte-Carlo validation suite (E1, E2, A2)
//!   sizing                       the §VIII sweep-sizing table
//!
//! Argument parsing is hand-rolled: the vendored crate set has no clap.

use anyhow::{bail, Context, Result};
use shptier::config::{EngineDemoConfig, FleetLaunchConfig, LaunchConfig, ScorerKind};
use shptier::cost::{case_study_1, case_study_2, expected_cost, rank_strategies};
use shptier::exp;
use shptier::pipeline::{native_scorer_factory, pjrt_scorer_factory, run_pipeline};
use shptier::report::Table;
use shptier::runtime::Manifest;
use shptier::ssa::SweepGrid;
use std::collections::HashMap;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Whether a CLI token is a flag. Only `--`-prefixed tokens whose
/// remainder is *not* a number count: negative numbers (`-1`, `-2.5`, even
/// a stray `--3`) are always values, so `shptier foo --offset -1` binds
/// `-1` to `offset` instead of misparsing it as the next flag. The numeric
/// exception requires a digit/sign/dot lead-in so that word-shaped flags
/// the f64 parser would accept (`--inf`, `--nan`) still parse as flags.
fn is_flag_token(tok: &str) -> bool {
    match tok.strip_prefix("--") {
        Some("") | None => false,
        Some(rest) => {
            let numeric_looking = rest
                .starts_with(|c: char| c.is_ascii_digit() || c == '.' || c == '-' || c == '+');
            !(numeric_looking && rest.parse::<f64>().is_ok())
        }
    }
}

/// Parse `--key value` / `--flag` style args after the subcommand.
fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if !is_flag_token(a) {
            bail!("unexpected argument '{a}' (expected --key [value])");
        }
        let key = a.strip_prefix("--").expect("flag tokens start with --");
        let takes_value = i + 1 < args.len() && !is_flag_token(&args[i + 1]);
        if takes_value {
            out.insert(key.to_string(), args[i + 1].clone());
            i += 2;
        } else {
            out.insert(key.to_string(), "true".to_string());
            i += 1;
        }
    }
    Ok(out)
}

fn real_main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = parse_flags(&args[1..])?;
    let seed: u64 = flags
        .get("seed")
        .map(|s| s.parse().context("--seed must be an integer"))
        .transpose()?
        .unwrap_or(20190412);
    let quick = flags.contains_key("quick");

    match cmd.as_str() {
        "run" => cmd_run(&flags),
        "fleet" => cmd_fleet(&flags, seed),
        "engine" => cmd_engine(&flags, seed),
        "exp" => {
            let id = flags.get("id").map(String::as_str).unwrap_or("all");
            exp::run(id, seed, quick)
        }
        "serve" => cmd_serve(&flags),
        "serve-soak" => cmd_serve_soak(&flags),
        "optimize" => cmd_optimize(&flags),
        "validate" => {
            exp::run("shp-classic", seed, quick)?;
            exp::run("alg-b", seed, quick)?;
            exp::run("ablation-ordering", seed, quick)?;
            Ok(())
        }
        "sizing" => exp::run("sweep-sizing", seed, quick),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `shptier help`)"),
    }
}

fn cmd_run(flags: &HashMap<String, String>) -> Result<()> {
    let config = match flags.get("config") {
        Some(path) => LaunchConfig::from_file(std::path::Path::new(path))?,
        None => {
            eprintln!("no --config given; using built-in defaults (case-study-2, 10k docs)");
            LaunchConfig::from_toml("")?
        }
    };
    let grid = SweepGrid {
        dims: shptier::ssa::oscillator_sweep(config.sweep_values_per_dim, 1).dims,
        samples_per_point: config.sweep_samples_per_point,
    };
    let artifacts = Manifest::default_dir();
    let factory = match config.scorer {
        ScorerKind::Pjrt => pjrt_scorer_factory(artifacts),
        ScorerKind::Native | ScorerKind::Auto => native_scorer_factory(artifacts),
    };
    let mut policy = config.policy.instantiate(&config.model);
    println!(
        "launching pipeline: {} docs, K={}, policy={}, scorer={:?}",
        config.pipeline.n_docs,
        config.model.k,
        policy.name(),
        config.scorer
    );
    let report = run_pipeline(
        &config.pipeline,
        &grid,
        &config.model,
        policy.as_mut(),
        factory,
    )?;
    println!("{}", report.summary());

    // measured vs analytic reconciliation
    let strat = match config.policy {
        shptier::config::PolicySpec::AllA => shptier::cost::Strategy::AllA,
        shptier::config::PolicySpec::AllB => shptier::cost::Strategy::AllB,
        shptier::config::PolicySpec::Changeover { r } => {
            shptier::cost::Strategy::Changeover { r }
        }
        shptier::config::PolicySpec::ChangeoverMigrate { r } => {
            shptier::cost::Strategy::ChangeoverMigrate { r }
        }
        _ => {
            println!("(reactive policy: no closed-form analytic comparison)");
            return Ok(());
        }
    };
    let analytic = expected_cost(&config.model, strat).total();
    let measured = report.run.total_cost();
    println!(
        "analytic expectation ${analytic:.4} | measured ${measured:.4} | Δ {:+.1}%",
        (measured / analytic - 1.0) * 100.0
    );
    Ok(())
}

/// `shptier fleet` — run M concurrent top-K streams over shared
/// capacity-limited tiers, printing the arbitration plan and the
/// per-stream reconciliation.
fn cmd_fleet(flags: &HashMap<String, String>, seed: u64) -> Result<()> {
    let mut launch = match flags.get("config") {
        Some(path) => FleetLaunchConfig::from_file(std::path::Path::new(path))?,
        None => FleetLaunchConfig::from_toml("")?,
    };
    // flag overrides (flags win over the config file)
    let parse_u64 = |key: &str| -> Result<Option<u64>> {
        flags
            .get(key)
            .map(|s| s.parse::<u64>().with_context(|| format!("--{key} must be an integer")))
            .transpose()
    };
    if flags.contains_key("seed") {
        launch.config.seed = seed;
    }
    // selector before the workload rebuild: the default-capacity
    // heuristic below must price the selector's admission slack
    if let Some(sel) = flags.get("selector") {
        launch.config.selector = shptier::topk::SelectorKind::parse(sel)?;
    }
    let streams_flag = parse_u64("streams")?;
    let docs_flag = parse_u64("docs")?;
    let k_flag = parse_u64("k")?;
    if streams_flag.is_some() || docs_flag.is_some() || k_flag.is_some() {
        // any workload flag rebuilds the demo fleet; unspecified dimensions
        // keep their defaults
        let m = streams_flag.unwrap_or(launch.specs.len() as u64).max(1);
        let n = docs_flag.unwrap_or(2_000).max(1);
        let k = k_flag.unwrap_or(32).max(1);
        launch.specs =
            shptier::fleet::demo_fleet(m as usize, n, k, true, launch.config.seed);
        if !flags.contains_key("capacity") {
            // re-derive the default contended capacity for the new fleet,
            // reserving the selector's admission slack (ADR-010)
            let demand: u64 = launch
                .specs
                .iter()
                .map(|s| {
                    let eps = launch.config.selector.slack(s.model.k);
                    shptier::cost::hot_demand_with_slack(&s.model, false, eps)
                })
                .sum();
            launch.config.hot_capacity = (demand / 2).max(1);
        }
    }
    if let Some(c) = parse_u64("capacity")? {
        launch.config.hot_capacity = c;
    }
    if let Some(w) = parse_u64("workers")? {
        launch.config.workers = w.max(1) as usize;
    }
    if let Some(mode) = flags.get("mode") {
        launch.config.mode = match mode.as_str() {
            "arbitrated" => shptier::fleet::FleetMode::Arbitrated,
            "naive" => shptier::fleet::FleetMode::Naive,
            other => bail!("unknown fleet mode '{other}' (arbitrated | naive)"),
        };
    }
    if let Some(family) = flags.get("family") {
        launch.config.family = shptier::policy::PlanFamily::parse(family)?;
    }
    if let Some(backend) = flags.get("backend") {
        launch.config.backend = shptier::engine::BackendSpec::parse(backend)?;
    }
    if flags.contains_key("adaptive") {
        launch.config.adaptive = true;
    }
    if flags.contains_key("group-commit") {
        launch.config.group_commit = true;
    }

    println!(
        "launching fleet: {} streams, hot capacity {}, {} workers, mode {:?}, \
         family {}, selector {}, backend '{}'{}",
        launch.specs.len(),
        launch.config.hot_capacity,
        launch.config.workers,
        launch.config.mode,
        launch.config.family.label(),
        launch.config.selector.label(),
        launch.config.backend.label(),
        if launch.config.adaptive { ", adaptive" } else { "" }
    );
    let report = shptier::fleet::run_fleet(&launch.specs, &launch.config)?;
    println!("{}", report.table().render());
    println!("{}", report.summary());
    if flags.contains_key("digest") {
        // stable one-line fingerprint of the run outcome, for the CI
        // worker-count parity gate (grep "^digest " and compare)
        println!("digest {:016x}", report.digest());
    }
    Ok(())
}

/// `shptier engine` — the N-tier engine demo: concurrent sessions over a
/// 3-tier (by default) topology, one closing mid-run with
/// `finish_release`, so the arbiter's online re-arbitration visibly grows
/// the survivors' quotas and a late joiner is admitted into the freed
/// capacity. Runs over the in-memory simulator by default; `--backend
/// fs:<root>` places real files on real tier directories (ADR-003), and
/// `--reconcile` runs the same seeded demo on both backends and asserts
/// ledger parity.
fn cmd_engine(flags: &HashMap<String, String>, seed: u64) -> Result<()> {
    use shptier::engine::{reconcile_backends, run_engine_demo, BackendSpec};

    let mut demo = match flags.get("config") {
        Some(path) => EngineDemoConfig::from_file(std::path::Path::new(path))?,
        None => EngineDemoConfig::from_toml("")?,
    };
    let parse_u64 = |key: &str| -> Result<Option<u64>> {
        flags
            .get(key)
            .map(|s| s.parse::<u64>().with_context(|| format!("--{key} must be an integer")))
            .transpose()
    };
    if let Some(m) = parse_u64("streams")? {
        demo.streams = m as usize;
    }
    if let Some(n) = parse_u64("docs")? {
        demo.docs = n;
    }
    if let Some(k) = parse_u64("k")? {
        demo.k = k;
    }
    if let Some(t) = parse_u64("tiers")? {
        demo.tiers = t as usize;
    }
    if let Some(c) = parse_u64("capacity")? {
        demo.hot_capacity = c;
    }
    if flags.contains_key("seed") {
        demo.seed = seed;
    }
    if let Some(b) = flags.get("backend") {
        demo.backend = b.clone();
    }
    if let Some(f) = flags.get("family") {
        demo.family = shptier::policy::PlanFamily::parse(f)?;
    }
    if let Some(sel) = flags.get("selector") {
        demo.selector = shptier::topk::SelectorKind::parse(sel)?;
    }
    if flags.contains_key("adaptive") {
        demo.adaptive = true;
    }
    if flags.contains_key("group-commit") {
        demo.group_commit = true;
    }
    // one shared rule set for flags and TOML (clamp soft knobs, reject
    // nonsensical ones)
    let demo = demo.normalized()?;
    let backend = BackendSpec::parse(&demo.backend)?;

    if flags.contains_key("reconcile") {
        // without an explicit durable root, reconcile the FS backend over
        // a scratch directory (pre-cleaned against pid reuse, removed
        // again afterwards); fs:/obj: roots are reconciled in place
        let (spec, scratch) = match &backend {
            BackendSpec::Sim => {
                let root = std::env::temp_dir()
                    .join(format!("shptier-reconcile-{}", std::process::id()));
                let _ = std::fs::remove_dir_all(&root);
                (BackendSpec::Fs { root: root.clone() }, Some(root))
            }
            durable => (durable.clone(), None),
        };
        let rep = reconcile_backends(&demo, &spec);
        if let Some(root) = scratch {
            let _ = std::fs::remove_dir_all(&root);
        }
        let rep = rep?;
        print_engine_demo(&rep.other);
        println!(
            "reconciliation: sim total ${:.4} vs {} total ${:.4} \
             (Δtotal {:.3e}, max per-stream Δ {:.3e}) — ledger parity holds",
            rep.sim.total, rep.other.backend, rep.other.total, rep.total_delta,
            rep.max_stream_delta
        );
        return Ok(());
    }

    let report = run_engine_demo(&demo, &backend)?;
    print_engine_demo(&report);
    Ok(())
}

fn print_engine_demo(report: &shptier::engine::EngineDemoReport) {
    for event in &report.events {
        println!("{event}");
    }
    let mut table = Table::new(
        &format!(
            "engine demo — {} tiers, hot capacity {}, {} re-arbitrations, backend '{}'",
            report.tiers, report.hot_capacity, report.rearbitrations, report.backend
        ),
        &["session", "cuts", "quotas", "retained", "hot/cold reads", "measured $"],
    );
    for r in &report.rows {
        table.row(vec![
            r.id.to_string(),
            format!("{:?}", r.cuts),
            format!("{:?}", r.quotas),
            r.retained.to_string(),
            format!("{}/{}", r.hot_reads, r.cold_reads),
            format!("{:.4}", r.measured),
        ]);
    }
    println!("{}", table.render());

    for (t, cap) in report.capacities.iter().enumerate() {
        if let Some(c) = cap {
            let peak = report.peaks[t];
            println!(
                "tier {t}: peak occupancy {peak} / capacity {c} {}",
                if peak <= *c { "(ok)" } else { "(VIOLATED)" }
            );
        }
    }
    for o in &report.overcommits {
        println!(
            "WARNING: tier {} over-committed — {} orphaned residents fill its \
             capacity of {}; live sessions get no quota there",
            o.tier.label(),
            o.orphaned,
            o.capacity
        );
    }
    println!("engine ledger: {}", report.ledger_summary);
}

/// `shptier serve` — run the multi-tenant placement server (ADR-006)
/// until a client posts `/v1/shutdown`. Durable backends reopen with
/// journal replay, so restarting on the same root resumes accounting.
fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let backend = shptier::engine::BackendSpec::parse(
        flags.get("backend").map(String::as_str).unwrap_or("sim"),
    )?;
    let config = match flags.get("config") {
        Some(path) => shptier::serve::ServeConfig::from_file(path)?,
        None => bail!("serve needs --config <serve.toml> (see configs/serve.toml)"),
    };
    let tenants = config.book.tenants().len();
    if tenants == 0 {
        bail!("serve config defines no tenants; nobody could ever connect");
    }
    let server = shptier::serve::RunningServer::start(config, backend.clone())?;
    // The soak harness and operators parse this exact line.
    println!("listening on {}", server.local_addr());
    println!(
        "serving {} tenants over backend '{}' (POST /v1/shutdown to stop)",
        tenants,
        backend.label()
    );
    use std::io::Write as _;
    std::io::stdout().flush().ok();
    server.wait()?;
    println!("drained and checkpointed; bye");
    Ok(())
}

/// `shptier serve-soak` — the acceptance soak (ADR-006): many concurrent
/// sessions across a mixed-class tenant roster, with `--kill` a SIGKILL
/// mid-traffic and a journal-replay restart, then ledger-conservation
/// and exactly-once-invoicing checks.
fn cmd_serve_soak(flags: &HashMap<String, String>) -> Result<()> {
    let backend_str = flags.get("backend").map(String::as_str).unwrap_or("sim");
    let sessions: usize = flags
        .get("sessions")
        .map(|s| s.parse().context("--sessions must be an integer"))
        .transpose()?
        .unwrap_or(1000);
    let threads: usize = flags
        .get("threads")
        .map(|s| s.parse().context("--threads must be an integer"))
        .transpose()?
        .unwrap_or(16);

    let group_commit = flags.contains_key("group-commit");

    let outcome = if flags.contains_key("kill") {
        shptier::serve::soak::run_kill_restart_soak(backend_str, sessions, threads, group_commit)?
    } else {
        let backend = shptier::engine::BackendSpec::parse(backend_str)?;
        let engine_extra = if group_commit { "group_commit = true\n" } else { "" };
        let (config_text, roster) =
            shptier::serve::soak::soak_config_with(6, 2, engine_extra);
        let config = shptier::serve::ServeConfig::from_toml(&config_text)?;
        let server = shptier::serve::RunningServer::start(config, backend)?;
        let addr = server.local_addr();
        println!("serve-soak: in-process server on {addr} ({sessions} sessions)");
        let outcome =
            shptier::serve::soak::drive_and_verify(addr, &roster, sessions, threads, 24, 4)?;
        server.shutdown()?;
        outcome
    };
    println!("{}", outcome.render());
    Ok(())
}

fn cmd_optimize(flags: &HashMap<String, String>) -> Result<()> {
    let preset = flags.get("preset").map(String::as_str).unwrap_or("case-study-1");
    let model = match preset {
        "case-study-1" => case_study_1(),
        "case-study-2" => case_study_2(),
        other => bail!("unknown preset '{other}' (case-study-1 | case-study-2)"),
    };
    let mut t = Table::new(
        &format!("strategy ranking — {preset} (N={}, K={})", model.n, model.k),
        &["rank", "strategy", "expected cost ($)"],
    );
    for (i, (s, cost)) in rank_strategies(&model).into_iter().enumerate() {
        t.row(vec![(i + 1).to_string(), s.label(), format!("{cost:.2}")]);
    }
    println!("{}", t.render());
    Ok(())
}

fn print_usage() {
    println!(
        "shptier {} — SHP-driven hot/cold tier placement (Blamey et al. 2019 reproduction)

USAGE:
  shptier run [--config configs/case_study_2.toml]
  shptier fleet [--streams M] [--docs N] [--k K] [--capacity C]
                [--workers W] [--mode arbitrated|naive]
                [--family keep|migrate|auto] [--selector bounded|logmem]
                [--adaptive] [--digest]
                [--backend sim|fs:<root>|obj:<root>] [--group-commit]
                [--config configs/fleet.toml]
  shptier engine [--streams M] [--docs N] [--k K] [--tiers 2..4]
                 [--capacity C] [--backend sim|fs:<root>|obj:<root>]
                 [--reconcile] [--family keep|migrate|auto]
                 [--selector bounded|logmem] [--adaptive]
                 [--group-commit] [--config configs/engine.toml]
  shptier serve --config configs/serve.toml [--backend sim|fs:<root>|obj:<root>]
  shptier serve-soak [--backend sim|fs:<root>] [--sessions 1000]
                     [--threads 16] [--kill] [--group-commit]
  shptier exp --id <{}> [--quick] [--seed N]
  shptier optimize [--preset case-study-1|case-study-2]
  shptier validate [--quick]
  shptier sizing
",
        shptier::VERSION,
        exp::EXPERIMENT_IDS.join("|")
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_flags_accepts_negative_number_values() {
        // `--offset -1` must bind -1 to `offset`, not misparse it as a flag
        let f = parse_flags(&argv(&["--offset", "-1", "--quick"])).unwrap();
        assert_eq!(f.get("offset").map(String::as_str), Some("-1"));
        assert_eq!(f.get("quick").map(String::as_str), Some("true"));
        // floats and even double-dashed numbers are values too
        let f = parse_flags(&argv(&["--delta", "-2.5", "--scale", "--3"])).unwrap();
        assert_eq!(f.get("delta").map(String::as_str), Some("-2.5"));
        assert_eq!(f.get("scale").map(String::as_str), Some("--3"));
    }

    #[test]
    fn parse_flags_key_value_and_boolean() {
        let f =
            parse_flags(&argv(&["--streams", "8", "--mode", "naive", "--quick"])).unwrap();
        assert_eq!(f.get("streams").map(String::as_str), Some("8"));
        assert_eq!(f.get("mode").map(String::as_str), Some("naive"));
        assert_eq!(f.get("quick").map(String::as_str), Some("true"));
        assert!(parse_flags(&argv(&[])).unwrap().is_empty());
        // adjacent boolean flags stay boolean
        let f = parse_flags(&argv(&["--a", "--b"])).unwrap();
        assert_eq!(f.get("a").map(String::as_str), Some("true"));
        assert_eq!(f.get("b").map(String::as_str), Some("true"));
    }

    #[test]
    fn parse_flags_rejects_stray_tokens() {
        assert!(parse_flags(&argv(&["stray"])).is_err());
        assert!(parse_flags(&argv(&["--a", "1", "stray"])).is_err());
        assert!(parse_flags(&argv(&["-x"])).is_err());
    }

    #[test]
    fn flag_token_classification() {
        assert!(is_flag_token("--mode"));
        assert!(is_flag_token("--k"));
        // word-shaped tokens the f64 parser would accept are still flags
        assert!(is_flag_token("--nan"));
        assert!(is_flag_token("--inf"));
        assert!(!is_flag_token("-1"));
        assert!(!is_flag_token("-2.5"));
        assert!(!is_flag_token("--3"));
        assert!(!is_flag_token("--"));
        assert!(!is_flag_token("value"));
    }
}
