//! Reactive baselines from the related-work tradition (§III): policies
//! that *monitor* residency and demote by age, without the paper's a-priori
//! model of the write process.

use super::{MigrationOrder, PlacementPolicy};
use crate::storage::{StorageBackend, TierId};

/// Age-based demotion ("document age as a predictor of document heat",
/// e.g. f4 [Muralidhar et al. 2014]): write everything hot (A); after each
/// step, demote residents of A older than `age_frac` of the window to B.
#[derive(Debug, Clone, Copy)]
pub struct AgeBasedDemotion {
    /// Age threshold as a fraction of the stream window.
    pub age_frac: f64,
}

impl AgeBasedDemotion {
    pub fn new(age_frac: f64) -> Self {
        assert!(age_frac >= 0.0);
        Self { age_frac }
    }
}

impl PlacementPolicy for AgeBasedDemotion {
    fn name(&self) -> String {
        format!("age-demotion(tau={:.3})", self.age_frac)
    }

    fn place(&mut self, _index: u64, _n: u64) -> TierId {
        TierId::A
    }

    fn on_step(
        &mut self,
        index: u64,
        n: u64,
        storage: &dyn StorageBackend,
    ) -> Vec<MigrationOrder> {
        let now = index as f64 / n as f64;
        let mut orders = Vec::new();
        for r in storage.residents(TierId::A) {
            if now - r.written_at > self.age_frac {
                orders.push(MigrationOrder::Doc { doc: r.doc, to: TierId::B });
            }
        }
        orders
    }
}

/// Per-document deterministic ski-rental (c.f. [Khanafer et al. 2013],
/// [Mansouri & Erradi 2018]): keep a document in the hot tier until its
/// accumulated hot rent equals the one-off cost of moving it cold, then
/// move it. 2-competitive against the clairvoyant per-document optimum.
#[derive(Debug, Clone, Copy)]
pub struct SkiRental {
    /// Rent of A per full window ($/doc).
    rent_a: f64,
    /// One-off move cost A→B ($/doc): read_A + write_B.
    move_cost: f64,
}

impl SkiRental {
    pub fn new(rent_a_per_window: f64, move_cost: f64) -> Self {
        Self { rent_a: rent_a_per_window, move_cost }
    }

    /// Derive from a cost model (uses tier A rent and the A→B hop).
    pub fn from_model(m: &crate::cost::CostModel) -> Self {
        Self::new(m.a.rent_window, m.a.read + m.b.write)
    }

    /// Break-even residency time, as a window fraction.
    pub fn break_even_frac(&self) -> f64 {
        if self.rent_a <= 0.0 {
            f64::INFINITY
        } else {
            self.move_cost / self.rent_a
        }
    }
}

impl PlacementPolicy for SkiRental {
    fn name(&self) -> String {
        format!("ski-rental(tau={:.4})", self.break_even_frac())
    }

    fn place(&mut self, _index: u64, _n: u64) -> TierId {
        TierId::A
    }

    fn on_step(
        &mut self,
        index: u64,
        n: u64,
        storage: &dyn StorageBackend,
    ) -> Vec<MigrationOrder> {
        let tau = self.break_even_frac();
        if !tau.is_finite() {
            return Vec::new();
        }
        let now = index as f64 / n as f64;
        let mut orders = Vec::new();
        for r in storage.residents(TierId::A) {
            if now - r.written_at >= tau {
                orders.push(MigrationOrder::Doc { doc: r.doc, to: TierId::B });
            }
        }
        orders
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PerDocCosts;
    use crate::storage::StorageSim;

    fn sim() -> StorageSim {
        StorageSim::two_tier(
            PerDocCosts { write: 0.0, read: 1.0, rent_window: 10.0 },
            PerDocCosts { write: 2.0, read: 0.0, rent_window: 1.0 },
            true,
        )
    }

    #[test]
    fn age_demotion_triggers_after_threshold() {
        let mut p = AgeBasedDemotion::new(0.1);
        let mut s = sim();
        s.put(1, TierId::A, 0.0).unwrap();
        // at 5% of the window: too young
        assert!(p.on_step(5, 100, &s).is_empty());
        // at 20%: old enough
        let orders = p.on_step(20, 100, &s);
        assert_eq!(orders, vec![MigrationOrder::Doc { doc: 1, to: TierId::B }]);
    }

    #[test]
    fn ski_rental_break_even() {
        // rent 10/window, move cost 3 → tau = 0.3 windows
        let p = SkiRental::new(10.0, 3.0);
        assert!((p.break_even_frac() - 0.3).abs() < 1e-12);
        // zero rent → never move
        let p0 = SkiRental::new(0.0, 3.0);
        assert!(!p0.break_even_frac().is_finite());
    }

    #[test]
    fn ski_rental_migrates_at_break_even() {
        let mut p = SkiRental::new(10.0, 3.0);
        let mut s = sim();
        s.put(1, TierId::A, 0.0).unwrap();
        assert!(p.on_step(29, 100, &s).is_empty());
        let orders = p.on_step(30, 100, &s);
        assert_eq!(orders.len(), 1);
    }

    #[test]
    fn ski_rental_from_model_uses_hop_cost() {
        let m = crate::cost::CostModel::new(
            100,
            10,
            PerDocCosts { write: 0.0, read: 1.0, rent_window: 10.0 },
            PerDocCosts { write: 2.0, read: 0.0, rent_window: 1.0 },
        );
        let p = SkiRental::from_model(&m);
        assert!((p.break_even_frac() - 0.3).abs() < 1e-12);
    }
}
