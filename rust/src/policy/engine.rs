//! Incremental placement engine: the single-stream compatibility facade
//! over [`crate::engine::Engine`], shared by the batch trace executor
//! ([`super::executor::run_policy`]) and the streaming pipeline
//! ([`crate::pipeline`]).
//!
//! Feed `(index, score)` observations in stream order; the underlying
//! engine session maintains the top-K tracker, executes the policy's
//! placements/migrations against the storage backend, and finishes with
//! the end-of-stream consumer read. This struct used to own the whole
//! state machine; since the `shptier::engine` redesign (ADR-002) it is a
//! thin wrapper over a one-session engine with an uncapacitated two-tier
//! topology — the two-tier degenerate case of the N-tier API, bit-
//! compatible with the pre-engine behaviour.

use super::PlacementPolicy;
use crate::cost::CostModel;
use crate::engine::{Engine, SessionSpec, StreamSession, TierTopology};
use crate::storage::TierId;
use anyhow::{anyhow, Result};

/// Outcome of a finished run (batch or streaming).
#[derive(Debug, Clone)]
pub struct RunResult {
    pub policy: String,
    pub ledger: crate::storage::Ledger,
    /// Final top-K document indices (best first).
    pub retained: Vec<u64>,
    /// Which tier each retained document was read from.
    pub read_from: Vec<(u64, TierId)>,
    /// Cumulative organic writes after each document (empty unless
    /// `record_series` was set).
    pub cumulative_writes: Vec<u64>,
}

impl RunResult {
    pub fn total_cost(&self) -> f64 {
        self.ledger.total()
    }
}

/// Online placement state machine (single-stream engine facade).
pub struct PlacementEngine {
    engine: Engine,
    session: Option<StreamSession>,
    n: u64,
    policy_name: String,
}

impl PlacementEngine {
    /// `n` is the total stream length (the paper's fixed-length window).
    pub fn new(
        model: &CostModel,
        n: u64,
        policy: &dyn PlacementPolicy,
        record_series: bool,
    ) -> Self {
        assert!(n > 0);
        let engine = Engine::builder()
            .topology(TierTopology::from_model(model))
            .charge_rent(model.include_rent)
            .build()
            .expect("a two-tier topology is always valid");
        let spec = SessionSpec::from_model(model);
        let session = engine
            .open_stream(SessionSpec { n, record_series, ..spec })
            .expect("a fresh engine admits its first session");
        Self { engine, session: Some(session), n, policy_name: policy.name() }
    }

    /// Observe the next document. Must be called in stream order; errors
    /// once the declared stream length is exceeded.
    pub fn observe(&mut self, score: f64, policy: &mut dyn PlacementPolicy) -> Result<()> {
        self.session
            .as_mut()
            .ok_or_else(|| anyhow!("placement engine already finished"))?
            .observe_with_policy(score, policy)
    }

    /// Documents observed so far.
    pub fn observed(&self) -> u64 {
        self.session.as_ref().map(|s| s.observed()).unwrap_or(self.n)
    }

    /// Residents of `tier` on the underlying backend (tests/diagnostics;
    /// replaces the pre-engine `sim()` accessor).
    pub fn tier_len(&self, tier: TierId) -> usize {
        self.engine.resident_len(tier)
    }

    /// Current top-K threshold score (None until K docs seen).
    pub fn threshold(&self) -> Option<f64> {
        self.session.as_ref().and_then(|s| s.threshold())
    }

    /// End of stream: settle rent, consumer reads the top-K.
    pub fn finish(mut self) -> Result<RunResult> {
        let session = self
            .session
            .take()
            .ok_or_else(|| anyhow!("placement engine already finished"))?;
        self.engine.settle_rent(1.0)?;
        let out = session.finish()?;
        Ok(RunResult {
            policy: self.policy_name,
            ledger: self.engine.ledger(),
            retained: out.retained,
            read_from: out.read_from,
            cumulative_writes: out.cumulative_writes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PerDocCosts;
    use crate::policy::SingleTier;
    use crate::util::Rng;

    #[test]
    fn engine_matches_batch_executor() {
        let model = CostModel::new(
            500,
            5,
            PerDocCosts { write: 2.0, read: 5.0, rent_window: 1.0 },
            PerDocCosts { write: 3.0, read: 7.0, rent_window: 2.0 },
        );
        let mut rng = Rng::new(12);
        let scores: Vec<f64> = (0..500).map(|_| rng.next_f64()).collect();

        let mut p1 = crate::policy::Changeover::new(200);
        let batch = crate::policy::run_policy_with_trace(&scores, &model, &mut p1, true).unwrap();

        let mut p2 = crate::policy::Changeover::new(200);
        let mut engine = PlacementEngine::new(&model, 500, &p2, true);
        for &s in &scores {
            engine.observe(s, &mut p2).unwrap();
        }
        let streaming = engine.finish().unwrap();

        assert_eq!(batch.retained, streaming.retained);
        assert_eq!(batch.cumulative_writes, streaming.cumulative_writes);
        assert!((batch.total_cost() - streaming.total_cost()).abs() < 1e-9);
    }

    #[test]
    fn threshold_appears_after_k() {
        let model = CostModel::new(
            100,
            3,
            PerDocCosts { write: 0.0, read: 0.0, rent_window: 0.0 },
            PerDocCosts { write: 0.0, read: 0.0, rent_window: 0.0 },
        );
        let mut p = SingleTier::new(TierId::A);
        let mut e = PlacementEngine::new(&model, 100, &p, false);
        e.observe(0.5, &mut p).unwrap();
        e.observe(0.7, &mut p).unwrap();
        assert!(e.threshold().is_none());
        e.observe(0.6, &mut p).unwrap();
        assert_eq!(e.threshold(), Some(0.5));
        assert_eq!(e.tier_len(TierId::A), 3);
    }

    #[test]
    #[should_panic]
    fn overlong_stream_panics() {
        let model = CostModel::new(
            2,
            1,
            PerDocCosts { write: 0.0, read: 0.0, rent_window: 0.0 },
            PerDocCosts { write: 0.0, read: 0.0, rent_window: 0.0 },
        );
        let mut p = SingleTier::new(TierId::A);
        let mut e = PlacementEngine::new(&model, 2, &p, false);
        for _ in 0..3 {
            e.observe(0.1, &mut p).unwrap();
        }
    }
}
