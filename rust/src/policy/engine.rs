//! Incremental placement engine: the online core shared by the batch trace
//! executor ([`super::executor::run_policy`]) and the streaming pipeline
//! ([`crate::pipeline`]).
//!
//! Feed `(index, score)` observations in stream order; the engine maintains
//! the top-K tracker, executes the policy's placements/migrations against
//! the storage simulator, and finishes with the end-of-stream consumer read.

use super::{MigrationOrder, PlacementPolicy};
use crate::cost::CostModel;
use crate::storage::{StorageSim, TierId};
use crate::topk::{BoundedTopK, Eviction, Scored};
use anyhow::Result;

/// Outcome of a finished run (batch or streaming).
#[derive(Debug, Clone)]
pub struct RunResult {
    pub policy: String,
    pub ledger: crate::storage::Ledger,
    /// Final top-K document indices (best first).
    pub retained: Vec<u64>,
    /// Which tier each retained document was read from.
    pub read_from: Vec<(u64, TierId)>,
    /// Cumulative organic writes after each document (empty unless
    /// `record_series` was set).
    pub cumulative_writes: Vec<u64>,
}

impl RunResult {
    pub fn total_cost(&self) -> f64 {
        self.ledger.total()
    }
}

/// Online placement state machine.
pub struct PlacementEngine {
    sim: StorageSim,
    tracker: BoundedTopK,
    n: u64,
    next_index: u64,
    writes: u64,
    series: Option<Vec<u64>>,
    policy_name: String,
}

impl PlacementEngine {
    /// `n` is the total stream length (the paper's fixed-length window).
    pub fn new(
        model: &CostModel,
        n: u64,
        policy: &dyn PlacementPolicy,
        record_series: bool,
    ) -> Self {
        assert!(n > 0);
        let k = (model.k as usize).min(n as usize);
        Self {
            sim: StorageSim::two_tier(model.a, model.b, model.include_rent),
            tracker: BoundedTopK::new(k),
            n,
            next_index: 0,
            writes: 0,
            series: if record_series { Some(Vec::with_capacity(n as usize)) } else { None },
            policy_name: policy.name(),
        }
    }

    /// Observe the next document. Must be called in stream order.
    pub fn observe(
        &mut self,
        score: f64,
        policy: &mut dyn PlacementPolicy,
    ) -> Result<()> {
        let i = self.next_index;
        assert!(i < self.n, "stream longer than declared N");
        self.next_index += 1;
        let at = i as f64 / self.n as f64;
        match self.tracker.offer(Scored::new(i, score)) {
            Eviction::Rejected => {}
            Eviction::Accepted => {
                let tier = policy.place(i, self.n);
                self.sim.put(i, tier, at)?;
                self.writes += 1;
            }
            Eviction::Replaced { victim } => {
                self.sim.delete(victim.index, at)?;
                let tier = policy.place(i, self.n);
                self.sim.put(i, tier, at)?;
                self.writes += 1;
            }
        }
        for order in policy.on_step(i, self.n, &self.sim) {
            match order {
                MigrationOrder::All { from, to } => {
                    self.sim.migrate_all(from, to, at)?;
                }
                MigrationOrder::Doc { doc, to } => {
                    self.sim.migrate_doc(doc, to, at)?;
                }
            }
        }
        if let Some(s) = self.series.as_mut() {
            s.push(self.writes);
        }
        Ok(())
    }

    /// Documents observed so far.
    pub fn observed(&self) -> u64 {
        self.next_index
    }

    /// Read-only view of the storage simulator (tests and diagnostics).
    pub fn sim(&self) -> &StorageSim {
        &self.sim
    }

    /// Current top-K threshold score (None until K docs seen).
    pub fn threshold(&self) -> Option<f64> {
        self.tracker.threshold().map(|s| s.score)
    }

    /// End of stream: settle rent, consumer reads the top-K.
    pub fn finish(mut self) -> Result<RunResult> {
        self.sim.settle_rent(1.0);
        let retained: Vec<u64> = self.tracker.sorted_desc().iter().map(|s| s.index).collect();
        let mut read_from = Vec::with_capacity(retained.len());
        for &doc in &retained {
            let tier = self.sim.read(doc)?;
            read_from.push((doc, tier));
        }
        Ok(RunResult {
            policy: self.policy_name,
            ledger: self.sim.ledger().clone(),
            retained,
            read_from,
            cumulative_writes: self.series.unwrap_or_default(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PerDocCosts;
    use crate::policy::SingleTier;
    use crate::util::Rng;

    #[test]
    fn engine_matches_batch_executor() {
        let model = CostModel::new(
            500,
            5,
            PerDocCosts { write: 2.0, read: 5.0, rent_window: 1.0 },
            PerDocCosts { write: 3.0, read: 7.0, rent_window: 2.0 },
        );
        let mut rng = Rng::new(12);
        let scores: Vec<f64> = (0..500).map(|_| rng.next_f64()).collect();

        let mut p1 = crate::policy::Changeover::new(200);
        let batch = crate::policy::run_policy_with_trace(&scores, &model, &mut p1, true).unwrap();

        let mut p2 = crate::policy::Changeover::new(200);
        let mut engine = PlacementEngine::new(&model, 500, &p2, true);
        for &s in &scores {
            engine.observe(s, &mut p2).unwrap();
        }
        let streaming = engine.finish().unwrap();

        assert_eq!(batch.retained, streaming.retained);
        assert_eq!(batch.cumulative_writes, streaming.cumulative_writes);
        assert!((batch.total_cost() - streaming.total_cost()).abs() < 1e-9);
    }

    #[test]
    fn threshold_appears_after_k() {
        let model = CostModel::new(
            100,
            3,
            PerDocCosts { write: 0.0, read: 0.0, rent_window: 0.0 },
            PerDocCosts { write: 0.0, read: 0.0, rent_window: 0.0 },
        );
        let mut p = SingleTier::new(TierId::A);
        let mut e = PlacementEngine::new(&model, 100, &p, false);
        e.observe(0.5, &mut p).unwrap();
        e.observe(0.7, &mut p).unwrap();
        assert!(e.threshold().is_none());
        e.observe(0.6, &mut p).unwrap();
        assert_eq!(e.threshold(), Some(0.5));
    }

    #[test]
    #[should_panic]
    fn overlong_stream_panics() {
        let model = CostModel::new(
            2,
            1,
            PerDocCosts { write: 0.0, read: 0.0, rent_window: 0.0 },
            PerDocCosts { write: 0.0, read: 0.0, rent_window: 0.0 },
        );
        let mut p = SingleTier::new(TierId::A);
        let mut e = PlacementEngine::new(&model, 2, &p, false);
        for _ in 0..3 {
            e.observe(0.1, &mut p).unwrap();
        }
    }
}
