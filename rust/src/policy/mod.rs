//! Tier-placement policies.
//!
//! The paper's contribution is *proactive* placement: because the top-K
//! workload's IO is the SHP record process, the optimal tier for a document
//! is a function of its stream index alone (Algorithm C). Reactive
//! baselines from the related-work tradition (age-based demotion,
//! per-document ski-rental) are provided for the comparison ablation (A1),
//! plus a clairvoyant oracle lower bound.

mod engine;
mod executor;
mod plan;
mod quota;
mod reactive;
mod shp_policies;

pub use engine::{PlacementEngine, RunResult};
pub use executor::{run_policy, run_policy_with_trace};
pub use plan::{PlacementPlan, PlanFamily};
pub use quota::{QuotaChangeover, QuotaChangeoverMigrate};
pub use reactive::{AgeBasedDemotion, SkiRental};
pub use shp_policies::{Changeover, ChangeoverMigrate, SingleTier};

use crate::storage::{StorageBackend, TierId};

/// A migration the policy wants executed after the current step.
#[derive(Debug, Clone, PartialEq)]
pub enum MigrationOrder {
    /// Bulk move of every resident of `from` into `to`.
    All { from: TierId, to: TierId },
    /// Move one document.
    Doc { doc: u64, to: TierId },
}

/// Online tier-placement policy. The executor calls `place` exactly once
/// for every document that enters the current top-K, and `on_step` after
/// every document (accepted or not).
pub trait PlacementPolicy {
    /// Human-readable name for reports.
    fn name(&self) -> String;

    /// Tier for a newly accepted document at stream index `index` (0-based)
    /// of a stream of length `n`.
    fn place(&mut self, index: u64, n: u64) -> TierId;

    /// Optional migrations after observing document `index`. `storage`
    /// provides read-only visibility of current residency through the
    /// backend-agnostic [`StorageBackend`] view (reactive policies inspect
    /// it; proactive policies ignore it).
    fn on_step(
        &mut self,
        _index: u64,
        _n: u64,
        _storage: &dyn StorageBackend,
    ) -> Vec<MigrationOrder> {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_tier_places_everything_in_one_tier() {
        let mut p = SingleTier::new(TierId::B);
        assert_eq!(p.place(0, 100), TierId::B);
        assert_eq!(p.place(99, 100), TierId::B);
        assert_eq!(p.name(), "all-B");
    }

    #[test]
    fn changeover_switches_at_r() {
        let mut p = Changeover::new(10);
        assert_eq!(p.place(9, 100), TierId::A);
        assert_eq!(p.place(10, 100), TierId::B);
    }

    #[test]
    fn changeover_migrate_orders_bulk_move_once() {
        let mut p = ChangeoverMigrate::new(10);
        let sim = crate::storage::StorageSim::two_tier(
            crate::cost::PerDocCosts { write: 0.0, read: 0.0, rent_window: 0.0 },
            crate::cost::PerDocCosts { write: 0.0, read: 0.0, rent_window: 0.0 },
            false,
        );
        assert!(p.on_step(9, 100, &sim).is_empty());
        let orders = p.on_step(10, 100, &sim);
        assert_eq!(
            orders,
            vec![MigrationOrder::All { from: TierId::A, to: TierId::B }]
        );
        // only once
        assert!(p.on_step(10, 100, &sim).is_empty());
        assert!(p.on_step(11, 100, &sim).is_empty());
    }
}
