//! N-tier placement plans — the generalization of the paper's two-tier
//! changeover rule, for both strategy families (keep and DO_MIGRATE).
//!
//! The paper's Algorithm C places "the first `r` documents in A, the rest
//! in B". Over an ordered hierarchy of `m` tiers (hot → cold) the natural
//! generalization is a vector of `m − 1` *changeover indices* (one per tier
//! boundary): document index `i` lands in the first tier `j` whose cut
//! `cuts[j]` exceeds `i`, i.e. tier `j` owns the index band
//! `[cuts[j−1], cuts[j])` (with `cuts[−1] = 0` and `cuts[m−1] = N`
//! implicit). A two-tier plan `cuts = [r]` degenerates exactly to
//! [`super::Changeover`] / [`super::QuotaChangeover`].
//!
//! **Migrate schedules.** The paper's DO_MIGRATE family (Fig. 3) carries a
//! per-boundary flag: when the stream reaches `i == cuts[j]` and
//! `migrate[j]` is set, every one of the stream's residents still in tier
//! `j` is bulk-demoted into the next colder tier — the *changeover
//! demotion*. Flags cascade: with consecutive boundaries flagged, a
//! document placed in the hottest band steps down one tier at each
//! changeover it survives, ending in the coldest flagged-through tier.
//! `cuts = [r]`, `migrate = [true]` reproduces
//! [`super::ChangeoverMigrate`] / [`super::QuotaChangeoverMigrate`]
//! exactly. The flag vector always has one entry per boundary — a
//! mismatched arity is a construction error
//! ([`PlacementPlan::from_cuts_migrate`]), not a silently dropped request
//! (the old two-tier encoding used to mask the flag for >2 tiers).
//!
//! The closed-form machinery carries over band-by-band: expected writes
//! into tier `j` are `W(cuts[j]) − W(cuts[j−1])` (harmonic sums, eq. 11),
//! a survivor is read from the band's *final* tier (its cascade target)
//! with probability `width_j / N` (the i.u.d. assumption behind eq. 15),
//! each changeover demotion moves the expected live residents of its tier
//! (eq. 19 per boundary), and rent integrates the expected per-tier
//! occupancy with the demotions folded in. For `m = 2` the plan's
//! analytic cost delegates to [`crate::cost::expected_cost`] so the
//! degenerate case is bit-identical with the pre-engine code paths.

use crate::cost::{
    expected_cost, expected_writes, optimal_cuts_family, CostModel, PerDocCosts, Strategy,
};
use crate::storage::TierId;
use anyhow::{bail, Result};

/// Which strategy family a stream runs (the arbiter's plan-family
/// dimension): the no-migration changeover, the DO_MIGRATE changeover
/// (every boundary carries a changeover demotion), or the analytically
/// cheaper of the two.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlanFamily {
    /// No migration: residents stay where they were written (paper
    /// eqs. 14–17).
    #[default]
    Keep,
    /// Bulk-demote at every changeover boundary (paper eqs. 18–21,
    /// Fig. 3 DO_MIGRATE) — the winner whenever rent dominates transport.
    Migrate,
    /// Per-stream choice: whichever family's closed-form optimum prices
    /// cheaper under the stream's economics.
    Auto,
}

impl PlanFamily {
    /// Parse a config/CLI selector (`keep` | `migrate` | `auto`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "keep" => Ok(Self::Keep),
            "migrate" => Ok(Self::Migrate),
            "auto" => Ok(Self::Auto),
            other => bail!("unknown plan family '{other}' (keep | migrate | auto)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::Keep => "keep",
            Self::Migrate => "migrate",
            Self::Auto => "auto",
        }
    }
}

/// An N-tier proactive placement plan: nondecreasing changeover indices,
/// one per tier boundary, each optionally carrying a changeover demotion.
#[derive(Debug, Clone, PartialEq)]
pub struct PlacementPlan {
    /// Changeover index per tier boundary (`len = num_tiers − 1`),
    /// nondecreasing, each in `[0, n]`.
    cuts: Vec<u64>,
    /// Per-boundary DO_MIGRATE flag (`len = cuts.len()`): bulk-demote the
    /// stream's residents of tier `j` into tier `j+1` at `i == cuts[j]`.
    migrate: Vec<bool>,
    /// Stream length.
    n: u64,
    /// Retained-set size (top-K).
    k: u64,
}

impl PlacementPlan {
    /// Validated construction from raw cuts (keep family: no demotions).
    pub fn from_cuts(cuts: Vec<u64>, n: u64, k: u64) -> Result<Self> {
        let migrate = vec![false; cuts.len()];
        Self::from_cuts_migrate(cuts, migrate, n, k)
    }

    /// Validated construction from raw cuts plus a per-boundary migrate
    /// schedule. `migrate.len()` must equal `cuts.len()` — asking for a
    /// migration schedule that does not match the tier hierarchy is an
    /// explicit error, never a silently dropped flag.
    pub fn from_cuts_migrate(
        cuts: Vec<u64>,
        migrate: Vec<bool>,
        n: u64,
        k: u64,
    ) -> Result<Self> {
        if cuts.is_empty() {
            bail!("placement plan needs at least one changeover index (two tiers)");
        }
        if migrate.len() != cuts.len() {
            bail!(
                "migrate schedule has {} flags for {} tier boundaries",
                migrate.len(),
                cuts.len()
            );
        }
        if n == 0 || k == 0 || k > n {
            bail!("placement plan requires 0 < K <= N (got K={k}, N={n})");
        }
        let mut prev = 0u64;
        for (j, &c) in cuts.iter().enumerate() {
            if c > n {
                bail!("cut {j} = {c} exceeds stream length {n}");
            }
            if c < prev {
                bail!("cuts must be nondecreasing (cut {j} = {c} < {prev})");
            }
            prev = c;
        }
        Ok(Self { cuts, migrate, n, k })
    }

    /// The paper's two-tier changeover at `r` (no migration).
    pub fn two_tier(r: u64, n: u64, k: u64) -> Self {
        Self {
            cuts: vec![r.min(n)],
            migrate: vec![false],
            n,
            k: k.min(n).max(1),
        }
    }

    /// The paper's two-tier changeover-with-migration at `r`.
    pub fn two_tier_migrate(r: u64, n: u64, k: u64) -> Self {
        Self { migrate: vec![true], ..Self::two_tier(r, n, k) }
    }

    /// Set every boundary's changeover-demotion flag (the full DO_MIGRATE
    /// cascade, builder-style).
    pub fn with_migration(mut self) -> Self {
        for f in self.migrate.iter_mut() {
            *f = true;
        }
        self
    }

    /// Closed-form optimal keep-family plan for a tier hierarchy: each
    /// boundary's cut is the two-tier optimum between its adjacent tiers
    /// ([`crate::cost::optimal_cuts`]), made nondecreasing by a running
    /// maximum (a document never returns to a hotter tier later in the
    /// stream). For two tiers this *is* `r*`.
    pub fn optimal(tier_costs: &[PerDocCosts], n: u64, k: u64, include_rent: bool) -> Self {
        assert!(tier_costs.len() >= 2, "need at least two tiers");
        let k = k.min(n).max(1);
        let cuts = optimal_cuts_family(tier_costs, n, k, include_rent, false);
        let migrate = vec![false; cuts.len()];
        Self { cuts, migrate, n, k }
    }

    /// Closed-form optimal migrate-family plan: per-boundary cuts from the
    /// migration closed form (paper eq. 21 per adjacent pair), every
    /// boundary carrying a changeover demotion. For two tiers this is the
    /// paper's DO_MIGRATE optimum `r*` ([`crate::cost::optimal_r`] with
    /// `migrate = true`).
    pub fn optimal_migrate(
        tier_costs: &[PerDocCosts],
        n: u64,
        k: u64,
        include_rent: bool,
    ) -> Self {
        assert!(tier_costs.len() >= 2, "need at least two tiers");
        let k = k.min(n).max(1);
        let cuts = optimal_cuts_family(tier_costs, n, k, include_rent, true);
        let migrate = vec![true; cuts.len()];
        Self { cuts, migrate, n, k }
    }

    /// Closed-form optimal plan for a family: [`PlacementPlan::optimal`]
    /// (keep), [`PlacementPlan::optimal_migrate`], or — for
    /// [`PlanFamily::Auto`] — whichever of the two prices cheaper under
    /// [`PlacementPlan::analytic_cost`].
    pub fn optimal_family(
        tier_costs: &[PerDocCosts],
        n: u64,
        k: u64,
        include_rent: bool,
        family: PlanFamily,
    ) -> Self {
        match family {
            PlanFamily::Keep => Self::optimal(tier_costs, n, k, include_rent),
            PlanFamily::Migrate => Self::optimal_migrate(tier_costs, n, k, include_rent),
            PlanFamily::Auto => {
                let keep = Self::optimal(tier_costs, n, k, include_rent);
                let mig = Self::optimal_migrate(tier_costs, n, k, include_rent);
                if mig.analytic_cost(tier_costs, include_rent)
                    < keep.analytic_cost(tier_costs, include_rent)
                {
                    mig
                } else {
                    keep
                }
            }
        }
    }

    pub fn num_tiers(&self) -> usize {
        self.cuts.len() + 1
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn k(&self) -> u64 {
        self.k
    }

    pub fn cuts(&self) -> &[u64] {
        &self.cuts
    }

    /// Per-boundary changeover-demotion flags (`len = num_tiers − 1`).
    pub fn migrate_flags(&self) -> &[bool] {
        &self.migrate
    }

    /// Whether boundary `j` carries a changeover demotion.
    pub fn migrate_at(&self, boundary: usize) -> bool {
        self.migrate.get(boundary).copied().unwrap_or(false)
    }

    /// Whether any boundary carries a changeover demotion.
    pub fn migrates(&self) -> bool {
        self.migrate.iter().any(|&m| m)
    }

    /// The family this plan belongs to (migrate iff any boundary demotes).
    pub fn family(&self) -> PlanFamily {
        if self.migrates() {
            PlanFamily::Migrate
        } else {
            PlanFamily::Keep
        }
    }

    /// The two-tier changeover parameter (first cut) — the quantity
    /// reported as `r` everywhere in the two-tier world.
    pub fn r(&self) -> u64 {
        self.cuts[0]
    }

    /// Index band `[lo, hi)` owned by `tier`.
    pub fn band(&self, tier: TierId) -> (u64, u64) {
        let lo = if tier.0 == 0 { 0 } else { self.cuts[tier.0 - 1] };
        let hi = if tier.0 == self.cuts.len() { self.n } else { self.cuts[tier.0] };
        (lo, hi)
    }

    /// Proactive tier for stream index `i` (hotter tiers own earlier bands).
    pub fn tier_for(&self, index: u64) -> TierId {
        for (j, &c) in self.cuts.iter().enumerate() {
            if index < c {
                return TierId(j);
            }
        }
        TierId(self.cuts.len())
    }

    /// The tier where band `tier`'s survivors end the stream: the cascade
    /// target through every consecutive demoting boundary that actually
    /// fires mid-stream (a boundary with `cut == N` never fires — indices
    /// stop at `N − 1`).
    pub fn final_tier(&self, tier: TierId) -> TierId {
        let mut q = tier.0;
        while q < self.cuts.len() && self.migrate[q] && self.cuts[q] < self.n {
            q += 1;
        }
        TierId(q)
    }

    /// Peak simultaneous residents `tier` can see from this stream:
    /// `min(reachable index range, K)`. For a keep plan that range is the
    /// tier's own band (only band indices are ever written there); a
    /// migrate schedule additionally cascades every hotter band that
    /// demotes into `tier`, so just before `tier`'s own boundary fires it
    /// can hold all live documents with index below its band end — the
    /// quota a capacitated middle tier must reserve for the bulk arrival.
    pub fn demand(&self, tier: TierId) -> u64 {
        let (_, hi) = self.band(tier);
        let mut j = tier.0;
        while j > 0 && self.migrate[j - 1] && self.cuts[j - 1] < self.n {
            j -= 1;
        }
        let lo = if j == 0 { 0 } else { self.cuts[j - 1] };
        (hi - lo).min(self.k)
    }

    /// Shrink `tier`'s band until its demand fits `quota`, pushing the
    /// displaced indices into the next colder tier. The two-tier case
    /// reproduces the arbiter's budget clamp (`r = quota` whenever
    /// `min(r, K) > quota`). Bands of later tiers are untouched (their cuts
    /// only ever move down, preserving monotonicity).
    pub fn clamp_tier_to_quota(&mut self, tier: TierId, quota: u64) {
        if tier.0 >= self.cuts.len() {
            return; // the coldest tier is the overflow sink — never clamped
        }
        if self.demand(tier) <= quota {
            return;
        }
        let (lo, _) = self.band(tier);
        self.cuts[tier.0] = lo + quota;
    }

    /// Cap `boundary`'s cut (and every hotter cut, to preserve
    /// monotonicity) at `max`. Used by the engine when re-arbitration
    /// hands a session a new plan after one of its boundaries already
    /// fired: a fired changeover must never re-open (indices past the
    /// fired cut would otherwise place hot again with no second demotion
    /// coming).
    pub fn clamp_cut_at_most(&mut self, boundary: usize, max: u64) {
        for c in self.cuts.iter_mut().take(boundary + 1) {
            if *c > max {
                *c = max;
            }
        }
    }

    /// The degenerate two-tier [`Strategy`], if this is a two-tier plan.
    pub fn strategy(&self) -> Option<Strategy> {
        if self.num_tiers() != 2 {
            return None;
        }
        Some(if self.migrate[0] {
            Strategy::ChangeoverMigrate { r: self.cuts[0] }
        } else {
            Strategy::Changeover { r: self.cuts[0] }
        })
    }

    /// Analytic expected total cost of running this plan over `tier_costs`.
    ///
    /// Two-tier plans delegate to [`crate::cost::expected_cost`] (exact
    /// degenerate compatibility, both families); N > 2 uses the band
    /// generalization: harmonic write sums per band, `width/N` read split
    /// against each band's cascade-final tier, one expected-resident
    /// demotion charge per firing boundary, and integrated expected
    /// per-tier occupancy for rent (demotions folded in).
    pub fn analytic_cost(&self, tier_costs: &[PerDocCosts], include_rent: bool) -> f64 {
        assert_eq!(tier_costs.len(), self.num_tiers(), "cost entries must match tiers");
        if self.num_tiers() == 2 {
            let model = CostModel::new(self.n, self.k, tier_costs[0], tier_costs[1])
                .with_rent(include_rent);
            return expected_cost(&model, self.strategy().unwrap()).total();
        }
        let (n, k) = (self.n, self.k);
        let kf = k as f64;
        let nf = n as f64;
        let mut total = 0.0;
        for (j, costs) in tier_costs.iter().enumerate() {
            let (lo, hi) = self.band(TierId(j));
            // writes: harmonic band sum (paper eq. 11 per band)
            let w = expected_writes(hi, k) - expected_writes(lo, k);
            total += w * costs.write;
            // reads: survivor born in the band w.p. width/N (eq. 15
            // i.u.d.), served by the band's cascade-final tier
            let dest = self.final_tier(TierId(j));
            total += kf * ((hi - lo) as f64 / nf) * tier_costs[dest.0].read;
        }
        total += self.transport_cost(tier_costs);
        if include_rent {
            total += if self.migrates() {
                self.migrate_rent(tier_costs)
            } else {
                (0..tier_costs.len())
                    .map(|j| {
                        let (lo, hi) = self.band(TierId(j));
                        band_occupancy_time(lo, hi, n, k) * tier_costs[j].rent_window
                    })
                    .sum::<f64>()
            };
        }
        total
    }

    /// Expected $ of the changeover demotions (eq. 19 generalized): when
    /// boundary `j` fires at `t = cuts[j]`, the stream's expected live
    /// residents of tier `j` — `min(t, K) · mass_j / t` under the i.u.d.
    /// assumption, where `mass_j` is the index measure that has cascaded
    /// into tier `j` by then — each pay a source read plus a destination
    /// write. Boundaries fire hot → cold, so co-located cuts cascade a
    /// document through several hops in one step, exactly like the
    /// executor.
    fn transport_cost(&self, tier_costs: &[PerDocCosts]) -> f64 {
        let n = self.n;
        let k = self.k;
        let mut mass = vec![0.0f64; self.num_tiers()];
        let mut total = 0.0;
        for j in 0..self.cuts.len() {
            let (lo, hi) = self.band(TierId(j));
            mass[j] += (hi - lo) as f64;
            let t = self.cuts[j];
            if self.migrate[j] && t > 0 && t < n && mass[j] > 0.0 {
                let live = t.min(k) as f64;
                let moved = live * mass[j] / t as f64;
                total += moved * (tier_costs[j].read + tier_costs[j + 1].write);
                mass[j + 1] += mass[j];
                mass[j] = 0.0;
            }
        }
        total
    }

    /// Integrated expected rent of a migrate-schedule plan: segment the
    /// stream at the distinct cut values; within a segment the fired
    /// boundary set is fixed, so each completed band's live mass sits at a
    /// fixed cascade target while the active band grows linearly. Uses
    /// the same `min(t, K)/t` i.u.d. kernel as the no-migration occupancy
    /// integral; with no flags set it reduces to exactly that integral.
    fn migrate_rent(&self, tier_costs: &[PerDocCosts]) -> f64 {
        let (n, k) = (self.n, self.k);
        let nf = n as f64;
        let m = self.num_tiers();
        let mut bps: Vec<u64> =
            self.cuts.iter().copied().filter(|&c| c > 0 && c < n).collect();
        bps.push(n);
        bps.sort_unstable();
        bps.dedup();
        let mut total = 0.0;
        let mut lo_seg = 0u64;
        for &hi_seg in &bps {
            if hi_seg <= lo_seg {
                continue;
            }
            // band owning [lo_seg, hi_seg): constant within the segment
            let active = self.tier_for(lo_seg).0;
            // completed bands sit at their cascade target (boundaries
            // `< active` have all fired by lo_seg)
            let mut mass = vec![0.0f64; m];
            for j in 0..active {
                let (blo, bhi) = self.band(TierId(j));
                if bhi <= blo {
                    continue;
                }
                let mut q = j;
                while q < active && self.migrate[q] {
                    q += 1;
                }
                mass[q] += (bhi - blo) as f64;
            }
            let f2 = int_min_tk_over_t(lo_seg as f64, hi_seg as f64, k);
            let f1 = int_min_tk(lo_seg as f64, hi_seg as f64, k);
            for (q, &mq) in mass.iter().enumerate() {
                if mq > 0.0 {
                    total += tier_costs[q].rent_window * mq * f2 / nf;
                }
            }
            // the active band's live length is t − band_lo
            let (band_lo, _) = self.band(TierId(active));
            total += tier_costs[active].rent_window * (f1 - band_lo as f64 * f2) / nf;
            lo_seg = hi_seg;
        }
        total
    }
}

/// `∫₀ᴺ occ_band(t) dt / N` in doc-windows, where the expected number of
/// live documents from band `[lo, hi)` at observation time `t` is
/// `min(t, K) · (min(hi, t) − lo)⁺ / t` (current top-K i.u.d. over `0..t`).
fn band_occupancy_time(lo: u64, hi: u64, n: u64, k: u64) -> f64 {
    if hi <= lo || n == 0 {
        return 0.0;
    }
    let (lo, hi, nf) = (lo as f64, hi as f64, n as f64);
    // inside the band: ∫ min(t,K)(t−lo)/t dt = F1 − lo·F2
    let inside = int_min_tk(lo, hi, k) - lo * int_min_tk_over_t(lo, hi, k);
    // after the band: ∫ min(t,K)(hi−lo)/t dt
    let after = (hi - lo) * int_min_tk_over_t(hi, nf, k);
    (inside + after) / nf
}

/// `∫_a^b min(t, K) dt` for `0 ≤ a ≤ b`.
fn int_min_tk(a: f64, b: f64, k: u64) -> f64 {
    let kf = k as f64;
    if b <= kf {
        0.5 * (b * b - a * a)
    } else if a >= kf {
        kf * (b - a)
    } else {
        0.5 * (kf * kf - a * a) + kf * (b - kf)
    }
}

/// `∫_a^b min(t, K)/t dt` for `0 ≤ a ≤ b` (the integrand is 1 below K).
fn int_min_tk_over_t(a: f64, b: f64, k: u64) -> f64 {
    let kf = k as f64;
    if b <= a {
        0.0
    } else if b <= kf {
        b - a
    } else if a >= kf {
        if a <= 0.0 { 0.0 } else { kf * (b / a).ln() }
    } else {
        (kf - a) + kf * (b / kf).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::optimal_r;

    fn costs(w: f64, r: f64, s: f64) -> PerDocCosts {
        PerDocCosts { write: w, read: r, rent_window: s }
    }

    #[test]
    fn two_tier_degenerates_to_changeover() {
        let p = PlacementPlan::two_tier(10, 100, 5);
        assert_eq!(p.num_tiers(), 2);
        assert_eq!(p.tier_for(9), TierId::A);
        assert_eq!(p.tier_for(10), TierId::B);
        assert_eq!(p.band(TierId::A), (0, 10));
        assert_eq!(p.band(TierId::B), (10, 100));
        assert_eq!(p.demand(TierId::A), 5); // min(10, K=5)
        assert_eq!(p.strategy(), Some(Strategy::Changeover { r: 10 }));
        assert_eq!(p.family(), PlanFamily::Keep);
        let m = PlacementPlan::two_tier_migrate(10, 100, 5);
        assert!(m.migrates());
        assert!(m.migrate_at(0));
        assert_eq!(m.family(), PlanFamily::Migrate);
        assert_eq!(m.strategy(), Some(Strategy::ChangeoverMigrate { r: 10 }));
    }

    #[test]
    fn from_cuts_validates() {
        assert!(PlacementPlan::from_cuts(vec![], 10, 1).is_err());
        assert!(PlacementPlan::from_cuts(vec![11], 10, 1).is_err());
        assert!(PlacementPlan::from_cuts(vec![5, 3], 10, 1).is_err());
        assert!(PlacementPlan::from_cuts(vec![3], 10, 0).is_err());
        let p = PlacementPlan::from_cuts(vec![3, 7], 10, 2).unwrap();
        assert_eq!(p.num_tiers(), 3);
        assert_eq!(p.tier_for(2), TierId(0));
        assert_eq!(p.tier_for(3), TierId(1));
        assert_eq!(p.tier_for(7), TierId(2));
        assert_eq!(p.band(TierId(1)), (3, 7));
    }

    #[test]
    fn migrate_arity_mismatch_is_a_construction_error() {
        // the old encoding silently masked the migrate flag beyond two
        // tiers; a schedule that does not match the hierarchy now errors
        assert!(PlacementPlan::from_cuts_migrate(vec![3, 7], vec![true], 10, 2).is_err());
        assert!(
            PlacementPlan::from_cuts_migrate(vec![3], vec![true, false], 10, 2).is_err()
        );
        let p =
            PlacementPlan::from_cuts_migrate(vec![3, 7], vec![true, false], 10, 2).unwrap();
        assert!(p.migrates());
        assert!(p.migrate_at(0));
        assert!(!p.migrate_at(1));
        // and a >2-tier migrate schedule is honored, not dropped
        assert_eq!(p.migrate_flags(), &[true, false]);
    }

    #[test]
    fn final_tier_follows_the_cascade() {
        let p = PlacementPlan::from_cuts_migrate(
            vec![10, 40, 70],
            vec![true, true, false],
            100,
            8,
        )
        .unwrap();
        // band 0 cascades through both flagged boundaries into tier 2
        assert_eq!(p.final_tier(TierId(0)), TierId(2));
        assert_eq!(p.final_tier(TierId(1)), TierId(2));
        assert_eq!(p.final_tier(TierId(2)), TierId(2));
        assert_eq!(p.final_tier(TierId(3)), TierId(3));
        // a boundary at N never fires: no cascade through it
        let q = PlacementPlan::from_cuts_migrate(
            vec![10, 100, 100],
            vec![true, true, true],
            100,
            8,
        )
        .unwrap();
        assert_eq!(q.final_tier(TierId(0)), TierId(1));
    }

    #[test]
    fn demand_accounts_for_cascading_demotions() {
        // keep plan: each tier's demand is its own band width capped at K
        let keep = PlacementPlan::from_cuts(vec![30, 40], 100, 20).unwrap();
        assert_eq!(keep.demand(TierId(1)), 10);
        // migrate plan: tier 1 receives band 0's bulk demotion at i=30 —
        // just before its own boundary it can hold every live document
        // with index < 40, i.e. min(40, K) residents
        let mig = keep.clone().with_migration();
        assert_eq!(mig.demand(TierId(0)), 20); // min(30, K) unchanged
        assert_eq!(mig.demand(TierId(1)), 20); // min(40, K), not min(10, K)
        // a non-demoting hotter boundary breaks the cascade
        let partial =
            PlacementPlan::from_cuts_migrate(vec![30, 40], vec![false, true], 100, 20)
                .unwrap();
        assert_eq!(partial.demand(TierId(1)), 10);
    }

    #[test]
    fn clamp_matches_two_tier_budget_clamp() {
        // demand = min(r, K) = 20 > quota 4 → r = quota
        let mut p = PlacementPlan::two_tier(50, 200, 20);
        p.clamp_tier_to_quota(TierId::A, 4);
        assert_eq!(p.r(), 4);
        // quota already satisfied → untouched
        let mut q = PlacementPlan::two_tier(50, 200, 20);
        q.clamp_tier_to_quota(TierId::A, 20);
        assert_eq!(q.r(), 50);
        // the coldest tier is never clamped
        let mut c = PlacementPlan::two_tier(50, 200, 20);
        c.clamp_tier_to_quota(TierId::B, 1);
        assert_eq!(c.r(), 50);
    }

    #[test]
    fn clamp_middle_tier_preserves_monotonicity() {
        let mut p = PlacementPlan::from_cuts(vec![10, 40], 100, 30).unwrap();
        // tier 1 band [10, 40): demand min(30, 30) = 30 > 5 → hi = 10 + 5
        p.clamp_tier_to_quota(TierId(1), 5);
        assert_eq!(p.cuts(), &[10, 15]);
        assert_eq!(p.demand(TierId(1)), 5);
        // displaced indices now belong to the coldest tier
        assert_eq!(p.tier_for(20), TierId(2));
    }

    #[test]
    fn clamp_cut_at_most_caps_the_prefix() {
        let mut p = PlacementPlan::from_cuts(vec![10, 40, 60], 100, 30).unwrap();
        p.clamp_cut_at_most(1, 25);
        assert_eq!(p.cuts(), &[10, 25, 60]);
        // a cap below a hotter cut pulls the whole prefix down (monotone)
        let mut q = PlacementPlan::from_cuts(vec![30, 40, 60], 100, 30).unwrap();
        q.clamp_cut_at_most(1, 20);
        assert_eq!(q.cuts(), &[20, 20, 60]);
    }

    #[test]
    fn optimal_two_tier_matches_optimal_r() {
        let a = costs(1e-6, 1e-4, 0.0);
        let b = costs(5e-5, 1e-6, 0.0);
        let p = PlacementPlan::optimal(&[a, b], 100_000, 100, false);
        let m = CostModel::new(100_000, 100, a, b).with_rent(false);
        assert_eq!(p.r(), optimal_r(&m, false).r);
        // and the analytic cost agrees with the closed form exactly
        let want = expected_cost(&m, Strategy::Changeover { r: p.r() }).total();
        assert!((p.analytic_cost(&[a, b], false) - want).abs() < 1e-12);
    }

    #[test]
    fn optimal_migrate_two_tier_matches_optimal_r_migrate() {
        // rent-dominated economics with an interior migrate optimum
        let a = costs(0.0, 0.0, 7e-5);
        let b = costs(5e-6, 5e-6, 5.4e-6);
        let m = CostModel::new(100_000, 100, a, b);
        let p = PlacementPlan::optimal_migrate(&[a, b], 100_000, 100, true);
        assert!(p.migrates());
        assert_eq!(p.r(), optimal_r(&m, true).r);
        let want = expected_cost(&m, Strategy::ChangeoverMigrate { r: p.r() }).total();
        assert!((p.analytic_cost(&[a, b], true) - want).abs() < 1e-12);
    }

    #[test]
    fn optimal_family_auto_picks_the_cheaper() {
        // rent-dominated: migrate wins
        let a = costs(0.0, 0.0, 1.2);
        let b = costs(0.2, 0.01, 0.2);
        let p = PlacementPlan::optimal_family(&[a, b], 2000, 32, true, PlanFamily::Auto);
        assert!(p.migrates(), "auto must pick the migrate family here");
        // transaction-dominated, rent excluded: keep wins (migration is a
        // pure extra charge)
        let a = costs(1e-6, 1e-4, 0.0);
        let b = costs(5e-5, 1e-6, 0.0);
        let q = PlacementPlan::optimal_family(&[a, b], 100_000, 100, false, PlanFamily::Auto);
        assert!(!q.migrates(), "auto must pick the keep family here");
    }

    #[test]
    fn optimal_three_tier_is_monotone() {
        // hot cheap to write / dear to read, warm intermediate, cold reverse
        let tiers = [
            costs(1.0, 4.0, 0.0),
            costs(2.0, 1.5, 0.0),
            costs(3.0, 0.5, 0.0),
        ];
        let p = PlacementPlan::optimal(&tiers, 1000, 20, false);
        assert_eq!(p.num_tiers(), 3);
        assert!(p.cuts()[0] <= p.cuts()[1]);
        assert!(p.cuts()[1] <= 1000);
    }

    #[test]
    fn three_tier_analytic_conserves_writes_and_reads() {
        let tiers = [costs(1.0, 0.0, 0.0), costs(1.0, 0.0, 0.0), costs(1.0, 0.0, 0.0)];
        let p = PlacementPlan::from_cuts(vec![100, 400], 1000, 10).unwrap();
        // identical unit write costs → total = expected writes over the stream
        let total = p.analytic_cost(&tiers, false);
        assert!((total - expected_writes(1000, 10)).abs() < 1e-9);
        // unit read costs → total = K
        let reads = [costs(0.0, 1.0, 0.0), costs(0.0, 1.0, 0.0), costs(0.0, 1.0, 0.0)];
        assert!((p.analytic_cost(&reads, false) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn three_tier_migrate_transport_and_reads() {
        // unit write in every tier, read free: transport = expected moved
        // docs × (read_src + write_dst) = moved × 1
        let w = [costs(1.0, 0.0, 0.0), costs(1.0, 0.0, 0.0), costs(1.0, 0.0, 0.0)];
        let keep = PlacementPlan::from_cuts(vec![100, 400], 1000, 10).unwrap();
        let mig = keep.clone().with_migration();
        let extra = mig.analytic_cost(&w, false) - keep.analytic_cost(&w, false);
        // boundary 0 at t=100: min(100,10)·100/100 = 10 docs; boundary 1
        // at t=400: 10·400/400 = 10 docs; each hop pays the unit write
        assert!((extra - 20.0).abs() < 1e-9, "transport extra = {extra}");
        // reads: every band's survivor is served by the coldest tier (the
        // final K reads cost 10 × 1), while each demotion hop pays its
        // source read: 10 docs × $4 at boundary 0, 10 docs × $2 at
        // boundary 1
        let reads = [costs(0.0, 4.0, 0.0), costs(0.0, 2.0, 0.0), costs(0.0, 1.0, 0.0)];
        let r = mig.analytic_cost(&reads, false);
        assert!(
            (r - (10.0 + 40.0 + 20.0)).abs() < 1e-9,
            "sink reads + demotion reads: {r}"
        );
    }

    #[test]
    fn three_tier_rent_is_bounded_by_k() {
        // unit rent everywhere: total resident doc-time ≤ K doc-windows
        let rents = [costs(0.0, 0.0, 1.0), costs(0.0, 0.0, 1.0), costs(0.0, 0.0, 1.0)];
        let p = PlacementPlan::from_cuts(vec![50, 300], 1000, 25).unwrap();
        let rent = p.analytic_cost(&rents, true);
        assert!(rent > 0.0);
        assert!(rent <= 25.0 + 1e-9, "rent {rent} exceeds K doc-windows");
        // a migrate schedule shuffles docs between tiers but conserves the
        // total resident doc-time (unit rent everywhere → identical total)
        let pm = p.clone().with_migration();
        let rent_m = pm.analytic_cost(&rents, true);
        assert!(
            (rent - rent_m).abs() < 1e-9,
            "unit-rent totals must agree: keep {rent} vs migrate {rent_m}"
        );
    }

    #[test]
    fn migrate_rent_moves_occupancy_to_colder_tiers() {
        // rent only in the hot tier: demoting at the boundary must cut the
        // bill vs keeping residents hot to the end
        let rents = [costs(0.0, 0.0, 1.0), costs(0.0, 0.0, 0.0), costs(0.0, 0.0, 0.0)];
        let keep = PlacementPlan::from_cuts(vec![100, 400], 1000, 10).unwrap();
        let mig = keep.clone().with_migration();
        let keep_rent = keep.analytic_cost(&rents, true);
        let mig_rent = mig.analytic_cost(&rents, true);
        assert!(
            mig_rent < keep_rent,
            "demotion must cut hot rent ({mig_rent} !< {keep_rent})"
        );
    }

    #[test]
    fn occupancy_integral_edges() {
        assert_eq!(band_occupancy_time(5, 5, 100, 10), 0.0);
        // whole-stream band of a K=N stream: everything resident to the end
        let full = band_occupancy_time(0, 100, 100, 100);
        assert!((full - 50.0).abs() < 1e-9); // ∫ t dt / N = N/2
    }

    #[test]
    fn plan_family_parses() {
        assert_eq!(PlanFamily::parse("keep").unwrap(), PlanFamily::Keep);
        assert_eq!(PlanFamily::parse("migrate").unwrap(), PlanFamily::Migrate);
        assert_eq!(PlanFamily::parse("auto").unwrap(), PlanFamily::Auto);
        assert!(PlanFamily::parse("chaos").is_err());
        assert_eq!(PlanFamily::Migrate.label(), "migrate");
    }
}
