//! N-tier placement plans — the generalization of the paper's two-tier
//! changeover rule.
//!
//! The paper's Algorithm C places "the first `r` documents in A, the rest
//! in B". Over an ordered hierarchy of `m` tiers (hot → cold) the natural
//! generalization is a vector of `m − 1` *changeover indices* (one per tier
//! boundary): document index `i` lands in the first tier `j` whose cut
//! `cuts[j]` exceeds `i`, i.e. tier `j` owns the index band
//! `[cuts[j−1], cuts[j])` (with `cuts[−1] = 0` and `cuts[m−1] = N`
//! implicit). A two-tier plan `cuts = [r]` degenerates exactly to
//! [`super::Changeover`] / [`super::QuotaChangeover`]; the optional
//! `migrate` flag reproduces the DO_MIGRATE family in the two-tier case.
//!
//! The closed-form machinery carries over band-by-band: expected writes
//! into tier `j` are `W(cuts[j]) − W(cuts[j−1])` (harmonic sums, eq. 11),
//! a survivor is read from tier `j` with probability `width_j / N`
//! (the i.u.d. assumption behind eq. 15), and each band's rent is the
//! integrated expected occupancy of the band. For `m = 2` the plan's
//! analytic cost delegates to [`crate::cost::expected_cost`] so the
//! degenerate case is bit-identical with the pre-engine code paths.

use crate::cost::{
    expected_cost, expected_writes, optimal_cuts, CostModel, PerDocCosts, Strategy,
};
use crate::storage::TierId;
use anyhow::{bail, Result};

/// An N-tier proactive placement plan: nondecreasing changeover indices,
/// one per tier boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct PlacementPlan {
    /// Changeover index per tier boundary (`len = num_tiers − 1`),
    /// nondecreasing, each in `[0, n]`.
    cuts: Vec<u64>,
    /// Stream length.
    n: u64,
    /// Retained-set size (top-K).
    k: u64,
    /// Two-tier only: bulk-migrate all hot residents at `i == cuts[0]`
    /// (the paper's DO_MIGRATE family). Ignored for `num_tiers > 2`.
    migrate: bool,
}

impl PlacementPlan {
    /// Validated construction from raw cuts.
    pub fn from_cuts(cuts: Vec<u64>, n: u64, k: u64) -> Result<Self> {
        if cuts.is_empty() {
            bail!("placement plan needs at least one changeover index (two tiers)");
        }
        if n == 0 || k == 0 || k > n {
            bail!("placement plan requires 0 < K <= N (got K={k}, N={n})");
        }
        let mut prev = 0u64;
        for (j, &c) in cuts.iter().enumerate() {
            if c > n {
                bail!("cut {j} = {c} exceeds stream length {n}");
            }
            if c < prev {
                bail!("cuts must be nondecreasing (cut {j} = {c} < {prev})");
            }
            prev = c;
        }
        Ok(Self { cuts, n, k, migrate: false })
    }

    /// The paper's two-tier changeover at `r` (no migration).
    pub fn two_tier(r: u64, n: u64, k: u64) -> Self {
        Self { cuts: vec![r.min(n)], n, k: k.min(n).max(1), migrate: false }
    }

    /// The paper's two-tier changeover-with-migration at `r`.
    pub fn two_tier_migrate(r: u64, n: u64, k: u64) -> Self {
        Self { migrate: true, ..Self::two_tier(r, n, k) }
    }

    /// Closed-form optimal plan for a tier hierarchy: each boundary's cut is
    /// the two-tier optimum between its adjacent tiers
    /// ([`crate::cost::optimal_cuts`]), made nondecreasing by a running
    /// maximum (a document never returns to a hotter tier later in the
    /// stream). For two tiers this *is* `r*`.
    pub fn optimal(tier_costs: &[PerDocCosts], n: u64, k: u64, include_rent: bool) -> Self {
        assert!(tier_costs.len() >= 2, "need at least two tiers");
        let k = k.min(n).max(1);
        let cuts = optimal_cuts(tier_costs, n, k, include_rent);
        Self { cuts, n, k, migrate: false }
    }

    pub fn num_tiers(&self) -> usize {
        self.cuts.len() + 1
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn k(&self) -> u64 {
        self.k
    }

    pub fn cuts(&self) -> &[u64] {
        &self.cuts
    }

    pub fn migrates(&self) -> bool {
        self.migrate && self.num_tiers() == 2
    }

    /// The two-tier changeover parameter (first cut) — the quantity
    /// reported as `r` everywhere in the two-tier world.
    pub fn r(&self) -> u64 {
        self.cuts[0]
    }

    /// Index band `[lo, hi)` owned by `tier`.
    pub fn band(&self, tier: TierId) -> (u64, u64) {
        let lo = if tier.0 == 0 { 0 } else { self.cuts[tier.0 - 1] };
        let hi = if tier.0 == self.cuts.len() { self.n } else { self.cuts[tier.0] };
        (lo, hi)
    }

    /// Proactive tier for stream index `i` (hotter tiers own earlier bands).
    pub fn tier_for(&self, index: u64) -> TierId {
        for (j, &c) in self.cuts.iter().enumerate() {
            if index < c {
                return TierId(j);
            }
        }
        TierId(self.cuts.len())
    }

    /// Peak simultaneous residents `tier` can see from this stream:
    /// `min(band width, K)` (the live set is the current top-K, and only
    /// band indices are ever written there).
    pub fn demand(&self, tier: TierId) -> u64 {
        let (lo, hi) = self.band(tier);
        (hi - lo).min(self.k)
    }

    /// Shrink `tier`'s band until its demand fits `quota`, pushing the
    /// displaced indices into the next colder tier. The two-tier case
    /// reproduces the arbiter's budget clamp (`r = quota` whenever
    /// `min(r, K) > quota`). Bands of later tiers are untouched (their cuts
    /// only ever move down, preserving monotonicity).
    pub fn clamp_tier_to_quota(&mut self, tier: TierId, quota: u64) {
        if tier.0 >= self.cuts.len() {
            return; // the coldest tier is the overflow sink — never clamped
        }
        if self.demand(tier) <= quota {
            return;
        }
        let (lo, _) = self.band(tier);
        self.cuts[tier.0] = lo + quota;
    }

    /// The degenerate two-tier [`Strategy`], if this is a two-tier plan.
    pub fn strategy(&self) -> Option<Strategy> {
        if self.num_tiers() != 2 {
            return None;
        }
        Some(if self.migrate {
            Strategy::ChangeoverMigrate { r: self.cuts[0] }
        } else {
            Strategy::Changeover { r: self.cuts[0] }
        })
    }

    /// Analytic expected total cost of running this plan over `tier_costs`.
    ///
    /// Two-tier plans delegate to [`crate::cost::expected_cost`] (exact
    /// degenerate compatibility); N > 2 uses the band generalization:
    /// harmonic write sums per band, `width/N` read split, and the
    /// integrated expected band occupancy for rent.
    pub fn analytic_cost(&self, tier_costs: &[PerDocCosts], include_rent: bool) -> f64 {
        assert_eq!(tier_costs.len(), self.num_tiers(), "cost entries must match tiers");
        if self.num_tiers() == 2 {
            let model = CostModel::new(self.n, self.k, tier_costs[0], tier_costs[1])
                .with_rent(include_rent);
            return expected_cost(&model, self.strategy().unwrap()).total();
        }
        let (n, k) = (self.n, self.k);
        let kf = k as f64;
        let nf = n as f64;
        let mut total = 0.0;
        for (j, costs) in tier_costs.iter().enumerate() {
            let (lo, hi) = self.band(TierId(j));
            // writes: harmonic band sum (paper eq. 11 per band)
            let w = expected_writes(hi, k) - expected_writes(lo, k);
            total += w * costs.write;
            // reads: survivor lands in the band w.p. width/N (eq. 15 i.u.d.)
            total += kf * ((hi - lo) as f64 / nf) * costs.read;
            // rent: integrated expected occupancy of the band
            if include_rent {
                total += band_occupancy_time(lo, hi, n, k) * costs.rent_window;
            }
        }
        total
    }
}

/// `∫₀ᴺ occ_band(t) dt / N` in doc-windows, where the expected number of
/// live documents from band `[lo, hi)` at observation time `t` is
/// `min(t, K) · (min(hi, t) − lo)⁺ / t` (current top-K i.u.d. over `0..t`).
fn band_occupancy_time(lo: u64, hi: u64, n: u64, k: u64) -> f64 {
    if hi <= lo || n == 0 {
        return 0.0;
    }
    let (lo, hi, nf) = (lo as f64, hi as f64, n as f64);
    // inside the band: ∫ min(t,K)(t−lo)/t dt = F1 − lo·F2
    let inside = int_min_tk(lo, hi, k) - lo * int_min_tk_over_t(lo, hi, k);
    // after the band: ∫ min(t,K)(hi−lo)/t dt
    let after = (hi - lo) * int_min_tk_over_t(hi, nf, k);
    (inside + after) / nf
}

/// `∫_a^b min(t, K) dt` for `0 ≤ a ≤ b`.
fn int_min_tk(a: f64, b: f64, k: u64) -> f64 {
    let kf = k as f64;
    if b <= kf {
        0.5 * (b * b - a * a)
    } else if a >= kf {
        kf * (b - a)
    } else {
        0.5 * (kf * kf - a * a) + kf * (b - kf)
    }
}

/// `∫_a^b min(t, K)/t dt` for `0 ≤ a ≤ b` (the integrand is 1 below K).
fn int_min_tk_over_t(a: f64, b: f64, k: u64) -> f64 {
    let kf = k as f64;
    if b <= a {
        0.0
    } else if b <= kf {
        b - a
    } else if a >= kf {
        if a <= 0.0 { 0.0 } else { kf * (b / a).ln() }
    } else {
        (kf - a) + kf * (b / kf).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::optimal_r;

    fn costs(w: f64, r: f64, s: f64) -> PerDocCosts {
        PerDocCosts { write: w, read: r, rent_window: s }
    }

    #[test]
    fn two_tier_degenerates_to_changeover() {
        let p = PlacementPlan::two_tier(10, 100, 5);
        assert_eq!(p.num_tiers(), 2);
        assert_eq!(p.tier_for(9), TierId::A);
        assert_eq!(p.tier_for(10), TierId::B);
        assert_eq!(p.band(TierId::A), (0, 10));
        assert_eq!(p.band(TierId::B), (10, 100));
        assert_eq!(p.demand(TierId::A), 5); // min(10, K=5)
        assert_eq!(p.strategy(), Some(Strategy::Changeover { r: 10 }));
        let m = PlacementPlan::two_tier_migrate(10, 100, 5);
        assert!(m.migrates());
        assert_eq!(m.strategy(), Some(Strategy::ChangeoverMigrate { r: 10 }));
    }

    #[test]
    fn from_cuts_validates() {
        assert!(PlacementPlan::from_cuts(vec![], 10, 1).is_err());
        assert!(PlacementPlan::from_cuts(vec![11], 10, 1).is_err());
        assert!(PlacementPlan::from_cuts(vec![5, 3], 10, 1).is_err());
        assert!(PlacementPlan::from_cuts(vec![3], 10, 0).is_err());
        let p = PlacementPlan::from_cuts(vec![3, 7], 10, 2).unwrap();
        assert_eq!(p.num_tiers(), 3);
        assert_eq!(p.tier_for(2), TierId(0));
        assert_eq!(p.tier_for(3), TierId(1));
        assert_eq!(p.tier_for(7), TierId(2));
        assert_eq!(p.band(TierId(1)), (3, 7));
    }

    #[test]
    fn clamp_matches_two_tier_budget_clamp() {
        // demand = min(r, K) = 20 > quota 4 → r = quota
        let mut p = PlacementPlan::two_tier(50, 200, 20);
        p.clamp_tier_to_quota(TierId::A, 4);
        assert_eq!(p.r(), 4);
        // quota already satisfied → untouched
        let mut q = PlacementPlan::two_tier(50, 200, 20);
        q.clamp_tier_to_quota(TierId::A, 20);
        assert_eq!(q.r(), 50);
        // the coldest tier is never clamped
        let mut c = PlacementPlan::two_tier(50, 200, 20);
        c.clamp_tier_to_quota(TierId::B, 1);
        assert_eq!(c.r(), 50);
    }

    #[test]
    fn clamp_middle_tier_preserves_monotonicity() {
        let mut p = PlacementPlan::from_cuts(vec![10, 40], 100, 30).unwrap();
        // tier 1 band [10, 40): demand min(30, 30) = 30 > 5 → hi = 10 + 5
        p.clamp_tier_to_quota(TierId(1), 5);
        assert_eq!(p.cuts(), &[10, 15]);
        assert_eq!(p.demand(TierId(1)), 5);
        // displaced indices now belong to the coldest tier
        assert_eq!(p.tier_for(20), TierId(2));
    }

    #[test]
    fn optimal_two_tier_matches_optimal_r() {
        let a = costs(1e-6, 1e-4, 0.0);
        let b = costs(5e-5, 1e-6, 0.0);
        let p = PlacementPlan::optimal(&[a, b], 100_000, 100, false);
        let m = CostModel::new(100_000, 100, a, b).with_rent(false);
        assert_eq!(p.r(), optimal_r(&m, false).r);
        // and the analytic cost agrees with the closed form exactly
        let want = expected_cost(&m, Strategy::Changeover { r: p.r() }).total();
        assert!((p.analytic_cost(&[a, b], false) - want).abs() < 1e-12);
    }

    #[test]
    fn optimal_three_tier_is_monotone() {
        // hot cheap to write / dear to read, warm intermediate, cold reverse
        let tiers = [
            costs(1.0, 4.0, 0.0),
            costs(2.0, 1.5, 0.0),
            costs(3.0, 0.5, 0.0),
        ];
        let p = PlacementPlan::optimal(&tiers, 1000, 20, false);
        assert_eq!(p.num_tiers(), 3);
        assert!(p.cuts()[0] <= p.cuts()[1]);
        assert!(p.cuts()[1] <= 1000);
    }

    #[test]
    fn three_tier_analytic_conserves_writes_and_reads() {
        let tiers = [costs(1.0, 0.0, 0.0), costs(1.0, 0.0, 0.0), costs(1.0, 0.0, 0.0)];
        let p = PlacementPlan::from_cuts(vec![100, 400], 1000, 10).unwrap();
        // identical unit write costs → total = expected writes over the stream
        let total = p.analytic_cost(&tiers, false);
        assert!((total - expected_writes(1000, 10)).abs() < 1e-9);
        // unit read costs → total = K
        let reads = [costs(0.0, 1.0, 0.0), costs(0.0, 1.0, 0.0), costs(0.0, 1.0, 0.0)];
        assert!((p.analytic_cost(&reads, false) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn three_tier_rent_is_bounded_by_k() {
        // unit rent everywhere: total resident doc-time ≤ K doc-windows
        let rents = [costs(0.0, 0.0, 1.0), costs(0.0, 0.0, 1.0), costs(0.0, 0.0, 1.0)];
        let p = PlacementPlan::from_cuts(vec![50, 300], 1000, 25).unwrap();
        let rent = p.analytic_cost(&rents, true);
        assert!(rent > 0.0);
        assert!(rent <= 25.0 + 1e-9, "rent {rent} exceeds K doc-windows");
    }

    #[test]
    fn occupancy_integral_edges() {
        assert_eq!(band_occupancy_time(5, 5, 100, 10), 0.0);
        // whole-stream band of a K=N stream: everything resident to the end
        let full = band_occupancy_time(0, 100, 100, 100);
        assert!((full - 50.0).abs() < 1e-9); // ∫ t dt / N = N/2
    }
}
