//! The paper's proactive policies (Algorithm C, Fig. 3) and the
//! single-tier baselines of Tables I–II.

use super::{MigrationOrder, PlacementPolicy};
use crate::storage::{StorageBackend, TierId};

/// Everything to one tier (Table I/II "Cost all storage A/B" rows).
#[derive(Debug, Clone, Copy)]
pub struct SingleTier {
    tier: TierId,
}

impl SingleTier {
    pub fn new(tier: TierId) -> Self {
        Self { tier }
    }
}

impl PlacementPolicy for SingleTier {
    fn name(&self) -> String {
        format!("all-{}", self.tier.label())
    }

    fn place(&mut self, _index: u64, _n: u64) -> TierId {
        self.tier
    }
}

/// "First r to A, the rest to B", DO_MIGRATE = false (paper Fig. 3).
#[derive(Debug, Clone, Copy)]
pub struct Changeover {
    r: u64,
}

impl Changeover {
    pub fn new(r: u64) -> Self {
        Self { r }
    }

    pub fn r(&self) -> u64 {
        self.r
    }
}

impl PlacementPolicy for Changeover {
    fn name(&self) -> String {
        format!("changeover(r={})", self.r)
    }

    fn place(&mut self, index: u64, _n: u64) -> TierId {
        if index < self.r {
            TierId::A
        } else {
            TierId::B
        }
    }
}

/// "First r to A, the rest to B", DO_MIGRATE = true: at `i == r` every
/// resident of A is bulk-migrated to B (paper Fig. 3).
#[derive(Debug, Clone, Copy)]
pub struct ChangeoverMigrate {
    r: u64,
    migrated: bool,
}

impl ChangeoverMigrate {
    pub fn new(r: u64) -> Self {
        Self { r, migrated: false }
    }
}

impl PlacementPolicy for ChangeoverMigrate {
    fn name(&self) -> String {
        format!("changeover+migrate(r={})", self.r)
    }

    fn place(&mut self, index: u64, _n: u64) -> TierId {
        if index < self.r {
            TierId::A
        } else {
            TierId::B
        }
    }

    fn on_step(
        &mut self,
        index: u64,
        _n: u64,
        _storage: &dyn StorageBackend,
    ) -> Vec<MigrationOrder> {
        if !self.migrated && index >= self.r {
            self.migrated = true;
            vec![MigrationOrder::All { from: TierId::A, to: TierId::B }]
        } else {
            Vec::new()
        }
    }
}
