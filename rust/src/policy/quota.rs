//! Quota-constrained variants of the proactive policies.
//!
//! Under shared hot-tier capacity (the fleet regime) a stream is assigned a
//! *hot quota*: the maximum number of its documents that may be resident in
//! tier A simultaneously. These policies keep the paper's "first r to A"
//! structure but degrade over-quota placements to tier B instead of
//! rejecting the write — the arbiter's degradation-over-rejection rule.
//!
//! With `r ≤ quota` the quota can never bind (hot residency is at most
//! `min(r, K)`) and the policies coincide exactly with
//! [`super::Changeover`] / [`super::ChangeoverMigrate`]. With `r > quota`
//! they fill the quota's hot slots and spill the rest cold — the
//! occupancy resync is one step conservative, so the cap is never
//! exceeded. [`QuotaChangeover::budgeted`] picks `r` via
//! [`crate::cost::optimal_r_budgeted`], which clamps `r = quota` whenever
//! the unconstrained optimum's demand `min(r*, K)` would not fit.
//!
//! The occupancy count is resynced from the storage backend after every
//! step (`on_step`), so single-stream runs track tier-A residency exactly.
//! On a shared backend the engine's session state
//! ([`crate::engine::StreamSession`]) tracks per-stream counts itself and
//! applies the same quota-degradation rule through its N-tier
//! [`super::PlacementPlan`]; [`QuotaChangeover::wants_hot`] remains the
//! two-tier reference form.

use super::{MigrationOrder, PlacementPolicy};
use crate::cost::{optimal_r_budgeted, CostModel};
use crate::storage::{StorageBackend, TierId};

/// "First r to A, the rest to B", with at most `quota` simultaneous hot
/// residents; over-quota placements degrade to B. No migration.
#[derive(Debug, Clone, Copy)]
pub struct QuotaChangeover {
    r: u64,
    quota: usize,
    hot_in_use: usize,
}

impl QuotaChangeover {
    pub fn new(r: u64, quota: usize) -> Self {
        Self { r, quota, hot_in_use: 0 }
    }

    /// Configure from a cost model and a hot-tier budget: recomputes the
    /// changeover point under the shrunken budget (the arbiter's rule).
    pub fn budgeted(model: &CostModel, hot_quota: u64) -> Self {
        Self::new(optimal_r_budgeted(model, false, hot_quota).r, hot_quota as usize)
    }

    pub fn r(&self) -> u64 {
        self.r
    }

    pub fn quota(&self) -> usize {
        self.quota
    }

    /// The placement rule, exposed for callers that track occupancy
    /// themselves (the fleet stream runner).
    pub fn wants_hot(r: u64, quota: usize, index: u64, hot_in_use: usize) -> bool {
        index < r && hot_in_use < quota
    }
}

impl PlacementPolicy for QuotaChangeover {
    fn name(&self) -> String {
        format!("changeover(r={},q={})", self.r, self.quota)
    }

    fn place(&mut self, index: u64, _n: u64) -> TierId {
        if Self::wants_hot(self.r, self.quota, index, self.hot_in_use) {
            self.hot_in_use += 1;
            TierId::A
        } else {
            TierId::B
        }
    }

    fn on_step(
        &mut self,
        _index: u64,
        _n: u64,
        storage: &dyn StorageBackend,
    ) -> Vec<MigrationOrder> {
        // Resync with actual residency: evictions free hot slots for later
        // (still index < r) documents. Between resyncs the internal count
        // only over-estimates, so the quota is never exceeded.
        self.hot_in_use = storage.resident_len(TierId::A);
        Vec::new()
    }
}

/// Quota-constrained changeover with bulk migration at `i == r` (paper
/// Fig. 3 DO_MIGRATE, fleet-degraded form).
#[derive(Debug, Clone, Copy)]
pub struct QuotaChangeoverMigrate {
    r: u64,
    quota: usize,
    hot_in_use: usize,
    migrated: bool,
}

impl QuotaChangeoverMigrate {
    pub fn new(r: u64, quota: usize) -> Self {
        Self { r, quota, hot_in_use: 0, migrated: false }
    }

    /// Configure from a cost model and a hot-tier budget.
    pub fn budgeted(model: &CostModel, hot_quota: u64) -> Self {
        Self::new(optimal_r_budgeted(model, true, hot_quota).r, hot_quota as usize)
    }
}

impl PlacementPolicy for QuotaChangeoverMigrate {
    fn name(&self) -> String {
        format!("changeover+migrate(r={},q={})", self.r, self.quota)
    }

    fn place(&mut self, index: u64, _n: u64) -> TierId {
        if !self.migrated
            && QuotaChangeover::wants_hot(self.r, self.quota, index, self.hot_in_use)
        {
            self.hot_in_use += 1;
            TierId::A
        } else {
            TierId::B
        }
    }

    fn on_step(
        &mut self,
        index: u64,
        _n: u64,
        storage: &dyn StorageBackend,
    ) -> Vec<MigrationOrder> {
        if !self.migrated && index >= self.r {
            self.migrated = true;
            self.hot_in_use = 0;
            vec![MigrationOrder::All { from: TierId::A, to: TierId::B }]
        } else {
            self.hot_in_use = storage.resident_len(TierId::A);
            Vec::new()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::{CostModel, PerDocCosts};
    use crate::policy::{run_policy, Changeover, ChangeoverMigrate, PlacementEngine};
    use crate::util::Rng;

    fn model(n: u64, k: u64) -> CostModel {
        CostModel::new(
            n,
            k,
            PerDocCosts { write: 1.0, read: 4.0, rent_window: 0.2 },
            PerDocCosts { write: 3.0, read: 0.5, rent_window: 0.1 },
        )
    }

    fn scores(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.next_f64()).collect()
    }

    #[test]
    fn budget_regime_matches_plain_changeover() {
        // The arbiter always configures r ≤ quota, where the quota can
        // never bind (hot residency ≤ min(r, K) ≤ quota) and the policy
        // must coincide exactly with the unconstrained Changeover.
        let m = model(800, 12);
        let trace = scores(800, 5);
        let mut plain = Changeover::new(300);
        let a = run_policy(&trace, &m, &mut plain).unwrap();
        let mut quota = QuotaChangeover::new(300, 300); // r ≤ quota
        let b = run_policy(&trace, &m, &mut quota).unwrap();
        assert_eq!(a.retained, b.retained);
        assert!((a.total_cost() - b.total_cost()).abs() < 1e-9);
    }

    #[test]
    fn budget_regime_matches_plain_changeover_migrate() {
        let m = model(600, 8);
        let trace = scores(600, 9);
        let mut plain = ChangeoverMigrate::new(200);
        let a = run_policy(&trace, &m, &mut plain).unwrap();
        let mut quota = QuotaChangeoverMigrate::new(200, 200); // r ≤ quota
        let b = run_policy(&trace, &m, &mut quota).unwrap();
        assert_eq!(a.retained, b.retained);
        assert!((a.total_cost() - b.total_cost()).abs() < 1e-9);
    }

    #[test]
    fn hot_occupancy_never_exceeds_quota() {
        let m = model(500, 20);
        let quota = 5usize;
        let mut p = QuotaChangeover::new(400, quota);
        let mut engine = PlacementEngine::new(&m, 500, &p, false);
        let mut rng = Rng::new(3);
        let mut ever_hot = 0usize;
        for _ in 0..500 {
            engine.observe(rng.next_f64(), &mut p).unwrap();
            let hot = engine.tier_len(TierId::A);
            assert!(hot <= quota, "hot occupancy {hot} > quota {quota}");
            ever_hot = ever_hot.max(hot);
        }
        assert_eq!(ever_hot, quota, "quota slots should actually be used");
        let result = engine.finish().unwrap();
        assert_eq!(result.retained.len(), 20);
    }

    #[test]
    fn zero_quota_degrades_fully_to_cold() {
        let m = model(300, 6);
        let trace = scores(300, 11);
        let mut p = QuotaChangeover::new(200, 0);
        let r = run_policy(&trace, &m, &mut p).unwrap();
        assert_eq!(r.ledger.tier(TierId::A).writes, 0);
        assert!(r.ledger.tier(TierId::B).writes > 0);
    }

    #[test]
    fn budgeted_constructor_clamps_r() {
        // hot-friendly economics with interior r*
        let m = CostModel::new(
            10_000,
            100,
            PerDocCosts { write: 1e-6, read: 1e-4, rent_window: 0.0 },
            PerDocCosts { write: 5e-5, read: 1e-6, rent_window: 0.0 },
        )
        .with_rent(false);
        let p = QuotaChangeover::budgeted(&m, 10);
        assert_eq!(p.r(), 10);
        assert_eq!(p.quota(), 10);
        let ample = QuotaChangeover::budgeted(&m, m.k);
        assert!(ample.r() > m.k, "ample quota keeps the unconstrained r*");
    }
}
