//! The batch trace executor: runs a placement policy against a complete
//! score trace (thin wrapper over the incremental [`PlacementEngine`]).
//!
//! This is the discrete-event realization of the paper's Fig. 3 listing:
//! rank each document online, prune the evicted one, store the accepted one
//! in the policy's tier, execute migrations, and finish with the K-document
//! consumer read.

use super::engine::PlacementEngine;
pub use super::engine::RunResult;
use super::PlacementPolicy;
use crate::cost::CostModel;
use anyhow::Result;

/// Run `policy` over `scores` with the economics of `model` (K, per-doc
/// costs, rent flag). The trace length is used as N.
pub fn run_policy(
    scores: &[f64],
    model: &CostModel,
    policy: &mut dyn PlacementPolicy,
) -> Result<RunResult> {
    run_policy_with_trace(scores, model, policy, false)
}

/// As [`run_policy`], optionally recording the cumulative-writes series
/// (costs a Vec of N u64; enable for figure generation).
pub fn run_policy_with_trace(
    scores: &[f64],
    model: &CostModel,
    policy: &mut dyn PlacementPolicy,
    record_series: bool,
) -> Result<RunResult> {
    assert!(!scores.is_empty(), "empty trace");
    let n = scores.len() as u64;
    let mut engine = PlacementEngine::new(model, n, policy, record_series);
    for &h in scores {
        engine.observe(h, policy)?;
    }
    engine.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::{expected_cost, expected_writes, PerDocCosts, Strategy};
    use crate::policy::{Changeover, ChangeoverMigrate, SingleTier};
    use crate::storage::TierId;
    use crate::util::Rng;

    fn model(n: u64, k: u64) -> CostModel {
        CostModel::new(
            n,
            k,
            PerDocCosts { write: 2.0, read: 5.0, rent_window: 0.0 },
            PerDocCosts { write: 3.0, read: 7.0, rent_window: 0.0 },
        )
        .with_rent(false)
    }

    fn random_scores(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.next_f64()).collect()
    }

    #[test]
    fn retains_exactly_k_and_reads_them() {
        let scores = random_scores(1000, 1);
        let m = model(1000, 10);
        let mut p = SingleTier::new(TierId::A);
        let r = run_policy(&scores, &m, &mut p).unwrap();
        assert_eq!(r.retained.len(), 10);
        assert_eq!(r.read_from.len(), 10);
        assert_eq!(r.ledger.total_reads(), 10);
    }

    #[test]
    fn measured_cost_matches_analytic_all_a() {
        let m = model(2000, 20);
        let reps = 60;
        let mut total = 0.0;
        for seed in 0..reps {
            let scores = random_scores(2000, 100 + seed);
            let mut p = SingleTier::new(TierId::A);
            total += run_policy(&scores, &m, &mut p).unwrap().total_cost();
        }
        let measured = total / reps as f64;
        let analytic = expected_cost(&m, Strategy::AllA).total();
        assert!(
            (measured - analytic).abs() / analytic < 0.03,
            "measured {measured} vs analytic {analytic}"
        );
    }

    #[test]
    fn measured_cost_matches_analytic_changeover() {
        let m = model(2000, 20);
        let r_cut = 800u64;
        let reps = 60;
        let mut total = 0.0;
        for seed in 0..reps {
            let scores = random_scores(2000, 500 + seed);
            let mut p = Changeover::new(r_cut);
            total += run_policy(&scores, &m, &mut p).unwrap().total_cost();
        }
        let measured = total / reps as f64;
        let analytic = expected_cost(&m, Strategy::Changeover { r: r_cut }).total();
        assert!(
            (measured - analytic).abs() / analytic < 0.04,
            "measured {measured} vs analytic {analytic}"
        );
    }

    #[test]
    fn measured_cost_matches_analytic_migrate_with_rent() {
        let m = CostModel::new(
            2000,
            20,
            PerDocCosts { write: 0.0, read: 0.0, rent_window: 70.0 },
            PerDocCosts { write: 0.5, read: 0.5, rent_window: 5.0 },
        );
        let r_cut = 400u64;
        let reps = 80;
        let mut total = 0.0;
        for seed in 0..reps {
            let scores = random_scores(2000, 900 + seed);
            let mut p = ChangeoverMigrate::new(r_cut);
            total += run_policy(&scores, &m, &mut p).unwrap().total_cost();
        }
        let measured = total / reps as f64;
        let analytic = expected_cost(&m, Strategy::ChangeoverMigrate { r: r_cut }).total();
        // analytic rent uses the linear-split approximation of eq. (18);
        // the simulator charges exact per-doc lifetimes → looser tolerance.
        assert!(
            (measured - analytic).abs() / analytic < 0.30,
            "measured {measured} vs analytic {analytic}"
        );
    }

    #[test]
    fn organic_write_count_matches_record_process() {
        let m = model(3000, 30);
        let reps = 40;
        let mut writes = 0u64;
        for seed in 0..reps {
            let scores = random_scores(3000, 2000 + seed);
            let mut p = Changeover::new(1000);
            let r = run_policy(&scores, &m, &mut p).unwrap();
            writes += r.ledger.organic_writes();
        }
        let mean = writes as f64 / reps as f64;
        let analytic = expected_writes(3000, 30);
        assert!(
            (mean - analytic).abs() / analytic < 0.03,
            "mean {mean} vs analytic {analytic}"
        );
    }

    #[test]
    fn cumulative_series_recorded_when_asked() {
        let scores = random_scores(500, 77);
        let m = model(500, 5);
        let mut p = SingleTier::new(TierId::B);
        let r = run_policy_with_trace(&scores, &m, &mut p, true).unwrap();
        assert_eq!(r.cumulative_writes.len(), 500);
        assert!(r.cumulative_writes.windows(2).all(|w| w[1] >= w[0]));
        let r2 = run_policy(&scores, &m, &mut p).unwrap();
        assert!(r2.cumulative_writes.is_empty());
    }

    #[test]
    fn reactive_policies_run_clean() {
        let scores = random_scores(800, 3);
        let m = CostModel::new(
            800,
            8,
            PerDocCosts { write: 0.0, read: 0.1, rent_window: 10.0 },
            PerDocCosts { write: 0.2, read: 0.2, rent_window: 1.0 },
        );
        let mut age = crate::policy::AgeBasedDemotion::new(0.05);
        let ra = run_policy(&scores, &m, &mut age).unwrap();
        assert_eq!(ra.retained.len(), 8);
        assert!(ra.ledger.migration_total() > 0.0);
        let mut ski = crate::policy::SkiRental::from_model(&m);
        let rs = run_policy(&scores, &m, &mut ski).unwrap();
        assert_eq!(rs.retained.len(), 8);
    }
}
