//! `engine` — the session-based, N-tier, backend-agnostic placement API.
//!
//! This module is the single codepath behind every placement surface in
//! the crate: the batch executor and streaming pipeline
//! ([`crate::policy::PlacementEngine`] / [`crate::pipeline::run_pipeline`])
//! and the multi-stream fleet ([`crate::fleet::run_fleet`]) are thin
//! compatibility wrappers over it (see `docs/adr/ADR-002-engine-api.md`).
//!
//! ```text
//!   Engine::builder()
//!       .topology(TierTopology)      // N tiers, hot → cold, capacities
//!       .backend(dyn StorageBackend) // default: the in-tree StorageSim
//!       .arbiter(dyn Arbiter)        // default: ProportionalArbiter
//!       .shards(n)                   // sharded core width (default 8)
//!       .build()?
//!       │
//!       ├─ open_stream(SessionSpec) ─────► StreamSession (re-arbitrates)
//!       │      session.observe(score)     plan/naive modes, or
//!       │      session.observe_with_policy(...)   external policies
//!       │      session.finish()  /  session.finish_release()
//!       │                                         (re-arbitrates)
//!       └─ settle_rent / ledger / assignments / peak_occupancy ...
//! ```
//!
//! **Online re-arbitration.** Every `open_stream`, every finish, and
//! every changeover demotion re-runs the [`Arbiter`] over the live
//! sessions, so quotas are no longer fixed at admission: a session
//! closing mid-run (via [`StreamSession::finish_release`]) — or a
//! migrate-family session bulk-demoting its hot residents at a plan
//! boundary — frees capacity and the survivors' closed-form quotas and
//! changeover plans are recomputed on the spot (*time-phased quota
//! lending*). Plan changes apply to *future* placements only — already
//! resident documents are never evicted by a quota shrink, and a fired
//! changeover boundary never re-opens.
//!
//! **Plan families.** [`SessionSpec::with_family`] selects the paper's
//! strategy family per stream: `Keep` (no migration), `Migrate`
//! (DO_MIGRATE — every boundary bulk-demotes, the winner when rent
//! dominates transport, e.g. case-study-2 economies), or `Auto`
//! (whichever closed form prices cheaper).
//!
//! # Sharded concurrency (ADR-008)
//!
//! The engine core is an N-way *sharded* state machine, not one big
//! mutex. Sessions hash to shards by id (`id % shards`); each shard owns
//! its sessions' residency/ledger accounting behind its own lock, padded
//! to its own cache line. Tier headroom — the one genuinely global
//! resource — reaches the shards as per-shard **quota leases**
//! ([`LeaseGrant`], see [`mod@self`]'s `lease` submodule docs) granted by
//! an epoch-guarded global allocator at every (re-)arbitration. The
//! paper's a-priori model is what makes this sound: per-stream demand is
//! known in closed form at open time, so capacity can be pre-partitioned
//! into leases instead of checked reactively on a global lock.
//!
//! The resulting lock discipline (total order, holders only ever acquire
//! rightward): `global < shard 0 < … < shard S−1 < backend`.
//!
//! - `observe` — the hot path — takes exactly its own shard's lock, plus
//!   the backend lock *only if* the observation actually touches storage
//!   (most rejections never do; the backend lock is taken lazily and held
//!   to the end of the observation so multi-op sequences stay atomic).
//!   No global lock.
//! - `open_stream` / `finish` / a firing changeover / a drift
//!   re-derivation synchronize globally: the global lock serializes
//!   arbitration, all shard locks are taken in order, the arbiter runs,
//!   and fresh leases are installed under a new epoch. Stale grants (an
//!   older epoch) are never installed over newer ones — the same
//!   monotonicity argument as the fired-boundary clamp.
//!
//! Every lock recovers from poisoning, and the damage radius of a panic
//! is one shard: a session that dies mid-observation poisons only its
//! own shard's mutex, and sessions on the other shards never even
//! observe the recovery (see [`Engine::shard_poison_recoveries`]).
//!
//! The default backend is the in-memory [`StorageSim`]; pass
//! [`crate::storage::FsBackend`] to [`EngineBuilder::backend`] to place
//! real files on real tier directories (`shptier engine --backend
//! fs:<root>`), or [`crate::storage::ObjectBackend`] for the S3-style
//! keyspace (`--backend obj:<root>`, ADR-005 — bucket per tier, flat
//! keys, request-counted verbs), with ledger parity against the sim
//! checked by [`demo::reconcile_backends`]. Durable backends journal
//! every operation; [`Engine::checkpoint`] snapshots residency + ledgers
//! and compacts the journal so long-running deployments replay live
//! state, not history. The journal keeps its single writer under
//! sharding: every journaled op happens under the one backend lock, so
//! replay semantics are unchanged.

pub mod arbiter;
pub mod demo;
mod lease;
pub mod session;
pub mod topology;

pub use arbiter::{
    allocate_assignments, Arbiter, PlanAssignment, ProportionalArbiter, SessionSnapshot,
    StaticArbiter,
};
pub use crate::adaptive::AdaptiveArbiter;
pub use demo::{
    reconcile_backends, run_engine_demo, BackendSpec, EngineDemoReport, ReconcileReport,
};
pub use lease::LeaseGrant;
pub use session::{SessionOutcome, SessionSpec};
pub use topology::{TierSpec, TierTopology};

pub use crate::policy::PlanFamily;

use crate::policy::{PlacementPlan, PlacementPolicy};
use crate::storage::{Ledger, StorageBackend, StorageSim, TierId};
use anyhow::{anyhow, bail, Result};
use lease::{BackendLease, CachePadded, LeaseAllocator};
use session::{SessionState, INDEX_BITS};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Default shard count for the engine core. Eight keeps shard collisions
/// rare for fleet-sized session counts while staying cheap to lock-all
/// at arbitration time.
pub const DEFAULT_SHARDS: usize = 8;

/// A capacitated tier whose orphaned residents (left by plain finishes of
/// now-closed sessions) consume its entire capacity: the arbiter would
/// silently allocate zero slots to every live session, starving them all.
/// Surfaced in the arbitration report instead of being clamped away.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TierOvercommit {
    pub tier: TierId,
    /// Configured capacity of the tier.
    pub capacity: usize,
    /// Residents owned by no live session.
    pub orphaned: usize,
}

/// One shard of the engine core: the sessions that hash to it, their
/// current quota lease, and the shard's own poison-recovery count.
struct ShardState {
    sessions: BTreeMap<u64, SessionState>,
    /// The tier-headroom lease the last arbitration granted this shard
    /// (`None` until the first arbitration touches the shard).
    lease: Option<LeaseGrant>,
    /// Times *this shard's* lock was recovered after a panic — the blast
    /// radius of a dying session is exactly one entry of this vector.
    poison_recoveries: u64,
}

/// Globally-synchronized engine state: everything only open/close/
/// re-arbitration events touch. Deliberately excludes the per-session
/// maps (sharded) and the backend (its own lock, last in the order).
struct Global {
    arbiter: Box<dyn Arbiter>,
    next_id: u64,
    rearbitrations: u64,
    last_assignments: Vec<PlanAssignment>,
    /// Tiers whose orphans swallowed their whole capacity at the last
    /// arbitration (empty = healthy).
    last_overcommits: Vec<TierOvercommit>,
    /// Live-session counts by contention mode. Mode mixing is validated
    /// against these so admission never has to walk the shards.
    live_naive: usize,
    live_arbitrated: usize,
    /// A policy-driven session owns the engine exclusively (its external
    /// policy migrates residents behind the arbiter's back).
    policy_driven: bool,
    /// The epoch source for quota leases (strictly monotonic; only ever
    /// advanced under this lock).
    allocator: LeaseAllocator,
}

/// Engine internals behind the session handles: the sharded core.
///
/// Lock order (acquire only rightward while holding):
/// `global < shard 0 < … < shard S−1 < backend`.
struct EngineCore {
    shards: Vec<CachePadded<Mutex<ShardState>>>,
    global: Mutex<Global>,
    backend: Mutex<Box<dyn StorageBackend>>,
    topology: TierTopology,
    /// Auto-checkpoint policy: checkpoint + compact when `journal_ops >
    /// checkpoint_factor × live documents` (0 disables — ADR-005
    /// follow-up, `engine.checkpoint_factor` in configs).
    checkpoint_factor: u64,
    /// Group-commit journaling (ADR-009): when set, the backend batches
    /// journal records and the engine's journal-maintenance step ticks
    /// the age/size caps after every backend-touching batch, so buffered
    /// records age out even on quiet roots.
    group_commit: bool,
    /// Adaptive placement (ADR-007): when set, a session's drift
    /// detection triggers an immediate re-arbitration so a drift-aware
    /// arbiter can re-derive its cuts. The estimator/detector run either
    /// way; this only arms the trigger.
    adaptive: bool,
    /// Times any engine lock (global, shard, or backend) was recovered
    /// from poisoning (a session panicked while holding it).
    poison_recoveries: AtomicU64,
    /// Checkpoints the auto-checkpoint policy has triggered (not counting
    /// explicit [`Engine::checkpoint`] calls).
    auto_checkpoints: AtomicU64,
    /// Sessions whose realized admission curve left the a-priori
    /// envelope (counted whether or not the engine is adaptive; under
    /// multi-shot detection a single session can contribute several).
    drift_detections: AtomicU64,
    /// Drift detections that triggered a re-arbitration (adaptive
    /// engines only).
    drift_rederivations: AtomicU64,
    /// Residents bulk-demoted by one-shot rescue demotions after late
    /// drift re-derivations (ADR-007 follow-up, adaptive engines only).
    rescue_demotions: AtomicU64,
}

impl EngineCore {
    /// The shard a session id hashes to. Session ids are dense (engine-
    /// assigned, sequential), so modulo is a perfect spreader.
    fn shard_of(&self, id: u64) -> usize {
        id as usize % self.shards.len()
    }

    /// Lock the global state, recovering from poisoning: a panic under
    /// any engine lock must not brick the surviving sessions. The
    /// recovery count is surfaced via [`Engine::poison_recoveries`].
    fn lock_global(&self) -> MutexGuard<'_, Global> {
        match self.global.lock() {
            Ok(g) => g,
            Err(poisoned) => {
                self.global.clear_poison();
                self.poison_recoveries.fetch_add(1, Ordering::Relaxed);
                poisoned.into_inner()
            }
        }
    }

    /// Lock the backend (the last lock in the order), recovering from
    /// poisoning.
    fn lock_backend(&self) -> MutexGuard<'_, Box<dyn StorageBackend>> {
        match self.backend.lock() {
            Ok(g) => g,
            Err(poisoned) => {
                self.backend.clear_poison();
                self.poison_recoveries.fetch_add(1, Ordering::Relaxed);
                poisoned.into_inner()
            }
        }
    }

    /// Lock one shard, recovering from poisoning. The recovery bumps both
    /// the engine-wide counter and the shard's own, so monitoring can see
    /// that the blast radius of a panic was confined.
    fn lock_shard_mutex<'a>(&self, m: &'a Mutex<ShardState>) -> MutexGuard<'a, ShardState> {
        match m.lock() {
            Ok(g) => g,
            Err(poisoned) => {
                m.clear_poison();
                self.poison_recoveries.fetch_add(1, Ordering::Relaxed);
                let mut g = poisoned.into_inner();
                g.poison_recoveries += 1;
                g
            }
        }
    }

    fn lock_shard(&self, idx: usize) -> MutexGuard<'_, ShardState> {
        self.lock_shard_mutex(&self.shards[idx].0)
    }

    /// Lock every shard in index order (the arbitration barrier). Only
    /// ever called while holding the global lock, which serializes
    /// callers — so the in-order sweep cannot deadlock against another
    /// sweep, and hot-path holders (one shard + backend) never acquire
    /// leftward.
    fn lock_all_shards(&self) -> Vec<MutexGuard<'_, ShardState>> {
        self.shards.iter().map(|m| self.lock_shard_mutex(&m.0)).collect()
    }

    /// Validate `spec` and admit it as a new session (no re-arbitration —
    /// callers run that once per open event or once per batch). Called
    /// under the global lock; briefly takes the backend lock (to register
    /// the stream's economics) and the target shard's lock (to insert),
    /// never simultaneously.
    fn admit(&self, g: &mut Global, spec: &SessionSpec) -> Result<u64> {
        if spec.n == 0 {
            bail!("session stream length must be positive");
        }
        if spec.n >= 1u64 << INDEX_BITS {
            bail!("session stream too long for id namespacing (N={})", spec.n);
        }
        let id = g.next_id;
        if id >= 1u64 << (64 - INDEX_BITS) {
            bail!("session id space exhausted");
        }
        // Naive sessions demote other sessions' residents behind the
        // arbiter's back, which would corrupt arbitrated sessions'
        // per-tier occupancy accounting — an engine runs one contention
        // mode at a time.
        if (spec.naive && g.live_arbitrated > 0) || (!spec.naive && g.live_naive > 0) {
            bail!(
                "cannot mix naive and arbitrated sessions on one engine \
                 (existing sessions are {})",
                if spec.naive { "arbitrated" } else { "naive" }
            );
        }
        // A policy-driven session's migration orders move residents behind
        // the arbiter's back — it must own the engine exclusively.
        if g.policy_driven {
            bail!("a policy-driven session owns this engine exclusively");
        }
        let tier_costs = match spec.tier_costs.clone() {
            Some(c) => {
                if c.len() != self.topology.num_tiers() {
                    bail!(
                        "session declares {} tier costs for a {}-tier topology",
                        c.len(),
                        self.topology.num_tiers()
                    );
                }
                c
            }
            None => self.topology.default_costs(),
        };
        let k = spec.k.clamp(1, spec.n);
        // the backend charges the *effective* costs: rent zeroed when the
        // session's economics exclude it
        let effective: Vec<crate::cost::PerDocCosts> = tier_costs
            .iter()
            .map(|c| crate::cost::PerDocCosts {
                rent_window: if spec.include_rent { c.rent_window } else { 0.0 },
                ..*c
            })
            .collect();
        // Tenancy metadata rides the registration record itself (ADR-009):
        // one journal append makes the stream and its ownership durable
        // atomically, closing the ADR-006 open-vs-sidecar race.
        match spec.note.as_deref() {
            Some(note) => {
                self.lock_backend().register_stream_with_note(id, effective, note)?
            }
            None => self.lock_backend().register_stream(id, effective)?,
        }
        g.next_id += 1;
        if spec.naive {
            g.live_naive += 1;
        } else {
            g.live_arbitrated += 1;
        }
        let state = SessionState::new(
            id,
            spec.n,
            k,
            tier_costs,
            spec.include_rent,
            spec.naive,
            spec.record_series,
            spec.family,
            spec.pinned_cold,
            spec.selector,
        );
        self.lock_shard(self.shard_of(id)).sessions.insert(id, state);
        Ok(id)
    }

    /// Re-run the arbiter over the live sessions, apply the verdict
    /// (naive sessions keep their unconstrained plans, quota-free), and
    /// install fresh per-shard quota leases under a new epoch.
    ///
    /// Residents orphaned by plain (non-release) finishes still occupy
    /// their slots, so each capacitated tier's capacity is reduced by its
    /// orphan count before allocation — quotas never promise capacity
    /// that is not actually free.
    ///
    /// Called under the global lock; takes every shard lock in order for
    /// the duration (the arbitration barrier) and the backend lock
    /// briefly for the orphan census.
    fn rearbitrate(&self, g: &mut Global) {
        let mut shards = self.lock_all_shards();
        let mut snapshots: Vec<SessionSnapshot> = shards
            .iter()
            .flat_map(|sh| sh.sessions.values().map(|s| s.snapshot()))
            .collect();
        // shards partition by `id % S`, so flat-map order interleaves;
        // the arbiters' largest-remainder pass is order-sensitive by
        // design — keep the pre-sharding ascending-id order
        snapshots.sort_by_key(|s| s.id);
        let mut topology = self.topology.clone();
        g.last_overcommits.clear();
        {
            let b = self.lock_backend();
            for tier in self.topology.capacitated() {
                let orphaned = b
                    .residents(tier)
                    .iter()
                    .filter(|r| {
                        !r.owner.is_some_and(|o| {
                            shards[self.shard_of(o)].sessions.contains_key(&o)
                        })
                    })
                    .count();
                if orphaned > 0 {
                    let cap = self.topology.tier(tier).capacity.unwrap_or(usize::MAX);
                    if orphaned >= cap && !snapshots.is_empty() {
                        // over-commit: the clamp below would hand every live
                        // session a zero quota with no signal — record it in
                        // the arbitration report instead of starving silently
                        // (callers like the CLI render it; the library itself
                        // stays quiet)
                        g.last_overcommits.push(TierOvercommit {
                            tier,
                            capacity: cap,
                            orphaned,
                        });
                    }
                    topology =
                        topology.with_capacity(tier, Some(cap.saturating_sub(orphaned)));
                }
            }
        }
        let assignments = g.arbiter.arbitrate(&snapshots, &topology);
        let epoch = g.allocator.next_epoch();
        let num_tiers = self.topology.num_tiers();
        let mut grants: Vec<LeaseGrant> = (0..shards.len())
            .map(|i| LeaseGrant {
                epoch,
                shard: i,
                per_tier: vec![None; num_tiers],
                sessions: Vec::new(),
            })
            .collect();
        for a in &assignments {
            let idx = self.shard_of(a.id);
            if let Some(s) = shards[idx].sessions.get_mut(&a.id) {
                if s.naive {
                    s.apply_plan(a.unconstrained.clone());
                    s.quotas = vec![None; num_tiers];
                } else {
                    s.apply_plan(a.plan.clone());
                    s.quotas = a.quota.clone();
                    let grant = &mut grants[idx];
                    grant.sessions.push(a.id);
                    for (t, q) in a.quota.iter().enumerate() {
                        if let Some(q) = q {
                            *grant.per_tier[t].get_or_insert(0) += q;
                        }
                    }
                }
            }
        }
        for grant in grants {
            let shard = &mut shards[grant.shard];
            match &shard.lease {
                // A revoked lease never resurrects: grants install only
                // over strictly older epochs. (With the global lock held
                // a stale grant cannot actually reach here — the guard
                // makes the protocol self-documenting and future-proof.)
                Some(prev) if prev.epoch >= grant.epoch => {}
                _ => shard.lease = Some(grant),
            }
        }
        g.rearbitrations += 1;
        g.last_assignments = assignments;
    }

    /// Re-arbitrate, rolling back the just-admitted sessions if the
    /// arbiter panics. Without this, a panicking custom [`Arbiter`]
    /// inside `open_stream` would — since every lock recovers from
    /// poisoning — leave ghost sessions behind (admitted, but no handle
    /// ever returned to finish them), silently shrinking every future
    /// quota. The panic is re-raised to the opener.
    fn rearbitrate_or_rollback(&self, g: &mut Global, admitted: &[u64]) {
        let result =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| self.rearbitrate(g)));
        if let Err(panic) = result {
            for id in admitted {
                let removed = self.lock_shard(self.shard_of(*id)).sessions.remove(id);
                if let Some(s) = removed {
                    if s.naive {
                        g.live_naive -= 1;
                    } else {
                        g.live_arbitrated -= 1;
                    }
                }
            }
            std::panic::resume_unwind(panic);
        }
    }

    /// Journal maintenance, run after every backend-touching batch (the
    /// lease-release path) and every close: tick the group-commit
    /// age/size caps, then enforce the auto-checkpoint policy — when the
    /// journal's replay suffix outgrows `checkpoint_factor ×` the live
    /// document count, fold it into a fresh snapshot. Keeps long-running
    /// deployments' journals sized by live state, not by op history.
    /// Free on memory-only backends (`journal_ops() == 0` always).
    /// Takes only the backend lock — callable from the hot path without
    /// global synchronization.
    fn maybe_auto_checkpoint(&self) -> Result<()> {
        if self.checkpoint_factor == 0 && !self.group_commit {
            return Ok(());
        }
        let mut b = self.lock_backend();
        if self.group_commit {
            // the two triggers fold into one flush machinery: a due
            // batch flushes here, and a checkpoint below flushes
            // whatever remains as its phase-0 barrier
            b.journal_tick()?;
        }
        if self.checkpoint_factor == 0 {
            return Ok(());
        }
        let ops = b.journal_ops();
        // `max(1)` keeps the policy armed on an empty store: a journal
        // full of deletes for dead documents still gets folded.
        let live = (b.resident_count() as u64).max(1);
        if ops > self.checkpoint_factor.saturating_mul(live) {
            b.checkpoint()?;
            self.auto_checkpoints.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }
}

/// The tier-placement engine: sharded session state + quota leases +
/// shared storage behind one handle.
pub struct Engine {
    core: Arc<EngineCore>,
}

/// Builder for [`Engine`].
pub struct EngineBuilder {
    topology: Option<TierTopology>,
    backend: Option<Box<dyn StorageBackend>>,
    arbiter: Box<dyn Arbiter>,
    charge_rent: bool,
    checkpoint_factor: u64,
    group_commit: bool,
    adaptive: bool,
    shards: usize,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        Self {
            topology: None,
            backend: None,
            arbiter: Box::new(ProportionalArbiter),
            charge_rent: true,
            // off by default: batch surfaces checkpoint explicitly, and
            // several acceptance tests inspect raw journal contents. The
            // serve layer turns this on (default factor 8 in serve.toml).
            checkpoint_factor: 0,
            // off by default for the same reason: per-op journaling is
            // the conservative posture, and tests that count raw journal
            // lines rely on it. Opt in via `engine.group_commit` /
            // `--group-commit` (ADR-009).
            group_commit: false,
            adaptive: false,
            shards: DEFAULT_SHARDS,
        }
    }
}

impl EngineBuilder {
    /// The tier hierarchy (required).
    pub fn topology(mut self, topology: TierTopology) -> Self {
        self.topology = Some(topology);
        self
    }

    /// Custom storage backend (default: a fresh [`StorageSim`] built from
    /// the topology). The backend's tier count must match the topology.
    pub fn backend(mut self, backend: Box<dyn StorageBackend>) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Custom arbitration strategy (default: [`ProportionalArbiter`]).
    pub fn arbiter(mut self, arbiter: Box<dyn Arbiter>) -> Self {
        self.arbiter = arbiter;
        self
    }

    /// Whether the default simulator charges rent (per-session rent is
    /// additionally controlled by [`SessionSpec::include_rent`]).
    pub fn charge_rent(mut self, charge: bool) -> Self {
        self.charge_rent = charge;
        self
    }

    /// Auto-checkpoint policy: trigger [`Engine::checkpoint`] whenever the
    /// journal's replay suffix exceeds `factor ×` the live document count
    /// (0 — the default — disables; long-running serve deployments run
    /// with 8). Irrelevant for memory-only backends.
    pub fn checkpoint_factor(mut self, factor: u64) -> Self {
        self.checkpoint_factor = factor;
        self
    }

    /// Group-commit journaling (ADR-009): when enabled, durable backends
    /// buffer journal records in a bounded in-memory batch and flush
    /// them as one framed write (size cap, age cap, or forced barrier —
    /// checkpoint, bulk migration, stream close, drain). Crash recovery
    /// then replays to a *batch-boundary prefix* of the op stream: a
    /// bounded staleness window traded for an order-of-magnitude cut in
    /// journal flushes (+fsyncs). No-op on memory-only backends.
    pub fn group_commit(mut self, enabled: bool) -> Self {
        self.group_commit = enabled;
        self
    }

    /// Adaptive placement (ADR-007): when enabled, a session whose
    /// realized admission curve drifts from the a-priori secretary law
    /// triggers an immediate re-arbitration, so a drift-aware arbiter
    /// (pair this with [`AdaptiveArbiter`]) re-derives its cuts from the
    /// detection index. The per-session estimator and detector run
    /// regardless — this flag only arms the re-arbitration trigger, so a
    /// non-adaptive engine pays nothing beyond the O(1) tracking.
    pub fn adaptive(mut self, adaptive: bool) -> Self {
        self.adaptive = adaptive;
        self
    }

    /// Width of the sharded core (default [`DEFAULT_SHARDS`], clamped to
    /// at least 1). Placement outcomes are shard-count-independent — the
    /// shard map only partitions lock ownership; use 1 to recover a
    /// fully serialized engine for debugging.
    pub fn shards(mut self, shards: usize) -> Self {
        self.shards = shards.max(1);
        self
    }

    pub fn build(self) -> Result<Engine> {
        let topology = self
            .topology
            .ok_or_else(|| anyhow!("engine builder: a tier topology is required"))?;
        topology.validate()?;
        let mut backend: Box<dyn StorageBackend> = match self.backend {
            Some(b) => b,
            None => {
                Box::new(StorageSim::with_tiers(topology.default_costs(), self.charge_rent))
            }
        };
        if backend.num_tiers() != topology.num_tiers() {
            bail!(
                "backend has {} tiers but the topology declares {}",
                backend.num_tiers(),
                topology.num_tiers()
            );
        }
        for (i, spec) in topology.tiers().iter().enumerate() {
            backend.set_capacity(TierId(i), spec.capacity);
        }
        if self.group_commit {
            backend.set_group_commit(true);
        }
        // Continue the id sequence past any streams a reopened durable
        // backend replayed from its journal: reissuing a historical id
        // would alias its documents and ledger lines. Fresh backends
        // report no streams, so ids still start at 0.
        let next_id = backend.stream_ids().iter().max().map_or(0, |m| m + 1);
        let shards = (0..self.shards)
            .map(|_| {
                CachePadded(Mutex::new(ShardState {
                    sessions: BTreeMap::new(),
                    lease: None,
                    poison_recoveries: 0,
                }))
            })
            .collect();
        Ok(Engine {
            core: Arc::new(EngineCore {
                shards,
                global: Mutex::new(Global {
                    arbiter: self.arbiter,
                    next_id,
                    rearbitrations: 0,
                    last_assignments: Vec::new(),
                    last_overcommits: Vec::new(),
                    live_naive: 0,
                    live_arbitrated: 0,
                    policy_driven: false,
                    allocator: LeaseAllocator::default(),
                }),
                backend: Mutex::new(backend),
                topology,
                checkpoint_factor: self.checkpoint_factor,
                group_commit: self.group_commit,
                adaptive: self.adaptive,
                poison_recoveries: AtomicU64::new(0),
                auto_checkpoints: AtomicU64::new(0),
                drift_detections: AtomicU64::new(0),
                drift_rederivations: AtomicU64::new(0),
                rescue_demotions: AtomicU64::new(0),
            }),
        })
    }
}

impl Engine {
    pub fn builder() -> EngineBuilder {
        EngineBuilder::default()
    }

    /// Open a new stream session. Registers the session's economics with
    /// the backend, admits it into its shard, and triggers re-arbitration
    /// over all live sessions.
    pub fn open_stream(&self, spec: SessionSpec) -> Result<StreamSession> {
        let mut g = self.core.lock_global();
        let id = self.core.admit(&mut g, &spec)?;
        self.core.rearbitrate_or_rollback(&mut g, &[id]);
        Ok(StreamSession { id, core: Arc::clone(&self.core) })
    }

    /// Open many sessions as one admission event: all specs are admitted,
    /// then the arbiter runs once over the full set — equivalent to (but
    /// much cheaper than) opening them one by one, since intermediate
    /// verdicts would be discarded anyway. On error, previously admitted
    /// specs from this batch remain open (arbitrated by the next event).
    pub fn open_streams(&self, specs: Vec<SessionSpec>) -> Result<Vec<StreamSession>> {
        let mut g = self.core.lock_global();
        let mut handles = Vec::with_capacity(specs.len());
        let mut failure = None;
        for spec in &specs {
            match self.core.admit(&mut g, spec) {
                Ok(id) => {
                    handles.push(StreamSession { id, core: Arc::clone(&self.core) })
                }
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            }
        }
        // arbitrate whatever was admitted, error or not, so no session is
        // ever left running its placeholder plan
        let admitted: Vec<u64> = handles.iter().map(|h| h.id).collect();
        self.core.rearbitrate_or_rollback(&mut g, &admitted);
        match failure {
            Some(e) => Err(e),
            None => Ok(handles),
        }
    }

    /// Settle rent for everything resident as of window fraction `at`
    /// (call once at end of window, before finishing end-of-run sessions).
    /// Fallible: durable backends journal the settlement.
    pub fn settle_rent(&self, at: f64) -> Result<()> {
        self.core.lock_backend().settle_rent(at)
    }

    /// Checkpoint + compact the backend's journal (see
    /// [`StorageBackend::checkpoint`]): residency and ledgers are
    /// snapshotted, the replay history is folded away, and accounting is
    /// untouched. A free no-op on the in-memory simulator. Long-running
    /// deployments call this periodically so the journal's size tracks
    /// live state instead of op count. Also notifies the arbiter
    /// ([`Arbiter::on_checkpoint`]) so learning arbiters can persist
    /// their state alongside the storage snapshot (ADR-007 follow-up).
    pub fn checkpoint(&self) -> Result<crate::storage::CheckpointReport> {
        let g = self.core.lock_global();
        g.arbiter.on_checkpoint();
        let report = self.core.lock_backend().checkpoint()?;
        drop(g);
        Ok(report)
    }

    /// Journal op records a kill-and-reopen would replay on top of the
    /// latest checkpoint (0 on the simulator). Under group commit this
    /// counts buffered records too — they are committed work, just not
    /// yet durable (see [`Engine::journal_buffered`]).
    pub fn journal_ops(&self) -> u64 {
        self.core.lock_backend().journal_ops()
    }

    /// Journal op records buffered in the group-commit batch, not yet
    /// durable (0 with group commit off, on the simulator, and right
    /// after any barrier).
    pub fn journal_buffered(&self) -> u64 {
        self.core.lock_backend().journal_buffered()
    }

    /// Forced barrier (ADR-009): durably flush any buffered journal
    /// batch now. Drains call this so nothing rides the staleness window
    /// across a planned stop.
    pub fn journal_flush(&self) -> Result<()> {
        self.core.lock_backend().journal_flush()
    }

    /// Snapshot of the engine-wide ledger.
    pub fn ledger(&self) -> Ledger {
        self.core.lock_backend().ledger().clone()
    }

    /// Snapshot of one session's attributed ledger.
    pub fn stream_ledger(&self, id: u64) -> Ledger {
        self.core.lock_backend().stream_ledger(id)
    }

    /// Every stream id the backend knows (live and recovered).
    pub fn stream_ids(&self) -> Vec<u64> {
        self.core.lock_backend().stream_ids()
    }

    /// The opaque annotation journaled with `id`'s registration, if any
    /// (ADR-009: serve stores tenant attribution here so it rides the
    /// engine transaction instead of a sidecar append).
    pub fn stream_note(&self, id: u64) -> Option<String> {
        self.core.lock_backend().stream_note(id)
    }

    pub fn num_tiers(&self) -> usize {
        self.core.topology.num_tiers()
    }

    /// High-water mark of simultaneous residents on `tier`.
    pub fn peak_occupancy(&self, tier: TierId) -> usize {
        self.core.lock_backend().peak_occupancy(tier)
    }

    /// Current residents of `tier`.
    pub fn resident_len(&self, tier: TierId) -> usize {
        self.core.lock_backend().resident_len(tier)
    }

    /// Live documents across all tiers.
    pub fn resident_count(&self) -> usize {
        self.core.lock_backend().resident_count()
    }

    /// Number of currently open sessions.
    pub fn live_sessions(&self) -> usize {
        let g = self.core.lock_global();
        g.live_naive + g.live_arbitrated
    }

    /// How many times the arbiter has run (one per open/close event).
    pub fn rearbitrations(&self) -> u64 {
        self.core.lock_global().rearbitrations
    }

    /// The most recent arbitration verdict (one entry per then-live
    /// session).
    pub fn assignments(&self) -> Vec<PlanAssignment> {
        self.core.lock_global().last_assignments.clone()
    }

    /// Capacitated tiers whose orphaned residents swallowed their entire
    /// capacity at the last arbitration — live sessions are starved of
    /// those tiers until capacity is released (empty = healthy). Part of
    /// the arbitration report alongside [`Engine::assignments`].
    pub fn overcommits(&self) -> Vec<TierOvercommit> {
        self.core.lock_global().last_overcommits.clone()
    }

    /// Times any engine lock was recovered after a session panicked while
    /// holding it (0 = no panics; survivors keep operating either way).
    /// [`Engine::shard_poison_recoveries`] breaks this down per shard.
    pub fn poison_recoveries(&self) -> u64 {
        self.core.poison_recoveries.load(Ordering::Relaxed)
    }

    /// Per-shard poison-recovery counts: the blast radius of a panicking
    /// session is exactly one nonzero entry (its own shard).
    pub fn shard_poison_recoveries(&self) -> Vec<u64> {
        (0..self.core.shards.len())
            .map(|i| self.core.lock_shard(i).poison_recoveries)
            .collect()
    }

    /// Number of shards the core was built with.
    pub fn shard_count(&self) -> usize {
        self.core.shards.len()
    }

    /// The quota leases currently installed, one per shard that has any
    /// (ascending shard index). All grants carry the epoch of the last
    /// arbitration; per tier, their sums never exceed the orphan-adjusted
    /// capacity (the invariant `tests/shard_invariants.rs` checks).
    pub fn lease_grants(&self) -> Vec<LeaseGrant> {
        (0..self.core.shards.len())
            .filter_map(|i| self.core.lock_shard(i).lease.clone())
            .collect()
    }

    /// Checkpoints triggered by the auto-checkpoint policy (see
    /// [`EngineBuilder::checkpoint_factor`]).
    pub fn auto_checkpoints(&self) -> u64 {
        self.core.auto_checkpoints.load(Ordering::Relaxed)
    }

    /// Sessions whose realized admission curve left the a-priori envelope
    /// (the ADR-007 drift detector; counted on every engine, adaptive or
    /// not — multi-shot, so one session can contribute several).
    pub fn drift_detections(&self) -> u64 {
        self.core.drift_detections.load(Ordering::Relaxed)
    }

    /// Drift detections that triggered a plan re-derivation
    /// ([`EngineBuilder::adaptive`] engines only).
    pub fn drift_rederivations(&self) -> u64 {
        self.core.drift_rederivations.load(Ordering::Relaxed)
    }

    /// Residents demoted by one-shot rescue demotions after late drift
    /// re-derivations (ADR-007 follow-up; adaptive engines only — static
    /// engines never re-derive, so they never rescue).
    pub fn rescue_demotions(&self) -> u64 {
        self.core.rescue_demotions.load(Ordering::Relaxed)
    }

    pub fn arbiter_name(&self) -> String {
        self.core.lock_global().arbiter.name()
    }

    pub fn backend_name(&self) -> String {
        self.core.lock_backend().backend_name()
    }
}

/// Handle to one open stream session. Independent of the engine handle:
/// sessions score/place/finish on their own, through the sharded core,
/// and may be moved freely across threads — two sessions on different
/// shards observe with no shared lock unless both touch storage.
pub struct StreamSession {
    id: u64,
    core: Arc<EngineCore>,
}

impl StreamSession {
    /// Engine-assigned session id (also the ledger-attribution stream id).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Observe the next document under the session's (arbitrated) plan.
    ///
    /// The hot path: takes only this session's shard lock, plus the
    /// backend lock lazily if the observation actually places, demotes,
    /// or deletes anything. A changeover demotion firing mid-observation
    /// triggers an immediate re-arbitration (after the shard lock is
    /// released): the capacity it freed is re-lent to the surviving
    /// sessions on the spot (time-phased quota lending). So does the
    /// session's drift detector firing, when the engine was built with
    /// [`EngineBuilder::adaptive`] — the re-run arbiter sees the detection
    /// index in the snapshot and can re-derive the cuts (ADR-007).
    pub fn observe(&mut self, score: f64) -> Result<()> {
        let core = &self.core;
        let shard_idx = core.shard_of(self.id);
        let (events, used) = {
            let mut shard = core.lock_shard(shard_idx);
            let s = shard
                .sessions
                .get_mut(&self.id)
                .ok_or_else(|| anyhow!("session {} is closed", self.id))?;
            let mut lease =
                BackendLease::new(&core.backend, &core.poison_recoveries, self.id);
            let events = s.observe(&mut lease, score)?;
            (events, lease.used())
        };
        if events.drift {
            core.drift_detections.fetch_add(1, Ordering::Relaxed);
        }
        let rederive = events.drift && core.adaptive;
        if rederive {
            core.drift_rederivations.fetch_add(1, Ordering::Relaxed);
        }
        if events.fired || rederive {
            let mut g = core.lock_global();
            core.rearbitrate(&mut g);
            if rederive {
                // Rescue demotion (ADR-007 follow-up, one-shot): the
                // re-derived plan only routes *future* documents — any
                // resident the shrunken plan no longer wants hot would
                // keep renting its slot to stream end. Still under the
                // global lock (so the freshly-applied plan cannot change
                // underneath), re-take this session's shard and demote
                // the stale excess; lock order global < shard < backend
                // holds throughout.
                let moved = {
                    let mut shard = core.lock_shard(shard_idx);
                    match shard.sessions.get_mut(&self.id) {
                        Some(s) => {
                            let mut lease = BackendLease::new(
                                &core.backend,
                                &core.poison_recoveries,
                                self.id,
                            );
                            s.rescue_demote(&mut lease)?
                        }
                        None => 0,
                    }
                };
                if moved > 0 {
                    core.rescue_demotions.fetch_add(moved, Ordering::Relaxed);
                    // the rescue freed hot slots — re-lend them now,
                    // exactly like a changeover demotion would
                    core.rearbitrate(&mut g);
                }
            }
        }
        if used {
            core.maybe_auto_checkpoint()?;
        }
        Ok(())
    }

    /// Observe the next document, deferring placement to an external
    /// policy (single-stream compatibility path). The policy's migration
    /// orders run against the shared backend outside the arbiter's
    /// accounting, so a policy-driven session must own the engine
    /// exclusively — multi-session engines reject this call.
    pub fn observe_with_policy(
        &mut self,
        score: f64,
        policy: &mut dyn PlacementPolicy,
    ) -> Result<()> {
        let core = &self.core;
        let mut g = core.lock_global();
        if g.live_naive + g.live_arbitrated > 1 {
            bail!("observe_with_policy requires exclusive engine ownership");
        }
        let mut shard = core.lock_shard(core.shard_of(self.id));
        let s = shard
            .sessions
            .get_mut(&self.id)
            .ok_or_else(|| anyhow!("session {} is closed", self.id))?;
        g.policy_driven = true;
        let mut lease = BackendLease::new(&core.backend, &core.poison_recoveries, self.id);
        s.observe_with_policy(&mut lease, score, policy)
    }

    /// Documents observed so far.
    pub fn observed(&self) -> u64 {
        self.with_state(|s| s.observed()).unwrap_or(0)
    }

    /// Whether the declared stream length has been fully observed.
    pub fn done(&self) -> bool {
        self.with_state(|s| s.done()).unwrap_or(true)
    }

    /// Current top-K threshold score (None until K docs seen).
    pub fn threshold(&self) -> Option<f64> {
        self.with_state(|s| s.threshold()).flatten()
    }

    /// The plan the session is currently running (re-arbitrated live).
    pub fn plan(&self) -> Option<PlacementPlan> {
        self.with_state(|s| s.plan.clone())
    }

    /// The session's current per-tier quotas.
    pub fn quotas(&self) -> Vec<Option<u64>> {
        self.with_state(|s| s.quotas.clone()).unwrap_or_default()
    }

    /// Residents of `tier` on the shared backend (diagnostics).
    pub fn tier_len(&self, tier: TierId) -> usize {
        self.core.lock_backend().resident_len(tier)
    }

    /// Finish at end of window: consumer-read the retained top-K, close
    /// the session, re-arbitrate. Residents stay where they are (the
    /// caller settles rent engine-wide); use
    /// [`StreamSession::finish_release`] to free capacity mid-run.
    pub fn finish(self) -> Result<SessionOutcome> {
        self.finish_inner(false)
    }

    /// Finish mid-run: consumer-read the retained top-K, then delete the
    /// session's residents (settling their rent), releasing its tier
    /// capacity to the surviving sessions via re-arbitration.
    pub fn finish_release(self) -> Result<SessionOutcome> {
        self.finish_inner(true)
    }

    fn finish_inner(self, release: bool) -> Result<SessionOutcome> {
        let core = &self.core;
        let mut g = core.lock_global();
        let mut s = {
            let mut shard = core.lock_shard(core.shard_of(self.id));
            shard
                .sessions
                .remove(&self.id)
                .ok_or_else(|| anyhow!("session {} is closed", self.id))?
        };
        if s.naive {
            g.live_naive -= 1;
        } else {
            g.live_arbitrated -= 1;
        }
        if s.policy_driven {
            g.policy_driven = false;
        }
        let snapshot = s.snapshot();
        let (outcome, realized) = {
            let mut b = core.lock_backend();
            let outcome = s.finish(b.as_mut())?;
            if release {
                s.release(b.as_mut())?;
            }
            // a stream close is a forced barrier (ADR-009): the
            // session's final records must be durable before its outcome
            // is reported to the caller
            b.journal_flush()?;
            (outcome, b.stream_ledger(self.id).total())
        };
        // reward signal for learning arbiters (ADR-007): the realized
        // attributed cost of the finished stream, against its final
        // snapshot (which carries the family and drift state)
        g.arbiter.on_stream_finished(&snapshot, realized);
        core.rearbitrate(&mut g);
        drop(g);
        core.maybe_auto_checkpoint()?;
        Ok(outcome)
    }

    fn with_state<T>(&self, f: impl FnOnce(&SessionState) -> T) -> Option<T> {
        self.core
            .lock_shard(self.core.shard_of(self.id))
            .sessions
            .get(&self.id)
            .map(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::{CostModel, PerDocCosts};
    use crate::util::Rng;

    fn pd(w: f64, r: f64) -> PerDocCosts {
        PerDocCosts { write: w, read: r, rent_window: 0.0 }
    }

    fn two_tier_engine(hot_cap: Option<usize>) -> Engine {
        Engine::builder()
            .topology(
                TierTopology::two_tier(pd(1.0, 4.0), pd(3.0, 0.5))
                    .with_capacity(TierId::A, hot_cap),
            )
            .charge_rent(false)
            .build()
            .unwrap()
    }

    #[test]
    fn builder_requires_topology() {
        assert!(Engine::builder().build().is_err());
    }

    #[test]
    fn single_session_runs_to_completion() {
        let engine = two_tier_engine(None);
        let mut s = engine
            .open_stream(SessionSpec::new(200, 10).with_rent(false))
            .unwrap();
        assert_eq!(s.id(), 0);
        assert_eq!(engine.live_sessions(), 1);
        assert_eq!(engine.rearbitrations(), 1);
        let mut rng = Rng::new(5);
        for _ in 0..200 {
            s.observe(rng.next_f64()).unwrap();
        }
        assert!(s.done());
        assert!(s.observe(0.5).is_err(), "overlong stream must error");
        engine.settle_rent(1.0).unwrap();
        let out = s.finish().unwrap();
        assert_eq!(out.retained.len(), 10);
        assert_eq!(out.hot_reads() + out.cold_reads(), 10);
        assert_eq!(engine.live_sessions(), 0);
        assert_eq!(engine.rearbitrations(), 2);
        assert!(engine.ledger().total() > 0.0);
    }

    #[test]
    fn open_close_events_rearbitrate_quotas() {
        // two sessions share a tight hot tier; closing one mid-run must
        // grow the survivor's quota
        let engine = two_tier_engine(Some(10));
        let spec = || SessionSpec::from_model(
            &CostModel::new(400, 20, pd(1.0, 4.0), pd(3.0, 0.5)).with_rent(false),
        );
        let mut a = engine.open_stream(spec()).unwrap();
        let mut b = engine.open_stream(spec()).unwrap();
        let quota_contended = b.quotas()[0].unwrap();
        assert!(quota_contended <= 5, "two sessions split 10 slots");
        let mut rng = Rng::new(9);
        for _ in 0..200 {
            a.observe(rng.next_f64()).unwrap();
            b.observe(rng.next_f64()).unwrap();
        }
        let before = engine.rearbitrations();
        a.finish_release().unwrap();
        assert_eq!(engine.rearbitrations(), before + 1);
        let quota_alone = b.quotas()[0].unwrap();
        assert!(
            quota_alone > quota_contended,
            "released capacity must flow to the survivor \
             ({quota_contended} -> {quota_alone})"
        );
        for _ in 0..200 {
            b.observe(rng.next_f64()).unwrap();
        }
        assert!(engine.peak_occupancy(TierId::A) <= 10);
        engine.settle_rent(1.0).unwrap();
        b.finish().unwrap();
    }

    #[test]
    fn session_ids_and_ledgers_are_disjoint() {
        let engine = two_tier_engine(None);
        let mut a = engine
            .open_stream(SessionSpec::new(50, 5).with_rent(false))
            .unwrap();
        let mut b = engine
            .open_stream(SessionSpec::new(50, 5).with_rent(false))
            .unwrap();
        assert_eq!((a.id(), b.id()), (0, 1));
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            a.observe(rng.next_f64()).unwrap();
            b.observe(rng.next_f64()).unwrap();
        }
        engine.settle_rent(1.0).unwrap();
        a.finish().unwrap();
        b.finish().unwrap();
        let total = engine.ledger().total();
        let split = engine.stream_ledger(0).total() + engine.stream_ledger(1).total();
        assert!((total - split).abs() < 1e-9, "engine ${total} vs sessions ${split}");
    }

    #[test]
    fn three_tier_topology_places_in_bands() {
        // economics with interior cuts at both boundaries:
        //   hot→warm  frac = (2−1)/(4−1.9) ≈ 0.48
        //   warm→cold frac = (3−2)/(1.9−0.2) ≈ 0.59
        let topo = TierTopology::from_costs(vec![
            pd(1.0, 4.0),
            pd(2.0, 1.9),
            pd(3.0, 0.2),
        ])
        .unwrap();
        let engine = Engine::builder().topology(topo).charge_rent(false).build().unwrap();
        assert_eq!(engine.num_tiers(), 3);
        let mut s = engine
            .open_stream(SessionSpec::new(300, 12).with_rent(false))
            .unwrap();
        let plan = s.plan().unwrap();
        assert_eq!(plan.num_tiers(), 3);
        assert!(plan.cuts()[0] > 0 && plan.cuts()[0] < plan.cuts()[1]);
        assert!(plan.cuts()[1] < 300);
        // strictly increasing scores: every document enters the top-K, so
        // every non-empty band deterministically receives writes
        for i in 0..300 {
            s.observe(i as f64).unwrap();
        }
        engine.settle_rent(1.0).unwrap();
        let out = s.finish().unwrap();
        assert_eq!(out.retained.len(), 12);
        let ledger = engine.ledger();
        for t in 0..3 {
            assert!(ledger.tier(TierId(t)).writes > 0, "tier {t} never written");
        }
    }

    #[test]
    fn closed_session_handle_errors() {
        let engine = two_tier_engine(None);
        let s = engine.open_stream(SessionSpec::new(10, 2)).unwrap();
        let sid = s.id();
        s.finish().unwrap();
        let mut ghost = StreamSession { id: sid, core: Arc::clone(&engine.core) };
        assert!(ghost.observe(0.5).is_err());
        assert!(ghost.finish().is_err());
    }

    #[test]
    fn spec_validation() {
        let engine = two_tier_engine(None);
        assert!(engine.open_stream(SessionSpec::new(0, 1)).is_err());
        let wrong_arity = SessionSpec::new(10, 2).with_costs(vec![pd(1.0, 1.0)]);
        assert!(engine.open_stream(wrong_arity).is_err());
    }

    #[test]
    fn mixed_contention_modes_rejected() {
        let engine = two_tier_engine(Some(4));
        let _a = engine.open_stream(SessionSpec::new(50, 5)).unwrap();
        let naive = SessionSpec::new(50, 5).with_naive(true);
        assert!(engine.open_stream(naive).is_err(), "mode mixing must be rejected");
        // same mode is fine
        assert!(engine.open_stream(SessionSpec::new(50, 5)).is_ok());
    }

    #[test]
    fn poisoned_lock_recovers_for_survivors() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let engine = two_tier_engine(Some(8));
        let mut survivor = engine
            .open_stream(SessionSpec::new(50, 5).with_rent(false))
            .unwrap();
        survivor.observe(0.3).unwrap();
        // poison the survivor's shard lock the way a panicking session
        // would: die while holding it (session 0 lives on shard 0)
        let core = Arc::clone(&engine.core);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let _guard = core.shards[0].0.lock().unwrap();
            panic!("session panicked mid-operation");
        }));
        assert!(result.is_err());
        // the survivor keeps observing, finishing, and reading ledgers —
        // no PoisonError propagates
        survivor.observe(0.9).unwrap();
        assert!(engine.poison_recoveries() >= 1);
        engine.settle_rent(1.0).unwrap();
        let out = survivor.finish().unwrap();
        assert_eq!(out.retained.len(), 2);
        assert!(engine.ledger().total() > 0.0);
    }

    #[test]
    fn panicking_session_poisons_only_its_own_shard() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let engine = Engine::builder()
            .topology(TierTopology::two_tier(pd(1.0, 4.0), pd(3.0, 0.5)))
            .charge_rent(false)
            .shards(2)
            .build()
            .unwrap();
        assert_eq!(engine.shard_count(), 2);
        let mut a = engine
            .open_stream(SessionSpec::new(50, 5).with_rent(false))
            .unwrap();
        let mut b = engine
            .open_stream(SessionSpec::new(50, 5).with_rent(false))
            .unwrap();
        // ids 0 and 1 land on shards 0 and 1
        a.observe(0.4).unwrap();
        b.observe(0.6).unwrap();
        // a session on shard 0 dies while holding its shard lock
        let core = Arc::clone(&engine.core);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let _guard = core.shards[0].0.lock().unwrap();
            panic!("session panicked mid-observation");
        }));
        assert!(result.is_err());
        // shard 1's session never notices: its lock was untouched, and no
        // recovery happens anywhere until shard 0 is next locked
        b.observe(0.7).unwrap();
        assert_eq!(engine.poison_recoveries(), 0, "shard 1 needed no recovery");
        // shard 0 recovers on next touch; the damage was confined to it
        a.observe(0.5).unwrap();
        assert_eq!(engine.shard_poison_recoveries(), vec![1, 0]);
        assert_eq!(engine.poison_recoveries(), 1);
        engine.settle_rent(1.0).unwrap();
        b.finish().unwrap();
        a.finish().unwrap();
    }

    #[test]
    fn panicking_arbiter_rolls_back_the_admission() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        struct PanickingArbiter;
        impl Arbiter for PanickingArbiter {
            fn name(&self) -> String {
                "panicking".into()
            }
            fn arbitrate(
                &self,
                _sessions: &[SessionSnapshot],
                _topology: &TierTopology,
            ) -> Vec<PlanAssignment> {
                panic!("injected arbiter panic");
            }
        }
        let engine = Engine::builder()
            .topology(TierTopology::two_tier(pd(1.0, 4.0), pd(3.0, 0.5)))
            .arbiter(Box::new(PanickingArbiter))
            .charge_rent(false)
            .build()
            .unwrap();
        let attempt = catch_unwind(AssertUnwindSafe(|| {
            engine.open_stream(SessionSpec::new(10, 2))
        }));
        assert!(attempt.is_err(), "the arbiter panic must reach the opener");
        // the half-admitted session was rolled back: no ghost shrinking
        // future quotas, and the engine still answers queries
        assert_eq!(engine.live_sessions(), 0);
        assert!(engine.poison_recoveries() >= 1);
    }

    #[test]
    fn orphan_overcommit_is_surfaced_not_silent() {
        // hot tier with 3 slots and hot-dominant economics (everything
        // places hot): a session fills it, finishes WITHOUT releasing,
        // and its residents become orphans that swallow the capacity
        let engine = Engine::builder()
            .topology(
                TierTopology::two_tier(pd(0.1, 0.1), pd(10.0, 10.0))
                    .with_capacity(TierId::A, Some(3)),
            )
            .charge_rent(false)
            .build()
            .unwrap();
        let mut a = engine
            .open_stream(SessionSpec::new(10, 3).with_rent(false))
            .unwrap();
        for i in 0..10 {
            a.observe(i as f64).unwrap(); // increasing: top-3 all hot
        }
        a.finish().unwrap(); // plain finish: residents stay as orphans
        assert_eq!(engine.resident_len(TierId::A), 3);
        assert!(engine.overcommits().is_empty(), "no live sessions: not an over-commit");
        // a new session arrives: every hot slot is orphaned, so its hot
        // quota silently clamps to 0 — the report must say so
        let b = engine
            .open_stream(SessionSpec::new(10, 3).with_rent(false))
            .unwrap();
        let over = engine.overcommits();
        assert_eq!(over.len(), 1);
        assert_eq!(over[0].tier, TierId::A);
        assert_eq!(over[0].capacity, 3);
        assert_eq!(over[0].orphaned, 3);
        assert_eq!(b.quotas()[0], Some(0), "the clamp itself is unchanged");
        // releasing the orphans is out of scope here; close cleanly
        drop(b);
    }

    #[test]
    fn quota_starved_migrate_stream_recovers_when_capacity_is_lent() {
        use crate::policy::PlanFamily;
        // rent-dominated economy: interior DO_MIGRATE optimum
        let a = PerDocCosts { write: 0.0, read: 0.0, rent_window: 2.0 };
        let b = PerDocCosts { write: 0.4, read: 0.01, rent_window: 0.1 };
        let engine = Engine::builder()
            .topology(TierTopology::two_tier(a, b).with_capacity(TierId::A, Some(5)))
            .build()
            .unwrap();
        // a hot-hungry keep stream swallows the whole tier: hot dominates
        // its economics everywhere, so r* = N and demand = K = 50 — with
        // capacity 5, largest-remainder hands it all five slots...
        let hog_hot = PerDocCosts { write: 0.1, read: 0.1, rent_window: 0.01 };
        let hog_cold = PerDocCosts { write: 5.0, read: 5.0, rent_window: 1.0 };
        let mut hog = engine
            .open_stream(SessionSpec::new(1000, 50).with_costs(vec![hog_hot, hog_cold]))
            .unwrap();
        // ...so the migrate stream is admitted with a zero hot quota: its
        // cut clamps to 0 and its changeover boundary is due immediately
        let mut starved = engine
            .open_stream(
                SessionSpec::new(100, 5)
                    .with_costs(vec![a, b])
                    .with_family(PlanFamily::Migrate),
            )
            .unwrap();
        assert_eq!(starved.quotas()[0], Some(0));
        assert_eq!(starved.plan().unwrap().r(), 0);
        let mut rng = Rng::new(11);
        for _ in 0..2 {
            hog.observe(rng.next_f64()).unwrap();
            starved.observe(rng.next_f64()).unwrap(); // empty demotion: stays armed
        }
        // the hog closes: its five slots are re-lent, and the starved
        // stream's boundary must RE-OPEN at the unconstrained migrate r*
        // (an empty demotion must not have pinned the cut at 0)
        hog.finish_release().unwrap();
        let r = starved.plan().unwrap().r();
        assert!(r > 5, "re-lent capacity must re-open the hot band (r={r})");
        while !starved.done() {
            starved.observe(rng.next_f64()).unwrap();
        }
        engine.settle_rent(1.0).unwrap();
        let out = starved.finish().unwrap();
        let ledger = engine.stream_ledger(1);
        assert!(ledger.tier(TierId::A).writes > 0, "the hot band was used");
        assert!(ledger.migration_total() > 0.0, "the changeover demotion fired");
        assert_eq!(out.hot_reads(), 0, "post-changeover reads are all cold");
        assert_eq!(engine.resident_len(TierId::A), 0, "hot tier handed back");
    }

    #[test]
    fn lease_grants_cover_live_quotas_under_fresh_epochs() {
        // two arbitrated sessions on a tight hot tier: the installed
        // grants must carry the current epoch, partition the sessions by
        // shard, and sum per tier to exactly the allocated capacity
        let engine = two_tier_engine(Some(10));
        let spec = || SessionSpec::from_model(
            &CostModel::new(400, 20, pd(1.0, 4.0), pd(3.0, 0.5)).with_rent(false),
        );
        let a = engine.open_stream(spec()).unwrap();
        let b = engine.open_stream(spec()).unwrap();
        let grants = engine.lease_grants();
        let epoch = grants.iter().map(|g| g.epoch).max().unwrap();
        assert!(grants.iter().all(|g| g.epoch == epoch), "one epoch per arbitration");
        let covered: Vec<u64> =
            grants.iter().flat_map(|g| g.sessions.iter().copied()).collect();
        assert_eq!(covered, vec![a.id(), b.id()]);
        let hot_granted: u64 = grants
            .iter()
            .map(|g| g.per_tier[TierId::A.0].unwrap_or(0))
            .sum();
        let hot_quotas: u64 = [&a, &b]
            .iter()
            .map(|s| s.quotas()[TierId::A.0].unwrap_or(0))
            .sum();
        assert_eq!(hot_granted, hot_quotas);
        assert!(hot_granted <= 10, "grants never exceed tier capacity");
        // a close re-arbitrates: the survivor's grant re-installs under a
        // strictly newer epoch (a stale grant can never resurrect)
        a.finish_release().unwrap();
        let after = engine.lease_grants();
        let epoch_after = after.iter().map(|g| g.epoch).max().unwrap();
        assert!(epoch_after > epoch, "re-arbitration must advance the epoch");
        let covered_after: Vec<u64> =
            after.iter().flat_map(|g| g.sessions.iter().copied()).collect();
        assert_eq!(covered_after, vec![b.id()]);
        b.finish().unwrap();
    }

    #[test]
    fn drift_rederivation_respects_fired_boundary_clamp() {
        use crate::policy::PlanFamily;
        // rent-dominated economy with an interior DO_MIGRATE optimum: the
        // changeover fires mid-stream, and the suffix-restart cut a later
        // drift detection derives necessarily lands past it
        let a = PerDocCosts { write: 0.0, read: 0.0, rent_window: 2.0 };
        let b = PerDocCosts { write: 0.4, read: 0.01, rent_window: 0.1 };
        let engine = Engine::builder()
            .topology(TierTopology::two_tier(a, b).with_capacity(TierId::A, Some(64)))
            .arbiter(Box::new(AdaptiveArbiter::new()))
            .adaptive(true)
            .build()
            .unwrap();
        let mut s = engine
            .open_stream(
                SessionSpec::new(400, 6)
                    .with_costs(vec![a, b])
                    .with_family(PlanFamily::Migrate),
            )
            .unwrap();
        // phase 1 — secretary-conformant random scores: the realized
        // admission curve tracks the a-priori law while the changeover
        // boundary fires on schedule
        let mut rng = Rng::new(11);
        let mut fired_cut = None;
        while fired_cut.is_none() {
            s.observe(rng.next_f64()).unwrap();
            if engine.stream_ledger(s.id()).migration_total() > 0.0 {
                fired_cut = Some(s.plan().unwrap().r());
            }
            assert!(!s.done(), "the changeover never fired");
        }
        let fired_cut = fired_cut.unwrap();
        assert!(fired_cut > 0);
        assert_eq!(engine.drift_detections(), 0, "random phase must not drift");
        // phase 2 — adversarial shift: every score beats the running
        // threshold, the curve leaves the envelope, and the adaptive
        // engine re-derives a suffix-restart plan whose cut sits past the
        // already-executed boundary
        let mut boost = 1e6;
        while engine.drift_detections() == 0 {
            assert!(!s.done(), "the shift was never detected");
            boost += 1.0;
            s.observe(boost).unwrap();
        }
        assert!(engine.drift_rederivations() >= 1);
        // the bugfix under test (ADR-004 × ADR-007): apply_plan must clamp
        // the re-derived cut back to the cut the boundary fired at — a
        // re-opened changeover would place hot again with no second
        // demotion coming
        assert_eq!(
            s.plan().unwrap().r(),
            fired_cut,
            "a drift re-derivation re-opened a fired changeover"
        );
        assert_eq!(engine.resident_len(TierId::A), 0);
        while !s.done() {
            boost += 1.0;
            s.observe(boost).unwrap();
        }
        assert_eq!(
            engine.resident_len(TierId::A),
            0,
            "post-clamp placements must all stay cold"
        );
        engine.settle_rent(1.0).unwrap();
        s.finish().unwrap();
    }

    #[test]
    fn non_finite_scores_are_rejected_before_consuming_the_index() {
        use crate::topk::{NonFiniteScore, SelectorKind};
        let engine = two_tier_engine(None);
        let mut s = engine
            .open_stream(SessionSpec::new(50, 4).with_rent(false))
            .unwrap();
        s.observe(0.3).unwrap();
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let err = s.observe(bad).unwrap_err();
            let typed = err
                .downcast_ref::<NonFiniteScore>()
                .expect("the rejection must be the typed NonFiniteScore");
            assert_eq!(typed.index, 1, "the stream index must not be consumed");
            assert_eq!(s.observed(), 1, "a rejected score is not an observation");
        }
        // the stream continues cleanly after the rejections
        s.observe(0.9).unwrap();
        assert_eq!(s.observed(), 2);
        // the log-memory selector sits behind the same guard
        let mut lm = engine
            .open_stream(
                SessionSpec::new(50, 4)
                    .with_rent(false)
                    .with_selector(SelectorKind::LogMem),
            )
            .unwrap();
        assert!(lm.observe(f64::NAN).is_err());
        assert_eq!(lm.observed(), 0);
        lm.observe(0.5).unwrap();
        assert_eq!(lm.observed(), 1);
    }

    #[test]
    fn late_drift_rescue_demotes_stale_hot_residents() {
        use crate::policy::PlanFamily;
        use crate::topk::SelectorKind;
        // hot strictly dominates on every axis, so keep-family optima put
        // the whole stream hot (cut = n) and hot residency is bounded only
        // by the arbitrated quota
        let a = PerDocCosts { write: 0.0, read: 0.0, rent_window: 0.01 };
        let b = PerDocCosts { write: 0.4, read: 0.5, rent_window: 0.1 };
        let engine = Engine::builder()
            .topology(TierTopology::two_tier(a, b).with_capacity(TierId::A, Some(8)))
            .arbiter(Box::new(AdaptiveArbiter::new()))
            .adaptive(true)
            .build()
            .unwrap();
        // a log-memory session never evicts, so everything admitted under
        // the pre-shift regime is exactly the stale-hot population at risk
        let mut s1 = engine
            .open_stream(
                SessionSpec::new(400, 6)
                    .with_costs(vec![a, b])
                    .with_family(PlanFamily::Keep)
                    .with_selector(SelectorKind::LogMem),
            )
            .unwrap();
        // phase 1 — alone, the session fills its whole hot quota
        let mut rng = Rng::new(11);
        for _ in 0..160 {
            s1.observe(rng.next_f64()).unwrap();
        }
        assert_eq!(s1.tier_len(TierId::A), 6, "hot quota must be filled");
        assert_eq!(engine.drift_detections(), 0, "random phase must not drift");
        // a second stream arrives: the proportional split (8 over 6+6)
        // shrinks session 1's hot quota to 4, but its 6 placed residents
        // stay hot — keep-family never demotes and logmem never evicts,
        // so session 2's promised slots are physically occupied
        let s2 = engine
            .open_stream(
                SessionSpec::new(400, 6)
                    .with_costs(vec![a, b])
                    .with_family(PlanFamily::Keep),
            )
            .unwrap();
        assert_eq!(s1.quotas()[TierId::A.0], Some(4));
        assert_eq!(s1.tier_len(TierId::A), 6, "stale residents still hot");
        // phase 2 — late shift: monotone boosts blow the admission
        // envelope and the adaptive engine re-derives the plan
        let mut boost = 1e6;
        while engine.drift_detections() == 0 {
            assert!(!s1.done(), "the shift was never detected");
            boost += 1.0;
            s1.observe(boost).unwrap();
        }
        // the bugfix under test (ADR-007 follow-up): re-derivation must
        // also *shed* the residents the shrunken plan no longer wants hot
        // — without the rescue they rent (and squat on session 2's
        // promised slots) to stream end
        assert!(engine.drift_rederivations() >= 1);
        assert_eq!(engine.rescue_demotions(), 2, "excess = 6 held − 4 wanted");
        assert_eq!(s1.tier_len(TierId::A), 4, "stale hot residents were shed");
        assert_eq!(engine.resident_len(TierId::A), 4);
        // the rescue is one-shot: later detections re-plan the suffix as
        // before but never thrash the backend with further bulk moves
        let before = engine.rescue_demotions();
        while !s1.done() {
            boost += 1.0;
            s1.observe(boost).unwrap();
        }
        assert_eq!(engine.rescue_demotions(), before);
        engine.settle_rent(1.0).unwrap();
        s1.finish().unwrap();
        drop(s2);
    }

    #[test]
    fn auto_checkpoint_bounds_journal_by_live_state() {
        use crate::storage::FsBackend;
        let root = crate::util::scratch_dir("auto-ckpt");
        let costs = vec![pd(1.0, 4.0), pd(3.0, 0.5)];
        let backend = FsBackend::open(&root, costs.clone(), false)
            .unwrap()
            .with_sync(false);
        let factor = 8u64;
        let engine = Engine::builder()
            .topology(TierTopology::from_costs(costs).unwrap())
            .backend(Box::new(backend))
            .charge_rent(false)
            .checkpoint_factor(factor)
            .build()
            .unwrap();
        // long churn: many short sessions opened, run, and released — the
        // op history grows without bound, the live state does not
        let mut rng = Rng::new(21);
        let mut max_live = 0u64;
        for _ in 0..30 {
            let mut s = engine
                .open_stream(SessionSpec::new(40, 4).with_rent(false))
                .unwrap();
            for _ in 0..40 {
                s.observe(rng.next_f64()).unwrap();
            }
            s.finish_release().unwrap();
            let live = engine.resident_count() as u64;
            max_live = max_live.max(live);
            assert!(
                engine.journal_ops() <= factor * live.max(1) + 1,
                "journal {} ops for {} live docs",
                engine.journal_ops(),
                live
            );
        }
        assert!(engine.auto_checkpoints() > 0, "the policy never fired");
        let _ = std::fs::remove_dir_all(root);

        // factor 0 disables the policy entirely
        let root2 = crate::util::scratch_dir("auto-ckpt-off");
        let costs = vec![pd(1.0, 4.0), pd(3.0, 0.5)];
        let backend = FsBackend::open(&root2, costs.clone(), false)
            .unwrap()
            .with_sync(false);
        let engine = Engine::builder()
            .topology(TierTopology::from_costs(costs).unwrap())
            .backend(Box::new(backend))
            .charge_rent(false)
            .checkpoint_factor(0)
            .build()
            .unwrap();
        let mut s = engine
            .open_stream(SessionSpec::new(60, 3).with_rent(false))
            .unwrap();
        for _ in 0..60 {
            s.observe(rng.next_f64()).unwrap();
        }
        s.finish_release().unwrap();
        assert_eq!(engine.auto_checkpoints(), 0);
        assert!(engine.journal_ops() > 0, "nothing folded the history");
        let _ = std::fs::remove_dir_all(root2);
    }

    #[test]
    fn group_commit_engine_counts_buffered_ops_and_flushes_on_close() {
        use crate::storage::FsBackend;
        let root = crate::util::scratch_dir("engine-group-commit");
        let costs = vec![pd(1.0, 4.0), pd(3.0, 0.5)];
        let backend = FsBackend::open(&root, costs.clone(), false)
            .unwrap()
            .with_sync(false);
        let engine = Engine::builder()
            .topology(TierTopology::from_costs(costs).unwrap())
            .backend(Box::new(backend))
            .charge_rent(false)
            .group_commit(true)
            .build()
            .unwrap();
        let mut rng = Rng::new(5);
        let mut s = engine
            .open_stream(SessionSpec::new(40, 4).with_rent(false))
            .unwrap();
        for _ in 0..40 {
            s.observe(rng.next_f64()).unwrap();
        }
        // buffered records count as committed work for checkpoint policy
        assert!(engine.journal_ops() > 0);
        s.finish().unwrap();
        // a stream close is a forced barrier: nothing may stay buffered
        assert_eq!(engine.journal_buffered(), 0, "close left buffered ops");
        engine.journal_flush().unwrap();
        assert_eq!(engine.journal_buffered(), 0);
        let _ = std::fs::remove_dir_all(root);
    }

    #[test]
    fn reopened_backend_continues_the_id_sequence() {
        use crate::storage::FsBackend;
        let root = crate::util::scratch_dir("next-id");
        let costs = vec![pd(1.0, 4.0), pd(3.0, 0.5)];
        let topo = TierTopology::from_costs(costs.clone()).unwrap();
        {
            let backend = FsBackend::open(&root, costs.clone(), false)
                .unwrap()
                .with_sync(false);
            let engine = Engine::builder()
                .topology(topo.clone())
                .backend(Box::new(backend))
                .charge_rent(false)
                .build()
                .unwrap();
            let mut s = engine
                .open_stream(SessionSpec::new(10, 2).with_rent(false))
                .unwrap();
            assert_eq!(s.id(), 0);
            for i in 0..10 {
                s.observe(i as f64).unwrap();
            }
            s.finish().unwrap(); // residents stay: the journal knows stream 0
        }
        // reopen the same root: the replayed stream ids must not be reissued
        let backend =
            FsBackend::open(&root, costs.clone(), false).unwrap().with_sync(false);
        let engine = Engine::builder()
            .topology(topo)
            .backend(Box::new(backend))
            .charge_rent(false)
            .build()
            .unwrap();
        let s = engine
            .open_stream(SessionSpec::new(10, 2).with_rent(false))
            .unwrap();
        assert_eq!(s.id(), 1, "replayed stream 0 must keep its documents");
        s.finish().unwrap();
        let _ = std::fs::remove_dir_all(root);
    }

    #[test]
    fn policy_mode_requires_exclusive_engine() {
        use crate::policy::SingleTier;
        // multi-session engine: policy-mode observation is rejected
        let engine = two_tier_engine(None);
        let mut a = engine.open_stream(SessionSpec::new(20, 2)).unwrap();
        let _b = engine.open_stream(SessionSpec::new(20, 2)).unwrap();
        let mut p = SingleTier::new(TierId::A);
        assert!(a.observe_with_policy(0.5, &mut p).is_err());

        // exclusive engine: policy mode works, and then locks out opens
        let engine = two_tier_engine(None);
        let mut solo = engine.open_stream(SessionSpec::new(20, 2)).unwrap();
        solo.observe_with_policy(0.5, &mut p).unwrap();
        assert!(
            engine.open_stream(SessionSpec::new(20, 2)).is_err(),
            "a policy-driven session owns the engine exclusively"
        );
    }
}
